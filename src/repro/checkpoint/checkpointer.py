"""Sharded, atomic, async-capable checkpointing.

Layout (one directory per step):
    <root>/step_000123.tmp/      — written first
        manifest.json            — pytree structure + shapes/dtypes + specs
        shard_<host>.npz         — this host's param shards (flat key → array)
    <root>/step_000123/          — atomic rename AFTER fsync (commit point)

Restart-safe: readers only ever see committed directories; a crash mid-write
leaves a .tmp that is garbage-collected on the next save. Restore reshards
automatically: the manifest stores *logical* PartitionSpecs, so loading onto
a different mesh (elastic shrink/grow) just re-applies the policy — this is
what makes elastic scaling cheap (DESIGN.md §4).

On multi-host deployments each host writes only the shards it owns
(``jax.experimental.multihost_utils`` handles the barrier); in this
single-process environment host 0 owns everything, but the layout and commit
protocol are the production ones.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, root: str, async_save: bool = True):
        self.root = root
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, specs: Any = None,
             extra: Optional[Dict] = None) -> str:
        """Snapshot on the host, then write (optionally) in the background —
        training continues while bytes hit disk."""
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, specs, extra))
            self._thread.start()
        else:
            self._write(step, host_tree, specs, extra)
        return self._final_dir(step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _final_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def _write(self, step: int, host_tree, specs, extra):
        final = self._final_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat, _ = _flatten_with_paths(host_tree)
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in flat.items()},
            "extra": extra or {},
        }
        if specs is not None:
            sflat, _ = _flatten_with_paths(specs)
            manifest["specs"] = {k: [list(ax) if isinstance(ax, tuple)
                                     else ax for ax in tuple(v)]
                                 for k, v in sflat.items()}
        np.savez(os.path.join(tmp, "shard_0.npz"),
                 **{k.replace("/", "|"): v for k, v in flat.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # commit point (atomic)

    # -- restore ----------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    steps.append(int(d[5:]))
                except ValueError:
                    pass
        return max(steps) if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs), placing shards per `shardings` if given."""
        d = self._final_dir(step)
        data = np.load(os.path.join(d, "shard_0.npz"))
        flat_like, treedef = _flatten_with_paths(like)
        leaves = []
        for key in flat_like:
            arr = data[key.replace("/", "|")]
            leaves.append(arr)
        restored = jax.tree_util.tree_unflatten(
            treedef, leaves)
        if shardings is not None:
            restored = jax.tree.map(
                lambda a, s: jax.device_put(a, s), restored, shardings)
        return restored

    def gc(self, keep: int):
        steps = sorted(s for s in (self.latest_step(),) if s is not None)
        all_steps = sorted(int(d[5:]) for d in os.listdir(self.root)
                           if d.startswith("step_") and not
                           d.endswith(".tmp"))
        for s in all_steps[:-keep] if keep else []:
            shutil.rmtree(self._final_dir(s), ignore_errors=True)
        for d in os.listdir(self.root):   # orphaned tmp dirs from crashes
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.root, d),
                              ignore_errors=True)
