from repro.checkpoint.checkpointer import Checkpointer  # noqa: F401
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
