"""Checkpoint policy: periodic saves, keep-N, auto-resume, preemption flush.

The training loop calls ``maybe_save(step, state)`` every step;
``restore_or_init`` picks up the newest committed checkpoint — together they
make the train loop restartable at any point (kill -9 included, thanks to
the atomic-rename commit in Checkpointer).
"""
from __future__ import annotations

import signal
from typing import Any, Callable, Optional

from repro.checkpoint.checkpointer import Checkpointer


class CheckpointManager:
    def __init__(self, root: str, every: int = 100, keep: int = 3,
                 async_save: bool = True):
        self.ckpt = Checkpointer(root, async_save=async_save)
        self.every = every
        self.keep = keep
        self._preempted = False

    def install_preemption_handler(self):
        """SIGTERM (the preemption signal on cloud TPU/TRN fleets) sets a
        flag; the loop checkpoints and exits cleanly at the next step edge."""
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    @property
    def preempted(self) -> bool:
        return self._preempted

    def maybe_save(self, step: int, tree: Any, specs: Any = None,
                   force: bool = False) -> bool:
        if force or self._preempted or (self.every and step % self.every == 0
                                        and step > 0):
            self.ckpt.save(step, tree, specs=specs)
            self.ckpt.gc(self.keep)
            return True
        return False

    def restore_or_init(self, init_fn: Callable[[], Any],
                        shardings: Any = None):
        """→ (state, start_step). Resumes from the latest commit if any."""
        latest = self.ckpt.latest_step()
        if latest is None:
            return init_fn(), 0
        like = init_fn()
        state = self.ckpt.restore(latest, like, shardings=shardings)
        return state, latest

    def finalize(self):
        self.ckpt.wait()
