"""Pallas TPU kernels for the perf-critical paths.

Each kernel package has:
  ref.py    — pure-jnp oracle (also the CPU/dry-run lowering path)
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd dispatching wrapper (TPU → kernel, CPU → ref;
              `interpret=True` available everywhere for validation)
"""
