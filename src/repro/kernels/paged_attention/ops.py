"""Dispatcher: TPU → Pallas flash-decode over translated pages; CPU → ref."""
from __future__ import annotations

import jax

from repro.kernels.paged_attention import ref
from repro.kernels.paged_attention.kernel import paged_attention_kernel


def paged_attention(q, k_pool, v_pool, page_map, lengths, scale: float,
                    force: str = "auto"):
    on_tpu = jax.default_backend() == "tpu"
    if force == "kernel" or (force == "auto" and on_tpu):
        return paged_attention_kernel(q, k_pool, v_pool, page_map, lengths,
                                      scale)
    if force == "interpret":
        return paged_attention_kernel(q, k_pool, v_pool, page_map, lengths,
                                      scale, interpret=True)
    return ref.paged_attention_ref(q, k_pool, v_pool, page_map, lengths,
                                   scale)
