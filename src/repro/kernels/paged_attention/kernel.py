"""Pallas TPU kernel: paged decode attention (flash-decode over the
two-stage-translated page list).

The page table (already fused/translated: logical → host slot) is a
*scalar-prefetch* operand: the BlockSpec index_map of the K/V pool operands
reads it to stream exactly the pages owned by the request — KV pages never
materialize contiguously (contrast the jnp ref which gathers).

Grid: (B, n_pages) — last dim sequential on TPU, so the online-softmax
state (m, l, acc) lives in VMEM scratch across the page steps of a request.

VMEM budget per step: one K page + one V page
  = 2 × page(64) × KV(≤16) × hd(≤256) × 2B ≈ 1 MiB, well under 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(page_map_ref, len_ref,          # scalar prefetch
            q_ref, k_ref, v_ref,            # blocks (leading dim 1)
            o_ref,                          # output block (leading dim 1)
            m_ref, l_ref, acc_ref):         # VMEM scratch
    b = pl.program_id(0)
    p = pl.program_id(1)
    n_pages = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    page, KV, hd = k_ref.shape[1], k_ref.shape[2], k_ref.shape[3]
    H = q_ref.shape[1]
    G = H // KV

    q = q_ref[0].astype(jnp.float32).reshape(KV, G, hd)
    k = k_ref[0].astype(jnp.float32)          # [page, KV, hd]
    v = v_ref[0].astype(jnp.float32)

    length = len_ref[b]
    mapped = page_map_ref[b, p] >= 0
    t_idx = p * page + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
    valid = (t_idx < length) & mapped

    s = jnp.einsum("kgh,tkh->kgt", q, k)      # [KV, G, page]
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_prev = m_ref[...]                       # [KV, G]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new[..., None])
    pexp = jnp.where(valid[None, None, :], pexp, 0.0)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + \
        jnp.einsum("kgt,tkh->kgh", pexp, v)

    @pl.when(p == n_pages - 1)
    def _fin():
        denom = jnp.maximum(l_ref[...], 1e-20)[..., None]
        o_ref[...] = (acc_ref[...] / denom).reshape(1, H, hd).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention_kernel(q, k_pool, v_pool, page_map, lengths,
                           scale: float, interpret: bool = False):
    """q [B,H,hd]; {k,v}_pool [slots,page,KV,hd]; page_map [B,n_pages] int32
    (host slots, -1 unmapped); lengths [B] int32 → [B,H,hd]."""
    B, H, hd = q.shape
    page, KV = k_pool.shape[1], k_pool.shape[2]
    n_pages = page_map.shape[1]
    q = (q.astype(jnp.float32) * scale).astype(q.dtype)
    G = H // KV

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pages),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, p, pm, ln: (b, 0, 0)),
            # stream exactly the page named by the (prefetched) page table
            pl.BlockSpec((1, page, KV, hd),
                         lambda b, p, pm, ln: (jnp.maximum(pm[b, p], 0),
                                               0, 0, 0)),
            pl.BlockSpec((1, page, KV, hd),
                         lambda b, p, pm, ln: (jnp.maximum(pm[b, p], 0),
                                               0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, p, pm, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G, hd), jnp.float32),
        ],
    )

    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(page_map, lengths, q, k_pool, v_pool)
