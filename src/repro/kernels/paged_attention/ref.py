"""jnp oracle for paged decode attention (GQA, online-softmax-free)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pool, v_pool, page_map, lengths, scale):
    """q [B,H,hd]; {k,v}_pool [slots, page, KV, hd]; page_map [B, n_pages]
    int32 (host slots, -1 unmapped); lengths [B] → out [B,H,hd]."""
    B, H, hd = q.shape
    page = k_pool.shape[1]
    KV = k_pool.shape[2]
    G = H // KV

    def one(qb, pages_b, len_b):
        slots = jnp.maximum(pages_b, 0)
        k = k_pool[slots]                       # [n_pages, page, KV, hd]
        v = v_pool[slots]
        valid_page = (pages_b >= 0)[:, None]
        T = k.shape[0] * page
        k = k.reshape(T, KV, hd)
        v = v.reshape(T, KV, hd)
        t_idx = jnp.arange(T)
        mask = (t_idx < len_b) & valid_page.repeat(page, 1).reshape(-1)
        qg = qb.reshape(KV, G, hd).astype(jnp.float32)
        scores = jnp.einsum("kgh,tkh->kgt", qg,
                            k.astype(jnp.float32)) * scale
        scores = jnp.where(mask[None, None, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("kgt,tkh->kgh", w, v.astype(jnp.float32))
        return out.reshape(H, hd)

    return jax.vmap(one)(q, page_map, lengths).astype(q.dtype)
