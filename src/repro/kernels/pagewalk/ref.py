"""Pure-jnp oracle for the batched two-stage table walk.

Semantics == repro.core.vmem.page_table.translate (without the fused cache):
stage 1: (tenant, req, page) → tenant_page  (perm-checked)
stage 2: (tenant, tenant_page) → host slot
"""
from __future__ import annotations

import jax.numpy as jnp

PERM_R, PERM_W = 1, 2


def two_stage_translate_ref(vs_table, vs_perm, g_table, tenant, req, page,
                            want_write):
    """vs_table [T,R,P] int32; g_table [T,G] int32; coords [B] int32;
    want_write [B] bool → (slot [B] int32, fault [B] bool, stage [B] int32).
    """
    tp = vs_table[tenant, req, page]
    perm = vs_perm[tenant, req, page]
    want = jnp.where(want_write, PERM_W, PERM_R)
    s1_fault = (tp < 0) | ((perm & want) == 0)
    slot = g_table[tenant, jnp.maximum(tp, 0)]
    s2_fault = ~s1_fault & (slot < 0)
    fault = s1_fault | s2_fault
    out = jnp.where(fault, -1, slot)
    stage = jnp.where(s1_fault, 1, jnp.where(s2_fault, 2, 0))
    return out.astype(jnp.int32), fault, stage.astype(jnp.int32)
