"""Pallas TPU kernel: batched two-stage table walk.

TPU adaptation of gem5's pointer-chasing ``stepWalk()``: both table stages
are VMEM-resident (they are small: stage-1 [T,R,P] and stage-2 [T,G] int32),
and a *vector* of (tenant, req, page) queries is translated per grid step
with masked gathers — the MXU stays free, this is pure VPU/VMEM work.

Block layout:
  queries are blocked along the batch dim (BLOCK_B at a time);
  both tables are broadcast (whole-table blocks) — they fit VMEM easily
  (e.g. 8 tenants × 64 reqs × 512 pages × 4 B = 1 MiB stage-1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PERM_R, PERM_W = 1, 2
BLOCK_B = 512


def _kernel(vs_ref, perm_ref, g_ref, tenant_ref, req_ref, page_ref, w_ref,
            slot_out, fault_out, stage_out):
    t = tenant_ref[...]
    r = req_ref[...]
    p = page_ref[...]
    ww = w_ref[...]
    T, R, P = vs_ref.shape
    G = g_ref.shape[1]
    # stage 1 gather: flatten index (VMEM gather)
    flat1 = (t * R + r) * P + p
    vs_flat = vs_ref[...].reshape(-1)
    perm_flat = perm_ref[...].reshape(-1)
    tp = vs_flat[flat1]
    perm = perm_flat[flat1]
    want = jnp.where(ww != 0, PERM_W, PERM_R)
    s1_fault = (tp < 0) | ((perm & want) == 0)
    # stage 2 gather
    flat2 = t * G + jnp.maximum(tp, 0)
    slot = g_ref[...].reshape(-1)[flat2]
    s2_fault = ~s1_fault & (slot < 0)
    fault = s1_fault | s2_fault
    slot_out[...] = jnp.where(fault, -1, slot).astype(jnp.int32)
    fault_out[...] = fault.astype(jnp.int32)
    stage_out[...] = jnp.where(s1_fault, 1,
                               jnp.where(s2_fault, 2, 0)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def two_stage_translate_kernel(vs_table, vs_perm, g_table, tenant, req, page,
                               want_write, interpret: bool = False):
    B = tenant.shape[0]
    bb = min(BLOCK_B, B)
    grid = (pl.cdiv(B, bb),)
    qspec = pl.BlockSpec((bb,), lambda i: (i,))
    full = lambda a: pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)
    out_shape = [jax.ShapeDtypeStruct((B,), jnp.int32)] * 3
    slot, fault, stage = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[full(vs_table), full(vs_perm), full(g_table),
                  qspec, qspec, qspec, qspec],
        out_specs=[qspec, qspec, qspec],
        out_shape=out_shape,
        interpret=interpret,
    )(vs_table, vs_perm, g_table, tenant, req, page,
      want_write.astype(jnp.int32))
    return slot, fault.astype(bool), stage
