from repro.kernels.pagewalk.ops import two_stage_translate  # noqa: F401
