"""Dispatching wrapper: TPU → Pallas kernel, CPU → jnp ref (identical
semantics; the dry-run lowers this path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pagewalk import ref
from repro.kernels.pagewalk.kernel import two_stage_translate_kernel


def two_stage_translate(vs_table, vs_perm, g_table, tenant, req, page,
                        want_write=None, force: str = "auto"):
    """force: auto | ref | kernel | interpret."""
    if want_write is None:
        want_write = jnp.zeros(tenant.shape, bool)
    on_tpu = jax.default_backend() == "tpu"
    if force == "kernel" or (force == "auto" and on_tpu):
        return two_stage_translate_kernel(vs_table, vs_perm, g_table, tenant,
                                          req, page, want_write)
    if force == "interpret":
        return two_stage_translate_kernel(vs_table, vs_perm, g_table, tenant,
                                          req, page, want_write,
                                          interpret=True)
    return ref.two_stage_translate_ref(vs_table, vs_perm, g_table, tenant,
                                       req, page, want_write)
