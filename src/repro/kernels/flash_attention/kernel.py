"""Pallas TPU kernel: causal flash attention (fwd), GQA + sliding window.

Grid (B*KV*G, nq, nk) — nk sequential (innermost) so online-softmax state
persists in VMEM scratch across K blocks of one Q block. Causal/window
pruning happens at two levels:
  * whole K-blocks past the diagonal are skipped via @pl.when (no FLOPs),
  * the diagonal block applies the elementwise mask.

Block sizes default to (BQ=256, BK=512) — MXU-aligned (≥128) and a VMEM
working set of q(256×hd) + k,v(512×hd) + acc ≈ 0.7 MiB at hd=128, bf16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 256
DEFAULT_BK = 512


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, window, bq, bk):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = iq * bq
    k0 = ik * bk
    # block-level causal/window pruning
    relevant = (k0 <= q0 + bq - 1)
    if window:
        relevant &= (k0 + bk - 1) > (q0 - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)       # [bq, hd]
        k = k_ref[0].astype(jnp.float32)       # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jnp.einsum("qh,kh->qk", q, k) * scale
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos <= qpos
        if window:
            mask &= kpos > (qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        m_ref[...] = m_new
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v

    @pl.when(ik == nk - 1)
    def _fin():
        denom = jnp.maximum(l_ref[...], 1e-20)[:, None]
        o_ref[...] = (acc_ref[...] / denom)[None].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "window", "bq", "bk",
                                    "interpret"))
def flash_attention_kernel(q, k, v, scale: float, window: int = 0,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = False):
    """q [B,S,H,hd]; k,v [B,S,KV,hd] → [B,S,H,hd] (causal, optional SWA)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(bq, S)
    bk = min(bk, S)
    # layout: fold heads into the batch grid dim; q rows per (b, kv, g)
    qf = q.reshape(B, S, KV, G, hd).transpose(0, 2, 3, 1, 4).reshape(
        B * KV * G, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    grid = (B * KV * G, pl.cdiv(S, bq), pl.cdiv(S, bk))

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, bq=bq, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, iq, ik: (h // G, ik, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, iq, ik: (h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV * G, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, KV, G, S, hd).transpose(0, 3, 1, 2, 4).reshape(
        B, S, H, hd)
