"""jnp oracle: causal (optionally windowed) GQA attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, scale, window: int = 0):
    """q [B,S,H,hd]; k,v [B,S,KV,hd] → [B,S,H,hd]. Causal; window>0 = SWA."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = kpos <= qpos
    if window:
        mask &= kpos > (qpos - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)
