"""Dispatcher: TPU → Pallas flash attention; CPU/dry-run → jnp ref."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.kernel import flash_attention_kernel


def flash_attention(q, k, v, scale: float, window: int = 0,
                    force: str = "auto"):
    on_tpu = jax.default_backend() == "tpu"
    if force == "kernel" or (force == "auto" and on_tpu):
        return flash_attention_kernel(q, k, v, scale, window)
    if force == "interpret":
        return flash_attention_kernel(q, k, v, scale, window, interpret=True)
    return ref.flash_attention_ref(q, k, v, scale, window)
