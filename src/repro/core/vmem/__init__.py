from repro.core.vmem.page_table import TwoStageTable  # noqa: F401
from repro.core.vmem.allocator import PagePool  # noqa: F401
from repro.core.vmem.kvcache import PagedKVCache  # noqa: F401
