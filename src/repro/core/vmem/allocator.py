"""Host page-pool allocator with per-tenant quotas (PMP/isolation analogue).

The pool is a fixed set of host slots (the physical KV pages living in HBM).
Allocation state is JAX-array based so the whole fault-handling path can run
inside a jitted scheduler step:

  free_stack: [n_slots] int32 — stack of free slot ids
  top:        scalar — number of free slots
  owner:      [n_slots] int32 — tenant owning each slot (-1 free)
  quota/used: [n_tenants] int32

Isolation invariants (hypothesis-tested in tests/test_vmem.py):
  * a slot is owned by ≤1 tenant,
  * used[t] ≤ quota[t],
  * tenants can never obtain a slot owned by another tenant without it being
    freed first (no leaks across `free_tenant`, the VM-teardown analogue).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class PagePool(NamedTuple):
    free_stack: jnp.ndarray
    top: jnp.ndarray
    owner: jnp.ndarray
    quota: jnp.ndarray
    used: jnp.ndarray

    @staticmethod
    def create(n_slots: int, quotas) -> "PagePool":
        quotas = jnp.asarray(quotas, jnp.int32)
        return PagePool(
            free_stack=jnp.arange(n_slots - 1, -1, -1, dtype=jnp.int32),
            top=jnp.asarray(n_slots, jnp.int32),
            owner=jnp.full((n_slots,), -1, jnp.int32),
            quota=quotas,
            used=jnp.zeros_like(quotas),
        )


def alloc(pool: PagePool, tenant) -> Tuple[PagePool, jnp.ndarray]:
    """Pop a slot for `tenant`. Returns (pool, slot) with slot=-1 on
    exhaustion or quota breach (the caller surfaces a capacity fault —
    the "guest ran out of physical memory" case)."""
    tenant = jnp.asarray(tenant, jnp.int32)
    has_free = pool.top > 0
    under_quota = pool.used[tenant] < pool.quota[tenant]
    ok = has_free & under_quota
    idx = jnp.maximum(pool.top - 1, 0)
    slot = jnp.where(ok, pool.free_stack[idx], -1)
    new = PagePool(
        free_stack=pool.free_stack,
        top=jnp.where(ok, pool.top - 1, pool.top),
        owner=jnp.where(ok, pool.owner.at[slot].set(tenant), pool.owner),
        quota=pool.quota,
        used=jnp.where(ok, pool.used.at[tenant].add(1), pool.used),
    )
    return new, slot


def free(pool: PagePool, slot) -> PagePool:
    """Push a slot back (idempotent for already-free slots)."""
    slot = jnp.asarray(slot, jnp.int32)
    tenant = pool.owner[slot]
    ok = (slot >= 0) & (tenant >= 0)
    idx = pool.top
    return PagePool(
        free_stack=jnp.where(
            ok, pool.free_stack.at[idx].set(slot), pool.free_stack),
        top=jnp.where(ok, pool.top + 1, pool.top),
        owner=jnp.where(ok, pool.owner.at[slot].set(-1), pool.owner),
        quota=pool.quota,
        used=jnp.where(ok, pool.used.at[tenant].add(-1), pool.used),
    )


def free_tenant(pool: PagePool, tenant) -> PagePool:
    """VM teardown: release every slot owned by `tenant` in one shot —
    O(tenant pages) via the stage-2 table, the paper's two-stage win."""
    tenant = jnp.asarray(tenant, jnp.int32)
    mine = pool.owner == tenant
    n = jnp.sum(mine, dtype=jnp.int32)
    slots = jnp.nonzero(mine, size=pool.owner.shape[0], fill_value=-1)[0]
    # push owned slots; -1 fills are ignored by writing at clamped positions
    pos = pool.top + jnp.arange(pool.owner.shape[0], dtype=jnp.int32)
    valid = slots >= 0
    fs = pool.free_stack.at[jnp.where(valid, pos, pool.owner.shape[0])].set(
        jnp.where(valid, slots, 0), mode="drop")
    return PagePool(
        free_stack=fs,
        top=pool.top + n,
        owner=jnp.where(mine, -1, pool.owner),
        quota=pool.quota,
        used=pool.used.at[tenant].set(0),
    )


def check_invariants(pool: PagePool) -> dict:
    """Host-side invariant audit (used by property tests)."""
    owner = jax.device_get(pool.owner)
    used = jax.device_get(pool.used)
    quota = jax.device_get(pool.quota)
    top = int(pool.top)
    free_set = set(jax.device_get(pool.free_stack[:top]).tolist())
    owned = {i for i, o in enumerate(owner.tolist()) if o >= 0}
    ok_disjoint = free_set.isdisjoint(owned)
    ok_cover = len(free_set) + len(owned) == owner.shape[0]
    ok_quota = all(u <= q for u, q in zip(used.tolist(), quota.tolist()))
    counts_ok = all(
        int((owner == t).sum()) == int(used[t]) for t in range(len(used)))
    return {"disjoint": ok_disjoint, "cover": ok_cover, "quota": ok_quota,
            "counts": counts_ok}
