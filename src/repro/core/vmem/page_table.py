"""Two-stage page tables for the KV-cache virtual memory (DESIGN.md §2b).

Mirrors the H extension exactly:

  stage 1 (VS-stage / ``vsatp``):  per-request logical page → tenant-physical
  stage 2 (G-stage  / ``hgatp``):  tenant-physical → host pool slot

Entries carry a valid bit and R/W permission bits (a read-only snapshot page
can be shared between requests — copy-on-write for shared prompt prefixes;
the permission composition matches the TLB discussion in paper §3.5(3)).

All tables are dense int32 arrays so translation is a pair of gathers (the
Pallas ``kernels/pagewalk`` computes the same function with VMEM-resident
tables). The fused cache (logical→host) is the TLB analogue and must be
invalidated by ``hfence()`` after any stage-2 edit — tests assert the
translate-after-hfence == fresh-walk invariant.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

INVALID = jnp.int32(-1)

# permission bits (stage-1 entries)
PERM_R = 1
PERM_W = 2


class TwoStageTable(NamedTuple):
    """Batched tables for T tenants.

    vs_table:  [T, max_req_per_tenant, max_logical_pages] → tenant page id
    vs_perm:   same shape, permission bits
    g_table:   [T, max_tenant_pages]                      → host slot
    fused:     [T, max_req_per_tenant, max_logical_pages] → host slot (TLB)
    fused_ok:  validity of fused entries
    """
    vs_table: jnp.ndarray
    vs_perm: jnp.ndarray
    g_table: jnp.ndarray
    fused: jnp.ndarray
    fused_ok: jnp.ndarray

    @staticmethod
    def create(n_tenants: int, reqs_per_tenant: int, logical_pages: int,
               tenant_pages: int) -> "TwoStageTable":
        shp1 = (n_tenants, reqs_per_tenant, logical_pages)
        return TwoStageTable(
            vs_table=jnp.full(shp1, INVALID, jnp.int32),
            vs_perm=jnp.zeros(shp1, jnp.int32),
            g_table=jnp.full((n_tenants, tenant_pages), INVALID, jnp.int32),
            fused=jnp.full(shp1, INVALID, jnp.int32),
            fused_ok=jnp.zeros(shp1, bool),
        )


class Translation(NamedTuple):
    slot: jnp.ndarray      # host pool slot (or -1)
    fault: jnp.ndarray     # bool: translation fault (either stage)
    stage: jnp.ndarray     # 1 = VS-stage fault, 2 = G-stage fault, 0 = ok


def translate(t: TwoStageTable, tenant, req, page, acc_write=False,
              use_fused=True) -> Translation:
    """Translate (tenant, request, logical page) → host slot.

    Vectorizes over any leading batch dims of tenant/req/page."""
    tenant = jnp.asarray(tenant, jnp.int32)
    req = jnp.asarray(req, jnp.int32)
    page = jnp.asarray(page, jnp.int32)
    fused = t.fused[tenant, req, page]
    fused_ok = t.fused_ok[tenant, req, page]
    # stage 1
    tp = t.vs_table[tenant, req, page]
    perm = t.vs_perm[tenant, req, page]
    want = jnp.where(acc_write, PERM_W, PERM_R)
    s1_fault = (tp < 0) | ((perm & want) == 0)
    # stage 2 — isolation: a tenant can only name its own g_table row
    slot = t.g_table[tenant, jnp.maximum(tp, 0)]
    s2_fault = ~s1_fault & (slot < 0)
    walk_slot = jnp.where(s1_fault | s2_fault, INVALID, slot)
    out_slot = jnp.where(use_fused & fused_ok, fused, walk_slot)
    fault = jnp.where(use_fused & fused_ok, False, s1_fault | s2_fault)
    stage = jnp.where(use_fused & fused_ok, 0,
                      jnp.where(s1_fault, 1, jnp.where(s2_fault, 2, 0)))
    return Translation(slot=out_slot, fault=fault, stage=stage)


def map_stage1(t: TwoStageTable, tenant, req, page, tenant_page,
               perm=PERM_R | PERM_W) -> TwoStageTable:
    """Guest (tenant runtime) edits its own stage-1 table."""
    return t._replace(
        vs_table=t.vs_table.at[tenant, req, page].set(tenant_page),
        vs_perm=t.vs_perm.at[tenant, req, page].set(perm),
        # stage-1 edits invalidate that fused line only
        fused_ok=t.fused_ok.at[tenant, req, page].set(False))


def map_stage2(t: TwoStageTable, tenant, tenant_page, slot) -> TwoStageTable:
    """Hypervisor (scheduler) maps a tenant page to a host slot."""
    return t._replace(g_table=t.g_table.at[tenant, tenant_page].set(slot))


def unmap_stage2(t: TwoStageTable, tenant, tenant_page) -> TwoStageTable:
    return t._replace(
        g_table=t.g_table.at[tenant, tenant_page].set(INVALID))


def hfence(t: TwoStageTable, tenant=None) -> TwoStageTable:
    """hfence.gvma analogue: invalidate fused (TLB) entries — all tenants or
    one tenant's."""
    if tenant is None:
        return t._replace(fused_ok=jnp.zeros_like(t.fused_ok))
    return t._replace(fused_ok=t.fused_ok.at[tenant].set(False))


def fill_fused(t: TwoStageTable, tenant, req, page) -> TwoStageTable:
    """Populate the fused cache for given coordinates (post-walk TLB fill)."""
    tr = translate(t, tenant, req, page, use_fused=False)
    ok = ~tr.fault
    return t._replace(
        fused=t.fused.at[tenant, req, page].set(
            jnp.where(ok, tr.slot, INVALID)),
        fused_ok=t.fused_ok.at[tenant, req, page].set(ok))


def translate_block(t: TwoStageTable, tenant, req, n_pages: int,
                    acc_write=False) -> Translation:
    """Translate all logical pages [0, n_pages) of one request — the decode
    path (gathers the whole per-request page list at once)."""
    pages = jnp.arange(n_pages, dtype=jnp.int32)
    return translate(t, jnp.full((n_pages,), tenant, jnp.int32),
                     jnp.full((n_pages,), req, jnp.int32), pages,
                     acc_write=acc_write)
