"""Paged KV cache backed by the two-stage tables (DESIGN.md §2b).

The pool holds KV pages for all tenants:
    k_pool, v_pool: [n_slots, page_size, n_kv_heads, head_dim]

A request's logical page p is resolved via TwoStageTable.translate
(tenant-local stage 1 → host stage 2); decode attention gathers the
translated slots. Writes go through the same translation with W permission.

Faults (unmapped logical page / tenant page without a host slot) surface to
the scheduler which allocates via PagePool and edits the tables — the
trap-and-emulate loop of the H extension, in scheduler form:

    guest page fault  →  stage-1 edit by the tenant runtime (map_stage1)
    G-stage fault     →  alloc(pool) + map_stage2 by the "hypervisor"
                          then hfence(tenant) to keep the fused cache sound
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.vmem import allocator as AL
from repro.core.vmem import page_table as PT


class PagedKVCache(NamedTuple):
    k_pool: jnp.ndarray      # [slots, page, kv_heads, head_dim]
    v_pool: jnp.ndarray
    tables: PT.TwoStageTable
    pool: AL.PagePool
    page_size: int

    @staticmethod
    def create(n_slots: int, page_size: int, n_kv_heads: int, head_dim: int,
               n_tenants: int, reqs_per_tenant: int, logical_pages: int,
               tenant_pages: int, quotas=None, dtype=jnp.bfloat16):
        quotas = quotas if quotas is not None else [tenant_pages] * n_tenants
        return PagedKVCache(
            k_pool=jnp.zeros((n_slots, page_size, n_kv_heads, head_dim),
                             dtype),
            v_pool=jnp.zeros((n_slots, page_size, n_kv_heads, head_dim),
                             dtype),
            tables=PT.TwoStageTable.create(n_tenants, reqs_per_tenant,
                                           logical_pages, tenant_pages),
            pool=AL.PagePool.create(n_slots, quotas),
            page_size=page_size,
        )


# ---------------------------------------------------------------------------
# scheduler-side fault handling (the hypervisor loop)
# ---------------------------------------------------------------------------

def ensure_mapped(kv: PagedKVCache, tenant: int, req: int,
                  page: int) -> Tuple["PagedKVCache", bool]:
    """Host-side: make (tenant, req, page) resolvable, allocating through
    both stages as needed. Returns (kv, ok)."""
    tr = PT.translate(kv.tables, tenant, req, page, use_fused=False)
    if not bool(tr.fault):
        return kv, True
    tables, pool = kv.tables, kv.pool
    if int(tr.stage) == 1:
        # stage-1 fault: tenant runtime maps logical → next tenant page.
        # pick the first unmapped tenant page (host-side python is fine here;
        # this is the control plane, not the data plane)
        g_row = jax.device_get(tables.g_table[tenant])
        vs_row = jax.device_get(tables.vs_table[tenant, req])
        used = set(int(x) for x in vs_row.tolist() if x >= 0)
        cand = [i for i in range(g_row.shape[0]) if i not in used]
        if not cand:
            return kv, False
        tp = cand[0]
        tables = PT.map_stage1(tables, tenant, req, page, tp)
        tr = PT.translate(tables, tenant, req, page, use_fused=False)
    if bool(tr.fault):  # stage-2: hypervisor allocates a host slot
        tp = int(jax.device_get(tables.vs_table[tenant, req, page]))
        pool, slot = AL.alloc(pool, tenant)
        if int(slot) < 0:
            return kv._replace(tables=tables, pool=pool), False
        tables = PT.map_stage2(tables, tenant, tp, slot)
        tables = PT.hfence(tables, tenant)
    tables = PT.fill_fused(tables, tenant, req, page)
    return kv._replace(tables=tables, pool=pool), True


def evict_tenant(kv: PagedKVCache, tenant: int) -> "PagedKVCache":
    """Tear down a tenant: one stage-2 sweep + pool free — O(tenant pages),
    independent of how many requests/logical pages the tenant had."""
    pool = AL.free_tenant(kv.pool, tenant)
    tables = kv.tables._replace(
        g_table=kv.tables.g_table.at[tenant].set(PT.INVALID),
        vs_table=kv.tables.vs_table.at[tenant].set(PT.INVALID),
        vs_perm=kv.tables.vs_perm.at[tenant].set(0))
    tables = PT.hfence(tables, tenant)
    return kv._replace(tables=tables, pool=pool)


# ---------------------------------------------------------------------------
# data plane (jittable)
# ---------------------------------------------------------------------------

def write_token(kv: PagedKVCache, tenant, req, pos, k, v):
    """Append one token's K/V at sequence position `pos` (page must be
    mapped): k,v [n_kv_heads, head_dim]."""
    page = pos // kv.page_size
    off = pos % kv.page_size
    tr = PT.translate(kv.tables, tenant, req, page, acc_write=True)
    slot = jnp.maximum(tr.slot, 0)
    k_pool = kv.k_pool.at[slot, off].set(
        jnp.where(tr.fault, kv.k_pool[slot, off], k.astype(kv.k_pool.dtype)))
    v_pool = kv.v_pool.at[slot, off].set(
        jnp.where(tr.fault, kv.v_pool[slot, off], v.astype(kv.v_pool.dtype)))
    return kv._replace(k_pool=k_pool, v_pool=v_pool), tr.fault


def gather_kv(kv: PagedKVCache, tenant, req, n_pages: int):
    """Decode-side gather: [n_pages*page, kv_heads, hd] K/V for one request.
    Unmapped pages read as zeros (masked by length in attention)."""
    tr = PT.translate_block(kv.tables, tenant, req, n_pages)
    slots = jnp.maximum(tr.slot, 0)
    k = kv.k_pool[slots]                     # [pages, page, kvh, hd]
    v = kv.v_pool[slots]
    mask = (~tr.fault)[:, None, None, None]
    k = jnp.where(mask, k, 0).reshape(-1, *kv.k_pool.shape[2:])
    v = jnp.where(mask, v, 0).reshape(-1, *kv.v_pool.shape[2:])
    return k, v, tr


def paged_decode_attention(kv: PagedKVCache, tenant, req, q, length,
                           scale: float):
    """Single-request decode attention through the two-stage translation.

    q: [n_heads, head_dim]; length: valid tokens. Returns [n_heads, hd].
    (The Pallas kernels/paged_attention computes this without materializing
    the gather; this jnp path is the oracle.)"""
    n_pages = kv.tables.fused.shape[-1]
    k, v, _ = gather_kv(kv, tenant, req, n_pages)
    H = q.shape[0]
    KV = k.shape[1]
    G = H // KV
    qf = q.reshape(KV, G, -1).astype(jnp.float32)
    kf = k.astype(jnp.float32)               # [T, KV, hd]
    scores = jnp.einsum("kgh,tkh->kgt", qf, kf) * scale
    t_idx = jnp.arange(kf.shape[0])
    scores = jnp.where(t_idx[None, None, :] < length, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("kgt,tkh->kgh", w, v.astype(jnp.float32))
    return out.reshape(H, -1).astype(q.dtype)
