"""The paper's contribution:

``repro.core.hext`` — bit-accurate, batched (vmap-over-harts) functional
simulator of the RISC-V H (hypervisor) extension: CSR file with WARL masks /
aliases / VS swapping, trap & interrupt delegation, two-stage Sv39/Sv39x4
translation, two-stage TLB, hypervisor load/store instructions, and a mini
type-1 hypervisor ("xvisor-lite") running MiBench-like guest workloads.

``repro.core.vmem`` — the TPU-native lift of the same mechanism: two-stage
paged virtual memory for multi-tenant LLM KV caches.
"""
