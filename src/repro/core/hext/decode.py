"""Table-driven instruction decode → flat micro-op record (DESIGN.md §7).

The old decode was a stack of nested ``op == const`` predicate chains
interleaved through one 650-line executor.  This module factors the
decode into its own pipeline stage: host-built numpy lookup tables over
the 7-bit major opcode are gathered with ``jnp.take`` to expand each
32-bit instruction word into a :class:`MicroOp` — opclass index,
register selects, funct fields, and the format-selected immediate — and
the executor becomes a set of uniform per-opclass contributors keyed on
``uop.cls`` (see ``isa.execute_uop``).

The same tables back :func:`decode_word`, a pure-Python (no-JAX) decoder
importable by the oracle differ and the decode-table property tests, so
the traced and host decoders can never drift structurally.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.hext.bits import sext, u64

# --- opclass indices ---------------------------------------------------------
# ~a dozen uniform classes; the executor dispatches one contributor per
# class (masked merge — under vmap a lax.switch degenerates to computing
# every branch anyway, so the merge IS the dispatch; the real
# short-circuiting happens at batch level in machine.step's cond-gated
# SYS/trap/walk phases).
(CLS_ILLEGAL, CLS_ALU, CLS_ALU32, CLS_LUI, CLS_AUIPC, CLS_JAL, CLS_JALR,
 CLS_BRANCH, CLS_LOAD, CLS_STORE, CLS_SYSTEM, CLS_FENCE,
 N_CLS) = range(13)

CLS_NAMES = ("illegal", "alu", "alu32", "lui", "auipc", "jal", "jalr",
             "branch", "load", "store", "system", "fence")

# --- immediate formats -------------------------------------------------------
(IMM_NONE, IMM_I, IMM_S, IMM_B, IMM_U, IMM_J, N_IMM) = range(7)

# --- host-built lookup tables over the 7-bit major opcode -------------------
_OPC = {
    0x33: (CLS_ALU, IMM_NONE),      # OP
    0x13: (CLS_ALU, IMM_I),         # OP-IMM
    0x3B: (CLS_ALU32, IMM_NONE),    # OP-32
    0x1B: (CLS_ALU32, IMM_I),       # OP-IMM-32
    0x37: (CLS_LUI, IMM_U),
    0x17: (CLS_AUIPC, IMM_U),
    0x6F: (CLS_JAL, IMM_J),
    0x67: (CLS_JALR, IMM_I),
    0x63: (CLS_BRANCH, IMM_B),
    0x03: (CLS_LOAD, IMM_I),
    0x23: (CLS_STORE, IMM_S),
    0x73: (CLS_SYSTEM, IMM_NONE),   # CSR / priv / hlv-hsv / fences(V)
    0x0F: (CLS_FENCE, IMM_NONE),    # FENCE / FENCE.I: architectural no-op
}

OPCLASS_TAB = np.zeros(128, np.int32)
IMMFMT_TAB = np.zeros(128, np.int32)
for _op, (_cls, _fmt) in _OPC.items():
    OPCLASS_TAB[_op] = _cls
    IMMFMT_TAB[_op] = _fmt

# uses-immediate-as-ALU-operand (OP-IMM forms): imm replaces rs2
ALU_IMM_TAB = np.zeros(128, bool)
ALU_IMM_TAB[0x13] = ALU_IMM_TAB[0x1B] = True


class MicroOp(NamedTuple):
    """Flat decoded record for one 32-bit instruction word.

    All fields are per-hart scalars (or a leading batch dim): ``cls`` is
    the opclass index (``CLS_*``), ``rd``/``rs1``/``rs2`` are register
    selects (int32), ``f3``/``f7`` the funct fields (uint64 to match the
    executor's compares), ``imm`` the format-selected immediate (uint64,
    sign-extended), ``alu_imm`` whether the ALU b-operand is ``imm``
    (OP-IMM forms), and ``instr`` the raw word (tval/tinst material).
    """

    cls: jnp.ndarray      # int32 opclass
    rd: jnp.ndarray       # int32
    rs1: jnp.ndarray      # int32
    rs2: jnp.ndarray      # int32
    f3: jnp.ndarray       # uint64
    f7: jnp.ndarray       # uint64
    imm: jnp.ndarray      # uint64 (sign-extended per format)
    alu_imm: jnp.ndarray  # bool: ALU b-operand is imm
    instr: jnp.ndarray    # uint64 raw instruction word


_OPCLASS_J = jnp.asarray(OPCLASS_TAB)
_IMMFMT_J = jnp.asarray(IMMFMT_TAB)
_ALUIMM_J = jnp.asarray(ALU_IMM_TAB)


def imm_fields(instr):
    """The five immediate encodings of `instr` (each sign-extended)."""
    imm_i = sext(instr >> u64(20), 12)
    imm_s = sext(((instr >> u64(20)) & ~u64(0x1F)) |
                 ((instr >> u64(7)) & u64(0x1F)), 12)
    imm_b = sext((((instr >> u64(31)) & u64(1)) << u64(12)) |
                 (((instr >> u64(7)) & u64(1)) << u64(11)) |
                 (((instr >> u64(25)) & u64(0x3F)) << u64(5)) |
                 (((instr >> u64(8)) & u64(0xF)) << u64(1)), 13)
    imm_u = sext(instr & u64(0xFFFFF000), 32)
    imm_j = sext((((instr >> u64(31)) & u64(1)) << u64(20)) |
                 (((instr >> u64(12)) & u64(0xFF)) << u64(12)) |
                 (((instr >> u64(20)) & u64(1)) << u64(11)) |
                 (((instr >> u64(21)) & u64(0x3FF)) << u64(1)), 21)
    return imm_i, imm_s, imm_b, imm_u, imm_j


def decode(instr) -> MicroOp:
    """Expand one instruction word into a :class:`MicroOp` (traced).

    Table gathers (``jnp.take``) pick the opclass and immediate format;
    register/funct fields are fixed-position extracts.  Works on scalar
    words; vmap for a batch.
    """
    instr = u64(instr)
    op7 = (instr & u64(0x7F)).astype(jnp.int32)
    cls = jnp.take(_OPCLASS_J, op7)
    fmt = jnp.take(_IMMFMT_J, op7)
    alu_imm = jnp.take(_ALUIMM_J, op7)
    imm_i, imm_s, imm_b, imm_u, imm_j = imm_fields(instr)
    imm = jnp.take(jnp.stack([u64(0), imm_i, imm_s, imm_b, imm_u, imm_j]),
                   fmt)
    return MicroOp(
        cls=cls,
        rd=((instr >> u64(7)) & u64(31)).astype(jnp.int32),
        rs1=((instr >> u64(15)) & u64(31)).astype(jnp.int32),
        rs2=((instr >> u64(20)) & u64(31)).astype(jnp.int32),
        f3=(instr >> u64(12)) & u64(7),
        f7=(instr >> u64(25)) & u64(0x7F),
        imm=imm,
        alu_imm=alu_imm,
        instr=instr,
    )


# ---------------------------------------------------------------------------
# pure-Python decoder over the SAME tables (oracle differ / property tests)
# ---------------------------------------------------------------------------

def _sext_py(x: int, bits: int) -> int:
    x &= (1 << bits) - 1
    m = 1 << (bits - 1)
    return ((x ^ m) - m) & ((1 << 64) - 1)


def decode_word(word: int) -> dict:
    """Host-side decode of one instruction word via the same tables.

    Returns a plain dict mirroring :class:`MicroOp` (ints), so the
    oracle differ and the decode-table sweep tests can compare the
    traced decode against an independent reference without JAX.
    """
    word &= 0xFFFFFFFF
    op7 = word & 0x7F
    fmt = int(IMMFMT_TAB[op7])
    imm_i = _sext_py(word >> 20, 12)
    imm_s = _sext_py(((word >> 20) & ~0x1F) | ((word >> 7) & 0x1F), 12)
    imm_b = _sext_py((((word >> 31) & 1) << 12) | (((word >> 7) & 1) << 11) |
                     (((word >> 25) & 0x3F) << 5) |
                     (((word >> 8) & 0xF) << 1), 13)
    imm_u = _sext_py(word & 0xFFFFF000, 32)
    imm_j = _sext_py((((word >> 31) & 1) << 20) |
                     (((word >> 12) & 0xFF) << 12) |
                     (((word >> 20) & 1) << 11) |
                     (((word >> 21) & 0x3FF) << 1), 21)
    imm = (0, imm_i, imm_s, imm_b, imm_u, imm_j)[fmt]
    return {
        "cls": int(OPCLASS_TAB[op7]),
        "rd": (word >> 7) & 31,
        "rs1": (word >> 15) & 31,
        "rs2": (word >> 20) & 31,
        "f3": (word >> 12) & 7,
        "f7": (word >> 25) & 0x7F,
        "imm": imm,
        "alu_imm": bool(ALU_IMM_TAB[op7]),
        "instr": word,
    }
