"""Fleet-as-a-service: the hypervisor control plane (DESIGN.md §8).

:class:`FleetService` is a persistent daemon over two fixed-shape
:class:`~repro.core.hext.sim.Fleet` pools — a *pod* pool of preemptive
N-guest scheduler harts and an optional *solo* pool for native/guest
single-tenant runs.  Tenants submit workloads into a queue; a pluggable
:class:`~repro.core.hext.policies.PlacementPolicy` admits and bin-packs
them onto harts; the service then drives the fleet in timeslice-sized
engine runs, interleaving one control round per slice:

    harvest → detect/recover failures → resume parked → shed → evict
            → place → snapshot → run one slice

* **harvest** reads per-guest done flags and checksum mailboxes straight
  from hart memory and retires finished jobs (a finished hart's lane
  returns to the vacant pool);
* **recover** watches per-lane ``instret`` progress — a lane that stops
  retiring instructions for ``fail_after`` rounds is declared dead and
  restored from its last healthy per-lane snapshot (suspect lanes are
  never snapshotted, so the last file always predates the failure), with
  zero lost completed work: harvested jobs stay done, un-harvested guests
  replay from the snapshot and reach the same checksums;
* **resume** splices parked guests (``Fleet.resume_guest``) into free
  same-slot lanes; **shed** rebalances hot harts via
  ``Fleet.migrate_guest``; **evict** parks a victim guest as a per-guest
  checkpoint (``Fleet.park_guest``) when the queue is starved of lanes;
* **place** boots policy-chosen cohorts onto vacant lanes — lanes keep
  the pool's compiled shapes (``Fleet.replace_hart``), so the control
  plane never triggers an XLA recompile after warmup.

Lanes never host mid-flight *new* arrivals: cohorts are formed at
provision time only (the HS scheduler initializes contexts at boot), so
a guest served through the daemon runs under exactly the same scheduler
dynamics as a direct ``Fleet.boot`` — checksums always match the
registry goldens, and whole-cohort lanes match counters bit-identically.

The progress monitor doubles as the straggler accounting that used to
live in the retired ``repro.runtime.fault_tolerance`` scaffolding (its
retry-with-restore supervisor loop became the recover phase here);
``stragglers()`` reports lanes currently behind.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.hext import checkpoint as _checkpoint
from repro.core.hext import programs as _programs
from repro.core.hext.policies import (BinPackPolicy, JobView, LaneView,
                                      PlacementPolicy, size_bucket,
                                      workload_footprint)
from repro.core.hext.sim import (Fleet, HartSpec, HartState, MASK64,
                                 MigrationError, checksum_ok)

__all__ = ["FleetService", "Job", "ServiceError",
           "QUEUED", "RUNNING", "PARKED", "DONE", "REJECTED"]

QUEUED, RUNNING, PARKED, DONE, REJECTED = \
    "queued", "running", "parked", "done", "rejected"
_TERMINAL = (DONE, REJECTED)


class ServiceError(RuntimeError):
    """The control plane hit an unrecoverable inconsistency."""


@dataclasses.dataclass
class Job:
    """One tenant submission and its full lifecycle record."""
    job_id: int
    workload: Any
    name: str
    tenant: int
    mode: str                       # "vm" | "native" | "guest"
    golden: int
    state: str = QUEUED
    submit_slice: int = 0
    start_slice: Optional[int] = None
    done_slice: Optional[int] = None
    lane: Optional[int] = None
    slot: Optional[int] = None
    checksum: Optional[int] = None
    ok: Optional[bool] = None
    parked_path: Optional[str] = None
    events: List[str] = dataclasses.field(default_factory=list)

    @property
    def terminal(self) -> bool:
        return self.state in _TERMINAL

    def time_to_result(self) -> Optional[int]:
        """Slices from submit to completion (None until done)."""
        if self.done_slice is None:
            return None
        return self.done_slice - self.submit_slice


@dataclasses.dataclass
class _Lane:
    active: bool = False
    jobs: List[Optional[int]] = dataclasses.field(default_factory=list)


class _Monitor:
    """Per-lane liveness/progress tracking (instret-based).

    ``observe`` compares a lane's retired-instruction counter against the
    last observation: a live hart always retires instructions (spin loops
    included), so a non-advancing counter across ``observe`` calls marks
    the lane as stalled.  Suspect lanes (stall > 0) are excluded from
    snapshotting and shedding until they either progress or are declared
    dead and recovered."""

    def __init__(self):
        self._last: Dict[int, int] = {}
        self.stall: Dict[int, int] = {}

    def reset(self, lane: int) -> None:
        self._last.pop(lane, None)
        self.stall[lane] = 0

    def drop(self, lane: int) -> None:
        self._last.pop(lane, None)
        self.stall.pop(lane, None)

    def observe(self, lane: int, instret: int) -> int:
        prev = self._last.get(lane)
        if prev is None or instret > prev:
            self.stall[lane] = 0
        else:
            self.stall[lane] = self.stall.get(lane, 0) + 1
        self._last[lane] = int(instret)
        return self.stall[lane]

    def suspect(self, lane: int) -> bool:
        return self.stall.get(lane, 0) > 0


class FleetService:
    """The persistent serving daemon (see module docstring).

    ``n_harts`` preemptive pod lanes of ``guests_per_hart`` slots each,
    plus ``n_solo`` single-tenant lanes for ``mode="native"|"guest"``
    submissions.  ``slice_ticks`` is the engine-run granularity between
    control rounds and must be a multiple of ``chunk``.  ``fail_after``
    is how many progress-free rounds declare a lane dead;
    ``snapshot_every`` bounds how stale a periodic lane snapshot may get
    (control-plane mutations always snapshot in the same round).
    """

    def __init__(self, n_harts: int = 4, guests_per_hart: int = 2,
                 n_solo: int = 0, timeslice: int = 300,
                 slice_ticks: int = 2048, chunk: int = 512,
                 engine: Any = None,
                 policy: Optional[PlacementPolicy] = None,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 4, fail_after: int = 2):
        if n_harts < 1:
            raise ValueError(f"n_harts must be >= 1, got {n_harts}")
        if slice_ticks % chunk:
            raise ValueError(
                f"slice_ticks ({slice_ticks}) must be a multiple of "
                f"chunk ({chunk}) so tick accounting stays exact")
        self.n = int(guests_per_hart)
        self.timeslice = int(timeslice)
        self.slice_ticks = int(slice_ticks)
        self.chunk = int(chunk)
        self.snapshot_every = int(snapshot_every)
        self.fail_after = int(fail_after)
        self.policy = policy or BinPackPolicy()
        self._lay = _programs.sched_layout(self.n)
        self._snapshot_dir = snapshot_dir or tempfile.mkdtemp(
            prefix="fleet-service-")
        os.makedirs(self._snapshot_dir, exist_ok=True)

        vac_pod = self._vacant_state(self._lay.mem_words)
        self._pod = Fleet.from_states(
            [vac_pod] * n_harts,
            [self._vacant_spec() for _ in range(n_harts)], engine=engine)
        self._pod_lanes = [_Lane(jobs=[None] * self.n)
                           for _ in range(n_harts)]
        self._solo: Optional[Fleet] = None
        self._solo_lanes: List[_Lane] = []
        if n_solo:
            vac_solo = self._vacant_state(_programs.MEM_WORDS)
            self._solo = Fleet.from_states(
                [vac_solo] * n_solo,
                [self._vacant_spec() for _ in range(n_solo)], engine=engine)
            self._solo_lanes = [_Lane(jobs=[None]) for _ in range(n_solo)]

        self._jobs: Dict[int, Job] = {}
        self._next_id = 0
        self._queue: List[int] = []
        self._parked: List[int] = []
        self._slices = 0
        self._pod_ran = False
        self._solo_ran = False
        self._pod_mon = _Monitor()
        self._solo_mon = _Monitor()
        self._dirty_pod: set = set()
        self._dirty_solo: set = set()
        self._weights: Dict[str, int] = {}
        self._idle = next((w for w in _programs.WORKLOADS_EXTRA
                           if w.name == "idle"), None)
        self.stats = {"submitted": 0, "rejected": 0, "completed": 0,
                      "failed": 0, "migrations": 0, "parks": 0,
                      "resumes": 0, "recoveries": 0, "balloons": 0}

    # -- construction helpers -----------------------------------------------
    @staticmethod
    def _vacant_state(mem_words: int) -> HartState:
        """A frozen lane: done=True parks it in the engine's done-mask."""
        st = HartState.fresh(mem_words)
        return st.replace(counters=dataclasses.replace(
            st.counters, done=np.ones((), bool)))

    @staticmethod
    def _vacant_spec() -> HartSpec:
        return HartSpec(None, False, "vacant")

    def _weight(self, workload: Any) -> int:
        name = getattr(workload, "name", repr(workload))
        if name not in self._weights:
            self._weights[name] = size_bucket(workload_footprint(workload))
        return self._weights[name]

    # -- public API ---------------------------------------------------------
    @property
    def slices(self) -> int:
        return self._slices

    @property
    def ticks(self) -> int:
        return self._slices * self.slice_ticks

    def job(self, job_id: int) -> Job:
        return self._jobs[job_id]

    def jobs(self) -> List[Job]:
        return [self._jobs[k] for k in sorted(self._jobs)]

    def stragglers(self) -> List[Tuple[str, int, int]]:
        """Lanes currently behind: ``(pool, lane, stall_rounds)``."""
        out = [("pod", lane, s) for lane, s in
               sorted(self._pod_mon.stall.items()) if s > 0]
        out += [("solo", lane, s) for lane, s in
                sorted(self._solo_mon.stall.items()) if s > 0]
        return out

    def submit(self, workload: Any, tenant: int = 0,
               mode: str = "vm") -> int:
        """Queue one workload; returns its job id.  ``mode="vm"`` serves
        it as a scheduler guest on the pod pool; ``"native"``/``"guest"``
        use a dedicated solo lane.  Over-capacity submissions are
        REJECTED by the admission policy (check ``job(id).state``)."""
        if mode not in ("vm", "native", "guest"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode != "vm" and self._solo is None:
            raise ValueError(f"mode {mode!r} needs n_solo > 0")
        jid = self._next_id
        self._next_id += 1
        # weight first: the footprint probe runs write_data, which warms
        # data-dependent workloads before their golden is computed
        self._weight(workload)
        job = Job(job_id=jid, workload=workload,
                  name=getattr(workload, "name", f"job{jid}"),
                  tenant=int(tenant), mode=mode,
                  golden=int(workload.golden()) & MASK64,
                  submit_slice=self._slices)
        self._jobs[jid] = job
        self.stats["submitted"] += 1
        if not self.policy.admit(len(self._queue)):
            job.state = REJECTED
            job.ok = False
            job.events.append(f"s{self._slices}: rejected (queue full)")
            self.stats["rejected"] += 1
            return jid
        self._queue.append(jid)
        job.events.append(f"s{self._slices}: queued")
        return jid

    def inject_hart_failure(self, lane: int, pool: str = "pod") -> None:
        """Test hook: scramble one lane to a powered-off (halted, not
        done) state — its instret freezes, so the progress monitor
        declares it dead after ``fail_after`` rounds and the recover
        phase restores it from its last healthy snapshot."""
        fleet, lanes = self._pool(pool)
        if not (0 <= lane < len(lanes)):
            raise ValueError(f"{pool} lane {lane} out of range")
        mem_words = self._lay.mem_words if pool == "pod" \
            else _programs.MEM_WORDS
        dead = HartState.fresh(mem_words)
        dead = dead.replace(halted=np.ones((), bool))
        fleet.replace_hart(lane, dead)          # spec/bookkeeping untouched
        for jid in lanes[lane].jobs:
            if jid is not None:
                self._jobs[jid].events.append(
                    f"s{self._slices}: hart failure injected on "
                    f"{pool} lane {lane}")

    def step(self) -> None:
        """One control round + one engine slice across both pools."""
        self._harvest()
        self._recover()
        self._resume_parked()
        self._shed()
        self._evict()
        self._place()
        self._snapshot()
        self._advance()
        self._slices += 1

    def drain(self, max_slices: int = 4000) -> bool:
        """Step until every job is terminal (or the budget runs out);
        True iff all terminal jobs completed with their golden."""
        while any(not j.terminal for j in self._jobs.values()):
            if self._slices >= max_slices:
                return False
            self.step()
        return all(j.ok for j in self._jobs.values()
                   if j.state == DONE)

    def metrics(self) -> Dict[str, Any]:
        """Serving metrics: completion counts, control-plane event
        totals, and p50/p99 time-to-result (slices and ticks)."""
        t2r = sorted(j.time_to_result() for j in self._jobs.values()
                     if j.time_to_result() is not None)
        out = dict(self.stats)
        out.update({
            "slices": self._slices,
            "ticks": self.ticks,
            "queued": len(self._queue),
            "parked": len(self._parked),
        })
        if t2r:
            p50 = float(np.percentile(t2r, 50))
            p99 = float(np.percentile(t2r, 99))
            out.update({
                "p50_ttr_slices": p50, "p99_ttr_slices": p99,
                "p50_ttr_ticks": p50 * self.slice_ticks,
                "p99_ttr_ticks": p99 * self.slice_ticks,
            })
        return out

    # -- pool plumbing ------------------------------------------------------
    def _pool(self, pool: str) -> Tuple[Fleet, List[_Lane]]:
        if pool == "pod":
            return self._pod, self._pod_lanes
        if pool == "solo":
            if self._solo is None:
                raise ValueError("service booted with n_solo=0")
            return self._solo, self._solo_lanes
        raise ValueError(f"unknown pool {pool!r}")

    def _gi_done_w(self, slot: int) -> int:
        return (self._lay.ginfo0 + slot * _programs.GINFO_SIZE + 24) >> 3

    def _mailbox_w(self, slot: int) -> int:
        return (self._lay.guest_res + 8 * slot) >> 3

    def _lane_path(self, pool: str, lane: int) -> str:
        return os.path.join(self._snapshot_dir, f"{pool}-lane{lane}.npz")

    def _park_path(self, jid: int) -> str:
        return os.path.join(self._snapshot_dir, f"park-job{jid}.npz")

    # -- control phases -----------------------------------------------------
    def _harvest(self) -> None:
        """Retire finished jobs from hart memory (per-guest mailboxes on
        the pod pool, exit codes on the solo pool); release exited lanes
        back to the vacant pool.  Already-DONE jobs are never touched, so
        a recovery replay cannot un-complete work."""
        harts = self._pod.harts.unwrap()
        mem = np.asarray(harts.mem)
        hart_done = np.asarray(harts.counters.done)
        for lane, lst in enumerate(self._pod_lanes):
            if not lst.active:
                continue
            for slot, jid in enumerate(lst.jobs):
                if jid is None:
                    continue
                job = self._jobs[jid]
                if job.state != RUNNING:
                    continue
                if int(mem[lane, self._gi_done_w(slot)]) != 1:
                    continue
                cks = int(mem[lane, self._mailbox_w(slot)]) & MASK64
                self._finish(job, cks)
                lst.jobs[slot] = None
            if bool(hart_done[lane]):
                lst.active = False
                lst.jobs = [None] * self.n
                self._pod_mon.drop(lane)
                self._dirty_pod.discard(lane)
        if self._solo is None:
            return
        sh = self._solo.harts.unwrap()
        s_done = np.asarray(sh.counters.done)
        s_exit = np.asarray(sh.counters.exit_code)
        for lane, lst in enumerate(self._solo_lanes):
            if not lst.active or not bool(s_done[lane]):
                continue
            jid = lst.jobs[0]
            if jid is not None and self._jobs[jid].state == RUNNING:
                self._finish(self._jobs[jid], int(s_exit[lane]) & MASK64)
            lst.active = False
            lst.jobs = [None]
            self._solo_mon.drop(lane)
            self._dirty_solo.discard(lane)

    def _finish(self, job: Job, checksum: int) -> None:
        job.state = DONE
        job.done_slice = self._slices
        job.checksum = checksum
        job.ok = checksum_ok(checksum, job.golden)
        job.lane = None
        job.events.append(
            f"s{self._slices}: done checksum={checksum:#x} ok={job.ok}")
        self.stats["completed"] += 1
        if not job.ok:
            self.stats["failed"] += 1

    def _recover(self) -> None:
        """Progress-monitor both pools; restore dead lanes from their
        last healthy per-lane snapshot (spec and job bookkeeping are
        unchanged — mutations cannot land on a V=0 lane, so the live
        assignment always matches the snapshot's)."""
        for pool, fleet, lanes, mon, ran in (
                ("pod", self._pod, self._pod_lanes, self._pod_mon,
                 self._pod_ran),
                ("solo", self._solo, self._solo_lanes, self._solo_mon,
                 self._solo_ran)):
            if fleet is None or not ran:
                continue
            instret = np.asarray(fleet.harts.unwrap().counters.instret)
            for lane, lst in enumerate(lanes):
                if not lst.active:
                    continue
                stall = mon.observe(lane, int(instret[lane]))
                if stall < self.fail_after:
                    continue
                path = self._lane_path(pool, lane)
                if not os.path.exists(path):
                    raise ServiceError(
                        f"{pool} lane {lane} is dead with no snapshot "
                        f"at {path!r}")
                state, _ = _checkpoint.load(path, decode_specs=False)
                fleet.replace_hart(lane, state)
                mon.reset(lane)
                self.stats["recoveries"] += 1
                for jid in lst.jobs:
                    if jid is not None:
                        self._jobs[jid].events.append(
                            f"s{self._slices}: lane recovered from "
                            f"snapshot")

    def _pressure(self) -> bool:
        """Capacity pressure: queued VM work with no vacant pod lane."""
        return any(self._jobs[j].mode == "vm" for j in self._queue) and \
            all(l.active for l in self._pod_lanes)

    def _resume_parked(self) -> None:
        """Splice parked guests into free same-slot lanes (FIFO).  While
        capacity pressure persists, parked guests stay parked — resuming
        would undo the eviction and thrash park/resume every round."""
        if self._pressure():
            return
        for jid in list(self._parked):
            job = self._jobs[jid]
            for lane, lst in enumerate(self._pod_lanes):
                if not lst.active or self._pod_mon.suspect(lane):
                    continue
                if lst.jobs[job.slot] is not None:
                    continue
                try:
                    self._pod.resume_guest(lane, job.parked_path,
                                           workload=job.workload)
                except MigrationError:
                    continue               # retry next round / next lane
                self._parked.remove(jid)
                job.state = RUNNING
                job.lane = lane
                job.events.append(
                    f"s{self._slices}: resumed on lane {lane} "
                    f"slot {job.slot}")
                lst.jobs[job.slot] = jid
                self._dirty_pod.add(lane)
                self.stats["resumes"] += 1
                break

    def _lane_views(self) -> List[LaneView]:
        """Healthy active pod lanes as policy views.  A slot is free when
        no job maps to it and its guest info block reads done (never
        scheduled again until something is spliced in)."""
        mem = np.asarray(self._pod.harts.unwrap().mem)
        views = []
        for lane, lst in enumerate(self._pod_lanes):
            if not lst.active or self._pod_mon.suspect(lane):
                continue
            free = tuple(
                s for s in range(self.n)
                if lst.jobs[s] is None
                and int(mem[lane, self._gi_done_w(s)]) == 1)
            views.append(LaneView(lane=lane, jobs=tuple(lst.jobs),
                                  free_slots=free))
        return views

    def _shed(self) -> None:
        """Ask the policy for one migration per round and apply it."""
        views = self._lane_views()
        if len(views) < 2:
            return
        dec = self.policy.shed(views)
        if dec is None:
            return
        jid = self._pod_lanes[dec.src].jobs[dec.slot]
        if jid is None:
            return
        try:
            self._pod.migrate_guest(dec.src, dec.dst, dec.slot)
        except MigrationError:
            return                         # preconditions retry next round
        self._pod_lanes[dec.src].jobs[dec.slot] = None
        self._pod_lanes[dec.dst].jobs[dec.slot] = jid
        job = self._jobs[jid]
        job.lane = dec.dst
        job.events.append(
            f"s{self._slices}: migrated lane {dec.src} -> {dec.dst} "
            f"(slot {dec.slot})")
        self._dirty_pod.update((dec.src, dec.dst))
        self.stats["migrations"] += 1

    def _evict(self) -> None:
        """Under sustained capacity pressure (queued VM jobs, no vacant
        lane, oldest job past the policy's patience), park a victim."""
        vm_queue = [j for j in self._queue
                    if self._jobs[j].mode == "vm"]
        if not vm_queue:
            return
        if any(not l.active for l in self._pod_lanes):
            return                         # placement will use the lane
        oldest = self._slices - min(self._jobs[j].submit_slice
                                    for j in vm_queue)
        if oldest < getattr(self.policy, "partial_after", 0):
            return
        pick = self.policy.victim(self._lane_views())
        if pick is None:
            return
        lane, slot = pick
        jid = self._pod_lanes[lane].jobs[slot]
        if jid is None:
            return
        job = self._jobs[jid]
        try:
            path = self._pod.park_guest(lane, slot, self._park_path(jid))
        except MigrationError:
            return                         # retry next round
        self._pod_lanes[lane].jobs[slot] = None
        job.state = PARKED
        job.lane = None
        job.slot = slot                    # parked guests are slot-bound
        job.parked_path = path
        job.events.append(
            f"s{self._slices}: evicted from lane {lane} slot {slot} "
            f"(parked)")
        self._parked.append(jid)
        self._dirty_pod.add(lane)
        self.stats["parks"] += 1

    def _homeless_parked(self) -> List[Job]:
        """Parked jobs with no live lane offering their slot."""
        views = self._lane_views()
        out = []
        for jid in self._parked:
            job = self._jobs[jid]
            if not any(job.slot in v.free_slots for v in views):
                out.append(job)
        return out

    def _place(self) -> None:
        """Boot policy-packed cohorts onto vacant lanes; solo jobs FIFO
        onto vacant solo lanes.  When parked guests have no live lane to
        resume into and the queue is empty, boot a balloon host: an
        ``idle`` tenant plus ``None`` reservations for the parked slots
        (the scheduler needs at least one live guest to boot)."""
        vacant = [i for i, l in enumerate(self._pod_lanes) if not l.active]
        vm_jobs = [self._jobs[j] for j in self._queue
                   if self._jobs[j].mode == "vm"]
        if vacant and vm_jobs:
            homeless = self._homeless_parked()
            reserved = [j.slot for j in homeless][:len(vacant)]
            queued_views = [
                JobView(job_id=j.job_id, tenant=j.tenant, name=j.name,
                        weight=self._weight(j.workload),
                        age=self._slices - j.submit_slice)
                for j in vm_jobs]
            cohorts = self.policy.pack(queued_views, len(vacant), self.n,
                                       reserved=reserved)
            for lane, cohort in zip(vacant, cohorts):
                self._provision(lane, cohort)
            vacant = [i for i, l in enumerate(self._pod_lanes)
                      if not l.active]
        # pure-resume corner: parked work, empty queue, only vacant lanes
        if vacant and not any(self._jobs[j].mode == "vm"
                              for j in self._queue):
            homeless = self._homeless_parked()
            if homeless and self._idle is not None:
                taken = {j.slot for j in homeless}
                idle_slot = next((s for s in range(self.n)
                                  if s not in taken), homeless[-1].slot)
                cohort: List[Optional[int]] = [None] * self.n
                self._provision(vacant[0], cohort,
                                balloon_slot=idle_slot)
                self.stats["balloons"] += 1
        if self._solo is None:
            return
        solo_vacant = [i for i, l in enumerate(self._solo_lanes)
                       if not l.active]
        solo_jobs = [j for j in self._queue
                     if self._jobs[j].mode in ("native", "guest")]
        for lane, jid in zip(solo_vacant, solo_jobs):
            job = self._jobs[jid]
            state = HartState.boot(job.workload,
                                   guest=(job.mode == "guest"))
            spec = HartSpec(job.workload, job.mode == "guest", job.name)
            self._solo.replace_hart(lane, state, spec)
            self._queue.remove(jid)
            job.state = RUNNING
            job.start_slice = self._slices
            job.lane = lane
            job.events.append(f"s{self._slices}: placed on solo "
                              f"lane {lane} ({job.mode})")
            self._solo_lanes[lane] = _Lane(active=True, jobs=[jid])
            self._solo_mon.reset(lane)
            self._dirty_solo.add(lane)

    def _provision(self, lane: int, cohort: List[Optional[int]],
                   balloon_slot: Optional[int] = None) -> None:
        wls: List[Optional[Any]] = []
        for slot, jid in enumerate(cohort):
            if jid is not None:
                wls.append(self._jobs[jid].workload)
            elif slot == balloon_slot:
                wls.append(self._idle)
            else:
                wls.append(None)
        state = HartState.boot_preemptive(*wls, timeslice=self.timeslice)
        name = "+".join(getattr(w, "name", "~") if w is not None else "~"
                        for w in wls)
        spec = HartSpec(wls[0], True, name, guests=tuple(wls),
                        timeslice=self.timeslice)
        self._pod.replace_hart(lane, state, spec)
        self._pod_lanes[lane] = _Lane(active=True, jobs=list(cohort))
        self._pod_mon.reset(lane)
        self._dirty_pod.add(lane)
        for slot, jid in enumerate(cohort):
            if jid is None:
                continue
            job = self._jobs[jid]
            self._queue.remove(jid)
            job.state = RUNNING
            job.start_slice = self._slices
            job.lane, job.slot = lane, slot
            job.events.append(
                f"s{self._slices}: placed on lane {lane} slot {slot}")

    def _snapshot(self) -> None:
        """Write per-lane snapshots: every lane a control-plane mutation
        dirtied this round, plus a periodic refresh.  Suspect lanes are
        skipped, so the newest file for a lane always predates its
        failure."""
        periodic = (self._slices % self.snapshot_every) == 0
        for pool, fleet, lanes, mon, dirty in (
                ("pod", self._pod, self._pod_lanes, self._pod_mon,
                 self._dirty_pod),
                ("solo", self._solo, self._solo_lanes, self._solo_mon,
                 self._dirty_solo)):
            if fleet is None:
                continue
            for lane, lst in enumerate(lanes):
                if not lst.active or mon.suspect(lane):
                    continue
                if lane not in dirty and not periodic:
                    continue
                _checkpoint.save(self._lane_path(pool, lane),
                                 fleet[lane], [fleet.specs[lane]],
                                 engine_name=getattr(fleet.engine, "name",
                                                     "custom"))
            dirty.clear()

    def _advance(self) -> None:
        self._pod_ran = any(l.active for l in self._pod_lanes)
        if self._pod_ran:
            self._pod.run(self.slice_ticks, self.chunk)
        self._solo_ran = self._solo is not None and \
            any(l.active for l in self._solo_lanes)
        if self._solo_ran:
            self._solo.run(self.slice_ticks, self.chunk)
