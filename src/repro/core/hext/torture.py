"""Randomized differential conformance harness (DESIGN.md §5).

RiescueC-style torture testing: a seeded generator emits randomized
guest/hypervisor scenarios — random ALU/load/store/CSR/HLV-HSV bodies,
random Sv39/Sv39x4 page-table shapes (reserved W=1/R=0 encodings, OOB
ppns, misaligned superpages, dropped U/A/D bits), random privilege entry
points (M/HS/VS/VU/S/U), random delegation masks, and random timer
arming — each compiled to a bootable image with the ``programs`` Asm.

Every scenario is self-terminating by construction: bodies are
straight-line (forward branches only), every trap handler either exits
through the DONE MMIO or ecalls its way down to the M handler, and the
WARL delegation masks make ecall-S/ecall-M undelegable, so no handler
chain can loop.  Pathological cases (WFI with nothing armed, wild jumps
into self-modified code) are bounded by the tick budget — both models
run the same budget, so even a non-terminating scenario is compared
exactly.

The whole corpus boots as ONE batched ``Fleet`` (images padded to a
common memory size so XLA compiles a single executable — see the
recompile pitfall in DESIGN.md §5) and is diffed hart-by-hart against
the pure-Python oracle.  Both legs go through the same first-class
``Fleet`` path: the reference leg is simply the corpus fleet re-run on
the ``OracleEngine`` backend (``engine="oracle"``, DESIGN.md §3).

Repro workflow::

    PYTHONPATH=src python -m repro.core.hext.torture --seed S --count 256
    PYTHONPATH=src python -m repro.core.hext.torture --seed S --case K -v
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.hext import csr as C
from repro.core.hext import oracle
from repro.core.hext.engine import DIFF_COUNTERS as _COUNTERS
from repro.core.hext.programs import (Asm, Image, G_L0, G_L1, G_L2,
                                      S_L0, S_L1, S_L2, SATP_SV39,
                                      PTE_V, PTE_R, PTE_W, PTE_X, PTE_U,
                                      PTE_A, PTE_D, P_KERN, P_GUEST)

# ---------------------------------------------------------------------------
# scenario memory map (identity VA=GPA=PA; 128 KiB per scenario)
# ---------------------------------------------------------------------------
T_MEM_WORDS = 1 << 14          # 128 KiB — one XLA shape for every corpus
T_MEM_BYTES = T_MEM_WORDS * 8
TM_HANDLER = 0x0400            # M trap handler (capture + DONE exit)
TS_HANDLER = 0x0600            # HS/S handler (log scause/stval/htval, ecall)
TVS_HANDLER = 0x0800           # VS handler (log vscause/vstval, ecall)
T_BODY = 0x1000                # randomized body
T_LOG = 0x2000                 # handler fingerprint page (always mapped RW)
T_DATA_PAGES = (0x3000, 0x4000, 0x5000, 0x6000, 0x7000)
MMIO_DONE = 0x10000008

DEFAULT_SEED = 2026
MAX_TICKS = 1536               # 3 × CHUNK — both models run this exact budget
CHUNK = 512

MODES = ("M", "HS", "S", "U", "VS", "VU")

_REGS = (1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 18, 19, 20,
         28, 29, 30)

# CSRs a body may freely read AND write (tvec/atp writes excluded: they can
# redirect traps/translation at a pc the generator cannot see)
_CSR_RW = (0x100, 0x104, 0x106, 0x140, 0x141, 0x142, 0x143, 0x144, 0x14D,
           0x200, 0x204, 0x240, 0x241, 0x242, 0x243, 0x244, 0x24D,
           0x300, 0x302, 0x303, 0x304, 0x306, 0x340, 0x341, 0x342, 0x343,
           0x344, 0x34A, 0x34B, 0x600, 0x602, 0x603, 0x605, 0x606, 0x607,
           0x643, 0x644, 0x645, 0x64A)
# read-only pool (reads are interesting from every mode: priv/vinst/counteren
# checks); includes tvec/atp regs whose *writes* are excluded above
_CSR_RO = (0xC01, 0xE12, 0x301, 0x105, 0x205, 0x305, 0x180, 0x280, 0x680,
           0x604)


def repro_line(seed: int, case: int) -> str:
    return (f"PYTHONPATH=src python -m repro.core.hext.torture "
            f"--seed {seed} --case {case}")


# ---------------------------------------------------------------------------
# scenario generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Scenario:
    seed: int
    case: int
    image: np.ndarray
    cfg: Dict

    @property
    def name(self) -> str:
        return f"s{self.seed}c{self.case}"


def _rand_u64(rng) -> int:
    return int(rng.integers(0, 1 << 64, dtype=np.uint64))


def _bits(rng, pool, p) -> int:
    return sum(1 << b for b in pool if rng.random() < p)


def _sample_cfg(rng) -> Dict:
    mode = MODES[int(rng.integers(0, len(MODES)))]
    virt = mode in ("VS", "VU")
    user = mode in ("U", "VU")
    cfg: Dict = {"mode": mode, "virt": virt, "user": user}

    # translation regimes.  "broken" roots / misaligned superpages can make
    # the S/VS handler unfetchable — the delegation masks below keep the
    # resulting fetch faults at M so no trap chain can loop.
    def stage():
        r = rng.random()
        if r < 0.40:
            return {"on": False}
        out = {"on": True, "root_oob": rng.random() < 0.04,
               "superpage": None}
        if rng.random() < 0.12:
            out["superpage"] = "misaligned" if rng.random() < 0.3 \
                else "aligned"
        return out

    cfg["satp"] = stage() if not virt else (
        {"on": False} if rng.random() < 0.5
        else {"on": True, "root_oob": False, "superpage": None})
    # HS is the hypervisor regime: bias the guest stages ON so its
    # HLV/HSV ops walk two stages; plain S is the pure-native supervisor
    # (otherwise the two modes would sample identical distributions)
    vsatp_p = {"HS": 0.8, "S": 0.1}.get(mode, 0.5)
    hgatp_p = {"HS": 0.7, "S": 0.1}.get(mode, 0.4)
    cfg["vsatp"] = stage() if virt else (
        {"on": rng.random() < vsatp_p, "root_oob": False,
         "superpage": None})
    cfg["hgatp"] = stage() if (virt or rng.random() < hgatp_p) \
        else {"on": False}
    # Bias (not eliminate) broken G roots under V=1: a broken root makes
    # the VS handler unfetchable, which is SAFE only because the
    # hedeleg &= ~(1|1<<12) guard below forces the resulting guest
    # handler-fetch faults to HS/M instead of looping at vstvec
    if virt and cfg["hgatp"].get("root_oob"):
        cfg["hgatp"]["root_oob"] = rng.random() < 0.5
    cfg["g_drop_vs_tables"] = virt and rng.random() < 0.08

    s_broken = cfg["satp"]["on"] and (
        cfg["satp"]["root_oob"] or cfg["satp"]["superpage"] is not None)
    vs_broken = cfg["vsatp"]["on"] and (
        cfg["vsatp"].get("root_oob") or cfg["vsatp"].get("superpage"))
    g_broken = cfg["hgatp"]["on"] and (
        cfg["hgatp"].get("root_oob") or
        cfg["hgatp"].get("superpage") == "misaligned" or
        cfg["g_drop_vs_tables"])

    medeleg = _bits(rng, (0, 1, 2, 3, 4, 5, 6, 7, 8, 12, 13, 15,
                          20, 21, 22, 23, 10), 0.35)
    if s_broken or (cfg["satp"]["on"] and user):
        # an S-handler fetch fault must exit at M, not re-delegate
        medeleg &= ~((1 << 1) | (1 << 12))
    hedeleg = _bits(rng, (0, 1, 2, 3, 4, 5, 6, 7, 8, 12, 13, 15), 0.35)
    if vs_broken or g_broken or (cfg["vsatp"]["on"] and user):
        hedeleg &= ~((1 << 1) | (1 << 12))
    cfg["medeleg"], cfg["hedeleg"] = medeleg, hedeleg
    cfg["mideleg"] = _bits(rng, (1, 5, 9), 0.4)
    cfg["hideleg"] = _bits(rng, (2, 6, 10), 0.4)

    cfg["mcounteren"] = int(rng.integers(0, 8))
    cfg["hcounteren"] = int(rng.integers(0, 8))
    cfg["scounteren"] = int(rng.integers(0, 8))
    cfg["mstatus_set"] = (
        (C.MSTATUS_SIE if rng.random() < 0.5 else 0) |
        (C.MSTATUS_MIE if rng.random() < 0.4 else 0) |
        (C.MSTATUS_SUM if rng.random() < 0.4 else 0) |
        (C.MSTATUS_MXR if rng.random() < 0.3 else 0) |
        (C.MSTATUS_TW if rng.random() < 0.15 else 0) |
        (C.MSTATUS_TSR if rng.random() < 0.15 else 0))
    cfg["hstatus"] = (
        (C.HSTATUS_VTW if rng.random() < 0.15 else 0) |
        (C.HSTATUS_VTSR if rng.random() < 0.15 else 0) |
        (C.HSTATUS_VTVM if rng.random() < 0.15 else 0) |
        (C.HSTATUS_HU if rng.random() < 0.3 else 0))
    cfg["vsstatus"] = (
        (C.MSTATUS_SIE if rng.random() < 0.5 else 0) |
        (C.MSTATUS_SUM if rng.random() < 0.4 else 0) |
        (C.MSTATUS_MXR if rng.random() < 0.3 else 0) |
        (C.MSTATUS_SPP if rng.random() < 0.5 else 0))
    cfg["mie"] = int(rng.integers(0, 1 << 13))
    cfg["hvip"] = _bits(rng, (2, 6, 10), 0.2)
    cfg["vsie"] = int(rng.integers(0, 1 << 11))
    cfg["htimedelta"] = (0 if rng.random() < 0.6 else
                         int(rng.integers(0, 4096)) if rng.random() < 0.75
                         else _rand_u64(rng))
    cfg["stimecmp_delta"] = int(rng.integers(8, 200)) \
        if rng.random() < 0.35 else None
    cfg["vstimecmp_delta"] = int(rng.integers(8, 200)) \
        if rng.random() < 0.35 else None
    cfg["mtimecmp_delta"] = int(rng.integers(8, 200)) \
        if rng.random() < 0.3 else None
    cfg["use_wfi"] = rng.random() < 0.06
    if cfg["use_wfi"]:
        cfg["mtimecmp_delta"] = cfg["mtimecmp_delta"] or \
            int(rng.integers(32, 200))
        cfg["mie"] |= C.IP_MTIP
    # bias the enables toward what was armed/injected, so interrupts
    # actually fire *during* scenarios instead of after their exit
    for delta_key, bit in (("stimecmp_delta", C.IP_STIP),
                           ("vstimecmp_delta", C.IP_VSTIP),
                           ("mtimecmp_delta", C.IP_MTIP)):
        if cfg[delta_key] is not None and rng.random() < 0.7:
            cfg["mie"] |= bit
    for b in (2, 6, 10):
        if cfg["hvip"] & (1 << b) and rng.random() < 0.6:
            cfg["mie"] |= 1 << b
    cfg["seed_regs"] = {int(r): _rand_u64(rng) for r in
                        rng.choice(_REGS, size=6, replace=False)}
    cfg["n_body"] = int(rng.integers(8, 36))
    return cfg


def _rand_pte(rng, pa: int, want_user: bool, gstage: bool) -> int:
    """A data-page PTE with randomized quirks (the torture surface)."""
    r = rng.random()
    if r < 0.10:
        return 0                                   # invalid (V=0)
    perms = PTE_V | PTE_R | PTE_A | PTE_D
    if rng.random() < 0.75:
        perms |= PTE_W
    if rng.random() < 0.25:
        perms |= PTE_X
    if gstage:
        if rng.random() >= 0.10:                   # 10%: missing U → GPF
            perms |= PTE_U
    elif want_user:
        if rng.random() < 0.75:
            perms |= PTE_U
    elif rng.random() < 0.35:
        perms |= PTE_U
    if rng.random() < 0.10:
        perms &= ~PTE_A
    if rng.random() < 0.12:
        perms &= ~PTE_D
    if rng.random() < 0.06:                        # reserved W=1/R=0
        perms = (perms | PTE_W) & ~PTE_R
    ppn = pa >> 12
    q = rng.random()
    if q < 0.05:                                   # OOB host page
        ppn = (T_MEM_BYTES >> 12) + int(rng.integers(0, 64))
    elif q < 0.08:                                 # alias another data page
        ppn = int(rng.integers(3, 8))
    return (ppn << 10) | perms


def _atp_value(st: Dict, root: int) -> int:
    if not st["on"]:
        return 0
    if st.get("root_oob"):
        root = T_MEM_BYTES + 0x100000
    return SATP_SV39 | (root >> 12)


def _build_s_tables(img: Image, rng, cfg) -> None:
    img.link(S_L2, 0, S_L1)
    sp = cfg["satp"].get("superpage") if not cfg["virt"] else \
        cfg["vsatp"].get("superpage")
    body_perms = P_KERN | (PTE_U if cfg["user"] else 0)
    if sp:
        ppn = 0 if sp == "aligned" else 1          # low bits ≠ 0 → fault
        img.store64(S_L1 + 0 * 8, (ppn << 10) | body_perms)
        return
    img.link(S_L1, 0, S_L0)
    img.map_page(S_L0, 0x0000, 0x0000, P_KERN)     # boot + handlers
    img.map_page(S_L0, T_BODY, T_BODY, body_perms)
    img.map_page(S_L0, T_LOG, T_LOG, P_KERN)
    for p in T_DATA_PAGES:
        pte = _rand_pte(rng, p, cfg["user"], gstage=False)
        img.store64(S_L0 + ((p >> 12) & 0x1FF) * 8, pte)


def _build_g_tables(img: Image, rng, cfg) -> None:
    img.link(G_L2, 0, G_L1)
    sp = cfg["hgatp"].get("superpage")
    if sp:
        ppn = 0 if sp == "aligned" else 1
        img.store64(G_L1 + 0 * 8, (ppn << 10) | P_GUEST)
        return
    img.link(G_L1, 0, G_L0)
    for p in (0x0000, T_BODY, T_LOG):
        img.map_page(G_L0, p, p, P_GUEST)
    if not cfg["g_drop_vs_tables"]:
        for p in (S_L2, S_L1, S_L0):               # VS-stage table GPAs
            img.map_page(G_L0, p, p, P_GUEST)
    for p in T_DATA_PAGES:
        pte = _rand_pte(rng, p, cfg["user"], gstage=True)
        img.store64(G_L0 + ((p >> 12) & 0x1FF) * 8, pte)


# -- body emission -----------------------------------------------------------

def _rand_addr(rng) -> int:
    r = rng.random()
    if r < 0.55:                                   # aligned data
        sz = 1 << int(rng.integers(0, 4))
        off = int(rng.integers(0, 0x5000 // sz)) * sz
        return 0x3000 + off
    if r < 0.70:                                   # misaligned data
        return 0x3000 + int(rng.integers(0, 0x5000))
    if r < 0.74:                                   # code / log page
        return int(rng.choice([T_BODY + 0x800, T_LOG + 0x80,
                               T_LOG + int(rng.integers(0, 0xF8))]))
    if r < 0.86:                                   # OOB physical
        return T_MEM_BYTES + int(rng.integers(0, 1 << 20))
    return int(rng.choice([0x10000000, 0x10000010, 0x10004000,
                           0x1000BFF8])) + int(rng.integers(0, 2)) * 4


_LOADS = ("lb", "lh", "lw", "ld", "lbu", "lhu", "lwu")
_STORES = ("sb", "sh", "sw", "sd")
_ALU_RR = ("add", "sub", "sll", "slt", "sltu", "xor", "srl", "sra", "or_",
           "and_", "mul", "mulhu", "div", "divu", "rem", "remu", "addw",
           "subw")
_ALU_I = ("addi", "slti", "sltiu", "xori", "ori", "andi", "addiw")
_HLV = ("hlv_b", "hlv_bu", "hlv_h", "hlv_hu", "hlvx_hu", "hlv_w", "hlv_wu",
        "hlvx_wu", "hlv_d")
_HSV = ("hsv_b", "hsv_h", "hsv_w", "hsv_d")


def _emit_body(a: Asm, rng, cfg, case: int) -> None:
    rreg = lambda: int(rng.choice(_REGS))
    n_br = [0]

    def item():
        r = rng.random()
        if r < 0.22:                               # ALU reg-reg
            getattr(a, str(rng.choice(_ALU_RR)))(rreg(), rreg(), rreg())
        elif r < 0.34:                             # ALU imm / shifts
            if rng.random() < 0.3:
                getattr(a, str(rng.choice(("slli", "srli", "srai"))))(
                    rreg(), rreg(), int(rng.integers(0, 64)))
            else:
                getattr(a, str(rng.choice(_ALU_I)))(
                    rreg(), rreg(), int(rng.integers(-2048, 2048)))
        elif r < 0.40:
            a.li(rreg(), _rand_u64(rng))
        elif r < 0.52:                             # load
            ar = rreg()
            a.li(ar, _rand_addr(rng))
            getattr(a, str(rng.choice(_LOADS)))(rreg(), 0, ar)
        elif r < 0.62:                             # store
            ar = rreg()
            a.li(ar, _rand_addr(rng))
            getattr(a, str(rng.choice(_STORES)))(rreg(), 0, ar)
        elif r < 0.74:                             # CSR op
            if rng.random() < 0.25:
                a.csrr(rreg(), int(rng.choice(_CSR_RO)))
            else:
                addr = int(rng.choice(_CSR_RW))
                k = rng.random()
                if k < 0.4:
                    vr = rreg()
                    a.li(vr, _rand_u64(rng) if rng.random() < 0.5
                         else int(rng.integers(0, 1 << 16)))
                    getattr(a, str(rng.choice(("csrrw", "csrrs",
                                               "csrrc"))))(rreg(), addr, vr)
                else:
                    getattr(a, str(rng.choice(("csrrwi", "csrrsi",
                                               "csrrci"))))(
                        rreg(), addr, int(rng.integers(0, 32)))
        elif r < 0.78:                             # hlv / hsv
            ar = rreg()
            a.li(ar, _rand_addr(rng))
            if rng.random() < 0.6:
                getattr(a, str(rng.choice(_HLV)))(rreg(), ar)
            else:
                getattr(a, str(rng.choice(_HSV)))(rreg(), ar)
        elif r < 0.86:                             # forward branch
            lab = f"c{case}b{n_br[0]}"
            n_br[0] += 1
            getattr(a, str(rng.choice(("beq", "bne", "blt", "bge", "bltu",
                                       "bgeu"))))(rreg(), rreg(), lab)
            for _ in range(int(rng.integers(1, 3))):
                a.addi(rreg(), rreg(), int(rng.integers(-64, 64)))
            a.label(lab)
        elif r < 0.90:                             # time read
            a.csrr(rreg(), 0xC01)
        elif r < 0.93:
            a.sfence_vma() if rng.random() < 0.5 else (
                a.hfence_vvma() if rng.random() < 0.5 else a.hfence_gvma())
        elif r < 0.95 and cfg["use_wfi"]:
            a.wfi()
        elif r < 0.97:                             # wild jump
            ar = rreg()
            a.li(ar, int(rng.choice([0x3400, 0x7008, T_MEM_BYTES + 64,
                                     0x100000])))
            a.jalr(int(rng.choice([0, 1])), 0, ar)
        else:                                      # early trap out
            [a.ecall, a.ebreak, a.sret, a.mret][int(rng.integers(0, 4))]()

    for _ in range(cfg["n_body"]):
        item()
    a.ecall()                                      # terminator


def _emit_boot(a: Asm, rng, cfg) -> None:
    a.li("t0", TM_HANDLER)
    a.csrw(0x305, "t0")
    a.li("t0", TS_HANDLER)
    a.csrw(0x105, "t0")                            # stvec (V=0 at boot)
    a.li("t0", TVS_HANDLER)
    a.csrw(0x205, "t0")                            # vstvec
    for csr, val in ((0x302, cfg["medeleg"]), (0x303, cfg["mideleg"]),
                     (0x602, cfg["hedeleg"]), (0x603, cfg["hideleg"]),
                     (0x306, cfg["mcounteren"]), (0x606, cfg["hcounteren"]),
                     (0x106, cfg["scounteren"]), (0x600, cfg["hstatus"]),
                     (0x200, cfg["vsstatus"]), (0x304, cfg["mie"]),
                     (0x645, cfg["hvip"]), (0x204, cfg["vsie"]),
                     (0x605, cfg["htimedelta"])):
        if val:
            a.li("t0", val)
            a.csrw(csr, "t0")
    if cfg["mstatus_set"]:
        a.li("t0", cfg["mstatus_set"])
        a.csrrs(0, 0x300, "t0")
    a.li("t0", _atp_value(cfg["satp"], S_L2))
    if cfg["satp"]["on"]:
        a.csrw(0x180, "t0")
    a.li("t0", _atp_value(cfg["vsatp"], S_L2))
    if cfg["vsatp"]["on"]:
        a.csrw(0x280, "t0")
    a.li("t0", _atp_value(cfg["hgatp"], G_L2))
    if cfg["hgatp"]["on"]:
        a.csrw(0x680, "t0")
    if cfg["stimecmp_delta"] is not None:
        a.csrr("t0", 0xC01)
        a.addi("t0", "t0", cfg["stimecmp_delta"])
        a.csrw(0x14D, "t0")
    if cfg["vstimecmp_delta"] is not None:
        a.csrr("t0", 0xC01)
        a.csrr("t1", 0x605)
        a.add("t0", "t0", "t1")
        a.addi("t0", "t0", cfg["vstimecmp_delta"])
        a.csrw(0x24D, "t0")
    if cfg["mtimecmp_delta"] is not None:
        a.csrr("t0", 0xC01)
        a.addi("t0", "t0", cfg["mtimecmp_delta"])
        a.li("t1", 0x10004000)
        a.sd("t0", 0, "t1")
    for reg, val in sorted(cfg["seed_regs"].items()):
        a.li(reg, val)
    if cfg["mode"] == "M":
        a.j("body")
        return
    if cfg["virt"]:
        a.li("t0", C.MSTATUS_MPV)
        a.csrrs(0, 0x300, "t0")
    if not cfg["user"]:
        a.li("t0", 1 << 11)                        # MPP = S
        a.csrrs(0, 0x300, "t0")
    a.li("t0", T_BODY)
    a.csrw(0x341, "t0")                            # mepc
    a.mret()


def _emit_handlers(a: Asm) -> None:
    """Fixed capture handlers (same for every scenario)."""
    a.pad_to(TM_HANDLER)
    # M: fingerprint = mcause ^ mtval + mepc + mtval2 → DONE
    a.csrr("t0", 0x342)
    a.csrr("t1", 0x343)
    a.xor("t0", "t0", "t1")
    a.csrr("t1", 0x341)
    a.add("t0", "t0", "t1")
    a.csrr("t1", 0x34B)
    a.add("t0", "t0", "t1")
    a.li("t6", MMIO_DONE)
    a.sd("t0", 0, "t6")
    a.label("m_spin")
    a.j("m_spin")
    a.pad_to(TS_HANDLER)
    # HS/S: log scause/stval/htval, then ecall down to M (cause 9,
    # undelegable by the WARL medeleg mask)
    a.li("t5", T_LOG)
    a.csrr("t4", 0x142)
    a.sd("t4", 0, "t5")
    a.csrr("t4", 0x143)
    a.sd("t4", 8, "t5")
    a.csrr("t4", 0x643)
    a.sd("t4", 16, "t5")
    a.ecall()
    a.label("s_spin")
    a.j("s_spin")
    a.pad_to(TVS_HANDLER)
    # VS: log vscause/vstval (via the V=1 swap), ecall (cause 10 → HS or M)
    a.li("t5", T_LOG + 0x40)
    a.csrr("t4", 0x142)
    a.sd("t4", 0, "t5")
    a.csrr("t4", 0x143)
    a.sd("t4", 8, "t5")
    a.ecall()
    a.label("vs_spin")
    a.j("vs_spin")
    a.pad_to(T_BODY)
    a.label("body")


def gen_scenario(seed: int, case: int) -> Scenario:
    """Deterministically regenerate scenario `case` of corpus `seed`."""
    rng = np.random.Generator(np.random.PCG64(
        np.random.SeedSequence([seed, case])))
    cfg = _sample_cfg(rng)
    a = Asm(0)
    _emit_boot(a, rng, cfg)
    _emit_handlers(a)
    _emit_body(a, rng, cfg, case)
    img = Image(T_MEM_WORDS)
    img.place_code(0, a.assemble())
    _build_s_tables(img, rng, cfg)
    _build_g_tables(img, rng, cfg)
    return Scenario(seed=seed, case=case, image=img.mem, cfg=cfg)


def generate(seed: int, count: int) -> List[Scenario]:
    return [gen_scenario(seed, k) for k in range(count)]


# ---------------------------------------------------------------------------
# differential run + diff
# ---------------------------------------------------------------------------

# the comparison scope is defined ONCE in engine.py (shared with
# `engine.diff_states`); `walks`/TLB are microarchitectural and excluded


def _final_arrays(fleet) -> Dict[str, np.ndarray]:
    """Extract a fleet's final state as host arrays (one batched copy)."""
    from repro.core.hext import engine as _engine
    return _engine.state_arrays(fleet.harts.unwrap())


def _run_corpus_fleet(scenarios: List[Scenario], max_ticks: int,
                      chunk: int, engine=None) -> Dict[str, np.ndarray]:
    """Boot the corpus as one batched Fleet on the given engine backend
    and return final-state arrays.  ``engine=None`` is the jitted device
    model; ``engine="oracle"`` is the pure-Python reference — both legs of
    the differential run now go through the same first-class ``Fleet``
    path (DESIGN.md §3)."""
    from repro.core.hext.sim import Fleet
    fleet = Fleet.from_corpus([s.image for s in scenarios],
                              names=[s.name for s in scenarios],
                              mem_words=T_MEM_WORDS, engine=engine)
    fleet.run(max_ticks, chunk=chunk)
    return _final_arrays(fleet)


def _check_reset_parity() -> None:
    """The OracleEngine reference leg *adopts* the machine's boot state
    (``resume_state``), which would hide exactly one class of bug: a
    machine reset-state divergence.  Guard it by diffing one fresh
    machine boot against the oracle's own independent reset (non-mem
    reset state is image-independent, so one check covers the corpus —
    and keeps the single-case ``--case`` repro path, which runs
    ``oracle.run`` from the oracle's reset, equivalent to the corpus
    leg)."""
    from repro.core.hext import engine as _engine
    from repro.core.hext.sim import HartState
    img = np.zeros(64, dtype=np.uint64)
    mach = _engine.state_arrays(HartState.fresh(64))
    orac = _oracle_arrays(oracle.reset_state(img))
    d = _engine.diff_arrays(mach, 0, orac, 0)
    if d:
        raise AssertionError(
            f"machine reset state diverged from the oracle's independent "
            f"reset: {d[:4]}")


def _oracle_arrays(ost: Dict) -> Dict[str, np.ndarray]:
    """Shape one oracle final state like a batch-of-1 `_final_arrays`."""
    out = {
        "pc": np.array([ost["pc"]], dtype=np.uint64),
        "regs": np.array([ost["regs"]], dtype=np.uint64),
        "csrs": np.array([ost["csrs"]], dtype=np.uint64),
        "priv": np.array([ost["priv"]]),
        "virt": np.array([1 if ost["virt"] else 0]),
        "halted": np.array([1 if ost["halted"] else 0]),
        "mem": np.array([ost["mem"]], dtype=np.uint64),
        "console": np.array([ost["console"]]),
        "done": np.array([1 if ost["done"] else 0]),
        "exit_code": np.array([ost["exit_code"]], dtype=np.uint64),
        "exc_by_level": np.array([ost["exc_by_level"]]),
        "int_by_level": np.array([ost["int_by_level"]]),
    }
    for k in _COUNTERS:
        out[k] = np.array([ost[k]])
    return out


def diff_pair(mach: Dict[str, np.ndarray], i: int,
              orac: Dict[str, np.ndarray], j: int) -> List[str]:
    """Compare machine hart `i` against oracle hart `j`, field by field —
    a thin wrapper over the single shared comparison core
    (`engine.diff_arrays`; in the output `a` is the machine, `b` the
    oracle; `walks`/TLB excluded by design)."""
    from repro.core.hext.engine import diff_arrays
    return diff_arrays(mach, i, orac, j)


def diff_case(mach: Dict[str, np.ndarray], i: int, ost: Dict) -> List[str]:
    """Compare machine hart `i` against an oracle final-state dict (the
    single-case repro path)."""
    return diff_pair(mach, i, _oracle_arrays(ost), 0)


def run_corpus(seed: int, count: int, max_ticks: int = MAX_TICKS,
               chunk: int = CHUNK, verbose: bool = False) -> Dict:
    """Generate, run (one batched Fleet + oracle), diff. Returns a report."""
    # the device engine rounds the budget UP to whole chunk-scans; the
    # oracle must run the exact same tick count or budget-burning
    # scenarios would report phantom mismatches
    max_ticks = -(-int(max_ticks) // int(chunk)) * int(chunk)
    _check_reset_parity()
    t0 = time.time()
    scenarios = generate(seed, count)
    t_gen = time.time() - t0
    t0 = time.time()
    mach = _run_corpus_fleet(scenarios, max_ticks, chunk)
    t_mach = time.time() - t0
    # the reference leg: the SAME corpus fleet on the OracleEngine backend
    t0 = time.time()
    orac = _run_corpus_fleet(scenarios, max_ticks, chunk, engine="oracle")
    failures = []
    for i, s in enumerate(scenarios):
        d = diff_pair(mach, i, orac, i)
        if d:
            failures.append({"case": s.case, "mode": s.cfg["mode"],
                             "repro": repro_line(seed, s.case),
                             "diff": d})
            if verbose:
                print(f"MISMATCH case {s.case} ({s.cfg['mode']}): "
                      f"{d[:4]}\n  repro: {repro_line(seed, s.case)}")
    t_oracle = time.time() - t0
    return {
        "seed": seed, "count": count, "max_ticks": max_ticks,
        "failures": failures,
        "wall_gen": t_gen, "wall_machine": t_mach, "wall_oracle": t_oracle,
        "scenarios_per_sec_batched": count / max(t_mach, 1e-9),
    }


# ---------------------------------------------------------------------------
# CLI: corpus run, or one-case repro with a full diff dump
# ---------------------------------------------------------------------------

def _write_report(path: Optional[str], rep: Dict) -> None:
    if not path:
        return
    import json
    import os
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as fh:
        json.dump(rep, fh, indent=2)


def _case_main(seed: int, case: int, max_ticks: int, verbose: bool,
               out: Optional[str] = None) -> int:
    max_ticks = -(-int(max_ticks) // CHUNK) * CHUNK   # match the engine
    s = gen_scenario(seed, case)
    print(f"case {case} of seed {seed}: mode={s.cfg['mode']} "
          f"satp={s.cfg['satp']} vsatp={s.cfg['vsatp']} "
          f"hgatp={s.cfg['hgatp']}")
    mach = _run_corpus_fleet([s], max_ticks, CHUNK)
    ost = oracle.run(s.image, max_ticks)
    d = diff_case(mach, 0, ost)
    if verbose or d:
        print(f"oracle: done={ost['done']} exit={ost['exit_code']:#x} "
              f"ticks={ost['ticks']} instret={ost['instret']} "
              f"exc={ost['exc_by_level']} int={ost['int_by_level']}")
    _write_report(out, {"seed": seed, "case": case, "max_ticks": max_ticks,
                        "mode": s.cfg["mode"], "diff": d,
                        "repro": repro_line(seed, case)})
    if d:
        print(f"MISMATCH ({len(d)} fields):")
        for line in d:
            print(f"  {line}")
        print(f"repro: {repro_line(seed, case)}")
        return 1
    print("machine == oracle")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="randomized differential conformance harness")
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED)
    ap.add_argument("--count", type=int, default=256)
    ap.add_argument("--case", type=int, default=None,
                    help="re-run ONE scenario with a full diff dump")
    ap.add_argument("--max-ticks", type=int, default=MAX_TICKS)
    ap.add_argument("--out", default=None, help="write a JSON report")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)
    if args.case is not None:
        return _case_main(args.seed, args.case, args.max_ticks, args.verbose,
                          out=args.out)
    rep = run_corpus(args.seed, args.count, args.max_ticks,
                     verbose=args.verbose)
    print(f"seed {rep['seed']}: {rep['count']} scenarios, "
          f"{len(rep['failures'])} mismatches "
          f"(machine {rep['wall_machine']:.1f}s = "
          f"{rep['scenarios_per_sec_batched']:.1f}/s batched, "
          f"oracle {rep['wall_oracle']:.1f}s)")
    for f in rep["failures"]:
        print(f"  case {f['case']} ({f['mode']}): {f['diff'][0]}")
        print(f"    repro: {f['repro']}")
    _write_report(args.out, rep)
    return 1 if rep["failures"] else 0


if __name__ == "__main__":
    sys.exit(main())
