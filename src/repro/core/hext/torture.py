"""Coverage-guided differential conformance harness (DESIGN.md §5).

RiescueC-style torture testing, v2: a seeded generator composes each
scenario from **action blocks** — straight-line fuzz runs, fuel-bounded
backward loops, PTE-rewrite-then-fence sequences, and trap trampolines
that bounce M→HS→VS→VU and back — over randomized Sv39/Sv39x4 page-table
shapes, privilege entry points, delegation masks, and timer arming.  A
sched family composes seeded fuzz bodies with the preemptive N-guest
scheduler (``build_image_nguest``).

Every scenario is self-terminating by construction: backward branches
only appear as fuel-counter loops (a dedicated register outside the fuzz
pool counts down to zero), trampoline bounces advance ``sepc`` by 4 each
time, every capture handler either exits through the DONE MMIO or ecalls
its way down to the terminal M handler, and the WARL delegation masks
make ecall-S/ecall-M undelegable.  Pathological leftovers are bounded by
the tick budget — both models run the same budget, so even a
non-terminating scenario is compared exactly.

Coverage feedback: per-scenario architectural-event signatures (trap
cause × priv × V, fence kind × scope, atp writes, WFI) recorded by the
oracle, plus static shape buckets (mode × paging kinds × block kinds),
hash into a bucket map.  Generation is biased toward unseen buckets:
each case samples ``N_CANDIDATES`` candidate configs and keeps the one
adding the most unseen static buckets (deterministic — replayable from
``(seed, case)`` alone).

Both legs go through the same first-class ``Fleet`` path; the reference
leg runs on the ``OracleEngine`` backend, which models the software TLB
(scoped fences included) and the ``walks`` counter bit-exactly — the
diff exclusion list is empty.

Repro workflow::

    PYTHONPATH=src python -m repro.core.hext.torture --seed S --count 256
    PYTHONPATH=src python -m repro.core.hext.torture --seed S --case K -v
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.hext import csr as C
from repro.core.hext import oracle
from repro.core.hext import programs
from repro.core.hext.engine import DIFF_COUNTERS as _COUNTERS
from repro.core.hext.programs import (Asm, Image, G_L0, G_L1, G_L2,
                                      S_L0, S_L1, S_L2, SATP_SV39,
                                      PTE_V, PTE_R, PTE_W, PTE_X, PTE_U,
                                      PTE_A, PTE_D, P_KERN, P_GUEST)

# ---------------------------------------------------------------------------
# scenario memory map (identity VA=GPA=PA; 128 KiB per scenario)
# ---------------------------------------------------------------------------
T_MEM_WORDS = 1 << 14          # 128 KiB — one XLA shape for the fuzz family
T_MEM_BYTES = T_MEM_WORDS * 8
TM_HANDLER = 0x0400            # M trap handler (capture + DONE exit)
TS_HANDLER = 0x0600            # HS/S handler (bounce or log+ecall)
TVS_HANDLER = 0x0800           # VS handler (bounce or log+ecall)
T_BODY = 0x1000                # randomized body
T_LOG = 0x2000                 # handler fingerprint page (always mapped RW)
T_DATA_PAGES = (0x3000, 0x4000, 0x5000, 0x6000, 0x7000)
MMIO_DONE = 0x10000008

DEFAULT_SEED = 2026
MAX_TICKS = 1536               # 3 × CHUNK — both models run this exact budget
SCHED_MAX_TICKS = 6144         # sched family: boot + slices need more room
CHUNK = 512
SCHED_EVERY = 8                # case k is a sched scenario iff k%8 == 7
N_CANDIDATES = 4               # configs sampled per case; best-scored wins

MODES = ("M", "HS", "S", "U", "VS", "VU")

_REGS = (1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 18, 19, 20,
         28, 29, 30)
FUEL_REG = 21                  # s5 — loop fuel counter, outside the fuzz pool
SENT_REG = 22                  # s6 — trampoline sentinel, outside the pool
TRAMP_MAGIC = 0x7A3F

# CSRs a body may freely read AND write (tvec/atp writes excluded: they can
# redirect traps/translation at a pc the generator cannot see)
_CSR_RW = (0x100, 0x104, 0x106, 0x140, 0x141, 0x142, 0x143, 0x144, 0x14D,
           0x200, 0x204, 0x240, 0x241, 0x242, 0x243, 0x244, 0x24D,
           0x300, 0x302, 0x303, 0x304, 0x306, 0x340, 0x341, 0x342, 0x343,
           0x344, 0x34A, 0x34B, 0x600, 0x602, 0x603, 0x605, 0x606, 0x607,
           0x643, 0x644, 0x645, 0x64A)
# read-only pool (reads are interesting from every mode: priv/vinst/counteren
# checks); includes tvec/atp regs whose *writes* are excluded above
_CSR_RO = (0xC01, 0xE12, 0x301, 0x105, 0x205, 0x305, 0x180, 0x280, 0x680,
           0x604)


def repro_line(seed: int, case: int) -> str:
    return (f"PYTHONPATH=src python -m repro.core.hext.torture "
            f"--seed {seed} --case {case}")


# ---------------------------------------------------------------------------
# scenario generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Scenario:
    seed: int
    case: int
    image: np.ndarray
    cfg: Dict

    @property
    def name(self) -> str:
        return f"s{self.seed}c{self.case}"

    @property
    def family(self) -> str:
        return self.cfg.get("family", "fuzz")

    @property
    def max_ticks(self) -> int:
        return SCHED_MAX_TICKS if self.family == "sched" else MAX_TICKS


def _rand_u64(rng) -> int:
    return int(rng.integers(0, 1 << 64, dtype=np.uint64))


def _bits(rng, pool, p) -> int:
    return sum(1 << b for b in pool if rng.random() < p)


def _sample_blocks(rng, mode: str) -> List[str]:
    """The action-block sequence: the v2 scenario grammar is
    ``body := block+ ; block := straight | fuel | pte | tramp``."""
    blocks = []
    for _ in range(int(rng.integers(2, 6))):
        r = rng.random()
        if r < 0.40:
            blocks.append("straight")
        elif r < 0.60:
            blocks.append("fuel")
        elif r < 0.80:
            # PTE rewrite needs a legal fence; from VU/U it would trap
            # straight out, so bias it toward the privileged modes
            blocks.append("pte" if mode in ("M", "HS", "S", "VS")
                          or rng.random() < 0.2 else "straight")
        else:
            # a trampoline from M exits at the terminal handler instantly
            blocks.append("tramp" if mode != "M" or rng.random() < 0.1
                          else "straight")
    return blocks


def _sample_cfg(rng) -> Dict:
    mode = MODES[int(rng.integers(0, len(MODES)))]
    virt = mode in ("VS", "VU")
    user = mode in ("U", "VU")
    cfg: Dict = {"family": "fuzz", "mode": mode, "virt": virt, "user": user}

    # translation regimes.  "broken" roots / misaligned superpages can make
    # the S/VS handler unfetchable — the delegation masks below keep the
    # resulting fetch faults at M so no trap chain can loop.
    def stage():
        r = rng.random()
        if r < 0.40:
            return {"on": False}
        out = {"on": True, "root_oob": rng.random() < 0.04,
               "superpage": None}
        if rng.random() < 0.12:
            out["superpage"] = "misaligned" if rng.random() < 0.3 \
                else "aligned"
        return out

    cfg["satp"] = stage() if not virt else (
        {"on": False} if rng.random() < 0.5
        else {"on": True, "root_oob": False, "superpage": None})
    # HS is the hypervisor regime: bias the guest stages ON so its
    # HLV/HSV ops walk two stages; plain S is the pure-native supervisor
    # (otherwise the two modes would sample identical distributions)
    vsatp_p = {"HS": 0.8, "S": 0.1}.get(mode, 0.5)
    hgatp_p = {"HS": 0.7, "S": 0.1}.get(mode, 0.4)
    cfg["vsatp"] = stage() if virt else (
        {"on": rng.random() < vsatp_p, "root_oob": False,
         "superpage": None})
    cfg["hgatp"] = stage() if (virt or rng.random() < hgatp_p) \
        else {"on": False}
    # Bias (not eliminate) broken G roots under V=1: a broken root makes
    # the VS handler unfetchable, which is SAFE only because the
    # hedeleg &= ~(1|1<<12) guard below forces the resulting guest
    # handler-fetch faults to HS/M instead of looping at vstvec
    if virt and cfg["hgatp"].get("root_oob"):
        cfg["hgatp"]["root_oob"] = rng.random() < 0.5
    cfg["g_drop_vs_tables"] = virt and rng.random() < 0.08

    s_broken = cfg["satp"]["on"] and (
        cfg["satp"]["root_oob"] or cfg["satp"]["superpage"] is not None)
    vs_broken = cfg["vsatp"]["on"] and (
        cfg["vsatp"].get("root_oob") or cfg["vsatp"].get("superpage"))
    g_broken = cfg["hgatp"]["on"] and (
        cfg["hgatp"].get("root_oob") or
        cfg["hgatp"].get("superpage") == "misaligned" or
        cfg["g_drop_vs_tables"])

    medeleg = _bits(rng, (0, 1, 2, 3, 4, 5, 6, 7, 8, 12, 13, 15,
                          20, 21, 22, 23, 10), 0.35)
    if s_broken or (cfg["satp"]["on"] and user):
        # an S-handler fetch fault must exit at M, not re-delegate
        medeleg &= ~((1 << 1) | (1 << 12))
    hedeleg = _bits(rng, (0, 1, 2, 3, 4, 5, 6, 7, 8, 12, 13, 15), 0.35)
    if vs_broken or g_broken or (cfg["vsatp"]["on"] and user):
        hedeleg &= ~((1 << 1) | (1 << 12))
    cfg["medeleg"], cfg["hedeleg"] = medeleg, hedeleg
    cfg["mideleg"] = _bits(rng, (1, 5, 9), 0.4)
    cfg["hideleg"] = _bits(rng, (2, 6, 10), 0.4)

    cfg["mcounteren"] = int(rng.integers(0, 8))
    cfg["hcounteren"] = int(rng.integers(0, 8))
    cfg["scounteren"] = int(rng.integers(0, 8))
    cfg["mstatus_set"] = (
        (C.MSTATUS_SIE if rng.random() < 0.5 else 0) |
        (C.MSTATUS_MIE if rng.random() < 0.4 else 0) |
        (C.MSTATUS_SUM if rng.random() < 0.4 else 0) |
        (C.MSTATUS_MXR if rng.random() < 0.3 else 0) |
        (C.MSTATUS_TW if rng.random() < 0.15 else 0) |
        (C.MSTATUS_TSR if rng.random() < 0.15 else 0))
    cfg["hstatus"] = (
        (C.HSTATUS_VTW if rng.random() < 0.15 else 0) |
        (C.HSTATUS_VTSR if rng.random() < 0.15 else 0) |
        (C.HSTATUS_VTVM if rng.random() < 0.15 else 0) |
        (C.HSTATUS_HU if rng.random() < 0.3 else 0))
    cfg["vsstatus"] = (
        (C.MSTATUS_SIE if rng.random() < 0.5 else 0) |
        (C.MSTATUS_SUM if rng.random() < 0.4 else 0) |
        (C.MSTATUS_MXR if rng.random() < 0.3 else 0) |
        (C.MSTATUS_SPP if rng.random() < 0.5 else 0))
    cfg["mie"] = int(rng.integers(0, 1 << 13))
    cfg["hvip"] = _bits(rng, (2, 6, 10), 0.2)
    cfg["vsie"] = int(rng.integers(0, 1 << 11))
    cfg["htimedelta"] = (0 if rng.random() < 0.6 else
                         int(rng.integers(0, 4096)) if rng.random() < 0.75
                         else _rand_u64(rng))
    cfg["stimecmp_delta"] = int(rng.integers(8, 200)) \
        if rng.random() < 0.35 else None
    cfg["vstimecmp_delta"] = int(rng.integers(8, 200)) \
        if rng.random() < 0.35 else None
    cfg["mtimecmp_delta"] = int(rng.integers(8, 200)) \
        if rng.random() < 0.3 else None
    cfg["use_wfi"] = rng.random() < 0.06
    if cfg["use_wfi"]:
        cfg["mtimecmp_delta"] = cfg["mtimecmp_delta"] or \
            int(rng.integers(32, 200))
        cfg["mie"] |= C.IP_MTIP
    # bias the enables toward what was armed/injected, so interrupts
    # actually fire *during* scenarios instead of after their exit
    for delta_key, bit in (("stimecmp_delta", C.IP_STIP),
                           ("vstimecmp_delta", C.IP_VSTIP),
                           ("mtimecmp_delta", C.IP_MTIP)):
        if cfg[delta_key] is not None and rng.random() < 0.7:
            cfg["mie"] |= bit
    for b in (2, 6, 10):
        if cfg["hvip"] & (1 << b) and rng.random() < 0.6:
            cfg["mie"] |= 1 << b
    cfg["seed_regs"] = {int(r): _rand_u64(rng) for r in
                        rng.choice(_REGS, size=6, replace=False)}
    cfg["blocks"] = _sample_blocks(rng, mode)
    # PTE-rewrite blocks only do interesting work when the guest can
    # reach its own tables through the live translation regime
    cfg["map_tables"] = rng.random() < (0.8 if "pte" in cfg["blocks"]
                                        else 0.2)
    return cfg


def _sample_sched_cfg(rng) -> Dict:
    """A multi-guest scenario: N seeded fuzz bodies under the preemptive
    scheduler (``build_image_nguest``), short timeslice."""
    n = 3 if rng.random() < 0.2 else 2
    guests = [{"seed": int(rng.integers(0, 1 << 31)),
               "n_items": int(rng.integers(6, 18)),
               "wfi": bool(rng.random() < 0.3),
               "loops": bool(rng.random() < 0.5)}
              for _ in range(n)]
    return {"family": "sched", "mode": f"SCHED{n}", "n_guests": n,
            "timeslice": int(rng.integers(60, 260)),
            "guests": guests,
            "use_wfi": any(g["wfi"] for g in guests)}


# -- coverage buckets --------------------------------------------------------

def _stage_kind(st: Dict) -> str:
    if not st.get("on"):
        return "off"
    if st.get("root_oob"):
        return "oob"
    sp = st.get("superpage")
    return f"sp-{sp}" if sp else "on"


def _static_buckets(cfg: Dict) -> frozenset:
    """Shape buckets predictable before running the scenario — the
    scoring signal for candidate selection."""
    if cfg.get("family") == "sched":
        b = {("mode", cfg["mode"]),
             ("sched", cfg["n_guests"], cfg["timeslice"] // 64,
              cfg["use_wfi"])}
        for g in cfg["guests"]:
            b.add(("sched-guest", g["wfi"], g["loops"]))
        return frozenset(b)
    b = {("mode", cfg["mode"]),
         ("paging", _stage_kind(cfg["satp"]), _stage_kind(cfg["vsatp"]),
          _stage_kind(cfg["hgatp"]), cfg["g_drop_vs_tables"]),
         ("tables-mapped", cfg["map_tables"]),
         ("timers", cfg["stimecmp_delta"] is not None,
          cfg["vstimecmp_delta"] is not None,
          cfg["mtimecmp_delta"] is not None, cfg["use_wfi"])}
    for k in cfg["blocks"]:
        b.add(("block", cfg["mode"], k))
    return frozenset(b)


def _is_sched_case(case: int) -> bool:
    return case % SCHED_EVERY == SCHED_EVERY - 1


def _case_rng(seed: int, case: int):
    return np.random.Generator(np.random.PCG64(
        np.random.SeedSequence([seed, case])))


def _choose_cfg(rng, sched: bool, seen: set) -> Dict:
    """Coverage-biased mutation: sample N candidates, keep the one that
    adds the most unseen static buckets (ties → first).  Deterministic
    given ``seen`` — replayable from (seed, case) alone."""
    sampler = _sample_sched_cfg if sched else _sample_cfg
    cands = [sampler(rng) for _ in range(N_CANDIDATES)]
    scores = [len(_static_buckets(c) - seen) for c in cands]
    cfg = cands[int(np.argmax(scores))]
    seen |= set(_static_buckets(cfg))
    return cfg


def _rand_pte(rng, pa: int, want_user: bool, gstage: bool) -> int:
    """A data-page PTE with randomized quirks (the torture surface)."""
    r = rng.random()
    if r < 0.10:
        return 0                                   # invalid (V=0)
    perms = PTE_V | PTE_R | PTE_A | PTE_D
    if rng.random() < 0.75:
        perms |= PTE_W
    if rng.random() < 0.25:
        perms |= PTE_X
    if gstage:
        if rng.random() >= 0.10:                   # 10%: missing U → GPF
            perms |= PTE_U
    elif want_user:
        if rng.random() < 0.75:
            perms |= PTE_U
    elif rng.random() < 0.35:
        perms |= PTE_U
    if rng.random() < 0.10:
        perms &= ~PTE_A
    if rng.random() < 0.12:
        perms &= ~PTE_D
    if rng.random() < 0.06:                        # reserved W=1/R=0
        perms = (perms | PTE_W) & ~PTE_R
    ppn = pa >> 12
    q = rng.random()
    if q < 0.05:                                   # OOB host page
        ppn = (T_MEM_BYTES >> 12) + int(rng.integers(0, 64))
    elif q < 0.08:                                 # alias another data page
        ppn = int(rng.integers(3, 8))
    return (ppn << 10) | perms


def _atp_value(st: Dict, root: int) -> int:
    if not st["on"]:
        return 0
    if st.get("root_oob"):
        root = T_MEM_BYTES + 0x100000
    return SATP_SV39 | (root >> 12)


def _build_s_tables(img: Image, rng, cfg) -> None:
    img.link(S_L2, 0, S_L1)
    sp = cfg["satp"].get("superpage") if not cfg["virt"] else \
        cfg["vsatp"].get("superpage")
    body_perms = P_KERN | (PTE_U if cfg["user"] else 0)
    if sp:
        ppn = 0 if sp == "aligned" else 1          # low bits ≠ 0 → fault
        img.store64(S_L1 + 0 * 8, (ppn << 10) | body_perms)
        return
    img.link(S_L1, 0, S_L0)
    img.map_page(S_L0, 0x0000, 0x0000, P_KERN)     # boot + handlers
    img.map_page(S_L0, T_BODY, T_BODY, body_perms)
    img.map_page(S_L0, T_LOG, T_LOG, P_KERN)
    if cfg.get("map_tables"):
        # guests may rewrite their own page tables (PTE-rewrite blocks)
        for p in (S_L2, S_L1, S_L0, G_L2, G_L1, G_L0):
            img.map_page(S_L0, p, p, P_KERN | (PTE_U if cfg["user"] else 0))
    for p in T_DATA_PAGES:
        pte = _rand_pte(rng, p, cfg["user"], gstage=False)
        img.store64(S_L0 + ((p >> 12) & 0x1FF) * 8, pte)


def _build_g_tables(img: Image, rng, cfg) -> None:
    img.link(G_L2, 0, G_L1)
    sp = cfg["hgatp"].get("superpage")
    if sp:
        ppn = 0 if sp == "aligned" else 1
        img.store64(G_L1 + 0 * 8, (ppn << 10) | P_GUEST)
        return
    img.link(G_L1, 0, G_L0)
    for p in (0x0000, T_BODY, T_LOG):
        img.map_page(G_L0, p, p, P_GUEST)
    if not cfg["g_drop_vs_tables"]:
        for p in (S_L2, S_L1, S_L0):               # VS-stage table GPAs
            img.map_page(G_L0, p, p, P_GUEST)
    if cfg.get("map_tables"):
        for p in (G_L2, G_L1, G_L0):               # G tables as GPAs too
            img.map_page(G_L0, p, p, P_GUEST)
    for p in T_DATA_PAGES:
        pte = _rand_pte(rng, p, cfg["user"], gstage=True)
        img.store64(G_L0 + ((p >> 12) & 0x1FF) * 8, pte)


# -- body emission: action blocks --------------------------------------------

def _rand_addr(rng) -> int:
    r = rng.random()
    if r < 0.55:                                   # aligned data
        sz = 1 << int(rng.integers(0, 4))
        off = int(rng.integers(0, 0x5000 // sz)) * sz
        return 0x3000 + off
    if r < 0.70:                                   # misaligned data
        return 0x3000 + int(rng.integers(0, 0x5000))
    if r < 0.74:                                   # code / log page
        return int(rng.choice([T_BODY + 0x800, T_LOG + 0x80,
                               T_LOG + int(rng.integers(0, 0xF8))]))
    if r < 0.86:                                   # OOB physical
        return T_MEM_BYTES + int(rng.integers(0, 1 << 20))
    return int(rng.choice([0x10000000, 0x10000010, 0x10004000,
                           0x1000BFF8])) + int(rng.integers(0, 2)) * 4


_LOADS = ("lb", "lh", "lw", "ld", "lbu", "lhu", "lwu")
_STORES = ("sb", "sh", "sw", "sd")
_ALU_RR = ("add", "sub", "sll", "slt", "sltu", "xor", "srl", "sra", "or_",
           "and_", "mul", "mulhu", "div", "divu", "rem", "remu", "addw",
           "subw")
_ALU_I = ("addi", "slti", "sltiu", "xori", "ori", "andi", "addiw")
_HLV = ("hlv_b", "hlv_bu", "hlv_h", "hlv_hu", "hlvx_hu", "hlv_w", "hlv_wu",
        "hlvx_wu", "hlv_d")
_HSV = ("hsv_b", "hsv_h", "hsv_w", "hsv_d")


def _emit_fence(a: Asm, rng, rreg) -> None:
    """A fence, address-scoped half the time (rs1 = a random VA page —
    the scoped-invalidation surface the TLB must honor)."""
    kind = rng.random()
    if rng.random() < 0.5:
        ar = rreg()
        a.li(ar, int(rng.choice(T_DATA_PAGES)) + int(rng.integers(0, 2)) * 8)
        if kind < 0.5:
            a.sfence_vma(rs1=ar)
        elif kind < 0.75:
            a.hfence_vvma(rs1=ar)
        else:
            a.hfence_gvma(rs1=ar)
    else:
        if kind < 0.5:
            a.sfence_vma()
        elif kind < 0.75:
            a.hfence_vvma()
        else:
            a.hfence_gvma()


def _emit_item(a: Asm, rng, cfg, case: int, uid: List[int],
               tame: bool = False) -> None:
    """One fuzz item.  ``tame=True`` (loop interiors) drops the items
    that unconditionally leave the body (trap-outs, wild jumps, WFI) so
    fuel loops actually iterate."""
    rreg = lambda: int(rng.choice(_REGS))
    r = rng.random() * (0.90 if tame else 1.0)
    if r < 0.22:                                   # ALU reg-reg
        getattr(a, str(rng.choice(_ALU_RR)))(rreg(), rreg(), rreg())
    elif r < 0.34:                                 # ALU imm / shifts
        if rng.random() < 0.3:
            getattr(a, str(rng.choice(("slli", "srli", "srai"))))(
                rreg(), rreg(), int(rng.integers(0, 64)))
        else:
            getattr(a, str(rng.choice(_ALU_I)))(
                rreg(), rreg(), int(rng.integers(-2048, 2048)))
    elif r < 0.40:
        a.li(rreg(), _rand_u64(rng))
    elif r < 0.52:                                 # load
        ar = rreg()
        a.li(ar, _rand_addr(rng))
        getattr(a, str(rng.choice(_LOADS)))(rreg(), 0, ar)
    elif r < 0.62:                                 # store
        ar = rreg()
        a.li(ar, _rand_addr(rng))
        getattr(a, str(rng.choice(_STORES)))(rreg(), 0, ar)
    elif r < 0.74:                                 # CSR op
        if rng.random() < 0.25:
            a.csrr(rreg(), int(rng.choice(_CSR_RO)))
        else:
            addr = int(rng.choice(_CSR_RW))
            k = rng.random()
            if k < 0.4:
                vr = rreg()
                a.li(vr, _rand_u64(rng) if rng.random() < 0.5
                     else int(rng.integers(0, 1 << 16)))
                getattr(a, str(rng.choice(("csrrw", "csrrs",
                                           "csrrc"))))(rreg(), addr, vr)
            else:
                getattr(a, str(rng.choice(("csrrwi", "csrrsi",
                                           "csrrci"))))(
                    rreg(), addr, int(rng.integers(0, 32)))
    elif r < 0.78:                                 # hlv / hsv
        ar = rreg()
        a.li(ar, _rand_addr(rng))
        if rng.random() < 0.6:
            getattr(a, str(rng.choice(_HLV)))(rreg(), ar)
        else:
            getattr(a, str(rng.choice(_HSV)))(rreg(), ar)
    elif r < 0.84:                                 # forward branch
        lab = f"c{case}u{uid[0]}"
        uid[0] += 1
        getattr(a, str(rng.choice(("beq", "bne", "blt", "bge", "bltu",
                                   "bgeu"))))(rreg(), rreg(), lab)
        for _ in range(int(rng.integers(1, 3))):
            a.addi(rreg(), rreg(), int(rng.integers(-64, 64)))
        a.label(lab)
    elif r < 0.87:                                 # time read
        a.csrr(rreg(), 0xC01)
    elif r < 0.90:
        _emit_fence(a, rng, rreg)
    elif r < 0.92 and cfg["use_wfi"]:
        a.wfi()
    elif r < 0.96:                                 # wild jump
        ar = rreg()
        a.li(ar, int(rng.choice([0x3400, 0x7008, T_MEM_BYTES + 64,
                                 0x100000])))
        a.jalr(int(rng.choice([0, 1])), 0, ar)
    else:                                          # early trap out
        [a.ecall, a.ebreak, a.sret, a.mret][int(rng.integers(0, 4))]()


def _block_straight(a: Asm, rng, cfg, case: int, uid: List[int]) -> None:
    for _ in range(int(rng.integers(3, 11))):
        _emit_item(a, rng, cfg, case, uid)


def _block_fuel(a: Asm, rng, cfg, case: int, uid: List[int]) -> None:
    """A backward branch, guaranteed to terminate: FUEL_REG (outside the
    fuzz register pool, so no item can refill it) counts down to zero."""
    lab = f"c{case}u{uid[0]}"
    uid[0] += 1
    a.li(FUEL_REG, int(rng.integers(2, 7)))
    a.label(lab)
    for _ in range(int(rng.integers(2, 6))):
        _emit_item(a, rng, cfg, case, uid, tame=True)
    a.addi(FUEL_REG, FUEL_REG, -1)
    a.bnez(FUEL_REG, lab)


def _block_pte(a: Asm, rng, cfg, case: int, uid: List[int]) -> None:
    """Rewrite a live data-page PTE mid-run, observe the stale TLB entry,
    fence (scoped or full), observe the fresh walk.  Under paging the
    table pages are only reachable when cfg["map_tables"]; an unreachable
    store simply faults out through the capture handlers."""
    rreg = lambda: int(rng.choice(_REGS))
    page = int(rng.choice(T_DATA_PAGES))
    use_g = cfg.get("hgatp", {}).get("on") and rng.random() < 0.4
    table = G_L0 if use_g else S_L0
    ar, vr, dr = rreg(), rreg(), rreg()
    perms = PTE_V | PTE_R | PTE_A | PTE_D
    if rng.random() < 0.7:
        perms |= PTE_W
    if use_g or rng.random() < 0.5:
        perms |= PTE_U
    if rng.random() < 0.15:
        perms &= ~PTE_V                            # yank the mapping
    ppn = (page >> 12) if rng.random() < 0.6 else int(rng.integers(3, 8))
    a.li(ar, page)
    a.ld(dr, 0, ar)                                # warm the TLB
    a.li(vr, table + ((page >> 12) & 0x1FF) * 8)
    a.li(dr, (ppn << 10) | perms)
    a.sd(dr, 0, vr)                                # rewrite under its feet
    a.ld(dr, 0, ar)                                # stale hit still serves
    if rng.random() < 0.6:                         # scoped: only this page
        if use_g and cfg["mode"] in ("M", "HS", "S"):
            a.hfence_gvma(rs1=ar)
        elif cfg["mode"] in ("M", "HS", "S") and rng.random() < 0.4:
            a.hfence_vvma(rs1=ar)
        else:
            a.sfence_vma(rs1=ar)
    else:
        if use_g and cfg["mode"] in ("M", "HS", "S"):
            a.hfence_gvma()
        else:
            a.sfence_vma()
    a.ld(dr, 0, ar)                                # fresh walk, new PTE


def _block_tramp(a: Asm, rng, cfg, case: int, uid: List[int]) -> None:
    """Trap trampoline: with SENT_REG holding the magic, the HS/VS
    capture handlers *resume* ecalls (epc += 4, sret) instead of
    escalating — bouncing VU→VS→VU / U→S→U / VS→HS→VS.  Each bounce
    advances epc, so progress is guaranteed; clearing the sentinel
    restores the terminal escalation chain."""
    a.li(SENT_REG, TRAMP_MAGIC)
    for _ in range(int(rng.integers(1, 4))):
        a.ecall()
        for _ in range(int(rng.integers(0, 3))):
            _emit_item(a, rng, cfg, case, uid, tame=True)
    a.li(SENT_REG, 0)


_BLOCKS = {"straight": _block_straight, "fuel": _block_fuel,
           "pte": _block_pte, "tramp": _block_tramp}


def _emit_body(a: Asm, rng, cfg, case: int) -> None:
    uid = [0]
    for kind in cfg["blocks"]:
        _BLOCKS[kind](a, rng, cfg, case, uid)
    a.ecall()                                      # terminator


def _emit_boot(a: Asm, rng, cfg) -> None:
    a.li("t0", TM_HANDLER)
    a.csrw(0x305, "t0")
    a.li("t0", TS_HANDLER)
    a.csrw(0x105, "t0")                            # stvec (V=0 at boot)
    a.li("t0", TVS_HANDLER)
    a.csrw(0x205, "t0")                            # vstvec
    for csr, val in ((0x302, cfg["medeleg"]), (0x303, cfg["mideleg"]),
                     (0x602, cfg["hedeleg"]), (0x603, cfg["hideleg"]),
                     (0x306, cfg["mcounteren"]), (0x606, cfg["hcounteren"]),
                     (0x106, cfg["scounteren"]), (0x600, cfg["hstatus"]),
                     (0x200, cfg["vsstatus"]), (0x304, cfg["mie"]),
                     (0x645, cfg["hvip"]), (0x204, cfg["vsie"]),
                     (0x605, cfg["htimedelta"])):
        if val:
            a.li("t0", val)
            a.csrw(csr, "t0")
    if cfg["mstatus_set"]:
        a.li("t0", cfg["mstatus_set"])
        a.csrrs(0, 0x300, "t0")
    a.li("t0", _atp_value(cfg["satp"], S_L2))
    if cfg["satp"]["on"]:
        a.csrw(0x180, "t0")
    a.li("t0", _atp_value(cfg["vsatp"], S_L2))
    if cfg["vsatp"]["on"]:
        a.csrw(0x280, "t0")
    a.li("t0", _atp_value(cfg["hgatp"], G_L2))
    if cfg["hgatp"]["on"]:
        a.csrw(0x680, "t0")
    if cfg["stimecmp_delta"] is not None:
        a.csrr("t0", 0xC01)
        a.addi("t0", "t0", cfg["stimecmp_delta"])
        a.csrw(0x14D, "t0")
    if cfg["vstimecmp_delta"] is not None:
        a.csrr("t0", 0xC01)
        a.csrr("t1", 0x605)
        a.add("t0", "t0", "t1")
        a.addi("t0", "t0", cfg["vstimecmp_delta"])
        a.csrw(0x24D, "t0")
    if cfg["mtimecmp_delta"] is not None:
        a.csrr("t0", 0xC01)
        a.addi("t0", "t0", cfg["mtimecmp_delta"])
        a.li("t1", 0x10004000)
        a.sd("t0", 0, "t1")
    for reg, val in sorted(cfg["seed_regs"].items()):
        a.li(reg, val)
    if cfg["mode"] == "M":
        a.j("body")
        return
    if cfg["virt"]:
        a.li("t0", C.MSTATUS_MPV)
        a.csrrs(0, 0x300, "t0")
    if not cfg["user"]:
        a.li("t0", 1 << 11)                        # MPP = S
        a.csrrs(0, 0x300, "t0")
    a.li("t0", T_BODY)
    a.csrw(0x341, "t0")                            # mepc
    a.mret()


def _emit_handlers(a: Asm) -> None:
    """Fixed capture handlers (same for every scenario).  The HS and VS
    handlers carry a trampoline fast path: an ecall cause (8..10) with
    SENT_REG == TRAMP_MAGIC resumes at epc+4 instead of escalating; the
    M handler is unconditionally terminal, which (with the undelegable
    ecall-S/ecall-M) is the global termination backstop."""
    a.pad_to(TM_HANDLER)
    # M: fingerprint = mcause ^ mtval + mepc + mtval2 → DONE
    a.csrr("t0", 0x342)
    a.csrr("t1", 0x343)
    a.xor("t0", "t0", "t1")
    a.csrr("t1", 0x341)
    a.add("t0", "t0", "t1")
    a.csrr("t1", 0x34B)
    a.add("t0", "t0", "t1")
    a.li("t6", MMIO_DONE)
    a.sd("t0", 0, "t6")
    a.label("m_spin")
    a.j("m_spin")
    a.pad_to(TS_HANDLER)
    # HS/S: trampoline bounce for sentineled ecalls (interrupt causes are
    # negative, so the signed range check routes them to capture)
    a.csrr("t4", 0x142)                            # scause
    a.li("t5", 8)
    a.blt("t4", "t5", "hs_cap")
    a.li("t5", 11)
    a.bge("t4", "t5", "hs_cap")
    a.li("t5", TRAMP_MAGIC)
    a.bne(SENT_REG, "t5", "hs_cap")
    a.csrr("t4", 0x141)                            # sepc
    a.addi("t4", "t4", 4)
    a.csrw(0x141, "t4")
    a.li("t5", T_LOG + 0x20)                       # bounce tally (diffed)
    a.ld("t4", 0, "t5")
    a.addi("t4", "t4", 1)
    a.sd("t4", 0, "t5")
    a.sret()
    a.label("hs_cap")
    # capture: log scause/stval/htval, then ecall down to M (cause 9,
    # undelegable by the WARL medeleg mask)
    a.li("t5", T_LOG)
    a.csrr("t4", 0x142)
    a.sd("t4", 0, "t5")
    a.csrr("t4", 0x143)
    a.sd("t4", 8, "t5")
    a.csrr("t4", 0x643)
    a.sd("t4", 16, "t5")
    a.ecall()
    a.label("s_spin")
    a.j("s_spin")
    a.pad_to(TVS_HANDLER)
    # VS: same bounce (vscause/vsepc via the V=1 swap; only ecall-VU=8
    # can land here), else log vscause/vstval and ecall (10 → HS or M)
    a.csrr("t4", 0x142)
    a.li("t5", 8)
    a.blt("t4", "t5", "vs_cap")
    a.li("t5", 11)
    a.bge("t4", "t5", "vs_cap")
    a.li("t5", TRAMP_MAGIC)
    a.bne(SENT_REG, "t5", "vs_cap")
    a.csrr("t4", 0x141)
    a.addi("t4", "t4", 4)
    a.csrw(0x141, "t4")
    a.li("t5", T_LOG + 0x60)                       # VS bounce tally
    a.ld("t4", 0, "t5")
    a.addi("t4", "t4", 1)
    a.sd("t4", 0, "t5")
    a.sret()
    a.label("vs_cap")
    a.li("t5", T_LOG + 0x40)
    a.csrr("t4", 0x142)
    a.sd("t4", 0, "t5")
    a.csrr("t4", 0x143)
    a.sd("t4", 8, "t5")
    a.ecall()
    a.label("vs_spin")
    a.j("vs_spin")
    a.pad_to(T_BODY)
    a.label("body")


# -- sched-family image: fuzz bodies under the preemptive scheduler ----------

class FuzzGuest(programs.Workload):
    """A seeded VS-safe fuzz body speaking the Workload protocol: only
    touches caller-saved registers (plus s0 as loop fuel), keeps
    loads/stores aligned inside the guest window (the demand pagers
    handle the faults), and optionally sprinkles WFIs — the slice timer
    the scheduler always arms is what wakes them."""
    name = "fuzzguest"
    _POOL = (5, 6, 7, 10, 11, 12, 13, 14, 15, 28, 29, 30)

    def __init__(self, spec: Dict):
        self.spec = spec

    def asm(self, a: Asm):
        rng = np.random.Generator(np.random.PCG64(
            np.random.SeedSequence([self.spec["seed"]])))
        rreg = lambda: int(rng.choice(self._POOL))
        uid = [0]
        a.label("workload_entry")
        for _ in range(self.spec["n_items"]):
            r = rng.random()
            if r < 0.30:
                getattr(a, str(rng.choice(_ALU_RR)))(rreg(), rreg(), rreg())
            elif r < 0.45:
                getattr(a, str(rng.choice(_ALU_I)))(
                    rreg(), rreg(), int(rng.integers(-2048, 2048)))
            elif r < 0.55:
                a.li(rreg(), _rand_u64(rng))
            elif r < 0.72:                         # aligned in-window ld/sd
                ar = rreg()
                a.li(ar, 0x3000 + int(rng.integers(0, 0x1800)) * 8)
                if rng.random() < 0.5:
                    a.ld(rreg(), 0, ar)
                else:
                    a.sd(rreg(), 0, ar)
            elif r < 0.80:
                a.csrr(rreg(), 0xC01)              # time (hcounteren=7)
            elif r < 0.88:
                lab = f"fg{self.spec['seed']}u{uid[0]}"
                uid[0] += 1
                getattr(a, str(rng.choice(("beq", "bne", "bltu"))))(
                    rreg(), rreg(), lab)
                a.addi(rreg(), rreg(), int(rng.integers(-64, 64)))
                a.label(lab)
            elif r < 0.94 and self.spec["loops"]:
                lab = f"fg{self.spec['seed']}u{uid[0]}"
                uid[0] += 1
                a.li(8, int(rng.integers(2, 6)))   # s0 = fuel
                a.label(lab)
                getattr(a, str(rng.choice(_ALU_RR)))(rreg(), rreg(), rreg())
                a.addi(8, 8, -1)
                a.bnez(8, lab)
            elif self.spec["wfi"]:
                a.wfi()
            else:
                getattr(a, str(rng.choice(_ALU_RR)))(rreg(), rreg(), rreg())
        a.xor("a0", "t0", "t1")
        a.add("a0", "a0", "a2")
        a.ret()

    def golden(self) -> int:
        return 0                                   # diffed, never asserted


def _build_sched_image(cfg: Dict) -> np.ndarray:
    wls = [FuzzGuest(g) for g in cfg["guests"]]
    return programs.build_image_nguest(wls, timeslice=cfg["timeslice"])


def _gen_with_seen(seed: int, case: int, seen: set) -> Scenario:
    rng = _case_rng(seed, case)
    cfg = _choose_cfg(rng, _is_sched_case(case), seen)
    if cfg["family"] == "sched":
        return Scenario(seed=seed, case=case,
                        image=_build_sched_image(cfg), cfg=cfg)
    a = Asm(0)
    _emit_boot(a, rng, cfg)
    _emit_handlers(a)
    _emit_body(a, rng, cfg, case)
    img = Image(T_MEM_WORDS)
    img.place_code(0, a.assemble())
    _build_s_tables(img, rng, cfg)
    _build_g_tables(img, rng, cfg)
    return Scenario(seed=seed, case=case, image=img.mem, cfg=cfg)


def gen_scenario(seed: int, case: int) -> Scenario:
    """Deterministically regenerate scenario `case` of corpus `seed` by
    replaying the coverage-biased candidate choices of cases 0..case-1
    (cfg sampling only — no image assembly, so replay stays cheap)."""
    seen: set = set()
    for k in range(case):
        _choose_cfg(_case_rng(seed, k), _is_sched_case(k), seen)
    return _gen_with_seen(seed, case, seen)


def generate(seed: int, count: int) -> List[Scenario]:
    seen: set = set()
    return [_gen_with_seen(seed, k, seen) for k in range(count)]


# ---------------------------------------------------------------------------
# differential run + diff
# ---------------------------------------------------------------------------

# the comparison scope is defined ONCE in engine.py (shared with
# `engine.diff_states`); the oracle models the software TLB, so `walks`
# is compared exactly — the exclusion list is empty


def _final_arrays(fleet) -> Dict[str, np.ndarray]:
    """Extract a fleet's final state as host arrays (one batched copy)."""
    from repro.core.hext import engine as _engine
    return _engine.state_arrays(fleet.harts.unwrap())


def _fleet_words(image: np.ndarray) -> int:
    """`Fleet.from_corpus`'s default sizing for one image: rounded up to
    a power of two."""
    return 1 << max(len(image) - 1, 1).bit_length()


def _pad_image(image: np.ndarray, mem_words: int) -> np.ndarray:
    """Zero-pad an image so a raw `oracle.run` leg sees the same
    address-space bound (and final-mem shape) as the batched Fleet leg."""
    out = np.zeros(mem_words, dtype=np.uint64)
    out[:len(image)] = image
    return out


def _run_corpus_fleet(scenarios: List[Scenario], max_ticks: int,
                      chunk: int, engine=None,
                      mem_words: Optional[int] = None
                      ) -> Dict[str, np.ndarray]:
    """Boot the corpus as one batched Fleet on the given engine backend
    and return final-state arrays.  ``engine=None`` is the jitted device
    model; an ``OracleEngine`` instance is the pure-Python reference —
    both legs of the differential run go through the same first-class
    ``Fleet`` path (DESIGN.md §3)."""
    from repro.core.hext.sim import Fleet
    fleet = Fleet.from_corpus([s.image for s in scenarios],
                              names=[s.name for s in scenarios],
                              mem_words=mem_words, engine=engine)
    fleet.run(max_ticks, chunk=chunk)
    return _final_arrays(fleet)


def _check_reset_parity() -> None:
    """The OracleEngine reference leg *adopts* the machine's boot state
    (``resume_state``), which would hide exactly one class of bug: a
    machine reset-state divergence.  Guard it by diffing one fresh
    machine boot against the oracle's own independent reset (non-mem
    reset state is image-independent, so one check covers the corpus —
    and keeps the single-case ``--case`` repro path, which runs
    ``oracle.run`` from the oracle's reset, equivalent to the corpus
    leg)."""
    from repro.core.hext import engine as _engine
    from repro.core.hext.sim import HartState
    img = np.zeros(64, dtype=np.uint64)
    mach = _engine.state_arrays(HartState.fresh(64))
    orac = _oracle_arrays(oracle.reset_state(img))
    d = _engine.diff_arrays(mach, 0, orac, 0)
    if d:
        raise AssertionError(
            f"machine reset state diverged from the oracle's independent "
            f"reset: {d[:4]}")


def _oracle_arrays(ost: Dict) -> Dict[str, np.ndarray]:
    """Shape one oracle final state like a batch-of-1 `_final_arrays`."""
    out = {
        "pc": np.array([ost["pc"]], dtype=np.uint64),
        "regs": np.array([ost["regs"]], dtype=np.uint64),
        "csrs": np.array([ost["csrs"]], dtype=np.uint64),
        "priv": np.array([ost["priv"]]),
        "virt": np.array([1 if ost["virt"] else 0]),
        "halted": np.array([1 if ost["halted"] else 0]),
        "mem": np.array([ost["mem"]], dtype=np.uint64),
        "console": np.array([ost["console"]]),
        "done": np.array([1 if ost["done"] else 0]),
        "exit_code": np.array([ost["exit_code"]], dtype=np.uint64),
        "exc_by_level": np.array([ost["exc_by_level"]]),
        "int_by_level": np.array([ost["int_by_level"]]),
    }
    for k in _COUNTERS:
        out[k] = np.array([ost[k]])
    return out


def diff_pair(mach: Dict[str, np.ndarray], i: int,
              orac: Dict[str, np.ndarray], j: int) -> List[str]:
    """Compare machine hart `i` against oracle hart `j`, field by field —
    a thin wrapper over the single shared comparison core
    (`engine.diff_arrays`; in the output `a` is the machine, `b` the
    oracle; every counter including `walks` is in scope)."""
    from repro.core.hext.engine import diff_arrays
    return diff_arrays(mach, i, orac, j)


def diff_case(mach: Dict[str, np.ndarray], i: int, ost: Dict) -> List[str]:
    """Compare machine hart `i` against an oracle final-state dict (the
    single-case repro path)."""
    return diff_pair(mach, i, _oracle_arrays(ost), 0)


# ---------------------------------------------------------------------------
# coverage accounting
# ---------------------------------------------------------------------------

def _bucket_key(b) -> str:
    return "|".join(str(x) for x in b)


def coverage_map(scenarios: List[Scenario],
                 events_by_case: Dict[int, frozenset]) -> Dict[str, int]:
    """Histogram of coverage buckets over a corpus: the static shape
    buckets plus the oracle-recorded architectural-event signatures
    (trap cause × priv × V, fence kind × scope, atp writes, WFI)."""
    hist: Dict[str, int] = {}
    for s in scenarios:
        buckets = set(_static_buckets(s.cfg))
        buckets |= set(events_by_case.get(s.case, ()))
        for b in sorted(_bucket_key(x) for x in buckets):
            hist[b] = hist.get(b, 0) + 1
    return hist


def run_corpus(seed: int, count: int, max_ticks: int = MAX_TICKS,
               chunk: int = CHUNK, verbose: bool = False) -> Dict:
    """Generate, run (per-family batched Fleets + oracle), diff, and
    bucket coverage.  Returns a report dict."""
    from repro.core.hext.engine import OracleEngine
    # the device engine rounds the budget UP to whole chunk-scans; the
    # oracle must run the exact same tick count or budget-burning
    # scenarios would report phantom mismatches
    rnd = lambda t: -(-int(t) // int(chunk)) * int(chunk)
    _check_reset_parity()
    t0 = time.time()
    scenarios = generate(seed, count)
    t_gen = time.time() - t0
    failures: List[Dict] = []
    events_by_case: Dict[int, frozenset] = {}
    t_mach = t_oracle = 0.0
    families = [("fuzz", [s for s in scenarios if s.family == "fuzz"]),
                ("sched", [s for s in scenarios if s.family == "sched"])]
    for family, scens in families:
        if not scens:
            continue
        budget = rnd(max_ticks if family == "fuzz"
                     else max(SCHED_MAX_TICKS, max_ticks))
        mem_words = T_MEM_WORDS if family == "fuzz" else None
        t0 = time.time()
        mach = _run_corpus_fleet(scens, budget, chunk, mem_words=mem_words)
        t_mach += time.time() - t0
        # the reference leg: the SAME fleet on the OracleEngine backend
        t0 = time.time()
        oeng = OracleEngine()
        orac = _run_corpus_fleet(scens, budget, chunk, engine=oeng,
                                 mem_words=mem_words)
        t_oracle += time.time() - t0
        for i, s in enumerate(scens):
            if i < len(oeng.last_events):
                events_by_case[s.case] = oeng.last_events[i]
            d = diff_pair(mach, i, orac, i)
            if d:
                failures.append({"case": s.case, "mode": s.cfg["mode"],
                                 "repro": repro_line(seed, s.case),
                                 "diff": d})
                if verbose:
                    print(f"MISMATCH case {s.case} ({s.cfg['mode']}): "
                          f"{d[:4]}\n  repro: {repro_line(seed, s.case)}")
    hist = coverage_map(scenarios, events_by_case)
    return {
        "seed": seed, "count": count, "max_ticks": rnd(max_ticks),
        "failures": failures,
        "coverage": {"buckets": len(hist), "histogram": hist},
        "wall_gen": t_gen, "wall_machine": t_mach, "wall_oracle": t_oracle,
        "scenarios_per_sec_batched": count / max(t_mach, 1e-9),
    }


# ---------------------------------------------------------------------------
# CLI: corpus run, or one-case repro with a full diff dump
# ---------------------------------------------------------------------------

def _write_report(path: Optional[str], rep: Dict) -> None:
    if not path:
        return
    import json
    import os
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as fh:
        json.dump(rep, fh, indent=2)


# single-field corruptions of the machine-leg arrays: the mutation hooks
# the exit-status conformance test drives (--inject-fault)
_INJECTORS = {
    "x7": lambda m: m["regs"].__setitem__(
        (0, 7), int(m["regs"][0, 7]) ^ 0xDEAD),
    "pc": lambda m: m["pc"].__setitem__(0, int(m["pc"][0]) ^ 4),
    "instret": lambda m: m["instret"].__setitem__(
        0, int(m["instret"][0]) + 1),
    "walks": lambda m: m["walks"].__setitem__(0, int(m["walks"][0]) + 1),
    "mem": lambda m: m["mem"].__setitem__(
        (0, 0x3000 // 8), int(m["mem"][0, 0x3000 // 8]) ^ 1),
    "exit_code": lambda m: m["exit_code"].__setitem__(
        0, int(m["exit_code"][0]) ^ 1),
}

_CASE_FIELDS = ("pc", "priv", "virt", "halted", "done", "exit_code",
                "console") + tuple(
    ("instret", "instret_virt", "pagefaults", "walks", "ticks",
     "timer_irqs", "ctx_switches"))


def _case_main(seed: int, case: int, max_ticks: int, verbose: bool,
               out: Optional[str] = None,
               inject_fault: Optional[str] = None) -> int:
    s = gen_scenario(seed, case)
    max_ticks = -(-int(max(max_ticks, s.max_ticks)) // CHUNK) * CHUNK
    print(f"case {case} of seed {seed}: family={s.family} "
          f"mode={s.cfg['mode']}" +
          (f" satp={s.cfg['satp']} vsatp={s.cfg['vsatp']} "
           f"hgatp={s.cfg['hgatp']} blocks={s.cfg['blocks']}"
           if s.family == "fuzz" else
           f" guests={s.cfg['n_guests']} timeslice={s.cfg['timeslice']}"))
    mem_words = _fleet_words(s.image)
    mach = _run_corpus_fleet([s], max_ticks, CHUNK, mem_words=mem_words)
    ost = oracle.run(_pad_image(s.image, mem_words), max_ticks)
    if inject_fault:
        # the Fleet arrays are read-only device views; copy before mutating
        mach = {k: np.array(v) for k, v in mach.items()}
        _INJECTORS[inject_fault](mach)
        print(f"(injected fault into machine-leg field {inject_fault!r})")
    # both-model values for every scalar/counter field, pass or fail
    print(f"{'field':<14}{'machine':>20}{'oracle':>20}")
    for k in _CASE_FIELDS:
        mv = int(mach[k][0])
        ov = int(_oracle_arrays(ost)[k][0])
        print(f"{k:<14}{mv:>20}{ov:>20}")
    d = diff_case(mach, 0, ost)
    _write_report(out, {"seed": seed, "case": case, "max_ticks": max_ticks,
                        "mode": s.cfg["mode"], "diff": d,
                        "repro": repro_line(seed, case)})
    if d:
        print(f"MISMATCH ({len(d)} fields; a=machine b=oracle):")
        for line in d:
            print(f"  {line}")
        print(f"repro: {repro_line(seed, case)}")
        return 1
    print("machine == oracle")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    import json
    ap = argparse.ArgumentParser(
        description="coverage-guided differential conformance harness")
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED)
    ap.add_argument("--count", type=int, default=256)
    ap.add_argument("--case", type=int, default=None,
                    help="re-run ONE scenario with a full diff dump")
    ap.add_argument("--max-ticks", type=int, default=MAX_TICKS)
    ap.add_argument("--out", default=None, help="write a JSON report")
    ap.add_argument("--coverage-out", default=None,
                    help="write the coverage-bucket histogram JSON")
    ap.add_argument("--coverage-baseline", default=None,
                    help="fail if bucket count regresses below this "
                         "baseline JSON's 'buckets'")
    ap.add_argument("--inject-fault", default=None,
                    choices=sorted(_INJECTORS),
                    help="corrupt one machine-leg field before diffing "
                         "(single-case mode; exercises the exit status)")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)
    if args.case is not None:
        return _case_main(args.seed, args.case, args.max_ticks, args.verbose,
                          out=args.out, inject_fault=args.inject_fault)
    rep = run_corpus(args.seed, args.count, args.max_ticks,
                     verbose=args.verbose)
    cov = rep["coverage"]
    print(f"seed {rep['seed']}: {rep['count']} scenarios, "
          f"{len(rep['failures'])} mismatches, "
          f"{cov['buckets']} coverage buckets "
          f"(machine {rep['wall_machine']:.1f}s = "
          f"{rep['scenarios_per_sec_batched']:.1f}/s batched, "
          f"oracle {rep['wall_oracle']:.1f}s)")
    for f in rep["failures"]:
        print(f"  case {f['case']} ({f['mode']}): {f['diff'][0]}")
        print(f"    repro: {f['repro']}")
    _write_report(args.out, rep)
    if args.coverage_out:
        _write_report(args.coverage_out,
                      {"seed": rep["seed"], "count": rep["count"],
                       "buckets": cov["buckets"],
                       "histogram": cov["histogram"]})
    rc = 1 if rep["failures"] else 0
    if args.coverage_baseline:
        with open(args.coverage_baseline) as fh:
            base = json.load(fh)
        if cov["buckets"] < int(base["buckets"]):
            print(f"COVERAGE REGRESSION: {cov['buckets']} buckets < "
                  f"baseline {base['buckets']}")
            rc = 1
        else:
            print(f"coverage: {cov['buckets']} buckets >= "
                  f"baseline {base['buckets']}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
