"""Trap & interrupt routing (paper §3.2, Fig 2).

``route``: delegation chain — M unless medeleg/mideleg delegates to HS,
then VS if (V=1 and hedeleg/hideleg delegates further).
``take_trap``: the ``RiscvFault::invoke()`` analogue — updates
{m,s,vs}status/cause/epc/tval (+ htval/mtval2/htinst/mtinst, GVA, MPV, SPV,
SPVP), switches privilege/virtualization mode, and returns the handler PC.
``pending_interrupt``: the per-tick ``CheckInterrupts()`` with the AIA-less
default priority order MEI>MSI>MTI>SEI>SSI>STI>SGEI>VSEI>VSSI>VSTI.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.hext import csr as C
from repro.core.hext.bits import U64
from repro.core.hext.bits import u64 as _u


class TrapTarget(NamedTuple):
    priv: jnp.ndarray   # int32 target privilege (3=M, 1=S/HS or VS)
    virt: jnp.ndarray   # bool target virtualization mode


def route(csrs, priv, virt, cause, is_int):
    """Delegation per §3.2: read {m,h}{e,i}deleg based on current priv."""
    bit = _u(1) << (cause & _u(63))
    mdeleg = jnp.where(is_int, csrs[C.R_MIDELEG], csrs[C.R_MEDELEG])
    hdeleg = jnp.where(is_int, csrs[C.R_HIDELEG], csrs[C.R_HEDELEG])
    m_delegates = (mdeleg & bit) != 0
    h_delegates = (hdeleg & bit) != 0
    # traps from M never delegate down
    to_hs_or_vs = m_delegates & (priv < 3)
    # VS-level interrupts delegated via hideleg go straight to VS when V=1;
    # exceptions likewise require V=1 (HS faults never route to VS)
    to_vs = to_hs_or_vs & h_delegates & virt
    tgt_priv = jnp.where(to_hs_or_vs, 1, 3).astype(jnp.int32)
    tgt_virt = to_vs
    return TrapTarget(priv=tgt_priv, virt=tgt_virt)


def take_trap(csrs, priv, virt, pc, cause, is_int, tval, tval2, gva, tinst):
    """Apply the trap to the CSR file → (csrs, new_pc, new_priv, new_virt,
    handled_level) where handled_level ∈ {0:M, 1:HS, 2:VS}."""
    tgt = route(csrs, priv, virt, cause, is_int)
    scause = jnp.where(is_int, cause | _u(C.INT_BIT), cause)

    mstatus = csrs[C.R_MSTATUS]
    hstatus = csrs[C.R_HSTATUS]
    vsstatus = csrs[C.R_VSSTATUS]

    # ---- to M --------------------------------------------------------------
    mst = mstatus
    mst = (mst & ~_u(C.MSTATUS_MPP)) | (_u(priv) << _u(11) & _u(C.MSTATUS_MPP))
    mie = (mstatus & _u(C.MSTATUS_MIE)) != 0
    mst = jnp.where(mie, mst | _u(C.MSTATUS_MPIE), mst & ~_u(C.MSTATUS_MPIE))
    mst = mst & ~_u(C.MSTATUS_MIE)
    mst = jnp.where(virt, mst | _u(C.MSTATUS_MPV), mst & ~_u(C.MSTATUS_MPV))
    mst = jnp.where(gva, mst | _u(C.MSTATUS_GVA), mst & ~_u(C.MSTATUS_GVA))
    csrs_m = csrs
    csrs_m = csrs_m.at[C.R_MSTATUS].set(mst)
    csrs_m = csrs_m.at[C.R_MEPC].set(_u(pc))
    csrs_m = csrs_m.at[C.R_MCAUSE].set(scause)
    csrs_m = csrs_m.at[C.R_MTVAL].set(_u(tval))
    csrs_m = csrs_m.at[C.R_MTVAL2].set(_u(tval2))
    csrs_m = csrs_m.at[C.R_MTINST].set(_u(tinst))
    pc_m = csrs[C.R_MTVEC] & ~_u(3)

    # ---- to HS -------------------------------------------------------------
    sst = mstatus
    sst = jnp.where(priv >= 1, sst | _u(C.MSTATUS_SPP),
                    sst & ~_u(C.MSTATUS_SPP))
    sie = (mstatus & _u(C.MSTATUS_SIE)) != 0
    sst = jnp.where(sie, sst | _u(C.MSTATUS_SPIE), sst & ~_u(C.MSTATUS_SPIE))
    sst = sst & ~_u(C.MSTATUS_SIE)
    hst = hstatus
    hst = jnp.where(virt, hst | _u(C.HSTATUS_SPV), hst & ~_u(C.HSTATUS_SPV))
    # SPVP: previous privilege *inside* the guest (only meaningful if V was 1)
    hst = jnp.where(virt & (priv >= 1), hst | _u(C.HSTATUS_SPVP),
                    jnp.where(virt, hst & ~_u(C.HSTATUS_SPVP), hst))
    hst = jnp.where(gva, hst | _u(C.HSTATUS_GVA), hst & ~_u(C.HSTATUS_GVA))
    csrs_h = csrs
    csrs_h = csrs_h.at[C.R_MSTATUS].set(sst)
    csrs_h = csrs_h.at[C.R_HSTATUS].set(hst)
    csrs_h = csrs_h.at[C.R_SEPC].set(_u(pc))
    csrs_h = csrs_h.at[C.R_SCAUSE].set(scause)
    csrs_h = csrs_h.at[C.R_STVAL].set(_u(tval))
    csrs_h = csrs_h.at[C.R_HTVAL].set(_u(tval2))
    csrs_h = csrs_h.at[C.R_HTINST].set(_u(tinst))
    pc_h = csrs[C.R_STVEC] & ~_u(3)

    # ---- to VS -------------------------------------------------------------
    vst = vsstatus
    vst = jnp.where(priv >= 1, vst | _u(C.MSTATUS_SPP),
                    vst & ~_u(C.MSTATUS_SPP))
    vsie = (vsstatus & _u(C.MSTATUS_SIE)) != 0
    vst = jnp.where(vsie, vst | _u(C.MSTATUS_SPIE),
                    vst & ~_u(C.MSTATUS_SPIE))
    vst = vst & ~_u(C.MSTATUS_SIE)
    # VS-level interrupt causes are presented shifted to S encodings
    vs_cause = jnp.where(is_int & (cause >= _u(2)) & (cause <= _u(10)),
                         scause - _u(1), scause)
    csrs_v = csrs
    csrs_v = csrs_v.at[C.R_VSSTATUS].set(vst)
    csrs_v = csrs_v.at[C.R_VSEPC].set(_u(pc))
    csrs_v = csrs_v.at[C.R_VSCAUSE].set(vs_cause)
    csrs_v = csrs_v.at[C.R_VSTVAL].set(_u(tval))
    pc_v = csrs[C.R_VSTVEC] & ~_u(3)

    to_m = tgt.priv == 3
    to_vs = tgt.virt
    new_csrs = jnp.where(to_m, csrs_m, jnp.where(to_vs, csrs_v, csrs_h))
    new_pc = jnp.where(to_m, pc_m, jnp.where(to_vs, pc_v, pc_h))
    new_priv = tgt.priv
    new_virt = to_vs
    handled = jnp.where(to_m, 0, jnp.where(to_vs, 2, 1)).astype(jnp.int32)
    return new_csrs, new_pc, new_priv, new_virt, handled


# interrupt priority: MEI, MSI, MTI, SEI, SSI, STI, SGEI, VSEI, VSSI, VSTI
_PRIORITY = (11, 3, 7, 9, 1, 5, 12, 10, 2, 6)


def pending_interrupt(csrs, priv, virt):
    """CheckInterrupts(): → (take, cause). Reads mip/mie + mstatus.MIE/SIE +
    mideleg/hideleg per current privilege (paper Fig 2)."""
    mip = csrs[C.R_MIP]
    mie = csrs[C.R_MIE]
    mideleg = csrs[C.R_MIDELEG]
    hideleg = csrs[C.R_HIDELEG]
    mstatus = csrs[C.R_MSTATUS]
    vsstatus = csrs[C.R_VSSTATUS]

    pend = mip & mie
    m_enabled = (priv < 3) | (((mstatus & _u(C.MSTATUS_MIE)) != 0) &
                              (priv == 3))
    s_enabled = (priv < 1) | ((priv == 1) & ~virt &
                              ((mstatus & _u(C.MSTATUS_SIE)) != 0))
    vs_enabled = (virt & (priv < 1)) | \
        (virt & (priv == 1) & ((vsstatus & _u(C.MSTATUS_SIE)) != 0))

    take = jnp.zeros((), bool)
    cause = _u(0)
    for code in _PRIORITY:
        bit = _u(1 << code)
        p = (pend & bit) != 0
        deleg_hs = (mideleg & bit) != 0
        deleg_vs = deleg_hs & ((hideleg & bit) != 0)
        # where would it be handled?
        at_m = ~deleg_hs
        at_vs = deleg_vs
        at_hs = deleg_hs & ~deleg_vs
        en = jnp.where(at_m, m_enabled,
                       jnp.where(at_vs, vs_enabled & virt,
                                 s_enabled | (virt & (priv <= 1))))
        # HS-level interrupts always preempt VS execution
        fire = p & en
        cause = jnp.where(~take & fire, _u(code), cause)
        take = take | fire
    return take, cause
