"""gem5-style checkpointing for hext fleets (DESIGN.md §3).

A checkpoint is a single versioned ``.npz`` holding every leaf of the
batched ``HartState`` pytree plus a JSON metadata record:

* one array per architectural field (``pc``, ``regs``, ``csrs``, …),
  ``tlb.<key>`` for the software-TLB sub-pytree and ``counters.<key>``
  for the counter record — host numpy, exact dtypes, leading fleet dim;
* ``__meta__`` — ``{format, version, schema, schema_sha256, specs,
  engine}``.  ``schema`` is the sorted ``(key, dtype, shape)`` table of
  the saved arrays and ``schema_sha256`` its hash; on restore the schema
  is recomputed from the arrays actually present and must hash to the
  stored value, so a truncated/tampered file or a snapshot written by an
  incompatible ``HartState`` layout is rejected with
  :class:`CheckpointError` instead of resuming silently wrong.

Restore rebuilds the typed state bit-for-bit, so
``snapshot → restore → run`` is indistinguishable from an uninterrupted
run (tested per workload class).  ``HartSpec`` metadata travels by
workload *name* and is resolved against the standard registry
(``programs.WORKLOADS``); custom workloads restore with
``workload=None`` (golden checks unavailable) unless the caller passes
explicit specs to ``Fleet.restore``.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hext import machine as _machine
from repro.core.hext import programs as _programs

FORMAT = "hext-fleet-checkpoint"
VERSION = 1
GUEST_FORMAT = "hext-guest-checkpoint"
GUEST_VERSION = 1
# per-guest migratable regions, in programs.guest_regions order
GUEST_REGIONS = ("ctx", "gtab", "window", "mailbox", "ginfo")

__all__ = ["CheckpointError", "FORMAT", "VERSION", "GUEST_FORMAT",
           "GUEST_VERSION", "GUEST_REGIONS", "save", "load", "save_guest",
           "load_guest", "schema_of", "schema_sha256", "workload_registry"]


class CheckpointError(RuntimeError):
    """A checkpoint file is unreadable, corrupted, or schema-incompatible."""


def _x64():
    return jax.experimental.enable_x64()


_STATE_KEYS = ("pc", "regs", "csrs", "priv", "virt", "mem", "halted",
               "console")
_COUNTER_KEYS = ("done", "exit_code", "instret", "instret_virt",
                 "exc_by_level", "int_by_level", "pagefaults", "walks",
                 "ticks", "timer_irqs", "ctx_switches")


def _flatten(harts) -> Dict[str, np.ndarray]:
    with _x64():
        out = {k: np.asarray(getattr(harts, k)) for k in _STATE_KEYS}
        out.update({f"tlb.{k}": np.asarray(v)
                    for k, v in harts.tlb.items()})
        out.update({f"counters.{k}": np.asarray(getattr(harts.counters, k))
                    for k in _COUNTER_KEYS})
        return out


def _expected_keys_and_dtypes() -> Dict[str, np.dtype]:
    """What the *current* HartState layout looks like (tiny reference
    state) — the restore side's notion of a compatible schema."""
    with _x64():
        ref = _machine._make_state(1)
    out = {k: np.asarray(ref[k]).dtype for k in _STATE_KEYS}
    out.update({f"tlb.{k}": np.asarray(v).dtype
                for k, v in ref["tlb"].items()})
    out.update({f"counters.{k}": np.asarray(ref[k]).dtype
                for k in _COUNTER_KEYS})
    return out


def schema_of(arrays: Dict[str, np.ndarray]) -> List[List[Any]]:
    """Canonical, JSON-stable ``[key, dtype, shape]`` table."""
    return [[k, arrays[k].dtype.str, list(arrays[k].shape)]
            for k in sorted(arrays)]


def schema_sha256(schema: List[List[Any]]) -> str:
    return hashlib.sha256(
        json.dumps(schema, separators=(",", ":")).encode()).hexdigest()


# ---------------------------------------------------------------------------
# HartSpec (de)serialization — workloads travel by name
# ---------------------------------------------------------------------------

def workload_registry() -> Dict[str, Any]:
    reg = {}
    for w in _programs.WORKLOADS + _programs.WORKLOADS_EXTRA:
        # several workloads materialize their input buffer (and hence
        # their golden) in write_data; a restored spec may be the first
        # user of the shared instance in this process, so warm it against
        # a scratch image (write_data is seeded → idempotent)
        w.write_data(_programs.Image(_programs.MEM_WORDS))
        reg[w.name] = w
    return reg


def _encode_spec(spec) -> Dict[str, Any]:
    return {
        "name": spec.name,
        "guest": bool(spec.guest),
        "timeslice": int(spec.timeslice),
        "workload": None if spec.workload is None else spec.workload.name,
        "guests": None if spec.guests is None else
        [None if w is None else w.name for w in spec.guests],
    }


def _decode_spec(d: Dict[str, Any], reg: Dict[str, Any]):
    from repro.core.hext.sim import HartSpec
    wl = reg.get(d["workload"]) if d["workload"] is not None else None
    guests = None
    if d["guests"] is not None:
        # a stored null is a migrated-away slot (legitimately None); an
        # unknown *name* must NOT decode to None — the report layer would
        # read it as migrated-away and mis-total the expected checksum.
        # The caller has to supply explicit specs instead.
        unknown = [n for n in d["guests"]
                   if n is not None and n not in reg]
        if unknown:
            raise CheckpointError(
                f"spec {d['name']!r} references guest workloads not in "
                f"the registry: {unknown} — restore with explicit "
                f"Fleet.restore(path, specs=...)")
        guests = tuple(None if n is None else reg[n]
                       for n in d["guests"])
    return HartSpec(workload=wl, guest=bool(d["guest"]),
                    name=str(d["name"]), guests=guests,
                    timeslice=int(d["timeslice"]))


# ---------------------------------------------------------------------------
# save / load
# ---------------------------------------------------------------------------

def _atomic_savez(path: str, **payload) -> str:
    """Write an ``.npz`` atomically: serialize to a temp file in the same
    directory, fsync, then ``os.replace`` over the target.  A crash (or
    kill) mid-write leaves the previous file intact — it can never leave
    a truncated ``.npz`` that :class:`CheckpointError`s at recovery time,
    exactly when the serving control plane needs its last snapshot."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".ckpt-", suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as fh:
            np.savez_compressed(fh, **payload)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def save(path: str, harts, specs: Sequence[Any],
         engine_name: str = "jit") -> str:
    """Write the fleet's full state + spec metadata as a versioned .npz
    (atomically — see :func:`_atomic_savez`)."""
    arrays = _flatten(harts)
    nharts = int(arrays["pc"].shape[0]) if arrays["pc"].ndim else 1
    if len(specs) != nharts:
        raise ValueError(f"{len(specs)} specs for {nharts} harts")
    schema = schema_of(arrays)
    meta = {
        "format": FORMAT,
        "version": VERSION,
        "schema": schema,
        "schema_sha256": schema_sha256(schema),
        "specs": [_encode_spec(s) for s in specs],
        "engine": engine_name,
    }
    return _atomic_savez(path, __meta__=np.array(json.dumps(meta)),
                         **arrays)


def load(path: str, decode_specs: bool = True) -> Tuple[Any, List[Any]]:
    """Read a checkpoint → ``(HartState, [HartSpec])``.

    Raises :class:`CheckpointError` on anything that cannot restore
    bit-for-bit: unreadable/corrupted files, a version or schema-hash
    mismatch, and fields missing/extra/retyped relative to the current
    ``HartState`` layout.  ``decode_specs=False`` skips workload-name
    resolution (returns ``[]``) — for callers supplying their own specs,
    e.g. when the snapshot ran custom workload objects."""
    try:
        z = np.load(path, allow_pickle=False)
    except Exception as e:
        raise CheckpointError(f"unreadable checkpoint {path!r}: {e}") from e
    with z:
        if "__meta__" not in z.files:
            raise CheckpointError(f"{path!r} has no __meta__ record — "
                                  f"not a {FORMAT} file")
        try:
            meta = json.loads(str(z["__meta__"][()]))
            arrays = {k: z[k] for k in z.files if k != "__meta__"}
        except Exception as e:
            raise CheckpointError(f"corrupted checkpoint {path!r}: "
                                  f"{e}") from e
    if meta.get("format") != FORMAT:
        raise CheckpointError(
            f"{path!r}: format {meta.get('format')!r} != {FORMAT!r}")
    if meta.get("version") != VERSION:
        raise CheckpointError(
            f"{path!r}: checkpoint version {meta.get('version')} is not "
            f"supported (this build reads version {VERSION})")
    schema = schema_of(arrays)
    if schema_sha256(schema) != meta.get("schema_sha256") or \
            schema != meta.get("schema"):
        raise CheckpointError(
            f"{path!r}: schema hash mismatch — the file is corrupted or "
            f"was edited after save")
    expected = _expected_keys_and_dtypes()
    missing = sorted(set(expected) - set(arrays))
    extra = sorted(set(arrays) - set(expected))
    if missing or extra:
        raise CheckpointError(
            f"{path!r}: field set does not match this build's HartState "
            f"(missing {missing}, unexpected {extra}) — snapshot from an "
            f"incompatible version")
    for k, dt in expected.items():
        if arrays[k].dtype != dt:
            raise CheckpointError(
                f"{path!r}: field {k!r} has dtype {arrays[k].dtype}, "
                f"this build expects {dt}")
    harts = _to_harts(arrays)
    specs: List[Any] = []
    if decode_specs:
        reg = workload_registry()             # built once per load
        specs = [_decode_spec(d, reg) for d in meta.get("specs", [])]
    return harts, specs


# ---------------------------------------------------------------------------
# per-guest checkpoints ("parking") — guest-granularity leaf extraction
# ---------------------------------------------------------------------------

def save_guest(path: str, regions: Dict[str, np.ndarray], *, n: int,
               slot: int, timeslice: int = 0,
               workload: Any = None) -> str:
    """Write one guest VM's migratable state as a versioned ``.npz``.

    ``regions`` maps the :data:`GUEST_REGIONS` names to the uint64 word
    arrays lifted from the owning hart's memory
    (``programs.guest_regions`` order: saved context, G-stage table
    block, physical window, result mailbox, scheduler info block).  The
    region addresses are slot-determined, so the file records ``n`` (the
    scheduler layout) and ``slot`` — a parked guest can only resume into
    slot ``slot`` of an N=``n`` hart.  Written atomically like fleet
    snapshots."""
    lay = _programs.sched_layout(int(n))
    expect = {name: size // 8 for name, (_, size) in
              zip(GUEST_REGIONS, _programs.guest_regions(lay, int(slot)))}
    if set(regions) != set(GUEST_REGIONS):
        raise CheckpointError(
            f"regions must be exactly {sorted(GUEST_REGIONS)}, "
            f"got {sorted(regions)}")
    arrays = {}
    for name in GUEST_REGIONS:
        a = np.asarray(regions[name], dtype=np.uint64)
        if a.shape != (expect[name],):
            raise CheckpointError(
                f"region {name!r}: shape {a.shape} != ({expect[name]},) "
                f"for an N={n} layout")
        arrays[f"region.{name}"] = a
    schema = schema_of(arrays)
    meta = {
        "format": GUEST_FORMAT,
        "version": GUEST_VERSION,
        "schema": schema,
        "schema_sha256": schema_sha256(schema),
        "n": int(n),
        "slot": int(slot),
        "timeslice": int(timeslice),
        "workload": None if workload is None else str(workload),
    }
    return _atomic_savez(path, __meta__=np.array(json.dumps(meta)),
                         **arrays)


def load_guest(path: str) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Read a parked-guest checkpoint → ``({region: words}, meta)``.

    Raises :class:`CheckpointError` on unreadable/corrupted files, a
    format/version mismatch, a schema-hash mismatch, or region sizes
    inconsistent with the recorded ``(n, slot)`` layout."""
    try:
        z = np.load(path, allow_pickle=False)
    except Exception as e:
        raise CheckpointError(f"unreadable guest checkpoint {path!r}: "
                              f"{e}") from e
    with z:
        if "__meta__" not in z.files:
            raise CheckpointError(f"{path!r} has no __meta__ record — "
                                  f"not a {GUEST_FORMAT} file")
        try:
            meta = json.loads(str(z["__meta__"][()]))
            arrays = {k: z[k] for k in z.files if k != "__meta__"}
        except Exception as e:
            raise CheckpointError(f"corrupted guest checkpoint {path!r}: "
                                  f"{e}") from e
    if meta.get("format") != GUEST_FORMAT:
        raise CheckpointError(
            f"{path!r}: format {meta.get('format')!r} != {GUEST_FORMAT!r}")
    if meta.get("version") != GUEST_VERSION:
        raise CheckpointError(
            f"{path!r}: guest checkpoint version {meta.get('version')} is "
            f"not supported (this build reads version {GUEST_VERSION})")
    schema = schema_of(arrays)
    if schema_sha256(schema) != meta.get("schema_sha256") or \
            schema != meta.get("schema"):
        raise CheckpointError(
            f"{path!r}: schema hash mismatch — the file is corrupted or "
            f"was edited after save")
    want = {f"region.{name}" for name in GUEST_REGIONS}
    if set(arrays) != want:
        raise CheckpointError(
            f"{path!r}: region set {sorted(arrays)} does not match "
            f"{sorted(want)}")
    try:
        n, slot = int(meta["n"]), int(meta["slot"])
        lay = _programs.sched_layout(n)
        sizes = {name: size // 8 for name, (_, size) in
                 zip(GUEST_REGIONS, _programs.guest_regions(lay, slot))}
    except Exception as e:
        raise CheckpointError(
            f"{path!r}: bad layout metadata (n={meta.get('n')!r}, "
            f"slot={meta.get('slot')!r}): {e}") from e
    regions = {}
    for name in GUEST_REGIONS:
        a = arrays[f"region.{name}"]
        if a.dtype != np.uint64 or a.shape != (sizes[name],):
            raise CheckpointError(
                f"{path!r}: region {name!r} is {a.dtype}{a.shape}, "
                f"expected uint64 ({sizes[name]},) for the recorded "
                f"N={n}/slot={slot} layout")
        regions[name] = a
    return regions, meta


def _to_harts(arrays: Dict[str, np.ndarray]):
    from repro.core.hext.sim import Counters, HartState
    with _x64():
        j = {k: jnp.asarray(v) for k, v in arrays.items()}
        counters = Counters(**{k: j[f"counters.{k}"]
                               for k in _COUNTER_KEYS})
        tlb = {k.split(".", 1)[1]: v
               for k, v in j.items() if k.startswith("tlb.")}
        return HartState(
            pc=j["pc"], regs=j["regs"], csrs=j["csrs"], priv=j["priv"],
            virt=j["virt"], mem=j["mem"], tlb=tlb, halted=j["halted"],
            console=j["console"], counters=counters)
