"""Placement / shedding / eviction policies for the fleet control plane.

The :class:`FleetService` daemon (:mod:`repro.core.hext.service`) is
policy-agnostic: every decision about *where* work runs goes through a
``PlacementPolicy`` object.  The policy sees only light-weight views —
:class:`JobView` for queued/parked jobs and :class:`LaneView` for live
harts — and answers four questions:

* ``admit``  — may another submission enter the queue?
* ``pack``   — which queued jobs boot together on a fresh hart (cohorts)?
* ``shed``   — should a hot hart live-migrate a guest to a cooler one?
* ``victim`` — which guest is parked to a checkpoint under capacity
  pressure?

The default :class:`BinPackPolicy` packs first-fit-decreasing by image
size bucket with tenant anti-affinity (spread one tenant's guests across
harts when possible), sheds when the live-guest imbalance between two
harts reaches ``shed_margin``, and evicts the youngest guest from the
most-loaded hart.  All decisions are deterministic — the serve benchmark
and its goldens depend on reproducible traces.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hext import programs as _programs

__all__ = ["JobView", "LaneView", "ShedDecision", "PlacementPolicy",
           "BinPackPolicy", "workload_footprint", "size_bucket"]


# ---------------------------------------------------------------------------
# policy-visible views
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class JobView:
    """What a policy may know about one queued/parked job."""
    job_id: int
    tenant: int
    name: str
    weight: int                 # size bucket (0 = small … 2 = large)
    age: int                    # control rounds spent in the queue
    slot: Optional[int] = None  # parked jobs: the slot they must resume into


@dataclasses.dataclass(frozen=True)
class LaneView:
    """One live preemptive hart: which slots run which jobs."""
    lane: int
    jobs: Tuple[Optional[int], ...]   # slot -> job_id (None = not live)
    free_slots: Tuple[int, ...]       # slots a guest could land in

    @property
    def live(self) -> int:
        return sum(1 for j in self.jobs if j is not None)


@dataclasses.dataclass(frozen=True)
class ShedDecision:
    """Live-migrate slot ``slot`` from hart ``src`` to hart ``dst``."""
    src: int
    dst: int
    slot: int


class PlacementPolicy:
    """Interface every control-plane policy implements."""

    def admit(self, queue_len: int) -> bool:
        raise NotImplementedError

    def pack(self, queued: Sequence[JobView], n_lanes: int, slots: int,
             reserved: Sequence[int] = ()) -> List[List[Optional[int]]]:
        raise NotImplementedError

    def shed(self, lanes: Sequence[LaneView]) -> Optional[ShedDecision]:
        raise NotImplementedError

    def victim(self, lanes: Sequence[LaneView]
               ) -> Optional[Tuple[int, int]]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# image-size buckets
# ---------------------------------------------------------------------------

def workload_footprint(workload: Any) -> int:
    """Approximate image footprint in 64-bit words: assembled code words
    plus non-zero data words the workload writes into a scratch image."""
    a = _programs.Asm(_programs.WORKLOAD)
    workload.asm(a)
    code = len(a.assemble())
    img = _programs.Image(_programs.MEM_WORDS)
    workload.write_data(img)
    return code + int(np.count_nonzero(img.mem))


def size_bucket(footprint_words: int) -> int:
    """0 = small (code-only kernels), 1 = medium, 2 = large (data-heavy).
    Thresholds are tuned to the registry's spread (~15–160 words) so the
    nine paper workloads actually land in distinct buckets."""
    if footprint_words < 32:
        return 0
    if footprint_words < 128:
        return 1
    return 2


# ---------------------------------------------------------------------------
# the default policy
# ---------------------------------------------------------------------------

class BinPackPolicy(PlacementPolicy):
    """First-fit-decreasing bin packing with tenant anti-affinity.

    ``pack`` sorts the queue by weight (descending, job_id tie-break) and
    forms full cohorts of ``slots`` guests, preferring to mix tenants
    inside a cohort (a tenant's own guests spread across harts).  A
    partial cohort boots only once the oldest queued job has waited
    ``partial_after`` control rounds — brief queueing beats running
    under-packed harts.  Each ``reserved`` slot index (a parked job that
    needs a same-slot home) claims one empty slot in one new cohort.

    ``shed`` proposes a migration when the live-guest count between the
    hottest and coolest lanes differs by at least ``shed_margin`` and the
    cool lane has a free matching slot.  ``victim`` parks the youngest
    guest (highest job_id) on the most-loaded lane.
    """

    def __init__(self, max_queue: int = 64, partial_after: int = 2,
                 shed_margin: int = 2):
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if shed_margin < 1:
            raise ValueError(f"shed_margin must be >= 1, got {shed_margin}")
        self.max_queue = int(max_queue)
        self.partial_after = int(partial_after)
        self.shed_margin = int(shed_margin)

    # -- admission ----------------------------------------------------------
    def admit(self, queue_len: int) -> bool:
        return queue_len < self.max_queue

    # -- placement ----------------------------------------------------------
    def pack(self, queued: Sequence[JobView], n_lanes: int, slots: int,
             reserved: Sequence[int] = ()) -> List[List[Optional[int]]]:
        jobs = sorted(queued, key=lambda j: (-j.weight, j.job_id))
        reserved = list(reserved)
        cohorts: List[List[Optional[int]]] = []
        while jobs and len(cohorts) < n_lanes:
            hold = reserved[0] if reserved else None
            capacity = slots - (1 if hold is not None else 0)
            take = min(capacity, len(jobs))
            if take < capacity and \
                    max(j.age for j in jobs) < self.partial_after:
                break                      # under-packed and nobody is old
            picked: List[JobView] = []
            pool = list(jobs)
            while pool and len(picked) < take:
                tenants = {j.tenant for j in picked}
                nxt = next((j for j in pool if j.tenant not in tenants),
                           pool[0])
                picked.append(nxt)
                pool.remove(nxt)
            cohort: List[Optional[int]] = [None] * slots
            fill = iter(picked)
            for s in range(slots):
                if hold is not None and s == hold:
                    continue               # reserved for a parked guest
                j = next(fill, None)
                cohort[s] = None if j is None else j.job_id
            if not any(c is not None for c in cohort):
                break
            if hold is not None:
                reserved.pop(0)
            for j in picked:
                jobs.remove(j)
            cohorts.append(cohort)
        return cohorts

    # -- load shedding ------------------------------------------------------
    def shed(self, lanes: Sequence[LaneView]) -> Optional[ShedDecision]:
        hot = sorted(lanes, key=lambda l: (-l.live, l.lane))
        cool = sorted(lanes, key=lambda l: (l.live, l.lane))
        for src in hot:
            for dst in cool:
                if src.lane == dst.lane:
                    continue
                if src.live - dst.live < self.shed_margin:
                    continue
                for slot in sorted(dst.free_slots):
                    if src.jobs[slot] is not None:
                        return ShedDecision(src.lane, dst.lane, slot)
        return None

    # -- eviction -----------------------------------------------------------
    def victim(self, lanes: Sequence[LaneView]
               ) -> Optional[Tuple[int, int]]:
        loaded = sorted(lanes, key=lambda l: (-l.live, l.lane))
        for lane in loaded:
            if lane.live < 2:
                continue                   # never empty a hart by eviction
            slot = max((s for s, j in enumerate(lane.jobs)
                        if j is not None), key=lambda s: lane.jobs[s])
            return lane.lane, slot
        return None
