"""RV64IM + Zicsr + H-extension decode/execute, branchless JAX.

Covers: LUI/AUIPC/JAL/JALR/branches, loads/stores (B/H/W/D, aligned),
OP/OP-IMM (+W forms), M extension (MUL/MULH*/DIV*/REM* + W forms),
CSR instructions, ECALL/EBREAK/SRET/MRET/WFI, SFENCE.VMA,
HFENCE.VVMA/HFENCE.GVMA, and the hypervisor loads/stores
HLV.{B,BU,H,HU,W,WU,D} / HLVX.{HU,WU} / HSV.{B,H,W,D} (paper §3.3's
XlateFlags: forced-virtualization + HLVX execute-permission reads).

``execute`` works on the machine-state dict and returns
(new_state, Fault) — machine.step merges on fault.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.hext import csr as C
from repro.core.hext import tlb as TLB
from repro.core.hext import translate as X

U64 = jnp.uint64
I64 = jnp.int64
INT_MIN = -(1 << 63)


def _u(x):
    return jnp.asarray(x, U64)


def _i(x):
    return jnp.asarray(x, I64)


def sext(x, bits):
    """Sign-extend the low `bits` of uint64 x (upper bits ignored)."""
    x = _u(x) & _u((1 << bits) - 1)
    m = _u(1 << (bits - 1))
    return ((x ^ m) - m)


class Fault(NamedTuple):
    fault: jnp.ndarray
    cause: jnp.ndarray      # uint64
    tval: jnp.ndarray       # uint64
    tval2: jnp.ndarray      # uint64
    gva: jnp.ndarray        # bool
    tinst: jnp.ndarray      # uint64


def no_fault():
    z = _u(0)
    return Fault(jnp.zeros((), bool), z, z, z, jnp.zeros((), bool), z)


def mk_fault(cond, cause, tval=0, tval2=0, gva=False, tinst=0):
    return Fault(jnp.asarray(cond, bool), _u(cause), _u(tval), _u(tval2),
                 jnp.asarray(gva, bool), _u(tinst))


def merge_fault(f1: Fault, f2: Fault) -> Fault:
    """f1 wins if set."""
    pick = f1.fault
    return Fault(f1.fault | f2.fault,
                 jnp.where(pick, f1.cause, f2.cause),
                 jnp.where(pick, f1.tval, f2.tval),
                 jnp.where(pick, f1.tval2, f2.tval2),
                 jnp.where(pick, f1.gva, f2.gva),
                 jnp.where(pick, f1.tinst, f2.tinst))


# ---------------------------------------------------------------------------
# 64-bit helpers (mulh / div semantics)
# ---------------------------------------------------------------------------

def _abs_u(a):
    neg = _i(a) < 0
    return jnp.where(neg, (~_u(a)) + _u(1), _u(a)), neg


def mulhu(a, b):
    a, b = _u(a), _u(b)
    m32 = _u(0xFFFFFFFF)
    a0, a1 = a & m32, a >> _u(32)
    b0, b1 = b & m32, b >> _u(32)
    ll = a0 * b0
    lh = a0 * b1
    hl = a1 * b0
    mid = (ll >> _u(32)) + (lh & m32) + (hl & m32)
    return a1 * b1 + (lh >> _u(32)) + (hl >> _u(32)) + (mid >> _u(32))


def mulh(a, b):
    h = mulhu(a, b)
    h = h - jnp.where(_i(a) < 0, _u(b), _u(0))
    h = h - jnp.where(_i(b) < 0, _u(a), _u(0))
    return h


def mulhsu(a, b):
    h = mulhu(a, b)
    return h - jnp.where(_i(a) < 0, _u(b), _u(0))


def divs(a, b):
    """Truncating signed division, RISC-V semantics."""
    az, bz = _i(a), _i(b)
    bzero = bz == 0
    ovf = (az == INT_MIN) & (bz == -1)
    ua, na = _abs_u(a)
    ub, nb = _abs_u(b)
    q = ua // jnp.where(bzero, _u(1), ub)
    neg = na ^ nb
    qs = jnp.where(neg, (~q) + _u(1), q)
    return jnp.where(bzero, _u(0xFFFFFFFFFFFFFFFF),
                     jnp.where(ovf, _u(1 << 63), qs))


def rems(a, b):
    az, bz = _i(a), _i(b)
    bzero = bz == 0
    ovf = (az == INT_MIN) & (bz == -1)
    ua, na = _abs_u(a)
    ub, _ = _abs_u(b)
    r = ua % jnp.where(bzero, _u(1), ub)
    rs = jnp.where(na, (~r) + _u(1), r)
    return jnp.where(bzero, _u(a), jnp.where(ovf, _u(0), rs))


def divu(a, b):
    bzero = _u(b) == 0
    return jnp.where(bzero, _u(0xFFFFFFFFFFFFFFFF),
                     _u(a) // jnp.where(bzero, _u(1), _u(b)))


def remu(a, b):
    bzero = _u(b) == 0
    return jnp.where(bzero, _u(a), _u(a) % jnp.where(bzero, _u(1), _u(b)))


# ---------------------------------------------------------------------------
# memory access through TLB + two-stage walk
# ---------------------------------------------------------------------------

def translate_cached(state, va, acc, force_virt=False, hlvx=False):
    """TLB-first translation; walk + insert on miss. Returns (pa, XResult,
    walked).  Lookups carry the access's privilege context so a hit can
    never reuse permissions composed under a different priv/SUM/MXR."""
    virt_eff = state["virt"] | jnp.asarray(force_virt, bool)
    sum_bit, mxr = X.eff_ctx(state["csrs"], virt_eff)
    hit, pa_tlb, perm_ok = TLB.lookup(state["tlb"], va, virt_eff, _u(acc),
                                      state["priv"], sum_bit, mxr)
    use_tlb = hit & perm_ok & ~jnp.asarray(hlvx, bool)
    xr = X.translate(state["mem"], state["csrs"], state["priv"],
                     state["virt"], va, acc, force_virt=force_virt,
                     hlvx=hlvx)
    pa = jnp.where(use_tlb, pa_tlb, xr.pa)
    fault = ~use_tlb & xr.fault
    xr = xr._replace(pa=pa, fault=fault)
    return xr, ~use_tlb


def tlb_fill(state, va, xr, force_virt=False):
    """Insert composed translation on successful walk."""
    virt_eff = state["virt"] | jnp.asarray(force_virt, bool)
    sum_bit, mxr = X.eff_ctx(state["csrs"], virt_eff)
    perm = TLB.compose_perms(xr.leaf_pte, xr.g_leaf_pte, state["priv"],
                             sum_bit, mxr)
    # guest entries are inserted at 4K granularity (composed two-stage leaf);
    # native entries keep their superpage level
    level = jnp.where(virt_eff, jnp.zeros((), jnp.int32), xr.level)
    new_tlb = TLB.insert(state["tlb"], va, xr.pa, level, perm, virt_eff,
                         state["priv"], sum_bit, mxr)
    ok = ~xr.fault
    tlb_sel = jax.tree.map(lambda n, o: jnp.where(ok, n, o), new_tlb,
                           state["tlb"])
    return tlb_sel


def word_extract(word, pa, size_log2, unsigned):
    """Read 1/2/4/8 bytes out of an aligned 64-bit word (shared by RAM and
    the CLINT MMIO registers)."""
    off = (_u(pa) & _u(7)) << _u(3)           # bit offset
    v = word >> off
    nbits = _u(8) << _u(size_log2)
    mask = jnp.where(nbits >= _u(64), ~_u(0), (_u(1) << nbits) - _u(1))
    v = v & mask
    shift = _u(64) - nbits                    # dynamic sign extension
    sv = _u(_i(v << shift) >> shift.astype(I64))
    return jnp.where(unsigned, v, sv)


def word_deposit(word, pa, val, size_log2):
    """Merge a 1/2/4/8-byte store into an aligned 64-bit word."""
    off = (_u(pa) & _u(7)) << _u(3)
    nbits = _u(8) << _u(size_log2)
    mask = jnp.where(nbits >= 64, ~_u(0), (_u(1) << nbits) - _u(1))
    return (word & ~(mask << off)) | ((_u(val) & mask) << off)


def mem_read(mem, pa, size_log2, unsigned):
    """Aligned read of 1/2/4/8 bytes from word-array memory."""
    word = mem[(_u(pa) >> _u(3)).astype(jnp.int32) % mem.shape[0]]
    return word_extract(word, pa, size_log2, unsigned)


def mem_write(mem, pa, val, size_log2):
    idx = (_u(pa) >> _u(3)).astype(jnp.int32) % mem.shape[0]
    return mem.at[idx].set(word_deposit(mem[idx], pa, val, size_log2))


# MMIO
MMIO_CONSOLE = 0x10000000
MMIO_DONE = 0x10000008
MMIO_CTXSW = 0x10000010          # hypervisor pokes: ctx_switches counter
# CLINT-style timer block (classic SiFive layout)
MMIO_MTIMECMP = 0x10004000
MMIO_MTIME = 0x1000BFF8


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------

def execute(state, instr):
    """One instruction. Returns (new_state, Fault, retired: bool)."""
    s = state
    csrs = s["csrs"]
    regs = s["regs"]
    priv = s["priv"]
    virt = s["virt"]
    pc = s["pc"]

    op = instr & _u(0x7F)
    rd = ((instr >> _u(7)) & _u(31)).astype(jnp.int32)
    f3 = (instr >> _u(12)) & _u(7)
    rs1 = ((instr >> _u(15)) & _u(31)).astype(jnp.int32)
    rs2i = ((instr >> _u(20)) & _u(31)).astype(jnp.int32)
    f7 = (instr >> _u(25)) & _u(0x7F)
    rv1 = regs[rs1]
    rv2 = regs[rs2i]

    imm_i = sext(instr >> _u(20), 12)
    imm_s = sext(((instr >> _u(20)) & ~_u(0x1F)) | ((instr >> _u(7)) & _u(0x1F)), 12)
    imm_b = sext((((instr >> _u(31)) & _u(1)) << _u(12)) |
                 (((instr >> _u(7)) & _u(1)) << _u(11)) |
                 (((instr >> _u(25)) & _u(0x3F)) << _u(5)) |
                 (((instr >> _u(8)) & _u(0xF)) << _u(1)), 13)
    imm_u = sext(instr & _u(0xFFFFF000), 32)
    imm_j = sext((((instr >> _u(31)) & _u(1)) << _u(20)) |
                 (((instr >> _u(12)) & _u(0xFF)) << _u(12)) |
                 (((instr >> _u(20)) & _u(1)) << _u(11)) |
                 (((instr >> _u(21)) & _u(0x3FF)) << _u(1)), 21)

    pc4 = pc + _u(4)
    new_pc = pc4
    wb = _u(0)           # writeback value
    do_wb = jnp.zeros((), bool)
    fault = no_fault()
    new_mem = s["mem"]
    new_csrs = csrs
    new_tlb = s["tlb"]
    new_priv = priv
    new_virt = virt
    new_halt = jnp.zeros((), bool)
    console = s["console"]
    done = s["done"]
    exit_code = s["exit_code"]

    # ---------------- ALU ---------------------------------------------------
    is_op = op == _u(0x33)
    is_opi = op == _u(0x13)
    is_op32 = op == _u(0x3B)
    is_opi32 = op == _u(0x1B)
    alu_b = jnp.where(is_op | is_op32, rv2, imm_i)
    m_ext = (is_op | is_op32) & (f7 == _u(1))

    sh6 = alu_b & _u(0x3F)
    sh5 = alu_b & _u(0x1F)
    srl = rv1 >> sh6
    sra = _u(_i(rv1) >> sh6.astype(I64))
    sll = rv1 << sh6
    addv = rv1 + alu_b
    subv = rv1 - alu_b
    sltv = _u(_i(rv1) < _i(alu_b))
    sltuv = _u(rv1 < alu_b)
    xorv = rv1 ^ alu_b
    orv = rv1 | alu_b
    andv = rv1 & alu_b
    arith_sub = (is_op & (f7 == _u(0x20)))
    # OP-IMM-64 srai carries shamt[5] in instr bit 25, so its funct7 is
    # 0x20 OR 0x21 — decode the arithmetic bit from funct6 there (an exact
    # 0x20 match silently turned `srai rd, rs, 32..63` into srli)
    sr_arith = jnp.where(is_opi, (f7 & _u(0x7E)) == _u(0x20),
                         f7 == _u(0x20))
    r64 = jnp.where(f3 == 0, jnp.where(arith_sub, subv, addv),
          jnp.where(f3 == 1, sll,
          jnp.where(f3 == 2, sltv,
          jnp.where(f3 == 3, sltuv,
          jnp.where(f3 == 4, xorv,
          jnp.where(f3 == 5, jnp.where(sr_arith, sra, srl),
          jnp.where(f3 == 6, orv, andv)))))))
    # M extension 64
    mulv = rv1 * alu_b
    m64 = jnp.where(f3 == 0, mulv,
          jnp.where(f3 == 1, mulh(rv1, alu_b),
          jnp.where(f3 == 2, mulhsu(rv1, alu_b),
          jnp.where(f3 == 3, mulhu(rv1, alu_b),
          jnp.where(f3 == 4, divs(rv1, alu_b),
          jnp.where(f3 == 5, divu(rv1, alu_b),
          jnp.where(f3 == 6, rems(rv1, alu_b), remu(rv1, alu_b))))))))
    r64 = jnp.where(m_ext & is_op, m64, r64)
    # 32-bit W forms
    a32 = sext(rv1, 32)
    b32 = sext(alu_b, 32)
    add32 = sext(a32 + b32, 32)
    sub32 = sext(a32 - b32, 32)
    sll32 = sext(a32 << sh5, 32)
    srl32 = sext((a32 & _u(0xFFFFFFFF)) >> sh5, 32)
    sra32 = sext(_u(_i(sext(rv1, 32)) >> sh5.astype(I64)), 32)
    mul32 = sext(a32 * b32, 32)
    # divw truncates THEN sign-extends from bit 31: the overflow quotient
    # INT32_MIN / -1 = +2^31 must read back as sign-extended INT32_MIN
    # (sext(..., 64) left it as 0x80000000)
    div32 = sext(divs(sext(rv1, 32), sext(alu_b, 32)), 32)
    divu32 = jnp.where((alu_b & _u(0xFFFFFFFF)) == 0, ~_u(0),
                       sext((rv1 & _u(0xFFFFFFFF)) //
                            jnp.maximum(alu_b & _u(0xFFFFFFFF), _u(1)), 32))
    rem32 = sext(rems(sext(rv1, 32), sext(alu_b, 32)), 64)
    remu32 = jnp.where((alu_b & _u(0xFFFFFFFF)) == 0, sext(rv1, 32),
                       sext((rv1 & _u(0xFFFFFFFF)) %
                            jnp.maximum(alu_b & _u(0xFFFFFFFF), _u(1)), 32))
    r32 = jnp.where(f3 == 0, jnp.where(is_op32 & (f7 == _u(0x20)), sub32,
                                       add32),
          jnp.where(f3 == 1, sll32,
          jnp.where(f3 == 5, jnp.where(sr_arith, sra32, srl32), add32)))
    m32 = jnp.where(f3 == 0, mul32,
          jnp.where(f3 == 4, div32,
          jnp.where(f3 == 5, divu32,
          jnp.where(f3 == 6, rem32, remu32))))
    r32 = jnp.where(m_ext & is_op32, m32, r32)

    alu_hit = is_op | is_opi | is_op32 | is_opi32
    wb = jnp.where(is_op | is_opi, r64, jnp.where(is_op32 | is_opi32, r32,
                                                  wb))
    do_wb = do_wb | alu_hit

    # ---------------- LUI / AUIPC / JAL / JALR / branches -------------------
    is_lui = op == _u(0x37)
    is_auipc = op == _u(0x17)
    is_jal = op == _u(0x6F)
    is_jalr = op == _u(0x67)
    wb = jnp.where(is_lui, imm_u, wb)
    wb = jnp.where(is_auipc, pc + imm_u, wb)
    wb = jnp.where(is_jal | is_jalr, pc4, wb)
    do_wb = do_wb | is_lui | is_auipc | is_jal | is_jalr
    new_pc = jnp.where(is_jal, pc + imm_j, new_pc)
    new_pc = jnp.where(is_jalr, (rv1 + imm_i) & ~_u(1), new_pc)

    is_br = op == _u(0x63)
    beq = rv1 == rv2
    blt = _i(rv1) < _i(rv2)
    bltu = rv1 < rv2
    brt = jnp.where(f3 == 0, beq,
          jnp.where(f3 == 1, ~beq,
          jnp.where(f3 == 4, blt,
          jnp.where(f3 == 5, ~blt,
          jnp.where(f3 == 6, bltu, ~bltu)))))
    new_pc = jnp.where(is_br & brt, pc + imm_b, new_pc)

    # ---------------- loads / stores (incl. hlv/hsv) -------------------------
    is_load = op == _u(0x03)
    is_store = op == _u(0x23)
    is_sys = op == _u(0x73)
    is_hx = is_sys & (f3 == _u(4))
    is_hlv = is_hx & ((f7 & _u(1)) == 0)
    is_hsv = is_hx & ((f7 & _u(1)) == 1)
    # hlv/hsv legality: M or HS (or U with hstatus.HU); VS/VU → virtual inst
    hu = (csrs[C.R_HSTATUS] & _u(C.HSTATUS_HU)) != 0
    hx_legal = (priv == 3) | ((priv == 1) & ~virt) | ((priv == 0) & ~virt & hu)
    hx_vinst = is_hx & virt
    hx_illegal = is_hx & ~virt & ~hx_legal

    any_load = is_load | is_hlv
    any_store = is_store | is_hsv
    addr = jnp.where(is_hx, rv1, rv1 + jnp.where(is_store, imm_s, imm_i))
    size = jnp.where(is_hx, ((f7 >> _u(1)) & _u(3)).astype(jnp.int32),
                     (f3 & _u(3)).astype(jnp.int32))
    uns = jnp.where(is_hx, (rs2i & 1) == 1, (f3 & _u(4)) != 0)
    hlvx = is_hlv & (rs2i == 3)
    force_virt = is_hx

    # alignment
    sz_b = _u(1) << _u(size)
    misaligned = (addr & (sz_b - _u(1))) != 0
    macc = jnp.where(any_store, X.ACC_W, X.ACC_R)
    xr, walked = translate_cached(
        {**s, "csrs": csrs}, addr, macc, force_virt=force_virt, hlvx=hlvx)
    # MMIO check (physical).  Every device register decodes as a whole
    # 8-byte region (the CLINT ones with size-aware access), so the classic
    # RV32-style pair of 32-bit stores works and a sub-word access can
    # never alias into RAM through the modulo-wrapped word index.
    pa_word = xr.pa & ~_u(7)
    is_console = pa_word == _u(MMIO_CONSOLE)
    is_done_io = pa_word == _u(MMIO_DONE)
    is_ctxsw_io = pa_word == _u(MMIO_CTXSW)
    is_mtimecmp_io = pa_word == _u(MMIO_MTIMECMP)
    is_mtime_io = pa_word == _u(MMIO_MTIME)
    is_mmio = (is_console | is_done_io | is_ctxsw_io | is_mtimecmp_io |
               is_mtime_io)
    # final-PA bounds: a translated (or bare) PA that is neither RAM nor a
    # decoded MMIO register is an access fault — it must not alias back
    # into RAM through the modulo-wrapped word index.  Loads are further
    # restricted to the *readable* MMIO registers (the CLINT pair); the
    # write-only ones (console/done/ctxsw) have no read decode, so a load
    # from them would otherwise wrap into RAM too.
    mmio_readable = is_mtimecmp_io | is_mtime_io
    pa_oob = (~is_mmio & (xr.pa >= _u(s["mem"].shape[0] * 8))) | \
        (any_load & is_mmio & ~mmio_readable)

    ld_val = mem_read(s["mem"], xr.pa, size, uns)
    # CLINT reads: mtime / mtimecmp come from the timer registers
    ld_val = jnp.where(is_mtime_io,
                       word_extract(csrs[C.R_MTIME], xr.pa, size, uns),
                       ld_val)
    ld_val = jnp.where(is_mtimecmp_io,
                       word_extract(csrs[C.R_MTIMECMP], xr.pa, size, uns),
                       ld_val)
    st_mem = mem_write(s["mem"], xr.pa, rv2, size)

    mem_op = (any_load | any_store) & ~hx_vinst & ~hx_illegal
    mem_fault_align = mem_op & misaligned
    mem_fault_page = mem_op & ~misaligned & xr.fault
    mem_fault_oob = mem_op & ~misaligned & ~xr.fault & pa_oob

    # tinst for guest page faults (paper tinst_tests): pseudoinstruction for
    # implicit PTE-walk faults, rs1-cleared transform for explicit accesses
    is_gpf = (xr.cause == _u(C.EXC_LGUEST_PAGE_FAULT)) | \
             (xr.cause == _u(C.EXC_SGUEST_PAGE_FAULT))
    pseudo = jnp.where(any_store, _u(0x2020), _u(0x2000))
    transform = instr & ~_u(0xF8000)      # clear rs1 field
    tinst = jnp.where(xr.implicit, pseudo, transform)
    tinst = jnp.where(is_gpf, tinst, _u(0))

    f_mem = mk_fault(
        mem_fault_page, 0, 0, 0, False, 0)._replace(
        cause=xr.cause, tval=xr.tval, tval2=xr.tval2,
        gva=xr.gva | (force_virt & xr.fault), tinst=tinst)
    align_cause = jnp.where(any_store, C.EXC_SADDR_MISALIGNED,
                            C.EXC_LADDR_MISALIGNED)
    f_align = Fault(mem_fault_align, _u(align_cause), _u(addr), _u(0),
                    jnp.asarray(virt | force_virt, bool), _u(0))
    oob_cause = jnp.where(any_store, C.EXC_SACCESS, C.EXC_LACCESS)
    f_oob = Fault(mem_fault_oob, _u(oob_cause), _u(addr), _u(0),
                  jnp.asarray(virt | force_virt, bool), _u(0))
    fault = merge_fault(merge_fault(merge_fault(f_align, f_mem), f_oob),
                        fault)

    mem_ok = mem_op & ~misaligned & ~xr.fault & ~pa_oob
    wb = jnp.where(any_load & mem_ok, ld_val, wb)
    do_wb = do_wb | (any_load & mem_ok)
    new_mem = jnp.where(any_store & mem_ok & ~is_mmio, st_mem, new_mem)
    console = jnp.where(any_store & mem_ok & is_console, console + 1,
                        console)
    done = done | (any_store & mem_ok & is_done_io)
    exit_code = jnp.where(any_store & mem_ok & is_done_io, rv2, exit_code)
    # CLINT writes: size-aware merges into the timer registers (mtimecmp
    # arms the M-level comparator; mtime is writable per the CLINT spec)
    new_csrs = jnp.where(
        any_store & mem_ok & is_mtimecmp_io,
        csrs.at[C.R_MTIMECMP].set(
            word_deposit(csrs[C.R_MTIMECMP], xr.pa, rv2, size)), new_csrs)
    new_csrs = jnp.where(
        any_store & mem_ok & is_mtime_io,
        csrs.at[C.R_MTIME].set(
            word_deposit(csrs[C.R_MTIME], xr.pa, rv2, size)), new_csrs)
    ctxsw_poke = any_store & mem_ok & is_ctxsw_io
    new_tlb = jax.tree.map(
        lambda n, o: jnp.where(mem_ok & walked, n, o),
        tlb_fill(s, addr, xr, force_virt=force_virt), new_tlb)
    fault = merge_fault(fault, mk_fault(hx_vinst, C.EXC_VIRTUAL_INSTRUCTION,
                                        instr))
    fault = merge_fault(fault, mk_fault(hx_illegal, C.EXC_ILLEGAL, instr))

    # ---------------- SYSTEM: CSR ops ---------------------------------------
    is_csr = is_sys & (f3 != _u(0)) & (f3 != _u(4))
    csr_addr = (instr >> _u(20)).astype(jnp.int32) & 0xFFF
    imm_z = _u(rs1)
    csr_wdata = jnp.where(f3 >= _u(5), imm_z, rv1)
    old, r_ok, r_vinst = C.csr_read(csrs, csr_addr, priv, virt)
    wval = jnp.where((f3 & _u(3)) == 1, csr_wdata,
           jnp.where((f3 & _u(3)) == 2, old | csr_wdata, old & ~csr_wdata))
    csr_do_write = ((f3 & _u(3)) == 1) | (rs1 != 0)
    csrs_w, w_ok, w_vinst = C.csr_write(csrs, csr_addr, wval, priv, virt)
    csr_ok = r_ok & jnp.where(csr_do_write, w_ok, True)
    csr_vinst = r_vinst | (csr_do_write & w_vinst)
    new_csrs = jnp.where(is_csr & csr_ok & csr_do_write, csrs_w, new_csrs)
    wb = jnp.where(is_csr & csr_ok, old, wb)
    do_wb = do_wb | (is_csr & csr_ok)
    fault = merge_fault(fault, mk_fault(is_csr & csr_vinst,
                                        C.EXC_VIRTUAL_INSTRUCTION, instr))
    fault = merge_fault(fault, mk_fault(is_csr & ~csr_ok & ~csr_vinst,
                                        C.EXC_ILLEGAL, instr))
    # satp/vsatp/hgatp writes invalidate cached translations
    atp_write = is_csr & csr_ok & csr_do_write & (
        (csr_addr == 0x180) | (csr_addr == 0x280) | (csr_addr == 0x680))
    new_tlb = jax.tree.map(
        lambda n, o: jnp.where(atp_write, n, o),
        TLB.flush_where(s["tlb"], jnp.ones((), bool), jnp.ones((), bool)),
        new_tlb)

    # ---------------- SYSTEM: priv ops --------------------------------------
    f7s = f7
    sys0 = is_sys & (f3 == _u(0))
    is_ecall = sys0 & (instr == _u(0x00000073))
    is_ebreak = sys0 & (instr == _u(0x00100073))
    is_sret = sys0 & (instr == _u(0x10200073))
    is_mret = sys0 & (instr == _u(0x30200073))
    is_wfi = sys0 & (instr == _u(0x10500073))
    is_sfence = sys0 & (f7s == _u(0x09))
    is_hfence_v = sys0 & (f7s == _u(0x11))   # hfence.vvma
    is_hfence_g = sys0 & (f7s == _u(0x31))   # hfence.gvma

    mstatus = csrs[C.R_MSTATUS]
    hstatus = csrs[C.R_HSTATUS]

    ecall_cause = jnp.where(priv == 3, C.EXC_ECALL_M,
                  jnp.where(priv == 0, C.EXC_ECALL_U,
                            jnp.where(virt, C.EXC_ECALL_VS, C.EXC_ECALL_S)))
    fault = merge_fault(fault, mk_fault(is_ecall, ecall_cause))
    fault = merge_fault(fault, mk_fault(is_ebreak, C.EXC_BREAK, pc))

    # WFI: TW/VTW trapping (paper wfi_exception_tests)
    tw = (mstatus & _u(C.MSTATUS_TW)) != 0
    vtw = (hstatus & _u(C.HSTATUS_VTW)) != 0
    wfi_illegal = is_wfi & ((tw & (priv < 3)) | (priv == 0) & ~virt)
    wfi_vinst = is_wfi & ~wfi_illegal & virt & (vtw | (priv == 0))
    wfi_ok = is_wfi & ~wfi_illegal & ~wfi_vinst
    pend_any = (csrs[C.R_MIP] & csrs[C.R_MIE]) != 0
    new_halt = new_halt | (wfi_ok & ~pend_any)
    fault = merge_fault(fault, mk_fault(wfi_illegal, C.EXC_ILLEGAL, instr))
    fault = merge_fault(fault, mk_fault(wfi_vinst,
                                        C.EXC_VIRTUAL_INSTRUCTION, instr))

    # SRET
    tsr = (mstatus & _u(C.MSTATUS_TSR)) != 0
    vtsr = (hstatus & _u(C.HSTATUS_VTSR)) != 0
    sret_illegal = is_sret & ((priv == 0) | (tsr & (priv == 1) & ~virt))
    sret_vinst = is_sret & ~sret_illegal & virt & (vtsr | (priv == 0))
    sret_ok = is_sret & ~sret_illegal & ~sret_vinst
    fault = merge_fault(fault, mk_fault(sret_illegal, C.EXC_ILLEGAL, instr))
    fault = merge_fault(fault, mk_fault(sret_vinst,
                                        C.EXC_VIRTUAL_INSTRUCTION, instr))
    # sret from HS: V ← hstatus.SPV, priv ← sstatus.SPP
    spp = ((mstatus & _u(C.MSTATUS_SPP)) != 0).astype(jnp.int32)
    spie = (mstatus & _u(C.MSTATUS_SPIE)) != 0
    mst_sret = mstatus
    mst_sret = jnp.where(spie, mst_sret | _u(C.MSTATUS_SIE),
                         mst_sret & ~_u(C.MSTATUS_SIE))
    mst_sret = (mst_sret | _u(C.MSTATUS_SPIE)) & ~_u(C.MSTATUS_SPP)
    spv = (hstatus & _u(C.HSTATUS_SPV)) != 0
    hst_sret = hstatus & ~_u(C.HSTATUS_SPV)
    # sret from VS (virt): uses vsstatus
    vsstatus = csrs[C.R_VSSTATUS]
    vspp = ((vsstatus & _u(C.MSTATUS_SPP)) != 0).astype(jnp.int32)
    vspie = (vsstatus & _u(C.MSTATUS_SPIE)) != 0
    vst_sret = vsstatus
    vst_sret = jnp.where(vspie, vst_sret | _u(C.MSTATUS_SIE),
                         vst_sret & ~_u(C.MSTATUS_SIE))
    vst_sret = (vst_sret | _u(C.MSTATUS_SPIE)) & ~_u(C.MSTATUS_SPP)
    csrs_sret_hs = csrs.at[C.R_MSTATUS].set(mst_sret).at[C.R_HSTATUS].set(
        hst_sret)
    csrs_sret_vs = csrs.at[C.R_VSSTATUS].set(vst_sret)
    new_csrs = jnp.where(sret_ok & ~virt, csrs_sret_hs,
                         jnp.where(sret_ok & virt, csrs_sret_vs, new_csrs))
    new_priv = jnp.where(sret_ok, jnp.where(virt, vspp, spp), new_priv)
    new_virt = jnp.where(sret_ok, jnp.where(virt, virt, spv), new_virt)
    new_pc = jnp.where(sret_ok, jnp.where(virt, csrs[C.R_VSEPC],
                                          csrs[C.R_SEPC]), new_pc)

    # MRET
    mret_illegal = is_mret & (priv != 3)
    mret_ok = is_mret & ~mret_illegal
    fault = merge_fault(fault, mk_fault(mret_illegal, C.EXC_ILLEGAL, instr))
    mpp = ((mstatus & _u(C.MSTATUS_MPP)) >> _u(11)).astype(jnp.int32)
    mpie = (mstatus & _u(C.MSTATUS_MPIE)) != 0
    mpv = (mstatus & _u(C.MSTATUS_MPV)) != 0
    mst_mret = mstatus
    mst_mret = jnp.where(mpie, mst_mret | _u(C.MSTATUS_MIE),
                         mst_mret & ~_u(C.MSTATUS_MIE))
    mst_mret = (mst_mret | _u(C.MSTATUS_MPIE)) & ~_u(C.MSTATUS_MPP) & \
        ~_u(C.MSTATUS_MPV)
    new_csrs = jnp.where(mret_ok, csrs.at[C.R_MSTATUS].set(mst_mret),
                         new_csrs)
    new_priv = jnp.where(mret_ok, mpp, new_priv)
    new_virt = jnp.where(mret_ok, (mpp != 3) & mpv, new_virt)
    new_pc = jnp.where(mret_ok, csrs[C.R_MEPC], new_pc)

    # fences (paper hfence_tests: hfence touches only guest TLB entries).
    # sfence.vma from VS flushes the guest's own (guest-tagged) entries;
    # hfence.{vvma,gvma} from VS raises virtual-instruction; from U illegal.
    is_hf = is_hfence_v | is_hfence_g
    hf_vinst = is_hf & virt
    hf_illegal = is_hf & ~virt & (priv == 0)
    sf_vinst = is_sfence & virt & (priv == 0)          # VU
    sf_illegal = is_sfence & ~virt & (priv == 0)       # native U
    fault = merge_fault(fault, mk_fault(hf_vinst | sf_vinst,
                                        C.EXC_VIRTUAL_INSTRUCTION, instr))
    fault = merge_fault(fault, mk_fault(hf_illegal | sf_illegal,
                                        C.EXC_ILLEGAL, instr))
    do_hf = is_hf & ~virt & (priv >= 1)
    do_sf_native = is_sfence & ~virt & (priv >= 1)
    do_sf_guest = is_sfence & virt & (priv >= 1)       # guest flushing itself
    new_tlb = jax.tree.map(
        lambda n, o: jnp.where(do_hf | do_sf_native | do_sf_guest, n, o),
        TLB.flush_where(s["tlb"],
                        cond_guest=do_hf | do_sf_guest,
                        cond_native=do_sf_native),
        new_tlb)

    # FENCE / FENCE.I: no-op
    # (opcode 0x0F)

    # ---------------- illegal opcode ----------------------------------------
    known = (alu_hit | is_lui | is_auipc | is_jal | is_jalr | is_br |
             is_load | is_store | is_sys | (op == _u(0x0F)))
    fault = merge_fault(fault, mk_fault(~known, C.EXC_ILLEGAL, instr))

    # ---------------- writeback & commit ------------------------------------
    retired = ~fault.fault
    wb_final = jnp.where(do_wb & retired & (rd != 0), wb, regs[rd])
    new_regs = regs.at[rd].set(wb_final)

    out = dict(s)
    out["regs"] = jnp.where(retired, new_regs, regs)
    out["pc"] = jnp.where(retired, new_pc, pc)
    out["csrs"] = jnp.where(retired, new_csrs, csrs)
    out["mem"] = jnp.where(retired, new_mem, s["mem"])
    out["tlb"] = jax.tree.map(lambda n, o: jnp.where(retired, n, o),
                              new_tlb, s["tlb"])
    out["priv"] = jnp.where(retired, new_priv, priv)
    out["virt"] = jnp.where(retired, new_virt, virt)
    out["halted"] = jnp.where(retired, new_halt, s["halted"])
    out["console"] = console
    out["done"] = done
    out["exit_code"] = exit_code
    out["ctx_switches"] = s["ctx_switches"] + \
        (retired & ctxsw_poke).astype(jnp.int64)
    return out, fault, retired
