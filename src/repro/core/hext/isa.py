"""RV64IM + Zicsr + H-extension execute, branchless JAX (DESIGN.md §7).

Covers: LUI/AUIPC/JAL/JALR/branches, loads/stores (B/H/W/D, aligned),
OP/OP-IMM (+W forms), M extension (MUL/MULH*/DIV*/REM* + W forms),
CSR instructions, ECALL/EBREAK/SRET/MRET/WFI, SFENCE.VMA,
HFENCE.VVMA/HFENCE.GVMA, and the hypervisor loads/stores
HLV.{B,BU,H,HU,W,WU,D} / HLVX.{HU,WU} / HSV.{B,H,W,D} (paper §3.3's
XlateFlags: forced-virtualization + HLVX execute-permission reads).

Execution is staged around the table-driven :mod:`repro.core.hext.decode`
micro-op record: per-opclass contributors (ALU / control flow / memory /
SYSTEM) each consume a :class:`decode.MicroOp` and merge into one
:class:`ExecOut` delta record (``execute_uop``) — no full-state selects,
no full-memory selects; ``machine`` applies the deltas with batch-level
commit masks.  The two pieces the pipeline hoists out of the executor:

* :func:`mem_query` — the memory-access *intent* (address, size, access
  type, forced-virtualization flags) computed **before** translation, so
  ``machine.step`` can probe the TLB and only build the two-stage walk
  graph when some hart in the batch actually misses;
* :func:`exec_sys` — the SYSTEM contributor (CSR file ops, xRET, WFI,
  fences) as a separable :class:`SysOut`, so machine can gate the heavy
  CSR where-chains behind a batch-level ``lax.cond``.

``execute`` remains as the single-instruction compat wrapper (decode +
always-walk translate + all contributors) with the legacy
``(new_state, Fault, retired)`` contract.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.hext import csr as C
from repro.core.hext import decode as D
from repro.core.hext import tlb as TLB
from repro.core.hext import translate as X
from repro.core.hext.bits import I64, U64, sext, word_deposit, word_extract
from repro.core.hext.bits import i64 as _i
from repro.core.hext.bits import u64 as _u

INT_MIN = -(1 << 63)


class Fault(NamedTuple):
    fault: jnp.ndarray
    cause: jnp.ndarray      # uint64
    tval: jnp.ndarray       # uint64
    tval2: jnp.ndarray      # uint64
    gva: jnp.ndarray        # bool
    tinst: jnp.ndarray      # uint64


def no_fault():
    z = _u(0)
    return Fault(jnp.zeros((), bool), z, z, z, jnp.zeros((), bool), z)


def mk_fault(cond, cause, tval=0, tval2=0, gva=False, tinst=0):
    return Fault(jnp.asarray(cond, bool), _u(cause), _u(tval), _u(tval2),
                 jnp.asarray(gva, bool), _u(tinst))


def merge_fault(f1: Fault, f2: Fault) -> Fault:
    """f1 wins if set."""
    pick = f1.fault
    return Fault(f1.fault | f2.fault,
                 jnp.where(pick, f1.cause, f2.cause),
                 jnp.where(pick, f1.tval, f2.tval),
                 jnp.where(pick, f1.tval2, f2.tval2),
                 jnp.where(pick, f1.gva, f2.gva),
                 jnp.where(pick, f1.tinst, f2.tinst))


# ---------------------------------------------------------------------------
# 64-bit helpers (mulh / div semantics)
# ---------------------------------------------------------------------------

def _abs_u(a):
    neg = _i(a) < 0
    return jnp.where(neg, (~_u(a)) + _u(1), _u(a)), neg


def mulhu(a, b):
    a, b = _u(a), _u(b)
    m32 = _u(0xFFFFFFFF)
    a0, a1 = a & m32, a >> _u(32)
    b0, b1 = b & m32, b >> _u(32)
    ll = a0 * b0
    lh = a0 * b1
    hl = a1 * b0
    mid = (ll >> _u(32)) + (lh & m32) + (hl & m32)
    return a1 * b1 + (lh >> _u(32)) + (hl >> _u(32)) + (mid >> _u(32))


def mulh(a, b):
    h = mulhu(a, b)
    h = h - jnp.where(_i(a) < 0, _u(b), _u(0))
    h = h - jnp.where(_i(b) < 0, _u(a), _u(0))
    return h


def mulhsu(a, b):
    h = mulhu(a, b)
    return h - jnp.where(_i(a) < 0, _u(b), _u(0))


def divs(a, b):
    """Truncating signed division, RISC-V semantics."""
    az, bz = _i(a), _i(b)
    bzero = bz == 0
    ovf = (az == INT_MIN) & (bz == -1)
    ua, na = _abs_u(a)
    ub, nb = _abs_u(b)
    q = ua // jnp.where(bzero, _u(1), ub)
    neg = na ^ nb
    qs = jnp.where(neg, (~q) + _u(1), q)
    return jnp.where(bzero, _u(0xFFFFFFFFFFFFFFFF),
                     jnp.where(ovf, _u(1 << 63), qs))


def rems(a, b):
    az, bz = _i(a), _i(b)
    bzero = bz == 0
    ovf = (az == INT_MIN) & (bz == -1)
    ua, na = _abs_u(a)
    ub, _ = _abs_u(b)
    r = ua % jnp.where(bzero, _u(1), ub)
    rs = jnp.where(na, (~r) + _u(1), r)
    return jnp.where(bzero, _u(a), jnp.where(ovf, _u(0), rs))


def divu(a, b):
    bzero = _u(b) == 0
    return jnp.where(bzero, _u(0xFFFFFFFFFFFFFFFF),
                     _u(a) // jnp.where(bzero, _u(1), _u(b)))


def remu(a, b):
    bzero = _u(b) == 0
    return jnp.where(bzero, _u(a), _u(a) % jnp.where(bzero, _u(1), _u(b)))


# ---------------------------------------------------------------------------
# memory access through TLB + two-stage walk
# ---------------------------------------------------------------------------

def translate_cached(state, va, acc, force_virt=False, hlvx=False):
    """TLB-first translation; walk + insert on miss. Returns (XResult,
    walked).  Lookups carry the access's privilege context so a hit can
    never reuse permissions composed under a different priv/SUM/MXR.

    This is the always-walk compat path (scalar callers, tests).  The
    pipelined ``machine.step`` uses the same TLB verdict but only builds
    the walk graph under a batch-level ``lax.cond`` when some hart needs
    it — on a usable hit the walk-only XResult fields are zero there,
    which is bit-equivalent because every consumer of those fields is
    gated on ``walked``/``xr.fault`` (DESIGN.md §7)."""
    virt_eff = state["virt"] | jnp.asarray(force_virt, bool)
    sum_bit, mxr = X.eff_ctx(state["csrs"], virt_eff)
    tv = TLB.lookup(state["tlb"], va, virt_eff, _u(acc),
                    state["priv"], sum_bit, mxr)
    use_tlb = tv.use & ~jnp.asarray(hlvx, bool)
    xr = X.translate(state["mem"], state["csrs"], state["priv"],
                     state["virt"], va, acc, force_virt=force_virt,
                     hlvx=hlvx)
    pa = jnp.where(use_tlb, tv.pa, xr.pa)
    fault = ~use_tlb & xr.fault
    xr = xr._replace(pa=pa, fault=fault)
    return xr, ~use_tlb


def tlb_fill(state, va, xr, force_virt=False):
    """Insert composed translation on successful walk."""
    virt_eff = state["virt"] | jnp.asarray(force_virt, bool)
    sum_bit, mxr = X.eff_ctx(state["csrs"], virt_eff)
    perm = TLB.compose_perms(xr.leaf_pte, xr.g_leaf_pte, state["priv"],
                             sum_bit, mxr)
    # guest entries are inserted at 4K granularity (composed two-stage leaf);
    # native entries keep their superpage level
    level = jnp.where(virt_eff, jnp.zeros((), jnp.int32), xr.level)
    new_tlb = TLB.insert(state["tlb"], va, xr.pa, level, perm, virt_eff,
                         state["priv"], sum_bit, mxr)
    ok = ~xr.fault
    tlb_sel = jax.tree.map(lambda n, o: jnp.where(ok, n, o), new_tlb,
                           state["tlb"])
    return tlb_sel


def mem_read(mem, pa, size_log2, unsigned):
    """Aligned read of 1/2/4/8 bytes from word-array memory."""
    word = mem[(_u(pa) >> _u(3)).astype(jnp.int32) % mem.shape[0]]
    return word_extract(word, pa, size_log2, unsigned)


def mem_write(mem, pa, val, size_log2):
    idx = (_u(pa) >> _u(3)).astype(jnp.int32) % mem.shape[0]
    return mem.at[idx].set(word_deposit(mem[idx], pa, val, size_log2))


# MMIO
MMIO_CONSOLE = 0x10000000
MMIO_DONE = 0x10000008
MMIO_CTXSW = 0x10000010          # hypervisor pokes: ctx_switches counter
# CLINT-style timer block (classic SiFive layout)
MMIO_MTIMECMP = 0x10004000
MMIO_MTIME = 0x1000BFF8


# ---------------------------------------------------------------------------
# stage 1: memory-access intent (pre-translation)
# ---------------------------------------------------------------------------

class MemQuery(NamedTuple):
    """The memory-access intent of one micro-op, computed *before*
    translation so the pipeline can probe the TLB (and decide whether the
    walk graph is needed at all) ahead of the executor."""

    any_load: jnp.ndarray
    any_store: jnp.ndarray
    mem_op: jnp.ndarray      # legal explicit access (excl. hlv/hsv traps)
    is_hx: jnp.ndarray       # hlv/hsv/hlvx family
    hx_vinst: jnp.ndarray
    hx_illegal: jnp.ndarray
    addr: jnp.ndarray        # uint64 VA
    size: jnp.ndarray        # int32 log2 bytes
    uns: jnp.ndarray         # bool: zero-extend load
    hlvx: jnp.ndarray        # bool: execute-permission read
    force_virt: jnp.ndarray  # bool: access as if V=1
    macc: jnp.ndarray        # uint64 ACC_R / ACC_W
    misaligned: jnp.ndarray


def mem_query(csrs, priv, virt, uop: D.MicroOp, rv1) -> MemQuery:
    is_load = uop.cls == D.CLS_LOAD
    is_store = uop.cls == D.CLS_STORE
    is_hx = (uop.cls == D.CLS_SYSTEM) & (uop.f3 == _u(4))
    is_hlv = is_hx & ((uop.f7 & _u(1)) == 0)
    is_hsv = is_hx & ((uop.f7 & _u(1)) == 1)
    # hlv/hsv legality: M or HS (or U with hstatus.HU); VS/VU → virtual inst
    hu = (csrs[C.R_HSTATUS] & _u(C.HSTATUS_HU)) != 0
    hx_legal = (priv == 3) | ((priv == 1) & ~virt) | \
        ((priv == 0) & ~virt & hu)
    hx_vinst = is_hx & virt
    hx_illegal = is_hx & ~virt & ~hx_legal

    any_load = is_load | is_hlv
    any_store = is_store | is_hsv
    # decode put the I-format imm on loads and the S-format imm on stores;
    # hlv/hsv address directly from rs1
    addr = jnp.where(is_hx, rv1, rv1 + uop.imm)
    size = jnp.where(is_hx, ((uop.f7 >> _u(1)) & _u(3)).astype(jnp.int32),
                     (uop.f3 & _u(3)).astype(jnp.int32))
    uns = jnp.where(is_hx, (uop.rs2 & 1) == 1, (uop.f3 & _u(4)) != 0)
    hlvx = is_hlv & (uop.rs2 == 3)

    sz_b = _u(1) << _u(size)
    misaligned = (addr & (sz_b - _u(1))) != 0
    macc = _u(jnp.where(any_store, X.ACC_W, X.ACC_R))
    mem_op = (any_load | any_store) & ~hx_vinst & ~hx_illegal
    return MemQuery(any_load=any_load, any_store=any_store, mem_op=mem_op,
                    is_hx=is_hx, hx_vinst=hx_vinst, hx_illegal=hx_illegal,
                    addr=addr, size=size, uns=uns, hlvx=hlvx,
                    force_virt=is_hx, macc=macc, misaligned=misaligned)


# ---------------------------------------------------------------------------
# SYSTEM contributor (CSR ops, xRET, WFI, fences) — separable so machine
# can gate it behind a batch-level cond (CSR read/write are the two
# heaviest where-chains in the executor)
# ---------------------------------------------------------------------------

class SysOut(NamedTuple):
    """Effects of the SYSTEM (non-hlv/hsv) contributor, pre-gated: for a
    non-SYSTEM micro-op every ``*_set``/flag field is False, so the
    all-False record IS the neutral element (``machine`` substitutes it
    when no hart in the batch runs a SYSTEM op)."""

    fault: Fault
    wb: jnp.ndarray          # CSR read value
    do_wb: jnp.ndarray
    csrs: jnp.ndarray        # full post-op CSR bank (valid when csrs_set)
    csrs_set: jnp.ndarray
    pc: jnp.ndarray          # xRET target (valid when pc_set)
    pc_set: jnp.ndarray
    priv: jnp.ndarray        # xRET privilege (valid when pv_set)
    virt: jnp.ndarray
    pv_set: jnp.ndarray
    halt: jnp.ndarray        # WFI with nothing pending
    flush_guest: jnp.ndarray   # TLB invalidation: full-scope flushes
    flush_native: jnp.ndarray
    flush_guest_addr: jnp.ndarray   # rs1≠x0: drop only entries of flush_va
    flush_native_addr: jnp.ndarray
    flush_va: jnp.ndarray


def exec_sys(csrs, priv, virt, pc, rv1, uop: D.MicroOp) -> SysOut:
    """CSR instructions + privileged ops + fences → :class:`SysOut`."""
    instr = uop.instr
    f3 = uop.f3
    is_sys = uop.cls == D.CLS_SYSTEM
    fault = no_fault()

    # ---------------- CSR ops ----------------------------------------------
    is_csr = is_sys & (f3 != _u(0)) & (f3 != _u(4))
    csr_addr = (instr >> _u(20)).astype(jnp.int32) & 0xFFF
    imm_z = _u(uop.rs1)
    csr_wdata = jnp.where(f3 >= _u(5), imm_z, rv1)
    old, r_ok, r_vinst = C.csr_read(csrs, csr_addr, priv, virt)
    wval = jnp.where((f3 & _u(3)) == 1, csr_wdata,
           jnp.where((f3 & _u(3)) == 2, old | csr_wdata, old & ~csr_wdata))
    csr_do_write = ((f3 & _u(3)) == 1) | (uop.rs1 != 0)
    csrs_w, w_ok, w_vinst = C.csr_write(csrs, csr_addr, wval, priv, virt)
    csr_ok = r_ok & jnp.where(csr_do_write, w_ok, True)
    csr_vinst = r_vinst | (csr_do_write & w_vinst)
    wb = old
    do_wb = is_csr & csr_ok
    fault = merge_fault(fault, mk_fault(is_csr & csr_vinst,
                                        C.EXC_VIRTUAL_INSTRUCTION, instr))
    fault = merge_fault(fault, mk_fault(is_csr & ~csr_ok & ~csr_vinst,
                                        C.EXC_ILLEGAL, instr))
    # satp/vsatp/hgatp writes invalidate cached translations
    atp_write = is_csr & csr_ok & csr_do_write & (
        (csr_addr == 0x180) | (csr_addr == 0x280) | (csr_addr == 0x680))

    # ---------------- priv ops ----------------------------------------------
    f7s = uop.f7
    sys0 = is_sys & (f3 == _u(0))
    is_ecall = sys0 & (instr == _u(0x00000073))
    is_ebreak = sys0 & (instr == _u(0x00100073))
    is_sret = sys0 & (instr == _u(0x10200073))
    is_mret = sys0 & (instr == _u(0x30200073))
    is_wfi = sys0 & (instr == _u(0x10500073))
    is_sfence = sys0 & (f7s == _u(0x09))
    is_hfence_v = sys0 & (f7s == _u(0x11))   # hfence.vvma
    is_hfence_g = sys0 & (f7s == _u(0x31))   # hfence.gvma

    mstatus = csrs[C.R_MSTATUS]
    hstatus = csrs[C.R_HSTATUS]

    ecall_cause = jnp.where(priv == 3, C.EXC_ECALL_M,
                  jnp.where(priv == 0, C.EXC_ECALL_U,
                            jnp.where(virt, C.EXC_ECALL_VS, C.EXC_ECALL_S)))
    fault = merge_fault(fault, mk_fault(is_ecall, ecall_cause))
    fault = merge_fault(fault, mk_fault(is_ebreak, C.EXC_BREAK, pc))

    # WFI: TW/VTW trapping (paper wfi_exception_tests)
    tw = (mstatus & _u(C.MSTATUS_TW)) != 0
    vtw = (hstatus & _u(C.HSTATUS_VTW)) != 0
    wfi_illegal = is_wfi & ((tw & (priv < 3)) | (priv == 0) & ~virt)
    wfi_vinst = is_wfi & ~wfi_illegal & virt & (vtw | (priv == 0))
    wfi_ok = is_wfi & ~wfi_illegal & ~wfi_vinst
    pend_any = (csrs[C.R_MIP] & csrs[C.R_MIE]) != 0
    halt = wfi_ok & ~pend_any
    fault = merge_fault(fault, mk_fault(wfi_illegal, C.EXC_ILLEGAL, instr))
    fault = merge_fault(fault, mk_fault(wfi_vinst,
                                        C.EXC_VIRTUAL_INSTRUCTION, instr))

    # SRET
    tsr = (mstatus & _u(C.MSTATUS_TSR)) != 0
    vtsr = (hstatus & _u(C.HSTATUS_VTSR)) != 0
    sret_illegal = is_sret & ((priv == 0) | (tsr & (priv == 1) & ~virt))
    sret_vinst = is_sret & ~sret_illegal & virt & (vtsr | (priv == 0))
    sret_ok = is_sret & ~sret_illegal & ~sret_vinst
    fault = merge_fault(fault, mk_fault(sret_illegal, C.EXC_ILLEGAL, instr))
    fault = merge_fault(fault, mk_fault(sret_vinst,
                                        C.EXC_VIRTUAL_INSTRUCTION, instr))
    # sret from HS: V ← hstatus.SPV, priv ← sstatus.SPP
    spp = ((mstatus & _u(C.MSTATUS_SPP)) != 0).astype(jnp.int32)
    spie = (mstatus & _u(C.MSTATUS_SPIE)) != 0
    mst_sret = mstatus
    mst_sret = jnp.where(spie, mst_sret | _u(C.MSTATUS_SIE),
                         mst_sret & ~_u(C.MSTATUS_SIE))
    mst_sret = (mst_sret | _u(C.MSTATUS_SPIE)) & ~_u(C.MSTATUS_SPP)
    spv = (hstatus & _u(C.HSTATUS_SPV)) != 0
    hst_sret = hstatus & ~_u(C.HSTATUS_SPV)
    # sret from VS (virt): uses vsstatus
    vsstatus = csrs[C.R_VSSTATUS]
    vspp = ((vsstatus & _u(C.MSTATUS_SPP)) != 0).astype(jnp.int32)
    vspie = (vsstatus & _u(C.MSTATUS_SPIE)) != 0
    vst_sret = vsstatus
    vst_sret = jnp.where(vspie, vst_sret | _u(C.MSTATUS_SIE),
                         vst_sret & ~_u(C.MSTATUS_SIE))
    vst_sret = (vst_sret | _u(C.MSTATUS_SPIE)) & ~_u(C.MSTATUS_SPP)
    csrs_sret_hs = csrs.at[C.R_MSTATUS].set(mst_sret).at[C.R_HSTATUS].set(
        hst_sret)
    csrs_sret_vs = csrs.at[C.R_VSSTATUS].set(vst_sret)

    # MRET
    mret_illegal = is_mret & (priv != 3)
    mret_ok = is_mret & ~mret_illegal
    fault = merge_fault(fault, mk_fault(mret_illegal, C.EXC_ILLEGAL, instr))
    mpp = ((mstatus & _u(C.MSTATUS_MPP)) >> _u(11)).astype(jnp.int32)
    mpie = (mstatus & _u(C.MSTATUS_MPIE)) != 0
    mpv = (mstatus & _u(C.MSTATUS_MPV)) != 0
    mst_mret = mstatus
    mst_mret = jnp.where(mpie, mst_mret | _u(C.MSTATUS_MIE),
                         mst_mret & ~_u(C.MSTATUS_MIE))
    mst_mret = (mst_mret | _u(C.MSTATUS_MPIE)) & ~_u(C.MSTATUS_MPP) & \
        ~_u(C.MSTATUS_MPV)

    # fences (paper hfence_tests: hfence touches only guest TLB entries).
    # sfence.vma from VS flushes the guest's own (guest-tagged) entries;
    # hfence.{vvma,gvma} from VS raises virtual-instruction; from U illegal.
    is_hf = is_hfence_v | is_hfence_g
    hf_vinst = is_hf & virt
    hf_illegal = is_hf & ~virt & (priv == 0)
    sf_vinst = is_sfence & virt & (priv == 0)          # VU
    sf_illegal = is_sfence & ~virt & (priv == 0)       # native U
    fault = merge_fault(fault, mk_fault(hf_vinst | sf_vinst,
                                        C.EXC_VIRTUAL_INSTRUCTION, instr))
    fault = merge_fault(fault, mk_fault(hf_illegal | sf_illegal,
                                        C.EXC_ILLEGAL, instr))
    do_hf_v = is_hfence_v & ~virt & (priv >= 1)
    do_hf_g = is_hfence_g & ~virt & (priv >= 1)
    do_sf_native = is_sfence & ~virt & (priv >= 1)
    do_sf_guest = is_sfence & virt & (priv >= 1)       # guest flushing itself
    # rs1≠x0 narrows sfence.vma / hfence.vvma to the one VA page in rs1.
    # hfence.gvma's rs1 is a guest-PHYSICAL address (>>2) and entries are
    # tagged by guest-virtual page, so it stays a conservative full flush.
    # rs2 (ASID/VMID) is conservatively ignored: flushing more than the
    # named address space is architecturally permitted.
    rs1_nz = uop.rs1 != 0
    scoped_g = (do_hf_v | do_sf_guest) & rs1_nz
    scoped_n = do_sf_native & rs1_nz

    # ---------------- merge --------------------------------------------------
    new_csrs = csrs
    new_csrs = jnp.where(is_csr & csr_ok & csr_do_write, csrs_w, new_csrs)
    new_csrs = jnp.where(sret_ok & ~virt, csrs_sret_hs,
                         jnp.where(sret_ok & virt, csrs_sret_vs, new_csrs))
    new_csrs = jnp.where(mret_ok, csrs.at[C.R_MSTATUS].set(mst_mret),
                         new_csrs)
    csrs_set = (is_csr & csr_ok & csr_do_write) | sret_ok | mret_ok

    new_pc = jnp.where(sret_ok, jnp.where(virt, csrs[C.R_VSEPC],
                                          csrs[C.R_SEPC]), csrs[C.R_MEPC])
    new_priv = jnp.where(sret_ok, jnp.where(virt, vspp, spp), mpp)
    new_virt = jnp.where(sret_ok, jnp.where(virt, virt, spv),
                         (mpp != 3) & mpv)
    pv_set = sret_ok | mret_ok

    return SysOut(fault=fault, wb=wb, do_wb=do_wb,
                  csrs=new_csrs, csrs_set=csrs_set,
                  pc=new_pc, pc_set=pv_set,
                  priv=new_priv, virt=new_virt, pv_set=pv_set,
                  halt=halt,
                  flush_guest=atp_write | do_hf_g |
                  ((do_hf_v | do_sf_guest) & ~rs1_nz),
                  flush_native=atp_write | (do_sf_native & ~rs1_nz),
                  flush_guest_addr=scoped_g,
                  flush_native_addr=scoped_n,
                  flush_va=jnp.asarray(rv1, U64))


# ---------------------------------------------------------------------------
# the executor: opclass contributors → one ExecOut delta record
# ---------------------------------------------------------------------------

class ExecOut(NamedTuple):
    """Per-instruction effect deltas.  ``machine``'s retire stage applies
    these under the batch commit masks instead of selecting between whole
    pre-built states — in particular the store is a single conditional
    scatter (``mem_idx``/``mem_word``/``mem_commit``), never a
    full-memory select."""

    fault: Fault
    retired: jnp.ndarray
    new_pc: jnp.ndarray
    rd: jnp.ndarray
    wb: jnp.ndarray
    do_wb: jnp.ndarray
    csrs: jnp.ndarray        # full post-exec CSR bank
    tlb: dict                # full post-exec TLB (data fill + flushes)
    priv: jnp.ndarray
    virt: jnp.ndarray
    halt: jnp.ndarray
    mem_idx: jnp.ndarray     # store target word index
    mem_word: jnp.ndarray    # merged word to write
    mem_commit: jnp.ndarray
    console_inc: jnp.ndarray
    done_set: jnp.ndarray
    exit_code: jnp.ndarray
    ctxsw_inc: jnp.ndarray


def _alu_result(uop: D.MicroOp, rv1, rv2):
    """OP / OP-IMM (+W forms, M extension) → (result, hit)."""
    f3, f7 = uop.f3, uop.f7
    is_alu = uop.cls == D.CLS_ALU
    is_alu32 = uop.cls == D.CLS_ALU32
    is_op = is_alu & ~uop.alu_imm
    is_opi = is_alu & uop.alu_imm
    is_op32 = is_alu32 & ~uop.alu_imm
    alu_b = jnp.where(uop.alu_imm, uop.imm, rv2)
    m_ext = (is_op | is_op32) & (f7 == _u(1))

    sh6 = alu_b & _u(0x3F)
    sh5 = alu_b & _u(0x1F)
    srl = rv1 >> sh6
    sra = _u(_i(rv1) >> sh6.astype(I64))
    sll = rv1 << sh6
    addv = rv1 + alu_b
    subv = rv1 - alu_b
    sltv = _u(_i(rv1) < _i(alu_b))
    sltuv = _u(rv1 < alu_b)
    xorv = rv1 ^ alu_b
    orv = rv1 | alu_b
    andv = rv1 & alu_b
    arith_sub = is_op & (f7 == _u(0x20))
    # OP-IMM-64 srai carries shamt[5] in instr bit 25, so its funct7 is
    # 0x20 OR 0x21 — decode the arithmetic bit from funct6 there (an exact
    # 0x20 match silently turned `srai rd, rs, 32..63` into srli)
    sr_arith = jnp.where(is_opi, (f7 & _u(0x7E)) == _u(0x20),
                         f7 == _u(0x20))
    r64 = jnp.where(f3 == 0, jnp.where(arith_sub, subv, addv),
          jnp.where(f3 == 1, sll,
          jnp.where(f3 == 2, sltv,
          jnp.where(f3 == 3, sltuv,
          jnp.where(f3 == 4, xorv,
          jnp.where(f3 == 5, jnp.where(sr_arith, sra, srl),
          jnp.where(f3 == 6, orv, andv)))))))
    # M extension 64
    mulv = rv1 * alu_b
    m64 = jnp.where(f3 == 0, mulv,
          jnp.where(f3 == 1, mulh(rv1, alu_b),
          jnp.where(f3 == 2, mulhsu(rv1, alu_b),
          jnp.where(f3 == 3, mulhu(rv1, alu_b),
          jnp.where(f3 == 4, divs(rv1, alu_b),
          jnp.where(f3 == 5, divu(rv1, alu_b),
          jnp.where(f3 == 6, rems(rv1, alu_b), remu(rv1, alu_b))))))))
    r64 = jnp.where(m_ext & is_op, m64, r64)
    # 32-bit W forms
    a32 = sext(rv1, 32)
    b32 = sext(alu_b, 32)
    add32 = sext(a32 + b32, 32)
    sub32 = sext(a32 - b32, 32)
    sll32 = sext(a32 << sh5, 32)
    srl32 = sext((a32 & _u(0xFFFFFFFF)) >> sh5, 32)
    sra32 = sext(_u(_i(sext(rv1, 32)) >> sh5.astype(I64)), 32)
    mul32 = sext(a32 * b32, 32)
    # divw truncates THEN sign-extends from bit 31: the overflow quotient
    # INT32_MIN / -1 = +2^31 must read back as sign-extended INT32_MIN
    # (sext(..., 64) left it as 0x80000000)
    div32 = sext(divs(sext(rv1, 32), sext(alu_b, 32)), 32)
    divu32 = jnp.where((alu_b & _u(0xFFFFFFFF)) == 0, ~_u(0),
                       sext((rv1 & _u(0xFFFFFFFF)) //
                            jnp.maximum(alu_b & _u(0xFFFFFFFF), _u(1)), 32))
    rem32 = sext(rems(sext(rv1, 32), sext(alu_b, 32)), 64)
    remu32 = jnp.where((alu_b & _u(0xFFFFFFFF)) == 0, sext(rv1, 32),
                       sext((rv1 & _u(0xFFFFFFFF)) %
                            jnp.maximum(alu_b & _u(0xFFFFFFFF), _u(1)), 32))
    r32 = jnp.where(f3 == 0, jnp.where(is_op32 & (f7 == _u(0x20)), sub32,
                                       add32),
          jnp.where(f3 == 1, sll32,
          jnp.where(f3 == 5, jnp.where(sr_arith, sra32, srl32), add32)))
    m32 = jnp.where(f3 == 0, mul32,
          jnp.where(f3 == 4, div32,
          jnp.where(f3 == 5, divu32,
          jnp.where(f3 == 6, rem32, remu32))))
    r32 = jnp.where(m_ext & is_op32, m32, r32)
    res = jnp.where(is_alu, r64, r32)
    return res, is_alu | is_alu32


def execute_uop(state, uop: D.MicroOp, rv1, rv2, q: MemQuery,
                xr: X.XResult, walked, sys: SysOut) -> ExecOut:
    """Merge all opclass contributors for one decoded micro-op.

    ``xr``/``walked`` is the (possibly TLB-short-circuited) data
    translation for ``q.addr``; ``sys`` the (possibly batch-gated) SYSTEM
    contribution.  Pure per-hart function — vmap over the batch."""
    s = state
    csrs = s["csrs"]
    pc = s["pc"]
    priv = s["priv"]
    virt = s["virt"]
    cls = uop.cls
    instr = uop.instr

    pc4 = pc + _u(4)
    new_pc = pc4
    fault = no_fault()

    # ---------------- ALU ---------------------------------------------------
    alu_res, alu_hit = _alu_result(uop, rv1, rv2)
    wb = jnp.where(alu_hit, alu_res, _u(0))
    do_wb = alu_hit

    # ---------------- LUI / AUIPC / JAL / JALR / branches -------------------
    is_lui = cls == D.CLS_LUI
    is_auipc = cls == D.CLS_AUIPC
    is_jal = cls == D.CLS_JAL
    is_jalr = cls == D.CLS_JALR
    wb = jnp.where(is_lui, uop.imm, wb)
    wb = jnp.where(is_auipc, pc + uop.imm, wb)
    wb = jnp.where(is_jal | is_jalr, pc4, wb)
    do_wb = do_wb | is_lui | is_auipc | is_jal | is_jalr
    new_pc = jnp.where(is_jal, pc + uop.imm, new_pc)
    new_pc = jnp.where(is_jalr, (rv1 + uop.imm) & ~_u(1), new_pc)

    is_br = cls == D.CLS_BRANCH
    f3 = uop.f3
    beq = rv1 == rv2
    blt = _i(rv1) < _i(rv2)
    bltu = rv1 < rv2
    brt = jnp.where(f3 == 0, beq,
          jnp.where(f3 == 1, ~beq,
          jnp.where(f3 == 4, blt,
          jnp.where(f3 == 5, ~blt,
          jnp.where(f3 == 6, bltu, ~bltu)))))
    new_pc = jnp.where(is_br & brt, pc + uop.imm, new_pc)

    # ---------------- loads / stores (incl. hlv/hsv) -------------------------
    addr, size, uns = q.addr, q.size, q.uns
    any_load, any_store = q.any_load, q.any_store
    mem_op = q.mem_op
    # MMIO check (physical).  Every device register decodes as a whole
    # 8-byte region (the CLINT ones with size-aware access), so the classic
    # RV32-style pair of 32-bit stores works and a sub-word access can
    # never alias into RAM through the modulo-wrapped word index.
    pa_word = xr.pa & ~_u(7)
    is_console = pa_word == _u(MMIO_CONSOLE)
    is_done_io = pa_word == _u(MMIO_DONE)
    is_ctxsw_io = pa_word == _u(MMIO_CTXSW)
    is_mtimecmp_io = pa_word == _u(MMIO_MTIMECMP)
    is_mtime_io = pa_word == _u(MMIO_MTIME)
    is_mmio = (is_console | is_done_io | is_ctxsw_io | is_mtimecmp_io |
               is_mtime_io)
    # final-PA bounds: a translated (or bare) PA that is neither RAM nor a
    # decoded MMIO register is an access fault — it must not alias back
    # into RAM through the modulo-wrapped word index.  Loads are further
    # restricted to the *readable* MMIO registers (the CLINT pair); the
    # write-only ones (console/done/ctxsw) have no read decode, so a load
    # from them would otherwise wrap into RAM too.
    mmio_readable = is_mtimecmp_io | is_mtime_io
    pa_oob = (~is_mmio & (xr.pa >= _u(s["mem"].shape[0] * 8))) | \
        (any_load & is_mmio & ~mmio_readable)

    mem_idx = (xr.pa >> _u(3)).astype(jnp.int32) % s["mem"].shape[0]
    word0 = s["mem"][mem_idx]
    ld_val = word_extract(word0, xr.pa, size, uns)
    # CLINT reads: mtime / mtimecmp come from the timer registers
    ld_val = jnp.where(is_mtime_io,
                       word_extract(csrs[C.R_MTIME], xr.pa, size, uns),
                       ld_val)
    ld_val = jnp.where(is_mtimecmp_io,
                       word_extract(csrs[C.R_MTIMECMP], xr.pa, size, uns),
                       ld_val)
    st_word = word_deposit(word0, xr.pa, rv2, size)

    mem_fault_align = mem_op & q.misaligned
    mem_fault_page = mem_op & ~q.misaligned & xr.fault
    mem_fault_oob = mem_op & ~q.misaligned & ~xr.fault & pa_oob

    # tinst for guest page faults (paper tinst_tests): pseudoinstruction for
    # implicit PTE-walk faults, rs1-cleared transform for explicit accesses
    is_gpf = (xr.cause == _u(C.EXC_LGUEST_PAGE_FAULT)) | \
             (xr.cause == _u(C.EXC_SGUEST_PAGE_FAULT))
    pseudo = jnp.where(any_store, _u(0x2020), _u(0x2000))
    transform = instr & ~_u(0xF8000)      # clear rs1 field
    tinst = jnp.where(xr.implicit, pseudo, transform)
    tinst = jnp.where(is_gpf, tinst, _u(0))

    f_mem = Fault(mem_fault_page, xr.cause, xr.tval, xr.tval2,
                  xr.gva | (q.force_virt & xr.fault), tinst)
    align_cause = jnp.where(any_store, C.EXC_SADDR_MISALIGNED,
                            C.EXC_LADDR_MISALIGNED)
    f_align = Fault(mem_fault_align, _u(align_cause), _u(addr), _u(0),
                    jnp.asarray(virt | q.force_virt, bool), _u(0))
    oob_cause = jnp.where(any_store, C.EXC_SACCESS, C.EXC_LACCESS)
    f_oob = Fault(mem_fault_oob, _u(oob_cause), _u(addr), _u(0),
                  jnp.asarray(virt | q.force_virt, bool), _u(0))
    fault = merge_fault(merge_fault(merge_fault(f_align, f_mem), f_oob),
                        fault)

    mem_ok = mem_op & ~q.misaligned & ~xr.fault & ~pa_oob
    wb = jnp.where(any_load & mem_ok, ld_val, wb)
    do_wb = do_wb | (any_load & mem_ok)
    mem_commit = any_store & mem_ok & ~is_mmio
    console_inc = any_store & mem_ok & is_console
    done_set = any_store & mem_ok & is_done_io
    ctxsw_inc = any_store & mem_ok & is_ctxsw_io
    # CLINT writes: size-aware merges into the timer registers (mtimecmp
    # arms the M-level comparator; mtime is writable per the CLINT spec)
    new_csrs = csrs
    new_csrs = jnp.where(
        any_store & mem_ok & is_mtimecmp_io,
        csrs.at[C.R_MTIMECMP].set(
            word_deposit(csrs[C.R_MTIMECMP], xr.pa, rv2, size)), new_csrs)
    new_csrs = jnp.where(
        any_store & mem_ok & is_mtime_io,
        csrs.at[C.R_MTIME].set(
            word_deposit(csrs[C.R_MTIME], xr.pa, rv2, size)), new_csrs)
    new_tlb = jax.tree.map(
        lambda n, o: jnp.where(mem_ok & walked, n, o),
        tlb_fill(s, addr, xr, force_virt=q.force_virt), s["tlb"])
    fault = merge_fault(fault, mk_fault(q.hx_vinst,
                                        C.EXC_VIRTUAL_INSTRUCTION, instr))
    fault = merge_fault(fault, mk_fault(q.hx_illegal, C.EXC_ILLEGAL, instr))

    # ---------------- SYSTEM contribution (possibly batch-gated) ------------
    fault = merge_fault(fault, sys.fault)
    wb = jnp.where(sys.do_wb, sys.wb, wb)
    do_wb = do_wb | sys.do_wb
    new_csrs = jnp.where(sys.csrs_set, sys.csrs, new_csrs)
    new_pc = jnp.where(sys.pc_set, sys.pc, new_pc)
    new_priv = jnp.where(sys.pv_set, sys.priv, priv)
    new_virt = jnp.where(sys.pv_set, sys.virt, virt)
    # flush_where is the identity when every scope is False
    new_tlb = TLB.flush_where(new_tlb, sys.flush_guest, sys.flush_native,
                              sys.flush_guest_addr, sys.flush_native_addr,
                              sys.flush_va)

    # ---------------- illegal opcode ----------------------------------------
    fault = merge_fault(fault, mk_fault(cls == D.CLS_ILLEGAL,
                                        C.EXC_ILLEGAL, instr))
    retired = ~fault.fault

    return ExecOut(fault=fault, retired=retired, new_pc=new_pc,
                   rd=uop.rd, wb=wb, do_wb=do_wb,
                   csrs=new_csrs, tlb=new_tlb,
                   priv=new_priv, virt=new_virt, halt=sys.halt,
                   mem_idx=mem_idx, mem_word=st_word, mem_commit=mem_commit,
                   console_inc=console_inc, done_set=done_set,
                   exit_code=rv2, ctxsw_inc=ctxsw_inc)


def execute(state, instr):
    """One instruction (compat path). Returns (new_state, Fault, retired).

    Runs every contributor unconditionally with the always-walk
    translation — the per-hart semantics of the staged pipeline without
    its batch-level gating."""
    s = state
    uop = D.decode(instr)
    rv1 = s["regs"][uop.rs1]
    rv2 = s["regs"][uop.rs2]
    q = mem_query(s["csrs"], s["priv"], s["virt"], uop, rv1)
    xr, walked = translate_cached(s, q.addr, q.macc, force_virt=q.force_virt,
                                  hlvx=q.hlvx)
    sys = exec_sys(s["csrs"], s["priv"], s["virt"], s["pc"], rv1, uop)
    eo = execute_uop(s, uop, rv1, rv2, q, xr, walked, sys)

    retired = eo.retired
    wb_final = jnp.where(eo.do_wb & retired & (eo.rd != 0), eo.wb,
                         s["regs"][eo.rd])
    out = dict(s)
    out["regs"] = s["regs"].at[eo.rd].set(wb_final)
    out["pc"] = jnp.where(retired, eo.new_pc, s["pc"])
    out["csrs"] = jnp.where(retired, eo.csrs, s["csrs"])
    out["mem"] = s["mem"].at[eo.mem_idx].set(
        jnp.where(eo.mem_commit, eo.mem_word, s["mem"][eo.mem_idx]))
    out["tlb"] = jax.tree.map(lambda n, o: jnp.where(retired, n, o),
                              eo.tlb, s["tlb"])
    out["priv"] = jnp.where(retired, eo.priv, s["priv"])
    out["virt"] = jnp.where(retired, eo.virt, s["virt"])
    out["halted"] = jnp.where(retired, eo.halt, s["halted"])
    out["console"] = s["console"] + eo.console_inc.astype(jnp.int64)
    out["done"] = s["done"] | eo.done_set
    out["exit_code"] = jnp.where(eo.done_set, eo.exit_code, s["exit_code"])
    out["ctx_switches"] = s["ctx_switches"] + \
        (retired & eo.ctxsw_inc).astype(jnp.int64)
    return out, eo.fault, retired
