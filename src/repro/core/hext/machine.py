"""Batched hart state machine — gem5's tick loop, vectorized.

``step`` = CheckInterrupts → (halted? idle) → fetch (translated) → execute →
(fault? RiscvFault::invoke analogue). All branchless; ``run`` scans ticks;
``batched_run`` vmaps over a hart batch (the TPU-native reformulation of
gem5's event loop — DESIGN.md §2a).

Counters (per hart) mirror the paper's Figures:
  instret              — Fig 5 (executed instructions w/ and w/o VM)
  exc_by_level[3]      — Figs 6/7 (exceptions handled at M / HS / VS)
  int_by_level[3]      — interrupts handled per level
  pagefaults           — page-fault subset of exceptions
  walks                — page-table walks performed (TLB misses)
  ticks                — Fig 4 (simulation time proxy; deterministic)
  timer_irqs           — taken timer interrupts (MTI/STI/VSTI)
  ctx_switches         — guest context switches (hypervisor MMIO pokes)

``step`` also advances the virtual CLINT each tick (``_advance_timers``):
mtime increments, and each *armed* comparator (mtimecmp, and the Sstc-style
stimecmp/vstimecmp CSRs) drives its mip bit.  Comparators boot disarmed
(2^64-1), so workloads that never arm one see identical behavior.

64-bit integer state requires x64; call sites must run under
``with jax.experimental.enable_x64():`` — ``run``/``batched_run`` do this
internally around trace+execute.

NOTE: this module is the raw-dict ISA-core layer.  The public simulation
API is ``repro.core.hext.sim`` (typed ``HartState`` pytree + ``Fleet``
facade, DESIGN.md §3) and the run loops live behind the pluggable
``repro.core.hext.engine`` backends; the old raw-dict shims
(``make_state``/``run_until_done``/``batched_run_until_done``) are gone —
use ``HartState.fresh`` / ``Fleet`` / ``engine.JitEngine`` instead.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.hext import csr as C
from repro.core.hext import isa
from repro.core.hext import tlb as TLB
from repro.core.hext import translate as X
from repro.core.hext import trap as TR

U64 = jnp.uint64


def _u(x):
    return jnp.asarray(x, U64)


DEFAULT_MEM_WORDS = 1 << 15          # 256 KiB per hart


def _make_state(mem_words: int) -> Dict:
    """Power-on raw-dict state (private: the typed ``sim.HartState.fresh``
    is the public constructor and owns the x64 context)."""
    return {
        "pc": _u(0),
        "regs": jnp.zeros((32,), U64),
        "csrs": C.init_csrs(),
        "priv": jnp.asarray(3, jnp.int32),     # boot in M
        "virt": jnp.zeros((), bool),
        "mem": jnp.zeros((mem_words,), U64),
        "tlb": TLB.init_tlb(),
        "halted": jnp.zeros((), bool),
        "done": jnp.zeros((), bool),
        "exit_code": _u(0),
        "console": jnp.zeros((), jnp.int64),
        # counters
        "instret": jnp.zeros((), jnp.int64),
        "instret_virt": jnp.zeros((), jnp.int64),
        "exc_by_level": jnp.zeros((3,), jnp.int64),   # M, HS, VS
        "int_by_level": jnp.zeros((3,), jnp.int64),
        "pagefaults": jnp.zeros((), jnp.int64),
        "walks": jnp.zeros((), jnp.int64),
        "ticks": jnp.zeros((), jnp.int64),
        "timer_irqs": jnp.zeros((), jnp.int64),
        "ctx_switches": jnp.zeros((), jnp.int64),
    }


def load_image(state: Dict, image, base: int = 0) -> Dict:
    """Write a uint64-word image into memory at byte address `base`."""
    with jax.experimental.enable_x64():
        w = base >> 3
        mem = state["mem"].at[w:w + image.shape[0]].set(image.astype(U64))
        return {**state, "mem": mem}


def _sel_state(cond, a: Dict, b: Dict) -> Dict:
    return jax.tree.map(lambda x, y: jnp.where(cond, x, y), a, b)


def _invoke(state: Dict, f: isa.Fault, is_int, pc_override=None) -> Dict:
    """RiscvFault::invoke(): route + update CSRs + bump counters."""
    pc = state["pc"] if pc_override is None else pc_override
    new_csrs, new_pc, new_priv, new_virt, handled = TR.take_trap(
        state["csrs"], state["priv"], state["virt"], pc, f.cause, is_int,
        f.tval, f.tval2, f.gva, f.tinst)
    out = dict(state)
    out["csrs"] = new_csrs
    out["pc"] = new_pc
    out["priv"] = new_priv
    out["virt"] = new_virt
    out["halted"] = jnp.zeros((), bool)
    is_pf = ((f.cause == _u(C.EXC_IPAGE_FAULT)) |
             (f.cause == _u(C.EXC_LPAGE_FAULT)) |
             (f.cause == _u(C.EXC_SPAGE_FAULT)) |
             (f.cause == _u(C.EXC_IGUEST_PAGE_FAULT)) |
             (f.cause == _u(C.EXC_LGUEST_PAGE_FAULT)) |
             (f.cause == _u(C.EXC_SGUEST_PAGE_FAULT)))
    lvl = handled  # 0 M, 1 HS, 2 VS
    key = "int_by_level" if is_int else "exc_by_level"
    out[key] = state[key].at[lvl].add(1)
    if not is_int:
        out["pagefaults"] = state["pagefaults"] + is_pf.astype(jnp.int64)
    else:
        is_timer = ((f.cause == _u(5)) | (f.cause == _u(6)) |
                    (f.cause == _u(7)))        # STI / VSTI / MTI
        out["timer_irqs"] = state["timer_irqs"] + is_timer.astype(jnp.int64)
    return out


def _advance_timers(csrs):
    """CLINT-style virtual time source: mtime advances once per tick; each
    *armed* comparator (mtimecmp / stimecmp / vstimecmp, Sstc-style) drives
    its mip bit from the comparison.  Disarmed comparators (the boot value,
    2^64-1) leave their mip bit fully software-owned — hvip injection and
    direct mip writes behave exactly as before the timer existed.

    The VS comparator sees the *guest's* time base: vstimecmp compares
    against mtime + htimedelta, so a hypervisor that maintains per-guest
    htimedelta across context switches gives each guest timer interrupts in
    its own virtual time."""
    mtime = csrs[C.R_MTIME] + _u(1)
    csrs = csrs.at[C.R_MTIME].set(mtime)
    mip = csrs[C.R_MIP]
    vs_time = mtime + csrs[C.R_HTIMEDELTA]
    for cmp_idx, bit, now in ((C.R_MTIMECMP, C.IP_MTIP, mtime),
                              (C.R_STIMECMP, C.IP_STIP, mtime),
                              (C.R_VSTIMECMP, C.IP_VSTIP, vs_time)):
        cmpv = csrs[cmp_idx]
        armed = cmpv != _u(C.TIMER_DISARMED)
        fired = mip | _u(bit)
        idle = mip & ~_u(bit)
        mip = jnp.where(armed, jnp.where(now >= cmpv, fired, idle), mip)
    return csrs.at[C.R_MIP].set(mip)


def step(state: Dict) -> Dict:
    frozen = state["done"]

    # ---- 0. virtual CLINT tick (frozen harts keep their old csrs) ----------
    s = dict(state)
    s["csrs"] = _advance_timers(state["csrs"])

    # ---- 1. CheckInterrupts (paper Fig 2) ----------------------------------
    take, cause = TR.pending_interrupt(s["csrs"], s["priv"], s["virt"])
    f_int = isa.mk_fault(take, 0)._replace(cause=cause)
    s_int = _invoke(s, f_int, is_int=True)

    # ---- 2. fetch + execute -------------------------------------------------
    xr, walked = isa.translate_cached(s, s["pc"], X.ACC_X)
    # fetching from a PA beyond memory (MMIO included — nothing up there is
    # executable) is an instruction access fault, not a wrap into RAM
    fetch_oob = ~xr.fault & (xr.pa >= _u(s["mem"].shape[0] * 8))
    fetch_fault = xr.fault | fetch_oob
    # fetch guest-page-fault tinst is always 0
    f_fetch = isa.Fault(
        fetch_fault,
        jnp.where(xr.fault, xr.cause, _u(C.EXC_IACCESS)),
        jnp.where(xr.fault, xr.tval, _u(s["pc"])),
        jnp.where(xr.fault, xr.tval2, _u(0)),
        jnp.where(xr.fault, xr.gva, s["virt"]),
        _u(0))
    word = s["mem"][(xr.pa >> _u(3)).astype(jnp.int32) % s["mem"].shape[0]]
    instr = jnp.where((xr.pa & _u(4)) != 0, word >> _u(32),
                      word & _u(0xFFFFFFFF))
    s_after_fill = dict(s)
    s_after_fill["tlb"] = jax.tree.map(
        lambda n, o: jnp.where(~fetch_fault & walked, n, o),
        isa.tlb_fill(s, s["pc"], xr), s["tlb"])
    s_after_fill["walks"] = s["walks"] + walked.astype(jnp.int64)

    s_exec, f_exec, retired = isa.execute(s_after_fill, instr)
    s_exec["instret"] = s_exec["instret"] + retired.astype(jnp.int64)
    s_exec["instret_virt"] = s_exec["instret_virt"] + \
        (retired & s["virt"]).astype(jnp.int64)

    fault = isa.merge_fault(f_fetch, f_exec)
    s_fault = _invoke(_sel_state(fetch_fault, s_after_fill, s_exec), fault,
                      is_int=False)

    s_run = _sel_state(fault.fault, s_fault, s_exec)
    # halted harts wake on any pending+locally-enabled interrupt — the spec
    # says WFI resumes on (mip & mie) != 0 regardless of mstatus.MIE/SIE
    # global gating; `take` additionally routes through the trap path when
    # the interrupt is actually deliverable at the current privilege.
    wake = (s["csrs"][C.R_MIP] & s["csrs"][C.R_MIE]) != _u(0)
    s_norm = _sel_state(s["halted"] & ~take & ~wake, s, s_run)
    out = _sel_state(take, s_int, s_norm)
    out = _sel_state(frozen, state, out)
    out["ticks"] = state["ticks"] + (~frozen).astype(jnp.int64)
    return out


def run(state: Dict, n_ticks: int, unroll: int = 1) -> Dict:
    """Scan `n_ticks` steps (compiled once)."""
    with jax.experimental.enable_x64():
        def body(s, _):
            return step(s), None
        fn = jax.jit(lambda s: jax.lax.scan(body, s, None, length=n_ticks,
                                            unroll=unroll)[0])
        return fn(state)


def batched_run(states: Dict, n_ticks: int) -> Dict:
    """vmap over the hart batch — many VMs simulated in lockstep."""
    with jax.experimental.enable_x64():
        def body(s, _):
            return step(s), None
        one = lambda s: jax.lax.scan(body, s, None, length=n_ticks)[0]
        return jax.jit(jax.vmap(one))(states)


# The deprecated raw-dict shims (`make_state`, `run_until_done`,
# `batched_run_until_done`) were removed: `sim.HartState.fresh` builds
# power-on state, and runs go through `sim.Fleet` / the pluggable
# `engine` backends (`engine.JitEngine(donate=False)` is the drop-in for
# the old non-donating host loop).
