"""Batched hart state machine — gem5's tick loop, vectorized.

The per-tick pipeline is staged (DESIGN.md §7):

  ``fetch``  — TLB probe for every hart; the two-stage walk graph is only
               materialized under a batch-level ``lax.cond`` when some
               *running* hart actually misses (paper Fig 3: the walk is
               the dominant cost, and warm phases never pay it);
  ``decode`` — table-driven expansion to a :class:`decode.MicroOp`;
  ``execute``— uniform opclass contributors (``isa.execute_uop``), with
               the data-side walk and the SYSTEM/CSR contributor each
               behind their own batch-level cond;
  ``retire`` — per-field commit under the batch outcome masks (frozen /
               interrupt / idle / fault / ok); stores and register
               writebacks are single conditional scatters, never
               full-array selects.

All four stages are pure functions of the raw dict state; ``step_batched``
is the fused pipeline over a leading hart axis and ``step`` the
single-hart wrapper (a B=1 batch).  The batch-level conds are the whole
point of the layout: inside ``vmap`` a ``lax.cond`` degenerates to
computing both branches, so the engine runs ``step_batched`` directly —
*never* ``vmap(step)``.

Counters (per hart) mirror the paper's Figures:
  instret              — Fig 5 (executed instructions w/ and w/o VM)
  exc_by_level[3]      — Figs 6/7 (exceptions handled at M / HS / VS)
  int_by_level[3]      — interrupts handled per level
  pagefaults           — page-fault subset of exceptions
  walks                — page-table walks performed (fetch TLB misses)
  ticks                — Fig 4 (simulation time proxy; deterministic)
  timer_irqs           — taken timer interrupts (MTI/STI/VSTI)
  ctx_switches         — guest context switches (hypervisor MMIO pokes)

``step_batched`` also advances the virtual CLINT each tick
(``_advance_timers``): mtime increments, and each *armed* comparator
(mtimecmp, and the Sstc-style stimecmp/vstimecmp CSRs) drives its mip
bit.  Comparators boot disarmed (2^64-1), so workloads that never arm
one see identical behavior.

64-bit integer state requires x64; call sites must run under
``with jax.experimental.enable_x64():`` — ``run``/``batched_run`` do this
internally around trace+execute.

NOTE: this module is the raw-dict ISA-core layer.  The public simulation
API is ``repro.core.hext.sim`` (typed ``HartState`` pytree + ``Fleet``
facade, DESIGN.md §3) and the run loops live behind the pluggable
``repro.core.hext.engine`` backends.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.hext import csr as C
from repro.core.hext import decode as D
from repro.core.hext import isa
from repro.core.hext import tlb as TLB
from repro.core.hext import translate as X
from repro.core.hext import trap as TR
from repro.core.hext.bits import u64 as _u

U64 = jnp.uint64


DEFAULT_MEM_WORDS = 1 << 15          # 256 KiB per hart


def _make_state(mem_words: int) -> Dict:
    """Power-on raw-dict state (private: the typed ``sim.HartState.fresh``
    is the public constructor and owns the x64 context)."""
    return {
        "pc": _u(0),
        "regs": jnp.zeros((32,), U64),
        "csrs": C.init_csrs(),
        "priv": jnp.asarray(3, jnp.int32),     # boot in M
        "virt": jnp.zeros((), bool),
        "mem": jnp.zeros((mem_words,), U64),
        "tlb": TLB.init_tlb(),
        "halted": jnp.zeros((), bool),
        "done": jnp.zeros((), bool),
        "exit_code": _u(0),
        "console": jnp.zeros((), jnp.int64),
        # counters
        "instret": jnp.zeros((), jnp.int64),
        "instret_virt": jnp.zeros((), jnp.int64),
        "exc_by_level": jnp.zeros((3,), jnp.int64),   # M, HS, VS
        "int_by_level": jnp.zeros((3,), jnp.int64),
        "pagefaults": jnp.zeros((), jnp.int64),
        "walks": jnp.zeros((), jnp.int64),
        "ticks": jnp.zeros((), jnp.int64),
        "timer_irqs": jnp.zeros((), jnp.int64),
        "ctx_switches": jnp.zeros((), jnp.int64),
    }


def load_image(state: Dict, image, base: int = 0) -> Dict:
    """Write a uint64-word image into memory at byte address `base`."""
    with jax.experimental.enable_x64():
        w = base >> 3
        mem = state["mem"].at[w:w + image.shape[0]].set(image.astype(U64))
        return {**state, "mem": mem}


def _advance_timers(csrs):
    """CLINT-style virtual time source: mtime advances once per tick; each
    *armed* comparator (mtimecmp / stimecmp / vstimecmp, Sstc-style) drives
    its mip bit from the comparison.  Disarmed comparators (the boot value,
    2^64-1) leave their mip bit fully software-owned — hvip injection and
    direct mip writes behave exactly as before the timer existed.

    The VS comparator sees the *guest's* time base: vstimecmp compares
    against mtime + htimedelta, so a hypervisor that maintains per-guest
    htimedelta across context switches gives each guest timer interrupts in
    its own virtual time."""
    mtime = csrs[C.R_MTIME] + _u(1)
    csrs = csrs.at[C.R_MTIME].set(mtime)
    mip = csrs[C.R_MIP]
    vs_time = mtime + csrs[C.R_HTIMEDELTA]
    for cmp_idx, bit, now in ((C.R_MTIMECMP, C.IP_MTIP, mtime),
                              (C.R_STIMECMP, C.IP_STIP, mtime),
                              (C.R_VSTIMECMP, C.IP_VSTIP, vs_time)):
        cmpv = csrs[cmp_idx]
        armed = cmpv != _u(C.TIMER_DISARMED)
        fired = mip | _u(bit)
        idle = mip & ~_u(bit)
        mip = jnp.where(armed, jnp.where(now >= cmpv, fired, idle), mip)
    return csrs.at[C.R_MIP].set(mip)


def _sel_tree(cond, a, b):
    """Per-hart tree select: cond is (B,); leaves may carry trailing dims."""
    def sel(x, y):
        c = cond.reshape(cond.shape + (1,) * (x.ndim - cond.ndim))
        return jnp.where(c, x, y)
    return jax.tree.map(sel, a, b)


def _zero_xr(batch: int) -> X.XResult:
    """Neutral XResult for the cond branch that skips the walk.  Safe
    because every consumer of a walk-only field is gated on ``walked`` /
    ``xr.fault`` (both forced false on the TLB fast path)."""
    z64 = jnp.zeros((batch,), U64)
    zb = jnp.zeros((batch,), bool)
    zi = jnp.zeros((batch,), jnp.int32)
    return X.XResult(pa=z64, fault=zb, cause=z64, tval=z64, tval2=z64,
                     gva=zb, implicit=zb, leaf_pte=z64, g_leaf_pte=z64,
                     level=zi)


def _neutral_sys(csrs) -> isa.SysOut:
    """All-gates-closed SysOut — exact for every non-SYSTEM micro-op
    (``exec_sys`` internally gates all its effects on the SYSTEM
    predicates, so the neutral record equals its output there)."""
    batch = csrs.shape[0]
    z64 = jnp.zeros((batch,), U64)
    zb = jnp.zeros((batch,), bool)
    zi = jnp.zeros((batch,), jnp.int32)
    fz = isa.Fault(zb, z64, z64, z64, zb, z64)
    return isa.SysOut(fault=fz, wb=z64, do_wb=zb, csrs=csrs, csrs_set=zb,
                      pc=z64, pc_set=zb, priv=zi, virt=zb, pv_set=zb,
                      halt=zb, flush_guest=zb, flush_native=zb,
                      flush_guest_addr=zb, flush_native_addr=zb,
                      flush_va=z64)


def _gather(arr2d, idx):
    """Per-hart dynamic gather: arr2d (B, N), idx (B,) → (B,)."""
    return jax.vmap(lambda a, i: a[i])(arr2d, idx)


def fetch(state: Dict, csrs1, m_run):
    """Stage 1: translate PC (TLB fast path, cond-gated walk) and gather
    the instruction word.  Returns (instr, fetch_fault, f_fetch, tlb1,
    walked) where tlb1 carries the fetch-side TLB fill."""
    pc0, priv0, virt0 = state["pc"], state["priv"], state["virt"]
    batch = pc0.shape[0]
    sum_f, mxr_f = jax.vmap(X.eff_ctx)(csrs1, virt0)
    tv = jax.vmap(TLB.lookup, in_axes=(0, 0, 0, None, 0, 0, 0))(
        state["tlb"], pc0, virt0, _u(X.ACC_X), priv0, sum_f, mxr_f)
    use_f = tv.hit & tv.perm_ok
    walked = ~use_f
    need = m_run & walked

    def walk():
        return jax.vmap(
            lambda m, c, p, v, va: X.translate(m, c, p, v, va, X.ACC_X))(
            state["mem"], csrs1, priv0, virt0, pc0)

    xrw = jax.lax.cond(jnp.any(need), walk, lambda: _zero_xr(batch))
    pa = jnp.where(use_f, tv.pa, xrw.pa)
    fault_w = ~use_f & xrw.fault
    xr = xrw._replace(pa=pa, fault=fault_w)
    # fetching from a PA beyond memory (MMIO included — nothing up there is
    # executable) is an instruction access fault, not a wrap into RAM
    fetch_oob = ~xr.fault & (pa >= _u(state["mem"].shape[1] * 8))
    fetch_fault = xr.fault | fetch_oob
    # fetch guest-page-fault tinst is always 0
    f_fetch = isa.Fault(
        fetch_fault,
        jnp.where(xr.fault, xr.cause, _u(C.EXC_IACCESS)),
        jnp.where(xr.fault, xr.tval, pc0),
        jnp.where(xr.fault, xr.tval2, _u(0)),
        jnp.where(xr.fault, xr.gva, virt0),
        jnp.zeros((batch,), U64))
    word = _gather(state["mem"],
                   (pa >> _u(3)).astype(jnp.int32) % state["mem"].shape[1])
    instr = jnp.where((pa & _u(4)) != 0, word >> _u(32),
                      word & _u(0xFFFFFFFF))

    def fill_one(tlb, c, p, v, va, x):
        return isa.tlb_fill({"tlb": tlb, "csrs": c, "priv": p, "virt": v},
                            va, x)

    fill = m_run & ~fetch_fault & walked
    tlb1 = _sel_tree(fill,
                     jax.vmap(fill_one)(state["tlb"], csrs1, priv0, virt0,
                                        pc0, xr),
                     state["tlb"])
    return instr, fetch_fault, f_fetch, tlb1, walked


def execute(state: Dict, csrs1, tlb1, instr, m_exec):
    """Stages 2+3: decode to micro-ops, translate the data access (TLB
    fast path, cond-gated walk), run the cond-gated SYSTEM contributor,
    and merge everything through ``isa.execute_uop``.  ``m_exec`` masks
    the harts whose execution will actually commit (running, fetch OK) —
    it gates the batch-level conds only; the per-hart outputs are wrong
    outside the mask and the retire stage discards them."""
    pc0, priv0, virt0 = state["pc"], state["priv"], state["virt"]

    # ---- decode ------------------------------------------------------------
    uop = jax.vmap(D.decode)(instr)
    rv1 = _gather(state["regs"], uop.rs1)
    rv2 = _gather(state["regs"], uop.rs2)

    # ---- data translation (TLB fast path + cond-gated walk) ----------------
    q = jax.vmap(isa.mem_query)(csrs1, priv0, virt0, uop, rv1)
    virt_d = virt0 | q.force_virt
    sum_d, mxr_d = jax.vmap(X.eff_ctx)(csrs1, virt_d)
    tv = jax.vmap(TLB.lookup)(tlb1, q.addr, virt_d, q.macc, priv0,
                              sum_d, mxr_d)
    use_d = tv.hit & tv.perm_ok & ~q.hlvx
    walked_d = ~use_d
    need_d = m_exec & q.mem_op & ~q.misaligned & walked_d

    def walk():
        return jax.vmap(
            lambda m, c, p, v, va, a, fv, hx: X.translate(
                m, c, p, v, va, a, force_virt=fv, hlvx=hx))(
            state["mem"], csrs1, priv0, virt0, q.addr, q.macc,
            q.force_virt, q.hlvx)

    xrw = jax.lax.cond(jnp.any(need_d), walk,
                       lambda: _zero_xr(pc0.shape[0]))
    pa = jnp.where(use_d, tv.pa, xrw.pa)
    fault_w = ~use_d & xrw.fault
    xr = xrw._replace(pa=pa, fault=fault_w)

    # ---- SYSTEM contributor (cond-gated: CSR where-chains are heavy) -------
    sys_need = m_exec & (uop.cls == D.CLS_SYSTEM) & (uop.f3 != _u(4))
    sys = jax.lax.cond(
        jnp.any(sys_need),
        lambda: jax.vmap(isa.exec_sys)(csrs1, priv0, virt0, pc0, rv1, uop),
        lambda: _neutral_sys(csrs1))

    # ---- merge contributors -------------------------------------------------
    st = dict(state)
    st["csrs"] = csrs1
    st["tlb"] = tlb1
    eo = jax.vmap(isa.execute_uop)(st, uop, rv1, rv2, q, xr, walked_d, sys)
    return eo, virt0


def retire(state: Dict, csrs1, tlb1, eo: isa.ExecOut, f_fetch, fetch_fault,
           walked_f, masks):
    """Stage 4: apply outcome-class commit masks per field.  Register
    writeback and the store are single conditional scatters."""
    frozen, take, icause, m_run, m_int = masks
    pc0, priv0, virt0 = state["pc"], state["priv"], state["virt"]
    batch = pc0.shape[0]

    fault = isa.merge_fault(f_fetch, eo.fault)
    m_fault = m_run & fault.fault
    m_ok = m_run & ~fault.fault
    m_trap = m_int | m_fault

    # ---- trap invoke (one cond-gated take_trap for interrupts + faults) ----
    t_cause = jnp.where(take, icause, fault.cause)
    t_tval = jnp.where(take, _u(0), fault.tval)
    t_tval2 = jnp.where(take, _u(0), fault.tval2)
    t_gva = jnp.where(take, False, fault.gva)
    t_tinst = jnp.where(take, _u(0), fault.tinst)

    def trap():
        return jax.vmap(TR.take_trap)(csrs1, priv0, virt0, pc0, t_cause,
                                      take, t_tval, t_tval2, t_gva, t_tinst)

    trap_csrs, trap_pc, trap_priv, trap_virt, handled = jax.lax.cond(
        jnp.any(m_trap), trap,
        lambda: (csrs1, jnp.zeros((batch,), U64),
                 jnp.zeros((batch,), jnp.int32), jnp.zeros((batch,), bool),
                 jnp.zeros((batch,), jnp.int32)))

    out = dict(state)
    out["pc"] = jnp.where(m_trap, trap_pc,
                          jnp.where(m_ok, eo.new_pc, pc0))
    out["csrs"] = jnp.where(frozen[:, None], state["csrs"],
                  jnp.where(m_trap[:, None], trap_csrs,
                  jnp.where(m_ok[:, None], eo.csrs, csrs1)))
    out["priv"] = jnp.where(m_trap, trap_priv,
                            jnp.where(m_ok, eo.priv, priv0))
    out["virt"] = jnp.where(m_trap, trap_virt,
                            jnp.where(m_ok, eo.virt, virt0))
    out["halted"] = jnp.where(m_trap, False,
                              jnp.where(m_ok, eo.halt, state["halted"]))
    # delta retire: one conditional scatter each for regs and memory
    wb_go = m_ok & eo.do_wb & (eo.rd != 0)
    out["regs"] = jax.vmap(
        lambda r, i, c, w: r.at[i].set(jnp.where(c, w, r[i])))(
        state["regs"], eo.rd, wb_go, eo.wb)
    st_go = m_ok & eo.mem_commit
    out["mem"] = jax.vmap(
        lambda m, i, c, w: m.at[i].set(jnp.where(c, w, m[i])))(
        state["mem"], eo.mem_idx, st_go, eo.mem_word)
    out["tlb"] = _sel_tree(m_ok, eo.tlb, tlb1)

    out["console"] = state["console"] + \
        (m_ok & eo.console_inc).astype(jnp.int64)
    out["done"] = state["done"] | (m_ok & eo.done_set)
    out["exit_code"] = jnp.where(m_ok & eo.done_set, eo.exit_code,
                                 state["exit_code"])
    out["ctx_switches"] = state["ctx_switches"] + \
        (m_ok & eo.ctxsw_inc).astype(jnp.int64)

    # ---- counters ----------------------------------------------------------
    out["instret"] = state["instret"] + m_ok.astype(jnp.int64)
    out["instret_virt"] = state["instret_virt"] + \
        (m_ok & virt0).astype(jnp.int64)
    out["walks"] = state["walks"] + (m_run & walked_f).astype(jnp.int64)
    out["ticks"] = state["ticks"] + (~frozen).astype(jnp.int64)
    is_pf = ((fault.cause == _u(C.EXC_IPAGE_FAULT)) |
             (fault.cause == _u(C.EXC_LPAGE_FAULT)) |
             (fault.cause == _u(C.EXC_SPAGE_FAULT)) |
             (fault.cause == _u(C.EXC_IGUEST_PAGE_FAULT)) |
             (fault.cause == _u(C.EXC_LGUEST_PAGE_FAULT)) |
             (fault.cause == _u(C.EXC_SGUEST_PAGE_FAULT)))
    out["pagefaults"] = state["pagefaults"] + \
        (m_fault & is_pf).astype(jnp.int64)
    is_timer = (icause == _u(5)) | (icause == _u(6)) | (icause == _u(7))
    out["timer_irqs"] = state["timer_irqs"] + \
        (m_int & is_timer).astype(jnp.int64)
    bump = jax.vmap(lambda a, i, c: a.at[i].add(c.astype(jnp.int64)))
    out["int_by_level"] = bump(state["int_by_level"], handled, m_int)
    out["exc_by_level"] = bump(state["exc_by_level"], handled, m_fault)
    return out


def step_batched(state: Dict) -> Dict:
    """One architectural tick for a (B, ...) hart batch — the fused
    fetch → decode → execute → retire pipeline."""
    frozen = state["done"]

    # ---- 0. virtual CLINT tick (frozen harts keep their old csrs) ----------
    csrs1 = jax.vmap(_advance_timers)(state["csrs"])

    # ---- 1. CheckInterrupts (paper Fig 2) ----------------------------------
    take, icause = jax.vmap(TR.pending_interrupt)(csrs1, state["priv"],
                                                  state["virt"])
    # halted harts wake on any pending+locally-enabled interrupt — the spec
    # says WFI resumes on (mip & mie) != 0 regardless of mstatus.MIE/SIE
    # global gating; `take` additionally routes through the trap path when
    # the interrupt is actually deliverable at the current privilege.
    wake = (csrs1[:, C.R_MIP] & csrs1[:, C.R_MIE]) != _u(0)
    idle = state["halted"] & ~take & ~wake
    m_run = ~frozen & ~take & ~idle
    m_int = ~frozen & take

    # ---- 2..4. fetch → decode+execute → retire -----------------------------
    instr, fetch_fault, f_fetch, tlb1, walked_f = fetch(state, csrs1, m_run)
    eo, _ = execute(state, csrs1, tlb1, instr, m_run & ~fetch_fault)
    return retire(state, csrs1, tlb1, eo, f_fetch, fetch_fault, walked_f,
                  (frozen, take, icause, m_run, m_int))


def step(state: Dict) -> Dict:
    """Single-hart tick: a B=1 ride through the batched pipeline.  Fine
    under ``scan``/``jit``; do NOT ``vmap`` this (use ``step_batched``) —
    vmap collapses the batch-level conds into always-both-branches."""
    b = jax.tree.map(lambda x: x[None], state)
    return jax.tree.map(lambda x: x[0], step_batched(b))


def run(state: Dict, n_ticks: int, unroll: int = 1) -> Dict:
    """Scan `n_ticks` steps (compiled once)."""
    with jax.experimental.enable_x64():
        def body(s, _):
            return step_batched(s), None
        fn = jax.jit(lambda s: jax.lax.scan(body, s, None, length=n_ticks,
                                            unroll=unroll)[0])
        b = jax.tree.map(lambda x: x[None], state)
        return jax.tree.map(lambda x: x[0], fn(b))


def batched_run(states: Dict, n_ticks: int) -> Dict:
    """Run a hart batch — many VMs simulated in lockstep.  Scans the
    batched pipeline directly (batch-level conds stay real conditionals;
    a vmap-of-scalar-step would compute both branches everywhere)."""
    with jax.experimental.enable_x64():
        def body(s, _):
            return step_batched(s), None
        return jax.jit(lambda s: jax.lax.scan(body, s, None,
                                              length=n_ticks)[0])(states)


# The deprecated raw-dict shims (`make_state`, `run_until_done`,
# `batched_run_until_done`) were removed: `sim.HartState.fresh` builds
# power-on state, and runs go through `sim.Fleet` / the pluggable
# `engine` backends (`engine.JitEngine(donate=False)` is the drop-in for
# the old non-donating host loop).
