"""Pure-Python architectural oracle for the hext machine (DESIGN.md §5).

An *independent* reimplementation of the simulator's architectural
semantics — plain ints and dicts, no JAX — used by the randomized
differential torture harness (``repro.core.hext.torture``) as the
reference model: both models boot the same memory image from reset and
the harness diffs their final state, RiescueC-style.

Scope (what the oracle predicts, and the harness compares):
  pc, x1..x31, priv, virt, halted, the full CSR file, memory, done /
  exit_code / console, and the counters instret / instret_virt /
  exc_by_level / int_by_level / pagefaults / ticks / timer_irqs /
  ctx_switches / walks.

The oracle carries a faithful model of the machine's software TLB
(guest/native tagging, priv/SUM/MXR context tags, per-level VPN masks,
round-robin replacement, scoped invalidation) so ``walks`` — and the
architectural side effects of *stale* cached translations, which
PTE-rewriting guests make visible — are compared exactly.  The exclusion
list is empty: nothing the machine computes is out of diff scope.

For coverage-guided fuzzing the oracle additionally records an
architectural-event set in ``st["events"]`` (trap/fence/atp-write
signatures); events are bookkeeping for the torture harness's coverage
buckets and are never part of the differential compare.

The oracle mirrors the machine's *documented* semantics including its
WARL masks, aliasing, and decode quirks (e.g. unknown SYSTEM f3=0
encodings retire as no-ops); constants are shared with ``csr.py`` /
``translate.py`` so the two models can only diverge in logic, which is
exactly what the differential harness is hunting.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.hext import csr as C
from repro.core.hext import isa as _isa  # MMIO addresses only
from repro.core.hext import translate as X

M64 = (1 << 64) - 1

# convenient local names ------------------------------------------------------
ACC_R, ACC_W, ACC_X = X.ACC_R, X.ACC_W, X.ACC_X
PTE_V, PTE_R, PTE_W, PTE_X = X.PTE_V, X.PTE_R, X.PTE_W, X.PTE_X
PTE_U, PTE_A, PTE_D = X.PTE_U, X.PTE_A, X.PTE_D
ALL_PERM_PTE = X.ALL_PERM_PTE

MMIO_CONSOLE = _isa.MMIO_CONSOLE
MMIO_DONE = _isa.MMIO_DONE
MMIO_CTXSW = _isa.MMIO_CTXSW
MMIO_MTIMECMP = _isa.MMIO_MTIMECMP
MMIO_MTIME = _isa.MMIO_MTIME


def u64(x: int) -> int:
    return x & M64


def sext(x: int, bits: int) -> int:
    """Sign-extend the low `bits` of x into a uint64 (two's complement)."""
    x &= (1 << bits) - 1
    m = 1 << (bits - 1)
    return u64((x ^ m) - m)


def s64(x: int) -> int:
    """uint64 → signed python int."""
    x &= M64
    return x - (1 << 64) if x >= (1 << 63) else x


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

N_TLB = 16
PERM_R, PERM_W, PERM_X = 1, 2, 4


def init_tlb() -> Dict:
    """Empty software-TLB model (mirror of ``tlb.init_tlb``)."""
    return {
        "vpn": [0] * N_TLB,
        "ppn": [0] * N_TLB,
        "level": [0] * N_TLB,
        "perm": [0] * N_TLB,
        "guest": [False] * N_TLB,
        "priv": [0] * N_TLB,
        "sum": [False] * N_TLB,
        "mxr": [False] * N_TLB,
        "valid": [False] * N_TLB,
        "ptr": 0,
    }


def reset_state(image) -> Dict:
    """Power-on state with a memory image loaded (pc=0, M mode)."""
    return {
        "pc": 0,
        "regs": [0] * 32,
        "csrs": init_csrs(),
        "priv": 3,
        "virt": False,
        "mem": [int(w) for w in image],
        "tlb": init_tlb(),
        "halted": False,
        "done": False,
        "exit_code": 0,
        "console": 0,
        "instret": 0,
        "instret_virt": 0,
        "exc_by_level": [0, 0, 0],
        "int_by_level": [0, 0, 0],
        "pagefaults": 0,
        "walks": 0,
        "ticks": 0,
        "timer_irqs": 0,
        "ctx_switches": 0,
        "events": set(),
    }


def resume_state(snap: Dict) -> Dict:
    """Oracle state adopted from a host-side machine snapshot (the
    ``OracleEngine`` adapter path, and the restore side of a gem5-style
    checkpoint): same keys as :func:`reset_state`, but every field comes
    from the snapshot instead of power-on values, so the oracle can take
    over a run mid-flight.  Values are re-masked to uint64 defensively,
    and the fixed-size fields (regs, csrs, the by-level counters) are
    length-checked so a truncated snapshot fails loudly here rather than
    as an IndexError mid-run (``mem`` is legitimately variable-size)."""
    if len(snap["regs"]) != 32:
        raise ValueError(f"regs must have 32 entries, "
                         f"got {len(snap['regs'])}")
    if len(snap["csrs"]) != C.N_CSR:
        raise ValueError(f"csrs must have {C.N_CSR} entries, "
                         f"got {len(snap['csrs'])}")
    for k in ("exc_by_level", "int_by_level"):
        if len(snap[k]) != 3:
            raise ValueError(f"{k} must have 3 entries (M/HS/VS), "
                             f"got {len(snap[k])}")
    tlb_in = snap.get("tlb")
    if tlb_in is None:
        tlb = init_tlb()                  # pre-TLB snapshot: cold TLB
    else:
        if len(tlb_in["valid"]) != N_TLB:
            raise ValueError(f"tlb must have {N_TLB} entries, "
                             f"got {len(tlb_in['valid'])}")
        tlb = {
            "vpn": [u64(int(x)) for x in tlb_in["vpn"]],
            "ppn": [u64(int(x)) for x in tlb_in["ppn"]],
            "level": [int(x) for x in tlb_in["level"]],
            "perm": [int(x) for x in tlb_in["perm"]],
            "guest": [bool(x) for x in tlb_in["guest"]],
            "priv": [int(x) for x in tlb_in["priv"]],
            "sum": [bool(x) for x in tlb_in["sum"]],
            "mxr": [bool(x) for x in tlb_in["mxr"]],
            "valid": [bool(x) for x in tlb_in["valid"]],
            "ptr": int(tlb_in["ptr"]),
        }
    return {
        "pc": u64(int(snap["pc"])),
        "regs": [u64(int(x)) for x in snap["regs"]],
        "csrs": [u64(int(x)) for x in snap["csrs"]],
        "priv": int(snap["priv"]),
        "virt": bool(snap["virt"]),
        "mem": [u64(int(w)) for w in snap["mem"]],
        "tlb": tlb,
        "halted": bool(snap["halted"]),
        "done": bool(snap["done"]),
        "exit_code": u64(int(snap["exit_code"])),
        "console": int(snap["console"]),
        "instret": int(snap["instret"]),
        "instret_virt": int(snap["instret_virt"]),
        "exc_by_level": [int(x) for x in snap["exc_by_level"]],
        "int_by_level": [int(x) for x in snap["int_by_level"]],
        "pagefaults": int(snap["pagefaults"]),
        "walks": int(snap.get("walks", 0)),
        "ticks": int(snap["ticks"]),
        "timer_irqs": int(snap["timer_irqs"]),
        "ctx_switches": int(snap["ctx_switches"]),
        "events": set(),
    }


def init_csrs() -> List[int]:
    c = [0] * C.N_CSR
    c[C.R_MISA] = u64((2 << 62) | (1 << 7) | (1 << 8) | (1 << 12) |
                      (1 << 18) | (1 << 20))
    c[C.R_MIDELEG] = C.MIDELEG_FORCED
    for r in (C.R_MTIMECMP, C.R_STIMECMP, C.R_VSTIMECMP):
        c[r] = C.TIMER_DISARMED
    return c


# ---------------------------------------------------------------------------
# CSR file (port of csr.csr_read / csr.csr_write)
# ---------------------------------------------------------------------------

_SWAP_READ = {0x100: C.R_VSSTATUS, 0x105: C.R_VSTVEC, 0x140: C.R_VSSCRATCH,
              0x141: C.R_VSEPC, 0x142: C.R_VSCAUSE, 0x143: C.R_VSTVAL,
              0x180: C.R_VSATP}


def _csr_priv_vinst(csrs, a, priv, virt):
    minp = (a >> 8) & 3
    is_h = minp == 2
    req = 1 if is_h else minp
    vinst = virt and is_h and priv < 3
    vtvm = (csrs[C.R_HSTATUS] & C.HSTATUS_VTVM) != 0
    vinst = vinst or (virt and a == 0x180 and vtvm and priv < 3)
    return req, vinst


def csr_read(csrs, a, priv, virt):
    """→ (value, ok, vinst)."""
    mstatus = csrs[C.R_MSTATUS]
    mip, mie = csrs[C.R_MIP], csrs[C.R_MIE]
    mideleg, hideleg = csrs[C.R_MIDELEG], csrs[C.R_HIDELEG]

    val, known = 0, False
    if a == 0x100:
        val = (csrs[C.R_VSSTATUS] if virt else mstatus) & C.SSTATUS_MASK
        known = True
    elif a == 0x104:
        val = ((mie & hideleg & C.VS_INTERRUPTS) >> 1) if virt else \
            (mie & mideleg & C.S_INTERRUPTS)
        known = True
    elif a == 0x144:
        val = ((mip & hideleg & C.VS_INTERRUPTS) >> 1) if virt else \
            (mip & mideleg & C.S_INTERRUPTS)
        known = True
    elif a == 0x604:
        val, known = mie & C.HS_INTERRUPTS, True
    elif a == 0x644:
        val, known = mip & C.HS_INTERRUPTS, True
    elif a == 0x645:
        val, known = mip & C.VS_INTERRUPTS, True
    elif a == 0x204:
        val, known = (mie & hideleg & C.VS_INTERRUPTS) >> 1, True
    elif a == 0x244:
        val, known = (mip & hideleg & C.VS_INTERRUPTS) >> 1, True
    elif a == 0xC01:
        val = u64(csrs[C.R_MTIME] + csrs[C.R_HTIMEDELTA]) if virt else \
            csrs[C.R_MTIME]
        known = True
    elif a == 0x14D:
        val = csrs[C.R_VSTIMECMP] if virt else csrs[C.R_STIMECMP]
        known = True
    elif a in C.CSR_ADDR and C.CSR_ADDR[a] is not None:
        idx = C.CSR_ADDR[a]
        if virt and a in _SWAP_READ:
            idx = _SWAP_READ[a]
        val, known = csrs[idx], True

    req, vinst = _csr_priv_vinst(csrs, a, priv, virt)
    # time (0xC01) counter-enable gating
    tm_m = (csrs[C.R_MCOUNTEREN] & C.COUNTEREN_TM) != 0
    tm_h = (csrs[C.R_HCOUNTEREN] & C.COUNTEREN_TM) != 0
    tm_s = (csrs[C.R_SCOUNTEREN] & C.COUNTEREN_TM) != 0
    is_time = a == 0xC01
    time_ill = is_time and priv < 3 and (
        not tm_m or (not virt and priv == 0 and not tm_s))
    time_vinst = is_time and virt and tm_m and (
        not tm_h or (priv == 0 and not tm_s))
    vinst = vinst or time_vinst
    ok = known and priv >= req and not vinst and not time_ill
    return val, ok, vinst and known


def _wr(csrs, idx, val, mask):
    csrs[idx] = u64((csrs[idx] & ~mask) | (val & mask))


def csr_write(csrs, a, v, priv, virt):
    """→ (new_csrs(list), ok, vinst). Pure: returns a fresh list."""
    new = list(csrs)
    hideleg = csrs[C.R_HIDELEG]
    known = True
    full = M64

    if a == 0x300:
        _wr(new, C.R_MSTATUS, v, C.MSTATUS_WMASK)
    elif a == 0x100:
        _wr(new, C.R_VSSTATUS if virt else C.R_MSTATUS, v, C.SSTATUS_MASK)
    elif a == 0x200:
        _wr(new, C.R_VSSTATUS, v, C.SSTATUS_MASK)
    elif a == 0x104:
        if virt:
            _wr(new, C.R_MIE, (v << 1) & hideleg & C.VS_INTERRUPTS,
                C.VS_INTERRUPTS)
        else:
            _wr(new, C.R_MIE, v, C.S_INTERRUPTS)
    elif a == 0x204:
        _wr(new, C.R_MIE, (v << 1) & hideleg & C.VS_INTERRUPTS,
            C.VS_INTERRUPTS)
    elif a == 0x304:
        _wr(new, C.R_MIE, v, C.MIE_WMASK)
    elif a == 0x604:
        _wr(new, C.R_MIE, v, C.HS_INTERRUPTS)
    elif a == 0x144:
        if virt:
            _wr(new, C.R_MIP, (v << 1) & hideleg & C.IP_VSSIP, C.IP_VSSIP)
        else:
            _wr(new, C.R_MIP, v, C.IP_SSIP)
    elif a == 0x244:
        _wr(new, C.R_MIP, (v << 1) & hideleg & C.IP_VSSIP, C.IP_VSSIP)
    elif a == 0x344:
        _wr(new, C.R_MIP, v, C.MIP_WMASK)
    elif a == 0x645:
        _wr(new, C.R_MIP, v, C.HVIP_WMASK)
    elif a == 0x644:
        _wr(new, C.R_MIP, v, C.IP_VSSIP)
    elif a == 0x302:
        _wr(new, C.R_MEDELEG, v, C.MEDELEG_WMASK)
    elif a == 0x303:
        _wr(new, C.R_MIDELEG, v, C.MIDELEG_WMASK)
    elif a == 0x602:
        _wr(new, C.R_HEDELEG, v, C.HEDELEG_WMASK)
    elif a == 0x603:
        _wr(new, C.R_HIDELEG, v, C.HIDELEG_WMASK)
    elif a in _PLAIN_W:
        idx, mask = _PLAIN_W[a]
        _wr(new, idx, v, mask)
    elif a in _SWAP_W:
        sidx, vidx = _SWAP_W[a]
        mask = ~1 & M64 if a == 0x141 else full
        _wr(new, vidx if virt else sidx, v, mask)
    elif a in (0xE12, 0x301, 0xC01):
        pass                       # read-only / write-ignored
    else:
        known = False

    req, vinst = _csr_priv_vinst(csrs, a, priv, virt)
    read_only = (a >> 10) == 3
    ok = known and priv >= req and not vinst and not read_only
    return new, ok, vinst and known


_PLAIN_W = {0x305: (C.R_MTVEC, M64), 0x306: (C.R_MCOUNTEREN, M64),
            0x340: (C.R_MSCRATCH, M64), 0x341: (C.R_MEPC, ~1 & M64),
            0x342: (C.R_MCAUSE, M64), 0x343: (C.R_MTVAL, M64),
            0x34B: (C.R_MTVAL2, M64), 0x34A: (C.R_MTINST, M64),
            0x106: (C.R_SCOUNTEREN, M64),
            0x600: (C.R_HSTATUS, C.HSTATUS_WMASK),
            0x605: (C.R_HTIMEDELTA, M64), 0x606: (C.R_HCOUNTEREN, M64),
            0x607: (C.R_HGEIE, M64), 0x643: (C.R_HTVAL, M64),
            0x64A: (C.R_HTINST, M64), 0x680: (C.R_HGATP, M64),
            0x205: (C.R_VSTVEC, M64), 0x240: (C.R_VSSCRATCH, M64),
            0x241: (C.R_VSEPC, ~1 & M64), 0x242: (C.R_VSCAUSE, M64),
            0x243: (C.R_VSTVAL, M64), 0x280: (C.R_VSATP, M64),
            0x24D: (C.R_VSTIMECMP, M64)}
_SWAP_W = {0x105: (C.R_STVEC, C.R_VSTVEC), 0x140: (C.R_SSCRATCH,
           C.R_VSSCRATCH), 0x141: (C.R_SEPC, C.R_VSEPC),
           0x142: (C.R_SCAUSE, C.R_VSCAUSE), 0x143: (C.R_STVAL, C.R_VSTVAL),
           0x180: (C.R_SATP, C.R_VSATP),
           0x14D: (C.R_STIMECMP, C.R_VSTIMECMP)}


# ---------------------------------------------------------------------------
# translation (port of translate._walk / g_translate / translate)
# ---------------------------------------------------------------------------

def _acc_cause(acc):
    return (C.EXC_LACCESS if acc == ACC_R else
            C.EXC_SACCESS if acc == ACC_W else C.EXC_IACCESS)


def _pf_cause(acc, guest):
    if guest:
        return (C.EXC_LGUEST_PAGE_FAULT if acc == ACC_R else
                C.EXC_SGUEST_PAGE_FAULT if acc == ACC_W else
                C.EXC_IGUEST_PAGE_FAULT)
    return (C.EXC_LPAGE_FAULT if acc == ACC_R else
            C.EXC_SPAGE_FAULT if acc == ACC_W else C.EXC_IPAGE_FAULT)


def _leaf_ok(pte, acc, priv, sum_bit, mxr, require_u):
    r = (pte & PTE_R) != 0
    w = (pte & PTE_W) != 0
    x = (pte & PTE_X) != 0
    u = (pte & PTE_U) != 0
    a_ = (pte & PTE_A) != 0
    d = (pte & PTE_D) != 0
    r_eff = r or (mxr and x)
    perm = r_eff if acc == ACC_R else (w and r) if acc == ACC_W else x
    if require_u:
        u_ok = u
    elif priv == 0:
        u_ok = u
    else:
        u_ok = (not u) or (sum_bit and acc != ACC_X)
    ad_ok = a_ and (d if acc == ACC_W else True)
    return perm and u_ok and ad_ok


def _xres(pa=0, fault=False, cause=0, tval2=0, implicit=False,
          leaf=0, level=0):
    return {"pa": pa, "fault": fault, "cause": cause, "tval2": tval2,
            "implicit": implicit, "leaf": leaf, "level": level}


def _walk(mem, root, vpn2_bits, va, acc, priv, sum_bit, mxr, require_u,
          guest, pte_xlate=None, cause_acc=None):
    """Sequential Sv39(x4) walk; returns an _xres dict."""
    cause_acc = acc if cause_acc is None else cause_acc
    nbytes = len(mem) * 8
    base = root & M64
    for level in (2, 1, 0):
        shift = X.PAGE_SHIFT + 9 * level
        nbits = vpn2_bits if level == 2 else 9
        vpn = (va >> shift) & ((1 << nbits) - 1)
        pte_addr = u64(base + (vpn << 3))
        if pte_xlate is not None:
            g = pte_xlate(pte_addr)
            if g["fault"]:
                return _xres(fault=True, cause=g["cause"],
                             tval2=g["tval2"], implicit=True)
            pte_pa = g["pa"]
        else:
            pte_pa = pte_addr
        if pte_pa >= nbytes:
            return _xres(fault=True, cause=_acc_cause(cause_acc))
        pte = mem[pte_pa >> 3]
        valid = (pte & PTE_V) != 0
        reserved = (pte & PTE_W) != 0 and (pte & PTE_R) == 0
        if not valid or reserved:
            return _xres(fault=True, cause=_pf_cause(cause_acc, guest))
        if (pte & (PTE_R | PTE_X)) != 0:          # leaf
            ppn = (pte >> 10) & ((1 << 44) - 1)
            align_ok = level == 0 or (ppn & ((1 << (9 * level)) - 1)) == 0
            perm_ok = _leaf_ok(pte, acc, priv, sum_bit, mxr, require_u)
            if not align_ok or not perm_ok:
                return _xres(fault=True, cause=_pf_cause(cause_acc, guest))
            mask_low = (1 << shift) - 1
            pa = u64(((ppn << X.PAGE_SHIFT) & ~mask_low) | (va & mask_low))
            return _xres(pa=pa, leaf=pte, level=level)
        base = u64((pte >> 10 & ((1 << 44) - 1)) << X.PAGE_SHIFT)
    return _xres(fault=True, cause=_pf_cause(cause_acc, guest))


def g_translate(mem, hgatp, gpa, acc, mxr, cause_acc=None):
    """G-stage only (guest-physical → host-physical); _xres + tval2."""
    mode = (hgatp >> C.ATP_MODE_SHIFT) & 0xF
    if mode == 0:
        return _xres(pa=u64(gpa), leaf=ALL_PERM_PTE,
                     tval2=u64(gpa) >> 2) | {"g_leaf": ALL_PERM_PTE}
    root = (hgatp & C.ATP_PPN_MASK) << X.PAGE_SHIFT
    r = _walk(mem, root, 11, u64(gpa), acc, 0, False, mxr, True, True,
              cause_acc=cause_acc)
    r["tval2"] = u64(gpa) >> 2
    r["g_leaf"] = r["leaf"]
    return r


def translate(st, va, acc, force_virt=False, hlvx=False):
    """Full two-stage translation; returns a dict mirroring XResult."""
    csrs = st["csrs"]
    priv, virt = st["priv"], st["virt"]
    mem = st["mem"]
    va = u64(va)
    virt_eff = virt or force_virt
    status = csrs[C.R_VSSTATUS] if virt_eff else csrs[C.R_MSTATUS]
    sum_bit = (status & C.MSTATUS_SUM) != 0
    mxr = (status & C.MSTATUS_MXR) != 0
    acc_eff = ACC_X if hlvx else acc

    hgatp_eff = csrs[C.R_HGATP] if virt_eff else 0
    atp = csrs[C.R_VSATP] if virt_eff else csrs[C.R_SATP]
    mode = (atp >> C.ATP_MODE_SHIFT) & 0xF
    no_paging = mode == 0 or (priv >= 3 and not virt_eff)

    if no_paging:
        gpa_out, stage1 = va, None
        stage1_fault = False
    else:
        root = (atp & C.ATP_PPN_MASK) << X.PAGE_SHIFT
        stage1 = _walk(
            mem, root, 9, va, acc_eff, priv, sum_bit, mxr, False, False,
            pte_xlate=lambda p: g_translate(mem, hgatp_eff, p, ACC_R, mxr,
                                            cause_acc=acc))
        stage1_fault = stage1["fault"]
        gpa_out = stage1["pa"]

    if stage1_fault:
        return {"pa": 0, "fault": True, "cause": stage1["cause"],
                "tval": va, "tval2": stage1["tval2"],
                "gva": virt_eff, "implicit": stage1["implicit"],
                "leaf": 0, "g_leaf": 0, "level": 0}
    g = g_translate(mem, hgatp_eff, gpa_out, acc_eff, mxr, cause_acc=acc)
    if g["fault"]:
        return {"pa": 0, "fault": True, "cause": g["cause"], "tval": va,
                "tval2": g["tval2"], "gva": virt_eff, "implicit": False,
                "leaf": 0, "g_leaf": 0, "level": 0}
    # leaf PTEs + level feed the TLB fill (mirror of XResult.leaf_pte /
    # g_leaf_pte / level: a pseudo all-permission PTE stands in for a
    # disabled stage)
    return {"pa": g["pa"], "fault": False, "cause": 0, "tval": va,
            "tval2": 0, "gva": False, "implicit": False,
            "leaf": ALL_PERM_PTE if no_paging else stage1["leaf"],
            "g_leaf": g["g_leaf"],
            "level": 0 if no_paging else stage1["level"]}


# ---------------------------------------------------------------------------
# software-TLB model (port of tlb.lookup / insert / compose_perms /
# flush_where + isa.tlb_fill) — bit-exact so `walks` diffs clean
# ---------------------------------------------------------------------------

def _eff_ctx(csrs, virt_eff):
    """Effective (SUM, MXR) — vsstatus when virtualized, else mstatus."""
    status = csrs[C.R_VSSTATUS] if virt_eff else csrs[C.R_MSTATUS]
    return (status & C.MSTATUS_SUM) != 0, (status & C.MSTATUS_MXR) != 0


def _lvl_mask(level):
    """VPN bits that must match for an entry of this level (uint64)."""
    return ~((1 << (9 * level)) - 1) & M64


def tlb_lookup(tlb, va, virt, acc, priv, sum_bit, mxr):
    """→ (hit, pa, perm_ok); first-match-by-index like the machine's
    argmax.  ``pa``/``perm_ok`` are only meaningful when ``hit``."""
    vpn = u64(va) >> 12
    for i in range(N_TLB):
        lm = _lvl_mask(tlb["level"][i])
        if tlb["valid"][i] and tlb["guest"][i] == virt and \
                tlb["priv"][i] == priv and tlb["sum"][i] == sum_bit and \
                tlb["mxr"][i] == mxr and \
                (vpn & lm) == (tlb["vpn"][i] & lm):
            level = tlb["level"][i]
            low = (1 << (12 + 9 * level)) - 1
            pa = ((tlb["ppn"][i] << 12) & ~low & M64) | (u64(va) & low)
            want = PERM_R if acc == ACC_R else \
                PERM_W if acc == ACC_W else PERM_X
            return True, pa, (tlb["perm"][i] & want) != 0
    return False, 0, False


def _compose_perms(vs_pte, g_pte, priv, sum_bit, mxr):
    bits = 0
    for acc, bit in ((ACC_R, PERM_R), (ACC_W, PERM_W), (ACC_X, PERM_X)):
        if _leaf_ok(vs_pte, acc, priv, sum_bit, mxr, False) and \
                _leaf_ok(g_pte, acc, 0, False, mxr, True):
            bits |= bit
    return bits


def tlb_fill(st, va, xr, force_virt=False):
    """Insert the composed translation of a successful walk (mirror of
    ``isa.tlb_fill``): guest entries insert at 4K granularity, native
    entries keep their superpage level; context tags come from the
    access's effective (priv, SUM, MXR)."""
    tlb = st["tlb"]
    virt_eff = st["virt"] or force_virt
    sum_bit, mxr = _eff_ctx(st["csrs"], virt_eff)
    i = tlb["ptr"] % N_TLB
    tlb["vpn"][i] = u64(va) >> 12
    tlb["ppn"][i] = u64(xr["pa"]) >> 12
    tlb["level"][i] = 0 if virt_eff else xr["level"]
    tlb["perm"][i] = _compose_perms(xr["leaf"], xr["g_leaf"], st["priv"],
                                    sum_bit, mxr)
    tlb["guest"][i] = virt_eff
    tlb["priv"][i] = st["priv"]
    tlb["sum"][i] = sum_bit
    tlb["mxr"][i] = mxr
    tlb["valid"][i] = True
    tlb["ptr"] += 1


def tlb_flush(tlb, guest=False, native=False, va=None):
    """Invalidate entries: full-scope per tag class, or — with ``va`` —
    only the entries of that class whose cached translation covers the
    VA page (the rs1≠x0 scoped fence forms)."""
    for i in range(N_TLB):
        if not tlb["valid"][i]:
            continue
        in_class = guest if tlb["guest"][i] else native
        if not in_class:
            continue
        if va is not None:
            lm = _lvl_mask(tlb["level"][i])
            if ((u64(va) >> 12) & lm) != (tlb["vpn"][i] & lm):
                continue
        tlb["valid"][i] = False


def _event(st, tag):
    """Record an architectural-event signature for coverage bucketing
    (never part of the differential compare)."""
    ev = st.get("events")
    if ev is not None:
        ev.add(tag)


# ---------------------------------------------------------------------------
# trap routing (port of trap.route / take_trap / pending_interrupt)
# ---------------------------------------------------------------------------

def route(csrs, priv, virt, cause, is_int):
    bit = 1 << (cause & 63)
    mdeleg = csrs[C.R_MIDELEG] if is_int else csrs[C.R_MEDELEG]
    hdeleg = csrs[C.R_HIDELEG] if is_int else csrs[C.R_HEDELEG]
    to_hs_or_vs = (mdeleg & bit) != 0 and priv < 3
    to_vs = to_hs_or_vs and (hdeleg & bit) != 0 and virt
    return (1 if to_hs_or_vs else 3), to_vs


def take_trap(st, pc, cause, is_int, tval, tval2, gva, tinst):
    """Apply the trap in place; returns handled level (0 M, 1 HS, 2 VS)."""
    csrs = st["csrs"]
    priv, virt = st["priv"], st["virt"]
    tgt_priv, to_vs = route(csrs, priv, virt, cause, is_int)
    scause = u64(cause | C.INT_BIT) if is_int else u64(cause)

    if tgt_priv == 3:
        mst = csrs[C.R_MSTATUS]
        mst = (mst & ~C.MSTATUS_MPP) | ((priv << 11) & C.MSTATUS_MPP)
        if mst & C.MSTATUS_MIE:
            mst |= C.MSTATUS_MPIE
        else:
            mst &= ~C.MSTATUS_MPIE
        mst &= ~C.MSTATUS_MIE
        mst = mst | C.MSTATUS_MPV if virt else mst & ~C.MSTATUS_MPV
        mst = mst | C.MSTATUS_GVA if gva else mst & ~C.MSTATUS_GVA
        csrs[C.R_MSTATUS] = u64(mst)
        csrs[C.R_MEPC] = u64(pc)
        csrs[C.R_MCAUSE] = scause
        csrs[C.R_MTVAL] = u64(tval)
        csrs[C.R_MTVAL2] = u64(tval2)
        csrs[C.R_MTINST] = u64(tinst)
        st["pc"] = csrs[C.R_MTVEC] & ~3 & M64
        st["priv"], st["virt"] = 3, False
        return 0
    if to_vs:
        vst = csrs[C.R_VSSTATUS]
        vst = vst | C.MSTATUS_SPP if priv >= 1 else vst & ~C.MSTATUS_SPP
        if vst & C.MSTATUS_SIE:
            vst |= C.MSTATUS_SPIE
        else:
            vst &= ~C.MSTATUS_SPIE
        vst &= ~C.MSTATUS_SIE
        vs_cause = scause
        if is_int and 2 <= cause <= 10:
            vs_cause = u64(scause - 1)
        csrs[C.R_VSSTATUS] = u64(vst)
        csrs[C.R_VSEPC] = u64(pc)
        csrs[C.R_VSCAUSE] = vs_cause
        csrs[C.R_VSTVAL] = u64(tval)
        st["pc"] = csrs[C.R_VSTVEC] & ~3 & M64
        st["priv"], st["virt"] = 1, True
        return 2
    # to HS
    sst = csrs[C.R_MSTATUS]
    sst = sst | C.MSTATUS_SPP if priv >= 1 else sst & ~C.MSTATUS_SPP
    if sst & C.MSTATUS_SIE:
        sst |= C.MSTATUS_SPIE
    else:
        sst &= ~C.MSTATUS_SPIE
    sst &= ~C.MSTATUS_SIE
    hst = csrs[C.R_HSTATUS]
    hst = hst | C.HSTATUS_SPV if virt else hst & ~C.HSTATUS_SPV
    if virt:                           # SPVP only updates when V was 1
        hst = hst | C.HSTATUS_SPVP if priv >= 1 else hst & ~C.HSTATUS_SPVP
    hst = hst | C.HSTATUS_GVA if gva else hst & ~C.HSTATUS_GVA
    csrs[C.R_MSTATUS] = u64(sst)
    csrs[C.R_HSTATUS] = u64(hst)
    csrs[C.R_SEPC] = u64(pc)
    csrs[C.R_SCAUSE] = scause
    csrs[C.R_STVAL] = u64(tval)
    csrs[C.R_HTVAL] = u64(tval2)
    csrs[C.R_HTINST] = u64(tinst)
    st["pc"] = csrs[C.R_STVEC] & ~3 & M64
    st["priv"], st["virt"] = 1, False
    return 1


_PRIORITY = (11, 3, 7, 9, 1, 5, 12, 10, 2, 6)


def pending_interrupt(csrs, priv, virt):
    mip, mie = csrs[C.R_MIP], csrs[C.R_MIE]
    mideleg, hideleg = csrs[C.R_MIDELEG], csrs[C.R_HIDELEG]
    mstatus, vsstatus = csrs[C.R_MSTATUS], csrs[C.R_VSSTATUS]
    pend = mip & mie
    m_en = priv < 3 or (mstatus & C.MSTATUS_MIE) != 0
    s_en = priv < 1 or (priv == 1 and not virt and
                        (mstatus & C.MSTATUS_SIE) != 0)
    vs_en = (virt and priv < 1) or (virt and priv == 1 and
                                    (vsstatus & C.MSTATUS_SIE) != 0)
    for code in _PRIORITY:
        bit = 1 << code
        if not pend & bit:
            continue
        deleg_hs = (mideleg & bit) != 0
        deleg_vs = deleg_hs and (hideleg & bit) != 0
        if not deleg_hs:
            en = m_en
        elif deleg_vs:
            en = vs_en and virt
        else:
            en = s_en or (virt and priv <= 1)
        if en:
            return True, code
    return False, 0


# ---------------------------------------------------------------------------
# execute (port of isa.execute) — mutates st in place, returns fault dict
# ---------------------------------------------------------------------------

def _fault(cause, tval=0, tval2=0, gva=False, tinst=0):
    return {"cause": cause, "tval": u64(tval), "tval2": u64(tval2),
            "gva": bool(gva), "tinst": u64(tinst)}


def _mulhu(a, b):
    return ((a & M64) * (b & M64)) >> 64


def _divs(a, b):
    sa, sb = s64(a), s64(b)
    if sb == 0:
        return M64
    if sa == -(1 << 63) and sb == -1:
        return 1 << 63
    q = abs(sa) // abs(sb)
    return u64(-q if (sa < 0) != (sb < 0) else q)


def _rems(a, b):
    sa, sb = s64(a), s64(b)
    if sb == 0:
        return u64(a)
    if sa == -(1 << 63) and sb == -1:
        return 0
    r = abs(sa) % abs(sb)
    return u64(-r if sa < 0 else r)


def _word_extract(word, pa, size, uns):
    off = (pa & 7) * 8
    nbits = 8 << size
    v = (word >> off) & ((1 << nbits) - 1) if nbits < 64 else \
        u64(word >> off)
    return v if uns else sext(v, min(nbits, 64))


def _word_deposit(word, pa, val, size):
    off = (pa & 7) * 8
    nbits = 8 << size
    mask = M64 if nbits >= 64 else (1 << nbits) - 1
    return u64((word & ~(mask << off)) | ((val & mask) << off))


def decode_fields(word: int) -> Dict:
    """Independent instruction decoder: direct opcode tests and bit
    slicing, no lookup tables.  Returns the micro-op record shape of
    ``decode.decode_word`` with ``cls`` as a class *name* — the
    decode-table sweep tests (tests/hext/test_isa_props.py) diff the two
    decoders over random words, so a mis-built table entry and a wrong
    immediate mux both show up as a named mismatch."""
    word &= 0xFFFFFFFF
    op = word & 0x7F
    if op in (0x33, 0x13):
        cls, fmt = "alu", ("none" if op == 0x33 else "i")
    elif op in (0x3B, 0x1B):
        cls, fmt = "alu32", ("none" if op == 0x3B else "i")
    elif op == 0x37:
        cls, fmt = "lui", "u"
    elif op == 0x17:
        cls, fmt = "auipc", "u"
    elif op == 0x6F:
        cls, fmt = "jal", "j"
    elif op == 0x67:
        cls, fmt = "jalr", "i"
    elif op == 0x63:
        cls, fmt = "branch", "b"
    elif op == 0x03:
        cls, fmt = "load", "i"
    elif op == 0x23:
        cls, fmt = "store", "s"
    elif op == 0x73:
        cls, fmt = "system", "none"
    elif op == 0x0F:
        cls, fmt = "fence", "none"
    else:
        cls, fmt = "illegal", "none"
    if fmt == "i":
        imm = sext(word >> 20, 12)
    elif fmt == "s":
        imm = sext(((word >> 25) << 5) | ((word >> 7) & 0x1F), 12)
    elif fmt == "b":
        imm = sext((((word >> 31) & 1) << 12) | (((word >> 7) & 1) << 11) |
                   (((word >> 25) & 0x3F) << 5) |
                   (((word >> 8) & 0xF) << 1), 13)
    elif fmt == "u":
        imm = sext(word & 0xFFFFF000, 32)
    else:                                  # "j" or "none"
        imm = 0 if fmt == "none" else \
            sext((((word >> 31) & 1) << 20) |
                 (((word >> 12) & 0xFF) << 12) |
                 (((word >> 20) & 1) << 11) |
                 (((word >> 21) & 0x3FF) << 1), 21)
    return {
        "cls": cls,
        "rd": (word >> 7) & 31,
        "rs1": (word >> 15) & 31,
        "rs2": (word >> 20) & 31,
        "f3": (word >> 12) & 7,
        "f7": (word >> 25) & 0x7F,
        "imm": imm,
        "alu_imm": op in (0x13, 0x1B),
        "instr": word,
    }


def execute(st, instr):
    """One instruction on the oracle state. Returns (fault_or_None,
    retired).  On fault, st is left with only the machine's non-reverted
    side effects (console/done/exit_code accumulate pre-fault like the
    branchless core, which gates regs/pc/csrs/mem on `retired`)."""
    csrs = st["csrs"]
    regs = st["regs"]
    priv, virt = st["priv"], st["virt"]
    pc = st["pc"]
    mem = st["mem"]
    nbytes = len(mem) * 8

    op = instr & 0x7F
    rd = (instr >> 7) & 31
    f3 = (instr >> 12) & 7
    rs1 = (instr >> 15) & 31
    rs2i = (instr >> 20) & 31
    f7 = (instr >> 25) & 0x7F
    rv1, rv2 = regs[rs1], regs[rs2i]

    imm_i = sext(instr >> 20, 12)
    imm_s = sext(((instr >> 20) & ~0x1F) | ((instr >> 7) & 0x1F), 12)
    imm_b = sext((((instr >> 31) & 1) << 12) | (((instr >> 7) & 1) << 11) |
                 (((instr >> 25) & 0x3F) << 5) | (((instr >> 8) & 0xF) << 1),
                 13)
    imm_u = sext(instr & 0xFFFFF000, 32)
    imm_j = sext((((instr >> 31) & 1) << 20) | (((instr >> 12) & 0xFF) << 12)
                 | (((instr >> 20) & 1) << 11) |
                 (((instr >> 21) & 0x3FF) << 1), 21)

    new_pc = u64(pc + 4)
    wb = None                 # None → no writeback

    is_op, is_opi = op == 0x33, op == 0x13
    is_op32, is_opi32 = op == 0x3B, op == 0x1B

    # ---------------- ALU --------------------------------------------------
    if is_op or is_opi or is_op32 or is_opi32:
        alu_b = rv2 if (is_op or is_op32) else imm_i
        m_ext = (is_op or is_op32) and f7 == 1
        sh6, sh5 = alu_b & 0x3F, alu_b & 0x1F
        if is_op or is_opi:
            if m_ext:                       # M extension (is_op only)
                r = (u64(rv1 * alu_b) if f3 == 0 else
                     u64(_mulhu(rv1, alu_b)
                         - (alu_b if s64(rv1) < 0 else 0)
                         - (rv1 if s64(alu_b) < 0 else 0)) if f3 == 1 else
                     u64(_mulhu(rv1, alu_b)
                         - (alu_b if s64(rv1) < 0 else 0)) if f3 == 2 else
                     _mulhu(rv1, alu_b) if f3 == 3 else
                     _divs(rv1, alu_b) if f3 == 4 else
                     (M64 if alu_b == 0 else rv1 // alu_b) if f3 == 5 else
                     _rems(rv1, alu_b) if f3 == 6 else
                     (rv1 if alu_b == 0 else rv1 % alu_b))
            else:
                arith_sub = is_op and f7 == 0x20
                # OP-IMM srai: shamt[5] lives in f7 bit 0 → funct6 decode
                sr_arith = (f7 & 0x7E) == 0x20 if is_opi else f7 == 0x20
                r = (u64(rv1 - alu_b if arith_sub else rv1 + alu_b)
                     if f3 == 0 else
                     u64(rv1 << sh6) if f3 == 1 else
                     (1 if s64(rv1) < s64(alu_b) else 0) if f3 == 2 else
                     (1 if rv1 < alu_b else 0) if f3 == 3 else
                     rv1 ^ alu_b if f3 == 4 else
                     (u64(s64(rv1) >> sh6) if sr_arith else rv1 >> sh6)
                     if f3 == 5 else
                     rv1 | alu_b if f3 == 6 else rv1 & alu_b)
        else:                               # W forms
            a32, b32 = sext(rv1, 32), sext(alu_b, 32)
            if m_ext:                       # is_op32 only
                r = (sext(s64(a32) * s64(b32), 32) if f3 == 0 else
                     sext(_divs(sext(rv1, 32), sext(alu_b, 32)), 32)
                     if f3 == 4 else
                     (M64 if alu_b & 0xFFFFFFFF == 0 else
                      sext((rv1 & 0xFFFFFFFF) // (alu_b & 0xFFFFFFFF), 32))
                     if f3 == 5 else
                     sext(_rems(sext(rv1, 32), sext(alu_b, 32)), 64)
                     if f3 == 6 else
                     (sext(rv1, 32) if alu_b & 0xFFFFFFFF == 0 else
                      sext((rv1 & 0xFFFFFFFF) % (alu_b & 0xFFFFFFFF), 32)))
            else:
                sr_arith = f7 == 0x20
                if f3 == 0:
                    sub32 = is_op32 and f7 == 0x20
                    r = sext(s64(a32) - s64(b32) if sub32 else
                             s64(a32) + s64(b32), 32)
                elif f3 == 1:
                    r = sext(a32 << sh5, 32)
                elif f3 == 5:
                    r = (sext(u64(s64(sext(rv1, 32)) >> sh5), 32)
                         if sr_arith else
                         sext((a32 & 0xFFFFFFFF) >> sh5, 32))
                else:
                    r = sext(s64(a32) + s64(b32), 32)
        wb = u64(r)

    # ---------------- LUI/AUIPC/JAL/JALR/branches --------------------------
    elif op == 0x37:
        wb = imm_u
    elif op == 0x17:
        wb = u64(pc + imm_u)
    elif op == 0x6F:
        wb = u64(pc + 4)
        new_pc = u64(pc + imm_j)
    elif op == 0x67:
        wb = u64(pc + 4)
        new_pc = u64(rv1 + imm_i) & ~1
    elif op == 0x63:
        taken = (rv1 == rv2 if f3 == 0 else
                 rv1 != rv2 if f3 == 1 else
                 s64(rv1) < s64(rv2) if f3 == 4 else
                 s64(rv1) >= s64(rv2) if f3 == 5 else
                 rv1 < rv2 if f3 == 6 else rv1 >= rv2)
        if taken:
            new_pc = u64(pc + imm_b)

    # ---------------- loads / stores (incl. hlv/hsv) -----------------------
    elif op == 0x03 or op == 0x23 or (op == 0x73 and f3 == 4):
        is_sysx = op == 0x73
        is_hlv = is_sysx and (f7 & 1) == 0
        is_hsv = is_sysx and (f7 & 1) == 1
        is_store = op == 0x23 or is_hsv
        if is_sysx:
            hu = (csrs[C.R_HSTATUS] & C.HSTATUS_HU) != 0
            hx_legal = priv == 3 or (priv == 1 and not virt) or \
                (priv == 0 and not virt and hu)
            if virt:
                return _fault(C.EXC_VIRTUAL_INSTRUCTION, instr), False
            if not hx_legal:
                return _fault(C.EXC_ILLEGAL, instr), False
            addr = rv1
            size = (f7 >> 1) & 3
            uns = (rs2i & 1) == 1
            hlvx = is_hlv and rs2i == 3
            force_virt = True
        else:
            addr = u64(rv1 + (imm_s if is_store else imm_i))
            size = f3 & 3
            uns = (f3 & 4) != 0
            hlvx, force_virt = False, False

        if addr & ((1 << size) - 1):
            cause = C.EXC_SADDR_MISALIGNED if is_store else \
                C.EXC_LADDR_MISALIGNED
            return _fault(cause, addr, gva=virt or force_virt), False
        acc = ACC_W if is_store else ACC_R
        # TLB fast path (mirror of machine.execute): a usable hit skips
        # the walk and uses the CACHED composed pa — stale entries after
        # an unfenced PTE rewrite are architecturally visible, exactly
        # like the machine.  HLVX never uses a hit (cached perms carry no
        # execute-for-read override).
        virt_d = virt or force_virt
        sum_d, mxr_d = _eff_ctx(csrs, virt_d)
        hit, tpa, perm_ok = tlb_lookup(st["tlb"], addr, virt_d, acc, priv,
                                       sum_d, mxr_d)
        use_d = hit and perm_ok and not hlvx
        if use_d:
            xr = {"pa": tpa, "fault": False, "cause": 0, "tval": addr,
                  "tval2": 0, "gva": False, "implicit": False}
        else:
            xr = translate(st, addr, acc, force_virt=force_virt, hlvx=hlvx)
        if xr["fault"]:
            is_gpf = xr["cause"] in (C.EXC_LGUEST_PAGE_FAULT,
                                     C.EXC_SGUEST_PAGE_FAULT)
            tinst = 0
            if is_gpf:
                tinst = (0x2020 if is_store else 0x2000) if xr["implicit"] \
                    else instr & ~0xF8000
            return _fault(xr["cause"], xr["tval"], xr["tval2"],
                          xr["gva"] or force_virt, tinst), False
        pa = xr["pa"]
        pa_word = pa & ~7
        is_console = pa_word == MMIO_CONSOLE
        is_done_io = pa_word == MMIO_DONE
        is_ctxsw_io = pa_word == MMIO_CTXSW
        is_mtimecmp_io = pa_word == MMIO_MTIMECMP
        is_mtime_io = pa_word == MMIO_MTIME
        is_mmio = (is_console or is_done_io or is_ctxsw_io or
                   is_mtimecmp_io or is_mtime_io)
        mmio_readable = is_mtimecmp_io or is_mtime_io
        if (not is_mmio and pa >= nbytes) or \
                (not is_store and is_mmio and not mmio_readable):
            cause = C.EXC_SACCESS if is_store else C.EXC_LACCESS
            return _fault(cause, addr, gva=virt or force_virt), False
        # the access will retire → commit the data-side fill when we
        # walked (machine: mem_ok & walked; MMIO PAs insert too)
        if not use_d:
            tlb_fill(st, addr, xr, force_virt=force_virt)
        if is_store:
            if is_mtimecmp_io:
                csrs[C.R_MTIMECMP] = _word_deposit(
                    csrs[C.R_MTIMECMP], pa, rv2, size)
            elif is_mtime_io:
                csrs[C.R_MTIME] = _word_deposit(
                    csrs[C.R_MTIME], pa, rv2, size)
            elif is_console:
                st["console"] += 1
            elif is_done_io:
                st["done"] = True
                st["exit_code"] = rv2
            elif is_ctxsw_io:
                st["ctx_switches"] += 1
            else:
                w = pa >> 3
                mem[w] = _word_deposit(mem[w], pa, rv2, size)
        else:
            if is_mtime_io:
                wb = _word_extract(csrs[C.R_MTIME], pa, size, uns)
            elif is_mtimecmp_io:
                wb = _word_extract(csrs[C.R_MTIMECMP], pa, size, uns)
            else:
                wb = _word_extract(mem[pa >> 3], pa, size, uns)

    # ---------------- SYSTEM: CSR / priv ops -------------------------------
    elif op == 0x73 and f3 != 0:
        csr_addr = (instr >> 20) & 0xFFF
        csr_wdata = rs1 if f3 >= 5 else rv1
        old, r_ok, r_vinst = csr_read(csrs, csr_addr, priv, virt)
        wval = (csr_wdata if (f3 & 3) == 1 else
                old | csr_wdata if (f3 & 3) == 2 else old & ~csr_wdata & M64)
        do_write = (f3 & 3) == 1 or rs1 != 0
        csrs_w, w_ok, w_vinst = csr_write(csrs, csr_addr, wval, priv, virt)
        csr_ok = r_ok and (w_ok if do_write else True)
        if r_vinst or (do_write and w_vinst):
            return _fault(C.EXC_VIRTUAL_INSTRUCTION, instr), False
        if not csr_ok:
            return _fault(C.EXC_ILLEGAL, instr), False
        if do_write:
            st["csrs"] = csrs_w
            # satp/vsatp/hgatp writes invalidate every cached translation
            if csr_addr in (0x180, 0x280, 0x680):
                tlb_flush(st["tlb"], guest=True, native=True)
                _event(st, ("atp", csr_addr, virt, priv))
        wb = old

    elif op == 0x73:                       # f3 == 0: priv ops
        mstatus = csrs[C.R_MSTATUS]
        hstatus = csrs[C.R_HSTATUS]
        if instr == 0x00000073:            # ecall
            cause = (C.EXC_ECALL_M if priv == 3 else
                     C.EXC_ECALL_U if priv == 0 else
                     C.EXC_ECALL_VS if virt else C.EXC_ECALL_S)
            return _fault(cause), False
        elif instr == 0x00100073:          # ebreak
            return _fault(C.EXC_BREAK, pc), False
        elif instr == 0x10200073:          # sret
            tsr = (mstatus & C.MSTATUS_TSR) != 0
            vtsr = (hstatus & C.HSTATUS_VTSR) != 0
            if priv == 0 or (tsr and priv == 1 and not virt):
                return _fault(C.EXC_ILLEGAL, instr), False
            if virt and (vtsr or priv == 0):
                return _fault(C.EXC_VIRTUAL_INSTRUCTION, instr), False
            if virt:
                vst = csrs[C.R_VSSTATUS]
                vspp = 1 if vst & C.MSTATUS_SPP else 0
                if vst & C.MSTATUS_SPIE:
                    vst |= C.MSTATUS_SIE
                else:
                    vst &= ~C.MSTATUS_SIE
                vst = (vst | C.MSTATUS_SPIE) & ~C.MSTATUS_SPP
                csrs[C.R_VSSTATUS] = u64(vst)
                st["priv"] = vspp
                new_pc = csrs[C.R_VSEPC]
            else:
                spp = 1 if mstatus & C.MSTATUS_SPP else 0
                mst = mstatus
                if mst & C.MSTATUS_SPIE:
                    mst |= C.MSTATUS_SIE
                else:
                    mst &= ~C.MSTATUS_SIE
                mst = (mst | C.MSTATUS_SPIE) & ~C.MSTATUS_SPP
                csrs[C.R_MSTATUS] = u64(mst)
                csrs[C.R_HSTATUS] = u64(hstatus & ~C.HSTATUS_SPV)
                st["priv"] = spp
                st["virt"] = (hstatus & C.HSTATUS_SPV) != 0
                new_pc = csrs[C.R_SEPC]
        elif instr == 0x30200073:          # mret
            if priv != 3:
                return _fault(C.EXC_ILLEGAL, instr), False
            mpp = (mstatus >> 11) & 3
            mpv = (mstatus & C.MSTATUS_MPV) != 0
            mst = mstatus
            if mst & C.MSTATUS_MPIE:
                mst |= C.MSTATUS_MIE
            else:
                mst &= ~C.MSTATUS_MIE
            mst = (mst | C.MSTATUS_MPIE) & ~C.MSTATUS_MPP & ~C.MSTATUS_MPV
            csrs[C.R_MSTATUS] = u64(mst)
            st["priv"] = mpp
            st["virt"] = mpp != 3 and mpv
            new_pc = csrs[C.R_MEPC]
        elif instr == 0x10500073:          # wfi
            tw = (mstatus & C.MSTATUS_TW) != 0
            vtw = (hstatus & C.HSTATUS_VTW) != 0
            if (tw and priv < 3) or (priv == 0 and not virt):
                return _fault(C.EXC_ILLEGAL, instr), False
            if virt and (vtw or priv == 0):
                return _fault(C.EXC_VIRTUAL_INSTRUCTION, instr), False
            if not csrs[C.R_MIP] & csrs[C.R_MIE]:
                st["halted"] = True
                _event(st, ("wfi", virt, priv))
        elif f7 in (0x11, 0x31):           # hfence.vvma / hfence.gvma
            if virt:
                return _fault(C.EXC_VIRTUAL_INSTRUCTION, instr), False
            if priv == 0:
                return _fault(C.EXC_ILLEGAL, instr), False
            if f7 == 0x31:
                # gvma's rs1 is a guest-physical address; entries are
                # VA-tagged, so it is a conservative full guest flush
                tlb_flush(st["tlb"], guest=True)
                _event(st, ("fence", "gvma", False, virt, priv))
            else:
                tlb_flush(st["tlb"], guest=True,
                          va=rv1 if rs1 != 0 else None)
                _event(st, ("fence", "vvma", rs1 != 0, virt, priv))
        elif f7 == 0x09:                   # sfence.vma
            if virt and priv == 0:
                return _fault(C.EXC_VIRTUAL_INSTRUCTION, instr), False
            if not virt and priv == 0:
                return _fault(C.EXC_ILLEGAL, instr), False
            # VS-mode sfence flushes the guest's own (guest-tagged)
            # entries; HS/M-mode flushes native ones.  rs1≠x0 scopes the
            # invalidation to the one VA page in rs1.
            tlb_flush(st["tlb"], guest=virt, native=not virt,
                      va=rv1 if rs1 != 0 else None)
            _event(st, ("fence", "sfence", rs1 != 0, virt, priv))
        # any other f3==0 encoding retires as a no-op (machine quirk)

    elif op == 0x0F:
        pass                               # FENCE / FENCE.I: no-op
    else:
        return _fault(C.EXC_ILLEGAL, instr), False

    if wb is not None and rd != 0:
        regs[rd] = u64(wb)
    st["pc"] = new_pc
    return None, True


# ---------------------------------------------------------------------------
# step (port of machine.step) and the run loop
# ---------------------------------------------------------------------------

def _advance_timers(csrs):
    mtime = u64(csrs[C.R_MTIME] + 1)
    csrs[C.R_MTIME] = mtime
    mip = csrs[C.R_MIP]
    vs_time = u64(mtime + csrs[C.R_HTIMEDELTA])
    for cmp_idx, bit, now in ((C.R_MTIMECMP, C.IP_MTIP, mtime),
                              (C.R_STIMECMP, C.IP_STIP, mtime),
                              (C.R_VSTIMECMP, C.IP_VSTIP, vs_time)):
        cmpv = csrs[cmp_idx]
        if cmpv != C.TIMER_DISARMED:
            mip = mip | bit if now >= cmpv else mip & ~bit
    csrs[C.R_MIP] = mip


def _count_trap(st, cause, is_int, level):
    key = "int_by_level" if is_int else "exc_by_level"
    st[key][level] += 1
    if is_int:
        if cause in (5, 6, 7):
            st["timer_irqs"] += 1
    elif cause in (C.EXC_IPAGE_FAULT, C.EXC_LPAGE_FAULT, C.EXC_SPAGE_FAULT,
                   C.EXC_IGUEST_PAGE_FAULT, C.EXC_LGUEST_PAGE_FAULT,
                   C.EXC_SGUEST_PAGE_FAULT):
        st["pagefaults"] += 1


def step(st):
    """One tick: timers → CheckInterrupts → fetch → execute → fault."""
    if st["done"]:
        return
    st["ticks"] += 1
    _advance_timers(st["csrs"])
    csrs = st["csrs"]

    take, cause = pending_interrupt(csrs, st["priv"], st["virt"])
    if take:
        virt_b, priv_b = st["virt"], st["priv"]
        lvl = take_trap(st, st["pc"], cause, True, 0, 0, False, 0)
        st["halted"] = False
        _count_trap(st, cause, True, lvl)
        _event(st, ("int", cause, lvl, virt_b, priv_b))
        return

    if st["halted"]:
        if not csrs[C.R_MIP] & csrs[C.R_MIE]:
            return                       # stay idle (timers advanced)
        st["halted"] = False             # WFI wake: resume executing

    # fetch: TLB fast path first (mirror of machine.fetch).  A miss — or
    # a hit whose cached perms deny execute — walks and counts in
    # `walks`; a successful walk fills unless the fetch faults/OOBs.
    pc = st["pc"]
    virt_b, priv_b = st["virt"], st["priv"]
    sum_f, mxr_f = _eff_ctx(csrs, virt_b)
    hit, tpa, perm_ok = tlb_lookup(st["tlb"], pc, virt_b, ACC_X, priv_b,
                                   sum_f, mxr_f)
    use_f = hit and perm_ok
    if use_f:
        xr = {"pa": tpa, "fault": False, "cause": 0, "tval": pc,
              "tval2": 0, "gva": False, "implicit": False}
    else:
        st["walks"] += 1
        xr = translate(st, pc, ACC_X)
    nbytes = len(st["mem"]) * 8
    if xr["fault"] or xr["pa"] >= nbytes:
        if xr["fault"]:
            f = _fault(xr["cause"], xr["tval"], xr["tval2"], xr["gva"])
        else:
            f = _fault(C.EXC_IACCESS, pc, gva=st["virt"])
        lvl = take_trap(st, pc, f["cause"], False, f["tval"], f["tval2"],
                        f["gva"], f["tinst"])
        st["halted"] = False
        _count_trap(st, f["cause"], False, lvl)
        _event(st, ("exc", f["cause"], lvl, virt_b, priv_b))
        return
    if not use_f:
        tlb_fill(st, pc, xr)             # fetch-side fill commits even
    word = st["mem"][xr["pa"] >> 3]      # if execute faults below
    instr = (word >> 32) if xr["pa"] & 4 else word & 0xFFFFFFFF

    virt_before = st["virt"]          # instret_virt counts the mode the
    fault, retired = execute(st, instr)   # instruction *entered* in
    if retired:
        st["instret"] += 1
        if virt_before:
            st["instret_virt"] += 1
    if fault is not None:
        lvl = take_trap(st, pc, fault["cause"], False, fault["tval"],
                        fault["tval2"], fault["gva"], fault["tinst"])
        st["halted"] = False
        _count_trap(st, fault["cause"], False, lvl)
        _event(st, ("exc", fault["cause"], lvl, virt_b, priv_b))


def run(image, max_ticks: int) -> Dict:
    """Boot `image` and run until done or `max_ticks` ticks elapse."""
    st = reset_state(image)
    for _ in range(max_ticks):
        step(st)
        if st["done"]:
            break
    return st
