"""Two-stage-aware TLB (paper §3.5 challenge (3)).

Each entry caches a *composed* translation (VPN → host PFN) plus the
permission bits derived from BOTH the guest (VS-stage) leaf PTE and the host
(G-stage) leaf PTE — the paper's observation that the guest's PFN may carry
different permissions than the supervisor's PFN. Entries created in
virtualization mode are tagged ``guest`` so that ``hfence.{vvma,gvma}``
invalidates only them while ``sfence.vma`` touches only native entries.
Megapage/gigapage leaves insert with their level so neighbours hit too.

Entries additionally carry the privilege context (priv/SUM/MXR) their
permission bits were composed under; a lookup from a different context
misses instead of reusing a stale permission verdict (e.g. a U-mode access
hitting an S-mode entry).

``lookup`` returns a :class:`TlbVerdict` — a complete (hit, pa, perm_ok)
record.  ``verdict.use`` is the machine's fast-path predicate: a usable
hit never needs the two-stage walk graph at all (machine.step only
materializes the walk when some hart in the batch misses — DESIGN.md §7).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.hext import translate as X
from repro.core.hext.bits import u64 as _u

U64 = jnp.uint64
N_TLB = 16

PERM_R, PERM_W, PERM_X = 1, 2, 4


class TlbVerdict(NamedTuple):
    """Complete TLB lookup outcome for one access.

    ``hit``: an entry matched (VPN + guest tag + privilege context);
    ``pa``: the composed host-physical address of the matched entry
    (garbage when ``hit`` is false — gate on ``hit``);
    ``perm_ok``: the cached composed permissions allow this access.

    ``use`` is the short-circuit predicate: the translation is fully
    resolved by the TLB and the walk can be skipped.  A hit with bad
    permissions still walks — the walk, not the TLB, determines the
    architectural fault cause.
    """

    hit: jnp.ndarray
    pa: jnp.ndarray
    perm_ok: jnp.ndarray

    @property
    def use(self):
        return self.hit & self.perm_ok


def init_tlb():
    return {
        "vpn": jnp.zeros((N_TLB,), U64),
        "ppn": jnp.zeros((N_TLB,), U64),
        "level": jnp.zeros((N_TLB,), jnp.int32),
        "perm": jnp.zeros((N_TLB,), jnp.int32),
        "guest": jnp.zeros((N_TLB,), bool),
        # privilege context the cached perms were composed under — a lookup
        # from a different (priv, SUM, MXR) must miss, otherwise e.g. a
        # U-mode access could reuse an S-mode entry's permission verdict
        "priv": jnp.zeros((N_TLB,), jnp.int32),
        "sum": jnp.zeros((N_TLB,), bool),
        "mxr": jnp.zeros((N_TLB,), bool),
        "valid": jnp.zeros((N_TLB,), bool),
        "ptr": jnp.zeros((), jnp.int32),
    }


def _vpn_mask(level):
    """VPN bits that must match for an entry of this level."""
    return ~((_u(1) << (level.astype(U64) * _u(9))) - _u(1))


def lookup(tlb, va, virt, acc, priv, sum_bit, mxr) -> TlbVerdict:
    """→ :class:`TlbVerdict` (unpacks as the legacy ``(hit, pa, perm_ok)``
    triple).  Matches only entries whose cached permission context
    (priv/SUM/MXR at insert time) equals the current access's."""
    vpn = jnp.asarray(va, U64) >> _u(12)
    lm = _vpn_mask(tlb["level"])
    match = tlb["valid"] & (tlb["guest"] == virt) & \
        (tlb["priv"] == priv) & (tlb["sum"] == sum_bit) & \
        (tlb["mxr"] == mxr) & \
        ((vpn & lm) == (tlb["vpn"] & lm))
    hit = jnp.any(match)
    idx = jnp.argmax(match)
    level = tlb["level"][idx]
    in_page = jnp.asarray(va, U64) & ((_u(1) << (_u(12) +
                                       level.astype(U64) * _u(9)))
                                      - _u(1))
    base = tlb["ppn"][idx] << _u(12)
    base = base & ~((_u(1) << (_u(12) + level.astype(U64) *
                                     _u(9))) - _u(1))
    pa = base | in_page
    want = jnp.where(acc == X.ACC_R, PERM_R,
                     jnp.where(acc == X.ACC_W, PERM_W, PERM_X))
    perm_ok = (tlb["perm"][idx] & want) != 0
    return TlbVerdict(hit=hit, pa=pa, perm_ok=perm_ok)


def compose_perms(vs_pte, g_pte, priv, sum_bit, mxr):
    """Permission bits of the composed entry — guest PTE perms AND host PTE
    perms (paper: store guest PTE permission bits alongside the host's)."""
    bits = jnp.zeros((), jnp.int32)
    for acc, bit in ((X.ACC_R, PERM_R), (X.ACC_W, PERM_W), (X.ACC_X, PERM_X)):
        a = jnp.asarray(acc, U64)
        ok1 = X._leaf_ok(vs_pte, a, priv, sum_bit, mxr, jnp.zeros((), bool))
        ok2 = X._leaf_ok(g_pte, a, jnp.zeros((), jnp.int32),
                         jnp.zeros((), bool), mxr, jnp.ones((), bool))
        bits = bits | jnp.where(ok1 & ok2, bit, 0)
    return bits


def insert(tlb, va, pa, level, perm, virt, priv, sum_bit, mxr):
    i = tlb["ptr"] % N_TLB
    t = dict(tlb)
    t["vpn"] = tlb["vpn"].at[i].set(jnp.asarray(va, U64) >> _u(12))
    t["ppn"] = tlb["ppn"].at[i].set(jnp.asarray(pa, U64) >> _u(12))
    t["level"] = tlb["level"].at[i].set(level)
    t["perm"] = tlb["perm"].at[i].set(perm)
    t["guest"] = tlb["guest"].at[i].set(virt)
    t["priv"] = tlb["priv"].at[i].set(priv)
    t["sum"] = tlb["sum"].at[i].set(sum_bit)
    t["mxr"] = tlb["mxr"].at[i].set(mxr)
    t["valid"] = tlb["valid"].at[i].set(True)
    t["ptr"] = tlb["ptr"] + 1
    return t


def _va_match(tlb, va):
    """Entries whose cached translation covers `va` (superpage-aware:
    an entry invalidates if the fence VA falls anywhere in its reach)."""
    vpn = jnp.asarray(va, U64) >> _u(12)
    lm = _vpn_mask(tlb["level"])
    return (vpn & lm) == (tlb["vpn"] & lm)


def flush(tlb, guest_only=False, native_only=False, va=None):
    """Host-python flush: full-scope per tag class, or — with ``va`` —
    only the entries of that class that translate the given VA page
    (the rs1≠x0 form of sfence.vma / hfence.vvma)."""
    keep = jnp.zeros((N_TLB,), bool)
    if guest_only:
        keep = ~tlb["guest"]       # hfence: drop guest entries only
    if native_only:
        keep = tlb["guest"]        # sfence: drop native entries only
    if va is not None:
        keep = keep | ~_va_match(tlb, va)
    t = dict(tlb)
    t["valid"] = tlb["valid"] & keep
    return t


def flush_where(tlb, cond_guest, cond_native,
                cond_guest_addr=None, cond_native_addr=None, va=None):
    """Traced flush; all conditions are traced bools.

    ``cond_guest``/``cond_native`` are the full-scope flushes (rs1=x0,
    atp writes).  ``cond_guest_addr``/``cond_native_addr`` are the
    address-targeted forms (rs1≠x0): only entries of that tag class
    whose cached translation covers the ``va`` page are dropped, so a
    guest flushing one page no longer nukes every warm entry."""
    drop = (tlb["guest"] & cond_guest) | (~tlb["guest"] & cond_native)
    if cond_guest_addr is not None:
        vm = _va_match(tlb, va)
        drop = drop | (tlb["guest"] & cond_guest_addr & vm) | \
            (~tlb["guest"] & cond_native_addr & vm)
    t = dict(tlb)
    t["valid"] = tlb["valid"] & ~drop
    return t
