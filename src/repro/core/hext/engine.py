"""Pluggable execution backends for the hext simulator (DESIGN.md §3).

gem5 exposes swappable CPU models behind one plug point; this module is
the same seam for the hext fleet.  An :class:`Engine` advances a (possibly
batched) ``HartState`` by up to ``max_ticks`` ticks and returns the final
state — everything else about *how* (one jitted while-loop, a pmap across
devices, a pure-Python interpreter) is backend-private.  Three backends
are registered:

* ``"jit"`` — :class:`JitEngine`, the donated on-device ``lax.while_loop``
  over chunked scans (the engine ``Fleet`` always used; extracted here
  from ``sim.run_on_device``).
* ``"sharded"`` — :class:`ShardedEngine`, ``jax.pmap`` over
  ``jax.devices()`` with the fleet padded to a device multiple.  Each
  device runs the same while-loop on its shard, so per-hart results are
  bit-identical to ``"jit"``.  On a single device it falls back to
  :class:`JitEngine` (same executable, no pmap overhead).
* ``"oracle"`` — :class:`OracleEngine`, the pure-Python architectural
  oracle (``repro.core.hext.oracle``) behind the same typed interface.
  This makes differential runs first-class: boot the same workloads twice
  (``engine="jit"`` / ``engine="oracle"``) and :func:`diff_states` the
  results — the torture harness (DESIGN.md §5) is now just a user of this
  path.  The oracle models the software TLB (scoped fences included) and
  the ``walks`` counter bit-exactly, so the diff exclusion list is empty.

Engines are resolved by name through the registry (``resolve``); any
object with a ``run(state, max_ticks, chunk=...)`` method is accepted
directly, so downstream experiments (async streams, multi-host, caching)
plug in without touching ``Fleet``.

All entry points own the x64 context, like the facade they serve.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Optional, Protocol, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hext import csr as C
from repro.core.hext import machine as _machine
from repro.core.hext import oracle as _oracle

U64 = jnp.uint64
MASK64 = (1 << 64) - 1

__all__ = ["Engine", "JitEngine", "ShardedEngine", "OracleEngine",
           "ENGINES", "register_engine", "resolve", "diff_states",
           "diff_arrays", "state_arrays", "DIFF_SCALARS",
           "DIFF_COUNTERS"]

# The single definition of the differential comparison scope, shared by
# `diff_states` and the torture harness's array-based diff so the two
# paths can never silently drift apart.  The oracle models the software
# TLB, so `walks` is compared exactly; the exclusion list is empty.
DIFF_SCALARS = ("pc", "priv", "virt", "halted", "done", "exit_code",
                "console")
DIFF_COUNTERS = ("instret", "instret_virt", "pagefaults", "walks",
                 "ticks", "timer_irqs", "ctx_switches")


def _x64():
    return jax.experimental.enable_x64()


def _n_chunks(max_ticks: int, chunk: int) -> int:
    """Tick budgets round UP to whole chunk-scans (legacy loop semantics)."""
    return -(-int(max_ticks) // int(chunk))


def _is_batched(state) -> bool:
    return state.counters.done.ndim == 1


# ---------------------------------------------------------------------------
# the shared on-device run loop (used by JitEngine and, per shard, by
# ShardedEngine): while_loop over chunked scans, gated on all(done)
# ---------------------------------------------------------------------------

def _run_impl(state, n_chunks, chunk: int, ips: int = 1):
    """`n_chunks` chunk-scans max, early exit once every hart reports done
    (no per-chunk host sync).  Only `chunk`/`ips` are static — different
    tick budgets reuse the same executable.

    A batched state runs ``machine.step_batched`` directly: the pipeline's
    batch-level ``lax.cond`` fast paths (walk skip, SYSTEM skip, trap
    skip) survive only as real HLO conditionals — wrapping the scalar step
    in ``vmap`` would lower every cond to compute-both-branches and give
    back the cost the pipeline removed.

    ``ips`` (instrs_per_step) unrolls that many architectural ticks into
    one scan element, shrinking the scan to ``chunk // ips`` elements —
    less per-element scan/dispatch overhead at the price of a bigger
    step graph.  Tick semantics are unchanged (each chunk-scan still
    advances exactly ``chunk`` ticks); results are bit-identical by
    construction because the unrolled body is the same step composed."""
    batched = _is_batched(state)
    if batched:
        def step_fn(s):
            return type(s).from_raw(_machine.step_batched(s.to_raw()))
    else:
        def step_fn(s):
            return s.step()

    def scan_body(s, _):
        for _ in range(ips):
            s = step_fn(s)
        return s, None

    def cond(carry):
        s, i = carry
        return (i < n_chunks) & ~jnp.all(s.counters.done)

    def body(carry):
        s, i = carry
        s = jax.lax.scan(scan_body, s, None, length=chunk // ips)[0]
        return s, i + jnp.ones((), jnp.int32)

    state, _ = jax.lax.while_loop(cond, body,
                                  (state, jnp.zeros((), jnp.int32)))
    return state


def _check_ips(chunk: int, ips: int) -> int:
    ips = int(ips)
    if ips < 1 or int(chunk) % ips != 0:
        raise ValueError(
            f"instrs_per_step must divide chunk: chunk={chunk} ips={ips}")
    return ips


_run_jit_donating = jax.jit(_run_impl, static_argnums=(2, 3),
                            donate_argnums=(0,))
_run_jit = jax.jit(_run_impl, static_argnums=(2, 3))


# ---------------------------------------------------------------------------
# Engine protocol + registry
# ---------------------------------------------------------------------------

@runtime_checkable
class Engine(Protocol):
    """An execution backend: advance `state` by up to `max_ticks` ticks.

    Must return a state of the same pytree structure; whether the input
    buffers are donated/invalidated is backend-private (``Fleet`` treats
    them as invalidated either way — see the run-generation guard)."""

    name: str

    def run(self, state, max_ticks: int, chunk: int = 4096):
        ...


ENGINES: Dict[str, Callable[[], "Engine"]] = {}


def register_engine(name: str, factory: Callable[[], "Engine"]) -> None:
    """Register a backend under `name` (`Fleet.boot(..., engine=name)`)."""
    ENGINES[name] = factory


def resolve(engine: Any) -> "Engine":
    """None → the default JitEngine; str → registry lookup; any object
    with a ``run`` method is taken as an engine instance."""
    if engine is None:
        return JitEngine()
    if isinstance(engine, str):
        if engine not in ENGINES:
            raise ValueError(
                f"unknown engine {engine!r}; registered: "
                f"{sorted(ENGINES)}")
        return ENGINES[engine]()
    if callable(getattr(engine, "run", None)):
        return engine
    raise TypeError(f"engine must be None, a registered name, or an "
                    f"object with .run(state, max_ticks); got {engine!r}")


# ---------------------------------------------------------------------------
# JitEngine — the donated single-executable while-loop
# ---------------------------------------------------------------------------

class JitEngine:
    """The default backend: one jitted on-device while-loop.

    With ``donate`` (Fleet's mode) the input buffers are donated and
    updated in place, so the input state must not be reused after `run`;
    ``donate=False`` serves callers that keep a reference to the input
    (the `run_on_device` compat wrapper exposes this)."""

    name = "jit"

    def __init__(self, donate: bool = True, instrs_per_step: int = 1):
        self._donate = donate
        self._ips = int(instrs_per_step)

    def run(self, state, max_ticks: int, chunk: int = 4096):
        ips = _check_ips(chunk, self._ips)
        fn = _run_jit_donating if self._donate else _run_jit
        with _x64(), warnings.catch_warnings():
            # buffer donation is best-effort on some backends (e.g. CPU)
            warnings.filterwarnings(
                "ignore", message=".*[Dd]onat.*", category=UserWarning)
            out = fn(state, jnp.asarray(_n_chunks(max_ticks, chunk),
                                        jnp.int32), int(chunk), ips)
            return jax.block_until_ready(out)


# ---------------------------------------------------------------------------
# ShardedEngine — pmap over jax.devices() with fleet padding
# ---------------------------------------------------------------------------

_pmap_cache: Dict[Any, Any] = {}


def _pmap_fn(chunk: int, devices: tuple, ips: int = 1):
    key = (chunk, devices, ips)
    fn = _pmap_cache.get(key)
    if fn is None:
        fn = jax.pmap(_run_impl, in_axes=(0, None),
                      static_broadcasted_argnums=(2, 3),
                      devices=list(devices))
        _pmap_cache[key] = fn
    return fn


class ShardedEngine:
    """Data-parallel backend: shard the hart batch across devices.

    The fleet is padded up to a device multiple by repeating harts with
    ``done=True`` (frozen by ``machine.step``, and invisible to each
    shard's ``all(done)`` early exit), reshaped to a leading device axis,
    and run through the same while-loop per device.  Harts are fully
    independent, so counters are bit-identical to :class:`JitEngine`.

    On a single device (or an unbatched state) this falls back to
    :class:`JitEngine` — same compiled executable, no pmap dispatch."""

    name = "sharded"

    def __init__(self, devices: Optional[list] = None,
                 instrs_per_step: int = 1):
        self._devices = devices
        self._ips = int(instrs_per_step)

    def run(self, state, max_ticks: int, chunk: int = 4096):
        ips = _check_ips(chunk, self._ips)
        devs = tuple(self._devices if self._devices is not None
                     else jax.devices())
        if not _is_batched(state) or len(devs) < 2:
            return JitEngine(instrs_per_step=ips).run(state, max_ticks,
                                                      chunk)
        with _x64():
            b = int(state.counters.done.shape[0])
            d = min(len(devs), b)
            bp = -(-b // d) * d
            if bp != b:
                idx = np.arange(bp) % b               # repeat to pad
                state = jax.tree.map(lambda x: x[idx], state)
                done = state.counters.done.at[b:].set(True)
                state = state.replace(counters=dataclasses.replace(
                    state.counters, done=done))
            sharded = jax.tree.map(
                lambda x: x.reshape((d, bp // d) + x.shape[1:]), state)
            out = _pmap_fn(int(chunk), devs[:d], ips)(
                sharded, jnp.asarray(_n_chunks(max_ticks, chunk),
                                     jnp.int32), int(chunk), ips)
            out = jax.tree.map(
                lambda x: x.reshape((bp,) + x.shape[2:])[:b], out)
            return jax.block_until_ready(out)


# ---------------------------------------------------------------------------
# OracleEngine — the pure-Python reference model as a backend
# ---------------------------------------------------------------------------

def _snapshot_row(row) -> Dict[str, Any]:
    """Host-side plain-python snapshot of one hart (oracle state shape)."""
    c = row.counters
    t = row.tlb
    return {
        "pc": int(row.pc), "priv": int(row.priv),
        "virt": bool(row.virt), "halted": bool(row.halted),
        "regs": np.asarray(row.regs).tolist(),
        "csrs": np.asarray(row.csrs).tolist(),
        "mem": np.asarray(row.mem).tolist(),
        "tlb": {k: (int(v) if np.ndim(v) == 0 else
                    np.asarray(v).tolist()) for k, v in t.items()},
        "console": int(row.console),
        "done": bool(c.done), "exit_code": int(c.exit_code),
        "instret": int(c.instret), "instret_virt": int(c.instret_virt),
        "exc_by_level": np.asarray(c.exc_by_level).tolist(),
        "int_by_level": np.asarray(c.int_by_level).tolist(),
        "pagefaults": int(c.pagefaults), "walks": int(c.walks),
        "ticks": int(c.ticks),
        "timer_irqs": int(c.timer_irqs),
        "ctx_switches": int(c.ctx_switches),
    }


def _adopt_row(ost: Dict, template):
    """Oracle final state → HartState, reusing the template's dtypes.

    The oracle models the TLB and ``walks`` too, so every leaf — the TLB
    sub-pytree included — is adopted from the oracle's final state."""
    def u64a(x):
        return jnp.asarray(np.asarray(x, dtype=np.uint64))

    def i64(x):
        return jnp.asarray(int(x), jnp.int64)

    def i32a(x):
        return jnp.asarray(np.asarray(x, dtype=np.int32))

    def ba(x):
        return jnp.asarray(np.asarray(x, dtype=bool))

    ot = ost["tlb"]
    tlb = {
        "vpn": u64a(ot["vpn"]), "ppn": u64a(ot["ppn"]),
        "level": i32a(ot["level"]), "perm": i32a(ot["perm"]),
        "guest": ba(ot["guest"]), "priv": i32a(ot["priv"]),
        "sum": ba(ot["sum"]), "mxr": ba(ot["mxr"]),
        "valid": ba(ot["valid"]),
        "ptr": jnp.asarray(int(ot["ptr"]), jnp.int32),
    }
    counters = dataclasses.replace(
        template.counters,
        done=jnp.asarray(bool(ost["done"]), bool),
        exit_code=u64a(ost["exit_code"]),
        instret=i64(ost["instret"]),
        instret_virt=i64(ost["instret_virt"]),
        exc_by_level=jnp.asarray(
            np.asarray(ost["exc_by_level"], dtype=np.int64)),
        int_by_level=jnp.asarray(
            np.asarray(ost["int_by_level"], dtype=np.int64)),
        pagefaults=i64(ost["pagefaults"]),
        walks=i64(ost["walks"]),
        ticks=i64(ost["ticks"]),
        timer_irqs=i64(ost["timer_irqs"]),
        ctx_switches=i64(ost["ctx_switches"]),
    )
    return template.replace(
        pc=u64a(ost["pc"]),
        regs=u64a(ost["regs"]),
        csrs=u64a(ost["csrs"]),
        priv=jnp.asarray(int(ost["priv"]), jnp.int32),
        virt=jnp.asarray(bool(ost["virt"]), bool),
        mem=u64a(ost["mem"]),
        tlb=tlb,
        halted=jnp.asarray(bool(ost["halted"]), bool),
        console=i64(ost["console"]),
        counters=counters,
    )


class OracleEngine:
    """The pure-Python architectural oracle behind the Engine interface.

    Each hart is lifted off device, stepped by ``oracle.step`` for the
    same rounded-up tick budget the device engines use (per-hart early
    exit on ``done``), and lowered back with the template's dtypes.  The
    oracle models the software TLB and ``walks`` bit-exactly (DESIGN.md
    §5), so every leaf is diffable.

    After :meth:`run`, ``last_events`` holds one frozenset of
    architectural-event tuples per hart (trap / fence / atp / wfi
    signatures the oracle recorded) — the torture harness hashes these
    into coverage buckets.  Events are observational only and are never
    part of the differential comparison."""

    name = "oracle"

    def __init__(self):
        self.last_events: List[frozenset] = []

    def run(self, state, max_ticks: int, chunk: int = 4096):
        total = _n_chunks(max_ticks, chunk) * int(chunk)
        self.last_events = []
        with _x64():
            if not _is_batched(state):
                return self._run_row(state, total)
            rows = [jax.tree.map(lambda x, i=i: x[i], state)
                    for i in range(int(state.counters.done.shape[0]))]
            outs = [self._run_row(r, total) for r in rows]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    def _run_row(self, row, total: int):
        ost = _oracle.resume_state(_snapshot_row(row))
        for _ in range(total):
            if ost["done"]:
                break
            _oracle.step(ost)
        self.last_events.append(frozenset(ost.get("events", ())))
        return _adopt_row(ost, row)


register_engine("jit", JitEngine)
register_engine("sharded", ShardedEngine)
register_engine("oracle", OracleEngine)


# ---------------------------------------------------------------------------
# first-class differential compare (ONE core, shared with the torture
# harness so the two diff paths cannot drift apart)
# ---------------------------------------------------------------------------

def state_arrays(state) -> Dict[str, np.ndarray]:
    """Host-array extraction of a (scalar or batched) ``HartState``,
    shaped for :func:`diff_arrays` — one batched device→host copy per
    field, leading batch dim always present."""
    with _x64():
        batched = _is_batched(state)

        def arr(x):
            a = np.asarray(x)
            return a if batched else a[None]

        c = state.counters
        out = {
            "pc": arr(state.pc), "regs": arr(state.regs),
            "csrs": arr(state.csrs), "priv": arr(state.priv),
            "virt": arr(state.virt), "halted": arr(state.halted),
            "mem": arr(state.mem), "console": arr(state.console),
            "done": arr(c.done), "exit_code": arr(c.exit_code),
            "exc_by_level": arr(c.exc_by_level),
            "int_by_level": arr(c.int_by_level),
        }
        for k in DIFF_COUNTERS:
            out[k] = arr(getattr(c, k))
        return out


def diff_arrays(a: Dict[str, np.ndarray], i: int,
                b: Dict[str, np.ndarray], j: int,
                compare_mem: bool = True) -> List[str]:
    """Field-by-field architectural diff of hart `i` of array-dict `a`
    against hart `j` of `b` — the single comparison core under both
    :func:`diff_states` and the torture harness's batched diff."""
    d: List[str] = []

    def chk(name, x, y):
        if int(x) != int(y):
            d.append(f"{name}: a={int(x):#x} b={int(y):#x}")

    for k in DIFF_SCALARS + DIFF_COUNTERS:
        chk(k, a[k][i], b[k][j])
    for r in range(1, 32):
        chk(f"x{r}", a["regs"][i, r], b["regs"][j, r])
    for idx in range(C.N_CSR):
        chk(f"csr[{idx}]", a["csrs"][i, idx], b["csrs"][j, idx])
    for lvl, nm in enumerate(("M", "HS", "VS")):
        chk(f"exc@{nm}", a["exc_by_level"][i, lvl],
            b["exc_by_level"][j, lvl])
        chk(f"int@{nm}", a["int_by_level"][i, lvl],
            b["int_by_level"][j, lvl])
    if compare_mem:
        ma, mb = a["mem"][i], b["mem"][j]
        bad = np.nonzero(ma != mb)[0]
        if bad.size:
            w = int(bad[0])
            d.append(f"mem[{w * 8:#x}]: a={int(ma[w]):#x} "
                     f"b={int(mb[w]):#x} (+{bad.size - 1} more words)")
    return d


def diff_states(a, b, compare_mem: bool = True) -> List[str]:
    """Field-by-field architectural diff of two scalar ``HartState`` s.

    Compares pc / x1..x31 / the full CSR file / priv / virt / halted /
    done / exit_code / console / memory / ALL counters, ``walks``
    included (the oracle models the software TLB, so the exclusion list
    is empty) — exactly the torture harness's comparison scope, now
    usable on any pair of runs (e.g. ``engine="jit"`` vs
    ``engine="oracle"`` of the same fleet)."""
    return diff_arrays(state_arrays(a), 0, state_arrays(b), 0,
                       compare_mem=compare_mem)
