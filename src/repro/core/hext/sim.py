"""Typed simulation API: `HartState` pytree + `Fleet` facade (DESIGN.md §3).

This module is the single public surface for running hext simulations.  It
replaces the raw-dict plumbing that every consumer used to hand-roll
(`make_state` → manual `jnp.stack` batching → chunked host-loop
`run_until_done` → stringly-typed counter reads) with two first-class
objects:

* ``HartState`` — a frozen, registered-pytree dataclass with typed fields
  for pc/regs/csrs/mem/tlb and a nested ``Counters`` record.  It is a
  drop-in pytree: ``jax.jit``/``jax.vmap``/``jax.lax.scan`` all traverse
  it, and ``to_raw``/``from_raw`` bridge to the legacy dict layout used by
  the branchless ISA core (a purely structural conversion — free under
  ``jit``).

* ``Fleet`` — the simulation facade, in the spirit of riescue's
  ``Hypervisor`` runtime object: ``Fleet.boot(workloads, guest=...)``
  assembles system images and batches them, ``fleet.run(max_ticks)``
  advances every machine in lockstep, ``fleet.counters()`` /
  ``fleet.report()`` read the architectural counters back out.

The run loop lives **on device**: a ``lax.while_loop`` over chunked
``lax.scan`` s, gated on ``all(done)``, so early exit costs no per-chunk
host round-trip.  Fleet buffers are donated (``donate_argnums``) so memory
is updated in place, and the x64 requirement is owned here in one place
(``Fleet`` methods run under ``jax.experimental.enable_x64``) instead of
being sprinkled across per-call wrappers.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.hext import machine as _machine

U64 = jnp.uint64
MASK64 = (1 << 64) - 1

__all__ = ["Counters", "HartState", "Fleet", "HartSpec", "checksum_ok",
           "run_on_device"]


def _x64():
    """The one x64 context the facade owns (64-bit architectural state)."""
    return jax.experimental.enable_x64()


def checksum_ok(exit_code, golden: int) -> bool:
    """Canonical result check: compare exit code and golden mod 2**64.

    Workload checksums are uint64 values; Python goldens may carry the top
    bit.  Both sides are reduced mod 2**64 so signedness can never skew the
    comparison (previously one call site masked with ``(1 << 63) - 1`` and
    another compared raw ints).
    """
    return (int(exit_code) & MASK64) == (int(golden) & MASK64)


# ---------------------------------------------------------------------------
# Counters — the per-hart measurement record (paper Figures 4-7)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["done", "exit_code", "instret", "instret_virt",
                 "exc_by_level", "int_by_level", "pagefaults", "walks",
                 "ticks", "timer_irqs", "ctx_switches"],
    meta_fields=[])
@dataclasses.dataclass(frozen=True)
class Counters:
    """Architectural counters + run outcome for one hart (or a batch).

    instret / instret_virt — Fig 5 (instructions w/ and w/o VM)
    exc_by_level[3] / int_by_level[3] — Figs 6/7 (M, HS, VS)
    pagefaults, walks — translation activity; ticks — Fig 4 time proxy
    timer_irqs / ctx_switches — preemption activity (DESIGN.md §2c)
    done / exit_code — run outcome (checksum mailbox)
    """

    done: jax.Array
    exit_code: jax.Array
    instret: jax.Array
    instret_virt: jax.Array
    exc_by_level: jax.Array
    int_by_level: jax.Array
    pagefaults: jax.Array
    walks: jax.Array
    ticks: jax.Array
    timer_irqs: jax.Array
    ctx_switches: jax.Array

    @classmethod
    def zero(cls) -> "Counters":
        return cls(
            done=jnp.zeros((), bool),
            exit_code=jnp.zeros((), U64),
            instret=jnp.zeros((), jnp.int64),
            instret_virt=jnp.zeros((), jnp.int64),
            exc_by_level=jnp.zeros((3,), jnp.int64),
            int_by_level=jnp.zeros((3,), jnp.int64),
            pagefaults=jnp.zeros((), jnp.int64),
            walks=jnp.zeros((), jnp.int64),
            ticks=jnp.zeros((), jnp.int64),
            timer_irqs=jnp.zeros((), jnp.int64),
            ctx_switches=jnp.zeros((), jnp.int64),
        )

    def ok(self, golden: int) -> bool:
        """One canonical uint64 comparison for every call site."""
        return checksum_ok(self.exit_code, golden)

    def to_dict(self, golden: Optional[int] = None) -> Dict[str, Any]:
        """Host-side dict (JSON-safe) — the legacy benchmark record shape."""
        with _x64():
            out = {
                "done": bool(self.done),
                "instret": int(self.instret),
                "instret_virt": int(self.instret_virt),
                "ticks": int(self.ticks),
                "exc_by_level": [int(x) for x in self.exc_by_level],
                "int_by_level": [int(x) for x in self.int_by_level],
                "pagefaults": int(self.pagefaults),
                "walks": int(self.walks),
                "timer_irqs": int(self.timer_irqs),
                "ctx_switches": int(self.ctx_switches),
            }
            if golden is not None:
                out["ok"] = self.ok(golden)
            return out


_COUNTER_KEYS = ("done", "exit_code", "instret", "instret_virt",
                 "exc_by_level", "int_by_level", "pagefaults", "walks",
                 "ticks", "timer_irqs", "ctx_switches")


# ---------------------------------------------------------------------------
# HartState — the typed machine state pytree
# ---------------------------------------------------------------------------

@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["pc", "regs", "csrs", "priv", "virt", "mem", "tlb",
                 "halted", "console", "counters"],
    meta_fields=[])
@dataclasses.dataclass(frozen=True)
class HartState:
    """Full architectural state of one hart (or a leading-dim batch).

    ``tlb`` is the software-TLB sub-pytree (see ``tlb.init_tlb``);
    ``counters`` is the nested :class:`Counters` record.  The class is a
    registered pytree, so it composes with jit/vmap/scan directly.
    """

    pc: jax.Array
    regs: jax.Array
    csrs: jax.Array
    priv: jax.Array
    virt: jax.Array
    mem: jax.Array
    tlb: Dict[str, jax.Array]
    halted: jax.Array
    console: jax.Array
    counters: Counters

    # -- construction -------------------------------------------------------
    @classmethod
    def fresh(cls, mem_words: int = _machine.DEFAULT_MEM_WORDS) -> "HartState":
        """Power-on state: pc=0, M mode, zeroed memory and counters."""
        with _x64():
            return cls.from_raw(_machine._make_state(mem_words))

    @classmethod
    def boot(cls, workload, guest: bool = False) -> "HartState":
        """State with a full bootable system image for `workload` loaded
        (native M→S stack, or M→HS xvisor-lite→VS when ``guest``)."""
        from repro.core.hext import programs
        image = programs.build_image(workload, guest)
        with _x64():
            st = cls.fresh(programs.MEM_WORDS)
            return st.with_mem(jnp.asarray(image))

    @classmethod
    def boot_preemptive(cls, *workloads,
                        timeslice: Optional[int] = None) -> "HartState":
        """State with an N-guest preemptive system image loaded: M firmware
        → HS scheduler-hypervisor → N VS guests round-robined on timer
        interrupts every `timeslice` ticks (DESIGN.md §2c).  Memory is
        sized per N (`programs.sched_layout`)."""
        from repro.core.hext import programs
        ts = programs.DEFAULT_TIMESLICE if timeslice is None else \
            int(timeslice)
        image = programs.build_image_nguest(workloads, timeslice=ts)
        with _x64():
            st = cls.fresh(int(image.shape[0]))
            return st.with_mem(jnp.asarray(image))

    # -- raw-dict bridge (legacy ISA-core layout) ---------------------------
    @classmethod
    def from_raw(cls, raw) -> "HartState":
        """Wrap the flat dict layout the branchless ISA core computes on.

        A `HartState` passes through unchanged, so compat shims accept
        either representation."""
        if isinstance(raw, cls):
            return raw
        return cls(
            pc=raw["pc"], regs=raw["regs"], csrs=raw["csrs"],
            priv=raw["priv"], virt=raw["virt"], mem=raw["mem"],
            tlb=raw["tlb"], halted=raw["halted"], console=raw["console"],
            counters=Counters(**{k: raw[k] for k in _COUNTER_KEYS}),
        )

    def to_raw(self) -> Dict[str, Any]:
        """Flat dict layout (inverse of :meth:`from_raw`; structural only)."""
        raw = {
            "pc": self.pc, "regs": self.regs, "csrs": self.csrs,
            "priv": self.priv, "virt": self.virt, "mem": self.mem,
            "tlb": self.tlb, "halted": self.halted, "console": self.console,
        }
        raw.update({k: getattr(self.counters, k) for k in _COUNTER_KEYS})
        return raw

    # -- functional updates -------------------------------------------------
    def replace(self, **kw) -> "HartState":
        return dataclasses.replace(self, **kw)

    def with_mem(self, mem) -> "HartState":
        with _x64():
            return self.replace(mem=jnp.asarray(mem, U64))

    def or_image(self, image, base: int = 0) -> "HartState":
        """OR a uint64-word image into memory at byte address `base`.

        Note: unlike ``machine.load_image`` (which overwrites), this merges
        — the semantics test harnesses want when layering fragments onto a
        fresh (zeroed) machine.  Use :meth:`with_mem` to replace memory."""
        with _x64():
            w = base >> 3
            img = jnp.asarray(image, U64)
            mem = self.mem.at[w:w + img.shape[0]].set(
                self.mem[w:w + img.shape[0]] | img)
            return self.replace(mem=mem)

    # -- stepping -----------------------------------------------------------
    def step(self) -> "HartState":
        """One tick (CheckInterrupts → fetch → execute → trap), typed."""
        return HartState.from_raw(_machine.step(self.to_raw()))


def _typed_step(state: HartState) -> HartState:
    return state.step()


# ---------------------------------------------------------------------------
# On-device run loop: while_loop over chunked scans, gated on all(done)
# ---------------------------------------------------------------------------

def _run_impl(state: HartState, n_chunks, chunk: int) -> HartState:
    """On-device run loop: `n_chunks` chunk-scans max, early exit once every
    hart reports done (no per-chunk host sync).  Only `chunk` is static —
    different tick budgets reuse the same executable."""
    batched = state.counters.done.ndim == 1
    step_fn = jax.vmap(_typed_step) if batched else _typed_step

    def scan_body(s, _):
        return step_fn(s), None

    def cond(carry):
        s, i = carry
        return (i < n_chunks) & ~jnp.all(s.counters.done)

    def body(carry):
        s, i = carry
        s = jax.lax.scan(scan_body, s, None, length=chunk)[0]
        return s, i + jnp.ones((), jnp.int32)

    state, _ = jax.lax.while_loop(cond, body,
                                  (state, jnp.zeros((), jnp.int32)))
    return state


_run_jit_donating = jax.jit(_run_impl, static_argnums=(2,),
                            donate_argnums=(0,))
_run_jit = jax.jit(_run_impl, static_argnums=(2,))


def run_on_device(state: HartState, max_ticks: int, chunk: int = 4096,
                  donate: bool = True) -> HartState:
    """Run until every hart is done or `max_ticks` elapse — one jitted call.

    Like the legacy host loop, the tick budget rounds up to whole chunks:
    `ceil(max_ticks / chunk)` scans.  With ``donate`` (the default, used by
    `Fleet`) the `state` buffers are donated and updated in place, so
    `state` must not be reused after this call; pass ``donate=False`` when
    the caller keeps a reference to the input (the legacy shims do).
    """
    n_chunks = -(-int(max_ticks) // int(chunk))
    fn = _run_jit_donating if donate else _run_jit
    with _x64(), warnings.catch_warnings():
        # buffer donation is best-effort on some backends (e.g. CPU)
        warnings.filterwarnings(
            "ignore", message=".*[Dd]onat.*", category=UserWarning)
        out = fn(state, jnp.asarray(n_chunks, jnp.int32), int(chunk))
        return jax.block_until_ready(out)


# ---------------------------------------------------------------------------
# Fleet — the simulation facade
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HartSpec:
    """What one fleet slot is running (for labels and golden checks).

    A preemptive slot carries the full guest tuple in ``guests`` (N ≥ 1;
    ``workload`` aliases guest 0) and the scheduler timeslice."""
    workload: Optional[Any]
    guest: bool
    name: str
    guests: Optional[tuple] = None
    timeslice: int = 0

    @property
    def preemptive(self) -> bool:
        return self.guests is not None

    @property
    def label(self) -> str:
        if self.preemptive:
            return f"{self.name}/{len(self.guests)}guest-preempt"
        return f"{self.name}/{'guest' if self.guest else 'native'}"


class Fleet:
    """A batch of harts simulated in lockstep — the 'gem5 pod'.

    >>> fleet = Fleet.boot(programs.WORKLOADS, guest=False)
    >>> fleet.run(120_000)
    >>> fleet.report()["crc32/native"]["ok"]
    True

    The fleet owns the x64 context, the batched ``HartState``, and the
    on-device while-loop engine; consumers never touch raw dicts,
    ``jnp.stack`` trees, or per-chunk host syncs.
    """

    def __init__(self, harts: HartState, specs: Sequence[HartSpec]):
        self._harts = harts
        self._specs = list(specs)

    # -- construction -------------------------------------------------------
    @classmethod
    def boot(cls, workloads, guest: Union[bool, Sequence[bool]] = False,
             guests_per_hart: int = 1,
             timeslice: Optional[int] = None) -> "Fleet":
        """Assemble + batch bootable machines, one per workload.

        ``workloads`` is a Workload or a sequence of them; ``guest`` is a
        bool applied fleet-wide or a per-slot sequence (e.g.
        ``Fleet.boot(wls * 2, guest=[False] * 9 + [True] * 9)`` is the
        paper's native-vs-VM matrix).

        ``guests_per_hart=N`` (N ≥ 2, or N=1 with an explicit
        ``timeslice``) boots the preemptive multi-guest images instead:
        each slot runs N guest VMs under the HS scheduler, round-robin
        every ``timeslice`` ticks.  A slot entry may be a single workload
        (all N guests run it) or a length-N tuple of workloads
        (heterogeneous tenants).
        """
        wls = list(workloads) if isinstance(workloads, (list, tuple)) \
            else [workloads]
        n = int(guests_per_hart)
        if n < 1:
            raise ValueError(f"guests_per_hart must be >= 1, got {n}")
        if n >= 2 or timeslice is not None:
            if guest is not False:
                raise ValueError(
                    "guest= does not apply with a preemptive boot "
                    "(every slot runs VS guests under the scheduler)")
            from repro.core.hext import programs
            ts = programs.DEFAULT_TIMESLICE if timeslice is None else \
                int(timeslice)
            groups = []
            for i, w in enumerate(wls):
                grp = tuple(w) if isinstance(w, (tuple, list)) else (w,) * n
                if len(grp) != n:
                    raise ValueError(
                        f"slot {i}: expected a workload or a length-{n} "
                        f"tuple, got {len(grp)} entries")
                groups.append(grp)
            specs = [HartSpec(g[0], True, "+".join(w.name for w in g),
                              guests=g, timeslice=ts) for g in groups]
            states = [HartState.boot_preemptive(*g, timeslice=ts)
                      for g in groups]
            return cls(cls._stack(states), specs)
        guests = list(guest) if isinstance(guest, (list, tuple)) \
            else [bool(guest)] * len(wls)
        if len(guests) != len(wls):
            raise ValueError(
                f"guest has {len(guests)} entries for {len(wls)} workloads")
        specs = [HartSpec(w, g, w.name) for w, g in zip(wls, guests)]
        states = [HartState.boot(w, guest=g) for w, g in zip(wls, guests)]
        return cls(cls._stack(states), specs)

    @classmethod
    def from_states(cls, states: Sequence[HartState],
                    specs: Optional[Sequence[HartSpec]] = None) -> "Fleet":
        """Fleet over pre-built states (e.g. hand-assembled test images)."""
        states = list(states)
        if specs is None:
            specs = [HartSpec(None, False, f"hart{i}")
                     for i in range(len(states))]
        return cls(cls._stack(states), specs)

    @classmethod
    def from_images(cls, images: Sequence[Any],
                    mem_words: int = _machine.DEFAULT_MEM_WORDS,
                    names: Optional[Sequence[str]] = None) -> "Fleet":
        """Fleet of fresh harts, each booted from a raw uint64-word image
        (shorter images are zero-padded; an oversized one is an error)."""
        with _x64():
            imgs = [jnp.asarray(im, U64) for im in images]
            for i, im in enumerate(imgs):
                if int(im.shape[0]) > mem_words:
                    raise ValueError(
                        f"image {i} has {int(im.shape[0])} words > "
                        f"mem_words={mem_words}")
            states = [HartState.fresh(mem_words).or_image(im)
                      for im in imgs]
        specs = None if names is None else \
            [HartSpec(None, False, str(n)) for n in names]
        return cls.from_states(states, specs)

    @classmethod
    def from_corpus(cls, images: Sequence[Any],
                    names: Optional[Sequence[str]] = None,
                    mem_words: Optional[int] = None) -> "Fleet":
        """Batch a scenario corpus (possibly differently-sized images) as
        ONE fleet: every image is zero-padded to a common word count so the
        whole corpus traces to a single XLA executable — the batched-fuzz
        mode of the torture harness (DESIGN.md §5).  ``mem_words`` defaults
        to the largest image rounded up to a power of two, so corpora of
        similar size reuse the compile cache across runs."""
        if not len(images):
            raise ValueError("from_corpus needs at least one image")
        if mem_words is None:
            m = max(len(im) for im in images)
            mem_words = 1 << max(m - 1, 1).bit_length()
        if names is None:
            names = [f"case{i}" for i in range(len(images))]
        return cls.from_images(images, mem_words, names=names)

    @staticmethod
    def _stack(states: Sequence[HartState]) -> HartState:
        if not states:
            raise ValueError("Fleet needs at least one hart")
        with _x64():
            return jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    # -- running ------------------------------------------------------------
    def run(self, max_ticks: int, chunk: int = 4096) -> "Fleet":
        """Advance the whole fleet (early exit on-device, buffers donated)."""
        self._harts = run_on_device(self._harts, max_ticks, chunk)
        return self

    # -- inspection ---------------------------------------------------------
    @property
    def harts(self) -> HartState:
        """The batched state (leading dim = fleet size).

        WARNING: ``fleet.run`` donates these buffers (in-place update), so
        on backends that honor donation a reference taken *before* a run is
        invalidated by it.  Re-read ``fleet.harts`` after each run."""
        return self._harts

    @property
    def specs(self) -> List[HartSpec]:
        return list(self._specs)

    @property
    def all_done(self) -> bool:
        with _x64():
            return bool(jnp.all(self._harts.counters.done))

    def __len__(self) -> int:
        return len(self._specs)

    def __getitem__(self, i: int) -> HartState:
        """Per-hart view (scalar leaves) of slot `i`."""
        with _x64():
            return jax.tree.map(lambda x: x[i], self._harts)

    def counters(self) -> List[Counters]:
        """Per-hart :class:`Counters`, in fleet order."""
        with _x64():
            return [jax.tree.map(lambda x: x[i], self._harts.counters)
                    for i in range(len(self))]

    def _preempt_entry(self, i: int, spec: HartSpec,
                       c: Counters) -> Dict[str, Any]:
        """Report entry for an N-guest slot: per-guest checksum mailboxes
        are read straight from the hart's memory (the HS scheduler records
        each guest's result before combining them into the exit code)."""
        from repro.core.hext import programs
        n = len(spec.guests)
        lay = programs.sched_layout(n)
        with _x64():
            res_w = lay.guest_res // 8
            cks = [int(self._harts.mem[i, res_w + k]) & MASK64
                   for k in range(n)]
        goldens = [int(w.golden()) & MASK64 for w in spec.guests]
        oks = [ck == g for ck, g in zip(cks, goldens)]
        total = sum(goldens) & MASK64
        entry = c.to_dict()
        entry.update({
            "golden": total,
            "guests": n,
            "checksums": cks,
            "ok_guests": oks,
            "ok": bool(c.done) and all(oks) and c.ok(total),
            "timeslice": spec.timeslice,
        })
        if n == 2:       # legacy 2-guest report keys
            entry.update({"checksum_a": cks[0], "checksum_b": cks[1],
                          "ok_a": oks[0], "ok_b": oks[1]})
        return entry

    def report(self) -> Dict[str, Dict[str, Any]]:
        """``{label: counter-dict}`` with golden checks where known.

        Duplicate (workload, guest) slots get a ``#<slot>`` suffix so no
        hart's counters are silently dropped."""
        out: Dict[str, Dict[str, Any]] = {}
        for i, (spec, c) in enumerate(zip(self._specs, self.counters())):
            if spec.preemptive:
                entry = self._preempt_entry(i, spec, c)
            else:
                golden = spec.workload.golden() if spec.workload is not None \
                    else None
                entry = c.to_dict(golden)
                if golden is not None:
                    entry["golden"] = int(golden) & MASK64
            label = spec.label
            if label in out:
                label = f"{label}#{i}"
            out[label] = entry
        return out
