"""Typed simulation API: `HartState` pytree + `Fleet` facade (DESIGN.md §3).

This module is the single public surface for running hext simulations.  It
replaces the raw-dict plumbing that every consumer used to hand-roll
(`make_state` → manual `jnp.stack` batching → chunked host-loop
`run_until_done` → stringly-typed counter reads) with two first-class
objects:

* ``HartState`` — a frozen, registered-pytree dataclass with typed fields
  for pc/regs/csrs/mem/tlb and a nested ``Counters`` record.  It is a
  drop-in pytree: ``jax.jit``/``jax.vmap``/``jax.lax.scan`` all traverse
  it, and ``to_raw``/``from_raw`` bridge to the legacy dict layout used by
  the branchless ISA core (a purely structural conversion — free under
  ``jit``).

* ``Fleet`` — the simulation facade, in the spirit of riescue's
  ``Hypervisor`` runtime object: ``Fleet.boot(workloads, guest=...)``
  assembles system images and batches them, ``fleet.run(max_ticks)``
  advances every machine in lockstep, ``fleet.counters()`` /
  ``fleet.report()`` read the architectural counters back out.

Execution is delegated to a pluggable :mod:`repro.core.hext.engine`
backend (``Fleet.boot(..., engine="jit"|"sharded"|"oracle")``): the
default ``JitEngine`` runs the donated on-device ``lax.while_loop`` over
chunked scans, ``ShardedEngine`` pmaps the batch across ``jax.devices()``,
and ``OracleEngine`` drives the pure-Python reference model behind the
same typed interface.  On top of the unified state path the fleet offers
gem5-style checkpointing (``Fleet.snapshot`` / ``Fleet.restore``, a
versioned ``.npz`` with a schema-hash guard — see
:mod:`repro.core.hext.checkpoint`) and live guest migration between harts
(``Fleet.migrate_guest``).  The x64 requirement is owned by the facade and
the engines in one place instead of being sprinkled across per-call
wrappers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hext import engine as _engine
from repro.core.hext import machine as _machine

U64 = jnp.uint64
MASK64 = (1 << 64) - 1

__all__ = ["Counters", "HartState", "Fleet", "HartSpec", "checksum_ok",
           "run_on_device", "StaleHartsError", "MigrationError"]


def _x64():
    """The one x64 context the facade owns (64-bit architectural state)."""
    return jax.experimental.enable_x64()


def checksum_ok(exit_code, golden: int) -> bool:
    """Canonical result check: compare exit code and golden mod 2**64.

    Workload checksums are uint64 values; Python goldens may carry the top
    bit.  Both sides are reduced mod 2**64 so signedness can never skew the
    comparison (previously one call site masked with ``(1 << 63) - 1`` and
    another compared raw ints).
    """
    return (int(exit_code) & MASK64) == (int(golden) & MASK64)


# ---------------------------------------------------------------------------
# Counters — the per-hart measurement record (paper Figures 4-7)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["done", "exit_code", "instret", "instret_virt",
                 "exc_by_level", "int_by_level", "pagefaults", "walks",
                 "ticks", "timer_irqs", "ctx_switches"],
    meta_fields=[])
@dataclasses.dataclass(frozen=True)
class Counters:
    """Architectural counters + run outcome for one hart (or a batch).

    instret / instret_virt — Fig 5 (instructions w/ and w/o VM)
    exc_by_level[3] / int_by_level[3] — Figs 6/7 (M, HS, VS)
    pagefaults, walks — translation activity; ticks — Fig 4 time proxy
    timer_irqs / ctx_switches — preemption activity (DESIGN.md §2c)
    done / exit_code — run outcome (checksum mailbox)
    """

    done: jax.Array
    exit_code: jax.Array
    instret: jax.Array
    instret_virt: jax.Array
    exc_by_level: jax.Array
    int_by_level: jax.Array
    pagefaults: jax.Array
    walks: jax.Array
    ticks: jax.Array
    timer_irqs: jax.Array
    ctx_switches: jax.Array

    @classmethod
    def zero(cls) -> "Counters":
        return cls(
            done=jnp.zeros((), bool),
            exit_code=jnp.zeros((), U64),
            instret=jnp.zeros((), jnp.int64),
            instret_virt=jnp.zeros((), jnp.int64),
            exc_by_level=jnp.zeros((3,), jnp.int64),
            int_by_level=jnp.zeros((3,), jnp.int64),
            pagefaults=jnp.zeros((), jnp.int64),
            walks=jnp.zeros((), jnp.int64),
            ticks=jnp.zeros((), jnp.int64),
            timer_irqs=jnp.zeros((), jnp.int64),
            ctx_switches=jnp.zeros((), jnp.int64),
        )

    def ok(self, golden: int) -> bool:
        """One canonical uint64 comparison for every call site."""
        return checksum_ok(self.exit_code, golden)

    def to_dict(self, golden: Optional[int] = None) -> Dict[str, Any]:
        """Host-side dict (JSON-safe) — the legacy benchmark record shape."""
        with _x64():
            out = {
                "done": bool(self.done),
                # masked to uint64 so a report entry can reproduce the
                # exact checksum its `ok` was computed from
                "exit_code": int(self.exit_code) & MASK64,
                "instret": int(self.instret),
                "instret_virt": int(self.instret_virt),
                "ticks": int(self.ticks),
                "exc_by_level": [int(x) for x in self.exc_by_level],
                "int_by_level": [int(x) for x in self.int_by_level],
                "pagefaults": int(self.pagefaults),
                "walks": int(self.walks),
                "timer_irqs": int(self.timer_irqs),
                "ctx_switches": int(self.ctx_switches),
            }
            if golden is not None:
                out["ok"] = self.ok(golden)
            return out


_COUNTER_KEYS = ("done", "exit_code", "instret", "instret_virt",
                 "exc_by_level", "int_by_level", "pagefaults", "walks",
                 "ticks", "timer_irqs", "ctx_switches")


# ---------------------------------------------------------------------------
# HartState — the typed machine state pytree
# ---------------------------------------------------------------------------

@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["pc", "regs", "csrs", "priv", "virt", "mem", "tlb",
                 "halted", "console", "counters"],
    meta_fields=[])
@dataclasses.dataclass(frozen=True)
class HartState:
    """Full architectural state of one hart (or a leading-dim batch).

    ``tlb`` is the software-TLB sub-pytree (see ``tlb.init_tlb``);
    ``counters`` is the nested :class:`Counters` record.  The class is a
    registered pytree, so it composes with jit/vmap/scan directly.
    """

    pc: jax.Array
    regs: jax.Array
    csrs: jax.Array
    priv: jax.Array
    virt: jax.Array
    mem: jax.Array
    tlb: Dict[str, jax.Array]
    halted: jax.Array
    console: jax.Array
    counters: Counters

    # -- construction -------------------------------------------------------
    @classmethod
    def fresh(cls, mem_words: int = _machine.DEFAULT_MEM_WORDS) -> "HartState":
        """Power-on state: pc=0, M mode, zeroed memory and counters."""
        with _x64():
            return cls.from_raw(_machine._make_state(mem_words))

    @classmethod
    def boot(cls, workload, guest: bool = False) -> "HartState":
        """State with a full bootable system image for `workload` loaded
        (native M→S stack, or M→HS xvisor-lite→VS when ``guest``)."""
        from repro.core.hext import programs
        image = programs.build_image(workload, guest)
        with _x64():
            st = cls.fresh(programs.MEM_WORDS)
            return st.with_mem(jnp.asarray(image))

    @classmethod
    def boot_preemptive(cls, *workloads,
                        timeslice: Optional[int] = None) -> "HartState":
        """State with an N-guest preemptive system image loaded: M firmware
        → HS scheduler-hypervisor → N VS guests round-robined on timer
        interrupts every `timeslice` ticks (DESIGN.md §2c).  Memory is
        sized per N (`programs.sched_layout`)."""
        from repro.core.hext import programs
        ts = programs.DEFAULT_TIMESLICE if timeslice is None else \
            int(timeslice)
        image = programs.build_image_nguest(workloads, timeslice=ts)
        with _x64():
            st = cls.fresh(int(image.shape[0]))
            return st.with_mem(jnp.asarray(image))

    # -- raw-dict bridge (legacy ISA-core layout) ---------------------------
    @classmethod
    def from_raw(cls, raw) -> "HartState":
        """Wrap the flat dict layout the branchless ISA core computes on.

        A `HartState` passes through unchanged, so compat shims accept
        either representation."""
        if isinstance(raw, cls):
            return raw
        return cls(
            pc=raw["pc"], regs=raw["regs"], csrs=raw["csrs"],
            priv=raw["priv"], virt=raw["virt"], mem=raw["mem"],
            tlb=raw["tlb"], halted=raw["halted"], console=raw["console"],
            counters=Counters(**{k: raw[k] for k in _COUNTER_KEYS}),
        )

    def to_raw(self) -> Dict[str, Any]:
        """Flat dict layout (inverse of :meth:`from_raw`; structural only)."""
        raw = {
            "pc": self.pc, "regs": self.regs, "csrs": self.csrs,
            "priv": self.priv, "virt": self.virt, "mem": self.mem,
            "tlb": self.tlb, "halted": self.halted, "console": self.console,
        }
        raw.update({k: getattr(self.counters, k) for k in _COUNTER_KEYS})
        return raw

    # -- functional updates -------------------------------------------------
    def replace(self, **kw) -> "HartState":
        return dataclasses.replace(self, **kw)

    def with_mem(self, mem) -> "HartState":
        with _x64():
            return self.replace(mem=jnp.asarray(mem, U64))

    def or_image(self, image, base: int = 0) -> "HartState":
        """OR a uint64-word image into memory at byte address `base`.

        Note: unlike ``machine.load_image`` (which overwrites), this merges
        — the semantics test harnesses want when layering fragments onto a
        fresh (zeroed) machine.  Use :meth:`with_mem` to replace memory."""
        with _x64():
            w = base >> 3
            img = jnp.asarray(image, U64)
            mem = self.mem.at[w:w + img.shape[0]].set(
                self.mem[w:w + img.shape[0]] | img)
            return self.replace(mem=mem)

    # -- stepping -----------------------------------------------------------
    def step(self) -> "HartState":
        """One tick (CheckInterrupts → fetch → execute → trap), typed."""
        return HartState.from_raw(_machine.step(self.to_raw()))


# ---------------------------------------------------------------------------
# run_on_device — thin compat wrapper over the default JitEngine backend
# ---------------------------------------------------------------------------

def run_on_device(state: HartState, max_ticks: int, chunk: int = 4096,
                  donate: bool = True) -> HartState:
    """Run until every hart is done or `max_ticks` elapse — one jitted call.

    Compat wrapper over ``engine.JitEngine`` (the while-loop over chunked
    scans now lives in :mod:`repro.core.hext.engine`).  The tick budget
    rounds up to whole chunks: `ceil(max_ticks / chunk)` scans.  With
    ``donate`` (the default) the `state` buffers are donated and updated
    in place, so `state` must not be reused after this call; pass
    ``donate=False`` when the caller keeps a reference to the input.
    """
    return _engine.JitEngine(donate=donate).run(state, max_ticks, chunk)


# ---------------------------------------------------------------------------
# Fleet — the simulation facade
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HartSpec:
    """What one fleet slot is running (for labels and golden checks).

    A preemptive slot carries the full guest tuple in ``guests`` (N ≥ 1;
    ``workload`` aliases guest 0) and the scheduler timeslice."""
    workload: Optional[Any]
    guest: bool
    name: str
    guests: Optional[tuple] = None
    timeslice: int = 0

    @property
    def preemptive(self) -> bool:
        return self.guests is not None

    @property
    def label(self) -> str:
        if self.preemptive:
            return f"{self.name}/{len(self.guests)}guest-preempt"
        return f"{self.name}/{'guest' if self.guest else 'native'}"


class StaleHartsError(RuntimeError):
    """A ``fleet.harts`` reference was used after a later ``fleet.run``
    (or ``migrate_guest``) invalidated it (donated buffers)."""


class MigrationError(RuntimeError):
    """A ``Fleet.migrate_guest`` precondition does not hold (wrong slot
    kind, guest currently scheduled, hart already exited, …)."""


class _HartsView:
    """Generation-checked view of the fleet's batched ``HartState``.

    ``fleet.run`` donates the fleet buffers, so a reference taken before
    a run points at invalidated memory on backends that honor donation —
    and at silently *stale* memory on those that don't (CPU).  The view
    forwards attribute access to the live state while its generation
    matches, and raises :class:`StaleHartsError` afterwards."""

    __slots__ = ("_fleet", "_gen")

    def __init__(self, fleet: "Fleet", gen: int):
        object.__setattr__(self, "_fleet", fleet)
        object.__setattr__(self, "_gen", gen)

    def _live(self) -> HartState:
        if self._fleet._generation != self._gen:
            raise StaleHartsError(
                f"this fleet.harts reference is stale: it was taken at "
                f"run-generation {self._gen} but the fleet is now at "
                f"generation {self._fleet._generation} (fleet.run donates "
                f"its buffers) — re-read fleet.harts after each run")
        return self._fleet._harts

    def unwrap(self) -> HartState:
        """The underlying ``HartState`` pytree (generation-checked)."""
        return self._live()

    def __getattr__(self, name):
        return getattr(self._live(), name)

    def __repr__(self):
        return f"<harts view gen={self._gen} of {self._fleet!r}>"


class Fleet:
    """A batch of harts simulated in lockstep — the 'gem5 pod'.

    >>> fleet = Fleet.boot(programs.WORKLOADS, guest=False)
    >>> fleet.run(120_000)
    >>> fleet.report()["crc32/native"]["ok"]
    True

    The fleet owns the x64 context, the batched ``HartState``, and a
    pluggable execution backend (``engine=`` — ``"jit"``, ``"sharded"``,
    ``"oracle"``, or any object with ``run(state, max_ticks, chunk)``);
    consumers never touch raw dicts, ``jnp.stack`` trees, or per-chunk
    host syncs.
    """

    def __init__(self, harts: HartState, specs: Sequence[HartSpec],
                 engine: Any = None):
        self._harts = harts
        self._specs = list(specs)
        self._engine = _engine.resolve(engine)
        self._generation = 0

    # -- construction -------------------------------------------------------
    @classmethod
    def boot(cls, workloads, guest: Union[bool, Sequence[bool]] = False,
             guests_per_hart: int = 1,
             timeslice: Optional[int] = None,
             engine: Any = None) -> "Fleet":
        """Assemble + batch bootable machines, one per workload.

        ``workloads`` is a Workload or a sequence of them; ``guest`` is a
        bool applied fleet-wide or a per-slot sequence (e.g.
        ``Fleet.boot(wls * 2, guest=[False] * 9 + [True] * 9)`` is the
        paper's native-vs-VM matrix).

        ``guests_per_hart=N`` (N ≥ 2, or N=1 with an explicit
        ``timeslice``) boots the preemptive multi-guest images instead:
        each slot runs N guest VMs under the HS scheduler, round-robin
        every ``timeslice`` ticks.  A slot entry may be a single workload
        (all N guests run it) or a length-N tuple of workloads
        (heterogeneous tenants).

        ``engine`` selects the execution backend (DESIGN.md §3): a
        registered name (``"jit"`` default, ``"sharded"``, ``"oracle"``)
        or an :class:`repro.core.hext.engine.Engine` instance.
        """
        wls = list(workloads) if isinstance(workloads, (list, tuple)) \
            else [workloads]
        n = int(guests_per_hart)
        if n < 1:
            raise ValueError(f"guests_per_hart must be >= 1, got {n}")
        if n >= 2 or timeslice is not None:
            if guest is not False:
                raise ValueError(
                    "guest= does not apply with a preemptive boot "
                    "(every slot runs VS guests under the scheduler)")
            from repro.core.hext import programs
            ts = programs.DEFAULT_TIMESLICE if timeslice is None else \
                int(timeslice)
            groups = []
            for i, w in enumerate(wls):
                grp = tuple(w) if isinstance(w, (tuple, list)) else (w,) * n
                if len(grp) != n:
                    raise ValueError(
                        f"slot {i}: expected a workload or a length-{n} "
                        f"tuple, got {len(grp)} entries")
                groups.append(grp)
            # a None guest entry is a reserved slot: it boots parked
            # (ginfo.done=1) and can later be filled via resume_guest
            specs = [HartSpec(g[0], True,
                              "+".join(w.name if w is not None else "~"
                                       for w in g),
                              guests=g, timeslice=ts) for g in groups]
            states = [HartState.boot_preemptive(*g, timeslice=ts)
                      for g in groups]
            return cls(cls._stack(states), specs, engine=engine)
        guests = list(guest) if isinstance(guest, (list, tuple)) \
            else [bool(guest)] * len(wls)
        if len(guests) != len(wls):
            raise ValueError(
                f"guest has {len(guests)} entries for {len(wls)} workloads")
        specs = [HartSpec(w, g, w.name) for w, g in zip(wls, guests)]
        states = [HartState.boot(w, guest=g) for w, g in zip(wls, guests)]
        return cls(cls._stack(states), specs, engine=engine)

    @classmethod
    def from_states(cls, states: Sequence[HartState],
                    specs: Optional[Sequence[HartSpec]] = None,
                    engine: Any = None) -> "Fleet":
        """Fleet over pre-built states (e.g. hand-assembled test images)."""
        states = list(states)
        if specs is None:
            specs = [HartSpec(None, False, f"hart{i}")
                     for i in range(len(states))]
        return cls(cls._stack(states), specs, engine=engine)

    @classmethod
    def from_images(cls, images: Sequence[Any],
                    mem_words: int = _machine.DEFAULT_MEM_WORDS,
                    names: Optional[Sequence[str]] = None,
                    engine: Any = None) -> "Fleet":
        """Fleet of fresh harts, each booted from a raw uint64-word image
        (shorter images are zero-padded; an oversized one is an error)."""
        with _x64():
            imgs = [jnp.asarray(im, U64) for im in images]
            for i, im in enumerate(imgs):
                if int(im.shape[0]) > mem_words:
                    raise ValueError(
                        f"image {i} has {int(im.shape[0])} words > "
                        f"mem_words={mem_words}")
            states = [HartState.fresh(mem_words).or_image(im)
                      for im in imgs]
        specs = None if names is None else \
            [HartSpec(None, False, str(n)) for n in names]
        return cls.from_states(states, specs, engine=engine)

    @classmethod
    def from_corpus(cls, images: Sequence[Any],
                    names: Optional[Sequence[str]] = None,
                    mem_words: Optional[int] = None,
                    engine: Any = None) -> "Fleet":
        """Batch a scenario corpus (possibly differently-sized images) as
        ONE fleet: every image is zero-padded to a common word count so the
        whole corpus traces to a single XLA executable — the batched-fuzz
        mode of the torture harness (DESIGN.md §5).  ``mem_words`` defaults
        to the largest image rounded up to a power of two, so corpora of
        similar size reuse the compile cache across runs."""
        if not len(images):
            raise ValueError("from_corpus needs at least one image")
        if mem_words is None:
            m = max(len(im) for im in images)
            mem_words = 1 << max(m - 1, 1).bit_length()
        if names is None:
            names = [f"case{i}" for i in range(len(images))]
        return cls.from_images(images, mem_words, names=names,
                               engine=engine)

    @staticmethod
    def _stack(states: Sequence[HartState]) -> HartState:
        if not states:
            raise ValueError("Fleet needs at least one hart")
        with _x64():
            return jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    # -- running ------------------------------------------------------------
    def run(self, max_ticks: int, chunk: int = 4096) -> "Fleet":
        """Advance the whole fleet through the selected engine backend.

        Bumps the run generation: every previously handed-out
        ``fleet.harts`` view is invalidated (the default engine donates
        the fleet buffers) and raises :class:`StaleHartsError` on access.
        """
        self._harts = self._engine.run(self._harts, max_ticks, chunk=chunk)
        self._generation += 1
        return self

    # -- gem5-style checkpoint / restore ------------------------------------
    def snapshot(self, path) -> str:
        """Persist the full fleet state as a versioned ``.npz`` checkpoint
        (every ``HartState`` leaf + ``HartSpec`` metadata + a schema-hash
        guard — :mod:`repro.core.hext.checkpoint`).  A restored fleet
        resumes bit-identically to an uninterrupted run."""
        from repro.core.hext import checkpoint
        return checkpoint.save(
            str(path), self._harts, self._specs,
            engine_name=getattr(self._engine, "name", "custom"))

    @classmethod
    def restore(cls, path, specs: Optional[Sequence[HartSpec]] = None,
                engine: Any = None) -> "Fleet":
        """Rebuild a fleet from a :meth:`snapshot` checkpoint.

        Specs are restored by workload *name* via the standard registry;
        pass ``specs=`` explicitly when the snapshot ran custom workload
        objects the registry cannot resolve.  Raises
        :class:`repro.core.hext.checkpoint.CheckpointError` on corrupted
        or schema-incompatible files."""
        from repro.core.hext import checkpoint
        harts, saved_specs = checkpoint.load(str(path),
                                             decode_specs=specs is None)
        if specs is None:
            specs = saved_specs
        specs = list(specs)
        n = int(harts.counters.done.shape[0])
        if len(specs) != n:
            raise ValueError(f"{len(specs)} specs for {n} restored harts")
        return cls(harts, specs, engine=engine)

    # -- live guest migration (the gem5 'switch CPU / move work' demo) ------
    def migrate_guest(self, src: int, dst: int, guest: int = 0) -> "Fleet":
        """Move a descheduled guest VM from hart `src` to hart `dst`.

        Lifts guest slot ``guest``'s entire migratable state out of the
        source hart's memory — saved context (GPRs + sepc + the VS CSR
        bank + the frozen virtual clock), private G-stage table block,
        64 KiB physical window (kernel + workload + VS tables + data),
        result mailbox, and scheduler info block — and injects it at the
        same addresses in the destination hart (`programs.guest_regions`).
        The destination's scheduler picks the guest up at its next switch
        and resumes it mid-flight; the context's frozen virtual time
        rebuilds ``htimedelta`` against the destination's own ``mtime``,
        so the guest's clock survives the move.  On the source the slot is
        marked done with a zeroed mailbox (migrated away), and both specs
        are updated so ``report()`` checks the guest's golden on its new
        hart.

        The destination slot's own tenant is **discarded**: its context,
        window, tables, and mailbox are overwritten and its spec entry is
        replaced by the migrated workload (the evacuation semantics the
        demo wants — migrate into a slot whose tenant has finished, or
        accept losing it).

        Preconditions (else :class:`MigrationError`): both slots are
        preemptive, neither hart has exited, both harts are paused while
        *executing guest code* (V=1 — a hart paused inside the HS
        scheduler may have a context switch in flight, making both
        ``SCHED_CUR`` and the context slots non-authoritative), and the
        guest is live and not currently scheduled on either hart.
        """
        from repro.core.hext import programs
        if src == dst:
            raise MigrationError("src and dst must be different harts")
        for i in (src, dst):
            if not (0 <= i < len(self._specs)):
                raise MigrationError(f"hart {i} out of range")
            if not self._specs[i].preemptive:
                raise MigrationError(
                    f"hart {i} ({self._specs[i].label}) is not a "
                    f"preemptive multi-guest slot")
        s_spec, d_spec = self._specs[src], self._specs[dst]
        n = len(s_spec.guests)
        if not 0 <= guest < n:
            raise MigrationError(f"guest {guest} out of range for N={n}")
        if s_spec.guests[guest] is None:
            raise MigrationError(
                f"hart {src} guest {guest} was already migrated away")
        lay = programs.sched_layout(n)
        with _x64():
            mem = np.array(self._harts.mem)       # writable host copy
            done = np.asarray(self._harts.counters.done)
            virt = np.asarray(self._harts.virt)
            for i in (src, dst):
                # paused in M firmware or inside the HS scheduler: a
                # context switch may be in flight (target chosen but
                # SCHED_CUR not yet updated), so neither SCHED_CUR
                # nor the context slots are authoritative
                self._check_guest_op(mem, done, virt, i, guest, "migrate")
            gi_done_w = (lay.ginfo0 + guest * programs.GINFO_SIZE + 24) >> 3
            if int(mem[src, gi_done_w]) != 0:
                raise MigrationError(
                    f"hart {src} guest {guest} already finished — "
                    f"nothing to migrate")
            for base, size in programs.guest_regions(lay, guest):
                w0, w1 = base >> 3, (base + size) >> 3
                mem[dst, w0:w1] = mem[src, w0:w1]
            # source: slot is gone — mark done, zero the mailbox so the
            # hart's combined exit checksum covers only remaining guests
            mem[src, gi_done_w] = 1
            mem[src, (lay.guest_res + 8 * guest) >> 3] = 0
            self._harts = self._harts.replace(mem=jnp.asarray(mem, U64))
        self._generation += 1          # invalidate handed-out views

        moved = s_spec.guests[guest]
        s_guests = tuple(None if k == guest else w
                         for k, w in enumerate(s_spec.guests))
        d_guests = tuple(moved if k == guest else w
                         for k, w in enumerate(d_spec.guests))
        self._respec_slot(src, s_guests)
        self._respec_slot(dst, d_guests)
        return self

    def _respec_slot(self, i: int, new_guests: tuple,
                     hole: str = "moved") -> None:
        """Rewrite slot i's spec after a guest-level mutation; ``hole``
        names empty (None) guest entries in the label."""
        spec = self._specs[i]
        name = "+".join(w.name if w is not None else hole
                        for w in new_guests)
        self._specs[i] = dataclasses.replace(
            spec, guests=new_guests, workload=new_guests[0], name=name)

    def _check_guest_op(self, mem, done, virt, hart: int, guest: int,
                        verb: str) -> None:
        """Shared park/resume precondition: the hart is paused while
        executing guest code and slot `guest` is not currently scheduled
        (same reasoning as :meth:`migrate_guest`)."""
        from repro.core.hext import programs
        if done[hart]:
            raise MigrationError(f"hart {hart} has already exited")
        if not bool(virt[hart]):
            raise MigrationError(
                f"hart {hart} is not executing guest code (V=0 — "
                f"possibly mid context-switch); run a little longer "
                f"and retry")
        if int(mem[hart, programs.SCHED_CUR >> 3]) == guest:
            raise MigrationError(
                f"guest {guest} is currently scheduled on hart {hart}; "
                f"{verb} only descheduled guests (run a little longer "
                f"and retry)")

    # -- guest park / resume (the control plane's evict + re-admit) ---------
    def park_guest(self, hart: int, guest: int, path) -> str:
        """Evict a descheduled guest VM to a per-guest checkpoint file.

        Lifts the same migratable region set :meth:`migrate_guest` moves —
        saved context, G-stage table block, 64 KiB window, result mailbox,
        and scheduler info block — out of the hart's memory into a
        versioned ``.npz`` (:func:`repro.core.hext.checkpoint.save_guest`):
        a migration whose destination is a file.  The slot is then marked
        done with a zeroed mailbox (parked away) and its spec entry
        cleared, exactly like a migration source.  :meth:`resume_guest`
        later splices the file into slot ``guest`` of any same-layout hart
        (the region addresses are slot-determined, so a parked guest must
        resume into the same slot index).

        Preconditions mirror :meth:`migrate_guest` (else
        :class:`MigrationError`): preemptive slot, hart not exited, hart
        paused while executing guest code (V=1), guest live and not
        currently scheduled.
        """
        from repro.core.hext import checkpoint, programs
        if not (0 <= hart < len(self._specs)):
            raise MigrationError(f"hart {hart} out of range")
        spec = self._specs[hart]
        if not spec.preemptive:
            raise MigrationError(
                f"hart {hart} ({spec.label}) is not a preemptive "
                f"multi-guest slot")
        n = len(spec.guests)
        if not 0 <= guest < n:
            raise MigrationError(f"guest {guest} out of range for N={n}")
        if spec.guests[guest] is None:
            raise MigrationError(f"hart {hart} guest {guest} is an "
                                 f"empty slot — nothing to park")
        lay = programs.sched_layout(n)
        with _x64():
            mem = np.array(self._harts.mem)       # writable host copy
            done = np.asarray(self._harts.counters.done)
            virt = np.asarray(self._harts.virt)
            self._check_guest_op(mem, done, virt, hart, guest, "park")
            gi_done_w = (lay.ginfo0 + guest * programs.GINFO_SIZE + 24) >> 3
            if int(mem[hart, gi_done_w]) != 0:
                raise MigrationError(
                    f"hart {hart} guest {guest} already finished — "
                    f"nothing to park")
            # the saved ginfo block carries done=0, so the region splice
            # alone revives the guest on resume
            regions = {
                name: mem[hart, base >> 3:(base + size) >> 3].copy()
                for name, (base, size) in zip(
                    checkpoint.GUEST_REGIONS,
                    programs.guest_regions(lay, guest))}
            out = checkpoint.save_guest(
                str(path), regions, n=n, slot=guest,
                timeslice=spec.timeslice,
                workload=getattr(spec.guests[guest], "name", None))
            mem[hart, gi_done_w] = 1
            mem[hart, (lay.guest_res + 8 * guest) >> 3] = 0
            self._harts = self._harts.replace(mem=jnp.asarray(mem, U64))
        self._generation += 1
        self._respec_slot(hart, tuple(
            None if k == guest else w
            for k, w in enumerate(spec.guests)), hole="parked")
        return out

    def resume_guest(self, hart: int, path,
                     workload: Optional[Any] = None) -> "Fleet":
        """Splice a parked guest checkpoint into its slot on hart `hart`.

        The checkpoint's region set is written at the slot-determined
        addresses (slot index comes from the file); the restored info
        block carries ``done=0``, so the destination scheduler picks the
        guest up at its next timer tick and resumes it mid-flight — the
        context's frozen virtual time rebuilds ``htimedelta`` against the
        destination's own clock, like :meth:`migrate_guest`.

        The destination slot must not be live: either a ``None`` entry
        (boot-time reservation, or a tenant that migrated/parked away) or
        a finished tenant — in the latter case the tenant's recorded
        mailbox result is overwritten, so harvest it first.  ``workload``
        sets the spec entry for golden checks; by default the stored
        workload name is resolved via the standard registry.

        Preconditions (else :class:`MigrationError`): preemptive slot
        with the checkpoint's layout (same N), hart not exited, hart
        paused while executing guest code (V=1), destination slot not
        live.
        """
        from repro.core.hext import checkpoint, programs
        regions, meta = checkpoint.load_guest(str(path))
        if not (0 <= hart < len(self._specs)):
            raise MigrationError(f"hart {hart} out of range")
        spec = self._specs[hart]
        if not spec.preemptive:
            raise MigrationError(
                f"hart {hart} ({spec.label}) is not a preemptive "
                f"multi-guest slot")
        n = len(spec.guests)
        if n != int(meta["n"]):
            raise MigrationError(
                f"guest checkpoint has an N={meta['n']} layout but hart "
                f"{hart} runs N={n}")
        guest = int(meta["slot"])
        if workload is None and meta.get("workload"):
            workload = checkpoint.workload_registry().get(meta["workload"])
        if workload is None:
            raise MigrationError(
                f"cannot resolve workload {meta.get('workload')!r} from "
                f"the guest checkpoint — pass workload= explicitly")
        lay = programs.sched_layout(n)
        with _x64():
            mem = np.array(self._harts.mem)       # writable host copy
            done = np.asarray(self._harts.counters.done)
            virt = np.asarray(self._harts.virt)
            self._check_guest_op(mem, done, virt, hart, guest, "resume")
            gi_done_w = (lay.ginfo0 + guest * programs.GINFO_SIZE + 24) >> 3
            if spec.guests[guest] is not None and \
                    int(mem[hart, gi_done_w]) == 0:
                raise MigrationError(
                    f"hart {hart} guest slot {guest} is still live — "
                    f"park or migrate it first")
            for name, (base, size) in zip(checkpoint.GUEST_REGIONS,
                                          programs.guest_regions(lay,
                                                                 guest)):
                mem[hart, base >> 3:(base + size) >> 3] = regions[name]
            self._harts = self._harts.replace(mem=jnp.asarray(mem, U64))
        self._generation += 1
        self._respec_slot(hart, tuple(
            workload if k == guest else w
            for k, w in enumerate(spec.guests)))
        return self

    def replace_hart(self, i: int, state: HartState,
                     spec: Optional[HartSpec] = None) -> "Fleet":
        """Splice one hart's full state (and optionally its spec) into the
        batch in place — the control plane's provision/recover primitive:
        lanes keep the fleet's compiled shapes (same batch size, same
        mem_words) while tenants come and go.  ``state`` must carry
        scalar (unbatched) leaves matching the fleet's per-hart shapes.
        """
        if not (0 <= i < len(self._specs)):
            raise ValueError(f"hart {i} out of range")
        with _x64():
            want = tuple(self._harts.mem.shape[1:])
            got = tuple(jnp.shape(state.mem))
            if got != want:
                raise ValueError(
                    f"hart {i}: state.mem shape {got} != fleet per-hart "
                    f"shape {want} (lanes must keep the compiled shape)")
            self._harts = jax.tree.map(
                lambda b, s: b.at[i].set(jnp.asarray(s, b.dtype)),
                self._harts, state)
        if spec is not None:
            self._specs[i] = spec
        self._generation += 1
        return self

    # -- inspection ---------------------------------------------------------
    @property
    def engine(self) -> Any:
        """The resolved execution backend this fleet runs on."""
        return self._engine

    @property
    def harts(self) -> "_HartsView":
        """Generation-checked view of the batched state (leading dim =
        fleet size).  ``fleet.run`` donates the underlying buffers, so a
        view taken *before* a run raises :class:`StaleHartsError` after
        it instead of silently reading stale (or freed) memory — re-read
        ``fleet.harts`` after each run.  Use ``.unwrap()`` (or
        ``fleet[i]``) when the raw pytree is needed."""
        return _HartsView(self, self._generation)

    @property
    def specs(self) -> List[HartSpec]:
        return list(self._specs)

    @property
    def all_done(self) -> bool:
        with _x64():
            return bool(jnp.all(self._harts.counters.done))

    def __len__(self) -> int:
        return len(self._specs)

    def __getitem__(self, i: int) -> HartState:
        """Per-hart view (scalar leaves) of slot `i`."""
        with _x64():
            return jax.tree.map(lambda x: x[i], self._harts)

    def counters(self) -> List[Counters]:
        """Per-hart :class:`Counters`, in fleet order."""
        with _x64():
            return [jax.tree.map(lambda x: x[i], self._harts.counters)
                    for i in range(len(self))]

    def _preempt_entry(self, i: int, spec: HartSpec,
                       c: Counters) -> Dict[str, Any]:
        """Report entry for an N-guest slot: per-guest checksum mailboxes
        are read straight from the hart's memory (the HS scheduler records
        each guest's result before combining them into the exit code).

        A ``None`` guest entry is a slot whose VM was migrated away
        (:meth:`migrate_guest`): its mailbox was zeroed, it contributes
        nothing to the expected combined checksum, and its ``ok_guests``
        entry reports ``None`` (not checked here — the VM's golden is
        checked on its destination hart)."""
        from repro.core.hext import programs
        n = len(spec.guests)
        lay = programs.sched_layout(n)
        with _x64():
            res_w = lay.guest_res // 8
            cks = [int(self._harts.mem[i, res_w + k]) & MASK64
                   for k in range(n)]
        goldens = [None if w is None else int(w.golden()) & MASK64
                   for w in spec.guests]
        oks = [None if g is None else ck == g
               for ck, g in zip(cks, goldens)]
        total = sum(g for g in goldens if g is not None) & MASK64
        entry = c.to_dict()
        entry.update({
            "golden": total,
            "guests": n,
            "checksums": cks,
            "ok_guests": oks,
            "ok": bool(c.done) and all(o for o in oks if o is not None)
            and c.ok(total),
            "timeslice": spec.timeslice,
        })
        if n == 2:       # legacy 2-guest report keys
            entry.update({"checksum_a": cks[0], "checksum_b": cks[1],
                          "ok_a": oks[0], "ok_b": oks[1]})
        return entry

    def report(self) -> Dict[str, Dict[str, Any]]:
        """``{label: counter-dict}`` with golden checks where known.

        Duplicate (workload, guest) slots get a ``#<slot>`` suffix so no
        hart's counters are silently dropped."""
        out: Dict[str, Dict[str, Any]] = {}
        for i, (spec, c) in enumerate(zip(self._specs, self.counters())):
            if spec.preemptive:
                entry = self._preempt_entry(i, spec, c)
            else:
                golden = spec.workload.golden() if spec.workload is not None \
                    else None
                entry = c.to_dict(golden)
                if golden is not None:
                    entry["golden"] = int(golden) & MASK64
            label = spec.label
            if label in out:
                label = f"{label}#{i}"
            out[label] = entry
        return out
