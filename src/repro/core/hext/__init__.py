# NOTE: `torture` is intentionally not imported eagerly — it is run as
# `python -m repro.core.hext.torture`, and an eager package import would
# double-execute the module under runpy.
from repro.core.hext import (checkpoint, csr, engine, isa,  # noqa: F401
                             machine, oracle, programs, sim, translate,
                             trap)
from repro.core.hext.checkpoint import CheckpointError  # noqa: F401
from repro.core.hext.engine import (Engine, JitEngine,  # noqa: F401
                                    OracleEngine, ShardedEngine,
                                    diff_states)
from repro.core.hext.sim import (Counters, Fleet, HartState,  # noqa: F401
                                 StaleHartsError)
