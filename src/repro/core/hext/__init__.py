# NOTE: `torture` is intentionally not imported eagerly — it is run as
# `python -m repro.core.hext.torture`, and an eager package import would
# double-execute the module under runpy.
from repro.core.hext import (csr, isa, machine, oracle,  # noqa: F401
                             programs, sim, translate, trap)
from repro.core.hext.sim import Counters, Fleet, HartState  # noqa: F401
