from repro.core.hext import (csr, isa, machine, programs, sim,  # noqa: F401
                             translate, trap)
from repro.core.hext.sim import Counters, Fleet, HartState  # noqa: F401
