from repro.core.hext import csr, isa, machine, programs, translate, trap  # noqa: F401
