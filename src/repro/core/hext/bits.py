"""Shared 64-bit helpers for the hext core.

One definition of the uint64/int64 casts, sign extension, word-granular
memory access, and sub-word extract/deposit that used to be copy-pasted
across ``isa.py`` / ``machine.py`` / ``translate.py`` / ``tlb.py``
(each module had its own ``_u``).  Everything is branchless jnp so it
traces into fixed graphs and vmaps over harts.

64-bit integer semantics require x64 mode; call sites own the
``jax.experimental.enable_x64()`` context (the sim facade and engines
do this in one place).
"""
from __future__ import annotations

import jax.numpy as jnp

U64 = jnp.uint64
I64 = jnp.int64
MASK64 = (1 << 64) - 1


def u64(x) -> jnp.ndarray:
    """Cast to uint64 (the architectural register width)."""
    return jnp.asarray(x, U64)


def i64(x) -> jnp.ndarray:
    """Cast to int64 (for signed compares/shifts)."""
    return jnp.asarray(x, I64)


def sext(x, bits: int):
    """Sign-extend the low `bits` of uint64 x (upper bits ignored)."""
    x = u64(x) & u64((1 << bits) - 1)
    m = u64(1 << (bits - 1))
    return (x ^ m) - m


def read64(mem, pa):
    """Aligned 64-bit word read at physical byte address `pa`.

    NOTE: the wrapped index is only a safe-indexing device for traced
    code; a PA beyond memory raises an access fault in the walker and at
    the final access, so the wrapped value is never architecturally
    visible.
    """
    return mem[(u64(pa) >> u64(3)).astype(jnp.int32) % mem.shape[0]]


def word_extract(word, pa, size_log2, unsigned):
    """Read 1/2/4/8 bytes out of an aligned 64-bit word (shared by RAM and
    the CLINT MMIO registers)."""
    off = (u64(pa) & u64(7)) << u64(3)           # bit offset
    v = word >> off
    nbits = u64(8) << u64(size_log2)
    mask = jnp.where(nbits >= u64(64), ~u64(0), (u64(1) << nbits) - u64(1))
    v = v & mask
    shift = u64(64) - nbits                      # dynamic sign extension
    sv = u64(i64(v << shift) >> shift.astype(I64))
    return jnp.where(unsigned, v, sv)


def word_deposit(word, pa, val, size_log2):
    """Merge a 1/2/4/8-byte store into an aligned 64-bit word."""
    off = (u64(pa) & u64(7)) << u64(3)
    nbits = u64(8) << u64(size_log2)
    mask = jnp.where(nbits >= 64, ~u64(0), (u64(1) << nbits) - u64(1))
    return (word & ~(mask << off)) | ((u64(val) & mask) << off)
