"""RV64 assembler, boot firmware, xvisor-lite hypervisor, and MiBench-like
guest workloads (paper §4).

Two system images per workload:

* **native** — M firmware → S kernel (Sv39, demand-paged data) → workload.
  Exceptions: data-page faults handled at S (medeleg), final ecall to M.
* **guest**  — M firmware → HS "xvisor-lite" (builds hgatp/hedeleg/hideleg,
  enters VS via sret+SPV) → VS kernel (vsatp Sv39, demand-paged) → same
  workload. Exceptions: VS-stage faults handled *by the guest* at VS
  (hedeleg), G-stage guest-page-faults handled by the hypervisor at HS
  (on-demand G-stage mapping), final guest ecall (cause 10) → HS shutdown.

Both run the *identical* workload code — the executed-instruction and
exception-count deltas are exactly the paper's Figures 5–7.

A third image family (``build_image_nguest``) boots N guests per hart
under a preemptive HS scheduler (time-sliced round-robin with per-guest
G-stage tables, 64 KiB windows, and htimedelta-virtualized clocks) — the
paper's cloud-consolidation scenario; see ``sched_layout`` / DESIGN.md
§2c.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

# ---------------------------------------------------------------------------
# register names
# ---------------------------------------------------------------------------
REG = {f"x{i}": i for i in range(32)}
REG.update(zero=0, ra=1, sp=2, gp=3, tp=4, t0=5, t1=6, t2=7, s0=8, fp=8,
           s1=9, a0=10, a1=11, a2=12, a3=13, a4=14, a5=15, a6=16, a7=17,
           s2=18, s3=19, s4=20, s5=21, s6=22, s7=23, s8=24, s9=25, s10=26,
           s11=27, t3=28, t4=29, t5=30, t6=31)


def _r(x):
    return REG[x] if isinstance(x, str) else int(x)


def _fit(v, bits):
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    return lo <= v <= hi


class Asm:
    """Tiny two-pass RV64 assembler (32-bit encodings only)."""

    def __init__(self, base: int):
        self.base = base
        self.words: list = []          # 32-bit ints or (label, encoder) fixups
        self.labels: dict = {}

    # -- infrastructure -----------------------------------------------------
    @property
    def pc(self) -> int:
        return self.base + 4 * len(self.words)

    def label(self, name: str):
        assert name not in self.labels, f"duplicate label {name!r}"
        self.labels[name] = self.pc
        return self

    def emit(self, w):
        self.words.append(w)

    def pad_to(self, addr: int):
        """NOP-pad up to `addr` (section alignment for handlers/bodies);
        asserts the current code has not already overrun it."""
        assert self.pc <= addr, hex(self.pc)
        while self.pc < addr:
            self.nop()
        return self

    def assemble(self) -> np.ndarray:
        out = []
        for i, w in enumerate(self.words):
            if isinstance(w, tuple):
                lab, enc = w
                target = self.labels[lab]
                out.append(enc(target, self.base + 4 * i))
            else:
                out.append(w)
        return np.array(out, dtype=np.uint32)

    # -- encoders -----------------------------------------------------------
    def _rtype(self, f7, rs2, rs1, f3, rd, op):
        self.emit((f7 << 25) | (_r(rs2) << 20) | (_r(rs1) << 15) |
                  (f3 << 12) | (_r(rd) << 7) | op)

    def _itype(self, imm, rs1, f3, rd, op):
        assert _fit(imm, 12), f"imm {imm} !fit12"
        self.emit(((imm & 0xFFF) << 20) | (_r(rs1) << 15) | (f3 << 12) |
                  (_r(rd) << 7) | op)

    def _stype(self, imm, rs2, rs1, f3, op):
        assert _fit(imm, 12)
        self.emit((((imm >> 5) & 0x7F) << 25) | (_r(rs2) << 20) |
                  (_r(rs1) << 15) | (f3 << 12) | ((imm & 0x1F) << 7) | op)

    def _utype(self, imm20, rd, op):
        self.emit(((imm20 & 0xFFFFF) << 12) | (_r(rd) << 7) | op)

    @staticmethod
    def _enc_b(imm, rs2, rs1, f3):
        return ((((imm >> 12) & 1) << 31) | (((imm >> 5) & 0x3F) << 25) |
                (_r(rs2) << 20) | (_r(rs1) << 15) | (f3 << 12) |
                (((imm >> 1) & 0xF) << 8) | (((imm >> 11) & 1) << 7) | 0x63)

    @staticmethod
    def _enc_j(imm, rd):
        return ((((imm >> 20) & 1) << 31) | (((imm >> 1) & 0x3FF) << 21) |
                (((imm >> 11) & 1) << 20) | (((imm >> 12) & 0xFF) << 12) |
                (_r(rd) << 7) | 0x6F)

    # -- ALU ----------------------------------------------------------------
    def addi(self, rd, rs1, imm): self._itype(imm, rs1, 0, rd, 0x13)
    def slti(self, rd, rs1, imm): self._itype(imm, rs1, 2, rd, 0x13)
    def sltiu(self, rd, rs1, imm): self._itype(imm, rs1, 3, rd, 0x13)
    def xori(self, rd, rs1, imm): self._itype(imm, rs1, 4, rd, 0x13)
    def ori(self, rd, rs1, imm): self._itype(imm, rs1, 6, rd, 0x13)
    def andi(self, rd, rs1, imm): self._itype(imm, rs1, 7, rd, 0x13)
    def slli(self, rd, rs1, sh): self._itype(sh, rs1, 1, rd, 0x13)
    def srli(self, rd, rs1, sh): self._itype(sh, rs1, 5, rd, 0x13)
    def srai(self, rd, rs1, sh): self._itype(sh | 0x400, rs1, 5, rd, 0x13)
    def addiw(self, rd, rs1, imm): self._itype(imm, rs1, 0, rd, 0x1B)
    def add(self, rd, rs1, rs2): self._rtype(0, rs2, rs1, 0, rd, 0x33)
    def sub(self, rd, rs1, rs2): self._rtype(0x20, rs2, rs1, 0, rd, 0x33)
    def sll(self, rd, rs1, rs2): self._rtype(0, rs2, rs1, 1, rd, 0x33)
    def slt(self, rd, rs1, rs2): self._rtype(0, rs2, rs1, 2, rd, 0x33)
    def sltu(self, rd, rs1, rs2): self._rtype(0, rs2, rs1, 3, rd, 0x33)
    def xor(self, rd, rs1, rs2): self._rtype(0, rs2, rs1, 4, rd, 0x33)
    def srl(self, rd, rs1, rs2): self._rtype(0, rs2, rs1, 5, rd, 0x33)
    def sra(self, rd, rs1, rs2): self._rtype(0x20, rs2, rs1, 5, rd, 0x33)
    def or_(self, rd, rs1, rs2): self._rtype(0, rs2, rs1, 6, rd, 0x33)
    def and_(self, rd, rs1, rs2): self._rtype(0, rs2, rs1, 7, rd, 0x33)
    def addw(self, rd, rs1, rs2): self._rtype(0, rs2, rs1, 0, rd, 0x3B)
    def subw(self, rd, rs1, rs2): self._rtype(0x20, rs2, rs1, 0, rd, 0x3B)
    def mul(self, rd, rs1, rs2): self._rtype(1, rs2, rs1, 0, rd, 0x33)
    def mulhu(self, rd, rs1, rs2): self._rtype(1, rs2, rs1, 3, rd, 0x33)
    def div(self, rd, rs1, rs2): self._rtype(1, rs2, rs1, 4, rd, 0x33)
    def divu(self, rd, rs1, rs2): self._rtype(1, rs2, rs1, 5, rd, 0x33)
    def rem(self, rd, rs1, rs2): self._rtype(1, rs2, rs1, 6, rd, 0x33)
    def remu(self, rd, rs1, rs2): self._rtype(1, rs2, rs1, 7, rd, 0x33)

    # -- memory ---------------------------------------------------------------
    def lb(self, rd, off, rs1): self._itype(off, rs1, 0, rd, 0x03)
    def lh(self, rd, off, rs1): self._itype(off, rs1, 1, rd, 0x03)
    def lw(self, rd, off, rs1): self._itype(off, rs1, 2, rd, 0x03)
    def ld(self, rd, off, rs1): self._itype(off, rs1, 3, rd, 0x03)
    def lbu(self, rd, off, rs1): self._itype(off, rs1, 4, rd, 0x03)
    def lhu(self, rd, off, rs1): self._itype(off, rs1, 5, rd, 0x03)
    def lwu(self, rd, off, rs1): self._itype(off, rs1, 6, rd, 0x03)
    def sb(self, rs2, off, rs1): self._stype(off, rs2, rs1, 0, 0x23)
    def sh(self, rs2, off, rs1): self._stype(off, rs2, rs1, 1, 0x23)
    def sw(self, rs2, off, rs1): self._stype(off, rs2, rs1, 2, 0x23)
    def sd(self, rs2, off, rs1): self._stype(off, rs2, rs1, 3, 0x23)

    # -- control --------------------------------------------------------------
    def lui(self, rd, imm20): self._utype(imm20, rd, 0x37)
    def auipc(self, rd, imm20): self._utype(imm20, rd, 0x17)

    def _branch(self, lab, rs1, rs2, f3):
        self.emit((lab, lambda t, pc, rs1=rs1, rs2=rs2, f3=f3:
                   Asm._enc_b(t - pc, rs2, rs1, f3)))

    def beq(self, rs1, rs2, lab): self._branch(lab, rs1, rs2, 0)
    def bne(self, rs1, rs2, lab): self._branch(lab, rs1, rs2, 1)
    def blt(self, rs1, rs2, lab): self._branch(lab, rs1, rs2, 4)
    def bge(self, rs1, rs2, lab): self._branch(lab, rs1, rs2, 5)
    def bltu(self, rs1, rs2, lab): self._branch(lab, rs1, rs2, 6)
    def bgeu(self, rs1, rs2, lab): self._branch(lab, rs1, rs2, 7)
    def beqz(self, rs1, lab): self.beq(rs1, "zero", lab)
    def bnez(self, rs1, lab): self.bne(rs1, "zero", lab)

    def jal(self, rd, lab):
        self.emit((lab, lambda t, pc, rd=rd: Asm._enc_j(t - pc, rd)))

    def j(self, lab): self.jal("zero", lab)
    def call(self, lab): self.jal("ra", lab)

    def jalr(self, rd, off, rs1): self._itype(off, rs1, 0, rd, 0x67)
    def ret(self): self.jalr("zero", 0, "ra")
    def nop(self): self.addi("zero", "zero", 0)
    def mv(self, rd, rs): self.addi(rd, rs, 0)

    # -- system ---------------------------------------------------------------
    def csrrw(self, rd, csr, rs1): self._itype_csr(csr, rs1, 1, rd)
    def csrrs(self, rd, csr, rs1): self._itype_csr(csr, rs1, 2, rd)
    def csrrc(self, rd, csr, rs1): self._itype_csr(csr, rs1, 3, rd)
    def csrrwi(self, rd, csr, z): self._itype_csr(csr, z, 5, rd, zimm=True)
    def csrrsi(self, rd, csr, z): self._itype_csr(csr, z, 6, rd, zimm=True)
    def csrrci(self, rd, csr, z): self._itype_csr(csr, z, 7, rd, zimm=True)

    def _itype_csr(self, csr, rs1, f3, rd, zimm=False):
        v = rs1 if zimm else _r(rs1)
        self.emit(((csr & 0xFFF) << 20) | (v << 15) | (f3 << 12) |
                  (_r(rd) << 7) | 0x73)

    def csrw(self, csr, rs1): self.csrrw("zero", csr, rs1)
    def csrr(self, rd, csr): self.csrrs(rd, csr, "zero")

    def ecall(self): self.emit(0x00000073)
    def ebreak(self): self.emit(0x00100073)
    def sret(self): self.emit(0x10200073)
    def mret(self): self.emit(0x30200073)
    def wfi(self): self.emit(0x10500073)
    # fences: rs1≠x0 requests an address-scoped invalidation (the VA —
    # or GPA>>2 for gvma — in rs1); rs1=x0 is the full-scope form
    def sfence_vma(self, rs1=0, rs2=0):
        self._rtype(0x09, rs2, rs1, 0, 0, 0x73)

    def hfence_vvma(self, rs1=0, rs2=0):
        self._rtype(0x11, rs2, rs1, 0, 0, 0x73)

    def hfence_gvma(self, rs1=0, rs2=0):
        self._rtype(0x31, rs2, rs1, 0, 0, 0x73)

    # hypervisor loads/stores
    def hlv_b(self, rd, rs1): self._rtype(0x30, 0, rs1, 4, rd, 0x73)
    def hlv_bu(self, rd, rs1): self._rtype(0x30, 1, rs1, 4, rd, 0x73)
    def hlv_h(self, rd, rs1): self._rtype(0x32, 0, rs1, 4, rd, 0x73)
    def hlv_hu(self, rd, rs1): self._rtype(0x32, 1, rs1, 4, rd, 0x73)
    def hlvx_hu(self, rd, rs1): self._rtype(0x32, 3, rs1, 4, rd, 0x73)
    def hlv_w(self, rd, rs1): self._rtype(0x34, 0, rs1, 4, rd, 0x73)
    def hlv_wu(self, rd, rs1): self._rtype(0x34, 1, rs1, 4, rd, 0x73)
    def hlvx_wu(self, rd, rs1): self._rtype(0x34, 3, rs1, 4, rd, 0x73)
    def hlv_d(self, rd, rs1): self._rtype(0x36, 0, rs1, 4, rd, 0x73)
    def hsv_b(self, rs2, rs1): self._rtype(0x31, rs2, rs1, 4, 0, 0x73)
    def hsv_h(self, rs2, rs1): self._rtype(0x33, rs2, rs1, 4, 0, 0x73)
    def hsv_w(self, rs2, rs1): self._rtype(0x35, rs2, rs1, 4, 0, 0x73)
    def hsv_d(self, rs2, rs1): self._rtype(0x37, rs2, rs1, 4, 0, 0x73)

    # -- pseudo: li (x31/t6 is assembler scratch for 64-bit) ------------------
    def li(self, rd, imm):
        imm = int(imm)
        if _fit(imm, 12):
            self.addi(rd, "zero", imm)
            return
        if -(1 << 31) <= imm < (1 << 31):
            self._li32(rd, imm)
            return
        lo = imm & 0xFFFFFFFF
        lo_s = lo - (1 << 32) if lo >= (1 << 31) else lo
        hi = ((imm - lo_s) >> 32) & 0xFFFFFFFF
        hi_s = hi - (1 << 32) if hi >= (1 << 31) else hi
        self._li32(rd, hi_s)
        self.slli(rd, rd, 32)
        if lo_s != 0:
            self._li32("t6", lo_s)
            self.add(rd, rd, "t6")

    def _li32(self, rd, v):
        if _fit(v, 12):
            self.addi(rd, "zero", v)
            return
        upper = (v + 0x800) >> 12
        lower = v - (upper << 12)
        self.lui(rd, upper & 0xFFFFF)
        if lower:
            self.addiw(rd, rd, lower)


# ---------------------------------------------------------------------------
# memory image builder + page tables
# ---------------------------------------------------------------------------

PTE_V, PTE_R, PTE_W, PTE_X, PTE_U, PTE_A, PTE_D = 1, 2, 4, 8, 16, 64, 128
P_KERN = PTE_V | PTE_R | PTE_W | PTE_X | PTE_A | PTE_D            # 0xCF
P_GUEST = P_KERN | PTE_U                                          # 0xDF


class Image:
    def __init__(self, mem_words: int):
        self.mem = np.zeros((mem_words,), dtype=np.uint64)

    def place_code(self, base: int, words32: np.ndarray):
        assert base % 8 == 0
        n = len(words32)
        pad = words32 if n % 2 == 0 else np.append(words32, np.uint32(0x13))
        pairs = pad.reshape(-1, 2).astype(np.uint64)
        w64 = pairs[:, 0] | (pairs[:, 1] << np.uint64(32))
        self.mem[base // 8: base // 8 + len(w64)] = w64

    def store64(self, addr: int, val: int):
        assert addr % 8 == 0
        self.mem[addr // 8] = np.uint64(val & 0xFFFFFFFFFFFFFFFF)

    def store_bytes(self, addr: int, data: bytes):
        for i, b in enumerate(data):
            a = addr + i
            w = self.mem[a // 8]
            sh = np.uint64((a % 8) * 8)
            w = (w & ~(np.uint64(0xFF) << sh)) | (np.uint64(b) << sh)
            self.mem[a // 8] = w

    def pte(self, pa: int, perms: int) -> int:
        return ((pa >> 12) << 10) | perms

    def map_page(self, l0_base: int, va: int, pa: int, perms: int):
        vpn0 = (va >> 12) & 0x1FF
        self.store64(l0_base + vpn0 * 8, self.pte(pa, perms))

    def link(self, table_base: int, idx: int, child_pa: int):
        self.store64(table_base + idx * 8, self.pte(child_pa, PTE_V))


# ---------------------------------------------------------------------------
# memory map (byte addresses; identity VA=PA=GPA throughout)
# ---------------------------------------------------------------------------
M_BOOT = 0x0000
M_HANDLER = 0x0200
HS_ENTRY = 0x0400
HS_HANDLER = 0x0800
KERN_ENTRY = 0x1000        # S (native) or VS (guest) kernel
KERN_HANDLER = 0x1400
WORKLOAD = 0x1800          # workload code (pages 1 & 2: 0x1000-0x2FFF)
SAVE_S = 0x2F00            # register save area for S/VS handler
SAVE_HS = 0x2F40
RESULT = 0x2F80            # checksum mailbox (mapped code page → no fault)
DATA = 0x3000              # demand-paged data: pages 0x3000..0x7FFF
STACK_TOP = 0x7F00
# native / VS-stage page tables
S_L2, S_L1, S_L0 = 0x8000, 0x9000, 0xA000
# G-stage tables (root 16K-aligned, 4 pages wide: Sv39x4)
G_L2, G_L1, G_L0 = 0x10000, 0x14000, 0x15000
MEM_WORDS = 1 << 15        # 256 KiB

MMIO_DONE = 0x10000008
MMIO_CTXSW = 0x10000010

SATP_SV39 = 8 << 60

# ---------------------------------------------------------------------------
# preemptive N-guest layout (paper §3.2 cloud scenario: time-sliced VMs).
# The M/HS region keeps the single-guest map; each guest gets a private
# 64 KiB host-physical window and a private G-stage table set, and the
# HS scheduler round-robins between them on timer interrupts.  Everything
# below SCHED_CUR is code; the 0x2000..0x4000 region holds scheduler state
# (computed per N by `sched_layout`), then the per-guest G-stage table
# blocks, then the guest windows.
# ---------------------------------------------------------------------------
HS2_HANDLER = 0x0800       # scheduler trap handler (code may run past 0x1000)
SCHED_CUR = 0x2000         # current guest index
SCHED_CURCTX = 0x2008      # &ctx[cur]
SCHED_CURGI = 0x2010       # &ginfo[cur]
SCHED_N = 0x2018           # guest count
GINFO0 = 0x2040            # per-guest {hgatp, g_l0, window, done} blocks
GINFO_SIZE = 0x40
GUEST_RES = 0x2100         # per-guest checksum mailboxes (N=2 layout)
CTX0 = 0x2200              # per-guest saved context (N=2 layout)
CTX_SIZE = 0x200
CTX_PC = 0x100             # byte offset of the sepc slot inside a context
GTAB0 = 0x4000             # first per-guest G-stage table block
GTAB_STRIDE = 0x8000       # 16K root + L1 + L0 pages (+ slack), 16K-aligned
G2_L2 = (0x4000, 0xC000)   # legacy N=2 table addresses (== sched_layout(2))
G2_L1 = (0x8000, 0x10000)
G2_L0 = (0x9000, 0x11000)
GUEST_WIN = 0x10000        # 64 KiB of guest-physical space per guest
PB = (0x20000, 0x30000)    # legacy N=2 window bases (== sched_layout(2))
DEFAULT_TIMESLICE = 1000   # ticks between preemptions
MAX_GUESTS = 8             # HS boot code must fit below HS2_HANDLER

# saved per guest at CTX_PC + 8*i: sepc (guest pc) then the VS CSR bank
# (vstimecmp included — an armed guest timer must not leak to its sibling)
_VS_CTX_CSRS = (0x141, 0x200, 0x205, 0x240, 0x241, 0x242, 0x243, 0x280,
                0x24D)
# one more slot: the guest's frozen virtual time (mtime + htimedelta at
# deschedule); on resume the scheduler rebuilds htimedelta from it
CTX_VTIME = CTX_PC + 8 * len(_VS_CTX_CSRS)


class SchedLayout(NamedTuple):
    """Computed memory map for an N-guests-per-hart scheduler image.

    For n == 2 every field equals the legacy module-level constants, so the
    committed 2-guest benchmark golden stays reproducible."""
    n: int
    ginfo0: int            # per-guest info blocks (GINFO_SIZE each)
    guest_res: int         # per-guest checksum mailboxes (8 bytes each)
    ctx0: int              # per-guest context save slots (CTX_SIZE each)
    g_l2: tuple            # per-guest Sv39x4 roots (16 KiB, 16K-aligned)
    g_l1: tuple
    g_l0: tuple
    win: tuple             # per-guest host-physical window bases
    mem_words: int         # total image size in 64-bit words


def _align(x: int, a: int) -> int:
    return -(-x // a) * a


def sched_layout(n: int) -> SchedLayout:
    """Memory map for an N-guest scheduler image (1 ≤ n ≤ MAX_GUESTS)."""
    if not 1 <= n <= MAX_GUESTS:
        raise ValueError(f"guests_per_hart must be in 1..{MAX_GUESTS}, "
                         f"got {n}")
    ginfo_end = GINFO0 + n * GINFO_SIZE
    guest_res = max(GUEST_RES, _align(ginfo_end, 0x40))
    ctx0 = max(CTX0, _align(guest_res + 8 * n, 0x100))
    assert ctx0 + n * CTX_SIZE <= GTAB0, "context area overruns G tables"
    g_l2 = tuple(GTAB0 + i * GTAB_STRIDE for i in range(n))
    g_l1 = tuple(b + 0x4000 for b in g_l2)
    g_l0 = tuple(b + 0x5000 for b in g_l2)
    win0 = max(0x20000, _align(GTAB0 + n * GTAB_STRIDE, GUEST_WIN))
    win = tuple(win0 + i * GUEST_WIN for i in range(n))
    return SchedLayout(n=n, ginfo0=GINFO0, guest_res=guest_res, ctx0=ctx0,
                       g_l2=g_l2, g_l1=g_l1, g_l0=g_l0, win=win,
                       mem_words=(win0 + n * GUEST_WIN) // 8)


def guest_regions(lay: SchedLayout, g: int):
    """Byte ``(start, length)`` regions holding guest `g`'s entire
    migratable state in an N-guest scheduler image: saved context slot,
    G-stage table block, host-physical window, result mailbox, and the
    scheduler's per-guest info block.  ``Fleet.migrate_guest`` copies
    exactly these regions between harts — the addresses are identical on
    any hart with the same layout, and window-offset G-stage leaves stay
    valid because ``lay.win[g]`` is layout-determined, not hart-local."""
    if not 0 <= g < lay.n:
        raise ValueError(f"guest {g} out of range for N={lay.n}")
    return ((lay.ctx0 + g * CTX_SIZE, CTX_SIZE),
            (lay.g_l2[g], GTAB_STRIDE),
            (lay.win[g], GUEST_WIN),
            (lay.guest_res + 8 * g, 8),
            (lay.ginfo0 + g * GINFO_SIZE, GINFO_SIZE))


def _build_kernel_pts(img: Image, perms: int):
    """Identity map of kernel/code/PT pages; data pages left invalid
    (demand-paged). Used for both the native satp tables and the guest's
    VS-stage tables (same layout, same GPAs)."""
    img.link(S_L2, 0, S_L1)
    img.link(S_L1, 0, S_L0)
    # code pages 0x0000-0x2FFF + PT pages + result area
    for page in range(0x0, 0x3000, 0x1000):
        img.map_page(S_L0, page, page, perms)
    for page in (S_L2, S_L1, S_L0):
        img.map_page(S_L0, page, page, perms)


def _build_gstage_pts(img: Image):
    """G-stage: fully demand-paged — only the non-leaf table links exist.
    EVERY first guest touch of a page (fetch, data, even the guest's own
    VS-stage page-table reads → implicit faults with pseudo-tinst) exits to
    the hypervisor, which maps the leaf on demand. This is the xvisor-style
    lazy stage-2 population that drives the paper's Fig 6/7 exception
    profile."""
    img.link(G_L2, 0, G_L1)
    img.link(G_L1, 0, G_L0)


# ---------------------------------------------------------------------------
# firmware / kernels / hypervisor
# ---------------------------------------------------------------------------

def _m_firmware(native: bool, counteren: bool = False) -> Asm:
    a = Asm(M_BOOT)
    a.li("t0", M_HANDLER)
    a.csrw(0x305, "t0")                       # mtvec
    if counteren:
        # open the counters (time/cycle/instret) to HS and below — the
        # scheduler hypervisor reads `time` to arm its slice timer.  The
        # single-guest firmware leaves mcounteren at its reset value (0) so
        # those images stay bit-identical to the pre-counteren goldens.
        a.li("t0", 7)
        a.csrw(0x306, "t0")                   # mcounteren: CY|TM|IR
    if native:
        # delegate S-level page faults + illegal etc to S; keep ecall-S at M
        a.li("t0", (1 << 12) | (1 << 13) | (1 << 15) | (1 << 8))
        a.csrw(0x302, "t0")                   # medeleg
    else:
        # delegate everything the hypervisor needs: page faults, guest page
        # faults, virtual instruction, ecall-U, ecall-VS → HS
        a.li("t0", (1 << 12) | (1 << 13) | (1 << 15) | (1 << 8) |
             (1 << 20) | (1 << 21) | (1 << 23) | (1 << 22) | (1 << 10))
        a.csrw(0x302, "t0")
        a.li("t0", 0x222)
        a.csrw(0x303, "t0")                   # mideleg (S bits; VS forced)
    # mstatus.MPP=S
    a.li("t0", 1 << 11)
    a.csrrs(0, 0x300, "t0")
    a.li("t0", KERN_ENTRY if native else HS_ENTRY)
    a.csrw(0x341, "t0")                       # mepc
    a.mret()
    # M trap handler: ecall-from-S(9) → DONE(a0); anything else → DONE(cause)
    a.pad_to(M_HANDLER)
    a.label("m_handler")
    a.csrr("t0", 0x342)                       # mcause
    a.li("t1", 9)
    a.beq("t0", "t1", "m_done_ok")
    a.li("t1", MMIO_DONE)
    a.sd("t0", 0, "t1")                       # exit with cause (error)
    a.label("m_spin")
    a.j("m_spin")
    a.label("m_done_ok")
    a.li("t1", MMIO_DONE)
    a.sd("a0", 0, "t1")
    a.label("m_spin2")
    a.j("m_spin2")
    return a


def _hypervisor() -> Asm:
    """xvisor-lite: HS-mode type-1 hypervisor (guest setup + exit handling)."""
    a = Asm(HS_ENTRY)
    a.li("sp", SAVE_HS + 0x30)
    a.li("t0", HS_HANDLER)
    a.csrw(0x105, "t0")                       # stvec (HS)
    # hgatp: Sv39x4 root
    a.li("t0", SATP_SV39 | (G_L2 >> 12))
    a.csrw(0x680, "t0")
    a.hfence_gvma()
    # hedeleg: let the guest handle its own VS-stage page faults + ecall-U
    a.li("t0", (1 << 12) | (1 << 13) | (1 << 15) | (1 << 8))
    a.csrw(0x602, "t0")
    # hideleg: delegate VS interrupts to the guest
    a.li("t0", 0x444)
    a.csrw(0x603, "t0")
    # hstatus: SPV=1 | SPVP=1 (return into VS S-mode)
    a.li("t0", (1 << 7) | (1 << 8))
    a.csrw(0x600, "t0")
    # sstatus.SPP=1
    a.li("t0", 1 << 8)
    a.csrrs(0, 0x100, "t0")
    a.li("t0", KERN_ENTRY)
    a.csrw(0x141, "t0")                       # sepc → guest entry
    a.sret()                                  # enter VS

    a.pad_to(HS_HANDLER)
    # ---- HS trap handler ---------------------------------------------------
    a.label("hs_handler")
    # save (t6 first — it is the li-scratch and must survive nested traps)
    a.csrw(0x140, "t6")                       # sscratch ← t6
    a.li("t6", SAVE_HS)
    a.sd("t0", 0, "t6")
    a.sd("t1", 8, "t6")
    a.sd("t2", 16, "t6")
    a.csrr("t0", 0x142)                       # scause
    a.li("t1", 10)
    a.beq("t0", "t1", "hs_shutdown")          # ecall from VS → done
    # guest page fault? (20/21/23)
    a.li("t1", 21)
    a.beq("t0", "t1", "hs_map")
    a.li("t1", 23)
    a.beq("t0", "t1", "hs_map")
    a.li("t1", 20)
    a.beq("t0", "t1", "hs_map")
    # unexpected → shutdown with cause
    a.li("t1", MMIO_DONE)
    a.sd("t0", 0, "t1")
    a.label("hs_spin")
    a.j("hs_spin")
    a.label("hs_map")                         # on-demand G-stage mapping
    # xvisor-lite accounting: per-exit bookkeeping (scheduler credit decay)
    a.li("t2", 12)
    a.label("hs_acct")
    a.addi("t2", "t2", -1)
    a.bnez("t2", "hs_acct")
    a.csrr("t0", 0x643)                       # htval = GPA >> 2
    a.slli("t0", "t0", 2)                     # GPA
    a.srli("t1", "t0", 12)
    a.andi("t1", "t1", 0x1FF)                 # vpn0
    a.slli("t1", "t1", 3)
    a.li("t2", G_L0)
    a.add("t1", "t1", "t2")
    a.srli("t2", "t0", 12)
    a.slli("t2", "t2", 10)
    a.ori("t2", "t2", P_GUEST)
    a.sd("t2", 0, "t1")                       # write G-stage PTE
    a.hfence_gvma()
    # restore + retry faulting instruction
    a.li("t6", SAVE_HS)
    a.ld("t0", 0, "t6")
    a.ld("t1", 8, "t6")
    a.ld("t2", 16, "t6")
    a.csrr("t6", 0x140)                       # t6 ← sscratch
    a.sret()
    a.label("hs_shutdown")
    a.li("t1", MMIO_DONE)
    a.sd("a0", 0, "t1")                       # checksum from guest a0
    a.label("hs_spin2")
    a.j("hs_spin2")
    return a


def _scheduler_hypervisor(timeslice: int, n: int = 2, live=None) -> Asm:
    """xvisor-lite with a preemptive round-robin scheduler: N guests per
    hart, time-sliced on the HS timer (stimecmp/STI), VSTI-style injection
    left to the guests' own vstimecmp.  Each guest owns a host-physical
    window and a private G-stage table set; on-demand G-stage mapping adds
    the window offset so every guest sees the same guest-physical map.

    Round-robin is the generalized ``next = (cur + 1) % N`` with finished
    guests skipped; when no *other* guest is live the timer only re-arms.
    Each guest also gets a virtualized time base: on deschedule the
    scheduler records the guest's virtual time (``mtime + htimedelta``) in
    its context, and on resume rebuilds ``htimedelta`` so guest time
    excludes the ticks it spent descheduled.

    ``live`` (default: all slots) marks which slots boot with a guest.  A
    dead slot's ginfo.done flag is initialized to 1, so the round-robin
    skips it exactly like a finished guest — until the control plane parks
    a checkpointed guest into the slot and clears the flag, at which point
    the next timer tick schedules it.  The emitted code is bit-identical
    to the pre-``live`` scheduler when every slot is live."""
    lay = sched_layout(n)
    if live is None:
        live = (True,) * n
    live = tuple(bool(x) for x in live)
    if len(live) != n:
        raise ValueError(f"live mask has {len(live)} entries for n={n}")
    if not any(live):
        raise ValueError("at least one scheduler slot must boot live")
    entry = live.index(True)
    a = Asm(HS_ENTRY)
    a.li("t0", HS2_HANDLER)
    a.csrw(0x105, "t0")                       # stvec (HS)
    # per-guest info blocks: {hgatp, G-stage L0, window base, done}
    for i in range(n):
        a.li("t0", lay.ginfo0 + i * GINFO_SIZE)
        a.li("t1", SATP_SV39 | (lay.g_l2[i] >> 12))
        a.sd("t1", 0, "t0")
        a.li("t1", lay.g_l0[i])
        a.sd("t1", 8, "t0")
        a.li("t1", lay.win[i])
        a.sd("t1", 16, "t0")
        if live[i]:
            a.sd("zero", 24, "t0")
        else:
            a.li("t1", 1)                     # dead slot: born finished
            a.sd("t1", 24, "t0")
    # scheduler state: the first live guest is current
    a.li("t0", SCHED_CUR)
    if entry == 0:
        a.sd("zero", 0, "t0")
    else:
        a.li("t1", entry)
        a.sd("t1", 0, "t0")
    a.li("t1", lay.ctx0 + entry * CTX_SIZE)
    a.sd("t1", 8, "t0")                       # SCHED_CURCTX
    a.li("t1", lay.ginfo0 + entry * GINFO_SIZE)
    a.sd("t1", 16, "t0")                      # SCHED_CURGI
    a.li("t1", n)
    a.sd("t1", 24, "t0")                      # SCHED_N
    # live non-entry guests first activate at the kernel entry (ctx
    # GPRs/CSRs and the virtual-time slot stay zero: their clocks start at
    # ~0 on resume); the saved vstimecmp must start DISARMED, not 0
    for i in range(n):
        if i == entry or not live[i]:
            continue
        a.li("t0", lay.ctx0 + i * CTX_SIZE)
        a.li("t1", KERN_ENTRY)
        a.sd("t1", CTX_PC, "t0")
        a.li("t1", -1)
        a.sd("t1", CTX_PC + 8 * _VS_CTX_CSRS.index(0x24D), "t0")
    # hedeleg: guests handle their own VS-stage page faults + ecall-U
    a.li("t0", (1 << 12) | (1 << 13) | (1 << 15) | (1 << 8))
    a.csrw(0x602, "t0")
    a.li("t0", 0x444)
    a.csrw(0x603, "t0")                       # hideleg: VS interrupts → VS
    a.li("t0", 7)
    a.csrw(0x606, "t0")                       # hcounteren: guests read time
    a.li("t0", SATP_SV39 | (lay.g_l2[entry] >> 12))
    a.csrw(0x680, "t0")                       # hgatp ← entry guest
    a.hfence_gvma()
    # arm the scheduler timer: sie.STIE, stimecmp = time + slice (STI stays
    # at HS — hideleg cannot delegate it — and preempts VS regardless of the
    # guest's own interrupt enables)
    a.li("t0", 1 << 5)
    a.csrrs(0, 0x104, "t0")                   # sie.STIE
    a.csrr("t0", 0xC01)                       # time
    a.li("t1", timeslice)
    a.add("t0", "t0", "t1")
    a.csrw(0x14D, "t0")                       # stimecmp
    # guest 0's clock starts at 0: htimedelta = -time
    a.csrr("t0", 0xC01)
    a.sub("t0", "zero", "t0")
    a.csrw(0x605, "t0")                       # htimedelta
    # enter guest 0
    a.li("t0", (1 << 7) | (1 << 8))           # hstatus.SPV|SPVP
    a.csrw(0x600, "t0")
    a.li("t0", 1 << 8)
    a.csrrs(0, 0x100, "t0")                   # sstatus.SPP
    a.li("t0", KERN_ENTRY)
    a.csrw(0x141, "t0")                       # sepc
    a.sret()

    a.pad_to(HS2_HANDLER)
    # ---- scheduler trap handler --------------------------------------------
    a.label("h2_handler")
    a.csrw(0x140, "t6")                       # sscratch ← t6 (li scratch)
    a.li("t6", SCHED_CURCTX)
    a.ld("t6", 0, "t6")                       # t6 = current guest's ctx
    a.sd("t0", 8 * 5, "t6")                   # park t0-t3 in their ctx slots
    a.sd("t1", 8 * 6, "t6")
    a.sd("t2", 8 * 7, "t6")
    a.sd("t3", 8 * 28, "t6")
    a.csrr("t0", 0x142)                       # scause
    a.blt("t0", "zero", "h2_timer")           # interrupt → only STI enabled
    a.li("t1", 10)
    a.beq("t0", "t1", "h2_exit")              # ecall from VS → guest done
    a.li("t1", 21)
    a.beq("t0", "t1", "h2_map")
    a.li("t1", 23)
    a.beq("t0", "t1", "h2_map")
    a.li("t1", 20)
    a.beq("t0", "t1", "h2_map")
    a.li("t1", MMIO_DONE)                     # unexpected → die loudly
    a.sd("t0", 0, "t1")
    a.label("h2_spin")
    a.j("h2_spin")

    # ---- on-demand G-stage mapping (window-offset xvisor-lite page-in) ----
    a.label("h2_map")
    a.csrr("t0", 0x643)                       # htval = GPA >> 2
    a.slli("t0", "t0", 2)                     # GPA
    # isolation: a GPA outside the guest's 64 KiB window must never be
    # mapped (it would land in a sibling guest's window or wrap into HS
    # memory) — kill the machine with the offending GPA as exit code
    a.li("t1", GUEST_WIN)
    a.bltu("t0", "t1", "h2_map_ok")
    a.li("t1", MMIO_DONE)
    a.sd("t0", 0, "t1")
    a.j("h2_spin")
    a.label("h2_map_ok")
    a.srli("t1", "t0", 12)
    a.andi("t1", "t1", 0x1FF)                 # vpn0
    a.slli("t1", "t1", 3)
    a.li("t2", SCHED_CURGI)
    a.ld("t2", 0, "t2")
    a.ld("t2", 8, "t2")                       # current guest's G-stage L0
    a.add("t1", "t1", "t2")                   # &PTE
    a.li("t2", SCHED_CURGI)
    a.ld("t2", 0, "t2")
    a.ld("t2", 16, "t2")                      # window base
    a.add("t0", "t0", "t2")                   # HPA = GPA + window
    a.srli("t0", "t0", 12)
    a.slli("t0", "t0", 10)
    a.ori("t0", "t0", P_GUEST)
    a.sd("t0", 0, "t1")                       # write G-stage leaf
    a.hfence_gvma()
    a.label("h2_ret")                         # restore t0-t3/t6 → guest
    a.li("t6", SCHED_CURCTX)
    a.ld("t6", 0, "t6")
    a.ld("t0", 8 * 5, "t6")
    a.ld("t1", 8 * 6, "t6")
    a.ld("t2", 8 * 7, "t6")
    a.ld("t3", 8 * 28, "t6")
    a.csrr("t6", 0x140)
    a.sret()

    # ---- timer tick: round-robin preemption --------------------------------
    # scan (cur+1) % n, (cur+2) % n, … for the first live guest; coming
    # back around to cur means nobody else runs → re-arm and resume cur.
    a.label("h2_timer")
    a.li("t6", SCHED_CUR)
    a.ld("t0", 0, "t6")                       # cur
    a.ld("t1", 24, "t6")                      # n
    a.mv("t2", "t0")                          # cand ← cur
    a.label("h2_scan")
    a.addi("t2", "t2", 1)
    a.blt("t2", "t1", "h2_scan_ck")
    a.li("t2", 0)                             # wrap: next = (cand+1) % n
    a.label("h2_scan_ck")
    a.beq("t2", "t0", "h2_rearm")             # full circle → only cur lives
    a.slli("t3", "t2", 6)                     # × GINFO_SIZE
    a.li("t6", lay.ginfo0)
    a.add("t3", "t3", "t6")
    a.ld("t3", 24, "t3")                      # ginfo[cand].done
    a.bnez("t3", "h2_scan")
    a.j("h2_save_switch")                     # t2 = next live guest

    a.label("h2_rearm")
    a.csrr("t0", 0xC01)
    a.li("t1", timeslice)
    a.add("t0", "t0", "t1")
    a.csrw(0x14D, "t0")
    a.j("h2_ret")

    a.label("h2_save_switch")                 # save the full guest context
    a.li("t6", SCHED_CURCTX)
    a.ld("t6", 0, "t6")
    for r in range(1, 31):
        if r in (5, 6, 7, 28):                # t0-t3 already parked
            continue
        a.sd(f"x{r}", 8 * r, "t6")
    a.csrr("t0", 0x140)                       # original t6
    a.sd("t0", 8 * 31, "t6")
    for i, csr in enumerate(_VS_CTX_CSRS):    # sepc + VS CSR bank
        a.csrr("t0", csr)
        a.sd("t0", CTX_PC + 8 * i, "t6")
    a.csrr("t0", 0xC01)                       # freeze the guest's clock:
    a.csrr("t3", 0x605)                       # vtime = mtime + htimedelta
    a.add("t0", "t0", "t3")
    a.sd("t0", CTX_VTIME, "t6")
    # fall through: t2 = target guest index

    a.label("h2_switch_to")                   # (also the exit-handoff path)
    a.li("t0", SCHED_CUR)
    a.sd("t2", 0, "t0")                       # cur ← target
    a.slli("t1", "t2", 9)                     # × CTX_SIZE
    a.li("t3", lay.ctx0)
    a.add("t1", "t1", "t3")
    a.sd("t1", 8, "t0")                       # SCHED_CURCTX
    a.slli("t3", "t2", 6)                     # × GINFO_SIZE
    a.li("t4", lay.ginfo0)
    a.add("t3", "t3", "t4")
    a.sd("t3", 16, "t0")                      # SCHED_CURGI
    a.ld("t4", 0, "t3")
    a.csrw(0x680, "t4")                       # hgatp ← target's root
    a.hfence_gvma()
    a.mv("t6", "t1")                          # t6 = target's ctx
    for i, csr in enumerate(_VS_CTX_CSRS):
        a.ld("t0", CTX_PC + 8 * i, "t6")
        a.csrw(csr, "t0")
    a.ld("t0", CTX_VTIME, "t6")               # resume the guest's clock:
    a.csrr("t3", 0xC01)                       # htimedelta = vtime - mtime
    a.sub("t0", "t0", "t3")
    a.csrw(0x605, "t0")
    a.csrw(0x645, "zero")                     # drop stale VS pending bits
    a.li("t0", MMIO_CTXSW)                    # count the context switch
    a.sd("zero", 0, "t0")
    a.csrr("t0", 0xC01)                       # re-arm the slice
    a.li("t1", timeslice)
    a.add("t0", "t0", "t1")
    a.csrw(0x14D, "t0")
    a.li("t0", (1 << 7) | (1 << 8))
    a.csrrs(0, 0x600, "t0")                   # hstatus.SPV|SPVP
    a.li("t0", 1 << 8)
    a.csrrs(0, 0x100, "t0")                   # sstatus.SPP
    for r in range(1, 31):
        a.ld(f"x{r}", 8 * r, "t6")
    a.ld("x31", 8 * 31, "t6")                 # ctx base restored last
    a.sret()

    # ---- guest exit: record checksum, hand off or shut down ---------------
    a.label("h2_exit")
    a.li("t0", SCHED_CUR)
    a.ld("t1", 0, "t0")                       # cur
    a.slli("t2", "t1", 3)
    a.li("t0", lay.guest_res)
    a.add("t2", "t2", "t0")
    a.sd("a0", 0, "t2")                       # mailbox[cur] ← checksum
    a.slli("t2", "t1", 6)
    a.li("t0", lay.ginfo0)
    a.add("t2", "t2", "t0")
    a.li("t0", 1)
    a.sd("t0", 24, "t2")                      # ginfo[cur].done = 1
    # scan for the next live guest (same round-robin order as the timer)
    a.li("t6", SCHED_CUR)
    a.ld("t0", 0, "t6")                       # cur
    a.ld("t1", 24, "t6")                      # n
    a.mv("t2", "t0")
    a.label("h2_exit_scan")
    a.addi("t2", "t2", 1)
    a.blt("t2", "t1", "h2_exit_ck")
    a.li("t2", 0)
    a.label("h2_exit_ck")
    a.beq("t2", "t0", "h2_all_done")          # full circle → fleet done
    a.slli("t3", "t2", 6)
    a.li("t6", lay.ginfo0)
    a.add("t3", "t3", "t6")
    a.ld("t3", 24, "t3")
    a.bnez("t3", "h2_exit_scan")
    a.j("h2_switch_to")                       # hand off (no save: cur done)

    a.label("h2_all_done")                    # combined checksum → DONE
    a.li("t0", lay.guest_res)
    a.li("t1", n)
    a.li("t2", 0)                             # acc
    a.li("t3", 0)                             # i
    a.label("h2_sum")
    a.slli("t4", "t3", 3)
    a.add("t4", "t4", "t0")
    a.ld("t4", 0, "t4")
    a.add("t2", "t2", "t4")
    a.addi("t3", "t3", 1)
    a.blt("t3", "t1", "h2_sum")
    a.li("t0", MMIO_DONE)
    a.sd("t2", 0, "t0")
    a.label("h2_spin2")
    a.j("h2_spin2")
    assert a.pc <= SCHED_CUR, hex(a.pc)
    return a


def _kernel(native: bool) -> Asm:
    """S-mode kernel (native) == VS-mode guest kernel (identical code):
    set stvec, enable paging, run the workload, ecall with checksum."""
    a = Asm(KERN_ENTRY)
    a.li("t0", KERN_HANDLER)
    a.csrw(0x105, "t0")                       # stvec (or vstvec via swap)
    a.li("t0", SATP_SV39 | (S_L2 >> 12))
    a.csrw(0x180, "t0")                       # satp (or vsatp via swap)
    a.sfence_vma()
    a.li("sp", STACK_TOP)
    a.call("workload_entry")
    # a0 = checksum
    a.li("t0", RESULT)
    a.sd("a0", 0, "t0")
    a.ecall()                                 # native → M; guest → HS
    a.label("k_spin")
    a.j("k_spin")

    a.pad_to(KERN_HANDLER)
    # ---- S/VS page-fault handler: demand-map 4K identity page -------------
    a.label("k_handler")
    a.csrw(0x140, "t6")                       # sscratch (vsscratch when V=1)
    a.li("t6", SAVE_S)
    a.sd("t0", 0, "t6")
    a.sd("t1", 8, "t6")
    a.sd("t2", 16, "t6")
    a.csrr("t0", 0x142)                       # scause (vscause via swap)
    a.li("t1", 13)
    a.beq("t0", "t1", "k_map")
    a.li("t1", 15)
    a.beq("t0", "t1", "k_map")
    a.li("t1", 12)
    a.beq("t0", "t1", "k_map")
    # unexpected: die loudly — write cause then stall
    a.li("t1", RESULT)
    a.sd("t0", 0, "t1")
    a.label("k_spin2")
    a.j("k_spin2")
    a.label("k_map")
    a.csrr("t0", 0x143)                       # stval (vstval)
    a.srli("t1", "t0", 12)
    a.andi("t1", "t1", 0x1FF)
    a.slli("t1", "t1", 3)
    a.li("t2", S_L0)
    a.add("t1", "t1", "t2")
    a.srli("t2", "t0", 12)
    a.slli("t2", "t2", 10)
    a.ori("t2", "t2", P_KERN)
    a.sd("t2", 0, "t1")
    a.sfence_vma()
    a.li("t6", SAVE_S)
    a.ld("t0", 0, "t6")
    a.ld("t1", 8, "t6")
    a.ld("t2", 16, "t6")
    a.csrr("t6", 0x140)
    a.sret()
    return a


# ---------------------------------------------------------------------------
# MiBench-like workloads. Each defines asm(a) and golden() → checksum.
# Code must start at label "workload_entry" and return checksum in a0.
# ---------------------------------------------------------------------------

def _lcg(seed):
    return (seed * 6364136223846793005 + 1442695040888963407) % (1 << 64)


class Workload:
    name = "base"
    data: dict = {}

    def asm(self, a: Asm):
        raise NotImplementedError

    def golden(self) -> int:
        raise NotImplementedError

    def write_data(self, img: Image):
        pass


class BitCount(Workload):
    """MiBench automotive/bitcount: Kernighan popcount over an LCG stream."""
    name = "bitcount"
    N = 96

    def asm(self, a):
        a.label("workload_entry")
        a.li("a0", 0)                  # acc
        a.li("t0", 0)                  # i
        a.li("t1", self.N)
        a.li("t2", 0x9E3779B97F4A7C15)  # golden-ratio stride
        a.li("t3", 0)                  # x state
        a.label("bc_loop")
        a.add("t3", "t3", "t2")
        a.mv("t4", "t3")
        a.label("bc_pop")
        a.beqz("t4", "bc_done")
        a.addi("t5", "t4", -1)
        a.and_("t4", "t4", "t5")
        a.addi("a0", "a0", 1)
        a.j("bc_pop")
        a.label("bc_done")
        a.addi("t0", "t0", 1)
        a.blt("t0", "t1", "bc_loop")
        a.ret()

    def golden(self):
        acc, x = 0, 0
        for _ in range(self.N):
            x = (x + 0x9E3779B97F4A7C15) % (1 << 64)
            acc += bin(x).count("1")
        return acc


class BasicMath(Workload):
    """MiBench automotive/basicmath: isqrt (Newton) + gcd over a range."""
    name = "basicmath"
    N = 28

    def asm(self, a):
        a.label("workload_entry")
        a.li("a0", 0)
        a.li("s0", 1)                  # i
        a.li("s1", self.N)
        a.label("bm_loop")
        # isqrt(i*2655 + 17) by integer Newton (8 iters)
        a.li("t0", 2655)
        a.mul("t0", "s0", "t0")
        a.addi("t0", "t0", 17)         # v
        a.mv("t1", "t0")               # x = v
        a.li("t2", 8)                  # iters
        a.label("bm_newton")
        a.beqz("t1", "bm_nzero")
        a.divu("t3", "t0", "t1")       # v/x
        a.add("t1", "t1", "t3")
        a.srli("t1", "t1", 1)          # x = (x + v/x)/2
        a.label("bm_nzero")
        a.addi("t2", "t2", -1)
        a.bnez("t2", "bm_newton")
        a.add("a0", "a0", "t1")
        # gcd(i*7919, i+1000)
        a.li("t0", 7919)
        a.mul("t0", "s0", "t0")
        a.addi("t1", "s0", 1000)
        a.label("bm_gcd")
        a.beqz("t1", "bm_gcd_done")
        a.remu("t2", "t0", "t1")
        a.mv("t0", "t1")
        a.mv("t1", "t2")
        a.j("bm_gcd")
        a.label("bm_gcd_done")
        a.add("a0", "a0", "t0")
        a.addi("s0", "s0", 1)
        a.bge("s1", "s0", "bm_loop")
        a.ret()

    def golden(self):
        import math
        acc = 0
        for i in range(1, self.N + 1):
            v = i * 2655 + 17
            x = v
            for _ in range(8):
                if x:
                    x = (x + v // x) // 2
            acc += x
            acc += math.gcd(i * 7919, i + 1000)
        return acc


class QSort(Workload):
    """MiBench automotive/qsort: insertion sort of LCG values (ld/sd heavy)."""
    name = "qsort"
    N = 40
    BASE = DATA

    def asm(self, a):
        a.label("workload_entry")
        a.li("s0", self.BASE)
        # generate
        a.li("t0", 0)
        a.li("t1", self.N)
        a.li("t2", 12345)
        a.li("t3", 6364136223846793005)
        a.li("t4", 1442695040888963407)
        a.label("qs_gen")
        a.mul("t2", "t2", "t3")
        a.add("t2", "t2", "t4")
        a.srli("t5", "t2", 16)         # positive-ish value
        a.slli("s2", "t0", 3)
        a.add("s2", "s2", "s0")
        a.sd("t5", 0, "s2")
        a.addi("t0", "t0", 1)
        a.blt("t0", "t1", "qs_gen")
        # insertion sort
        a.li("s1", 1)                  # i
        a.label("qs_outer")
        a.bge("s1", "t1", "qs_done")
        a.slli("s2", "s1", 3)
        a.add("s2", "s2", "s0")
        a.ld("s3", 0, "s2")            # key
        a.mv("s4", "s1")               # j
        a.label("qs_inner")
        a.beqz("s4", "qs_insert")
        a.addi("s5", "s4", -1)
        a.slli("s6", "s5", 3)
        a.add("s6", "s6", "s0")
        a.ld("s7", 0, "s6")
        a.bgeu("s3", "s7", "qs_insert")
        a.slli("s8", "s4", 3)
        a.add("s8", "s8", "s0")
        a.sd("s7", 0, "s8")
        a.mv("s4", "s5")
        a.j("qs_inner")
        a.label("qs_insert")
        a.slli("s8", "s4", 3)
        a.add("s8", "s8", "s0")
        a.sd("s3", 0, "s8")
        a.addi("s1", "s1", 1)
        a.j("qs_outer")
        a.label("qs_done")
        # checksum: sum of arr[i]*i
        a.li("a0", 0)
        a.li("t0", 0)
        a.label("qs_ck")
        a.slli("s2", "t0", 3)
        a.add("s2", "s2", "s0")
        a.ld("s3", 0, "s2")
        a.mul("s3", "s3", "t0")
        a.add("a0", "a0", "s3")
        a.addi("t0", "t0", 1)
        a.blt("t0", "t1", "qs_ck")
        a.ret()

    def golden(self):
        vals = []
        x = 12345
        for _ in range(self.N):
            x = _lcg(x)
            vals.append(x >> 16)
        vals.sort()
        return sum((v * i) % (1 << 64) for i, v in enumerate(vals)) % (1 << 64)


class Susan(Workload):
    """MiBench automotive/susan: 3×3 brightness stencil over a byte image."""
    name = "susan"
    W, H = 20, 12
    BASE = DATA + 0x800

    def write_data(self, img: Image):
        rng = np.random.RandomState(7)
        self.pix = rng.randint(0, 256, size=(self.H, self.W)).astype(np.uint8)
        img.store_bytes(self.BASE, self.pix.tobytes())

    def asm(self, a):
        W, H = self.W, self.H
        a.label("workload_entry")
        a.li("a0", 0)
        a.li("s0", self.BASE)
        a.li("s1", 1)                  # y
        a.label("su_y")
        a.li("t0", H - 1)
        a.bge("s1", "t0", "su_done")
        a.li("s2", 1)                  # x
        a.label("su_x")
        a.li("t0", W - 1)
        a.bge("s2", "t0", "su_next_y")
        # sum 3x3 neighbourhood
        a.li("s3", 0)                  # acc3x3
        a.li("s4", -1)                 # dy
        a.label("su_dy")
        a.li("t0", 2)
        a.bge("s4", "t0", "su_have")
        a.li("s5", -1)                 # dx
        a.label("su_dx")
        a.li("t0", 2)
        a.bge("s5", "t0", "su_next_dy")
        a.add("t1", "s1", "s4")        # y+dy
        a.li("t2", W)
        a.mul("t1", "t1", "t2")
        a.add("t1", "t1", "s2")
        a.add("t1", "t1", "s5")        # idx
        a.add("t1", "t1", "s0")
        a.lbu("t2", 0, "t1")
        a.add("s3", "s3", "t2")
        a.addi("s5", "s5", 1)
        a.j("su_dx")
        a.label("su_next_dy")
        a.addi("s4", "s4", 1)
        a.j("su_dy")
        a.label("su_have")
        a.add("a0", "a0", "s3")
        a.addi("s2", "s2", 1)
        a.j("su_x")
        a.label("su_next_y")
        a.addi("s1", "s1", 1)
        a.j("su_y")
        a.label("su_done")
        a.ret()

    def golden(self):
        acc = 0
        p = self.pix.astype(np.int64)
        for y in range(1, self.H - 1):
            for x in range(1, self.W - 1):
                acc += int(p[y - 1:y + 2, x - 1:x + 2].sum())
        return acc % (1 << 64)


class SHA(Workload):
    """MiBench security/sha: rotate/xor/add mixing rounds."""
    name = "sha"
    N = 160

    def asm(self, a):
        a.label("workload_entry")
        a.li("a0", 0x67452301)
        a.li("t0", 0)
        a.li("t1", self.N)
        a.li("t2", 0x5A827999)
        a.label("sh_loop")
        # a0 = rotl(a0,5) ^ (a0 + t2 + i)
        a.slli("t3", "a0", 5)
        a.srli("t4", "a0", 59)
        a.or_("t3", "t3", "t4")
        a.add("t5", "a0", "t2")
        a.add("t5", "t5", "t0")
        a.xor("a0", "t3", "t5")
        a.addi("t0", "t0", 1)
        a.blt("t0", "t1", "sh_loop")
        a.ret()

    def golden(self):
        M = (1 << 64) - 1
        h = 0x67452301
        for i in range(self.N):
            rot = ((h << 5) | (h >> 59)) & M
            h = rot ^ ((h + 0x5A827999 + i) & M)
        return h


class CRC32(Workload):
    """MiBench telecomm/crc32: bitwise CRC over bytes."""
    name = "crc32"
    N = 48
    BASE = DATA + 0x1000

    def write_data(self, img: Image):
        rng = np.random.RandomState(11)
        self.buf = rng.randint(0, 256, size=self.N).astype(np.uint8)
        img.store_bytes(self.BASE, self.buf.tobytes())

    def asm(self, a):
        a.label("workload_entry")
        a.li("a0", 0xFFFFFFFF)
        a.li("s0", self.BASE)
        a.li("t0", 0)
        a.li("t1", self.N)
        a.li("s1", 0xEDB88320)
        a.label("cr_byte")
        a.add("t2", "s0", "t0")
        a.lbu("t3", 0, "t2")
        a.xor("a0", "a0", "t3")
        a.li("t4", 8)
        a.label("cr_bit")
        a.andi("t5", "a0", 1)
        a.srli("a0", "a0", 1)
        a.beqz("t5", "cr_nox")
        a.xor("a0", "a0", "s1")
        a.label("cr_nox")
        a.addi("t4", "t4", -1)
        a.bnez("t4", "cr_bit")
        a.addi("t0", "t0", 1)
        a.blt("t0", "t1", "cr_byte")
        a.ret()

    def golden(self):
        crc = 0xFFFFFFFF
        for b in self.buf:
            crc ^= int(b)
            for _ in range(8):
                lsb = crc & 1
                crc >>= 1
                if lsb:
                    crc ^= 0xEDB88320
        return crc


class Dijkstra(Workload):
    """MiBench network/dijkstra: dense relaxation over a K×K matrix."""
    name = "dijkstra"
    K = 10
    BASE = DATA + 0x1800

    def write_data(self, img: Image):
        rng = np.random.RandomState(3)
        self.adj = rng.randint(1, 100, size=(self.K, self.K)).astype(np.int64)
        np.fill_diagonal(self.adj, 0)
        for i in range(self.K):
            for j in range(self.K):
                img.store64(self.BASE + (i * self.K + j) * 8,
                            int(self.adj[i, j]))

    def asm(self, a):
        K = self.K
        a.label("workload_entry")
        a.li("s0", self.BASE)
        # Floyd-Warshall-style triple loop (bounded Dijkstra analogue)
        a.li("s1", 0)                  # k
        a.label("dj_k")
        a.li("t0", K)
        a.bge("s1", "t0", "dj_done")
        a.li("s2", 0)                  # i
        a.label("dj_i")
        a.li("t0", K)
        a.bge("s2", "t0", "dj_next_k")
        a.li("s3", 0)                  # j
        a.label("dj_j")
        a.li("t0", K)
        a.bge("s3", "t0", "dj_next_i")
        # d[i][j] = min(d[i][j], d[i][k]+d[k][j])
        a.li("t0", K)
        a.mul("t1", "s2", "t0")
        a.add("t1", "t1", "s3")
        a.slli("t1", "t1", 3)
        a.add("t1", "t1", "s0")        # &d[i][j]
        a.ld("t2", 0, "t1")
        a.mul("t3", "s2", "t0")
        a.add("t3", "t3", "s1")
        a.slli("t3", "t3", 3)
        a.add("t3", "t3", "s0")
        a.ld("t3", 0, "t3")            # d[i][k]
        a.mul("t4", "s1", "t0")
        a.add("t4", "t4", "s3")
        a.slli("t4", "t4", 3)
        a.add("t4", "t4", "s0")
        a.ld("t4", 0, "t4")            # d[k][j]
        a.add("t3", "t3", "t4")
        a.bge("t3", "t2", "dj_skip")
        a.sd("t3", 0, "t1")
        a.label("dj_skip")
        a.addi("s3", "s3", 1)
        a.j("dj_j")
        a.label("dj_next_i")
        a.addi("s2", "s2", 1)
        a.j("dj_i")
        a.label("dj_next_k")
        a.addi("s1", "s1", 1)
        a.j("dj_k")
        a.label("dj_done")
        # checksum = sum d[i][j]
        a.li("a0", 0)
        a.li("s1", 0)
        a.li("t0", K * K)
        a.label("dj_ck")
        a.slli("t1", "s1", 3)
        a.add("t1", "t1", "s0")
        a.ld("t1", 0, "t1")
        a.add("a0", "a0", "t1")
        a.addi("s1", "s1", 1)
        a.blt("s1", "t0", "dj_ck")
        a.ret()

    def golden(self):
        d = self.adj.copy()
        K = self.K
        for k in range(K):
            for i in range(K):
                for j in range(K):
                    if d[i, k] + d[k, j] < d[i, j]:
                        d[i, j] = d[i, k] + d[k, j]
        return int(d.sum()) % (1 << 64)


class StringSearch(Workload):
    """MiBench office/stringsearch: naive pattern scan."""
    name = "stringsearch"
    TEXT = (b"the quick brown fox jumps over the lazy dog and then the fox "
            b"runs away to the forest where the other foxes live happily ")
    PAT = b"fox"
    BASE = DATA + 0x2000

    def write_data(self, img: Image):
        img.store_bytes(self.BASE, self.TEXT)
        img.store_bytes(self.BASE + 0x400, self.PAT)

    def asm(self, a):
        n, m = len(self.TEXT), len(self.PAT)
        a.label("workload_entry")
        a.li("a0", 0)                  # match count
        a.li("s0", self.BASE)
        a.li("s1", self.BASE + 0x400)
        a.li("t0", 0)                  # i
        a.li("t1", n - m + 1)
        a.label("ss_outer")
        a.bge("t0", "t1", "ss_done")
        a.li("t2", 0)                  # j
        a.label("ss_inner")
        a.li("t3", m)
        a.bge("t2", "t3", "ss_match")
        a.add("t4", "s0", "t0")
        a.add("t4", "t4", "t2")
        a.lbu("t5", 0, "t4")
        a.add("t4", "s1", "t2")
        a.lbu("t6", 0, "t4")           # (t6 is scratch but safe here: no li)
        a.bne("t5", "t6", "ss_next")
        a.addi("t2", "t2", 1)
        a.j("ss_inner")
        a.label("ss_match")
        a.addi("a0", "a0", 1)
        a.label("ss_next")
        a.addi("t0", "t0", 1)
        a.j("ss_outer")
        a.label("ss_done")
        a.ret()

    def golden(self):
        return self.TEXT.count(self.PAT)


class FFT(Workload):
    """MiBench telecomm/fft: fixed-point butterfly-style mixing."""
    name = "fft"
    N = 64
    BASE = DATA + 0x2800

    def write_data(self, img: Image):
        rng = np.random.RandomState(5)
        self.re = rng.randint(-1000, 1000, size=self.N).astype(np.int64)
        self.im = rng.randint(-1000, 1000, size=self.N).astype(np.int64)
        for i in range(self.N):
            img.store64(self.BASE + i * 8, int(self.re[i]) & ((1 << 64) - 1))
            img.store64(self.BASE + (self.N + i) * 8,
                        int(self.im[i]) & ((1 << 64) - 1))

    def asm(self, a):
        N = self.N
        a.label("workload_entry")
        a.li("s0", self.BASE)
        a.li("s1", self.BASE + N * 8)
        # butterfly pass: (re,im)[i] ⊗ twiddle(i) accumulated
        a.li("a0", 0)
        a.li("t0", 0)
        a.li("t1", N)
        a.li("s2", 987)                # tw_re
        a.li("s3", -654)               # tw_im
        a.label("ff_loop")
        a.slli("t2", "t0", 3)
        a.add("t3", "t2", "s0")
        a.ld("t4", 0, "t3")            # re
        a.add("t3", "t2", "s1")
        a.ld("t5", 0, "t3")            # im
        # out_re = (re*tw_re - im*tw_im) >> 10
        a.mul("s4", "t4", "s2")
        a.mul("s5", "t5", "s3")
        a.sub("s4", "s4", "s5")
        a.srai("s4", "s4", 10)
        # out_im = (re*tw_im + im*tw_re) >> 10
        a.mul("s6", "t4", "s3")
        a.mul("s7", "t5", "s2")
        a.add("s6", "s6", "s7")
        a.srai("s6", "s6", 10)
        a.xor("s8", "s4", "s6")
        a.add("a0", "a0", "s8")
        a.addi("t0", "t0", 1)
        a.blt("t0", "t1", "ff_loop")
        a.ret()

    def golden(self):
        M = (1 << 64) - 1
        acc = 0
        for i in range(self.N):
            re, im = int(self.re[i]), int(self.im[i])
            out_re = (re * 987 - im * (-654)) >> 10
            out_im = (re * (-654) + im * 987) >> 10
            acc = (acc + (out_re ^ out_im)) & M
        return acc


class Patricia(Workload):
    """MiBench network/patricia (analogue): bit-trie insert/search mix."""
    name = "patricia"
    N = 48

    def asm(self, a):
        a.label("workload_entry")
        a.li("a0", 0)
        a.li("t0", 0)
        a.li("t1", self.N)
        a.li("t2", 0xDEADBEEF12345678)
        a.label("pa_loop")
        # key = lcg step; walk 16 bits, accumulate path parity
        a.li("t3", 6364136223846793005)
        a.mul("t2", "t2", "t3")
        a.li("t3", 1442695040888963407)
        a.add("t2", "t2", "t3")
        a.mv("t4", "t2")
        a.li("t5", 16)
        a.label("pa_bits")
        a.andi("t3", "t4", 1)
        a.add("a0", "a0", "t3")
        a.srli("t4", "t4", 1)
        a.addi("t5", "t5", -1)
        a.bnez("t5", "pa_bits")
        a.addi("t0", "t0", 1)
        a.blt("t0", "t1", "pa_loop")
        a.ret()

    def golden(self):
        acc, x = 0, 0xDEADBEEF12345678
        for _ in range(self.N):
            x = _lcg(x)
            acc += bin(x & 0xFFFF).count("1")
        return acc


class Idle(Workload):
    """Balloon guest for the control plane: a finite busy-loop with
    checksum 0.  `FleetService` boots one as the host tenant of a
    resume-only hart when parked guests have no live hart to land on —
    the scheduler needs at least one live slot to boot, and the balloon
    keeps the round-robin alive for a few timeslices while checkpointed
    guests are spliced into the reserved (`None`) slots."""
    name = "idle"
    N = 6000

    def asm(self, a):
        a.label("workload_entry")
        a.li("a0", 0)
        a.li("t0", self.N)
        a.label("id_loop")
        a.addi("t0", "t0", -1)
        a.bnez("t0", "id_loop")
        a.ret()

    def golden(self):
        return 0


WORKLOADS = [BitCount(), BasicMath(), QSort(), Susan(), SHA(), CRC32(),
             Dijkstra(), StringSearch(), FFT()]
WORKLOADS_EXTRA = [Patricia(), Idle()]


# ---------------------------------------------------------------------------
# image builders
# ---------------------------------------------------------------------------

def build_image(workload: Workload, guest: bool) -> np.ndarray:
    """Full bootable memory image (native or guest/VM run)."""
    img = Image(MEM_WORDS)
    fw = _m_firmware(native=not guest)
    img.place_code(M_BOOT, fw.assemble())
    if guest:
        hv = _hypervisor()
        img.place_code(HS_ENTRY, hv.assemble())
    kern = _kernel(native=not guest)
    wl = Asm(WORKLOAD)
    workload.asm(wl)
    kern.labels["workload_entry"] = WORKLOAD
    img.place_code(KERN_ENTRY, kern.assemble())
    img.place_code(WORKLOAD, wl.assemble())
    workload.write_data(img)
    _build_kernel_pts(img, P_KERN)
    if guest:
        _build_gstage_pts(img)
    return img.mem


class _GuestWindow:
    """Image view that places guest-physical content at a host-physical
    window: writes are offset by the window base, while PTE contents keep
    guest-physical ppns (the G-stage adds the offset at run time)."""

    def __init__(self, img: Image, base: int):
        self.img, self.base = img, base

    def store64(self, addr: int, val: int):
        self.img.store64(self.base + addr, val)

    def store_bytes(self, addr: int, data: bytes):
        self.img.store_bytes(self.base + addr, data)

    def place_code(self, base: int, words32: np.ndarray):
        self.img.place_code(self.base + base, words32)

    def pte(self, pa: int, perms: int) -> int:
        return self.img.pte(pa, perms)            # GPA ppn, no offset

    def map_page(self, l0_base: int, va: int, pa: int, perms: int):
        vpn0 = (va >> 12) & 0x1FF
        self.store64(l0_base + vpn0 * 8, self.pte(pa, perms))

    def link(self, table_base: int, idx: int, child_pa: int):
        self.store64(table_base + idx * 8, self.pte(child_pa, PTE_V))


def build_image_nguest(workloads, timeslice: int = DEFAULT_TIMESLICE
                       ) -> np.ndarray:
    """Bootable image running N guest VMs per hart under the preemptive
    scheduler: M firmware → HS scheduler-hypervisor → N VS guests
    round-robin on timer interrupts.  Each guest gets the standard guest
    system image (kernel + workload + VS-stage tables) inside its own
    host-physical window, and a private demand-populated G-stage set.  The
    image size grows with N (`sched_layout(n).mem_words`).

    Entries may be ``None``: such a slot boots parked (ginfo.done = 1, no
    window content, no G-stage links) — a reservation the control plane
    can later fill with a checkpointed guest via ``Fleet.resume_guest``."""
    wls = list(workloads)
    live = tuple(wl is not None for wl in wls)
    lay = sched_layout(len(wls))
    img = Image(lay.mem_words)
    img.place_code(M_BOOT, _m_firmware(native=False,
                                       counteren=True).assemble())
    img.place_code(HS_ENTRY,
                   _scheduler_hypervisor(timeslice, n=len(wls),
                                         live=live).assemble())
    for i, wl in enumerate(wls):
        if wl is None:
            continue
        win = _GuestWindow(img, lay.win[i])
        kern = _kernel(native=False)
        w = Asm(WORKLOAD)
        wl.asm(w)
        kern.labels["workload_entry"] = WORKLOAD
        win.place_code(KERN_ENTRY, kern.assemble())
        win.place_code(WORKLOAD, w.assemble())
        wl.write_data(win)
        _build_kernel_pts(win, P_KERN)
        # G-stage skeleton: non-leaf links only — every leaf is mapped on
        # demand by the scheduler, with the window offset applied
        img.link(lay.g_l2[i], 0, lay.g_l1[i])
        img.link(lay.g_l1[i], 0, lay.g_l0[i])
    return img.mem


def build_image_2guest(wl_a: Workload, wl_b: Workload,
                       timeslice: int = DEFAULT_TIMESLICE) -> np.ndarray:
    """Legacy 2-guest entry point — thin wrapper over the N-guest builder."""
    return build_image_nguest((wl_a, wl_b), timeslice=timeslice)


def boot_state(workload: Workload, guest: bool):
    """Typed `HartState` ready to run (import here to keep numpy-only
    users import-light).  Legacy raw-dict consumers: call ``.to_raw()``."""
    from repro.core.hext.sim import HartState
    return HartState.boot(workload, guest=guest)
