"""CSR file for RV64 + H extension (paper §3.1, Table 1).

Storage is a flat uint64 vector per hart, indexed by the ``R_*`` constants.
Architectural behaviors implemented bit-accurately:

* READ masks (some fields read as zero at lower privileges),
* WRITE masks (WARL: read-only fields are preserved on write — the paper's
  added "WRITE REGISTERS MASKS"),
* aliasing (``sstatus`` ⊂ ``mstatus``; ``sip/sie`` ⊂ ``mip/mie``;
  ``hvip/hip/hie`` alias the VS bits of ``mip/mie``; ``vsip/vsie`` are the
  VS bits *shifted down by 1* so the guest sees them at S positions),
* VS swapping: with V=1, supervisor CSR numbers access the ``vs*`` bank
  (paper: "accessing supervisor CSRs in VS mode is redirected"),
* privilege/virtualization access faults: accessing a higher-privilege CSR
  raises illegal-instruction; accessing H/S CSRs from VS/VU raises
  virtual-instruction (cause 22).

All functions are branchless (jnp.where chains over the known address set)
so they trace into a fixed graph and vmap over harts.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.hext.bits import U64, u64


# --- privilege encodings ----------------------------------------------------
PRV_U, PRV_S, PRV_M = 0, 1, 3

# --- internal storage indices ------------------------------------------------
(R_MSTATUS, R_MEDELEG, R_MIDELEG, R_MIE, R_MTVEC, R_MSCRATCH, R_MEPC,
 R_MCAUSE, R_MTVAL, R_MIP, R_MTVAL2, R_MTINST,
 R_STVEC, R_SSCRATCH, R_SEPC, R_SCAUSE, R_STVAL, R_SATP, R_SCOUNTEREN,
 R_HSTATUS, R_HEDELEG, R_HIDELEG, R_HVIP, R_HGEIP, R_HGEIE, R_HCOUNTEREN,
 R_HTVAL, R_HTINST, R_HGATP,
 R_VSSTATUS, R_VSTVEC, R_VSSCRATCH, R_VSEPC, R_VSCAUSE, R_VSTVAL, R_VSATP,
 R_MCOUNTEREN, R_MISA,
 R_MTIME, R_MTIMECMP, R_STIMECMP, R_VSTIMECMP, R_HTIMEDELTA,
 N_CSR) = range(44)

# Timer comparators boot disarmed (all-ones): the virtual CLINT only drives
# mip bits for a comparator once software writes it, so workloads that never
# opt in see bit-identical interrupt behavior.
TIMER_DISARMED = (1 << 64) - 1

# --- architectural CSR addresses ---------------------------------------------
CSR_ADDR = {
    # M
    0x300: R_MSTATUS, 0x301: R_MISA, 0x302: R_MEDELEG, 0x303: R_MIDELEG,
    0x304: R_MIE, 0x305: R_MTVEC, 0x306: R_MCOUNTEREN,
    0x340: R_MSCRATCH, 0x341: R_MEPC, 0x342: R_MCAUSE, 0x343: R_MTVAL,
    0x344: R_MIP, 0x34B: R_MTVAL2, 0x34A: R_MTINST,
    # S (0x100 sstatus / 0x104 sie / 0x144 sip handled as aliases)
    0x105: R_STVEC, 0x106: R_SCOUNTEREN, 0x140: R_SSCRATCH, 0x141: R_SEPC,
    0x142: R_SCAUSE, 0x143: R_STVAL, 0x180: R_SATP,
    # H
    0x600: R_HSTATUS, 0x602: R_HEDELEG, 0x603: R_HIDELEG, 0x604: None,  # hie
    0x605: R_HTIMEDELTA,
    0x606: R_HCOUNTEREN, 0x607: R_HGEIE, 0x643: R_HTVAL, 0x644: None,  # hip
    0x645: R_HVIP, 0x64A: R_HTINST, 0x680: R_HGATP, 0xE12: R_HGEIP,
    # VS
    0x200: R_VSSTATUS, 0x204: None,  # vsie
    0x205: R_VSTVEC, 0x240: R_VSSCRATCH, 0x241: R_VSEPC, 0x242: R_VSCAUSE,
    0x243: R_VSTVAL, 0x244: None,  # vsip
    0x280: R_VSATP,
    # Sstc timers: stimecmp swaps to vstimecmp with V=1 (handled below);
    # time (0xC01) is a read-only view of mtime.
    0x14D: None, 0x24D: R_VSTIMECMP, 0xC01: None,
}

# --- mstatus fields ----------------------------------------------------------
MSTATUS_SIE = 1 << 1
MSTATUS_MIE = 1 << 3
MSTATUS_SPIE = 1 << 5
MSTATUS_MPIE = 1 << 7
MSTATUS_SPP = 1 << 8
MSTATUS_MPP = 3 << 11
MSTATUS_FS = 3 << 13
MSTATUS_SUM = 1 << 18
MSTATUS_MXR = 1 << 19
MSTATUS_TVM = 1 << 20
MSTATUS_TW = 1 << 21
MSTATUS_TSR = 1 << 22
MSTATUS_MPV = 1 << 39   # H: previous virtualization mode
MSTATUS_GVA = 1 << 38   # H: guest virtual address

SSTATUS_MASK = (MSTATUS_SIE | MSTATUS_SPIE | MSTATUS_SPP | MSTATUS_FS |
                MSTATUS_SUM | MSTATUS_MXR)
MSTATUS_WMASK = (SSTATUS_MASK | MSTATUS_MIE | MSTATUS_MPIE | MSTATUS_MPP |
                 MSTATUS_TVM | MSTATUS_TW | MSTATUS_TSR | MSTATUS_MPV |
                 MSTATUS_GVA)

# --- hstatus fields ----------------------------------------------------------
HSTATUS_VSBE = 1 << 5
HSTATUS_GVA = 1 << 6
HSTATUS_SPV = 1 << 7     # supervisor previous virtualization
HSTATUS_SPVP = 1 << 8    # supervisor previous virtual privilege
HSTATUS_HU = 1 << 9      # hypervisor-in-U (allows hlv/hsv from U)
HSTATUS_VTVM = 1 << 20
HSTATUS_VTW = 1 << 21
HSTATUS_VTSR = 1 << 22
HSTATUS_WMASK = (HSTATUS_GVA | HSTATUS_SPV | HSTATUS_SPVP | HSTATUS_HU |
                 HSTATUS_VTVM | HSTATUS_VTW | HSTATUS_VTSR)

# --- counter-enable bits (mcounteren/hcounteren/scounteren) ------------------
COUNTEREN_CY = 1 << 0
COUNTEREN_TM = 1 << 1
COUNTEREN_IR = 1 << 2

# --- interrupt bits (mip/mie layout) -----------------------------------------
IP_SSIP = 1 << 1
IP_VSSIP = 1 << 2
IP_MSIP = 1 << 3
IP_STIP = 1 << 5
IP_VSTIP = 1 << 6
IP_MTIP = 1 << 7
IP_SEIP = 1 << 9
IP_VSEIP = 1 << 10
IP_MEIP = 1 << 11
IP_SGEIP = 1 << 12

HS_INTERRUPTS = IP_VSSIP | IP_VSTIP | IP_VSEIP | IP_SGEIP   # hip/hvip-visible
VS_INTERRUPTS = IP_VSSIP | IP_VSTIP | IP_VSEIP
S_INTERRUPTS = IP_SSIP | IP_STIP | IP_SEIP
HVIP_WMASK = VS_INTERRUPTS                                  # hvip writable bits
# mideleg: VS-level interrupts + SGEI are *read-only one* with H (paper §3.1:
# "new read-only 1-bit fields ... these interrupts are now handled by HS")
MIDELEG_FORCED = HS_INTERRUPTS
MIDELEG_WMASK = S_INTERRUPTS
MIP_WMASK = IP_SSIP | IP_STIP | IP_SEIP | VS_INTERRUPTS | IP_MSIP | IP_MTIP
MIE_WMASK = MIP_WMASK | IP_MEIP | IP_SGEIP

# hideleg: only VS-level interrupts delegable to VS
HIDELEG_WMASK = VS_INTERRUPTS

# --- exception causes ---------------------------------------------------------
EXC_IADDR_MISALIGNED = 0
EXC_IACCESS = 1
EXC_ILLEGAL = 2
EXC_BREAK = 3
EXC_LADDR_MISALIGNED = 4
EXC_LACCESS = 5
EXC_SADDR_MISALIGNED = 6
EXC_SACCESS = 7
EXC_ECALL_U = 8
EXC_ECALL_S = 9         # ecall from HS (or S)
EXC_ECALL_VS = 10       # ecall from VS
EXC_ECALL_M = 11
EXC_IPAGE_FAULT = 12
EXC_LPAGE_FAULT = 13
EXC_SPAGE_FAULT = 15
EXC_IGUEST_PAGE_FAULT = 20
EXC_LGUEST_PAGE_FAULT = 21
EXC_VIRTUAL_INSTRUCTION = 22
EXC_SGUEST_PAGE_FAULT = 23

# hedeleg cannot delegate guest-page-faults / ecalls-from-HS etc. to VS
HEDELEG_WMASK = ((1 << EXC_IADDR_MISALIGNED) | (1 << EXC_IACCESS) |
                 (1 << EXC_ILLEGAL) | (1 << EXC_BREAK) |
                 (1 << EXC_LADDR_MISALIGNED) | (1 << EXC_LACCESS) |
                 (1 << EXC_SADDR_MISALIGNED) | (1 << EXC_SACCESS) |
                 (1 << EXC_ECALL_U) | (1 << EXC_IPAGE_FAULT) |
                 (1 << EXC_LPAGE_FAULT) | (1 << EXC_SPAGE_FAULT))
MEDELEG_WMASK = HEDELEG_WMASK | (1 << EXC_ECALL_S) | (1 << EXC_ECALL_VS) | \
    (1 << EXC_VIRTUAL_INSTRUCTION) | (1 << EXC_IGUEST_PAGE_FAULT) | \
    (1 << EXC_LGUEST_PAGE_FAULT) | (1 << EXC_SGUEST_PAGE_FAULT)

INT_BIT = 1 << 63

# satp/hgatp/vsatp
ATP_MODE_SHIFT = 60
ATP_MODE_SV39 = 8
ATP_PPN_MASK = (1 << 44) - 1


def init_csrs():
    c = jnp.zeros((N_CSR,), U64)
    # misa: RV64 + H + I + M + S + U
    misa = (2 << 62) | (1 << 7) | (1 << 8) | (1 << 12) | (1 << 18) | (1 << 20)
    c = c.at[R_MISA].set(u64(misa))
    c = c.at[R_MIDELEG].set(u64(MIDELEG_FORCED))  # forced-one VS bits
    for r in (R_MTIMECMP, R_STIMECMP, R_VSTIMECMP):
        c = c.at[r].set(u64(TIMER_DISARMED))
    return c


# -----------------------------------------------------------------------------
# Read / write with aliasing + VS swapping. All args traced uint64/int32.
# -----------------------------------------------------------------------------

def _sel(cond, a, b):
    return jnp.where(cond, a, b)


def csr_min_priv(addr):
    """CSR address bits [9:8] encode the minimum privilege."""
    return (addr >> 8) & 3


def csr_read(csrs, addr, priv, virt):
    """Returns (value, ok, vinst_fault).

    ok=False → illegal instruction; vinst_fault → virtual-instruction trap
    (V=1 access to H/S-above CSRs)."""
    a = addr
    mstatus = csrs[R_MSTATUS]
    mip = csrs[R_MIP]
    mie = csrs[R_MIE]
    hideleg = csrs[R_HIDELEG]

    # --- VS swapping: with V=1, supervisor addresses hit the vs bank -------
    swap = {0x100: R_VSSTATUS, 0x105: R_VSTVEC, 0x140: R_VSSCRATCH,
            0x141: R_VSEPC, 0x142: R_VSCAUSE, 0x143: R_VSTVAL,
            0x180: R_VSATP}

    val = u64(0)
    known = jnp.zeros((), bool)

    def hit(addr_const, v):
        nonlocal val, known
        m = a == addr_const
        val = _sel(m, v, val)
        known = known | m

    # aliases / computed CSRs
    sstatus = mstatus & u64(SSTATUS_MASK)
    vsstatus = csrs[R_VSSTATUS] & u64(SSTATUS_MASK)
    mideleg = csrs[R_MIDELEG]
    sip = mip & mideleg & u64(S_INTERRUPTS)
    sie = mie & mideleg & u64(S_INTERRUPTS)
    hip = mip & u64(HS_INTERRUPTS)
    hie = mie & u64(HS_INTERRUPTS)
    hvip = mip & u64(VS_INTERRUPTS)
    # vsip/vsie: VS bits shifted down 1 to S positions, gated by hideleg
    vsip = (mip & hideleg & u64(VS_INTERRUPTS)) >> u64(1)
    vsie = (mie & hideleg & u64(VS_INTERRUPTS)) >> u64(1)

    hit(0x100, _sel(virt, vsstatus, sstatus))
    hit(0x104, _sel(virt, vsie, sie))
    hit(0x144, _sel(virt, vsip, sip))
    hit(0x604, hie)
    hit(0x644, hip)
    hit(0x645, hvip)
    hit(0x204, vsie)
    hit(0x244, vsip)
    # time: read-only view of mtime; under V=1 the guest sees the
    # hypervisor-shifted time base mtime + htimedelta
    hit(0xC01, _sel(virt, csrs[R_MTIME] + csrs[R_HTIMEDELTA],
                    csrs[R_MTIME]))
    hit(0x14D, _sel(virt, csrs[R_VSTIMECMP], csrs[R_STIMECMP]))

    for addr_const, idx in CSR_ADDR.items():
        if idx is None or addr_const in (0x100, 0x104, 0x144, 0x604, 0x644,
                                         0x645, 0x204, 0x244, 0xC01,
                                         0x14D):
            continue
        v = csrs[idx]
        if addr_const in swap:
            v = _sel(virt, csrs[swap[addr_const]], v)
        hit(addr_const, v)

    # --- privilege checks ----------------------------------------------------
    # CSR addr bits [9:8]: 0=U,1=S,2=H(HS-level),3=M. H-level CSRs are
    # accessible from HS (priv=S, V=0); from VS/VU they raise
    # virtual-instruction (cause 22), per the H spec.
    minp = csr_min_priv(a).astype(priv.dtype)
    is_h_csr = minp == 2
    req = jnp.where(is_h_csr, 1, minp)
    vinst = virt & is_h_csr & (priv < 3)
    # hstatus.VTVM: VS access to satp traps as virtual instruction
    vtvm = (csrs[R_HSTATUS] & u64(HSTATUS_VTVM)) != 0
    vinst = vinst | (virt & (a == 0x180) & vtvm & (priv < 3))
    # time (0xC01) is gated by the counter-enable TM bits: mcounteren for
    # any sub-M read, scounteren additionally for U/VU, and hcounteren for
    # V=1 (mcounteren clear → illegal; hcounteren/scounteren clear under
    # V=1 → virtual instruction, per the H spec's counter-access rules).
    tm_m = (csrs[R_MCOUNTEREN] & u64(COUNTEREN_TM)) != 0
    tm_h = (csrs[R_HCOUNTEREN] & u64(COUNTEREN_TM)) != 0
    tm_s = (csrs[R_SCOUNTEREN] & u64(COUNTEREN_TM)) != 0
    is_time = a == 0xC01
    time_ill = is_time & (priv < 3) & (
        ~tm_m | (~virt & (priv == 0) & ~tm_s))
    time_vinst = is_time & virt & tm_m & (~tm_h | ((priv == 0) & ~tm_s))
    vinst = vinst | time_vinst
    priv_ok = priv >= req
    ok = known & priv_ok & jnp.logical_not(vinst) & jnp.logical_not(time_ill)
    return val, ok, vinst & known


def csr_write(csrs, addr, value, priv, virt):
    """Returns (new_csrs, ok, vinst_fault). Applies WARL write masks and
    aliasing writes (paper: WRITE REGISTERS MASKS)."""
    a = addr
    v = value

    def wr(c, idx, val, mask):
        old = c[idx]
        nv = (old & ~u64(mask)) | (val & u64(mask))
        return c.at[idx].set(nv)

    new = csrs
    known = jnp.zeros((), bool)

    # Because csrs is a flat vector we can jnp.where whole-vector updates.
    def case_v(addr_const, cand):
        nonlocal new, known
        m = a == addr_const
        new = jnp.where(m, cand, new)
        known = known | m

    full = ~u64(0)
    mideleg = csrs[R_MIDELEG]
    hideleg = csrs[R_HIDELEG]

    # mstatus (WARL)
    case_v(0x300, wr(csrs, R_MSTATUS, v, MSTATUS_WMASK))
    # sstatus: alias into mstatus (or vsstatus when V=1)
    sstat_m = wr(csrs, R_MSTATUS, v, SSTATUS_MASK)
    sstat_v = wr(csrs, R_VSSTATUS, v, SSTATUS_MASK)
    case_v(0x100, jnp.where(virt, sstat_v, sstat_m))
    case_v(0x200, wr(csrs, R_VSSTATUS, v, SSTATUS_MASK))
    # interrupt enables: sie aliases mie (masked by mideleg); vsie shifts up
    sie_m = wr(csrs, R_MIE, v, S_INTERRUPTS)
    vsie_shift = (v << u64(1)) & hideleg & u64(VS_INTERRUPTS)
    vsie_w = wr(csrs, R_MIE, vsie_shift, VS_INTERRUPTS)
    case_v(0x104, jnp.where(virt, vsie_w, sie_m))
    case_v(0x204, vsie_w)
    case_v(0x304, wr(csrs, R_MIE, v, MIE_WMASK))
    case_v(0x604, wr(csrs, R_MIE, v, HS_INTERRUPTS))
    # interrupt pendings: sip.SSIP writable; hvip VS bits; vsip.SSIP→VSSIP
    sip_m = wr(csrs, R_MIP, v, IP_SSIP)
    vsip_shift = (v << u64(1)) & hideleg & u64(IP_VSSIP)
    vsip_w = wr(csrs, R_MIP, vsip_shift, IP_VSSIP)
    case_v(0x144, jnp.where(virt, vsip_w, sip_m))
    case_v(0x244, vsip_w)
    case_v(0x344, wr(csrs, R_MIP, v, MIP_WMASK))
    case_v(0x645, wr(csrs, R_MIP, v, HVIP_WMASK))  # hvip aliases mip VS bits
    case_v(0x644, wr(csrs, R_MIP, v, IP_VSSIP))    # hip: only VSSIP writable
    # delegation
    case_v(0x302, wr(csrs, R_MEDELEG, v, MEDELEG_WMASK))
    case_v(0x303, wr(csrs, R_MIDELEG, v, MIDELEG_WMASK))  # VS bits read-only-1
    case_v(0x602, wr(csrs, R_HEDELEG, v, HEDELEG_WMASK))
    case_v(0x603, wr(csrs, R_HIDELEG, v, HIDELEG_WMASK))
    # plain registers (with VS swapping where applicable)
    plain = {0x305: (R_MTVEC, full), 0x306: (R_MCOUNTEREN, full),
             0x340: (R_MSCRATCH, full), 0x341: (R_MEPC, ~u64(1)),
             0x342: (R_MCAUSE, full), 0x343: (R_MTVAL, full),
             0x34B: (R_MTVAL2, full), 0x34A: (R_MTINST, full),
             0x106: (R_SCOUNTEREN, full),
             0x600: (R_HSTATUS, HSTATUS_WMASK), 0x605: (R_HTIMEDELTA, full),
             0x606: (R_HCOUNTEREN, full),
             0x607: (R_HGEIE, full), 0x643: (R_HTVAL, full),
             0x64A: (R_HTINST, full), 0x680: (R_HGATP, full),
             0x205: (R_VSTVEC, full), 0x240: (R_VSSCRATCH, full),
             0x241: (R_VSEPC, ~u64(1)), 0x242: (R_VSCAUSE, full),
             0x243: (R_VSTVAL, full), 0x280: (R_VSATP, full),
             0x24D: (R_VSTIMECMP, full)}
    for addr_const, (idx, mask) in plain.items():
        case_v(addr_const, wr(csrs, idx, v, mask))
    swap = {0x105: (R_STVEC, R_VSTVEC), 0x140: (R_SSCRATCH, R_VSSCRATCH),
            0x141: (R_SEPC, R_VSEPC), 0x142: (R_SCAUSE, R_VSCAUSE),
            0x143: (R_STVAL, R_VSTVAL), 0x180: (R_SATP, R_VSATP),
            0x14D: (R_STIMECMP, R_VSTIMECMP)}
    for addr_const, (sidx, vidx) in swap.items():
        mask = ~u64(1) if addr_const == 0x141 else full
        case_v(addr_const,
               jnp.where(virt, wr(csrs, vidx, v, mask),
                         wr(csrs, sidx, v, mask)))
    # read-only CSRs (hgeip, misa treated RO here): write ignored but legal @M
    case_v(0xE12, csrs)
    case_v(0x301, csrs)
    case_v(0xC01, csrs)   # time: RO region → write faults via read_only below

    minp = csr_min_priv(a).astype(priv.dtype)
    is_h_csr = minp == 2
    req = jnp.where(is_h_csr, 1, minp)
    vinst = virt & is_h_csr & (priv < 3)
    vtvm = (csrs[R_HSTATUS] & u64(HSTATUS_VTVM)) != 0
    vinst = vinst | (virt & (a == 0x180) & vtvm & (priv < 3))
    read_only = (a >> 10) == 3    # addr[11:10]==11 → read-only region
    priv_ok = priv >= req
    ok = known & priv_ok & jnp.logical_not(vinst) & jnp.logical_not(
        read_only.astype(bool))
    return new, ok, vinst & known
