"""Sv39 / Sv39x4 one- and two-stage address translation (paper §3.3).

The VS-stage (``vsatp``) translates guest-virtual → guest-physical; every
page-table access of that walk, and the final guest-physical address, is
itself translated by the G-stage (``hgatp``, Sv39x4: root widened by 2 bits)
— guest PA → host PA. Faults carry (cause, tval=VA, tval2=GPA>>2, gva).

Everything is branchless (masked 3-level unrolled walks) so it traces into a
fixed graph, vmaps over harts, and mirrors the Pallas `kernels/pagewalk`
implementation (same math; kernel is the VMEM-tiled version).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.hext import csr as C
from repro.core.hext.bits import read64 as _read64
from repro.core.hext.bits import u64 as _u

U64 = jnp.uint64

# PTE bits
PTE_V = 1 << 0
PTE_R = 1 << 1
PTE_W = 1 << 2
PTE_X = 1 << 3
PTE_U = 1 << 4
PTE_A = 1 << 6
PTE_D = 1 << 7

ACC_R, ACC_W, ACC_X = 0, 1, 2

PAGE_SHIFT = 12
LEVELS = 3


class XResult(NamedTuple):
    pa: jnp.ndarray          # host-physical address (uint64)
    fault: jnp.ndarray       # bool
    cause: jnp.ndarray       # uint64 exception cause
    tval: jnp.ndarray        # faulting VA (uint64)
    tval2: jnp.ndarray       # faulting GPA >> 2 (uint64); 0 if none
    gva: jnp.ndarray         # bool: tval is a guest virtual address
    implicit: jnp.ndarray    # bool: G-stage fault on an *implicit* PTE fetch
    leaf_pte: jnp.ndarray    # stage-1 leaf PTE (or all-perm pseudo-PTE)
    g_leaf_pte: jnp.ndarray  # G-stage leaf PTE (or all-perm pseudo-PTE)
    level: jnp.ndarray       # stage-1 leaf level (0=4K,1=2M,2=1G)


# pseudo-PTE carrying every permission (used for bare/no-paging stages)
ALL_PERM_PTE = PTE_V | PTE_R | PTE_W | PTE_X | PTE_U | PTE_A | PTE_D


def _acc_cause(acc):
    """Access-fault cause for an access type (PMA-style fault: the PA does
    not exist).  Faults on implicit PTE fetches report the cause of the
    *original* access type, like page faults do."""
    return _u(jnp.where(acc == ACC_R, C.EXC_LACCESS,
                        jnp.where(acc == ACC_W, C.EXC_SACCESS,
                                  C.EXC_IACCESS)))


def _pf_cause(acc, guest):
    """Page-fault cause for access type; guest=True → guest-page-fault."""
    norm = jnp.where(acc == ACC_R, C.EXC_LPAGE_FAULT,
                     jnp.where(acc == ACC_W, C.EXC_SPAGE_FAULT,
                               C.EXC_IPAGE_FAULT))
    g = jnp.where(acc == ACC_R, C.EXC_LGUEST_PAGE_FAULT,
                  jnp.where(acc == ACC_W, C.EXC_SGUEST_PAGE_FAULT,
                            C.EXC_IGUEST_PAGE_FAULT))
    return _u(jnp.where(guest, g, norm))


def _leaf_ok(pte, acc, priv, sum_bit, mxr, require_u):
    """Permission check on a leaf PTE."""
    r = (pte & _u(PTE_R)) != 0
    w = (pte & _u(PTE_W)) != 0
    x = (pte & _u(PTE_X)) != 0
    u = (pte & _u(PTE_U)) != 0
    a = (pte & _u(PTE_A)) != 0
    d = (pte & _u(PTE_D)) != 0
    r_eff = r | (mxr & x)
    perm = jnp.where(acc == ACC_R, r_eff, jnp.where(acc == ACC_W, w & r, x))
    # U-bit discipline: U-mode needs U=1; S-mode needs U=0 unless SUM (loads/
    # stores only). G-stage walks pass require_u=True (guest accesses are "U").
    upriv = priv == 0
    u_ok = jnp.where(require_u, u,
                     jnp.where(upriv, u,
                               (~u) | (sum_bit & (acc != ACC_X))))
    ad_ok = a & jnp.where(acc == ACC_W, d, True)
    return perm & u_ok & ad_ok


def _walk(mem, root_pa, vpn2_bits, va, acc, priv, sum_bit, mxr, require_u,
          guest, pte_xlate=None, cause_acc=None):
    """Generic 3-level Sv39(x4) walk.

    vpn2_bits: 9 (Sv39) or 11 (Sv39x4). pte_xlate: optional fn(gpa) →
    XResult used to G-translate each PTE address (the nesting that makes
    two-stage translation expensive — paper Fig 3). cause_acc: access type
    used for fault *causes* (G-stage faults during implicit PTE fetches
    report the original access type per the spec)."""
    cause_acc = acc if cause_acc is None else cause_acc
    va = _u(va)
    base = _u(root_pa)
    done = jnp.zeros((), bool)
    fault = jnp.zeros((), bool)
    f_cause = _u(0)
    f_tval2 = _u(0)
    f_implicit = jnp.zeros((), bool)
    pa = _u(0)
    leaf_pte = _u(0)
    leaf_level = jnp.zeros((), jnp.int32)
    for level in (2, 1, 0):
        shift = PAGE_SHIFT + 9 * level
        nbits = vpn2_bits if level == 2 else 9
        vpn = (va >> _u(shift)) & _u((1 << nbits) - 1)
        pte_addr = base + (vpn << _u(3))
        g_tval2 = _u(0)
        if pte_xlate is not None:
            xr = pte_xlate(pte_addr, _u(ACC_R))
            pte_pa = xr.pa
            g_fault = xr.fault
            g_cause = xr.cause
            g_tval2 = xr.tval2
        else:
            pte_pa, g_fault, g_cause = pte_addr, jnp.zeros((), bool), _u(0)
        # a PTE address beyond physical memory is an access fault, not a
        # wrap-around into RAM (previously `_read64`'s modulo index aliased
        # bogus walk addresses back into memory)
        oob = pte_pa >= _u(mem.shape[0] * 8)
        pte = _read64(mem, pte_pa)
        valid = (pte & _u(PTE_V)) != 0
        # W=1,R=0 encodings are reserved in Sv39/Sv39x4 and must page-fault
        # (previously such a PTE fell through as a non-leaf pointer)
        reserved = ((pte & _u(PTE_W)) != 0) & ((pte & _u(PTE_R)) == 0)
        is_leaf = (pte & _u(PTE_R | PTE_X)) != 0
        ppn = (pte >> _u(10)) & _u((1 << 44) - 1)
        # superpage alignment: low ppn bits must be zero at level>0
        align_ok = (ppn & _u((1 << (9 * level)) - 1)) == 0 if level else \
            jnp.ones((), bool)
        perm_ok = _leaf_ok(pte, acc, priv, sum_bit, mxr, require_u)
        this_fault_pte = ~valid | reserved
        leaf_fault = is_leaf & (~align_ok | ~perm_ok)
        level_fault = jnp.where(g_fault, True,
                                oob | this_fault_pte | leaf_fault)
        level_cause = jnp.where(g_fault, g_cause,
                                jnp.where(oob, _acc_cause(cause_acc),
                                          _pf_cause(cause_acc, guest)))
        # leaf PA: ppn high bits + VA low bits per level
        mask_low = _u((1 << shift) - 1)
        leaf_pa = ((ppn << _u(PAGE_SHIFT)) & ~mask_low) | (va & mask_low)
        new_fault = ~done & level_fault
        fault = fault | new_fault
        f_cause = jnp.where(new_fault, level_cause, f_cause)
        f_tval2 = jnp.where(new_fault & g_fault, g_tval2, f_tval2)
        f_implicit = f_implicit | (new_fault & g_fault)
        take_leaf = ~done & ~level_fault & is_leaf
        pa = jnp.where(take_leaf, leaf_pa, pa)
        leaf_pte = jnp.where(take_leaf, pte, leaf_pte)
        leaf_level = jnp.where(take_leaf, level, leaf_level)
        done = done | new_fault | take_leaf
        # walk down: next base
        base = jnp.where(done, base, ppn << _u(PAGE_SHIFT))
    # ran out of levels without leaf → page fault
    miss = ~done
    fault = fault | miss
    f_cause = jnp.where(miss, _pf_cause(cause_acc, guest), f_cause)
    return pa, fault, f_cause, f_tval2, f_implicit, leaf_pte, leaf_level


def g_translate(mem, hgatp, gpa, acc, mxr, cause_acc=None):
    """G-stage only: guest-physical → host-physical (Sv39x4).

    Guest accesses are treated as user-level (PTE.U required). cause_acc:
    original access type for fault causes (implicit PTE fetches)."""
    mode = (hgatp >> _u(C.ATP_MODE_SHIFT)) & _u(0xF)
    root = (hgatp & _u(C.ATP_PPN_MASK)) << _u(PAGE_SHIFT)
    gpa = _u(gpa)
    pa, fault, cause, _, _imp, lp, lvl = _walk(
        mem, root, 11, gpa, acc, jnp.zeros((), jnp.int32), jnp.zeros((), bool),
        mxr, jnp.ones((), bool), jnp.ones((), bool), cause_acc=cause_acc)
    bare = mode == 0
    pa = jnp.where(bare, gpa, pa)
    fault = jnp.where(bare, False, fault)
    cause = jnp.where(bare, _u(0), cause)
    lp = jnp.where(bare, _u(ALL_PERM_PTE), lp)
    return XResult(pa=pa, fault=fault, cause=cause, tval=gpa,
                   tval2=gpa >> _u(2), gva=jnp.zeros((), bool),
                   implicit=jnp.zeros((), bool),
                   leaf_pte=_u(ALL_PERM_PTE), g_leaf_pte=lp,
                   level=jnp.where(bare, jnp.zeros((), jnp.int32), lvl))


def eff_ctx(csrs, virt_eff):
    """Effective (SUM, MXR) for an access: vsstatus supplies both when the
    access is virtualized, mstatus otherwise.  Shared by the walker and the
    TLB so cached permissions always match what a fresh walk would check."""
    mstatus = csrs[C.R_MSTATUS]
    vsstatus = csrs[C.R_VSSTATUS]
    sum_bit = jnp.where(virt_eff, (vsstatus & _u(C.MSTATUS_SUM)) != 0,
                        (mstatus & _u(C.MSTATUS_SUM)) != 0)
    mxr = jnp.where(virt_eff, (vsstatus & _u(C.MSTATUS_MXR)) != 0,
                    (mstatus & _u(C.MSTATUS_MXR)) != 0)
    return sum_bit, mxr


def translate(mem, csrs, priv, virt, va, acc, force_virt=False,
              hlvx=False, mprv_sum=None):
    """Full translation honoring privilege & virtualization mode.

    force_virt: hlv/hsv — execute the access as if V=1 (paper §3.3's
    XlateFlags.forced virtualization). hlvx: require execute permission
    instead of read (HLVX).
    Returns XResult."""
    va = _u(va)
    virt_eff = jnp.asarray(virt, bool) | jnp.asarray(force_virt, bool)
    # effective privilege for the access
    s_bit, mxr = eff_ctx(csrs, virt_eff)
    if mprv_sum is not None:
        s_bit = mprv_sum
    acc_eff = jnp.where(jnp.asarray(hlvx, bool), _u(ACC_X), _u(acc))

    vsatp = csrs[C.R_VSATP]
    satp = csrs[C.R_SATP]
    # hgatp participates only for virtualized accesses; forcing it to BARE
    # otherwise lets one walk serve both cases (g_translate is identity when
    # mode=0).
    hgatp_eff = jnp.where(virt_eff, csrs[C.R_HGATP], _u(0))
    atp = jnp.where(virt_eff, vsatp, satp)
    mode = (atp >> _u(C.ATP_MODE_SHIFT)) & _u(0xF)
    root = (atp & _u(C.ATP_PPN_MASK)) << _u(PAGE_SHIFT)

    no_paging = (mode == 0) | ((priv >= 3) & ~virt_eff)

    # --- first stage (VS or S), PTE fetches G-translated when virtual ------
    def pte_xlate(gpa, a):
        # implicit VS-stage PTE fetch: needs R at G-stage, but a fault is
        # reported with the ORIGINAL access type (spec §hypervisor) — raw
        # `acc`, not acc_eff: an hlvx walk fault is still a LOAD guest fault
        return g_translate(mem, hgatp_eff, gpa, a, mxr, cause_acc=_u(acc))

    pa1, fault1, cause1, tval2_1, implicit1, vs_pte, vs_level = _walk(
        mem, root, 9, va, acc_eff, priv, s_bit, mxr,
        jnp.zeros((), bool), jnp.zeros((), bool), pte_xlate=pte_xlate)

    gpa_out = jnp.where(no_paging, va, pa1)
    stage1_fault = ~no_paging & fault1

    # --- second stage on the final GPA -------------------------------------
    # HLVX carries its execute-permission override through the G-stage too
    # (acc_eff, not raw acc), while fault causes still report the original
    # access type — an X-only G-stage page must satisfy an hlvx read.
    g = g_translate(mem, hgatp_eff, gpa_out, acc_eff, mxr, cause_acc=_u(acc))
    pa = g.pa
    g_fault = ~stage1_fault & g.fault

    fault = stage1_fault | g_fault
    cause = jnp.where(stage1_fault, cause1, g.cause)
    tval2 = jnp.where(stage1_fault, tval2_1, jnp.where(g_fault, g.tval2,
                                                       _u(0)))
    # GVA: tval holds a guest-virtual address whenever the access ran V=1
    gva = virt_eff & fault
    vs_pte = jnp.where(no_paging, _u(ALL_PERM_PTE), vs_pte)
    vs_level = jnp.where(no_paging, jnp.zeros((), jnp.int32), vs_level)
    implicit = stage1_fault & implicit1
    return XResult(pa=pa, fault=fault, cause=cause, tval=va, tval2=tval2,
                   gva=gva, implicit=implicit, leaf_pte=vs_pte,
                   g_leaf_pte=g.g_leaf_pte, level=vs_level)
