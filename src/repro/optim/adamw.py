"""AdamW (decoupled weight decay) with global-norm clipping.

Optimizer state mirrors the param tree (so it inherits the params'
shardings — ZeRO-3 for free under the FSDP policy).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params, dtype=jnp.float32) -> AdamWState:
    """dtype=bfloat16 halves optimizer memory (bf16-Adam; update math stays
    f32 — states are cast on store). The 340B-class configs need this to
    fit v5e HBM (EXPERIMENTS.md §Perf)."""
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dtype), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, max_grad_norm: float = 1.0):
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gn = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        mdt = m.dtype
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh = m32 / b1c
        vh = v32 / b2c
        # decay only matrices (norms/biases are 1-D)
        wd = weight_decay if p.ndim >= 2 else 0.0
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p32)
        return p32.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gn
