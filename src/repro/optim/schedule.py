"""LR schedules: cosine (default) and WSD (warmup-stable-decay, MiniCPM)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def wsd_schedule(peak_lr: float, warmup: int, stable: int, decay: int,
                 min_ratio: float = 0.01):
    """MiniCPM warmup-stable-decay: linear warmup → constant → exp decay."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        in_decay = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = peak_lr * jnp.power(min_ratio, in_decay)
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < warmup + stable, peak_lr, dec))
        return out
    return lr
