"""Deterministic sharded synthetic token pipeline with host-side prefetch.

Production posture: each host generates only its shard of the global batch
(`host_batch = global_batch // n_hosts`), keyed by (seed, step, host) so a
restarted/elastically-resized job regenerates identical data for any step —
data determinism is what makes checkpoint-resume exact. A background thread
keeps `prefetch` batches ready so the accelerator never waits on the host.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticLMData:
    def __init__(self, cfg: ModelConfig, global_batch: int, seq_len: int,
                 seed: int = 0, n_hosts: int = 1, host_id: int = 0,
                 prefetch: int = 2):
        assert global_batch % n_hosts == 0
        self.cfg = cfg
        self.global_batch = global_batch
        self.host_batch = global_batch // n_hosts
        self.seq_len = seq_len
        self.seed = seed
        self.host_id = host_id
        self.prefetch = prefetch
        self._q: Optional[queue.Queue] = None
        self._stop = threading.Event()

    # -- deterministic batch synthesis ---------------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        # zipf-ish marginal over the vocab: realistic softmax pressure
        z = rng.zipf(1.3, size=(self.host_batch, self.seq_len + 1))
        tokens = (z % self.cfg.vocab_size).astype(np.int32)
        batch = {"tokens": tokens[:, :-1],
                 "labels": tokens[:, 1:].copy()}
        if self.cfg.frontend == "vit_stub":
            batch["patches"] = rng.standard_normal(
                (self.host_batch, self.cfg.n_frontend_tokens,
                 self.cfg.d_model), dtype=np.float32)
        if self.cfg.frontend == "audio_stub":
            batch["frames"] = rng.standard_normal(
                (self.host_batch, self.cfg.n_enc_ctx, self.cfg.d_model),
                dtype=np.float32)
        return batch

    # -- prefetching iterator -------------------------------------------------
    def iterator(self, start_step: int = 0) -> Iterator[Dict]:
        self._q = queue.Queue(maxsize=self.prefetch)
        self._stop.clear()

        def worker():
            step = start_step
            while not self._stop.is_set():
                try:
                    self._q.put(self.batch_at(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield self._q.get()
        finally:
            self._stop.set()

    def stop(self):
        self._stop.set()
