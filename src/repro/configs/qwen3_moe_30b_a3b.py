"""Qwen3-MoE-30B-A3B  [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) vocab=151936; MoE 128 experts top-8,
per-expert d_ff=768. Qwen3 uses explicit head_dim=128 and q/k RMSNorm.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,                      # per-expert intermediate
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp_type="swiglu",
    moe=MoEConfig(n_experts=128, top_k=8, d_ff=768, dispatch="gather"),
    tie_embeddings=False,
    notes="128e top-8 MoE; qk-norm; head_dim 128 per HF config.",
)
