"""Mamba2-130M  [arXiv:2405.21060].

24L d_model=768, attention-free SSD blocks, vocab=50280, ssm_state=128,
expand=2 (d_inner=1536), head_dim=64 → 24 SSD heads.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=12,            # unused (attention-free); kept valid for shared code
    n_kv_heads=12,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    block_pattern=("ssm",),
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk=64, conv_width=4),
    tie_embeddings=True,
    norm_type="rmsnorm",
    notes="attention-free: paper's paged-KV technique inapplicable "
          "(DESIGN.md §Arch-applicability); constant-size SSD state instead.",
)
