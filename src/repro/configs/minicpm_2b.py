"""MiniCPM-2B  [arXiv:2404.06395].

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753, llama-like with
muP-style scalings (scale_emb=12, scale_depth=1.4, dim_model_base=256) and a
WSD (warmup-stable-decay) schedule — wired into repro.optim.schedule.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    rope_theta=10000.0,
    mlp_type="swiglu",
    tie_embeddings=True,
    scale_emb=12.0,
    scale_depth=1.4,
    dim_model_base=256,
    notes="muP scalings active; trained with WSD schedule.",
)
