"""Whisper-base  [arXiv:2212.04356].

Enc-dec: 6+6L d_model=512 8H (MHA) d_ff=2048 vocab=51865, GELU MLP,
LayerNorm. Conv audio frontend is a STUB — input_specs() provides
precomputed frame embeddings [B, 1500, 512].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    mlp_type="gelu",
    norm_type="layernorm",
    is_enc_dec=True,
    n_enc_layers=6,
    n_enc_ctx=1500,
    frontend="audio_stub",
    notes="decode_32k lowered shape-faithfully with sinusoidal positions "
          "(real whisper caps decoder at 448 positions); long_500k skipped.",
)
