"""InternVL2-2B  [arXiv:2404.16821].

LM backbone (InternLM2-like): 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553. InternViT frontend is a STUB — input_specs() provides
precomputed patch embeddings [B, 256, 2048] prepended to the text sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    mlp_type="swiglu",
    frontend="vit_stub",
    n_frontend_tokens=256,
    notes="ViT frontend stubbed per assignment; loss over text positions.",
)
