"""Granite-MoE-3B-A800M  [hf:ibm-granite family].

32L d_model=1536 24H (GQA kv=8) vocab=49155; MoE top-8, per-expert d_ff=512.
Assignment lists "MoE 40e top-8" in the structured field and "32 experts" in
the free text; we follow the structured field (40 experts — matches the 3b
granite MoE). Discrepancy noted here per instructions.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    rope_theta=10000.0,
    mlp_type="swiglu",
    moe=MoEConfig(n_experts=40, top_k=8, d_ff=512, dispatch="gather",
              pad_experts_to=48),  # §Perf: EP divides tp=16
    tie_embeddings=True,           # granite ties embeddings
    notes="40e top-8 (structured field; free text said 32e).",
)
