"""RecurrentGemma-9B (Griffin)  [arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; RG-LRU + local
attention in a 2:1 pattern (R,R,A); lru_width=d_model; local window 2048.
38 = 12×(R,R,A) + (R,R) remainder.
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    attn_type="swa",
    window=2048,
    rope_theta=10000.0,
    mlp_type="swiglu",
    block_pattern=("rglru", "rglru", "attn"),
    rglru=RGLRUConfig(lru_width=4096, conv_width=4, window=2048),
    tie_embeddings=True,
    scale_emb=64.0,                # gemma-style sqrt(d_model) emb scaling
    notes="hybrid: RG-LRU blocks carry fixed-size state (no KV paging); "
          "local-attn blocks use windowed KV. Sub-quadratic → long_500k runs.",
)
