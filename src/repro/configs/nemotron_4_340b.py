"""Nemotron-4-340B  [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000, squared-ReLU MLP,
no gating (2-matrix FFN). The heaviest assigned architecture.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    rope_theta=10000.0,
    mlp_type="squared_relu",
    notes="squared-ReLU FFN; GQA kv=8; 340B params.",
)
