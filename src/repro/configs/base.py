"""Model / run configuration system.

Every assigned architecture is expressed as a ``ModelConfig``. Configs are
plain frozen dataclasses so they can be hashed into jit static args and
serialized into checkpoints. ``reduced()`` derives the CPU-smoke-test version
of the same family (small widths/depths, same code paths).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff: int = 0                  # per-expert intermediate size
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    capacity_factor: float = 1.25  # used by dropping dispatch path
    dispatch: str = "dense"        # "dense" (einsum masked) | "gather" (cumsum capacity)
    pad_experts_to: int = 0        # round E up so EP divides tp (§Perf)
    ep_shard: bool = True          # False: replicate expert weights (small-
                                   # expert archs; zero MoE collectives)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 0
    expand: int = 2
    head_dim: int = 64
    chunk: int = 64
    conv_width: int = 4
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0             # recurrence width (defaults to d_model)
    conv_width: int = 4
    c: float = 8.0                 # RG-LRU gating exponent constant
    window: int = 2048             # local-attention window of hybrid blocks


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- attention ---
    attn_type: str = "full"        # full | swa
    window: int = 0                # swa window (tokens)
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    # --- mlp ---
    mlp_type: str = "swiglu"       # swiglu | squared_relu | gelu
    # --- norm / embedding ---
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- subconfigs ---
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    rglru: RGLRUConfig = field(default_factory=RGLRUConfig)
    # hybrid layout: pattern of block kinds, tiled to n_layers
    block_pattern: Tuple[str, ...] = ("attn",)
    # --- encoder/decoder (whisper) ---
    is_enc_dec: bool = False
    n_enc_layers: int = 0
    n_enc_ctx: int = 0             # encoder sequence length (frames)
    # --- modality frontend stubs ---
    frontend: str = "none"         # none | audio_stub | vit_stub
    n_frontend_tokens: int = 0     # tokens contributed by the frontend (vlm)
    # --- muP-ish scalings (minicpm) ---
    scale_emb: float = 1.0
    scale_depth: float = 0.0       # 0 = disabled; else residual *= scale_depth/sqrt(2L)
    dim_model_base: int = 0        # 0 = disabled; else logits /= d_model/dim_model_base
    # --- runtime knobs (overridable per run) ---
    max_seq: int = 4096
    remat: str = "dots"            # none | dots | full
    scan_layers: bool = True
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding table padded to 256 so vocab shards over tp=16 cleanly
        (padded logits are masked in unembed/loss)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs only (ssm / hybrid / swa)."""
        return self.family in ("ssm", "hybrid") or self.attn_type == "swa"

    @property
    def has_decode_step(self) -> bool:
        return True  # all assigned archs are (or contain) decoders

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.mlp_type == "swiglu":
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        if self.moe.n_experts:
            moe_ff = self.moe.d_ff or ff
            mlp = self.moe.n_experts * 3 * d * moe_ff + d * self.moe.n_experts
        per_kind = {"attn": attn + mlp, "rglru": 0, "ssm": 0}
        if "rglru" in self.block_pattern:
            w = self.rglru.lru_width or d
            per_kind["rglru"] = 2 * d * w + w * d + 3 * w + mlp
        if self.family == "ssm":
            d_in = self.ssm.expand * d
            per_kind["ssm"] = d * (2 * d_in + 2 * self.ssm.d_state) + d_in * d
        total = 0
        pat = self.block_pattern
        for i in range(self.n_layers):
            total += per_kind.get(pat[i % len(pat)], attn + mlp)
        total += V * d * (1 if self.tie_embeddings else 2)
        if self.is_enc_dec:
            total += self.n_enc_layers * (attn + mlp) + self.n_layers * attn  # cross-attn
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE uses top_k of n_experts)."""
        if not self.moe.n_experts:
            return self.n_params()
        d = self.d_model
        moe_ff = self.moe.d_ff or self.d_ff
        dense_total = self.n_params()
        all_experts = self.n_layers * self.moe.n_experts * 3 * d * moe_ff
        active = self.n_layers * self.moe.top_k * 3 * d * moe_ff
        return dense_total - all_experts + active

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant: same family/code paths, tiny dims."""
        kw = dict(
            n_layers=min(self.n_layers, 2 * max(1, len(self.block_pattern))),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(1, self.n_heads))),
            d_ff=128,
            vocab_size=256,
            head_dim=16 if self.head_dim else 0,
            max_seq=64,
            window=min(self.window, 32) if self.window else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_enc_ctx=min(self.n_enc_ctx, 16) if self.n_enc_ctx else 0,
            n_frontend_tokens=min(self.n_frontend_tokens, 8) if self.n_frontend_tokens else 0,
            remat="none",
        )
        if self.moe.n_experts:
            kw["moe"] = dataclasses.replace(self.moe, n_experts=8, top_k=2, d_ff=32)
        if self.ssm.d_state:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16, chunk=16)
        if self.rglru.lru_width:
            kw["rglru"] = dataclasses.replace(self.rglru, lru_width=64, window=16)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shape sets (assigned to every LM arch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Return (applicable, reason_if_not) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 500k decode is quadratic (skip per spec)"
    if cfg.is_enc_dec and shape.name == "long_500k":
        return False, "enc-dec decoder positional range << 500k"
    return True, ""
