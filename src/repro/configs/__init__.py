"""Config registry: one module per assigned architecture.

``get_config(arch_id)`` returns the exact assigned ModelConfig;
``get_config(arch_id, reduced=True)`` returns the CPU smoke-test variant.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (ModelConfig, MoEConfig, RGLRUConfig,
                                ShapeConfig, SHAPES, SSMConfig,
                                shape_applicable)

ARCHS = [
    "qwen3_moe_30b_a3b",
    "granite_moe_3b_a800m",
    "qwen15_32b",
    "h2o_danube_3_4b",
    "nemotron_4_340b",
    "minicpm_2b",
    "recurrentgemma_9b",
    "mamba2_130m",
    "whisper_base",
    "internvl2_2b",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    cfg = mod.CONFIG
    return cfg.reduced() if reduced else cfg


__all__ = ["ARCHS", "SHAPES", "get_config", "shape_applicable", "ModelConfig",
           "MoEConfig", "SSMConfig", "RGLRUConfig", "ShapeConfig"]
