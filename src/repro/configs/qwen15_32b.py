"""Qwen1.5-32B  [hf:Qwen family].

64L d_model=5120 40H (MHA: kv=40) d_ff=27392 vocab=152064, QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_type="swiglu",
    notes="MHA with QKV bias (qwen1.5 signature).",
)
