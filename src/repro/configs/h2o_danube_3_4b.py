"""H2O-Danube-3-4B  [arXiv:2401.16818].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000; llama+mistral mix
with sliding-window attention (window 4096 — mistral default; the assignment
does not pin a window).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    attn_type="swa",
    window=4096,
    rope_theta=10000.0,
    mlp_type="swiglu",
    notes="SWA(4096) → sub-quadratic; long_500k cell runs with windowed KV.",
)
