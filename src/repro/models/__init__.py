"""Pure-JAX functional model zoo.

Every module exposes ``init_*`` (returns a pytree of ``PV(value, spec)``
leaves — weight + logical PartitionSpec) and a pure ``apply``-style function.
``repro.runtime.sharding`` resolves logical specs to mesh-physical
NamedShardings.
"""
from repro.models.layers import PV, split_pv_tree  # noqa: F401
