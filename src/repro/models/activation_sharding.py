"""Injection points for activation sharding constraints (sequence/tensor
parallelism) — the runtime installs constraint fns before tracing; the model
calls them at well-known points. Module-level hooks avoid threading
mesh/policy objects through model code."""
from __future__ import annotations

from typing import Callable, Dict, Optional

_HOOKS: Dict[str, Optional[Callable]] = {
    "block": None,    # superblock boundary [B,S,D] (SP: seq-sharded carry)
    "inner": None,    # post-norm activation [B,S,D] (SP: gathered for TP)
    "embed": None,    # embedding output   [B,S,D]
    "logits": None,   # unembed output     [B,S,V]
    "scores": None,   # attention scores   [B,H,S,T]
    "moe": None,      # MoE dispatch buffers [G,E,C,d] (EP sharding)
    "moe_rep": None,  # MoE dispatch buffers, replicated-expert variant
    "embed_onehot": None,  # truthy → one-hot matmul embedding (serving:
                           # gather from a vocab-sharded table replicates it)
}


def enabled(name: str) -> bool:
    return _HOOKS.get(name) is not None


def set_constraint(fn: Optional[Callable], name: str = "block") -> None:
    _HOOKS[name] = fn


def clear() -> None:
    for k in _HOOKS:
        _HOOKS[k] = None


def constrain(x, name: str = "block"):
    fn = _HOOKS.get(name)
    return x if fn is None else fn(x)
