"""Model assembly: decoder-only LMs (dense/MoE/hybrid/SSM), whisper enc-dec,
VLM with stub frontend. Pure functions over PV param trees.

Layer stacking: layers are grouped into *superblocks* (one period of
``cfg.block_pattern``); superblocks are stacked and iterated with
``jax.lax.scan`` so the HLO stays O(1) in depth. Remainder layers (pattern
not dividing n_layers, e.g. recurrentgemma's 38 = 12×(R,R,A) + R,R) are
applied explicitly after the scan.

Caches are pytrees aligned with the superblock structure:
  attn  → {"k": [B,T,KV,hd], "v": [B,T,KV,hd]}
  rglru → {"h": [B,w], "conv": [B,W-1,w]}
  ssm   → {"h": [B,H,P,N], "conv": [B,W-1,C]}
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import activation_sharding
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (PV, apply_norm, embed_init, init_norm,
                                 sinusoidal_positions, split_pv_tree,
                                 stack_layer_trees)

COMPUTE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def _pattern(cfg: ModelConfig) -> Tuple[str, ...]:
    return cfg.block_pattern or ("attn",)


def init_block(key, cfg: ModelConfig, kind: str) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "attn":
        blk = {"norm1": init_norm(cfg.norm_type, cfg.d_model),
               "attn": attn_mod.init_attention(k1, cfg),
               "norm2": init_norm(cfg.norm_type, cfg.d_model)}
        if cfg.moe.n_experts:
            blk["moe"] = moe_mod.init_moe(k2, cfg)
        else:
            blk["mlp"] = mlp_mod.init_mlp(k2, cfg)
        return blk
    if kind == "rglru":
        return {"norm1": init_norm(cfg.norm_type, cfg.d_model),
                "rglru": rglru_mod.init_rglru(k1, cfg),
                "norm2": init_norm(cfg.norm_type, cfg.d_model),
                "mlp": mlp_mod.init_mlp(k2, cfg)}
    if kind == "ssm":
        return {"norm1": init_norm(cfg.norm_type, cfg.d_model),
                "ssm": ssm_mod.init_ssm(k1, cfg)}
    raise ValueError(kind)


def _res_scale(cfg: ModelConfig):
    if cfg.scale_depth:
        return cfg.scale_depth / (2.0 * cfg.n_layers) ** 0.5
    return 1.0


def apply_block(p, cfg: ModelConfig, kind: str, x, positions, mode: str,
                cache=None, pos=None):
    """mode: train | prefill | decode. Returns (x, new_cache, aux_loss)."""
    rs = _res_scale(cfg)
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    h = apply_norm(cfg.norm_type, p["norm1"], x, cfg.norm_eps)
    # "inner" hook: under SP the carry is seq-sharded for memory; gather the
    # activation here (cheap) so TP weights stay sharded inside the block
    h = activation_sharding.constrain(h, "inner")
    if kind == "attn":
        if mode == "train":
            a = attn_mod.attn_train(p["attn"], cfg, h, positions)
        elif mode == "prefill":
            a, (k, v) = attn_mod.attn_prefill(p["attn"], cfg, h, positions)
            new_cache = {"k": k, "v": v}
        else:
            a, ck, cv = attn_mod.attn_decode(
                p["attn"], cfg, h, cache["k"], cache["v"], pos)
            new_cache = {"k": ck, "v": cv}
        x = x + rs * a
        h2 = apply_norm(cfg.norm_type, p["norm2"], x, cfg.norm_eps)
        h2 = activation_sharding.constrain(h2, "inner")
        if "moe" in p:
            m, aux = moe_mod.apply_moe(p["moe"], cfg, h2)
        else:
            m = mlp_mod.apply_mlp(p["mlp"], cfg, h2)
        return x + rs * m, new_cache, aux
    if kind == "rglru":
        h0 = cache["h"] if cache is not None else None
        cs = cache["conv"] if cache is not None else None
        r, (hn, csn) = rglru_mod.apply_rglru(
            p["rglru"], cfg, h, h0=h0, conv_state=cs, decode=(mode == "decode"))
        if mode != "train":
            new_cache = {"h": hn, "conv": csn}
        x = x + rs * r
        h2 = apply_norm(cfg.norm_type, p["norm2"], x, cfg.norm_eps)
        h2 = activation_sharding.constrain(h2, "inner")
        m = mlp_mod.apply_mlp(p["mlp"], cfg, h2)
        return x + rs * m, new_cache, aux
    if kind == "ssm":
        h0 = cache["h"] if cache is not None else None
        cs = cache["conv"] if cache is not None else None
        s, (hn, csn) = ssm_mod.apply_ssm(
            p["ssm"], cfg, h, h0=h0, conv_state=cs, decode=(mode == "decode"))
        if mode != "train":
            new_cache = {"h": hn, "conv": csn}
        return x + rs * s, new_cache, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# LM init
# ---------------------------------------------------------------------------

def _superblock_layout(cfg: ModelConfig):
    pat = _pattern(cfg)
    n_super = cfg.n_layers // len(pat)
    rem = tuple(pat[i] for i in range(cfg.n_layers - n_super * len(pat)))
    return pat, n_super, rem


def init_superblock(key, cfg: ModelConfig) -> dict:
    pat, _, _ = _superblock_layout(cfg)
    ks = jax.random.split(key, len(pat))
    return {f"b{i}_{kind}": init_block(ks[i], cfg, kind)
            for i, kind in enumerate(pat)}


def init_lm(cfg: ModelConfig, key) -> Tuple[Any, Any]:
    """Returns (params, logical_specs) twin trees."""
    pat, n_super, rem = _superblock_layout(cfg)
    keys = jax.random.split(key, n_super + len(rem) + 4)
    tree: Dict[str, Any] = {}
    tree["embed"] = embed_init(keys[0], cfg.padded_vocab, cfg.d_model)
    if not cfg.tie_embeddings:
        tree["lm_head"] = embed_init(keys[1], cfg.padded_vocab, cfg.d_model)
    tree["final_norm"] = init_norm(cfg.norm_type, cfg.d_model)
    params, specs = split_pv_tree(tree)
    sb_params, sb_specs = stack_layer_trees(
        [init_superblock(keys[2 + i], cfg) for i in range(n_super)])
    params["layers"] = sb_params
    specs["layers"] = sb_specs
    for j, kind in enumerate(rem):
        rp, rsp = split_pv_tree(init_block(keys[2 + n_super + j], cfg, kind))
        params[f"rem{j}_{kind}"] = rp
        specs[f"rem{j}_{kind}"] = rsp
    if cfg.is_enc_dec:
        ep, es = _init_encoder(cfg, keys[-1])
        params["encoder"] = ep
        specs["encoder"] = es
        cp, cs_ = stack_layer_trees(
            [ _init_cross_block(jax.random.fold_in(keys[-2], i), cfg)
              for i in range(cfg.n_layers) ])
        params["cross"] = cp
        specs["cross"] = cs_
    return params, specs


def _init_cross_block(key, cfg: ModelConfig) -> dict:
    return {"norm": init_norm(cfg.norm_type, cfg.d_model),
            "attn": attn_mod.init_attention(key, cfg, cross=True)}


def _init_encoder(cfg: ModelConfig, key):
    ks = jax.random.split(key, cfg.n_enc_layers + 1)
    blocks, bspecs = stack_layer_trees(
        [{"norm1": init_norm(cfg.norm_type, cfg.d_model),
          "attn": attn_mod.init_attention(ks[i], cfg),
          "norm2": init_norm(cfg.norm_type, cfg.d_model),
          "mlp": mlp_mod.init_mlp(jax.random.fold_in(ks[i], 1), cfg)}
         for i in range(cfg.n_enc_layers)])
    fp, fs = split_pv_tree({"final_norm": init_norm(cfg.norm_type, cfg.d_model)})
    return ({"blocks": blocks, **fp}, {"blocks": bspecs, **fs})


# ---------------------------------------------------------------------------
# Remat policy
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens):
    if activation_sharding.enabled("embed_onehot"):
        # serving path: the table is vocab-sharded (2-D); a row-gather would
        # replicate it. One-hot contraction reduces over the sharded vocab
        # instead (tokens-per-step is tiny in decode).
        oh = jax.nn.one_hot(tokens, params["embed"].shape[0],
                            dtype=COMPUTE_DTYPE)
        x = jnp.einsum("...v,vd->...d", oh,
                       params["embed"].astype(COMPUTE_DTYPE))
    else:
        x = params["embed"][tokens].astype(COMPUTE_DTYPE)
    x = activation_sharding.constrain(x, "embed")
    return x * jnp.asarray(cfg.scale_emb, COMPUTE_DTYPE)


def unembed(params, cfg: ModelConfig, x):
    x = apply_norm(cfg.norm_type, params["final_norm"], x, cfg.norm_eps)
    table = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))
    if logits.ndim == 3:
        logits = activation_sharding.constrain(logits, "logits")
    if cfg.dim_model_base:
        logits = logits / (cfg.d_model / cfg.dim_model_base)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:   # mask vocab-padding columns
        vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(vmask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def _run_blocks(params, cfg: ModelConfig, x, positions, mode: str,
                cache=None, pos=None):
    """Scan superblocks, then remainder blocks. Returns (x, new_cache, aux)."""
    pat, n_super, rem = _superblock_layout(cfg)

    def superblock(carry, xs):
        x, aux = carry
        x = activation_sharding.constrain(x)
        sb_params, sb_cache = xs
        new_caches = {}
        for i, kind in enumerate(pat):
            name = f"b{i}_{kind}"
            c = None if sb_cache is None else sb_cache.get(name)
            x, nc, a = apply_block(sb_params[name], cfg, kind, x, positions,
                                   mode, cache=c, pos=pos)
            aux = aux + a
            if nc is not None:
                new_caches[name] = nc
        return (x, aux), (new_caches if new_caches else None)

    body = _maybe_remat(superblock, cfg) if mode == "train" else superblock
    aux0 = jnp.zeros((), jnp.float32)
    sb_cache_stack = None if cache is None else cache.get("layers")
    if n_super > 0:
        if cfg.scan_layers:
            (x, aux), caches = jax.lax.scan(
                body, (x, aux0), (params["layers"], sb_cache_stack))
        else:
            # unrolled path (train-only; used for perf A/B in §Perf)
            carry, caches = (x, aux0), None
            for i in range(n_super):
                sl = jax.tree.map(lambda a: a[i], params["layers"])
                cc = (None if sb_cache_stack is None
                      else jax.tree.map(lambda a: a[i], sb_cache_stack))
                carry, _ = body(carry, (sl, cc))
            x, aux = carry
    else:
        aux, caches = aux0, None
    new_cache: Dict[str, Any] = {}
    if caches is not None:
        new_cache["layers"] = caches
    for j, kind in enumerate(rem):
        name = f"rem{j}_{kind}"
        c = None if cache is None else cache.get(name)
        x, nc, a = apply_block(params[name], cfg, kind, x, positions, mode,
                               cache=c, pos=pos)
        aux = aux + a
        if nc is not None:
            new_cache[name] = nc
    return x, (new_cache if new_cache else None), aux


def forward_train(params, cfg: ModelConfig, tokens, extra_embeds=None):
    """tokens [B,S] (+ optional frontend embeds [B,F,D]) → (logits, aux).

    extra_embeds: VLM patch embeddings (prepended) or whisper frame
    embeddings (encoder input) — the stub modality frontends."""
    if cfg.is_enc_dec:
        enc_out = _encode(params, cfg, extra_embeds)
        x = embed_tokens(params, cfg, tokens)
        B, S = x.shape[0], x.shape[1]
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
        positions = jnp.arange(S)[None, :]
        x, _, aux = _run_blocks_with_cross(params, cfg, x, positions,
                                           enc_out, "train")
    else:
        x = embed_tokens(params, cfg, tokens)
        if extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.arange(S)[None, :]
        x, _, aux = _run_blocks(params, cfg, x, positions, "train")
    return unembed(params, cfg, x), aux


def _encode(params, cfg: ModelConfig, frames):
    """Whisper encoder: frames [B,T,D] (precomputed conv-frontend embeds)."""
    x = frames.astype(COMPUTE_DTYPE)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(x.shape[1])[None, :]

    def block(x, bp):
        h = apply_norm(cfg.norm_type, bp["norm1"], x, cfg.norm_eps)
        x = x + attn_mod.bidir_attend(bp["attn"], cfg, h, positions)
        h = apply_norm(cfg.norm_type, bp["norm2"], x, cfg.norm_eps)
        return x + mlp_mod.apply_mlp(bp["mlp"], cfg, h), None

    x, _ = jax.lax.scan(block, x, params["encoder"]["blocks"])
    return apply_norm(cfg.norm_type, params["encoder"]["final_norm"], x,
                      cfg.norm_eps)


def _run_blocks_with_cross(params, cfg: ModelConfig, x, positions, enc_out,
                           mode, cache=None, pos=None):
    """Whisper decoder: self-attn block + cross-attn per layer (layers NOT
    scanned together with cross since cross K/V are precomputed per layer)."""
    # precompute cross K/V for all layers: [L,B,T,KV,hd]
    if cache is not None and "cross_k" in cache:
        ck, cv = cache["cross_k"], cache["cross_v"]
    else:
        ck, cv = jax.vmap(
            lambda cp: attn_mod.cross_kv(cp["attn"], cfg, enc_out)
        )(params["cross"])

    def superblock(carry, xs):
        x, aux = carry
        sb_params, cross_p, k, v, sb_cache = xs
        name = "b0_attn"
        c = None if sb_cache is None else sb_cache.get(name)
        x, nc, a = apply_block(sb_params[name], cfg, "attn", x, positions,
                               mode, cache=c, pos=pos)
        h = apply_norm(cfg.norm_type, cross_p["norm"], x, cfg.norm_eps)
        x = x + attn_mod.cross_attend(cross_p["attn"], cfg, h, k, v)
        return (x, aux + a), ({name: nc} if nc is not None else None)

    body = _maybe_remat(superblock, cfg) if mode == "train" else superblock
    sb_cache_stack = None if cache is None else cache.get("layers")
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], params["cross"], ck, cv, sb_cache_stack))
    new_cache = None
    if caches is not None:
        new_cache = {"layers": caches, "cross_k": ck, "cross_v": cv}
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(logits, labels, z_loss: float = 1e-4):
    """Stable masked CE. labels < 0 are ignored.

    Implemented with iota-select instead of take_along_axis so the vocab dim
    can stay tp-sharded under SPMD (a gather over a sharded dim triggers
    involuntary full rematerialization)."""
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    shifted = lf - m
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1)
    lse = jnp.log(sumexp) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_iota == labels[..., None], shifted, 0.0),
                     axis=-1)
    ll = picked + m[..., 0]
    ce = (lse - ll) * mask
    zl = z_loss * jnp.square(lse) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    return (ce.sum() + zl.sum()) / denom


def loss_fn(params, cfg: ModelConfig, batch):
    """batch: {"tokens": [B,S], "labels": [B,S], optional "frames"/"patches"}."""
    extra = batch.get("frames", batch.get("patches"))
    logits, aux = forward_train(params, cfg, batch["tokens"],
                                extra_embeds=extra)
    labels = batch["labels"]
    if extra is not None and not cfg.is_enc_dec:
        # VLM: frontend tokens prepended — loss only over text positions
        logits = logits[:, extra.shape[1]:]
    loss = lm_loss(logits, labels)
    if cfg.moe.n_experts:
        loss = loss + cfg.moe.aux_loss_weight * aux
    return loss, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Caches: init / prefill / decode
# ---------------------------------------------------------------------------

def _cache_entry_shapes(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    hd = cfg.resolved_head_dim
    if kind == "attn":
        # sliding-window archs only keep a window-sized ring slab
        T = min(max_seq, cfg.window) if cfg.window else max_seq
        return {"k": ((batch, T, cfg.n_kv_heads, hd), COMPUTE_DTYPE,
                      P("dp", "sp", "tp", None)),
                "v": ((batch, T, cfg.n_kv_heads, hd), COMPUTE_DTYPE,
                      P("dp", "sp", "tp", None))}
    if kind == "rglru":
        w = cfg.rglru.lru_width or cfg.d_model
        return {"h": ((batch, w), jnp.float32, P("dp", "tp")),
                "conv": ((batch, cfg.rglru.conv_width - 1, w), COMPUTE_DTYPE,
                         P("dp", None, "tp"))}
    if kind == "ssm":
        d_inner, H, N = ssm_mod.ssm_dims(cfg)
        conv_ch = d_inner + 2 * N
        return {"h": ((batch, H, cfg.ssm.head_dim, N), jnp.float32,
                      P("dp", "tp", None, None)),
                "conv": ((batch, cfg.ssm.conv_width - 1, conv_ch),
                         COMPUTE_DTYPE, P("dp", None, "tp"))}
    raise ValueError(kind)


def cache_shapes(cfg: ModelConfig, batch: int, max_seq: int):
    """Abstract cache: pytree of (shape, dtype, logical_spec)."""
    pat, n_super, rem = _superblock_layout(cfg)
    out: Dict[str, Any] = {}
    if n_super:
        sb = {}
        for i, kind in enumerate(pat):
            ent = _cache_entry_shapes(cfg, kind, batch, max_seq)
            sb[f"b{i}_{kind}"] = {
                k: ((n_super,) + s, d, P(*((None,) + tuple(sp))))
                for k, (s, d, sp) in ent.items()}
        out["layers"] = sb
    for j, kind in enumerate(rem):
        out[f"rem{j}_{kind}"] = _cache_entry_shapes(cfg, kind, batch, max_seq)
    if cfg.is_enc_dec:
        hd = cfg.resolved_head_dim
        out["cross_k"] = ((cfg.n_layers, batch, cfg.n_enc_ctx,
                           cfg.n_kv_heads, hd), COMPUTE_DTYPE,
                          P(None, "dp", None, "tp", None))
        out["cross_v"] = out["cross_k"]
    return out


def _is_shape_leaf(x):
    return isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    shp = cache_shapes(cfg, batch, max_seq)
    return jax.tree.map(lambda t: jnp.zeros(t[0], t[1]), shp,
                        is_leaf=_is_shape_leaf)


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    shp = cache_shapes(cfg, batch, max_seq)
    return jax.tree.map(lambda t: t[2], shp, is_leaf=_is_shape_leaf)


def prefill(params, cfg: ModelConfig, tokens, cache, extra_embeds=None):
    """Run the prompt; write K/V into `cache` slabs (sized max_seq ≥ S).

    Returns (logits_last [B,V], cache)."""
    x = embed_tokens(params, cfg, tokens)
    if extra_embeds is not None and not cfg.is_enc_dec:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]
    if cfg.is_enc_dec:
        enc_out = _encode(params, cfg, extra_embeds)
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
        x, pcache, _ = _run_blocks_with_cross(params, cfg, x, positions,
                                              enc_out, "prefill")
    else:
        x, pcache, _ = _run_blocks(params, cfg, x, positions, "prefill")
    # place prefill K/V into the cache slabs (window slabs keep the ring-
    # aligned tail; S % window == 0 keeps slots position-congruent)
    def merge(slab, fresh):
        if slab.ndim == fresh.ndim and slab.ndim >= 4 \
                and slab.shape[-3] != fresh.shape[-3]:
            T = slab.shape[-3]
            Sf = fresh.shape[-3]
            if Sf > T:          # windowed slab: keep last T positions
                return jax.lax.slice_in_dim(
                    fresh, Sf - T, Sf, axis=slab.ndim - 3).astype(slab.dtype)
            return jax.lax.dynamic_update_slice_in_dim(
                slab, fresh.astype(slab.dtype), 0, axis=slab.ndim - 3)
        return fresh.astype(slab.dtype)
    cache = jax.tree.map(merge, cache, pcache)
    logits = unembed(params, cfg, x[:, -1:])
    return logits[:, 0], cache


def decode_step(params, cfg: ModelConfig, token, pos, cache):
    """token [B] int32, pos [B] int32 → (logits [B,V], cache)."""
    x = embed_tokens(params, cfg, token[:, None])
    positions = pos[:, None]
    if cfg.is_enc_dec:
        x = x + sinusoidal_positions(cfg.max_seq, cfg.d_model
                                     ).astype(x.dtype)[pos][:, None]
        x, cache, _ = _run_blocks_with_cross(params, cfg, x, positions, None,
                                             "decode", cache=cache, pos=pos)
    else:
        x, cache, _ = _run_blocks(params, cfg, x, positions, "decode",
                                  cache=cache, pos=pos)
    logits = unembed(params, cfg, x)
    return logits[:, 0], cache
