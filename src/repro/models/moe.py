"""Mixture-of-Experts block (qwen3-moe, granite-moe).

Dispatch paths:

* ``dense``  — every expert computes every token, masked combine. Exact
  semantics of a capacity-unbounded top-k MoE; O(E) FLOPs. Tiny smoke
  configs only.
* ``gather`` — capacity-bounded **cumsum dispatch** (GShard semantics,
  no argsort): tokens are grouped (group ≈ one data shard); a running
  per-expert count assigns each (token, k) a capacity slot; tokens scatter
  to [E, C, d], experts run as grouped einsums, results scatter-add back.
  FLOPs ≈ active-params × capacity_factor — what a real deployment pays.
  Overflow tokens drop (standard GShard).

Sharding (per §Perf hillclimb, see EXPERIMENTS.md):
  expert weights [E, d, ff] carry P("tp", None, None) — experts shard over
  the model axis (EP); d/ff stay unsharded (expert weights are small, and
  sharding the contraction dim forces a partial-sum all-reduce of the full
  [E,G,C,ff] intermediate — the dominant collective in the baseline).
  ``pad_experts_to`` rounds E up so EP divides tp=16 (granite: 40→48;
  padded experts are masked out of routing and receive zero tokens).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import activation_sharding
from repro.models.layers import PV, dense_init


def _padded_experts(cfg: ModelConfig) -> int:
    E = cfg.moe.n_experts
    pad = getattr(cfg.moe, "pad_experts_to", 0)
    return max(E, pad) if pad else E


def init_moe(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    E = _padded_experts(cfg)
    ff = cfg.moe.d_ff or cfg.d_ff
    kr, kg, ku, kd = jax.random.split(key, 4)
    scale_in = 1.0 / d ** 0.5
    scale_out = 1.0 / ff ** 0.5
    # §Perf: small-expert archs replicate expert weights (zero MoE
    # collectives, dp-local dispatch); big-expert archs shard E over tp.
    e_ax = "tp" if cfg.moe.ep_shard else None
    return {
        "router": dense_init(kr, d, E, (None, None), scale=0.02),
        "w_gate": PV(jax.random.truncated_normal(kg, -2, 2, (E, d, ff),
                                                 jnp.float32) * scale_in,
                     P(e_ax, None, None)),
        "w_up": PV(jax.random.truncated_normal(ku, -2, 2, (E, d, ff),
                                               jnp.float32) * scale_in,
                   P(e_ax, None, None)),
        "w_down": PV(jax.random.truncated_normal(kd, -2, 2, (E, ff, d),
                                                 jnp.float32) * scale_out,
                     P(e_ax, None, None)),
    }


def _route(p, cfg: ModelConfig, x):
    """x: [..., d] → (gates [..., k], experts [..., k], aux_loss scalar)."""
    E_real, k = cfg.moe.n_experts, cfg.moe.top_k
    E = p["router"].shape[-1]
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if E != E_real:  # mask padded experts out of routing
        emask = jnp.arange(E) < E_real
        logits = jnp.where(emask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)  # norm_topk_prob
    # Switch-style load-balance aux loss: E·mean_e(frac_tokens_e·mean_prob_e)
    assign = jax.nn.one_hot(experts, E, dtype=probs.dtype).sum(axis=-2)
    frac = jnp.mean(assign.reshape(-1, E), axis=0) / k
    mp = jnp.mean(probs.reshape(-1, E), axis=0)
    aux = E_real * jnp.sum(frac * mp)
    return gates.astype(x.dtype), experts, aux


def _dense_moe(p, cfg: ModelConfig, x, gates, experts):
    """All-experts einsum path (smoke configs)."""
    E = p["router"].shape[-1]
    xf = x.astype(jnp.float32)
    g = jnp.einsum("...d,edf->...ef", xf, p["w_gate"].astype(jnp.float32))
    u = jnp.einsum("...d,edf->...ef", xf, p["w_up"].astype(jnp.float32))
    h = jax.nn.silu(g) * u
    y_all = jnp.einsum("...ef,efd->...ed", h, p["w_down"].astype(jnp.float32))
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)          # [...,k,E]
    w = jnp.einsum("...k,...ke->...e", gates.astype(jnp.float32), onehot)
    return jnp.einsum("...ed,...e->...d", y_all, w).astype(x.dtype)


def _gather_moe(p, cfg: ModelConfig, x, gates, experts):
    """Cumsum capacity dispatch (no argsort), per-group flat scatter.

    §Perf iterations 2/3 (2-D EP-sharded buffers; replicated experts) both
    REFUTED their hypotheses — the per-assignment combine gather crosses EP
    shards / replicated fp32 masters blow memory. This formulation keeps the
    iteration-1 flat layout (scatter/gather stay dp-local) and removes the
    argsort (cumsum rank + scatter-ADD with a zero-masked source makes the
    overflow row harmless)."""
    E = p["router"].shape[-1]
    k = cfg.moe.top_k
    G, T, d = x.shape
    C = int(max(1, (T * k * cfg.moe.capacity_factor) //
                max(cfg.moe.n_experts, 1)))

    def per_group(xg, gg, eg):
        flat_e = eg.reshape(-1)                              # [T*k]
        flat_t = jnp.arange(T * k, dtype=jnp.int32) // k
        flat_g = gg.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [T*k, E]
        pos = jnp.cumsum(onehot, axis=0) - onehot
        rank = jnp.sum(pos * onehot, axis=-1)                # [T*k]
        keep = rank < C
        slot = jnp.where(keep, flat_e * C + rank, 0)         # overflow → 0
        xsrc = jnp.where(keep[:, None], xg[flat_t], 0)       # masked source
        xe = jnp.zeros((E * C, d), x.dtype).at[slot].add(xsrc)
        xe = xe.reshape(E, C, d)
        g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(x.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
        contrib = ye.reshape(E * C, d)[slot] * \
            (flat_g * keep).astype(x.dtype)[:, None]
        return jnp.zeros_like(xg).at[flat_t].add(contrib)

    return jax.vmap(per_group)(x, gates, experts)


def apply_moe(p, cfg: ModelConfig, x, n_groups: int = 0):
    """x: [B,S,d] → ([B,S,d], aux loss)."""
    B, S, d = x.shape
    gates, experts, aux = _route(p, cfg, x)
    if cfg.moe.dispatch == "dense":
        y = _dense_moe(p, cfg, x, gates, experts)
        return y, aux
    # group tokens: one group per (pod,data) shard keeps scatters local
    G = n_groups or max(1, B)
    xg = x.reshape(G, (B * S) // G, d)
    gg = gates.reshape(G, (B * S) // G, -1)
    eg = experts.reshape(G, (B * S) // G, -1)
    y = _gather_moe(p, cfg, xg, gg, eg).reshape(B, S, d)
    return y, aux
