"""RG-LRU recurrent block (RecurrentGemma / Griffin — arXiv:2402.19427).

Block: x → {gate branch: GeLU(W_gate x)} ⊙ {main: conv1d → RG-LRU} → W_out.
RG-LRU recurrence (per channel):
    r_t = σ(W_a x_t + b_a)            recurrence gate
    i_t = σ(W_x x_t + b_x)            input gate
    a_t = exp(-c · r_t · softplus(Λ))
    h_t = a_t h_{t-1} + √(1 - a_t²) · (i_t ⊙ x_t)

Train/prefill uses an associative scan (log-depth on TPU); decode is a single
fused step. This block is attention-free: no KV cache → the paper's paged-KV
technique does not apply here (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import PV, dense_init, zeros_init


def init_rglru(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    ks = jax.random.split(key, 5)
    # Λ init so that a^c ∈ (0.9, 0.999) roughly — standard Griffin init
    lam = jnp.log(jnp.expm1(-jnp.log(
        jnp.linspace(0.9, 0.999, w)) / cfg.rglru.c))
    return {
        "w_main": dense_init(ks[0], d, w, ("fsdp", "tp")),
        "w_gate": dense_init(ks[1], d, w, ("fsdp", "tp")),
        "conv_w": PV(jax.random.truncated_normal(
            ks[2], -2, 2, (cfg.rglru.conv_width, w), jnp.float32) * 0.3,
            P(None, "tp")),
        "conv_b": zeros_init((w,), ("tp",)),
        "wa": dense_init(ks[3], w, w, ("tp", None), scale=1.0 / w ** 0.5),
        "ba": zeros_init((w,), (None,)),
        "wx": dense_init(ks[4], w, w, ("tp", None), scale=1.0 / w ** 0.5),
        "bx": zeros_init((w,), (None,)),
        "lam": PV(lam.astype(jnp.float32), P("tp")),
        "w_out": dense_init(jax.random.fold_in(key, 7), w, d, ("tp", "fsdp")),
    }


def _conv1d(x, w, b, state=None):
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (W - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    return y + b.astype(x.dtype), xp[:, -(W - 1):]


def _rglru_coeffs(p, cfg: ModelConfig, u):
    """u [B,S,w] → (a, b) of the linear recurrence h = a·h_prev + b."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["wa"].astype(jnp.float32)
                       + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["wx"].astype(jnp.float32)
                       + p["bx"].astype(jnp.float32))
    log_a = -cfg.rglru.c * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def apply_rglru(p, cfg: ModelConfig, x, h0=None, conv_state=None,
                decode: bool = False):
    """x [B,S,D] → (y [B,S,D], (h [B,w], conv_state))."""
    gate = jax.nn.gelu(jnp.einsum(
        "bsd,dw->bsw", x, p["w_gate"].astype(x.dtype)).astype(jnp.float32))
    u = jnp.einsum("bsd,dw->bsw", x, p["w_main"].astype(x.dtype))
    u, conv_state = _conv1d(u, p["conv_w"], p["conv_b"], conv_state)
    a, b = _rglru_coeffs(p, cfg, u)
    if decode:
        h_prev = jnp.zeros_like(b[:, 0]) if h0 is None else h0
        h = a[:, 0] * h_prev + b[:, 0]
        hs = h[:, None]
    else:
        h_init = jnp.zeros_like(b[:, :1]) if h0 is None else h0[:, None]

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        # fold initial state into the first step's b
        b = b.at[:, 0].add(a[:, 0] * (0.0 if h0 is None else h0))
        _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = hs[:, -1]
    y = (hs * gate).astype(x.dtype)
    y = jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(x.dtype))
    return y, (h, conv_state)
