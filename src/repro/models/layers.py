"""Foundational layers: param containers, norms, RoPE, embeddings.

Logical sharding axes used throughout (resolved by runtime.sharding):
  "fsdp"  — weight-sharded data axes (ZeRO-3 style), maps to ("pod","data")
  "tp"    — tensor-parallel axis, maps to "model"
  None    — replicated
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class PV(NamedTuple):
    """A parameter leaf: value + logical partition spec (PartitionSpec of
    logical axis names)."""
    value: Any
    spec: P


def is_pv(x) -> bool:
    return isinstance(x, PV)


def split_pv_tree(tree):
    """Split a PV-leaf tree into (params, logical_specs) twin trees."""
    params = jax.tree.map(lambda pv: pv.value, tree, is_leaf=is_pv)
    specs = jax.tree.map(lambda pv: pv.spec, tree, is_leaf=is_pv)
    return params, specs


def stack_layer_trees(trees):
    """Stack a list of identical-structure PV trees along a new leading
    (layer) axis; the new axis is unsharded."""
    param_trees = []
    spec_tree = None
    for t in trees:
        p, s = split_pv_tree(t)
        param_trees.append(p)
        spec_tree = s
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *param_trees)
    specs = jax.tree.map(lambda s: P(*((None,) + tuple(s))), spec_tree)
    return params, specs


def _truncated_normal(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in: int, d_out, spec, dtype=jnp.float32, scale=None) -> PV:
    """Fan-in scaled init for a [d_in, *d_out] projection."""
    shape = (d_in,) + (d_out if isinstance(d_out, tuple) else (d_out,))
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return PV(_truncated_normal(key, shape, scale, dtype), P(*spec) if not isinstance(spec, P) else spec)


def zeros_init(shape, spec, dtype=jnp.float32) -> PV:
    return PV(jnp.zeros(shape, dtype), P(*spec) if not isinstance(spec, P) else spec)


def ones_init(shape, spec, dtype=jnp.float32) -> PV:
    return PV(jnp.ones(shape, dtype), P(*spec) if not isinstance(spec, P) else spec)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> PV:
    # vocab on tp (sharded logits/softmax), d on fsdp
    return PV(_truncated_normal(key, (vocab, d), 1.0, dtype), P("tp", "fsdp"))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layernorm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def init_norm(norm_type: str, d: int) -> dict:
    if norm_type == "rmsnorm":
        # stored as (scale - 1) so zeros == identity, llama-style
        return {"w": zeros_init((d,), (None,))}
    return {"w": ones_init((d,), (None,)), "b": zeros_init((d,), (None,))}


def apply_norm(norm_type: str, p: dict, x, eps: float):
    if norm_type == "rmsnorm":
        return rmsnorm(x, p["w"], eps)
    return layernorm(x, p["w"], p["b"], eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_ctx: int, d: int):
    """Whisper-style fixed sinusoidal embeddings [n_ctx, d]."""
    pos = jnp.arange(n_ctx, dtype=jnp.float32)[:, None]
    div = jnp.exp(-math.log(10000.0) * jnp.arange(d // 2, dtype=jnp.float32)
                  / max(d // 2 - 1, 1))
    ang = pos * div[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap else x
