"""Mamba-2 SSD (state-space duality) block — arXiv:2405.21060.

Chunked SSD for train/prefill (quadratic within chunks, linear across), and a
constant-memory recurrent step for decode. Single group (G=1) of B/C shared
across heads, scalar-per-head A — the mamba2-130m configuration.

Shapes (train):  x [B,S,D] → y [B,S,D]
State (decode):  h [B,H,P,N]  (H=ssm heads, P=head_dim, N=d_state)
                 conv [B,W-1,d_conv_channels]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import PV, dense_init, ones_init, zeros_init


def ssm_dims(cfg: ModelConfig):
    d_inner = cfg.ssm.expand * cfg.d_model
    n_heads = d_inner // cfg.ssm.head_dim
    return d_inner, n_heads, cfg.ssm.d_state


def init_ssm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, H, N = ssm_dims(cfg)
    conv_ch = d_inner + 2 * N          # x, B, C all pass through the conv
    ks = jax.random.split(key, 5)
    dt_bias = jnp.log(jnp.exp(
        jnp.linspace(cfg.ssm.dt_min, cfg.ssm.dt_max, H)) - 1.0)  # inv softplus
    return {
        # in_proj → [z, x, B, C, dt]
        "w_in": dense_init(ks[0], d, 2 * d_inner + 2 * N + H, ("fsdp", "tp")),
        "conv_w": PV(jax.random.truncated_normal(
            ks[1], -2, 2, (cfg.ssm.conv_width, conv_ch), jnp.float32) * 0.3,
            P(None, "tp")),
        "conv_b": zeros_init((conv_ch,), ("tp",)),
        "A_log": PV(jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)), P("tp")),
        "dt_bias": PV(dt_bias.astype(jnp.float32), P("tp")),
        "D": ones_init((H,), ("tp",)),
        "norm_w": zeros_init((d_inner,), ("tp",)),
        "w_out": dense_init(ks[2], d_inner, d, ("tp", "fsdp")),
    }


def _split_proj(p, cfg, zxbcdt):
    d_inner, H, N = ssm_dims(cfg)
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, b, state=None):
    """Depthwise causal conv, width W. xBC [B,S,C]; w [W,C].

    Returns (y [B,S,C], new_state [B,W-1,C])."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros(xBC.shape[:1] + (W - 1,) + xBC.shape[2:], xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)                     # [B,S+W-1,C]
    y = sum(xp[:, i:i + xBC.shape[1]] * w[i].astype(xBC.dtype)
            for i in range(W))
    y = jax.nn.silu((y + b.astype(xBC.dtype)).astype(jnp.float32)).astype(xBC.dtype)
    return y, xp[:, -(W - 1):]


def _segsum(x):
    """x [..., L] → lower-triangular pairwise sums: out[..., i, j] =
    sum_{j<m<=i} x[m]; -inf above diagonal."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int, h0=None):
    """Chunked SSD scan.

    x  [B,S,H,P]  inputs per head
    dt [B,S,H]    softplus'd timestep
    A  [H]        negative decay rate
    Bm [B,S,N], Cm [B,S,N]  (single group broadcast over heads)
    Returns (y [B,S,H,P], h_final [B,H,P,N]).
    """
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S) if S % chunk else chunk
    pad = (-S) % L
    if pad:
        # zero-dt padding is a no-op on the recurrence (decay=1, input=0)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_pad = S + pad
    nc = S_pad // L
    xc = x.reshape(Bsz, nc, L, H, Pd)
    dtc = dt.reshape(Bsz, nc, L, H)
    Bc = Bm.reshape(Bsz, nc, L, N)
    Cc = Cm.reshape(Bsz, nc, L, N)

    dA = dtc * A  # [B,nc,L,H]  (A<0)
    dA_cum = jnp.cumsum(dA, axis=2)                              # within-chunk
    # 1) diagonal (intra-chunk) term
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA, 3, 2)))              # [B,nc,H,L,L]
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)               # [B,nc,L,L]
    y_diag = jnp.einsum("bclm,bchlm,bcmh,bcmhp->bclhp",
                        scores, Lmat, dtc, xc)
    # 2) chunk states: contribution of each chunk to the carried state
    decay_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)           # [B,nc,L,H]
    states = jnp.einsum("bcln,bclh,bclhp->bchpn",
                        Bc, dtc * decay_end, xc)                 # [B,nc,H,P,N]
    # 3) inter-chunk recurrence h_c = h_{c-1} * exp(sum dA_c) + states_c
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                   # [B,nc,H]

    def scan_fn(h, inp):
        st, cd = inp
        h_new = h * cd[..., None, None] + st
        return h_new, h

    h_init = (jnp.zeros((Bsz, H, Pd, N), x.dtype) if h0 is None
              else h0.astype(x.dtype))
    h_last, h_prev = jax.lax.scan(
        scan_fn, h_init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                          # [B,nc,H,P,N]
    # 4) off-diagonal term: prior state read at each position
    state_decay = jnp.exp(dA_cum)                                # [B,nc,L,H]
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, h_prev, state_decay)
    y = (y_diag + y_off).reshape(Bsz, S_pad, H, Pd) + D[None, None, :, None] * x
    return y[:, :S], h_last


def ssd_decode_step(x, dt, A, Bm, Cm, D, h):
    """Single-token recurrence. x [B,H,P]; dt [B,H]; Bm,Cm [B,N]; h [B,H,P,N]."""
    dA = jnp.exp(dt * A)                                         # [B,H]
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm, x)
    h = h * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm, h) + D[None, :, None] * x
    return y, h


def apply_ssm(p, cfg: ModelConfig, x, h0=None, conv_state=None, decode=False):
    """Full mamba2 block. Train/prefill: x [B,S,D]. Decode: x [B,1,D].

    Returns (y, (h, conv_state))."""
    d_inner, H, N = ssm_dims(cfg)
    Pd = cfg.ssm.head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    z, xBC, dt = _split_proj(p, cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # [B,S,H]
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xin, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # [H]
    Bsz, S = x.shape[0], x.shape[1]
    xh = xin.reshape(Bsz, S, H, Pd)
    if decode:
        y, h = ssd_decode_step(
            xh[:, 0].astype(jnp.float32), dt[:, 0], A,
            Bm[:, 0].astype(jnp.float32), Cm[:, 0].astype(jnp.float32),
            p["D"].astype(jnp.float32),
            (jnp.zeros((Bsz, H, Pd, N), jnp.float32) if h0 is None
             else h0.astype(jnp.float32)))
        y = y[:, None].reshape(Bsz, 1, d_inner).astype(x.dtype)
    else:
        y, h = ssd_chunked(xh.astype(jnp.float32), dt, A,
                           Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                           p["D"].astype(jnp.float32), cfg.ssm.chunk,
                           h0=h0)
        y = y.reshape(Bsz, S, d_inner).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    dtp = y.dtype
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, axis=-1, keepdims=True) + 1e-6)
    y = (yf * (1.0 + p["norm_w"].astype(jnp.float32))).astype(dtp)
    y = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    return y, (h, conv_state)
