"""Feed-forward blocks: SwiGLU (llama/qwen), squared-ReLU (nemotron-4),
GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, squared_relu


def init_mlp(key, cfg: ModelConfig, d_ff: int = 0) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(k1, d, ff, ("fsdp", "tp")),
            "w_up": dense_init(k2, d, ff, ("fsdp", "tp")),
            "w_down": dense_init(k3, ff, d, ("tp", "fsdp")),
        }
    return {
        "w_up": dense_init(k1, d, ff, ("fsdp", "tp")),
        "w_down": dense_init(k2, ff, d, ("tp", "fsdp")),
    }


def apply_mlp(p, cfg: ModelConfig, x):
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        h = squared_relu(h) if cfg.mlp_type == "squared_relu" else jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
