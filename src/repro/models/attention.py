"""GQA / MQA / MHA attention with full + sliding-window masking.

Three entry points sharing one weight set:
  attn_train    — causal self-attention over a full sequence
  attn_prefill  — same, but also returns the KV cache slab
  attn_decode   — single-token step against a dense KV cache

The inner product is factored through ``attention_core`` so the runtime can
swap in the flash-attention Pallas kernel (TPU) or the jnp reference (CPU /
dry-run lowering) without touching call sites.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import activation_sharding
from repro.models.layers import (PV, apply_rope, dense_init, rmsnorm,
                                 softcap, zeros_init)

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (H, hd), ("fsdp", "tp", None)),
        "wk": dense_init(ks[1], d, (KV, hd), ("fsdp", "tp", None)),
        "wv": dense_init(ks[2], d, (KV, hd), ("fsdp", "tp", None)),
        "wo": PV(dense_init(ks[3], H * hd, d, (None,), scale=1.0 / (H * hd) ** 0.5).value
                 .reshape(H, hd, d), P("tp", None, "fsdp")),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = zeros_init((H, hd), ("tp", None))
        p["bk"] = zeros_init((KV, hd), ("tp", None))
        p["bv"] = zeros_init((KV, hd), ("tp", None))
    if cfg.qk_norm:
        p["q_norm"] = zeros_init((hd,), (None,))
        p["k_norm"] = zeros_init((hd,), (None,))
    return p


def _project_qkv(p, cfg: ModelConfig, x, kv_x=None):
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    return q, k, v


def _causal_mask(q_pos, k_pos, window: int):
    """mask[..., s, t] True where k-position t is visible from q-position s."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def attention_core(q, k, v, mask, scale: float, attn_softcap: float = 0.0):
    """q:[B,S,H,hd] k,v:[B,T,KV,hd] mask:[B,1,S,T] or broadcastable.

    GQA is computed flat-head (K/V repeated to H): every assigned arch has
    KV < 16, so a [B,KV,G,S,T] score layout cannot shard its head dims on
    tp=16 — the flat [B,H,S,T] layout shards cleanly whenever H % tp == 0
    (and replication of the *repeated* K/V is local, no collectives).
    fp32 accumulation, bf16 operands (MXU-native).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    # bf16 matmul + f32 softmax after the cast: an f32-accumulating einsum
    # here makes every backward cotangent (and thus every SP/FSDP collective
    # in the layer body) f32 — 2× the bytes. The deployed Pallas flash
    # kernel accumulates in f32 *inside* the kernel without f32 residents.
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    # hook: shard the query dim of S×T scores (archs with H % tp != 0)
    scores = activation_sharding.constrain(scores, "scores")
    scores = softcap(scores, attn_softcap)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthd->bshd", w, v)
    return out


def _out_proj(p, cfg, out):
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))


def attn_train(p, cfg: ModelConfig, x, positions, window: Optional[int] = None):
    """x: [B,S,D], positions: [B,S] → [B,S,D]. Causal (+optional window)."""
    w = cfg.window if window is None else window
    q, k, v = _project_qkv(p, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    mask = _causal_mask(positions, positions, w)[:, None]  # [B,1,S,T]
    out = attention_core(q, k, v, mask, cfg.resolved_head_dim ** -0.5,
                         cfg.attn_softcap)
    return _out_proj(p, cfg, out)


def attn_prefill(p, cfg: ModelConfig, x, positions, window: Optional[int] = None):
    """Like attn_train but also returns (k,v) cache slabs [B,T,KV,hd]."""
    w = cfg.window if window is None else window
    q, k, v = _project_qkv(p, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    mask = _causal_mask(positions, positions, w)[:, None]
    out = attention_core(q, k, v, mask, cfg.resolved_head_dim ** -0.5,
                         cfg.attn_softcap)
    return _out_proj(p, cfg, out), (k, v)


def attn_decode(p, cfg: ModelConfig, x, cache_k, cache_v, pos,
                window: Optional[int] = None):
    """Single-token decode.

    x: [B,1,D]; cache_{k,v}: [B,T,KV,hd]; pos: [B] current write position.
    When the cache slab is smaller than the position range (sliding-window
    archs), it is treated as a RING buffer: slot j holds the most recent
    position ≡ j (mod T). Returns (y [B,1,D], new_cache_k, new_cache_v).
    """
    w = cfg.window if window is None else window
    B, T = cache_k.shape[0], cache_k.shape[1]
    ring = bool(w) and T <= w
    q, k, v = _project_qkv(p, cfg, x)                      # [B,1,·,hd]
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    bidx = jnp.arange(B)
    slot = (pos % T) if ring else pos
    cache_k = cache_k.at[bidx, slot].set(k[:, 0])
    cache_v = cache_v.at[bidx, slot].set(v[:, 0])
    if ring:
        j = jnp.arange(T)[None, :]
        k_pos = pos[:, None] - ((pos[:, None] - j) % T)    # [B,T]
        mask = (_causal_mask(pos[:, None], k_pos, w) &
                (k_pos >= 0)[:, None, :])[:, None]
    else:
        k_pos = jnp.arange(T)[None, :]                     # [1,T]
        mask = _causal_mask(pos[:, None], k_pos, w)[:, None]
    out = attention_core(q, cache_k, cache_v, mask,
                         cfg.resolved_head_dim ** -0.5, cfg.attn_softcap)
    return _out_proj(p, cfg, out), cache_k, cache_v


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_kv(p, cfg: ModelConfig, enc_out):
    """Precompute K,V from encoder output: [B,T,D] → ([B,T,KV,hd] ×2)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v


def cross_attend(p, cfg: ModelConfig, x, k, v):
    """Decoder queries against precomputed encoder K/V (no mask, no rope)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    mask = jnp.ones((1, 1, 1, k.shape[1]), dtype=bool)
    out = attention_core(q, k, v, mask, cfg.resolved_head_dim ** -0.5)
    return _out_proj(p, cfg, out)


def bidir_attend(p, cfg: ModelConfig, x, positions):
    """Bidirectional self-attention (whisper encoder). No rope (sinusoid pos
    already added), no mask."""
    q, k, v = _project_qkv(p, cfg, x)
    mask = jnp.ones((1, 1, 1, k.shape[1]), dtype=bool)
    out = attention_core(q, k, v, mask, cfg.resolved_head_dim ** -0.5)
    return _out_proj(p, cfg, out)
