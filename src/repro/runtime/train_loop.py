"""Train-step builder: microbatched grad accumulation, bf16 grad
compression, remat-aware, ZeRO-sharded AdamW. The returned step is a pure
function suitable for ``jax.jit(..., donate_argnums=(0, 1))``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.runtime.sharding import ShardingPolicy


def _cast_params(params, dtype):
    """Cast ≥2-D float params for compute/grad; keeps the backward
    reduce-scatter in `dtype` (gradient compression)."""
    def c(x):
        if x.dtype == jnp.float32 and x.ndim >= 2:
            return x.astype(dtype)
        return x
    return jax.tree.map(c, params)


def build_train_step(cfg: ModelConfig, policy: ShardingPolicy,
                     lr_fn: Callable, loss_fn: Optional[Callable] = None,
                     grad_shardings=None, accum_dtype=jnp.float32):
    """Returns train_step(params, opt_state, batch, step) →
    (params, opt_state, metrics). grad_shardings (optional, pytree matching
    params): keeps the grad-accumulation carry sharded like the params —
    without it XLA may replicate the accumulator across the mesh."""
    loss_fn = loss_fn or (lambda p, b: tf.loss_fn(p, cfg, b))
    M = policy.microbatches
    gdtype = (jnp.bfloat16 if policy.grad_compress_dtype == "bfloat16"
              else jnp.float32)

    def _constrain(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    def train_step(params, opt_state, batch, step):
        # cast once OUTSIDE the microbatch loop: FSDP weight all-gathers in
        # the loop bodies then move bf16, not f32 (2× collective bytes).
        # The cast is linear, so ∂L/∂params == ∂L/∂pb numerically.
        pb = _cast_params(params, gdtype)
        if M > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                batch)

            def body(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(pb, mb)
                g_acc = jax.tree.map(
                    lambda a, b: (a + b.astype(accum_dtype)
                                  ).astype(accum_dtype), g_acc, g)
                return (_constrain(g_acc), l_acc + loss), None

            g0 = _constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))
            (grads, loss), _ = jax.lax.scan(body, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / M, grads)
            loss = loss / M
        else:
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(pb, batch)
        lr = lr_fn(step)
        new_params, new_opt, gn = adamw_update(params, grads, opt_state, lr)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": gn, "lr": jnp.asarray(lr, jnp.float32)}
        return new_params, new_opt, metrics

    return train_step


def init_train_state(cfg: ModelConfig, key):
    params, specs = tf.init_lm(cfg, key)
    opt_state = adamw_init(params)
    return params, opt_state, specs


def opt_state_specs(param_specs):
    """AdamW state specs mirror params (ZeRO: same sharding)."""
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), m=param_specs,
                      v=jax.tree.map(lambda s: s, param_specs))
