"""Fault tolerance & elasticity for 1000+ node fleets.

Pieces (all host-side control plane — the data plane stays pure JAX):

* HeartbeatMonitor — tracks per-worker liveness + step latencies; flags
  stragglers at p99 × factor (mitigation: skip-and-rebalance or reshard).
* ElasticMeshManager — given the surviving device set, rebuilds the largest
  (data × model) mesh that keeps `model` intact (TP groups must be whole —
  losing one chip removes its whole TP group from the data axis), and
  computes the resharding plan = just re-applying the logical specs on the
  new mesh (checkpoints store logical specs, never device layouts).
* TrainSupervisor — retry loop: run_step with deadline → on failure,
  checkpoint-restore → remesh → continue. Exercised in tests with injected
  failures.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence


@dataclasses.dataclass
class WorkerHealth:
    worker_id: int
    last_seen: float
    step_times: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, n_workers: int, timeout_s: float = 60.0,
                 straggler_factor: float = 3.0):
        now = time.time()
        self.workers = {i: WorkerHealth(i, now) for i in range(n_workers)}
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor

    def heartbeat(self, worker_id: int, step_time: Optional[float] = None):
        w = self.workers[worker_id]
        w.last_seen = time.time()
        w.alive = True
        if step_time is not None:
            w.step_times.append(step_time)
            w.step_times = w.step_times[-100:]

    def dead_workers(self) -> List[int]:
        now = time.time()
        out = []
        for w in self.workers.values():
            if now - w.last_seen > self.timeout_s:
                w.alive = False
                out.append(w.worker_id)
        return out

    def stragglers(self) -> List[int]:
        med = []
        for w in self.workers.values():
            if w.step_times:
                med.append(sorted(w.step_times)[len(w.step_times) // 2])
        if not med:
            return []
        fleet_median = sorted(med)[len(med) // 2]
        out = []
        for w in self.workers.values():
            if w.step_times and w.step_times[-1] > \
                    fleet_median * self.straggler_factor:
                out.append(w.worker_id)
        return out


class ElasticMeshManager:
    """Recompute the (data, model) mesh after failures.

    Chips come in TP groups of `model` size; a dead chip disables its whole
    group (collectives inside a TP group are latency-critical — spanning a
    hole is worse than dropping the group). The data axis shrinks to the
    surviving group count; global batch stays constant (per-device batch
    grows or grad-accumulation microbatches increase)."""

    def __init__(self, model_axis: int = 16):
        self.model_axis = model_axis

    def plan(self, n_total_chips: int, dead_chips: Sequence[int]) -> Dict:
        groups = n_total_chips // self.model_axis
        dead_groups = {c // self.model_axis for c in dead_chips}
        surviving = [g for g in range(groups) if g not in dead_groups]
        if not surviving:
            raise RuntimeError("no surviving TP groups")
        return {
            "mesh_shape": (len(surviving), self.model_axis),
            "surviving_groups": surviving,
            "lost_fraction": 1 - len(surviving) / groups,
            # microbatch multiplier keeps global batch & math identical
            "microbatch_scale": groups / len(surviving),
        }


class TrainSupervisor:
    """Run-with-retry harness around a step function."""

    def __init__(self, step_fn: Callable, save_fn: Callable,
                 restore_fn: Callable, max_retries: int = 3,
                 step_deadline_s: Optional[float] = None):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.max_retries = max_retries
        self.step_deadline_s = step_deadline_s
        self.failures: List[Dict] = []

    def run(self, state, start_step: int, n_steps: int):
        step = start_step
        retries = 0
        while step < start_step + n_steps:
            t0 = time.time()
            try:
                state = self.step_fn(state, step)
                dt = time.time() - t0
                if self.step_deadline_s and dt > self.step_deadline_s:
                    self.failures.append(
                        {"step": step, "kind": "straggler", "dt": dt})
                retries = 0
                step += 1
            except Exception as e:  # noqa: BLE001 — injected faults in tests
                self.failures.append(
                    {"step": step, "kind": "error", "err": str(e)})
                retries += 1
                if retries > self.max_retries:
                    self.save_fn(step, state)
                    raise
                state, step = self.restore_fn()
        return state, step
