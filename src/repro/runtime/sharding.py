"""Logical-axis → mesh-axis resolution.

Models annotate params/activations with *logical* axes:
  "fsdp" — weight sharding over the data-parallel axes (ZeRO-3)
  "tp"   — tensor parallel (heads / ffn / vocab / experts)
  "dp"   — batch data parallel
  "sp"   — sequence parallel (long-context decode caches)

A ``ShardingPolicy`` maps logical names to physical mesh axes. The default
production policy on mesh (pod, data, model):
  fsdp → ("pod","data")   tp → "model"   dp → ("pod","data")   sp → "model"

Policies are the unit of perf iteration: §Perf hillclimbs swap policies, not
model code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Logical→physical axis mapping + runtime knobs."""
    rules: Dict[str, Axis]
    microbatches: int = 1           # grad-accumulation steps per train step
    zero_opt_state: bool = True     # shard optimizer state like params (ZeRO)
    grad_compress_dtype: Optional[str] = "bfloat16"  # DP-reduce compression
    name: str = "default"

    def resolve(self, spec: P) -> P:
        out = []
        for ax in tuple(spec):
            if ax is None:
                out.append(None)
            elif isinstance(ax, str):
                out.append(self.rules.get(ax, None))
            else:  # tuple of logical names
                phys: list = []
                for a in ax:
                    r = self.rules.get(a)
                    if r is None:
                        continue
                    phys.extend(r if isinstance(r, tuple) else (r,))
                out.append(tuple(phys) if phys else None)
        return P(*out)

    def shard(self, mesh: Mesh, spec: P) -> NamedSharding:
        return NamedSharding(mesh, self.resolve(spec))

    def tree_shardings(self, mesh: Mesh, spec_tree) -> Any:
        return jax.tree.map(lambda s: self.shard(mesh, s), spec_tree)

    def tree_specs(self, spec_tree) -> Any:
        return jax.tree.map(self.resolve, spec_tree)


def default_policy(mesh: Mesh, **kw) -> ShardingPolicy:
    names = mesh.axis_names
    dp_axes = tuple(a for a in ("pod", "data") if a in names) or None
    rules = {
        "fsdp": dp_axes,
        "dp": dp_axes,
        "tp": "model" if "model" in names else None,
        "sp": "model" if "model" in names else None,
    }
    return ShardingPolicy(rules=rules, **kw)


def single_device_policy(**kw) -> ShardingPolicy:
    return ShardingPolicy(rules={}, name="single", **kw)


def batch_specs(policy: ShardingPolicy, batch_tree_specs) -> Any:
    return jax.tree.map(policy.resolve, batch_tree_specs)


# --- policy variants used by §Perf hillclimbs -------------------------------

def tp_only_policy(mesh: Mesh, **kw) -> ShardingPolicy:
    """No FSDP: weights replicated over data axes, TP over model."""
    p = default_policy(mesh, **kw)
    rules = dict(p.rules)
    rules["fsdp"] = None
    return dataclasses.replace(p, rules=rules, name="tp_only")


def seq_shard_policy(mesh: Mesh, **kw) -> ShardingPolicy:
    """Long-context decode: shard cache sequence dim over the data axes
    (batch too small to occupy them)."""
    p = default_policy(mesh, **kw)
    rules = dict(p.rules)
    rules["sp"] = rules["dp"]       # sequence rides the data axes
    rules["dp"] = None              # batch=1: replicate
    return dataclasses.replace(p, rules=rules, name="seq_shard")
