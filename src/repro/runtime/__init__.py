"""Distributed runtime: sharding resolution, train/serve step builders,
fault tolerance."""
