"""Distributed runtime: sharding resolution and the train step builder.

The old serve loop and fault-tolerance scaffolding moved into the
hypervisor control plane (``repro.core.hext.service`` /
``repro.core.hext.policies``)."""
