"""Multi-tenant continuous-batching serving loop over the two-stage paged
KV cache (the paper's technique as a first-class serving feature).

Control plane (python): admission, per-tenant quotas, page-fault handling
(the hypervisor loop), eviction. Data plane (jit): prefill / batched decode
steps that read KV through the fused translation.

For frameworks-level simplicity the decode data plane here uses the *dense*
per-request cache produced by ``transformer.prefill`` for model state
(conv/ssm states etc.) and the paged pool for attention K/V; the Pallas
``paged_attention`` kernel is the TPU hot path (ref path on CPU).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.vmem import allocator as AL
from repro.core.vmem import kvcache as KC
from repro.core.vmem import page_table as PT


@dataclasses.dataclass
class Request:
    req_id: int
    tenant: int
    prompt: np.ndarray                 # [S] int32
    max_new: int = 16
    slot: int = -1                     # batch lane when scheduled
    generated: Optional[List[int]] = None
    done: bool = False
    t_submit: float = 0.0
    t_first_token: float = 0.0


class PagedServer:
    """Continuous batching with tenant isolation.

    Per decoded token, each running request:
      1. translates its logical KV pages (fused cache fast path),
      2. on a translation fault, traps to the scheduler which allocates via
         the quota-checked pool and edits stage-1/stage-2 (+hfence) — the
         exact trap-and-emulate structure of the H extension,
      3. appends K/V through the write path and attends via paged attention.
    """

    def __init__(self, cfg: ModelConfig, params, page_size: int = 16,
                 n_slots: int = 256, n_tenants: int = 4,
                 reqs_per_tenant: int = 8, logical_pages: int = 32,
                 tenant_pages: int = 64, quotas=None, max_batch: int = 8):
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_batch = max_batch
        self.kv = KC.PagedKVCache.create(
            n_slots, page_size, max(cfg.n_kv_heads, 1),
            cfg.resolved_head_dim, n_tenants, reqs_per_tenant,
            logical_pages, tenant_pages, quotas=quotas)
        self.queue: List[Request] = []
        self.running: Dict[int, Request] = {}
        self.tenant_req_ids: Dict[int, int] = {t: 0 for t in range(n_tenants)}
        self.stats = {"faults_stage1": 0, "faults_stage2": 0,
                      "tokens": 0, "evictions": 0, "rejected": 0}

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        req.t_submit = time.time()
        req.generated = []
        self.queue.append(req)
        return True

    def _admit(self):
        while self.queue and len(self.running) < self.max_batch:
            req = self.queue.pop(0)
            rid = self.tenant_req_ids[req.tenant]
            self.tenant_req_ids[req.tenant] = \
                (rid + 1) % self.kv.tables.vs_table.shape[1]
            req.slot = rid
            if not self._ensure_pages(req.tenant, rid,
                                      len(req.prompt) + req.max_new):
                self.stats["rejected"] += 1
                req.done = True
                continue
            self._prefill(req)
            self.running[req.req_id] = req

    # -- the hypervisor loop ----------------------------------------------------
    def _ensure_pages(self, tenant: int, rid: int, n_tokens: int) -> bool:
        n_pages = (n_tokens + self.page_size - 1) // self.page_size
        for p in range(n_pages):
            tr = PT.translate(self.kv.tables, tenant, rid, p,
                              use_fused=False)
            if bool(tr.fault):
                self.stats["faults_stage%d" % max(int(tr.stage), 1)] += 1
                self.kv, ok = KC.ensure_mapped(self.kv, tenant, rid, p)
                if not ok:
                    return False
        return True

    def evict_tenant(self, tenant: int):
        """Tenant teardown: one stage-2 sweep (the two-stage win)."""
        self.kv = KC.evict_tenant(self.kv, tenant)
        for req in list(self.running.values()):
            if req.tenant == tenant:
                req.done = True
                del self.running[req.req_id]
        self.stats["evictions"] += 1

    # -- data plane -------------------------------------------------------------
    def _prefill(self, req: Request):
        from repro.models import transformer as tf
        tokens = jnp.asarray(req.prompt)[None]
        cache = tf.init_cache(self.cfg, 1, len(req.prompt) + req.max_new)
        logits, cache = tf.prefill(self.params, self.cfg, tokens, cache)
        req.cache = cache
        req.pos = len(req.prompt)
        req.next_token = int(jnp.argmax(logits[0]))
        req.t_first_token = time.time()
        # mirror prompt K/V into the paged pool (write path, perm-checked)
        # (demonstrates the translation write path; attention reads go
        # through the same tables)
        for t in range(len(req.prompt)):
            k = jnp.zeros((max(self.cfg.n_kv_heads, 1),
                           self.cfg.resolved_head_dim), jnp.bfloat16)
            self.kv, fault = KC.write_token(self.kv, req.tenant, req.slot,
                                            t, k, k)

    def step(self):
        """One decode step for every running request."""
        from repro.models import transformer as tf
        self._admit()
        if not self.running:
            return []
        emitted = []
        for req in list(self.running.values()):
            # page fault check for the next position (trap-and-emulate)
            page = req.pos // self.page_size
            tr = PT.translate(self.kv.tables, req.tenant, req.slot, page,
                              acc_write=True)
            if bool(tr.fault):
                self.stats["faults_stage%d" % max(int(tr.stage), 1)] += 1
                self.kv, ok = KC.ensure_mapped(self.kv, req.tenant,
                                               req.slot, page)
                if not ok:          # quota exhausted → reject/evict
                    req.done = True
                    del self.running[req.req_id]
                    self.stats["rejected"] += 1
                    continue
            token = jnp.asarray([req.next_token], jnp.int32)
            pos = jnp.asarray([req.pos], jnp.int32)
            logits, req.cache = tf.decode_step(self.params, self.cfg, token,
                                               pos, req.cache)
            nxt = int(jnp.argmax(logits[0]))
            req.generated.append(nxt)
            req.next_token = nxt
            req.pos += 1
            self.stats["tokens"] += 1
            emitted.append((req.req_id, nxt))
            if len(req.generated) >= req.max_new:
                req.done = True
                del self.running[req.req_id]
        return emitted

    def run_until_drained(self, max_steps: int = 1000):
        steps = 0
        while (self.queue or self.running) and steps < max_steps:
            self.step()
            steps += 1
        return self.stats
