import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
ShapeDtypeStruct inputs (no allocation), print memory/cost analysis, and
extract the roofline terms (§Roofline of EXPERIMENTS.md).

The two XLA_FLAGS lines above MUST run before any other import — jax locks
the host device count at first init. Smoke tests / benches never import this
module, so they see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch minicpm_2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch import specs as SPECS
from repro.launch.mesh import make_production_mesh
from repro.launch import analytic
from repro.launch.roofline import (HBM_PER_CHIP, HBM_BW, LINK_BW, PEAK_FLOPS,
                                   collective_bytes,
                                   collective_bytes_corrected)
from repro.models import activation_sharding
from repro.models import transformer as tf
from repro.optim.schedule import cosine_schedule
from repro.runtime.sharding import ShardingPolicy, default_policy
from repro.runtime.train_loop import build_train_step

# Per-arch dry-run overrides: dtype/microbatching tuned so the big configs
# fit v5e HBM (documented in EXPERIMENTS.md §Dry-run).
ARCH_OVERRIDES = {
    "nemotron_4_340b": {"param_dtype": "bfloat16", "microbatches": 16,
                        "seq_shard": True, "remat": "full",
                        "low_mem_opt": True},   # bf16 m/v + bf16 grad accum
    "qwen15_32b": {"microbatches": 8, "seq_shard": True},      # 40 heads
    "qwen3_moe_30b_a3b": {"microbatches": 8},
    "recurrentgemma_9b": {"microbatches": 8},
    "minicpm_2b": {"microbatches": 8},  # 36 heads → scores hook
    "granite_moe_3b_a800m": {"microbatches": 8},  # 24 heads
    "h2o_danube_3_4b": {"microbatches": 8},
    "internvl2_2b": {"microbatches": 8},
    "whisper_base": {"microbatches": 4},  # 8 heads
    "mamba2_130m": {"microbatches": 2},
}


def _fb_shardings(mesh, pol, spec_tree, shape_tree):
    """Resolve logical specs → NamedShardings, dropping any axis that does
    not divide the corresponding dim (vocab/expert/head remainders)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(spec, sds):
        phys = pol.resolve(spec)
        new = []
        for i, ax in enumerate(tuple(phys)):
            if ax is None or i >= len(sds.shape):
                new.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= sizes[a]
            new.append(ax if (n and sds.shape[i] % n == 0) else None)
        return NamedSharding(mesh, P(*new))

    import jax as _jax
    return _jax.tree.map(one, spec_tree, shape_tree,
                         is_leaf=lambda x: isinstance(x, P))


def _policy_for(mesh, mode: str, arch: str,
                policy_name: str = "default") -> ShardingPolicy:
    ov = ARCH_OVERRIDES.get(arch, {})
    mb = ov.get("microbatches", 8) if mode == "train" else 1
    pol = default_policy(mesh, microbatches=mb)
    if policy_name == "tp_only":
        from repro.runtime.sharding import tp_only_policy
        pol = tp_only_policy(mesh, microbatches=mb)
    return pol


def _install_seq_shard(mesh, pol, on: bool, scores_on: bool = False):
    """Sequence-parallel activation constraint (large archs); scores_on
    installs the score-matrix constraint (archs whose head count does not
    divide tp would otherwise replicate S×T score buffers)."""
    dp = pol.rules.get("dp")
    tp = pol.rules.get("tp")

    def block_c(x):
        if x.ndim == 3:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, tp, None)))
        return x

    def embed_c(x):
        spec = P(dp, tp, None) if on else P(dp, None, None)
        if x.ndim == 3 and x.shape[1] % 16 == 0:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        return x

    def logits_c(x):
        if x.ndim == 3:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, None, tp)))
        return x

    def scores_c(x):
        # shard the *query* seq dim of [B,H,S,T] — softmax over keys stays
        # local, composes with SP. Batch stays on dp (None = replicate!).
        if x.ndim == 4 and x.shape[-2] % 16 == 0:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, None, tp, None)))
        return x

    def inner_c(x):
        if x.ndim == 3:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, None, None)))
        return x

    activation_sharding.set_constraint(block_c if on else None, "block")
    activation_sharding.set_constraint(inner_c if on else None, "inner")
    activation_sharding.set_constraint(embed_c, "embed")
    activation_sharding.set_constraint(logits_c, "logits")
    activation_sharding.set_constraint(scores_c if scores_on else None,
                                       "scores")

    def moe_c(x):
        if x.ndim == 4 and x.shape[1] % 16 == 0:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, tp, None, None)))
        return x

    activation_sharding.set_constraint(moe_c, "moe")

    def moe_rep_c(x):
        if x.ndim == 4:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, None, None, None)))
        return x

    activation_sharding.set_constraint(moe_rep_c, "moe_rep")


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             policy_name: str = "default", seq_shard: Optional[bool] = None,
             microbatches: Optional[int] = None,
             param_dtype: Optional[str] = None,
             donate: bool = True) -> dict:
    cfg = get_config(arch)
    ov0 = ARCH_OVERRIDES.get(arch, {})
    if "remat" in ov0:
        cfg = dataclasses.replace(cfg, remat=ov0["remat"])
    shape = SHAPES[shape_name]
    mode = shape.kind
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mode": mode,
           "multi_pod": multi_pod, "policy": policy_name}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    pol = _policy_for(mesh, mode, arch, policy_name)
    ov = ARCH_OVERRIDES.get(arch, {})
    if microbatches is not None and mode == "train":
        pol = dataclasses.replace(pol, microbatches=microbatches)
    pdtype = param_dtype or ov.get("param_dtype")
    seq_on = ov.get("seq_shard", False) if seq_shard is None else seq_shard
    heads_div = cfg.n_heads % 16 == 0   # flat-head attention: H is the axis
    _install_seq_shard(mesh, pol, seq_on and mode == "train",
                       scores_on=(not heads_div) and mode != "decode")
    if mode == "decode":
        # flash-decode sharding: scores stay sharded on the KEY dim (the
        # cache's seq shards) — softmax/out reduce small partials instead of
        # all-gathering the KV cache every step (§Perf qwen3-decode iter 2)
        dp_ax = pol.rules.get("dp")
        tp_ax = pol.rules.get("tp")

        def scores_decode_c(x):
            if x.ndim == 4 and x.shape[-1] % 16 == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(dp_ax, None, None, tp_ax)))
            return x

        activation_sharding.set_constraint(scores_decode_c, "scores")

    t0 = time.time()
    try:
        if mode == "train":
            dtype = jnp.bfloat16 if pdtype == "bfloat16" else None
            pshapes, pspecs = SPECS.abstract_params(cfg, dtype=dtype)
            low_mem = ov.get("low_mem_opt", False)
            oshapes, ospecs = SPECS.abstract_opt_state(
                pshapes, pspecs,
                dtype=jnp.bfloat16 if low_mem else jnp.float32)
            bshapes, bspecs = SPECS.train_inputs(cfg, shape)
            psh = _fb_shardings(mesh, pol, pspecs, pshapes)
            step = build_train_step(
                cfg, pol, cosine_schedule(3e-4, 100, 10000),
                grad_shardings=psh,
                accum_dtype=jnp.bfloat16 if low_mem else jnp.float32)
            in_sh = (psh,
                     _fb_shardings(mesh, pol, ospecs, oshapes),
                     _fb_shardings(mesh, pol, bspecs, bshapes),
                     NamedSharding(mesh, P()))
            out_sh = (in_sh[0], in_sh[1], NamedSharding(mesh, P()))
            fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0, 1) if donate else ())
            args = (pshapes, oshapes, bshapes,
                    jax.ShapeDtypeStruct((), jnp.int32))
        elif mode == "prefill":
            pshapes, pspecs = SPECS.abstract_params(cfg, dtype=jnp.bfloat16)
            (tokens, cache_s, extra), (tsp, csp, esp) = \
                SPECS.prefill_inputs(cfg, shape)

            def step(params, tokens, cache, extra=None):
                return tf.prefill(params, cfg, tokens, cache,
                                  extra_embeds=extra)

            in_sh = [_fb_shardings(mesh, pol, pspecs, pshapes),
                     _fb_shardings(mesh, pol, tsp, tokens),
                     _fb_shardings(mesh, pol, csp, cache_s)]
            args = [pshapes, tokens, cache_s]
            if extra is not None:
                in_sh.append(_fb_shardings(mesh, pol, esp, extra))
                args.append(extra)
            logit_sd = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.padded_vocab), jnp.bfloat16)
            out_sh = (_fb_shardings(mesh, pol, P("dp", "tp"), logit_sd),
                      in_sh[2])
            fn = jax.jit(step, in_shardings=tuple(in_sh), out_shardings=out_sh,
                         donate_argnums=(2,) if donate else ())
            args = tuple(args)
        else:  # decode
            pshapes, pspecs = SPECS.abstract_params(cfg, dtype=jnp.bfloat16)
            (token, pos, cache_s), (ksp, psp, csp) = \
                SPECS.decode_inputs(cfg, shape)

            def step(params, token, pos, cache):
                return tf.decode_step(params, cfg, token, pos, cache)

            in_sh = (_fb_shardings(mesh, pol, pspecs, pshapes),
                     _fb_shardings(mesh, pol, ksp, token),
                     _fb_shardings(mesh, pol, psp, pos),
                     _fb_shardings(mesh, pol, csp, cache_s))
            logit_sd = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.padded_vocab), jnp.bfloat16)
            out_sh = (_fb_shardings(mesh, pol, P("dp", "tp"), logit_sd),
                      in_sh[3])
            fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(3,) if donate else ())
            args = (pshapes, token, pos, cache_s)

        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo_txt = compiled.as_text()
        coll_raw = collective_bytes(hlo_txt)
        coll = collective_bytes_corrected(hlo_txt)
        # --- roofline terms: analytic compute/memory (HLO while bodies are
        # counted once — see launch/analytic.py), corrected collectives ----
        remat = cfg.remat
        pbytes = 2 if (pdtype == "bfloat16" or mode != "train") else 4
        ex_flops = analytic.exec_flops(cfg, shape, mode, remat)
        us_flops = analytic.useful_flops(cfg, shape, mode)
        hbm = analytic.hbm_bytes(cfg, shape, mode, pbytes)
        t_compute = ex_flops / (chips * PEAK_FLOPS)
        t_memory = hbm / (chips * HBM_BW)
        coll_dev = float(sum(coll.values()))
        t_coll = coll_dev / LINK_BW
        t_max = max(t_compute, t_memory, t_coll, 1e-12)
        dominant = {t_compute: "compute", t_memory: "memory",
                    t_coll: "collective"}[t_max]
        terms = {
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "exec_flops": ex_flops,
            "model_flops": us_flops,
            "useful_flops_fraction": us_flops / max(ex_flops, 1.0),
            "analytic_hbm_bytes": hbm,
            "collective_bytes_per_dev": coll_dev,
            "collective_by_kind": coll,
            "collective_by_kind_raw_once": coll_raw,
            "hlo_flops_per_dev_once": float(cost.get("flops", 0.0)),
            "hlo_bytes_per_dev_once": float(cost.get("bytes accessed", 0.0)),
            "roofline_fraction": (us_flops / (chips * PEAK_FLOPS)) / t_max,
            "memory_bound_fraction": t_memory / t_max,
        }
        per_dev_bytes = (mem.argument_size_in_bytes +
                         mem.output_size_in_bytes -
                         mem.alias_size_in_bytes +
                         mem.temp_size_in_bytes)
        rec.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_live_bytes": per_dev_bytes,
                "fits_v5e_16g": bool(per_dev_bytes <= HBM_PER_CHIP),
            },
            roofline=terms,
            microbatches=pol.microbatches,
            seq_shard=bool(seq_on and mode == "train"),
            param_dtype=pdtype or "float32",
        )
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    finally:
        activation_sharding.clear()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--policy", default="default")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]

    for multi_pod in meshes:
        for arch, shape in cells:
            tag = f"{arch}__{shape}__{'mp' if multi_pod else 'sp'}"
            if args.policy != "default":
                tag += f"__{args.policy}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip existing] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            rec = run_cell(arch, shape, multi_pod, policy_name=args.policy)
            with open(path, "w") as f:
                json.dump(rec, f, indent=2)
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(f"  ok: compile {rec['compile_s']}s  "
                      f"mem/dev {rec['memory']['per_device_live_bytes']/1e9:.2f}GB "
                      f"terms(c/m/x) {r['t_compute_s']:.3e}/"
                      f"{r['t_memory_s']:.3e}/{r['t_collective_s']:.3e} "
                      f"dom={r['dominant']} frac={r['roofline_fraction']:.3f}",
                      flush=True)
            else:
                print(f"  {rec['status']}: {rec.get('reason', rec.get('error'))}",
                      flush=True)


if __name__ == "__main__":
    main()
