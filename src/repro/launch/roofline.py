"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e-class, per chip):
  peak_flops  = 197e12 bf16 FLOP/s
  hbm_bw      = 819e9  B/s
  link_bw     = 50e9   B/s ICI

Terms (per train/serve step, seconds):
  compute    = HLO_FLOPs / (chips × peak)         [cost_analysis 'flops']
  memory     = HLO_bytes / (chips × hbm_bw)       [cost_analysis 'bytes accessed']
  collective = collective_bytes / (chips × link_bw)

cost_analysis numbers from a post-SPMD module are PER-DEVICE; we multiply
back to global so the formulas above (which divide by chips) are consistent.
collective_bytes sums the *result* shapes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute in the compiled HLO (per
device), ×chips for the global figure.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_PER_CHIP = 16e9  # v5e

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes by collective kind from a compiled HLO module."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_txt)
    return out


_COMP_HDR = re.compile(r"^(%?[\w.\-]+)\s*(?:\([^)]*\))?\s*->\s*[^{]*\{|^ENTRY\s+(%?[\w.\-]+)")
_WHILE_RE = re.compile(
    r"while\(.*?\)(?:,|\s)+condition=([%\w.\-]+)(?:,|\s)+body=([%\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and ("{" in line) and ("->" in line or
                                                           line.startswith("ENTRY")):
            name = line.split()[0]
            if name == "ENTRY":
                name = line.split()[1]
            cur = name.rstrip("{").strip()
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(stripped)
    return {k: "\n".join(v) for k, v in comps.items()}


def collective_bytes_corrected(hlo_text: str) -> Dict[str, int]:
    """Per-device collective bytes with while-loop trip-count multipliers
    (XLA cost analysis counts loop bodies once — scans would undercount by
    n_layers × microbatches)."""
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            entry = line.split()[1].rstrip("{").strip()
    if entry is None or entry not in comps:
        return collective_bytes(hlo_text)

    def trip_count(cond_name: str) -> int:
        body = comps.get(cond_name, "")
        consts = [int(x) for x in _CONST_RE.findall(body)]
        return max(consts) if consts else 1

    totals: Dict[str, float] = {}

    def walk(name: str, mult: float, seen=()):
        if name not in comps or name in seen:
            return
        text = comps[name]
        for line in text.splitlines():
            m = _COLL_RE.search(line)
            if m:
                kind = m.group(2)
                totals[kind] = totals.get(kind, 0) + \
                    _shape_bytes(m.group(1)) * mult
        for wm in _WHILE_RE.finditer(text):
            cond, body = wm.group(1), wm.group(2)
            walk(body, mult * trip_count(cond), seen + (name,))

    walk(entry, 1.0)
    return {k: int(v) for k, v in totals.items()}


def roofline_terms(cost: dict, coll: Dict[str, int], chips: int,
                   model_flops: float) -> dict:
    """cost: compiled.cost_analysis() (per-device); coll: per-device
    collective bytes by kind; model_flops: 6·N·D useful FLOPs (global)."""
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = float(sum(coll.values()))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    hlo_flops_global = flops_dev * chips
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll_dev,
        "collective_by_kind": coll,
        "model_flops": model_flops,
        "useful_flops_fraction": (model_flops / hlo_flops_global
                                  if hlo_flops_global else 0.0),
        # roofline fraction: useful compute time over the achievable step
        # time (max of the three terms) — the score we hillclimb
        "roofline_fraction": (
            (model_flops / (chips * PEAK_FLOPS)) /
            max(t_compute, t_memory, t_coll, 1e-12)),
    }


def model_flops_for(cfg, shape, mode: str) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode counts one token per seq."""
    n = cfg.n_active_params() if cfg.moe.n_experts else cfg.n_params()
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: 2·N per token + attention reads (memory-bound; FLOPs small)
    return 2.0 * n * shape.global_batch
