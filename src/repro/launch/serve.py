"""Serving driver: multi-tenant paged-KV server on a reduced config.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_moe_30b_a3b \
      --requests 12 --tenants 3 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tf
from repro.runtime.serve_loop import PagedServer, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--quota-pages", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    params, _ = tf.init_lm(cfg, jax.random.PRNGKey(0))
    server = PagedServer(cfg, params, page_size=8, n_slots=128,
                         n_tenants=args.tenants,
                         quotas=[args.quota_pages] * args.tenants)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        server.submit(Request(
            req_id=i, tenant=i % args.tenants,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new=args.max_new))
    stats = server.run_until_drained()
    dt = time.time() - t0
    print(f"served {args.requests} requests / {stats['tokens']} tokens in "
          f"{dt:.1f}s ({stats['tokens']/dt:.1f} tok/s)")
    print(f"page faults: stage1={stats['faults_stage1']} "
          f"stage2={stats['faults_stage2']} rejected={stats['rejected']}")
    return stats


if __name__ == "__main__":
    main()
