"""Analytic (napkin-math) compute & memory models for the roofline.

XLA's ``cost_analysis`` counts ``while`` bodies ONCE (verified: a 10-trip
scan reports 1/10th the flops of the unrolled loop), so scanned-layer HLO
costs undercount by n_layers × microbatches. Rather than unrolling (compile
blow-up), the dry-run uses:

  compute/memory terms — the analytic model below (standard 6·N·D accounting
    + attention/KV terms, with a remat multiplier), matching what the
    *deployed* system executes (flash-attention kernels: no S² HBM traffic);
  collective term     — HLO parse with structural trip-count multipliers
    (roofline.collective_bytes_corrected).

Formulas (per step, GLOBAL):
  train   : exec_flops = 3·(2·N·T + A_fwd)·r      (fwd+bwd, r = remat factor)
  prefill : exec_flops = 2·N·T + A_fwd
  decode  : exec_flops = 2·N·B + A_dec
  A_fwd   = Σ_attn_layers 4·B·S·W_eff·H·hd        (W_eff = min(S, window)/2
            causal, or S/2 full)
  A_dec   = Σ_attn_layers 4·B·T_cache·KV_... (score+AV reads ≈ 4·B·T·H·hd)

  train HBM bytes   = 3·P_b (read fwd/bwd + opt rw) + 2·P_b(m,v rw)·2
                      + act_bytes (saved layer inputs, rw)
  prefill HBM bytes = P_b + KV_write + act_stream
  decode HBM bytes  = P_b + KV_read (the classic decode bound)
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig


def _attn_layers(cfg: ModelConfig) -> int:
    pat = cfg.block_pattern or ("attn",)
    n_super = cfg.n_layers // len(pat)
    n = sum(1 for k in pat if k == "attn") * n_super
    n += sum(1 for i, k in enumerate(pat[:cfg.n_layers - n_super * len(pat)])
             if k == "attn")
    if cfg.is_enc_dec:
        n += cfg.n_enc_layers + cfg.n_layers  # enc self + dec cross
    return n


def param_bytes(cfg: ModelConfig, dtype_bytes: int) -> float:
    return cfg.n_params() * dtype_bytes


def exec_flops(cfg: ModelConfig, shape: ShapeConfig, mode: str,
               remat: str = "dots") -> float:
    N = cfg.n_active_params() if cfg.moe.n_experts else cfg.n_params()
    H, hd = max(cfg.n_heads, 1), cfg.resolved_head_dim
    L_attn = _attn_layers(cfg)
    B, S = shape.global_batch, shape.seq_len
    if mode in ("train", "prefill"):
        W_eff = (min(S, cfg.window) if cfg.window else S) / 2
        a_fwd = L_attn * 4.0 * B * S * W_eff * H * hd
        fwd = 2.0 * N * B * S + a_fwd
        if mode == "prefill":
            return fwd
        r = {"none": 1.0, "dots": 1.05, "full": 4.0 / 3.0}.get(remat, 1.05)
        return 3.0 * fwd * r
    # decode
    T_eff = min(S, cfg.window) if cfg.window else S
    a_dec = L_attn * 4.0 * B * T_eff * H * hd
    return 2.0 * N * B + a_dec


def useful_flops(cfg: ModelConfig, shape: ShapeConfig, mode: str) -> float:
    N = cfg.n_active_params() if cfg.moe.n_experts else cfg.n_params()
    if mode == "train":
        return 6.0 * N * shape.global_batch * shape.seq_len
    if mode == "prefill":
        return 2.0 * N * shape.global_batch * shape.seq_len
    return 2.0 * N * shape.global_batch


def kv_cache_bytes(cfg: ModelConfig, shape: ShapeConfig,
                   dtype_bytes: int = 2) -> float:
    T_eff = min(shape.seq_len, cfg.window) if cfg.window else shape.seq_len
    kv = (_attn_layers(cfg) * shape.global_batch * T_eff *
          max(cfg.n_kv_heads, 1) * cfg.resolved_head_dim * 2 * dtype_bytes)
    if cfg.family == "ssm":
        d_inner = cfg.ssm.expand * cfg.d_model
        hs = cfg.n_layers * shape.global_batch * \
            (d_inner // cfg.ssm.head_dim) * cfg.ssm.head_dim * \
            cfg.ssm.d_state * 4
        kv += hs
    if "rglru" in (cfg.block_pattern or ()):
        w = cfg.rglru.lru_width or cfg.d_model
        kv += cfg.n_layers * shape.global_batch * w * 4
    return kv


def hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, mode: str,
              param_dtype_bytes: int = 4) -> float:
    P_b = param_bytes(cfg, param_dtype_bytes)
    B, S = shape.global_batch, shape.seq_len
    act_unit = B * S * cfg.d_model * 2          # one layer activation, bf16
    if mode == "train":
        # params: fwd read + bwd read + grad write + opt read/write m,v,p
        p_traffic = P_b * (2 + 1) + P_b * 2 * 2 + P_b
        acts = cfg.n_layers * act_unit * 2 * 2  # save w + read r (fwd+bwd)
        logits = B * S * cfg.padded_vocab * 2 * 2
        return p_traffic + acts + logits
    if mode == "prefill":
        return P_b / 2 + kv_cache_bytes(cfg, shape) + \
            cfg.n_layers * act_unit * 2
    # decode: read every param + the whole KV cache once per token
    return P_b / 2 * (2 / param_dtype_bytes) + kv_cache_bytes(cfg, shape)
