"""Abstract parameter/input construction for the dry-run.

``abstract_params`` traces init under ``jax.eval_shape`` (zero allocation —
nemotron's 340B params stay abstract) and captures the logical
PartitionSpecs via a host-side side channel.

``input_specs`` builds ShapeDtypeStruct stand-ins for every model input of a
given (arch × shape × mode) cell, plus their logical shardings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf
from repro.optim.adamw import AdamWState


def abstract_params(cfg: ModelConfig, dtype=None):
    """→ (shape_tree, logical_spec_tree). dtype overrides float param dtype
    (serving uses bf16)."""
    captured = {}

    def build(key):
        params, specs = tf.init_lm(cfg, key)
        captured["specs"] = specs
        return params

    shapes = jax.eval_shape(build, jax.random.PRNGKey(0))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating)
                else s.dtype), shapes)
    return shapes, captured["specs"]


def abstract_opt_state(param_shapes, param_specs, dtype=jnp.float32):
    m = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), param_shapes)
    shapes = AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                        m=m, v=jax.tree.map(lambda s: s, m))
    specs = AdamWState(step=P(), m=param_specs,
                       v=jax.tree.map(lambda s: s, param_specs))
    return shapes, specs


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_inputs(cfg: ModelConfig, shape: ShapeConfig):
    """→ (batch_shapes, batch_logical_specs)."""
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sd((B, S), jnp.int32),
             "labels": _sd((B, S), jnp.int32)}
    specs = {"tokens": P("dp", None), "labels": P("dp", None)}
    if cfg.frontend == "vit_stub":
        batch["patches"] = _sd((B, cfg.n_frontend_tokens, cfg.d_model),
                               jnp.bfloat16)
        specs["patches"] = P("dp", None, None)
    if cfg.frontend == "audio_stub":
        batch["frames"] = _sd((B, cfg.n_enc_ctx, cfg.d_model), jnp.bfloat16)
        specs["frames"] = P("dp", None, None)
    return batch, specs


def cache_abstract(cfg: ModelConfig, batch: int, max_seq: int):
    shp = tf.cache_shapes(cfg, batch, max_seq)
    shapes = jax.tree.map(lambda t: _sd(t[0], t[1]), shp,
                          is_leaf=tf._is_shape_leaf)
    specs = jax.tree.map(lambda t: t[2], shp, is_leaf=tf._is_shape_leaf)
    return shapes, specs


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    tokens = _sd((B, S), jnp.int32)
    cache_shapes_, cache_specs_ = cache_abstract(cfg, B, S)
    extra = extra_specs = None
    if cfg.frontend == "vit_stub":
        extra = _sd((B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
        extra_specs = P("dp", None, None)
    if cfg.frontend == "audio_stub":
        extra = _sd((B, cfg.n_enc_ctx, cfg.d_model), jnp.bfloat16)
        extra_specs = P("dp", None, None)
    return ((tokens, cache_shapes_, extra),
            (P("dp", None), cache_specs_, extra_specs))


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    token = _sd((B,), jnp.int32)
    pos = _sd((B,), jnp.int32)
    cache_shapes_, cache_specs_ = cache_abstract(cfg, B, S)
    return ((token, pos, cache_shapes_),
            (P("dp"), P("dp"), cache_specs_))
