"""Production mesh construction.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips;
multi-pod: (pod=2, data=16, model=16) = 512 chips — the `pod` axis carries
only data-parallel gradient traffic (DCN-friendly).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """1-device mesh for CPU smoke runs (same axis names, size 1)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
