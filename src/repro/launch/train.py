"""End-to-end training driver.

CPU-runnable end-to-end (reduced configs); on a real fleet the same driver
runs the full config — only `--mesh` differs. Integrates every substrate:
config registry, synthetic data pipeline, sharded train step, WSD/cosine
schedules, checkpoint manager with auto-resume + preemption handling, and
the fault-tolerance supervisor.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch minicpm_2b --reduced \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.models import transformer as tf
from repro.optim.adamw import adamw_init
from repro.optim.schedule import cosine_schedule, wsd_schedule
from repro.runtime.sharding import single_device_policy
from repro.runtime.train_loop import build_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    # minicpm trains with WSD per its paper
    sched = (wsd_schedule(args.lr, args.steps // 10, args.steps // 2,
                          args.steps // 2)
             if (args.schedule == "wsd" or cfg.scale_depth) else
             cosine_schedule(args.lr, args.steps // 10, args.steps))
    pol = single_device_policy(microbatches=args.microbatches)
    step_fn = jax.jit(build_train_step(cfg, pol, sched),
                      donate_argnums=(0, 1))

    data = SyntheticLMData(cfg, args.batch, args.seq)

    def init():
        params, _ = tf.init_lm(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": adamw_init(params)}

    mgr = None
    start = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
        mgr.install_preemption_handler()
        state, start = mgr.restore_or_init(init)
    else:
        state = init()

    params, opt = state["params"], state["opt"]
    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, data.batch_at(step))
        params, opt, metrics = step_fn(params, opt, batch,
                                       jnp.asarray(step, jnp.int32))
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tok_s = (step - start + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"{tok_s:,.0f} tok/s", flush=True)
        if mgr is not None:
            mgr.maybe_save(step, {"params": params, "opt": opt})
            if mgr.preempted:
                print("preempted: checkpoint flushed, exiting cleanly")
                break
    if mgr is not None:
        mgr.maybe_save(args.steps - 1, {"params": params, "opt": opt},
                       force=True)
        mgr.finalize()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
