"""Benchmark aggregator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract:
  fig4_sim_time      — native vs guest simulation time (paper Fig 4)
  fig5_instructions  — executed instructions w/ and w/o VM (paper Fig 5)
  fig6_native_exc    — exceptions per privilege level, native (paper Fig 6)
  fig7_guest_exc     — exceptions per privilege level, guest (paper Fig 7)
  vmem_*             — beyond-paper: two-stage paged-KV data/control plane
  kernel_*           — kernel ref-path micro-benches
  roofline_*         — condensed §Roofline rows from the dry-run artifacts

Heavy simulator runs are cached in benchmarks/results/hext_runs.json
(regenerate with ``python -m benchmarks.run_hext``).
"""
from __future__ import annotations

import json
import os
import time

ROOT = os.path.dirname(__file__)
HEXT_RESULTS = os.path.join(ROOT, "results", "hext_runs.json")


def _row(name, us, derived):
    print(f"{name},{us:.3f},{derived}")


def _hext_data():
    if not os.path.exists(HEXT_RESULTS):
        from benchmarks import run_hext
        run_hext.main(HEXT_RESULTS)
    with open(HEXT_RESULTS) as f:
        return json.load(f)


def fig4_sim_time():
    """Sim-time proxy: deterministic ticks native vs guest (+ the measured
    batched-run wall time). The paper's Fig 4 measures gem5 host seconds —
    our batched lockstep simulator has constant per-tick cost, so tick
    ratios are the faithful analogue (DESIGN.md §6)."""
    d = _hext_data()
    for name, r in d["workloads"].items():
        n, g = r["native"], r["guest"]
        slow = g["ticks"] / max(n["ticks"], 1)
        _row(f"fig4_sim_time_{name}", 0.0,
             f"native_ticks={n['ticks']};guest_ticks={g['ticks']};"
             f"slowdown={slow:.3f}")
    _row("fig4_batched_wall", d["wall_seconds_batched"] * 1e6,
         "18 machines (9 workloads x native+guest) in one vmapped run")


def fig5_instructions():
    d = _hext_data()
    for name, r in d["workloads"].items():
        n, g = r["native"], r["guest"]
        _row(f"fig5_instret_{name}", 0.0,
             f"wo_vm={n['instret']};w_vm={g['instret']};"
             f"overhead={g['instret']/max(n['instret'],1):.3f};"
             f"ok={n['ok'] and g['ok']}")


def fig6_native_exceptions():
    d = _hext_data()
    for name, r in d["workloads"].items():
        e = r["native"]["exc_by_level"]
        _row(f"fig6_native_exc_{name}", 0.0,
             f"M={e[0]};S={e[1]};pagefaults={r['native']['pagefaults']}")


def fig7_guest_exceptions():
    d = _hext_data()
    for name, r in d["workloads"].items():
        e = r["guest"]["exc_by_level"]
        _row(f"fig7_guest_exc_{name}", 0.0,
             f"M={e[0]};HS={e[1]};VS={e[2]};"
             f"pagefaults={r['guest']['pagefaults']}")


def vmem_bench():
    import jax
    import jax.numpy as jnp
    from repro.core.vmem import kvcache as KC
    from repro.core.vmem import page_table as PT

    kv = KC.PagedKVCache.create(
        n_slots=512, page_size=16, n_kv_heads=8, head_dim=128, n_tenants=8,
        reqs_per_tenant=8, logical_pages=64, tenant_pages=256)
    for p in range(64):
        kv, ok = KC.ensure_mapped(kv, 0, 0, p)

    t_ids = jnp.zeros((1024,), jnp.int32)
    r_ids = jnp.zeros((1024,), jnp.int32)
    pages = jnp.arange(1024, dtype=jnp.int32) % 64
    f = jax.jit(lambda t, r, p: PT.translate(kv.tables, t, r, p))
    f(t_ids, r_ids, pages)  # compile
    t0 = time.time()
    N = 100
    for _ in range(N):
        out = f(t_ids, r_ids, pages)
    jax.block_until_ready(out.slot)
    us = (time.time() - t0) / N * 1e6
    _row("vmem_translate_1024", us, "two-stage translate (fused-cache path)")

    t0 = time.time()
    KC.evict_tenant(kv, 0)
    _row("vmem_evict_tenant", (time.time() - t0) * 1e6,
         "O(tenant pages) teardown — the paper's two-stage win")


def kernel_bench():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.flash_attention.ops import flash_attention

    rng = np.random.RandomState(0)
    B, S, H, KV, hd = 1, 256, 8, 2, 64
    q = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KV, hd), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KV, hd), jnp.float32)
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, 0.125, force="ref"))
    f(q, k, v)
    t0 = time.time()
    for _ in range(10):
        out = f(q, k, v)
    jax.block_until_ready(out)
    _row("flash_attention_ref", (time.time() - t0) / 10 * 1e6,
         f"B{B} S{S} H{H} hd{hd} (TPU path = Pallas kernel)")


def roofline_summary():
    """Condensed §Roofline rows from the dry-run JSONs (if present)."""
    d = os.path.join(ROOT, "results", "dryrun")
    if not os.path.isdir(d):
        _row("roofline", 0.0, "no dryrun results yet")
        return
    for fn in sorted(os.listdir(d)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(d, fn)) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        _row(f"roofline_{rec['arch']}_{rec['shape']}"
             f"_{'mp' if rec['multi_pod'] else 'sp'}", 0.0,
             f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
             f"tc={r['t_compute_s']:.2e};tm={r['t_memory_s']:.2e};"
             f"tx={r['t_collective_s']:.2e}")


def main() -> None:
    print("name,us_per_call,derived")
    fig4_sim_time()
    fig5_instructions()
    fig6_native_exceptions()
    fig7_guest_exceptions()
    vmem_bench()
    kernel_bench()
    roofline_summary()


if __name__ == "__main__":
    main()
