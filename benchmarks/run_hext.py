"""Run all MiBench-like workloads native + guest through the hext simulator
(batched vmap run — the TPU-native 'many VMs in lockstep' mode) and dump the
per-workload counters that reproduce paper Figures 4-7.

Usage: PYTHONPATH=src python -m benchmarks.run_hext [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core.hext import machine, programs


def main(out_path: str = "benchmarks/results/hext_runs.json",
         max_ticks: int = 120000, chunk: int = 8192):
    wls = programs.WORKLOADS
    t_start = time.time()
    results = {}
    with jax.experimental.enable_x64():
        # build the batch: [native×9 ; guest×9]
        states = [programs.boot_state(w, guest=False) for w in wls] + \
                 [programs.boot_state(w, guest=True) for w in wls]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    t0 = time.time()
    batch = machine.batched_run_until_done(batch, max_ticks, chunk=chunk)
    wall = time.time() - t0
    for i, w in enumerate(wls):
        nat = jax.tree.map(lambda x: x[i], batch)
        gst = jax.tree.map(lambda x: x[i + len(wls)], batch)
        g = w.golden()
        results[w.name] = {
            "golden": int(g) & ((1 << 63) - 1),
            "native": _counters(nat, g),
            "guest": _counters(gst, g),
        }
    out = {
        "wall_seconds_batched": wall,
        "setup_seconds": t0 - t_start,
        "workloads": results,
    }
    import os
    os.makedirs(out_path.rsplit("/", 1)[0], exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for name, r in results.items():
        n, gg = r["native"], r["guest"]
        ratio = gg["instret"] / max(n["instret"], 1)
        print(f"{name:14s} ok={n['ok']}/{gg['ok']} instret {n['instret']}→"
              f"{gg['instret']} ({ratio:.2f}x) exc {n['exc_by_level']}→"
              f"{gg['exc_by_level']} pf {n['pagefaults']}→{gg['pagefaults']}")
    return out


def _counters(s, golden):
    return {
        "ok": bool(int(s["exit_code"]) == golden),
        "done": bool(s["done"]),
        "instret": int(s["instret"]),
        "instret_virt": int(s["instret_virt"]),
        "ticks": int(s["ticks"]),
        "exc_by_level": [int(x) for x in s["exc_by_level"]],
        "int_by_level": [int(x) for x in s["int_by_level"]],
        "pagefaults": int(s["pagefaults"]),
        "walks": int(s["walks"]),
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/results/hext_runs.json")
    ap.add_argument("--max-ticks", type=int, default=120000)
    a = ap.parse_args()
    main(a.out, a.max_ticks)
