"""Run all MiBench-like workloads native + guest through the hext simulator
(one `Fleet` — the TPU-native 'many VMs in lockstep' mode) and dump the
per-workload counters that reproduce paper Figures 4-7.

On top of the native/guest pair, one ``{n}guest-preempt`` column per
requested tenant count boots every workload N times per hart under the
preemptive HS scheduler (timer-sliced round-robin, DESIGN.md §2c) and
reports the **consolidation-overhead curve**: how virtualization overhead
grows with tenants per hart — ``instret / (N × single-guest instret)`` for
N ∈ {1, 2, 4} by default (the cloud-density measurement the paper's
scenario motivates; add 8 with ``--guests``).

An **engine column** additionally times the same matrix on the pluggable
backends (``jit`` vs ``sharded`` ticks/s, DESIGN.md §3) after verifying
both are bit-identical to the counter-producing reference run, so the
committed counter goldens can never be perturbed by an engine swap.

Usage: PYTHONPATH=src python -m benchmarks.run_hext [--out PATH]
                                                    [--timeslice N]
                                                    [--guests 1 2 4 ...]
                                                    [--no-preempt]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.core.hext import engine as hext_engine
from repro.core.hext import programs
from repro.core.hext.sim import Fleet, MASK64

DEFAULT_GUEST_COUNTS = (1, 2, 4)


def _engine_column(wls, max_ticks: int, chunk: int, ref_fleet) -> dict:
    """jit-vs-sharded throughput on the same native/guest matrix.

    Each engine gets one untimed warmup pass over a throwaway fleet
    before its timed run.  Compilation is already shared across engines
    (the executable is cached per chunk shape), but the *first* timed
    run used to also pay one-off allocator growth and donation-buffer
    churn — which made whichever engine ran first (jit) look ~30%
    slower than the second (sharded's single-device jit fallback), a
    pure measurement-order artifact (DESIGN.md §7d).  With the warmup,
    both rates are steady-state and converge on one device.

    Results are checked bit-identical against the reference fleet the
    counter columns came from, and ticks/s is aggregate simulated ticks
    over wall time.  On a single-device host the sharded engine falls
    back to jit (recorded in the column)."""
    flags = [False] * len(wls) + [True] * len(wls)
    ref = ref_fleet.counters()
    total_ticks = sum(int(c.ticks) for c in ref)
    out = {}
    for name in ("jit", "sharded"):
        warm = Fleet.boot(wls + wls, guest=flags, engine=name)
        t0 = time.time()
        warm.run(max_ticks, chunk=chunk)
        warmup_wall = time.time() - t0
        fleet = Fleet.boot(wls + wls, guest=flags, engine=name)
        t0 = time.time()
        fleet.run(max_ticks, chunk=chunk)
        wall = time.time() - t0
        for i in range(len(fleet)):
            d = hext_engine.diff_states(fleet[i], ref_fleet[i])
            if d:
                raise RuntimeError(
                    f"engine {name} drifted from the reference on hart "
                    f"{i}: {d[:3]}")
        out[name] = {
            "wall_seconds": wall,
            "warmup_wall_seconds": warmup_wall,
            "ticks_per_sec": total_ticks / max(wall, 1e-9),
        }
    out["sharded"]["devices"] = len(jax.devices())
    out["sharded"]["fallback_to_jit"] = len(jax.devices()) < 2
    return out


def main(out_path: str = "benchmarks/results/hext_runs.json",
         max_ticks: int = 120000, chunk: int = 8192,
         timeslice: int | None = None, preempt: bool = True,
         guest_counts=DEFAULT_GUEST_COUNTS):
    wls = programs.WORKLOADS
    t_start = time.time()
    # the batch: [native×9 ; guest×9]
    fleet = Fleet.boot(wls + wls,
                       guest=[False] * len(wls) + [True] * len(wls))
    t0 = time.time()
    fleet.run(max_ticks, chunk=chunk)
    wall = time.time() - t0
    counters = fleet.counters()

    # engine column: jit vs sharded throughput on the same matrix, with a
    # bit-identity check against the counter-producing reference fleet so
    # the published goldens cannot be perturbed by an engine bug
    engines = _engine_column(wls, max_ticks, chunk, fleet)

    # consolidation columns: each workload × N tenants per hart, timer
    # round-robin (every N is its own fleet — image sizes differ with N)
    preempt_reports: dict = {}
    wall_preempt: dict = {}
    counts = tuple(guest_counts) if preempt else ()
    ts = programs.DEFAULT_TIMESLICE if timeslice is None else int(timeslice)
    for n in counts:
        pfleet = Fleet.boot(wls, guests_per_hart=n, timeslice=ts)
        t1 = time.time()
        pfleet.run(max_ticks * n, chunk=chunk)
        wall_preempt[n] = time.time() - t1
        preempt_reports[n] = pfleet.report()

    results = {}
    curve: dict = {n: [] for n in counts}
    for i, w in enumerate(wls):
        g = w.golden()
        entry = {
            "golden": int(g) & MASK64,
            "native": counters[i].to_dict(g),
            "guest": counters[i + len(wls)].to_dict(g),
        }
        for n in counts:
            label = "+".join([w.name] * n) + f"/{n}guest-preempt"
            p = preempt_reports[n].get(label)
            if p is None:
                continue
            # overhead vs running the N tenants back-to-back without
            # preemption: hart instret / (N × single-guest instret)
            ovh = p["instret"] / max(n * entry["guest"]["instret"], 1)
            p["overhead_vs_nx_guest"] = ovh
            if n == 2:                        # legacy key, same number
                p["overhead_vs_2x_guest"] = ovh
            if p["ok"]:
                curve[n].append(ovh)
            else:
                # an unfinished/failed hart has a truncated instret — keep
                # the column but keep it out of the published curve
                print(f"WARNING: {label} not ok — excluded from the "
                      f"consolidation curve")
            entry[f"{n}guest-preempt"] = p
        results[w.name] = entry
    consolidation = {
        str(n): {
            "mean_overhead": sum(v) / len(v) if v else None,
            "max_overhead": max(v) if v else None,
        } for n, v in curve.items()
    }
    out = {
        "wall_seconds_batched": wall,
        "wall_seconds_preempt": sum(wall_preempt.values()),
        "wall_seconds_preempt_by_n": {str(n): wall_preempt[n]
                                      for n in counts},
        "setup_seconds": t0 - t_start,
        "timeslice": ts,
        "engines": engines,
        "consolidation_overhead": consolidation,
        "workloads": results,
    }
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for name, r in results.items():
        n_, gg = r["native"], r["guest"]
        ratio = gg["instret"] / max(n_["instret"], 1)
        line = (f"{name:14s} ok={n_['ok']}/{gg['ok']} instret "
                f"{n_['instret']}→{gg['instret']} ({ratio:.2f}x) exc "
                f"{n_['exc_by_level']}→{gg['exc_by_level']} "
                f"pf {n_['pagefaults']}→{gg['pagefaults']}")
        ovhs = []
        for n in counts:
            p = r.get(f"{n}guest-preempt")
            if p is not None:
                ovhs.append(f"N={n}:{p['overhead_vs_nx_guest']:.2f}x")
        if ovhs:
            line += " | consolidation " + " ".join(ovhs)
        print(line)
    if consolidation:
        print("consolidation-overhead curve (mean over workloads): " +
              "  ".join(f"N={n}: {c['mean_overhead']:.3f}x"
                        for n, c in consolidation.items()
                        if c["mean_overhead"]))
    print("engine column: " +
          "  ".join(f"{n}: {e['ticks_per_sec']:,.0f} ticks/s"
                    for n, e in engines.items()) +
          (f"  (sharded fell back to jit on "
           f"{engines['sharded']['devices']} device)"
           if engines["sharded"]["fallback_to_jit"] else
           f"  ({engines['sharded']['devices']} devices)"))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/results/hext_runs.json")
    ap.add_argument("--max-ticks", type=int, default=120000)
    ap.add_argument("--timeslice", type=int, default=None,
                    help="preemption interval in ticks "
                         f"(default {programs.DEFAULT_TIMESLICE})")
    ap.add_argument("--guests", type=int, nargs="+",
                    default=list(DEFAULT_GUEST_COUNTS),
                    help="tenant counts for the consolidation columns")
    ap.add_argument("--no-preempt", action="store_true",
                    help="skip the consolidation columns")
    a = ap.parse_args()
    main(a.out, a.max_ticks, timeslice=a.timeslice,
         preempt=not a.no_preempt, guest_counts=tuple(a.guests))
