"""Run all MiBench-like workloads native + guest through the hext simulator
(one `Fleet` — the TPU-native 'many VMs in lockstep' mode) and dump the
per-workload counters that reproduce paper Figures 4-7.

A third column, ``2guest-preempt``, boots every workload twice per hart
under the preemptive HS scheduler (timer-sliced round-robin, DESIGN.md
§2c) and reports the virtualization overhead under preemption.

Usage: PYTHONPATH=src python -m benchmarks.run_hext [--out PATH]
                                                    [--timeslice N]
                                                    [--no-preempt]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core.hext import programs
from repro.core.hext.sim import Fleet, MASK64


def main(out_path: str = "benchmarks/results/hext_runs.json",
         max_ticks: int = 120000, chunk: int = 8192,
         timeslice: int | None = None, preempt: bool = True):
    wls = programs.WORKLOADS
    t_start = time.time()
    # the batch: [native×9 ; guest×9]
    fleet = Fleet.boot(wls + wls,
                       guest=[False] * len(wls) + [True] * len(wls))
    t0 = time.time()
    fleet.run(max_ticks, chunk=chunk)
    wall = time.time() - t0
    counters = fleet.counters()

    preempt_report = {}
    wall_preempt = 0.0
    if preempt:
        # third column: each workload × 2 guests per hart, timer round-robin
        pfleet = Fleet.boot(wls, guests_per_hart=2, timeslice=timeslice)
        t1 = time.time()
        pfleet.run(max_ticks, chunk=chunk)
        wall_preempt = time.time() - t1
        preempt_report = pfleet.report()

    results = {}
    for i, w in enumerate(wls):
        g = w.golden()
        entry = {
            "golden": int(g) & MASK64,
            "native": counters[i].to_dict(g),
            "guest": counters[i + len(wls)].to_dict(g),
        }
        p = preempt_report.get(f"{w.name}+{w.name}/2guest-preempt")
        if p is not None:
            # overhead vs running the two guests back-to-back without
            # preemption: hart instret / (2 × single-guest instret)
            p["overhead_vs_2x_guest"] = (
                p["instret"] / max(2 * entry["guest"]["instret"], 1))
            entry["2guest-preempt"] = p
        results[w.name] = entry
    out = {
        "wall_seconds_batched": wall,
        "wall_seconds_preempt": wall_preempt,
        "setup_seconds": t0 - t_start,
        "workloads": results,
    }
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for name, r in results.items():
        n, gg = r["native"], r["guest"]
        ratio = gg["instret"] / max(n["instret"], 1)
        line = (f"{name:14s} ok={n['ok']}/{gg['ok']} instret {n['instret']}→"
                f"{gg['instret']} ({ratio:.2f}x) exc {n['exc_by_level']}→"
                f"{gg['exc_by_level']} pf {n['pagefaults']}→{gg['pagefaults']}")
        p = r.get("2guest-preempt")
        if p is not None:
            line += (f" | 2guest ok={p['ok']} irq={p['timer_irqs']} "
                     f"ctxsw={p['ctx_switches']} "
                     f"ovh={p['overhead_vs_2x_guest']:.2f}x")
        print(line)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/results/hext_runs.json")
    ap.add_argument("--max-ticks", type=int, default=120000)
    ap.add_argument("--timeslice", type=int, default=None,
                    help="preemption interval in ticks "
                         f"(default {programs.DEFAULT_TIMESLICE})")
    ap.add_argument("--no-preempt", action="store_true",
                    help="skip the 2guest-preempt column")
    a = ap.parse_args()
    main(a.out, a.max_ticks, timeslice=a.timeslice,
         preempt=not a.no_preempt)
