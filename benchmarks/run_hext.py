"""Run all MiBench-like workloads native + guest through the hext simulator
(one `Fleet` — the TPU-native 'many VMs in lockstep' mode) and dump the
per-workload counters that reproduce paper Figures 4-7.

Usage: PYTHONPATH=src python -m benchmarks.run_hext [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core.hext import programs
from repro.core.hext.sim import Fleet, MASK64


def main(out_path: str = "benchmarks/results/hext_runs.json",
         max_ticks: int = 120000, chunk: int = 8192):
    wls = programs.WORKLOADS
    t_start = time.time()
    # the batch: [native×9 ; guest×9]
    fleet = Fleet.boot(wls + wls,
                       guest=[False] * len(wls) + [True] * len(wls))
    t0 = time.time()
    fleet.run(max_ticks, chunk=chunk)
    wall = time.time() - t0
    counters = fleet.counters()
    results = {}
    for i, w in enumerate(wls):
        g = w.golden()
        results[w.name] = {
            "golden": int(g) & MASK64,
            "native": counters[i].to_dict(g),
            "guest": counters[i + len(wls)].to_dict(g),
        }
    out = {
        "wall_seconds_batched": wall,
        "setup_seconds": t0 - t_start,
        "workloads": results,
    }
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for name, r in results.items():
        n, gg = r["native"], r["guest"]
        ratio = gg["instret"] / max(n["instret"], 1)
        print(f"{name:14s} ok={n['ok']}/{gg['ok']} instret {n['instret']}→"
              f"{gg['instret']} ({ratio:.2f}x) exc {n['exc_by_level']}→"
              f"{gg['exc_by_level']} pf {n['pagefaults']}→{gg['pagefaults']}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/results/hext_runs.json")
    ap.add_argument("--max-ticks", type=int, default=120000)
    a = ap.parse_args()
    main(a.out, a.max_ticks)
