"""Serve benchmark: drive the fleet-as-a-service control plane with a
seeded open-loop arrival process and report serving metrics.

A Poisson-ish trace (seeded exponential inter-arrival times, measured in
control slices) draws workloads uniformly from the 9-workload registry
across eight tenants and three modes (``vm`` scheduler guests on the pod
pool, ``native``/``guest`` on solo lanes).  The daemon admits, bin-packs,
sheds, evicts, and recovers exactly as in production; the report records

* **sustained guests/sec** — completed jobs over wall-clock drain time,
* **p50/p99 time-to-result** — in control slices and simulated ticks,
* control-plane event totals (migrations, parks, resumes, recoveries),
* a correctness bit: every completed checksum matched its registry
  golden (the daemon-vs-direct invariant, enforced per job).

``--smoke`` runs the 16-submission CI gate instead: a fixed-seed trace
with forced geometry — a full N=3 cohort plus a later long-running
tenant (so the policy must shed), sustained queue pressure (so a victim
is parked and later resumed), and one injected hart failure (so recovery
restores a snapshot).  The smoke asserts all of admission, >=1
migration, >=1 park, and >=1 recovery happened and every checksum hit
its golden; any violation exits non-zero.

Usage: PYTHONPATH=src python -m benchmarks.run_serve [--out PATH]
           [--submissions 64] [--seed 1234] [--rate 1.5]
           [--harts 4] [--guests 2] [--solo 2] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core.hext import programs
from repro.core.hext.policies import BinPackPolicy
from repro.core.hext.service import DONE, FleetService

MODE_MIX = ("vm", "vm", "vm", "vm", "vm", "vm", "native", "guest")


def _drain_trace(svc: FleetService, arrivals, picks, fail_at=None,
                 max_slices=20000) -> float:
    """Feed the arrival trace into the daemon and drain it; returns the
    wall-clock seconds spent stepping (placement through completion)."""
    k = 0
    failed = False
    t0 = time.perf_counter()
    while k < len(arrivals) or any(not j.terminal for j in svc.jobs()):
        while k < len(arrivals) and arrivals[k] <= svc.slices:
            wl, tenant, mode = picks[k]
            svc.submit(wl, tenant=tenant, mode=mode)
            k += 1
        if fail_at is not None and not failed and svc.slices >= fail_at:
            lanes = [i for i, l in enumerate(svc._pod_lanes) if l.active]
            if lanes:
                svc.inject_hart_failure(lanes[-1], pool="pod")
                failed = True
        svc.step()
        if svc.slices >= max_slices:
            raise RuntimeError(f"trace failed to drain in {max_slices} "
                               f"slices (queued={len(svc._queue)})")
    return time.perf_counter() - t0


def _trace(n, seed, rate, registry):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(rate, size=n)).astype(int)
    picks = [(registry[int(rng.integers(len(registry)))],
              int(rng.integers(8)),
              MODE_MIX[int(rng.integers(len(MODE_MIX)))])
             for _ in range(n)]
    return arrivals, picks


def run_soak(args) -> dict:
    registry = list(programs.WORKLOADS)
    svc = FleetService(
        n_harts=args.harts, guests_per_hart=args.guests, n_solo=args.solo,
        timeslice=args.timeslice, slice_ticks=args.slice_ticks,
        chunk=args.chunk, snapshot_every=3,
        policy=BinPackPolicy(max_queue=args.submissions,
                             partial_after=2))
    arrivals, picks = _trace(args.submissions, args.seed, args.rate,
                             registry)
    wall = _drain_trace(svc, arrivals, picks,
                        fail_at=args.fail_at if args.fail else None)
    done = [j for j in svc.jobs() if j.state == DONE]
    bad = [j.job_id for j in done if not j.ok]
    m = svc.metrics()
    report = {
        "submissions": args.submissions,
        "seed": args.seed,
        "rate_slices": args.rate,
        "pool": {"harts": args.harts, "guests_per_hart": args.guests,
                 "solo": args.solo, "timeslice": args.timeslice,
                 "slice_ticks": args.slice_ticks},
        "wall_seconds": round(wall, 3),
        "sustained_guests_per_sec": round(len(done) / wall, 3),
        "all_goldens_ok": not bad,
        "mismatched_jobs": bad,
        "metrics": m,
    }
    return report


def run_smoke(args) -> dict:
    """Fixed-seed 16-submission gate: forces one shed, one park/resume
    cycle, and one recovery, then checks every golden."""
    by = {w.name: w for w in programs.WORKLOADS}
    svc = FleetService(
        n_harts=2, guests_per_hart=3, n_solo=1, timeslice=args.timeslice,
        slice_ticks=args.slice_ticks, chunk=args.chunk, snapshot_every=3,
        fail_after=2,
        policy=BinPackPolicy(max_queue=16, partial_after=1, shed_margin=2))
    # forced geometry: a full N=3 cohort of long guests at slice 0, a
    # long 4th tenant a little later (partial cohort -> shed window),
    # then a burst of short jobs to hold queue pressure (evict), one
    # native solo job, and a mid-run hart failure (recover)
    names = (["susan", "dijkstra", "bitcount"] + ["qsort"] +
             ["sha", "crc32", "stringsearch", "fft", "sha", "crc32",
              "stringsearch", "fft", "sha", "crc32", "basicmath"])
    # the burst waits until slice 6 so the qsort lane boots under-packed
    # (live 3-vs-1 imbalance) and the shed window opens before the queue
    # pressure starts forcing evictions
    arrivals = np.array([0, 0, 0, 2] + [6] * 11)
    picks = [(by[n], t % 8, "vm") for t, n in enumerate(names)]
    picks.append((by["dijkstra"], 7, "native"))
    arrivals = np.append(arrivals, 6)
    wall = _drain_trace(svc, arrivals, picks, fail_at=10, max_slices=2000)
    done = [j for j in svc.jobs() if j.state == DONE]
    bad = [j.job_id for j in done if not j.ok]
    checks = {
        "all_goldens_ok": not bad and len(done) == 16,
        "shed_happened": svc.stats["migrations"] >= 1,
        "park_happened": svc.stats["parks"] >= 1,
        "recovery_happened": svc.stats["recoveries"] >= 1,
    }
    report = {
        "mode": "smoke", "submissions": 16,
        "wall_seconds": round(wall, 3),
        "sustained_guests_per_sec": round(len(done) / wall, 3),
        "checks": checks, "mismatched_jobs": bad,
        "metrics": svc.metrics(),
    }
    report["ok"] = all(checks.values())
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "results", "serve_runs.json"))
    ap.add_argument("--submissions", type=int, default=64)
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--rate", type=float, default=1.5,
                    help="mean inter-arrival time in control slices")
    ap.add_argument("--harts", type=int, default=4)
    ap.add_argument("--guests", type=int, default=2)
    ap.add_argument("--solo", type=int, default=2)
    ap.add_argument("--timeslice", type=int, default=300)
    ap.add_argument("--slice-ticks", type=int, default=2048)
    ap.add_argument("--chunk", type=int, default=512)
    ap.add_argument("--fail", action="store_true", default=True,
                    help="inject one hart failure mid-trace (default)")
    ap.add_argument("--no-fail", dest="fail", action="store_false")
    ap.add_argument("--fail-at", type=int, default=40,
                    help="slice at which the failure is injected")
    ap.add_argument("--smoke", action="store_true",
                    help="run the fixed 16-submission CI gate instead")
    args = ap.parse_args(argv)

    report = run_smoke(args) if args.smoke else run_soak(args)
    report["generated_by"] = "benchmarks/run_serve.py"
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(json.dumps(report, indent=2, sort_keys=True))
    print(f"\nwrote {args.out}")
    if args.smoke and not report["ok"]:
        print("SMOKE FAILED", file=sys.stderr)
        return 1
    if not args.smoke and not report["all_goldens_ok"]:
        print("GOLDEN MISMATCH", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
