"""CI push-gate perf smoke: fixed workload matrix, counter-drift gate.

Boots the same native+guest workload matrix the committed
``benchmarks/results/hext_runs.json`` goldens came from, runs it to
completion, and

* **fails (exit 1)** if any counter column drifts from the committed
  per-workload goldens — the bit-identity contract behind every perf
  change (DESIGN.md §7);
* **appends** the measured aggregate ticks/s to a
  ``perf_smoke_history`` list inside ``hext_runs.json`` so successive
  runs leave a throughput trail next to the goldens they were gated on.

Throughput is recorded, not gated — CI hosts vary too much for a wall
-clock threshold, while counters must never move.  The timed pass runs
after one untimed warmup pass so the number is steady-state (same
rationale as ``run_hext._engine_column``).

Usage: PYTHONPATH=src python -m benchmarks.perf_smoke [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core.hext import programs
from repro.core.hext.sim import Fleet

GOLDEN_PATH = "benchmarks/results/hext_runs.json"
MAX_TICKS = 120000
CHUNK = 8192


def _boot():
    wls = programs.WORKLOADS
    return wls, Fleet.boot(wls + wls,
                           guest=[False] * len(wls) + [True] * len(wls))


def main(out_path: str = GOLDEN_PATH) -> int:
    with open(out_path) as f:
        committed = json.load(f)
    golden_wl = committed["workloads"]

    # warmup pass (compile + allocator steady state), then the timed pass
    wls, fleet = _boot()
    fleet.run(MAX_TICKS, chunk=CHUNK)
    wls, fleet = _boot()
    t0 = time.time()
    fleet.run(MAX_TICKS, chunk=CHUNK)
    wall = time.time() - t0
    counters = fleet.counters()
    total_ticks = sum(int(c.ticks) for c in counters)
    rate = total_ticks / max(wall, 1e-9)

    drifted = []
    for i, w in enumerate(wls):
        g = w.golden()
        got = {"native": counters[i].to_dict(g),
               "guest": counters[i + len(wls)].to_dict(g)}
        for col in ("native", "guest"):
            want = golden_wl[w.name][col]
            for k, v in want.items():
                have = got[col].get(k)
                # json round-trip normalizes tuples → lists
                if isinstance(have, tuple):
                    have = list(have)
                if have != v:
                    drifted.append(f"{w.name}/{col}.{k}: "
                                   f"committed={v} measured={have}")
    if drifted:
        print(f"FAIL: {len(drifted)} counter column(s) drifted from the "
              f"committed goldens in {out_path}:")
        for line in drifted[:20]:
            print("  " + line)
        return 1

    entry = {"ticks_per_sec": rate, "wall_seconds": wall,
             "total_ticks": total_ticks}
    committed.setdefault("perf_smoke_history", []).append(entry)
    with open(out_path, "w") as f:
        json.dump(committed, f, indent=2)
    base = committed.get("engines", {}).get("jit", {}).get("ticks_per_sec")
    vs = f" ({rate / base:.2f}x committed jit column)" if base else ""
    print(f"OK: all counter columns bit-identical to committed goldens; "
          f"{rate:,.0f} ticks/s over {total_ticks} ticks{vs}")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=GOLDEN_PATH)
    sys.exit(main(ap.parse_args().out))
