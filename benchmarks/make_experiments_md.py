"""Generate the data-driven sections of EXPERIMENTS.md from artifacts:
benchmarks/results/hext_runs.json + benchmarks/results/dryrun/*.json
(+ optional perf iteration files under benchmarks/results/perf/).

Usage: PYTHONPATH=src python -m benchmarks.make_experiments_md > EXPERIMENTS.generated.md
"""
from __future__ import annotations

import json
import os

ROOT = os.path.dirname(__file__)


def _load_dryrun():
    d = os.path.join(ROOT, "results", "dryrun")
    recs = []
    if os.path.isdir(d):
        for fn in sorted(os.listdir(d)):
            if fn.endswith(".json"):
                with open(os.path.join(d, fn)) as f:
                    recs.append(json.load(f))
    return recs


def emit_repro_section():
    path = os.path.join(ROOT, "results", "hext_runs.json")
    if not os.path.exists(path):
        print("(hext results missing — run `python -m benchmarks.run_hext`)")
        return
    with open(path) as f:
        d = json.load(f)
    print("### Paper reproduction (Figs 4–7 analogues)\n")
    print("| workload | ok (nat/guest) | instret w/o VM | instret w/ VM | "
          "overhead | native exc M/S | guest exc M/HS/VS | pf nat→guest |")
    print("|---|---|---|---|---|---|---|---|")
    overheads = []
    for name, r in d["workloads"].items():
        n, g = r["native"], r["guest"]
        ov = g["instret"] / max(n["instret"], 1)
        overheads.append(ov)
        ne, ge = n["exc_by_level"], g["exc_by_level"]
        print(f"| {name} | {n['ok']}/{g['ok']} | {n['instret']} | "
              f"{g['instret']} | {ov:.2f}× | {ne[0]}/{ne[1]} | "
              f"{ge[0]}/{ge[1]}/{ge[2]} | "
              f"{n['pagefaults']}→{g['pagefaults']} |")
    print(f"\nMean instruction overhead: "
          f"{sum(overheads)/len(overheads):.2f}× "
          f"(range {min(overheads):.2f}–{max(overheads):.2f}×). "
          f"Batched 18-machine lockstep wall time: "
          f"{d['wall_seconds_batched']:.1f}s.\n")
    curve = d.get("consolidation_overhead")
    if curve:
        print("### Consolidation-overhead curve (N tenants per hart)\n")
        print("| N | mean overhead vs N× single guest | max |")
        print("|---|---|---|")
        for n, c in curve.items():
            if c.get("mean_overhead"):
                print(f"| {n} | {c['mean_overhead']:.3f}× | "
                      f"{c['max_overhead']:.3f}× |")
        print(f"\nPreemptive scheduler timeslice: "
              f"{d.get('timeslice', '?')} ticks; per-N wall times: " +
              ", ".join(f"N={n}: {w:.1f}s" for n, w in
                        d.get("wall_seconds_preempt_by_n", {}).items()) +
              ".\n")


def emit_roofline_table(multi_pod=False):
    recs = [r for r in _load_dryrun()
            if r.get("multi_pod") == multi_pod and
            r.get("policy", "default") == "default"]
    tag = "multi-pod (2×16×16 = 512 chips)" if multi_pod else \
        "single-pod (16×16 = 256 chips)"
    print(f"### Roofline — {tag}\n")
    print("| arch | shape | status | mem/dev GB | fits 16G | t_compute | "
          "t_memory | t_coll | dominant | useful/exec | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | skipped: "
                  f"{r['reason'][:40]}… | | | | | | | | |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | | |")
            continue
        t = r["roofline"]
        m = r["memory"]
        print(f"| {r['arch']} | {r['shape']} | ok | "
              f"{m['per_device_live_bytes']/1e9:.2f} | "
              f"{'✓' if m['fits_v5e_16g'] else '✗'} | "
              f"{t['t_compute_s']:.2e} | {t['t_memory_s']:.2e} | "
              f"{t['t_collective_s']:.2e} | {t['dominant']} | "
              f"{t['useful_flops_fraction']:.2f} | "
              f"{t['roofline_fraction']:.3f} |")
    print()


def emit_dryrun_stats():
    recs = _load_dryrun()
    ok = sum(1 for r in recs if r["status"] == "ok")
    sk = sum(1 for r in recs if r["status"] == "skipped")
    er = sum(1 for r in recs if r["status"] not in ("ok", "skipped"))
    print(f"Cells: {ok} compiled ok, {sk} skipped-by-spec, {er} errors "
          f"(of {len(recs)} lowered).\n")


def main():
    emit_repro_section()
    emit_dryrun_stats()
    emit_roofline_table(multi_pod=False)
    emit_roofline_table(multi_pod=True)


if __name__ == "__main__":
    main()
