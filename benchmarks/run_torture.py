"""Fuzz-throughput benchmark: scenarios/sec batched vs serial vs oracle.

The torture harness's design claim (ROADMAP north star: batch everything)
is that running the whole randomized corpus as ONE vmapped Fleet beats
per-scenario host loops.  This benchmark measures all three executors on
the same fixed-seed corpus:

* **batched** — the full corpus as one ``Fleet.from_corpus`` run (one XLA
  executable, all scenarios in lockstep);
* **serial**  — one single-hart Fleet per scenario (one compile for the
  (1, mem) shape, then per-scenario dispatch + host sync), measured on a
  subsample and reported per-scenario;
* **oracle**  — the pure-Python reference model.

Results land in ``benchmarks/results/torture_fuzz.json`` — a separate
file from ``hext_runs.json``, whose counter columns are a bit-identical
regression oracle and must never be perturbed by a fuzz run.

Usage: PYTHONPATH=src python -m benchmarks.run_torture [--count N]
                                                       [--serial-sample K]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core.hext import torture
from repro.core.hext.sim import Fleet


def main(out_path: str = "benchmarks/results/torture_fuzz.json",
         seed: int = torture.DEFAULT_SEED, count: int = 256,
         serial_sample: int = 16, max_ticks: int = torture.MAX_TICKS):
    t0 = time.time()
    corpus = torture.generate(seed, count)
    wall_gen = time.time() - t0
    # throughput legs use the fuzz family only: sched-family images are
    # bigger than T_MEM_WORDS, and mixing shapes would split the single
    # XLA executable the benchmark is about
    scenarios = [s for s in corpus if s.family == "fuzz"]
    n_fuzz = len(scenarios)

    # batched cold: the whole corpus as one Fleet, including the one-time
    # XLA compile for the (count, mem) shape
    t0 = time.time()
    fleet = Fleet.from_corpus([s.image for s in scenarios],
                              mem_words=torture.T_MEM_WORDS)
    fleet.run(max_ticks, chunk=torture.CHUNK)
    wall_batched_cold = time.time() - t0
    n_done = sum(1 for c in fleet.counters() if bool(c.done))
    # batched warm: a fresh Fleet of the same shape reuses the executable —
    # the steady-state rate a nightly corpus sweep actually sees
    t0 = time.time()
    Fleet.from_corpus([s.image for s in scenarios],
                      mem_words=torture.T_MEM_WORDS).run(
        max_ticks, chunk=torture.CHUNK)
    wall_batched = time.time() - t0

    # serial: per-scenario single-hart Fleets (subsample, steady-state —
    # the first run pays the (1, mem) compile, so time runs 2..K+1)
    sub = scenarios[:serial_sample + 1]
    Fleet.from_corpus([sub[0].image],
                      mem_words=torture.T_MEM_WORDS).run(
        max_ticks, chunk=torture.CHUNK)             # warm the compile cache
    t0 = time.time()
    for s in sub[1:]:
        Fleet.from_corpus([s.image],
                          mem_words=torture.T_MEM_WORDS).run(
            max_ticks, chunk=torture.CHUNK)
    wall_serial_each = (time.time() - t0) / max(len(sub) - 1, 1)

    # oracle throughput (the host-side reference cost per scenario),
    # measured through the first-class OracleEngine fleet path — the same
    # leg run_corpus diffs against (DESIGN.md §3/§5)
    t0 = time.time()
    Fleet.from_corpus([s.image for s in scenarios],
                      mem_words=torture.T_MEM_WORDS,
                      engine="oracle").run(max_ticks, chunk=torture.CHUNK)
    wall_oracle = time.time() - t0

    batched_rate = n_fuzz / wall_batched
    serial_rate = 1.0 / wall_serial_each
    # coverage column: the static shape buckets the coverage-guided
    # generator steered into over the WHOLE corpus (sched included) —
    # the dynamic-event buckets on top of these are the nightly
    # `--coverage-out` artifact's job, since they need an oracle pass
    static_hist = torture.coverage_map(corpus, {})
    out = {
        "seed": seed, "count": count, "max_ticks": max_ticks,
        "fuzz_scenarios": n_fuzz,
        "sched_scenarios": count - n_fuzz,
        "scenarios_done": n_done,
        "wall_gen_seconds": wall_gen,
        "coverage_buckets_static": len(static_hist),
        "fuzz_throughput": {
            "batched_scenarios_per_sec": batched_rate,
            "batched_cold_scenarios_per_sec": n_fuzz / wall_batched_cold,
            "serial_scenarios_per_sec": serial_rate,
            "oracle_scenarios_per_sec": n_fuzz / wall_oracle,
            "batched_speedup_vs_serial": batched_rate / serial_rate,
            "serial_sample": serial_sample,
        },
    }
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    ft = out["fuzz_throughput"]
    print(f"{n_fuzz}/{count} fuzz scenarios ({n_done} done, "
          f"{len(static_hist)} static coverage buckets): "
          f"batched {ft['batched_scenarios_per_sec']:.2f}/s, "
          f"serial {ft['serial_scenarios_per_sec']:.2f}/s "
          f"({ft['batched_speedup_vs_serial']:.1f}x), "
          f"oracle {ft['oracle_scenarios_per_sec']:.1f}/s")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/results/torture_fuzz.json")
    ap.add_argument("--seed", type=int, default=torture.DEFAULT_SEED)
    ap.add_argument("--count", type=int, default=256)
    ap.add_argument("--serial-sample", type=int, default=16)
    a = ap.parse_args()
    main(a.out, a.seed, a.count, serial_sample=a.serial_sample)
