"""End-to-end behaviour tests for the paper's system.

The headline claims, as executable assertions:
  1. guest runs produce bit-identical workload results to native runs
     (functional correctness of the H extension, paper §3.4/§4),
  2. guest runs execute MORE instructions (paper Fig 5),
  3. native exceptions are handled at {M,S}; guest exceptions at {M,HS,VS}
     with VS ≈ native S and extra page faults (paper Figs 6/7),
  4. training end-to-end: loss falls and checkpoint-resume works,
  5. serving end-to-end: multi-tenant paged decode with quota isolation.
"""
import pytest

from repro.core.hext import programs
from repro.core.hext.sim import Fleet


@pytest.fixture(scope="module")
def crc_native_and_guest():
    wl = programs.CRC32()
    fleet = Fleet.boot([wl, wl], guest=[False, True])
    fleet.run(60000, chunk=4096)
    return wl, fleet[0].counters, fleet[1].counters


def test_guest_matches_native_checksum(crc_native_and_guest):
    wl, nat, gst = crc_native_and_guest
    assert bool(nat.done) and bool(gst.done)
    assert nat.ok(wl.golden())
    assert gst.ok(wl.golden())


def test_guest_executes_more_instructions(crc_native_and_guest):
    _, nat, gst = crc_native_and_guest
    assert int(gst.instret) > int(nat.instret)             # paper Fig 5
    assert int(gst.instret_virt) > 0                       # ran in VS


def test_exception_levels_match_paper_structure(crc_native_and_guest):
    _, nat, gst = crc_native_and_guest
    n_exc = nat.exc_by_level.tolist()
    g_exc = gst.exc_by_level.tolist()
    assert n_exc[2] == 0                      # native never uses VS
    assert g_exc[1] > 0                       # hypervisor handles G faults
    assert g_exc[2] >= n_exc[1]               # VS ≈ native S (paper §4.3)
    assert int(gst.pagefaults) > int(nat.pagefaults)


def test_training_loss_falls_and_resume(tmp_path):
    from repro.launch.train import main as train_main
    args = ["--arch", "mamba2_130m", "--reduced", "--steps", "20",
            "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "10", "--log-every", "50"]
    losses = train_main(args)
    assert losses[-1] < losses[0]
    # resume from the step-19 checkpoint: returns immediately-complete run
    losses2 = train_main(args)
    assert losses2 is not None


def test_serving_multi_tenant_quota():
    """Multi-tenant serving moved to the hypervisor control plane
    (repro.core.hext.service); admission/quota behaviour is covered by
    tests/hext/test_service.py and the run_serve.py smoke."""
    from repro.core.hext.service import FleetService
    from repro.core.hext.policies import BinPackPolicy
    svc = FleetService(n_harts=1, guests_per_hart=2,
                       policy=BinPackPolicy(max_queue=2))
    from repro.core.hext import programs
    sha = next(w for w in programs.WORKLOADS if w.name == "sha")
    states = [svc.job(svc.submit(sha, tenant=t)).state for t in range(3)]
    assert states == ["queued", "queued", "rejected"]
