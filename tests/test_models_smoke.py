"""Per-architecture smoke tests: reduced config, one forward + one train-like
step + one decode step on CPU; assert shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import transformer as tf


def _batch_for(cfg, B=2, S=16):
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.frontend == "vit_stub":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        batch["labels"] = labels
    if cfg.frontend == "audio_stub":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.n_enc_ctx, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, reduced=True)
    params, specs = tf.init_lm(cfg, jax.random.PRNGKey(1))
    assert jax.tree.structure(params) == jax.tree.structure(specs)
    batch = _batch_for(cfg)
    loss, metrics = jax.jit(
        lambda p, b: tf.loss_fn(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss not finite"


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step(arch):
    cfg = get_config(arch, reduced=True)
    params, _ = tf.init_lm(cfg, jax.random.PRNGKey(2))
    batch = _batch_for(cfg)

    @jax.jit
    def step(p, b):
        (l, m), g = jax.value_and_grad(
            lambda p: tf.loss_fn(p, cfg, b), has_aux=True)(p)
        p2 = jax.tree.map(lambda w, gw: w - 1e-3 * gw, p, g)
        return l, p2

    loss, params2 = step(params, batch)
    assert jnp.isfinite(loss)
    flat = jax.tree.leaves(params2)
    assert all(jnp.all(jnp.isfinite(x)) for x in flat), f"{arch}: NaN params"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = get_config(arch, reduced=True)
    params, _ = tf.init_lm(cfg, jax.random.PRNGKey(3))
    B, S, T = 2, 8, 16
    batch = _batch_for(cfg, B=B, S=S)
    cache = tf.init_cache(cfg, B, T)
    extra = batch.get("frames", batch.get("patches"))

    @jax.jit
    def run(p, tokens, cache, extra):
        logits, cache = tf.prefill(p, cfg, tokens, cache, extra_embeds=extra)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = jnp.full((B,), S, jnp.int32)
        logits2, cache = tf.decode_step(p, cfg, nxt, pos, cache)
        return logits, logits2

    logits, logits2 = run(params, batch["tokens"], cache, extra)
    assert logits.shape == (B, cfg.vocab_size)
    assert logits2.shape == (B, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits)) and jnp.all(jnp.isfinite(logits2))


def test_decode_matches_prefill_dense():
    """Teacher-forced decode logits must match full-context prefill logits."""
    cfg = get_config("minicpm_2b", reduced=True)
    params, _ = tf.init_lm(cfg, jax.random.PRNGKey(4))
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                                cfg.vocab_size)
    cache = tf.init_cache(cfg, B, S + 4)
    logits_pre, cache = jax.jit(
        lambda p, t, c: tf.prefill(p, cfg, t, c))(params, tokens, cache)
    # decode the same prefix token-by-token from a fresh cache
    cache2 = tf.init_cache(cfg, B, S + 4)
    dec = jax.jit(lambda p, t, pos, c: tf.decode_step(p, cfg, t, pos, c))
    logits = None
    for i in range(S):
        logits, cache2 = dec(params, tokens[:, i], jnp.full((B,), i,
                             jnp.int32), cache2)
    assert jnp.allclose(logits_pre.astype(jnp.float32),
                        logits.astype(jnp.float32), atol=2e-2, rtol=2e-2)
