"""Property + unit tests for the two-stage KV virtual memory (vmem)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

try:
    from repro.core.vmem import allocator as AL
    from repro.core.vmem import kvcache as KC
    from repro.core.vmem import page_table as PT
except (ImportError, NotImplementedError, RuntimeError) as e:
    # pallas backend unavailable on this host (real bugs still propagate)
    pytest.skip(f"pallas backend unavailable: {e}", allow_module_level=True)


def test_translate_two_stage_composition():
    t = PT.TwoStageTable.create(2, 2, 8, 16)
    t = PT.map_stage1(t, 0, 0, 3, 5)
    t = PT.map_stage2(t, 0, 5, 42)
    tr = PT.translate(t, 0, 0, 3)
    assert int(tr.slot) == 42 and not bool(tr.fault)


def test_stage1_fault_then_stage2_fault():
    t = PT.TwoStageTable.create(1, 1, 4, 4)
    tr = PT.translate(t, 0, 0, 2)
    assert bool(tr.fault) and int(tr.stage) == 1
    t = PT.map_stage1(t, 0, 0, 2, 1)
    tr = PT.translate(t, 0, 0, 2)
    assert bool(tr.fault) and int(tr.stage) == 2


def test_write_permission_enforced():
    t = PT.TwoStageTable.create(1, 1, 4, 4)
    t = PT.map_stage1(t, 0, 0, 0, 0, perm=PT.PERM_R)  # read-only (CoW page)
    t = PT.map_stage2(t, 0, 0, 7)
    assert not bool(PT.translate(t, 0, 0, 0).fault)
    assert bool(PT.translate(t, 0, 0, 0, acc_write=True).fault)


def test_hfence_invalidates_fused_cache():
    """translate-after-hfence == fresh walk (paper hfence semantics)."""
    t = PT.TwoStageTable.create(1, 1, 4, 4)
    t = PT.map_stage1(t, 0, 0, 0, 1)
    t = PT.map_stage2(t, 0, 1, 9)
    t = PT.fill_fused(t, 0, 0, 0)
    assert int(PT.translate(t, 0, 0, 0).slot) == 9
    # hypervisor remaps stage 2 WITHOUT hfence → fused cache is stale
    t = PT.map_stage2(t, 0, 1, 4)
    assert int(PT.translate(t, 0, 0, 0).slot) == 9      # stale (TLB hit)
    t = PT.hfence(t, 0)
    assert int(PT.translate(t, 0, 0, 0).slot) == 4      # fresh walk


def test_tenant_cannot_reach_other_tenants_pages():
    """Isolation: tenant coordinates only index the tenant's own g_table
    row; identical logical coordinates resolve to disjoint slots."""
    t = PT.TwoStageTable.create(2, 1, 4, 4)
    for tenant, slot in ((0, 10), (1, 20)):
        t = PT.map_stage1(t, tenant, 0, 0, 0)
        t = PT.map_stage2(t, tenant, 0, slot)
    assert int(PT.translate(t, 0, 0, 0).slot) == 10
    assert int(PT.translate(t, 1, 0, 0).slot) == 20


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "free", "free_tenant"]),
                          st.integers(0, 2)), min_size=1, max_size=40))
def test_allocator_invariants_hold_under_any_sequence(ops):
    """Hypothesis: disjointness / coverage / quota / ownership-count
    invariants hold under arbitrary alloc/free/teardown interleavings."""
    pool = AL.PagePool.create(16, [6, 6, 6])
    live = []
    for op, tenant in ops:
        if op == "alloc":
            pool, slot = AL.alloc(pool, tenant)
            if int(slot) >= 0:
                live.append(int(slot))
        elif op == "free" and live:
            pool = AL.free(pool, live.pop())
        elif op == "free_tenant":
            pool = AL.free_tenant(pool, tenant)
            owner = np.asarray(pool.owner)
            live = [s for s in live if owner[s] >= 0]
        inv = AL.check_invariants(pool)
        assert all(inv.values()), inv


def test_quota_rejects_over_allocation():
    pool = AL.PagePool.create(8, [2, 8])
    pool, a = AL.alloc(pool, 0)
    pool, b = AL.alloc(pool, 0)
    pool, c = AL.alloc(pool, 0)
    assert int(a) >= 0 and int(b) >= 0 and int(c) == -1  # quota=2 enforced
    pool, d = AL.alloc(pool, 1)
    assert int(d) >= 0                                   # other tenant fine


def test_paged_kv_write_read_roundtrip():
    kv = KC.PagedKVCache.create(
        n_slots=8, page_size=4, n_kv_heads=2, head_dim=8, n_tenants=2,
        reqs_per_tenant=2, logical_pages=4, tenant_pages=8)
    kv, ok = KC.ensure_mapped(kv, 0, 0, 0)
    assert ok
    k = jnp.ones((2, 8)) * 3
    v = jnp.ones((2, 8)) * 5
    kv, fault = KC.write_token(kv, 0, 0, 2, k, v)
    assert not bool(fault)
    kk, vv, tr = KC.gather_kv(kv, 0, 0, 1)
    assert float(kk[2, 0, 0]) == 3 and float(vv[2, 0, 0]) == 5


def test_evict_tenant_frees_everything_and_isolates():
    kv = KC.PagedKVCache.create(
        n_slots=8, page_size=4, n_kv_heads=2, head_dim=8, n_tenants=2,
        reqs_per_tenant=1, logical_pages=4, tenant_pages=8)
    for p in range(3):
        kv, ok = KC.ensure_mapped(kv, 0, 0, p)
        assert ok
    assert int(kv.pool.used[0]) == 3
    kv = KC.evict_tenant(kv, 0)
    assert int(kv.pool.used[0]) == 0
    assert bool(PT.translate(kv.tables, 0, 0, 0, use_fused=False).fault)
    inv = AL.check_invariants(kv.pool)
    assert all(inv.values())


def test_paged_decode_attention_matches_dense():
    """Attention through the two-stage translation == dense attention over
    the same tokens (the serving data plane is exact)."""
    rng = np.random.RandomState(0)
    kv = KC.PagedKVCache.create(
        n_slots=16, page_size=4, n_kv_heads=2, head_dim=8, n_tenants=1,
        reqs_per_tenant=1, logical_pages=8, tenant_pages=16,
        dtype=jnp.float32)
    T = 10
    ks = rng.randn(T, 2, 8).astype(np.float32)
    vs = rng.randn(T, 2, 8).astype(np.float32)
    for t in range(T):
        page = t // 4
        kv, ok = KC.ensure_mapped(kv, 0, 0, page)
        assert ok
        kv, fault = KC.write_token(kv, 0, 0, t, jnp.asarray(ks[t]),
                                   jnp.asarray(vs[t]))
        assert not bool(fault)
    q = jnp.asarray(rng.randn(4, 8).astype(np.float32))  # H=4, G=2
    out = KC.paged_decode_attention(kv, 0, 0, q, T, scale=0.35)
    # dense oracle
    G = 2
    qf = np.asarray(q).reshape(2, G, 8)
    scores = np.einsum("kgh,tkh->kgt", qf, ks) * 0.35
    w = np.exp(scores - scores.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    ref = np.einsum("kgt,tkh->kgh", w, vs).reshape(4, 8)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)
