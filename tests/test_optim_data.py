"""Optimizer, schedule, data-pipeline, and sharding-policy unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule, wsd_schedule
from repro.runtime.sharding import ShardingPolicy, default_policy


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, gn = adamw_update(params, grads, opt, lr=0.05,
                                       weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_adamw_no_decay_on_vectors():
    params = {"b": jnp.ones((4,)), "w": jnp.ones((4, 4))}
    opt = adamw_init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(params, zeros, opt, lr=0.1, weight_decay=0.5)
    np.testing.assert_allclose(np.asarray(p2["b"]), 1.0)       # no decay
    assert float(p2["w"][0, 0]) < 1.0                          # decayed


def test_wsd_schedule_phases():
    lr = wsd_schedule(1.0, warmup=10, stable=20, decay=10)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(25)) == pytest.approx(1.0)      # stable plateau
    assert float(lr(40)) < 0.05                     # decayed


def test_cosine_schedule_monotone_after_peak():
    lr = cosine_schedule(1.0, warmup=5, total=50)
    vals = [float(lr(s)) for s in range(5, 50, 5)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_data_pipeline_determinism_and_shapes():
    cfg = get_config("minicpm_2b", reduced=True)
    d1 = SyntheticLMData(cfg, 8, 16, seed=1)
    d2 = SyntheticLMData(cfg, 8, 16, seed=1)
    b1, b2 = d1.batch_at(5), d2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 16)
    assert (b1["tokens"] >= 0).all() and \
        (b1["tokens"] < cfg.vocab_size).all()
    # next-token labels
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_data_pipeline_host_sharding_disjoint():
    cfg = get_config("minicpm_2b", reduced=True)
    h0 = SyntheticLMData(cfg, 8, 16, seed=1, n_hosts=2, host_id=0)
    h1 = SyntheticLMData(cfg, 8, 16, seed=1, n_hosts=2, host_id=1)
    b0, b1 = h0.batch_at(0), h1.batch_at(0)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_sharding_policy_resolution():
    pol = ShardingPolicy(rules={"fsdp": ("pod", "data"), "tp": "model",
                                "dp": ("pod", "data")})
    assert tuple(pol.resolve(P("fsdp", "tp"))) == (("pod", "data"), "model")
    assert tuple(pol.resolve(P(None, "tp"))) == (None, "model")
    # tuple-of-logical axes flatten
    assert tuple(pol.resolve(P(("fsdp",), "tp"))) == \
        (("pod", "data"), "model")


def test_prefetching_iterator():
    cfg = get_config("mamba2_130m", reduced=True)
    d = SyntheticLMData(cfg, 4, 8, prefetch=2)
    it = d.iterator()
    batches = [next(it) for _ in range(3)]
    d.stop()
    assert all(b["tokens"].shape == (4, 8) for b in batches)
