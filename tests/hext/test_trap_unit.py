"""Direct unit tests for the interrupt/trap plumbing (ISSUE 2 satellites):

* ``trap.pending_interrupt`` priority order and per-level enable gating,
* ``trap.route`` delegation matrix (M → HS → VS),
* ``machine._advance_timers`` CLINT semantics (armed vs disarmed),
* TLB privilege-context tagging (a U-mode access must not reuse an
  S-mode entry's permission verdict),
* reserved PTE encodings (W=1,R=0) page-faulting at both stages,
* HLVX carrying its execute-permission override through the G-stage.

Plus the ISSUE 3 conformance satellites:

* out-of-range physical addresses raising access faults (walk PTE
  fetches and final accesses) instead of wrapping back into RAM,
* ``htimedelta`` shifting the guest's ``time`` view and the vstimecmp
  comparison,
* the counter-enable (TM bit) trap matrix for ``time`` reads,
* the N-guest scheduler memory layout invariants.

These paths were previously exercised only indirectly through workloads.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hext import csr as C
from repro.core.hext import machine
from repro.core.hext import programs
from repro.core.hext import tlb as TLB
from repro.core.hext import translate as X
from repro.core.hext import trap as TR
from tests.hext.conftest import (build_vs_identity, exit_with,
                                 m_handler_capture, prologue, result, run_asm)


def _csrs(**kw):
    """init_csrs with named register overrides (R_* suffix keys)."""
    c = C.init_csrs()
    for name, val in kw.items():
        c = c.at[getattr(C, f"R_{name.upper()}")].set(jnp.uint64(val))
    return c


def _pending(csrs, priv=3, virt=False):
    take, cause = TR.pending_interrupt(
        csrs, jnp.asarray(priv, jnp.int32), jnp.asarray(virt, bool))
    return bool(take), int(cause)


def _route(csrs, priv, virt, cause, is_int):
    tgt = TR.route(csrs, jnp.asarray(priv, jnp.int32),
                   jnp.asarray(virt, bool), jnp.uint64(cause),
                   jnp.asarray(is_int, bool))
    return int(tgt.priv), bool(tgt.virt)


# ---------------------------------------------------------------------------
# pending_interrupt: priority order MEI > MSI > MTI > SEI > SSI > STI > ...
# ---------------------------------------------------------------------------

class TestPendingPriority:
    def test_mei_beats_msi_beats_mti(self):
        with jax.experimental.enable_x64():
            allm = C.IP_MEIP | C.IP_MSIP | C.IP_MTIP
            c = _csrs(mip=allm, mie=allm, mstatus=C.MSTATUS_MIE)
            assert _pending(c) == (True, 11)
            c = _csrs(mip=C.IP_MSIP | C.IP_MTIP, mie=allm,
                      mstatus=C.MSTATUS_MIE)
            assert _pending(c) == (True, 3)
            c = _csrs(mip=C.IP_MTIP, mie=allm, mstatus=C.MSTATUS_MIE)
            assert _pending(c) == (True, 7)

    def test_m_interrupts_beat_s_interrupts(self):
        with jax.experimental.enable_x64():
            c = _csrs(mip=C.IP_MTIP | C.IP_SEIP,
                      mie=C.IP_MTIP | C.IP_SEIP,
                      mideleg=C.MIDELEG_FORCED | C.IP_SEIP,
                      mstatus=C.MSTATUS_MIE | C.MSTATUS_SIE)
            # both deliverable at priv=S: M-level wins
            assert _pending(c, priv=1) == (True, 7)

    def test_s_priority_sei_ssi_sti(self):
        with jax.experimental.enable_x64():
            alls = C.IP_SEIP | C.IP_SSIP | C.IP_STIP
            c = _csrs(mip=alls, mie=alls,
                      mideleg=C.MIDELEG_FORCED | alls,
                      mstatus=C.MSTATUS_SIE)
            assert _pending(c, priv=1) == (True, 9)
            c = _csrs(mip=C.IP_SSIP | C.IP_STIP, mie=alls,
                      mideleg=C.MIDELEG_FORCED | alls,
                      mstatus=C.MSTATUS_SIE)
            assert _pending(c, priv=1) == (True, 1)
            c = _csrs(mip=C.IP_STIP, mie=alls,
                      mideleg=C.MIDELEG_FORCED | alls,
                      mstatus=C.MSTATUS_SIE)
            assert _pending(c, priv=1) == (True, 5)


class TestPendingEnables:
    def test_m_gated_by_mie_at_m_only(self):
        with jax.experimental.enable_x64():
            c = _csrs(mip=C.IP_MSIP, mie=C.IP_MSIP)   # mstatus.MIE = 0
            assert _pending(c, priv=3) == (False, 0)
            # from lower privilege, M interrupts always fire
            assert _pending(c, priv=1)[0]
            assert _pending(c, priv=0)[0]

    def test_hs_gated_by_sie_at_hs_only(self):
        with jax.experimental.enable_x64():
            c = _csrs(mip=C.IP_SSIP, mie=C.IP_SSIP,
                      mideleg=C.MIDELEG_FORCED | C.IP_SSIP)
            assert _pending(c, priv=1) == (False, 0)  # SIE=0 at HS
            assert _pending(c, priv=0)[0]             # U always interruptible
            c = _csrs(mip=C.IP_SSIP, mie=C.IP_SSIP,
                      mideleg=C.MIDELEG_FORCED | C.IP_SSIP,
                      mstatus=C.MSTATUS_SIE)
            assert _pending(c, priv=1) == (True, 1)

    def test_hs_interrupt_preempts_vs_regardless_of_guest_sie(self):
        """The scheduler relies on this: STI delegated to HS fires while a
        guest runs in VS even with all guest enables clear."""
        with jax.experimental.enable_x64():
            c = _csrs(mip=C.IP_STIP, mie=C.IP_STIP,
                      mideleg=C.MIDELEG_FORCED | C.IP_STIP)
            assert _pending(c, priv=1, virt=True) == (True, 5)

    def test_vs_interrupt_gated_by_vsstatus_sie(self):
        with jax.experimental.enable_x64():
            base = dict(mip=C.IP_VSSIP, mie=C.IP_VSSIP,
                        hideleg=C.IP_VSSIP)
            c = _csrs(**base)
            assert _pending(c, priv=1, virt=True) == (False, 0)
            c = _csrs(vsstatus=C.MSTATUS_SIE, **base)
            assert _pending(c, priv=1, virt=True) == (True, 2)
            # VU mode: always interruptible for VS-level interrupts
            c = _csrs(**base)
            assert _pending(c, priv=0, virt=True) == (True, 2)

    def test_vs_interrupt_not_deliverable_without_virt(self):
        """hideleg'd VS interrupt targets VS — with V=0 it must not fire as
        a VS-level interrupt."""
        with jax.experimental.enable_x64():
            c = _csrs(mip=C.IP_VSSIP, mie=C.IP_VSSIP, hideleg=C.IP_VSSIP,
                      vsstatus=C.MSTATUS_SIE)
            assert _pending(c, priv=1, virt=False) == (False, 0)


# ---------------------------------------------------------------------------
# route: the M → HS → VS delegation matrix
# ---------------------------------------------------------------------------

class TestRouteMatrix:
    def test_exception_default_to_m(self):
        with jax.experimental.enable_x64():
            c = _csrs()
            assert _route(c, 1, False, C.EXC_LPAGE_FAULT, False) == (3, False)

    def test_exception_medeleg_to_hs(self):
        with jax.experimental.enable_x64():
            c = _csrs(medeleg=1 << C.EXC_LPAGE_FAULT)
            assert _route(c, 1, False, C.EXC_LPAGE_FAULT, False) == (1, False)
            # HS faults never route to VS even with hedeleg set
            c = _csrs(medeleg=1 << C.EXC_LPAGE_FAULT,
                      hedeleg=1 << C.EXC_LPAGE_FAULT)
            assert _route(c, 1, False, C.EXC_LPAGE_FAULT, False) == (1, False)

    def test_exception_hedeleg_to_vs_only_when_virt(self):
        with jax.experimental.enable_x64():
            c = _csrs(medeleg=1 << C.EXC_LPAGE_FAULT,
                      hedeleg=1 << C.EXC_LPAGE_FAULT)
            assert _route(c, 1, True, C.EXC_LPAGE_FAULT, False) == (1, True)
            # medeleg'd but not hedeleg'd: guest fault lands at HS
            c = _csrs(medeleg=1 << C.EXC_LPAGE_FAULT)
            assert _route(c, 1, True, C.EXC_LPAGE_FAULT, False) == (1, False)

    def test_traps_from_m_never_delegate(self):
        with jax.experimental.enable_x64():
            c = _csrs(medeleg=0xFFFF, mideleg=0xFFFF, hedeleg=0xFFFF)
            assert _route(c, 3, False, C.EXC_LPAGE_FAULT, False) == (3, False)
            assert _route(c, 3, False, 3, True) == (3, False)

    def test_interrupt_mideleg_hideleg_chain(self):
        with jax.experimental.enable_x64():
            # VSSI: mideleg VS bits are forced-one; hideleg decides HS vs VS
            c = _csrs(hideleg=C.IP_VSSIP)
            assert _route(c, 1, True, 2, True) == (1, True)    # → VS
            c = _csrs()
            assert _route(c, 1, True, 2, True) == (1, False)   # → HS
            # STI: mideleg clear → M; set → HS (never VS: hideleg WARL-0)
            c = _csrs()
            assert _route(c, 1, True, 5, True) == (3, False)
            c = _csrs(mideleg=C.MIDELEG_FORCED | C.IP_STIP)
            assert _route(c, 1, True, 5, True) == (1, False)


# ---------------------------------------------------------------------------
# the virtual CLINT: armed comparators drive mip, disarmed leave it alone
# ---------------------------------------------------------------------------

class TestAdvanceTimers:
    def test_disarmed_never_touches_mip(self):
        with jax.experimental.enable_x64():
            c = _csrs(mip=C.IP_SSIP)              # software-injected bit
            for _ in range(3):
                c = machine._advance_timers(c)
            assert int(c[C.R_MTIME]) == 3
            assert int(c[C.R_MIP]) == C.IP_SSIP   # untouched

    def test_armed_mtimecmp_sets_then_clears_mtip(self):
        with jax.experimental.enable_x64():
            c = _csrs(mtimecmp=2)
            c = machine._advance_timers(c)        # mtime=1 < 2
            assert int(c[C.R_MIP]) & C.IP_MTIP == 0
            c = machine._advance_timers(c)        # mtime=2 >= 2
            assert int(c[C.R_MIP]) & C.IP_MTIP
            # re-arming into the future clears the pending bit
            c = c.at[C.R_MTIMECMP].set(jnp.uint64(100))
            c = machine._advance_timers(c)
            assert int(c[C.R_MIP]) & C.IP_MTIP == 0

    def test_stimecmp_and_vstimecmp_drive_their_bits(self):
        with jax.experimental.enable_x64():
            c = _csrs(stimecmp=1, vstimecmp=2)
            c = machine._advance_timers(c)
            assert int(c[C.R_MIP]) & C.IP_STIP
            assert int(c[C.R_MIP]) & C.IP_VSTIP == 0
            c = machine._advance_timers(c)
            assert int(c[C.R_MIP]) & C.IP_VSTIP


# ---------------------------------------------------------------------------
# TLB privilege-context tags
# ---------------------------------------------------------------------------

class TestTlbPrivTags:
    def _mk(self, priv, sum_bit=False, mxr=False):
        return (jnp.asarray(priv, jnp.int32), jnp.asarray(sum_bit, bool),
                jnp.asarray(mxr, bool))

    def test_cross_priv_lookup_misses(self):
        with jax.experimental.enable_x64():
            t = TLB.init_tlb()
            virt = jnp.asarray(False, bool)
            p1 = self._mk(1)
            t = TLB.insert(t, jnp.uint64(0x5000), jnp.uint64(0x5000),
                           jnp.asarray(0, jnp.int32),
                           jnp.asarray(TLB.PERM_R, jnp.int32), virt, *p1)
            hit, _, ok = TLB.lookup(t, jnp.uint64(0x5000), virt,
                                    jnp.uint64(X.ACC_R), *p1)
            assert bool(hit) and bool(ok)
            # U-mode must not reuse the S-mode verdict
            hit, _, _ = TLB.lookup(t, jnp.uint64(0x5000), virt,
                                   jnp.uint64(X.ACC_R), *self._mk(0))
            assert not bool(hit)

    def test_sum_and_mxr_context_mismatch_misses(self):
        with jax.experimental.enable_x64():
            t = TLB.init_tlb()
            virt = jnp.asarray(False, bool)
            ctx = self._mk(1, sum_bit=True)
            t = TLB.insert(t, jnp.uint64(0x6000), jnp.uint64(0x6000),
                           jnp.asarray(0, jnp.int32),
                           jnp.asarray(TLB.PERM_R, jnp.int32), virt, *ctx)
            hit, _, _ = TLB.lookup(t, jnp.uint64(0x6000), virt,
                                   jnp.uint64(X.ACC_R), *self._mk(1))
            assert not bool(hit)                      # SUM flipped off
            hit, _, _ = TLB.lookup(t, jnp.uint64(0x6000), virt,
                                   jnp.uint64(X.ACC_R),
                                   *self._mk(1, sum_bit=True, mxr=True))
            assert not bool(hit)                      # MXR differs


# ---------------------------------------------------------------------------
# reserved PTE encodings + HLVX G-stage override (direct walker tests)
# ---------------------------------------------------------------------------

SV39 = C.ATP_MODE_SV39 << C.ATP_MODE_SHIFT


def _mem_with(entries):
    """Flat uint64 memory with {byte_addr: value} poked in."""
    mem = np.zeros((1 << 12,), dtype=np.uint64)   # 32 KiB
    for addr, val in entries.items():
        mem[addr // 8] = np.uint64(val & ((1 << 64) - 1))
    return jnp.asarray(mem)


def _pte(pa, perms):
    return ((pa >> 12) << 10) | perms


class TestReservedPte:
    def test_w_only_pte_faults_first_stage(self):
        """W=1,R=0 is reserved — previously walked through as a pointer."""
        with jax.experimental.enable_x64():
            P = X.PTE_V | X.PTE_W | X.PTE_A | X.PTE_D
            mem = _mem_with({
                0x1000: _pte(0x2000, X.PTE_V),            # L2 → L1
                0x2000: _pte(0x3000, X.PTE_V),            # L1 → L0
                0x3000 + 5 * 8: _pte(0x5000, P),          # reserved leaf
            })
            csrs = _csrs(satp=SV39 | (0x1000 >> 12))
            xr = X.translate(mem, csrs, jnp.asarray(1, jnp.int32),
                             jnp.asarray(False, bool), jnp.uint64(0x5000),
                             X.ACC_R)
            assert bool(xr.fault)
            assert int(xr.cause) == C.EXC_LPAGE_FAULT

    def test_w_only_nonleaf_position_faults(self):
        """A reserved encoding in a *non-leaf* slot must fault too, not be
        dereferenced as a next-level pointer."""
        with jax.experimental.enable_x64():
            mem = _mem_with({
                0x1000: _pte(0x2000, X.PTE_V | X.PTE_W),  # reserved pointer
                0x2000: _pte(0x3000, X.PTE_V),
                0x3000 + 5 * 8: _pte(0x5000, X.ALL_PERM_PTE),
            })
            csrs = _csrs(satp=SV39 | (0x1000 >> 12))
            xr = X.translate(mem, csrs, jnp.asarray(1, jnp.int32),
                             jnp.asarray(False, bool), jnp.uint64(0x5000),
                             X.ACC_X)
            assert bool(xr.fault)
            assert int(xr.cause) == C.EXC_IPAGE_FAULT

    def test_w_only_pte_faults_g_stage(self):
        with jax.experimental.enable_x64():
            P = X.PTE_V | X.PTE_W | X.PTE_U | X.PTE_A | X.PTE_D
            mem = _mem_with({
                0x1000: _pte(0x2000, X.PTE_V),
                0x2000: _pte(0x3000, X.PTE_V),
                0x3000 + 5 * 8: _pte(0x5000, P),
            })
            hgatp = jnp.uint64(SV39 | (0x1000 >> 12))
            xr = X.g_translate(mem, hgatp, jnp.uint64(0x5000),
                               jnp.uint64(X.ACC_R), jnp.asarray(False, bool))
            assert bool(xr.fault)
            assert int(xr.cause) == C.EXC_LGUEST_PAGE_FAULT


class TestHlvxGStage:
    def _setup(self, g_perms):
        """vsatp BARE, hgatp maps GPA 0x5000 with `g_perms`."""
        mem = _mem_with({
            0x1000: _pte(0x2000, X.PTE_V),
            0x2000: _pte(0x3000, X.PTE_V),
            0x3000 + 5 * 8: _pte(0x5000, g_perms),
            0x5000: 0xCAFE,
        })
        csrs = _csrs(hgatp=SV39 | (0x1000 >> 12))
        return mem, csrs

    def test_hlvx_reads_x_only_g_stage_page(self):
        """HLVX requires execute permission INSTEAD of read — at both
        stages.  An X-only G-stage page must satisfy it."""
        with jax.experimental.enable_x64():
            xonly = X.PTE_V | X.PTE_X | X.PTE_U | X.PTE_A | X.PTE_D
            mem, csrs = self._setup(xonly)
            xr = X.translate(mem, csrs, jnp.asarray(3, jnp.int32),
                             jnp.asarray(False, bool), jnp.uint64(0x5000),
                             X.ACC_R, force_virt=True, hlvx=True)
            assert not bool(xr.fault)
            assert int(xr.pa) == 0x5000
            # while a plain hlv load of the same page still faults …
            xr = X.translate(mem, csrs, jnp.asarray(3, jnp.int32),
                             jnp.asarray(False, bool), jnp.uint64(0x5000),
                             X.ACC_R, force_virt=True, hlvx=False)
            assert bool(xr.fault)
            assert int(xr.cause) == C.EXC_LGUEST_PAGE_FAULT

    def test_hlvx_faults_on_r_only_g_stage_page(self):
        with jax.experimental.enable_x64():
            ronly = X.PTE_V | X.PTE_R | X.PTE_U | X.PTE_A | X.PTE_D
            mem, csrs = self._setup(ronly)
            xr = X.translate(mem, csrs, jnp.asarray(3, jnp.int32),
                             jnp.asarray(False, bool), jnp.uint64(0x5000),
                             X.ACC_R, force_virt=True, hlvx=True)
            assert bool(xr.fault)
            # …reported with the original (load) access type
            assert int(xr.cause) == C.EXC_LGUEST_PAGE_FAULT

    def test_hlvx_implicit_walk_fault_reports_load_cause(self):
        """An hlvx whose VS-stage PTE *fetch* guest-faults must report the
        original (load) access type, not the execute override."""
        with jax.experimental.enable_x64():
            mem = np.zeros((1 << 13,), dtype=np.uint64)   # 64 KiB

            def poke(addr, val):
                mem[addr // 8] = np.uint64(val & ((1 << 64) - 1))
            # VS-stage tables at GPA 0x1000/0x2000/0x3000 → VA 0x5000
            poke(0x1000, _pte(0x2000, X.PTE_V))
            poke(0x2000, _pte(0x3000, X.PTE_V))
            poke(0x3000 + 5 * 8, _pte(0x5000, X.ALL_PERM_PTE))
            # G-stage (root 0x8000, Sv39x4) maps GPA 0x5000 but NOT the VS
            # page-table pages → the implicit PTE fetch guest-faults
            gp = X.PTE_V | X.PTE_R | X.PTE_W | X.PTE_X | X.PTE_U | \
                X.PTE_A | X.PTE_D
            poke(0x8000, _pte(0xC000, X.PTE_V))
            poke(0xC000, _pte(0xD000, X.PTE_V))
            poke(0xD000 + 5 * 8, _pte(0x5000, gp))
            csrs = _csrs(vsatp=SV39 | (0x1000 >> 12),
                         hgatp=SV39 | (0x8000 >> 12))
            xr = X.translate(jnp.asarray(mem), csrs,
                             jnp.asarray(3, jnp.int32),
                             jnp.asarray(False, bool), jnp.uint64(0x5000),
                             X.ACC_R, force_virt=True, hlvx=True)
            assert bool(xr.fault) and bool(xr.implicit)
            assert int(xr.cause) == C.EXC_LGUEST_PAGE_FAULT   # not I-GPF


# ---------------------------------------------------------------------------
# out-of-range physical addresses: access faults, not modulo wrap-around
# ---------------------------------------------------------------------------

class TestOobPaAccessFault:
    """A PA beyond physical memory previously aliased back into RAM via
    `% mem.shape[0]`; it must raise the access fault of the original
    access type instead — during walks and on the final access."""

    def test_walk_pte_beyond_memory_faults_per_access_type(self):
        with jax.experimental.enable_x64():
            mem = jnp.zeros((1 << 12,), jnp.uint64)       # 32 KiB
            # satp root far beyond memory: the level-2 PTE fetch is OOB
            csrs = _csrs(satp=SV39 | ((1 << 20) >> 12))
            for acc, cause in ((X.ACC_R, C.EXC_LACCESS),
                               (X.ACC_W, C.EXC_SACCESS),
                               (X.ACC_X, C.EXC_IACCESS)):
                xr = X.translate(mem, csrs, jnp.asarray(1, jnp.int32),
                                 jnp.asarray(False, bool),
                                 jnp.uint64(0x5000), acc)
                assert bool(xr.fault)
                assert int(xr.cause) == cause

    def test_walk_inner_pte_beyond_memory_faults(self):
        """An in-range root whose next-level pointer leaves memory must
        fault at that level, not wrap and keep walking."""
        with jax.experimental.enable_x64():
            mem = _mem_with({0x1000: _pte(1 << 21, X.PTE_V)})  # L2 → OOB L1
            csrs = _csrs(satp=SV39 | (0x1000 >> 12))
            xr = X.translate(jnp.asarray(mem), csrs,
                             jnp.asarray(1, jnp.int32),
                             jnp.asarray(False, bool), jnp.uint64(0x5000),
                             X.ACC_R)
            assert bool(xr.fault)
            assert int(xr.cause) == C.EXC_LACCESS

    def test_gstage_walk_pte_beyond_memory_faults(self):
        """G-stage PTE fetches are bounds-checked too — and report the
        access-fault cause, not a guest-page-fault."""
        with jax.experimental.enable_x64():
            mem = jnp.zeros((1 << 12,), jnp.uint64)
            hgatp = jnp.uint64(SV39 | ((1 << 20) >> 12))
            xr = X.g_translate(mem, hgatp, jnp.uint64(0x5000),
                               jnp.uint64(X.ACC_R), jnp.asarray(False, bool))
            assert bool(xr.fault)
            assert int(xr.cause) == C.EXC_LACCESS

    def test_final_load_store_beyond_memory_fault_e2e(self):
        """M-mode load/store of a PA past RAM (and not a decoded MMIO
        register) raises the load/store access fault."""
        OOB = programs.MEM_WORDS * 8 + 0x8000

        def build_load(a, img):
            prologue(a)
            a.li("t0", OOB)
            a.ld("a0", 0, "t0")
            a.nop()
            m_handler_capture(a)

        st = run_asm(build_load, ticks=200)
        assert result(st) == C.EXC_LACCESS
        assert csr_of_mtval(st) == OOB

        def build_store(a, img):
            prologue(a)
            a.li("t0", OOB)
            a.sd("t0", 0, "t0")
            a.nop()
            m_handler_capture(a)

        st = run_asm(build_store, ticks=200)
        assert result(st) == C.EXC_SACCESS

    def test_load_from_write_only_mmio_faults_e2e(self):
        """The console/done/ctxsw MMIO registers have no read decode — a
        load from them must access-fault, not wrap into RAM (the CLINT
        mtime/mtimecmp pair stays readable)."""
        from repro.core.hext import isa

        def build(a, img):
            prologue(a)
            a.li("t0", isa.MMIO_CONSOLE)
            a.ld("a0", 0, "t0")
            a.nop()
            m_handler_capture(a)

        st = run_asm(build, ticks=200)
        assert result(st) == C.EXC_LACCESS

        def build_ok(a, img):
            prologue(a)
            a.li("t0", isa.MMIO_MTIME)
            a.ld("a0", 0, "t0")              # readable: raw mtime
            exit_with(a, "a0")
            m_handler_capture(a)

        st = run_asm(build_ok, ticks=200)
        assert st.counters.exc_by_level.tolist() == [0, 0, 0]   # no trap
        assert result(st) > 0                                   # raw mtime

    def test_final_fetch_beyond_memory_faults_e2e(self):
        OOB = programs.MEM_WORDS * 8 + 0x8000

        def build(a, img):
            prologue(a)
            a.li("t0", OOB)
            a.jalr("zero", 0, "t0")
            m_handler_capture(a)

        st = run_asm(build, ticks=200)
        assert result(st) == C.EXC_IACCESS
        assert csr_of_mtval(st) == OOB        # tval = faulting fetch address
        assert int(st.csrs[C.R_MEPC]) == OOB

    def test_translated_load_to_oob_pa_faults_e2e(self):
        """S-mode VA whose leaf PTE points past RAM: translation succeeds,
        the final access faults (previously it wrapped into RAM)."""
        def build(a, img):
            prologue(a)
            build_vs_identity(img)
            # VA 0x5000 → PA 1 MiB (beyond the 256 KiB image)
            img.map_page(programs.S_L0, 0x5000, 1 << 20, programs.P_KERN)
            a.li("t0", 1 << 11)
            a.csrrs(0, 0x300, "t0")           # MPP=S
            a.li("t0", 0x400)
            a.csrw(0x341, "t0")
            a.mret()
            while a.pc < 0x400:
                a.nop()
            a.li("t0", (8 << 60) | (programs.S_L2 >> 12))
            a.csrw(0x180, "t0")               # satp
            a.sfence_vma()
            a.li("t1", 0x5000)
            a.ld("a0", 0, "t1")
            a.nop()
            m_handler_capture(a)

        st = run_asm(build, ticks=400)
        assert result(st) == C.EXC_LACCESS
        assert csr_of_mtval(st) == 0x5000     # tval = faulting VA


def csr_of_mtval(st):
    return int(st.csrs[C.R_MTVAL])


# ---------------------------------------------------------------------------
# htimedelta: the guest time base (CSR 0x605)
# ---------------------------------------------------------------------------

class TestHtimedelta:
    M64 = (1 << 64) - 1

    def _open_counters(self, c):
        return c.at[C.R_MCOUNTEREN].set(jnp.uint64(7)).at[
            C.R_HCOUNTEREN].set(jnp.uint64(7)).at[
            C.R_SCOUNTEREN].set(jnp.uint64(7))

    def _time(self, c, priv, virt):
        with jax.experimental.enable_x64():
            v, ok, vinst = C.csr_read(c, jnp.asarray(0xC01, jnp.int32),
                                      jnp.asarray(priv, jnp.int32),
                                      jnp.asarray(virt, bool))
            return int(v), bool(ok), bool(vinst)

    def test_time_shifted_under_v1_only(self):
        with jax.experimental.enable_x64():
            c = self._open_counters(_csrs(mtime=1000))
            c = c.at[C.R_HTIMEDELTA].set(jnp.uint64(self.M64 - 99))  # -100
            assert self._time(c, 1, False)[0] == 1000    # HS: raw mtime
            assert self._time(c, 1, True)[0] == 900      # VS: mtime + delta
            assert self._time(c, 0, True)[0] == 900      # VU too

    def test_write_preserved_from_hs_vinst_from_vs(self):
        with jax.experimental.enable_x64():
            c = _csrs()
            new, ok, vinst = C.csr_write(
                c, jnp.asarray(0x605, jnp.int32), jnp.uint64(0x1234),
                jnp.asarray(1, jnp.int32), jnp.asarray(False, bool))
            assert bool(ok) and not bool(vinst)
            assert int(new[C.R_HTIMEDELTA]) == 0x1234
            rd, ok, _ = (lambda t: (int(t[0]), bool(t[1]), bool(t[2])))(
                C.csr_read(new, jnp.asarray(0x605, jnp.int32),
                           jnp.asarray(1, jnp.int32),
                           jnp.asarray(False, bool)))
            assert ok and rd == 0x1234
            # VS access to the H-level CSR → virtual instruction
            _, ok, vinst = C.csr_write(
                c, jnp.asarray(0x605, jnp.int32), jnp.uint64(1),
                jnp.asarray(1, jnp.int32), jnp.asarray(True, bool))
            assert not bool(ok) and bool(vinst)

    def test_vstimecmp_compares_guest_time(self):
        """VSTIP must arm on mtime + htimedelta: with delta = -30 and
        vstimecmp = 50, the comparator fires at mtime 80, not 50."""
        with jax.experimental.enable_x64():
            c = _csrs(vstimecmp=50, mtime=49)
            c = c.at[C.R_HTIMEDELTA].set(jnp.uint64(self.M64 - 29))  # -30
            c = machine._advance_timers(c)               # mtime 50: vs 20
            assert int(c[C.R_MIP]) & C.IP_VSTIP == 0
            c = c.at[C.R_MTIME].set(jnp.uint64(79))
            c = machine._advance_timers(c)               # mtime 80: vs 50
            assert int(c[C.R_MIP]) & C.IP_VSTIP


# ---------------------------------------------------------------------------
# counter-enable (TM) gating of `time` reads
# ---------------------------------------------------------------------------

class TestTimeCounterEnable:
    def _rd(self, c, priv, virt):
        with jax.experimental.enable_x64():
            _, ok, vinst = C.csr_read(c, jnp.asarray(0xC01, jnp.int32),
                                      jnp.asarray(priv, jnp.int32),
                                      jnp.asarray(virt, bool))
            return bool(ok), bool(vinst)

    def _c(self, m=0, h=0, s=0):
        with jax.experimental.enable_x64():
            c = C.init_csrs()
            return c.at[C.R_MCOUNTEREN].set(jnp.uint64(m)).at[
                C.R_HCOUNTEREN].set(jnp.uint64(h)).at[
                C.R_SCOUNTEREN].set(jnp.uint64(s))

    TM = C.COUNTEREN_TM

    def test_m_mode_always_reads(self):
        assert self._rd(self._c(), 3, False) == (True, False)

    def test_s_mode_gated_by_mcounteren(self):
        assert self._rd(self._c(), 1, False) == (False, False)   # illegal
        assert self._rd(self._c(m=self.TM), 1, False) == (True, False)

    def test_u_mode_needs_mcounteren_and_scounteren(self):
        assert self._rd(self._c(m=self.TM), 0, False) == (False, False)
        assert self._rd(self._c(m=self.TM, s=self.TM), 0, False) == \
            (True, False)

    def test_vs_matrix(self):
        # mcounteren clear → illegal even under V=1
        assert self._rd(self._c(), 1, True) == (False, False)
        # mcounteren set, hcounteren clear → virtual instruction
        assert self._rd(self._c(m=self.TM), 1, True) == (False, True)
        assert self._rd(self._c(m=self.TM, h=self.TM), 1, True) == \
            (True, False)

    def test_vu_additionally_needs_scounteren(self):
        assert self._rd(self._c(m=self.TM, h=self.TM), 0, True) == \
            (False, True)
        assert self._rd(self._c(m=self.TM, h=self.TM, s=self.TM),
                        0, True) == (True, False)


# ---------------------------------------------------------------------------
# N-guest scheduler layout invariants
# ---------------------------------------------------------------------------

class TestSchedLayout:
    def test_n2_layout_is_the_legacy_layout(self):
        lay = programs.sched_layout(2)
        assert lay.g_l2 == programs.G2_L2
        assert lay.g_l1 == programs.G2_L1
        assert lay.g_l0 == programs.G2_L0
        assert lay.win == programs.PB
        assert lay.guest_res == programs.GUEST_RES
        assert lay.ctx0 == programs.CTX0
        assert lay.mem_words == programs.MEM_WORDS

    @pytest.mark.parametrize("n", range(1, programs.MAX_GUESTS + 1))
    def test_layout_invariants_all_n(self, n):
        lay = programs.sched_layout(n)
        # Sv39x4 roots are 16K-aligned, 16 KiB wide, non-overlapping
        for l2, l1, l0 in zip(lay.g_l2, lay.g_l1, lay.g_l0):
            assert l2 % 0x4000 == 0
            assert l1 == l2 + 0x4000 and l0 == l2 + 0x5000
        # scheduler state fits below the G-stage tables
        assert lay.ctx0 + n * programs.CTX_SIZE <= lay.g_l2[0]
        assert lay.guest_res + 8 * n <= lay.ctx0
        assert lay.ginfo0 + n * programs.GINFO_SIZE <= lay.guest_res
        # windows sit above every table block and tile contiguously
        tab_end = lay.g_l2[-1] + programs.GTAB_STRIDE
        assert lay.win[0] >= tab_end
        for i, w in enumerate(lay.win):
            assert w == lay.win[0] + i * programs.GUEST_WIN
        assert lay.mem_words * 8 == lay.win[-1] + programs.GUEST_WIN

    @pytest.mark.parametrize("n", range(1, programs.MAX_GUESTS + 1))
    def test_region_disjointness_all_n(self, n):
        """Every layout region — scheduler state blocks, per-guest table
        blocks, per-guest windows — must be pairwise disjoint and inside
        the image, for EVERY n (an overlap at an untested n would mean one
        guest silently corrupting a sibling's tables or context)."""
        lay = programs.sched_layout(n)
        regions = [("ginfo", lay.ginfo0, lay.ginfo0 +
                    n * programs.GINFO_SIZE),
                   ("res", lay.guest_res, lay.guest_res + 8 * n)]
        regions += [(f"ctx{i}", lay.ctx0 + i * programs.CTX_SIZE,
                     lay.ctx0 + (i + 1) * programs.CTX_SIZE)
                    for i in range(n)]
        regions += [(f"gtab{i}", l2, l2 + programs.GTAB_STRIDE)
                    for i, l2 in enumerate(lay.g_l2)]
        regions += [(f"win{i}", w, w + programs.GUEST_WIN)
                    for i, w in enumerate(lay.win)]
        for i, (na, sa, ea) in enumerate(regions):
            assert sa < ea <= lay.mem_words * 8, (na, n)
            assert sa % 8 == 0, (na, n)
            for nb, sb, eb in regions[i + 1:]:
                assert ea <= sb or eb <= sa, \
                    f"n={n}: {na} [{sa:#x},{ea:#x}) overlaps " \
                    f"{nb} [{sb:#x},{eb:#x})"
        # context-slot count: exactly n slots fit between ctx0 and the
        # first table block, each holding GPRs + the VS CSR bank + vtime
        assert programs.CTX_VTIME + 8 < programs.CTX_SIZE
        assert lay.ctx0 >= lay.guest_res + 8 * n
        # scheduler code/data regions below the dynamic area are fixed
        assert lay.ginfo0 == programs.GINFO0 >= programs.SCHED_CUR + 0x20

    @pytest.mark.parametrize("n", (0, -1, programs.MAX_GUESTS + 1,
                                   programs.MAX_GUESTS + 100))
    def test_out_of_range_n_rejected(self, n):
        with pytest.raises(ValueError):
            programs.sched_layout(n)

    @pytest.mark.parametrize("n", (0, 9))
    def test_nguest_builders_reject_bad_n(self, n):
        """The image builder and the Fleet facade both surface the
        layout's ValueError instead of building a corrupt image."""
        wls = [programs.SHA()] * n
        with pytest.raises(ValueError):
            programs.build_image_nguest(wls)
        from repro.core.hext.sim import Fleet
        if n > 0:
            with pytest.raises(ValueError):
                Fleet.boot([tuple(wls)], guests_per_hart=n)

    @pytest.mark.parametrize("n", range(1, programs.MAX_GUESTS + 1))
    def test_image_sized_by_layout_all_n(self, n):
        img = programs.build_image_nguest([programs.SHA()] * n)
        assert img.shape[0] == programs.sched_layout(n).mem_words

    def test_scheduler_assembles_for_all_n(self):
        """Boot code must fit below HS2_HANDLER and the handler below
        SCHED_CUR for every supported N (the asserts fire at build time)."""
        for n in range(1, programs.MAX_GUESTS + 1):
            programs._scheduler_hypervisor(500, n=n).assemble()

    def test_max_guests_image_builds(self):
        img = programs.build_image_nguest(
            [programs.SHA()] * programs.MAX_GUESTS)
        assert img.shape[0] == programs.sched_layout(
            programs.MAX_GUESTS).mem_words
