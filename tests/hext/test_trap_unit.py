"""Direct unit tests for the interrupt/trap plumbing (ISSUE 2 satellites):

* ``trap.pending_interrupt`` priority order and per-level enable gating,
* ``trap.route`` delegation matrix (M → HS → VS),
* ``machine._advance_timers`` CLINT semantics (armed vs disarmed),
* TLB privilege-context tagging (a U-mode access must not reuse an
  S-mode entry's permission verdict),
* reserved PTE encodings (W=1,R=0) page-faulting at both stages,
* HLVX carrying its execute-permission override through the G-stage.

These paths were previously exercised only indirectly through workloads.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hext import csr as C
from repro.core.hext import machine
from repro.core.hext import tlb as TLB
from repro.core.hext import translate as X
from repro.core.hext import trap as TR


def _csrs(**kw):
    """init_csrs with named register overrides (R_* suffix keys)."""
    c = C.init_csrs()
    for name, val in kw.items():
        c = c.at[getattr(C, f"R_{name.upper()}")].set(jnp.uint64(val))
    return c


def _pending(csrs, priv=3, virt=False):
    take, cause = TR.pending_interrupt(
        csrs, jnp.asarray(priv, jnp.int32), jnp.asarray(virt, bool))
    return bool(take), int(cause)


def _route(csrs, priv, virt, cause, is_int):
    tgt = TR.route(csrs, jnp.asarray(priv, jnp.int32),
                   jnp.asarray(virt, bool), jnp.uint64(cause),
                   jnp.asarray(is_int, bool))
    return int(tgt.priv), bool(tgt.virt)


# ---------------------------------------------------------------------------
# pending_interrupt: priority order MEI > MSI > MTI > SEI > SSI > STI > ...
# ---------------------------------------------------------------------------

class TestPendingPriority:
    def test_mei_beats_msi_beats_mti(self):
        with jax.experimental.enable_x64():
            allm = C.IP_MEIP | C.IP_MSIP | C.IP_MTIP
            c = _csrs(mip=allm, mie=allm, mstatus=C.MSTATUS_MIE)
            assert _pending(c) == (True, 11)
            c = _csrs(mip=C.IP_MSIP | C.IP_MTIP, mie=allm,
                      mstatus=C.MSTATUS_MIE)
            assert _pending(c) == (True, 3)
            c = _csrs(mip=C.IP_MTIP, mie=allm, mstatus=C.MSTATUS_MIE)
            assert _pending(c) == (True, 7)

    def test_m_interrupts_beat_s_interrupts(self):
        with jax.experimental.enable_x64():
            c = _csrs(mip=C.IP_MTIP | C.IP_SEIP,
                      mie=C.IP_MTIP | C.IP_SEIP,
                      mideleg=C.MIDELEG_FORCED | C.IP_SEIP,
                      mstatus=C.MSTATUS_MIE | C.MSTATUS_SIE)
            # both deliverable at priv=S: M-level wins
            assert _pending(c, priv=1) == (True, 7)

    def test_s_priority_sei_ssi_sti(self):
        with jax.experimental.enable_x64():
            alls = C.IP_SEIP | C.IP_SSIP | C.IP_STIP
            c = _csrs(mip=alls, mie=alls,
                      mideleg=C.MIDELEG_FORCED | alls,
                      mstatus=C.MSTATUS_SIE)
            assert _pending(c, priv=1) == (True, 9)
            c = _csrs(mip=C.IP_SSIP | C.IP_STIP, mie=alls,
                      mideleg=C.MIDELEG_FORCED | alls,
                      mstatus=C.MSTATUS_SIE)
            assert _pending(c, priv=1) == (True, 1)
            c = _csrs(mip=C.IP_STIP, mie=alls,
                      mideleg=C.MIDELEG_FORCED | alls,
                      mstatus=C.MSTATUS_SIE)
            assert _pending(c, priv=1) == (True, 5)


class TestPendingEnables:
    def test_m_gated_by_mie_at_m_only(self):
        with jax.experimental.enable_x64():
            c = _csrs(mip=C.IP_MSIP, mie=C.IP_MSIP)   # mstatus.MIE = 0
            assert _pending(c, priv=3) == (False, 0)
            # from lower privilege, M interrupts always fire
            assert _pending(c, priv=1)[0]
            assert _pending(c, priv=0)[0]

    def test_hs_gated_by_sie_at_hs_only(self):
        with jax.experimental.enable_x64():
            c = _csrs(mip=C.IP_SSIP, mie=C.IP_SSIP,
                      mideleg=C.MIDELEG_FORCED | C.IP_SSIP)
            assert _pending(c, priv=1) == (False, 0)  # SIE=0 at HS
            assert _pending(c, priv=0)[0]             # U always interruptible
            c = _csrs(mip=C.IP_SSIP, mie=C.IP_SSIP,
                      mideleg=C.MIDELEG_FORCED | C.IP_SSIP,
                      mstatus=C.MSTATUS_SIE)
            assert _pending(c, priv=1) == (True, 1)

    def test_hs_interrupt_preempts_vs_regardless_of_guest_sie(self):
        """The scheduler relies on this: STI delegated to HS fires while a
        guest runs in VS even with all guest enables clear."""
        with jax.experimental.enable_x64():
            c = _csrs(mip=C.IP_STIP, mie=C.IP_STIP,
                      mideleg=C.MIDELEG_FORCED | C.IP_STIP)
            assert _pending(c, priv=1, virt=True) == (True, 5)

    def test_vs_interrupt_gated_by_vsstatus_sie(self):
        with jax.experimental.enable_x64():
            base = dict(mip=C.IP_VSSIP, mie=C.IP_VSSIP,
                        hideleg=C.IP_VSSIP)
            c = _csrs(**base)
            assert _pending(c, priv=1, virt=True) == (False, 0)
            c = _csrs(vsstatus=C.MSTATUS_SIE, **base)
            assert _pending(c, priv=1, virt=True) == (True, 2)
            # VU mode: always interruptible for VS-level interrupts
            c = _csrs(**base)
            assert _pending(c, priv=0, virt=True) == (True, 2)

    def test_vs_interrupt_not_deliverable_without_virt(self):
        """hideleg'd VS interrupt targets VS — with V=0 it must not fire as
        a VS-level interrupt."""
        with jax.experimental.enable_x64():
            c = _csrs(mip=C.IP_VSSIP, mie=C.IP_VSSIP, hideleg=C.IP_VSSIP,
                      vsstatus=C.MSTATUS_SIE)
            assert _pending(c, priv=1, virt=False) == (False, 0)


# ---------------------------------------------------------------------------
# route: the M → HS → VS delegation matrix
# ---------------------------------------------------------------------------

class TestRouteMatrix:
    def test_exception_default_to_m(self):
        with jax.experimental.enable_x64():
            c = _csrs()
            assert _route(c, 1, False, C.EXC_LPAGE_FAULT, False) == (3, False)

    def test_exception_medeleg_to_hs(self):
        with jax.experimental.enable_x64():
            c = _csrs(medeleg=1 << C.EXC_LPAGE_FAULT)
            assert _route(c, 1, False, C.EXC_LPAGE_FAULT, False) == (1, False)
            # HS faults never route to VS even with hedeleg set
            c = _csrs(medeleg=1 << C.EXC_LPAGE_FAULT,
                      hedeleg=1 << C.EXC_LPAGE_FAULT)
            assert _route(c, 1, False, C.EXC_LPAGE_FAULT, False) == (1, False)

    def test_exception_hedeleg_to_vs_only_when_virt(self):
        with jax.experimental.enable_x64():
            c = _csrs(medeleg=1 << C.EXC_LPAGE_FAULT,
                      hedeleg=1 << C.EXC_LPAGE_FAULT)
            assert _route(c, 1, True, C.EXC_LPAGE_FAULT, False) == (1, True)
            # medeleg'd but not hedeleg'd: guest fault lands at HS
            c = _csrs(medeleg=1 << C.EXC_LPAGE_FAULT)
            assert _route(c, 1, True, C.EXC_LPAGE_FAULT, False) == (1, False)

    def test_traps_from_m_never_delegate(self):
        with jax.experimental.enable_x64():
            c = _csrs(medeleg=0xFFFF, mideleg=0xFFFF, hedeleg=0xFFFF)
            assert _route(c, 3, False, C.EXC_LPAGE_FAULT, False) == (3, False)
            assert _route(c, 3, False, 3, True) == (3, False)

    def test_interrupt_mideleg_hideleg_chain(self):
        with jax.experimental.enable_x64():
            # VSSI: mideleg VS bits are forced-one; hideleg decides HS vs VS
            c = _csrs(hideleg=C.IP_VSSIP)
            assert _route(c, 1, True, 2, True) == (1, True)    # → VS
            c = _csrs()
            assert _route(c, 1, True, 2, True) == (1, False)   # → HS
            # STI: mideleg clear → M; set → HS (never VS: hideleg WARL-0)
            c = _csrs()
            assert _route(c, 1, True, 5, True) == (3, False)
            c = _csrs(mideleg=C.MIDELEG_FORCED | C.IP_STIP)
            assert _route(c, 1, True, 5, True) == (1, False)


# ---------------------------------------------------------------------------
# the virtual CLINT: armed comparators drive mip, disarmed leave it alone
# ---------------------------------------------------------------------------

class TestAdvanceTimers:
    def test_disarmed_never_touches_mip(self):
        with jax.experimental.enable_x64():
            c = _csrs(mip=C.IP_SSIP)              # software-injected bit
            for _ in range(3):
                c = machine._advance_timers(c)
            assert int(c[C.R_MTIME]) == 3
            assert int(c[C.R_MIP]) == C.IP_SSIP   # untouched

    def test_armed_mtimecmp_sets_then_clears_mtip(self):
        with jax.experimental.enable_x64():
            c = _csrs(mtimecmp=2)
            c = machine._advance_timers(c)        # mtime=1 < 2
            assert int(c[C.R_MIP]) & C.IP_MTIP == 0
            c = machine._advance_timers(c)        # mtime=2 >= 2
            assert int(c[C.R_MIP]) & C.IP_MTIP
            # re-arming into the future clears the pending bit
            c = c.at[C.R_MTIMECMP].set(jnp.uint64(100))
            c = machine._advance_timers(c)
            assert int(c[C.R_MIP]) & C.IP_MTIP == 0

    def test_stimecmp_and_vstimecmp_drive_their_bits(self):
        with jax.experimental.enable_x64():
            c = _csrs(stimecmp=1, vstimecmp=2)
            c = machine._advance_timers(c)
            assert int(c[C.R_MIP]) & C.IP_STIP
            assert int(c[C.R_MIP]) & C.IP_VSTIP == 0
            c = machine._advance_timers(c)
            assert int(c[C.R_MIP]) & C.IP_VSTIP


# ---------------------------------------------------------------------------
# TLB privilege-context tags
# ---------------------------------------------------------------------------

class TestTlbPrivTags:
    def _mk(self, priv, sum_bit=False, mxr=False):
        return (jnp.asarray(priv, jnp.int32), jnp.asarray(sum_bit, bool),
                jnp.asarray(mxr, bool))

    def test_cross_priv_lookup_misses(self):
        with jax.experimental.enable_x64():
            t = TLB.init_tlb()
            virt = jnp.asarray(False, bool)
            p1 = self._mk(1)
            t = TLB.insert(t, jnp.uint64(0x5000), jnp.uint64(0x5000),
                           jnp.asarray(0, jnp.int32),
                           jnp.asarray(TLB.PERM_R, jnp.int32), virt, *p1)
            hit, _, ok = TLB.lookup(t, jnp.uint64(0x5000), virt,
                                    jnp.uint64(X.ACC_R), *p1)
            assert bool(hit) and bool(ok)
            # U-mode must not reuse the S-mode verdict
            hit, _, _ = TLB.lookup(t, jnp.uint64(0x5000), virt,
                                   jnp.uint64(X.ACC_R), *self._mk(0))
            assert not bool(hit)

    def test_sum_and_mxr_context_mismatch_misses(self):
        with jax.experimental.enable_x64():
            t = TLB.init_tlb()
            virt = jnp.asarray(False, bool)
            ctx = self._mk(1, sum_bit=True)
            t = TLB.insert(t, jnp.uint64(0x6000), jnp.uint64(0x6000),
                           jnp.asarray(0, jnp.int32),
                           jnp.asarray(TLB.PERM_R, jnp.int32), virt, *ctx)
            hit, _, _ = TLB.lookup(t, jnp.uint64(0x6000), virt,
                                   jnp.uint64(X.ACC_R), *self._mk(1))
            assert not bool(hit)                      # SUM flipped off
            hit, _, _ = TLB.lookup(t, jnp.uint64(0x6000), virt,
                                   jnp.uint64(X.ACC_R),
                                   *self._mk(1, sum_bit=True, mxr=True))
            assert not bool(hit)                      # MXR differs


# ---------------------------------------------------------------------------
# reserved PTE encodings + HLVX G-stage override (direct walker tests)
# ---------------------------------------------------------------------------

SV39 = C.ATP_MODE_SV39 << C.ATP_MODE_SHIFT


def _mem_with(entries):
    """Flat uint64 memory with {byte_addr: value} poked in."""
    mem = np.zeros((1 << 12,), dtype=np.uint64)   # 32 KiB
    for addr, val in entries.items():
        mem[addr // 8] = np.uint64(val & ((1 << 64) - 1))
    return jnp.asarray(mem)


def _pte(pa, perms):
    return ((pa >> 12) << 10) | perms


class TestReservedPte:
    def test_w_only_pte_faults_first_stage(self):
        """W=1,R=0 is reserved — previously walked through as a pointer."""
        with jax.experimental.enable_x64():
            P = X.PTE_V | X.PTE_W | X.PTE_A | X.PTE_D
            mem = _mem_with({
                0x1000: _pte(0x2000, X.PTE_V),            # L2 → L1
                0x2000: _pte(0x3000, X.PTE_V),            # L1 → L0
                0x3000 + 5 * 8: _pte(0x5000, P),          # reserved leaf
            })
            csrs = _csrs(satp=SV39 | (0x1000 >> 12))
            xr = X.translate(mem, csrs, jnp.asarray(1, jnp.int32),
                             jnp.asarray(False, bool), jnp.uint64(0x5000),
                             X.ACC_R)
            assert bool(xr.fault)
            assert int(xr.cause) == C.EXC_LPAGE_FAULT

    def test_w_only_nonleaf_position_faults(self):
        """A reserved encoding in a *non-leaf* slot must fault too, not be
        dereferenced as a next-level pointer."""
        with jax.experimental.enable_x64():
            mem = _mem_with({
                0x1000: _pte(0x2000, X.PTE_V | X.PTE_W),  # reserved pointer
                0x2000: _pte(0x3000, X.PTE_V),
                0x3000 + 5 * 8: _pte(0x5000, X.ALL_PERM_PTE),
            })
            csrs = _csrs(satp=SV39 | (0x1000 >> 12))
            xr = X.translate(mem, csrs, jnp.asarray(1, jnp.int32),
                             jnp.asarray(False, bool), jnp.uint64(0x5000),
                             X.ACC_X)
            assert bool(xr.fault)
            assert int(xr.cause) == C.EXC_IPAGE_FAULT

    def test_w_only_pte_faults_g_stage(self):
        with jax.experimental.enable_x64():
            P = X.PTE_V | X.PTE_W | X.PTE_U | X.PTE_A | X.PTE_D
            mem = _mem_with({
                0x1000: _pte(0x2000, X.PTE_V),
                0x2000: _pte(0x3000, X.PTE_V),
                0x3000 + 5 * 8: _pte(0x5000, P),
            })
            hgatp = jnp.uint64(SV39 | (0x1000 >> 12))
            xr = X.g_translate(mem, hgatp, jnp.uint64(0x5000),
                               jnp.uint64(X.ACC_R), jnp.asarray(False, bool))
            assert bool(xr.fault)
            assert int(xr.cause) == C.EXC_LGUEST_PAGE_FAULT


class TestHlvxGStage:
    def _setup(self, g_perms):
        """vsatp BARE, hgatp maps GPA 0x5000 with `g_perms`."""
        mem = _mem_with({
            0x1000: _pte(0x2000, X.PTE_V),
            0x2000: _pte(0x3000, X.PTE_V),
            0x3000 + 5 * 8: _pte(0x5000, g_perms),
            0x5000: 0xCAFE,
        })
        csrs = _csrs(hgatp=SV39 | (0x1000 >> 12))
        return mem, csrs

    def test_hlvx_reads_x_only_g_stage_page(self):
        """HLVX requires execute permission INSTEAD of read — at both
        stages.  An X-only G-stage page must satisfy it."""
        with jax.experimental.enable_x64():
            xonly = X.PTE_V | X.PTE_X | X.PTE_U | X.PTE_A | X.PTE_D
            mem, csrs = self._setup(xonly)
            xr = X.translate(mem, csrs, jnp.asarray(3, jnp.int32),
                             jnp.asarray(False, bool), jnp.uint64(0x5000),
                             X.ACC_R, force_virt=True, hlvx=True)
            assert not bool(xr.fault)
            assert int(xr.pa) == 0x5000
            # while a plain hlv load of the same page still faults …
            xr = X.translate(mem, csrs, jnp.asarray(3, jnp.int32),
                             jnp.asarray(False, bool), jnp.uint64(0x5000),
                             X.ACC_R, force_virt=True, hlvx=False)
            assert bool(xr.fault)
            assert int(xr.cause) == C.EXC_LGUEST_PAGE_FAULT

    def test_hlvx_faults_on_r_only_g_stage_page(self):
        with jax.experimental.enable_x64():
            ronly = X.PTE_V | X.PTE_R | X.PTE_U | X.PTE_A | X.PTE_D
            mem, csrs = self._setup(ronly)
            xr = X.translate(mem, csrs, jnp.asarray(3, jnp.int32),
                             jnp.asarray(False, bool), jnp.uint64(0x5000),
                             X.ACC_R, force_virt=True, hlvx=True)
            assert bool(xr.fault)
            # …reported with the original (load) access type
            assert int(xr.cause) == C.EXC_LGUEST_PAGE_FAULT

    def test_hlvx_implicit_walk_fault_reports_load_cause(self):
        """An hlvx whose VS-stage PTE *fetch* guest-faults must report the
        original (load) access type, not the execute override."""
        with jax.experimental.enable_x64():
            mem = np.zeros((1 << 13,), dtype=np.uint64)   # 64 KiB

            def poke(addr, val):
                mem[addr // 8] = np.uint64(val & ((1 << 64) - 1))
            # VS-stage tables at GPA 0x1000/0x2000/0x3000 → VA 0x5000
            poke(0x1000, _pte(0x2000, X.PTE_V))
            poke(0x2000, _pte(0x3000, X.PTE_V))
            poke(0x3000 + 5 * 8, _pte(0x5000, X.ALL_PERM_PTE))
            # G-stage (root 0x8000, Sv39x4) maps GPA 0x5000 but NOT the VS
            # page-table pages → the implicit PTE fetch guest-faults
            gp = X.PTE_V | X.PTE_R | X.PTE_W | X.PTE_X | X.PTE_U | \
                X.PTE_A | X.PTE_D
            poke(0x8000, _pte(0xC000, X.PTE_V))
            poke(0xC000, _pte(0xD000, X.PTE_V))
            poke(0xD000 + 5 * 8, _pte(0x5000, gp))
            csrs = _csrs(vsatp=SV39 | (0x1000 >> 12),
                         hgatp=SV39 | (0x8000 >> 12))
            xr = X.translate(jnp.asarray(mem), csrs,
                             jnp.asarray(3, jnp.int32),
                             jnp.asarray(False, bool), jnp.uint64(0x5000),
                             X.ACC_R, force_virt=True, hlvx=True)
            assert bool(xr.fault) and bool(xr.implicit)
            assert int(xr.cause) == C.EXC_LGUEST_PAGE_FAULT   # not I-GPF
