"""Fleet-as-a-service control plane (ISSUE 10, DESIGN.md §8).

Quick tests (CI push gate, ``-m serve`` selects the family):

* policy unit tests — admission capacity, FFD bin-packing with tenant
  anti-affinity and parked-slot reservations, shed/victim decisions,
* per-guest checkpoint atomicity (kill-mid-write leaves the previous
  file intact) and schema validation,
* ``None``-slot scheduler boots (reserved holes) hit the same goldens,
* the golden invariant: daemon-served workloads finish with counters
  bit-identical to direct ``Fleet.boot`` runs (native, guest, and an
  N=2 preemptive pod),
* evict → park → resume round-trips bit-identically under capacity
  pressure,
* migration-based shed preserves goldens (N=3 pod),
* an injected hart failure (pod and solo) recovers from the last
  per-lane snapshot with zero lost completed work.

Slow tests (nightly): a seeded 64-submission open-loop soak with a
mid-soak hart failure — every checksum matches the registry goldens.

All quick sim tests standardize on (B=2 lanes, 32768 mem words,
chunk=512): the N=2 scheduler layout and the solo layout share one
memory size, so every pool compiles a single XLA executable.
"""
import dataclasses
import os
import pathlib

import numpy as np
import pytest

from repro.core.hext import checkpoint, programs
from repro.core.hext.policies import (BinPackPolicy, JobView, LaneView,
                                      size_bucket, workload_footprint)
from repro.core.hext.service import (DONE, QUEUED, REJECTED,
                                     FleetService, ServiceError)
from repro.core.hext.sim import (Fleet, HartSpec, HartState, MASK64,
                                 checksum_ok)

pytestmark = pytest.mark.serve

BY_NAME = {w.name: w for w in programs.WORKLOADS + programs.WORKLOADS_EXTRA}
CHUNK = 512
SLICE = 2048


def _svc(tmp_path, **kw):
    kw.setdefault("n_harts", 2)
    kw.setdefault("guests_per_hart", 2)
    kw.setdefault("timeslice", 300)
    kw.setdefault("slice_ticks", SLICE)
    kw.setdefault("chunk", CHUNK)
    kw.setdefault("snapshot_dir", str(tmp_path / "snaps"))
    return FleetService(**kw)


# ---------------------------------------------------------------------------
# policy units (no simulation)
# ---------------------------------------------------------------------------

def test_admission_rejects_over_capacity(tmp_path):
    svc = _svc(tmp_path, policy=BinPackPolicy(max_queue=2))
    sha = BY_NAME["sha"]
    ids = [svc.submit(sha, tenant=t) for t in range(3)]
    assert [svc.job(i).state for i in ids] == [QUEUED, QUEUED, REJECTED]
    assert svc.job(ids[2]).ok is False
    assert svc.stats["rejected"] == 1
    # terminal rejection never blocks drain
    assert svc.job(ids[2]).terminal


def test_binpack_ffd_and_tenant_anti_affinity():
    pol = BinPackPolicy(partial_after=2)
    # two tenants, four jobs, mixed weights: heavy jobs seed cohorts
    # first and one tenant's jobs spread across cohorts
    q = [JobView(0, tenant=7, name="a", weight=0, age=0),
         JobView(1, tenant=7, name="b", weight=2, age=0),
         JobView(2, tenant=8, name="c", weight=2, age=0),
         JobView(3, tenant=8, name="d", weight=0, age=0)]
    cohorts = pol.pack(q, n_lanes=2, slots=2)
    assert cohorts == [[1, 2], [0, 3]] or cohorts == [[1, 2], [3, 0]]
    tenants = [{q[j].tenant for j in c} for c in cohorts]
    assert all(len(t) == 2 for t in tenants)   # never two of one tenant


def test_binpack_partial_cohorts_wait_then_boot():
    pol = BinPackPolicy(partial_after=2)
    young = [JobView(0, tenant=0, name="a", weight=0, age=0)]
    assert pol.pack(young, n_lanes=1, slots=2) == []
    old = [JobView(0, tenant=0, name="a", weight=0, age=2)]
    assert pol.pack(old, n_lanes=1, slots=2) == [[0, None]]


def test_binpack_reserved_slot_held_for_parked_guest():
    pol = BinPackPolicy(partial_after=0)
    q = [JobView(0, tenant=0, name="a", weight=0, age=5),
         JobView(1, tenant=1, name="b", weight=0, age=5)]
    cohorts = pol.pack(q, n_lanes=1, slots=2, reserved=[1])
    assert cohorts == [[0, None]]              # slot 1 stays open
    cohorts = pol.pack(q, n_lanes=2, slots=2, reserved=[0])
    assert cohorts[0] == [None, 0]             # first cohort holds slot 0
    assert 1 in cohorts[1]


def test_policy_shed_and_victim_decisions():
    pol = BinPackPolicy(shed_margin=2)
    hot = LaneView(lane=0, jobs=(10, 11, 12), free_slots=())
    cool = LaneView(lane=1, jobs=(13, None, None), free_slots=(1, 2))
    dec = pol.shed([hot, cool])
    assert (dec.src, dec.dst) == (0, 1) and dec.slot in (1, 2)
    # margin not met -> no shed
    assert pol.shed([hot, LaneView(1, (13, 14, None), (2,))]) is None
    # victim: youngest job on the most-loaded lane; never empties a hart
    lane, slot = pol.victim([hot, cool])
    assert (lane, slot) == (0, 2)              # job_id 12 is youngest
    assert pol.victim([LaneView(0, (5, None), (1,))]) is None


def test_size_buckets_span_registry():
    buckets = {w.name: size_bucket(workload_footprint(w))
               for w in programs.WORKLOADS}
    assert set(buckets.values()) == {0, 1, 2}  # registry spans all buckets
    assert buckets["sha"] == 0 and buckets["fft"] == 2


# ---------------------------------------------------------------------------
# checkpoint atomicity + guest-checkpoint schema
# ---------------------------------------------------------------------------

def _guest_regions(n=2, slot=0):
    lay = programs.sched_layout(n)
    return {name: np.full(size >> 3, 7, np.uint64)
            for name, (base, size) in zip(
                checkpoint.GUEST_REGIONS, programs.guest_regions(lay, slot))}


def test_guest_checkpoint_roundtrip(tmp_path):
    path = str(tmp_path / "g.npz")
    regions = _guest_regions()
    out = checkpoint.save_guest(path, regions, n=2, slot=0,
                                timeslice=300, workload="sha")
    got, meta = checkpoint.load_guest(out)
    assert meta["n"] == 2 and meta["slot"] == 0
    assert meta["workload"] == "sha" and meta["timeslice"] == 300
    for name in checkpoint.GUEST_REGIONS:
        np.testing.assert_array_equal(got[name], regions[name])


def test_atomic_write_kill_mid_write_keeps_old_file(tmp_path, monkeypatch):
    path = str(tmp_path / "g.npz")
    checkpoint.save_guest(path, _guest_regions(), n=2, slot=0)
    before = pathlib.Path(path).read_bytes()

    real = checkpoint.np.savez_compressed

    def dying_savez(fh, **arrays):
        real(fh, **arrays)                     # bytes hit the temp file
        raise KeyboardInterrupt("killed mid-write")

    monkeypatch.setattr(checkpoint.np, "savez_compressed", dying_savez)
    with pytest.raises(KeyboardInterrupt):
        checkpoint.save_guest(path, _guest_regions(), n=2, slot=1)
    monkeypatch.undo()
    # the original file is untouched and still loads; no temp debris
    assert pathlib.Path(path).read_bytes() == before
    regions, meta = checkpoint.load_guest(path)
    assert meta["slot"] == 0
    assert [p.name for p in tmp_path.iterdir()] == ["g.npz"]


def test_guest_checkpoint_validation(tmp_path):
    bad = _guest_regions()
    bad.pop("gtab")
    with pytest.raises(checkpoint.CheckpointError):
        checkpoint.save_guest(str(tmp_path / "a.npz"), bad, n=2, slot=0)
    wrong = _guest_regions()
    wrong["ctx"] = wrong["ctx"][:-1]
    with pytest.raises(checkpoint.CheckpointError):
        checkpoint.save_guest(str(tmp_path / "b.npz"), wrong, n=2, slot=0)
    # a fleet checkpoint is not a guest checkpoint
    st = HartState.fresh(1024)
    checkpoint.save(str(tmp_path / "fleet.npz"), st,
                    [HartSpec(None, False, "vacant")])
    with pytest.raises(checkpoint.CheckpointError):
        checkpoint.load_guest(str(tmp_path / "fleet.npz"))


# ---------------------------------------------------------------------------
# simulation: reserved holes, daemon-vs-direct identity
# ---------------------------------------------------------------------------

def test_none_slot_boot_hits_goldens():
    sha, fft = BY_NAME["sha"], BY_NAME["fft"]
    fleet = Fleet.boot([(sha, None), (None, fft)], guests_per_hart=2,
                       timeslice=300)
    fleet.run(80000, chunk=CHUNK)
    harts = fleet.harts.unwrap()
    assert bool(np.asarray(harts.counters.done).all())
    lay = programs.sched_layout(2)
    mem = np.asarray(harts.mem)
    res = lambda h, s: int(mem[h, (lay.guest_res + 8 * s) >> 3]) & MASK64
    assert checksum_ok(res(0, 0), sha.golden())
    assert res(0, 1) == 0                      # dead slot never reports
    assert checksum_ok(res(1, 1), fft.golden())
    assert res(1, 0) == 0


def test_daemon_matches_direct_bit_identical(tmp_path):
    """The golden invariant for native, guest, and N=2 preemptive pods:
    a whole-cohort lane served by the daemon ends with counters (every
    field) bit-identical to a direct ``Fleet.boot`` of the same group."""
    wl = {k: BY_NAME[k] for k in ("fft", "sha", "crc32", "stringsearch")}
    svc = _svc(tmp_path, n_solo=2, policy=BinPackPolicy(partial_after=0))
    vm_ids = [svc.submit(w, tenant=t) for t, w in enumerate(wl.values())]
    nat = svc.submit(BY_NAME["sha"], tenant=8, mode="native")
    gst = svc.submit(BY_NAME["fft"], tenant=9, mode="guest")
    svc.step()                                 # everything places round 0
    placed = {(svc.job(i).lane, svc.job(i).slot): svc.job(i).workload
              for i in vm_ids}
    groups = [tuple(placed[(lane, s)] for s in range(2)) for lane in (0, 1)]
    solo_order = [svc.job(nat).lane, svc.job(gst).lane]
    assert svc.drain(200)
    assert svc.stats["completed"] == 6 and svc.stats["failed"] == 0

    direct = Fleet.boot(groups, guests_per_hart=2, timeslice=300)
    while not bool(np.asarray(direct.harts.unwrap().counters.done).all()):
        direct.run(SLICE, chunk=CHUNK)
    got = svc._pod.harts.unwrap().counters
    want = direct.harts.unwrap().counters
    for field in dataclasses.fields(want):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field.name)),
            np.asarray(getattr(want, field.name)), err_msg=field.name)

    # solo lanes: rebuild the same native/guest boots directly
    d_nat = Fleet.boot([BY_NAME["sha"], BY_NAME["fft"]],
                       guest=[False, True])
    while not bool(np.asarray(d_nat.harts.unwrap().counters.done).all()):
        d_nat.run(SLICE, chunk=CHUNK)
    sg = svc._solo.harts.unwrap().counters
    dg = d_nat.harts.unwrap().counters
    for field in dataclasses.fields(dg):
        np.testing.assert_array_equal(
            np.asarray(getattr(sg, field.name))[solo_order],
            np.asarray(getattr(dg, field.name)), err_msg=field.name)
    for i in (nat, gst):
        assert svc.job(i).ok


# ---------------------------------------------------------------------------
# simulation: control-plane maneuvers
# ---------------------------------------------------------------------------

def test_evict_park_resume_roundtrip(tmp_path):
    """Capacity pressure parks the youngest guest as a checkpoint; the
    queued job lands once a lane drains; the parked guest resumes into
    a reserved slot and still reaches its registry golden."""
    svc = _svc(tmp_path, policy=BinPackPolicy(partial_after=1))
    for t, name in enumerate(["qsort", "bitcount", "dijkstra", "susan"]):
        svc.submit(BY_NAME[name], tenant=t)
    svc.step()
    late = svc.submit(BY_NAME["sha"], tenant=4)
    assert svc.drain(400)
    assert svc.stats["parks"] >= 1 and svc.stats["resumes"] >= 1
    assert svc.stats["completed"] == 5 and svc.stats["failed"] == 0
    parked = [j for j in svc.jobs() if any("parked" in e for e in j.events)]
    assert parked and all(j.ok for j in parked)
    assert any("resumed" in e for j in parked for e in j.events)
    assert svc.job(late).ok


def test_shed_migration_preserves_goldens(tmp_path):
    """N=3 pod: a partially-packed hot lane sheds a guest to the cool
    lane via live migration; every checksum still matches."""
    svc = _svc(tmp_path, guests_per_hart=3,
               policy=BinPackPolicy(partial_after=1, shed_margin=2))
    for t, name in enumerate(["susan", "dijkstra", "bitcount"]):
        svc.submit(BY_NAME[name], tenant=t)
    svc.step()                                 # full cohort on lane 0
    svc.submit(BY_NAME["qsort"], tenant=3)     # partial cohort on lane 1
    assert svc.drain(400)
    assert svc.stats["migrations"] >= 1
    assert svc.stats["completed"] == 4 and svc.stats["failed"] == 0
    moved = [j for j in svc.jobs() if any("migrated" in e for e in j.events)]
    assert moved and all(j.ok for j in moved)


def test_injected_hart_failure_recovers_from_snapshot(tmp_path):
    """Kill a pod lane and a solo lane mid-run: the progress monitor
    flags the stall, recovery restores the last healthy snapshot, and
    every affected guest still reaches its golden (zero lost work)."""
    svc = _svc(tmp_path, n_solo=2, snapshot_every=3, fail_after=2)
    for t, name in enumerate(["qsort", "bitcount", "dijkstra", "susan"]):
        svc.submit(BY_NAME[name], tenant=t)
    svc.submit(BY_NAME["dijkstra"], tenant=9, mode="native")
    for _ in range(4):
        svc.step()
    svc.inject_hart_failure(0, pool="pod")
    svc.inject_hart_failure(0, pool="solo")
    for _ in range(2 + svc.fail_after):
        svc.step()
    assert svc.stats["recoveries"] >= 2
    assert svc.drain(400)
    assert svc.stats["failed"] == 0
    touched = [j for j in svc.jobs()
               if any("recovered" in e for e in j.events)]
    assert touched and all(j.ok for j in touched)


def test_recovery_without_snapshot_raises(tmp_path):
    svc = _svc(tmp_path, snapshot_every=10_000, fail_after=1)
    svc.submit(BY_NAME["qsort"], tenant=0)
    svc.submit(BY_NAME["bitcount"], tenant=1)
    svc.step()
    # wipe the mutation-time snapshot, then kill the lane
    for p in pathlib.Path(svc._snapshot_dir).glob("pod-lane*.npz"):
        p.unlink()
    svc.inject_hart_failure(0, pool="pod")
    with pytest.raises(ServiceError):
        for _ in range(4):
            svc.step()


def test_stragglers_surface_stalled_lanes(tmp_path):
    svc = _svc(tmp_path, fail_after=10)        # observe but never recover
    svc.submit(BY_NAME["qsort"], tenant=0)
    svc.submit(BY_NAME["bitcount"], tenant=1)
    svc.step()
    svc.inject_hart_failure(0, pool="pod")
    svc.step()
    svc.step()
    assert ("pod", 0, svc._pod_mon.stall[0]) in svc.stragglers()


# ---------------------------------------------------------------------------
# slow: the seeded open-loop soak
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_soak_64_submissions_all_goldens(tmp_path):
    """Drain a seeded 64-submission arrival trace (every registry
    workload, three modes, eight tenants) with one injected hart
    failure mid-soak; every checksum matches its registry golden."""
    rng = np.random.default_rng(1234)
    reg = programs.WORKLOADS
    svc = _svc(tmp_path, n_harts=4, n_solo=2, snapshot_every=3,
               policy=BinPackPolicy(max_queue=64, partial_after=2))
    arrivals = np.cumsum(rng.exponential(1.5, size=64)).astype(int)
    modes = ["vm"] * 6 + ["native", "guest"]
    k = 0
    failed_once = False
    while k < len(arrivals) or any(not j.terminal for j in svc.jobs()):
        while k < len(arrivals) and arrivals[k] <= svc.slices:
            w = reg[int(rng.integers(len(reg)))]
            m = modes[int(rng.integers(len(modes)))]
            svc.submit(w, tenant=int(rng.integers(8)), mode=m)
            k += 1
        if not failed_once and svc.slices >= 40:
            lanes = [i for i, l in enumerate(svc._pod_lanes) if l.active]
            if lanes:
                svc.inject_hart_failure(lanes[-1], pool="pod")
                failed_once = True
        svc.step()
        assert svc.slices < 5000, "soak failed to drain"
    assert failed_once and svc.stats["recoveries"] >= 1
    done = [j for j in svc.jobs() if j.state == DONE]
    assert len(done) == 64 - svc.stats["rejected"]
    assert all(j.ok for j in done)
    m = svc.metrics()
    assert m["p99_ttr_slices"] >= m["p50_ttr_slices"] > 0
