"""Randomized property tests on the CSR file invariants (paper §3.1):
WARL write masks, read-only fields, aliasing coherence, VS swapping.

Seeded ``numpy.random.Generator`` + ``pytest.mark.parametrize`` instead of
hypothesis (absent from the CI container, which used to skip this file
silently).  Case counts are kept small for the push gate; the values are
deterministic, so a failure's ``case`` index is directly reproducible.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hext import csr as C

N_CASES = 16


def _vals(test_tag: str, n: int = N_CASES):
    """Deterministic per-test stream of u64 values (seeded by the test
    name so adding a test never reshuffles another's cases)."""
    rng = np.random.Generator(np.random.PCG64(
        np.random.SeedSequence([0xC54] + list(test_tag.encode()))))
    vals = rng.integers(0, 1 << 64, size=n, dtype=np.uint64)
    # always include the classic corner values
    vals[0], vals[1] = 0, (1 << 64) - 1
    return [int(v) for v in vals]


def _csrs():
    with jax.experimental.enable_x64():
        return C.init_csrs()


def _rw(csrs, addr, value, priv=3, virt=False):
    with jax.experimental.enable_x64():
        new, ok, vinst = C.csr_write(
            csrs, jnp.asarray(addr, jnp.int32),
            jnp.asarray(value, jnp.uint64),
            jnp.asarray(priv, jnp.int32), jnp.asarray(virt, bool))
        return new, bool(ok), bool(vinst)


def _rd(csrs, addr, priv=3, virt=False):
    with jax.experimental.enable_x64():
        val, ok, vinst = C.csr_read(
            csrs, jnp.asarray(addr, jnp.int32),
            jnp.asarray(priv, jnp.int32), jnp.asarray(virt, bool))
        return int(val), bool(ok), bool(vinst)


@pytest.mark.parametrize("v", _vals("mideleg"))
def test_mideleg_vs_bits_forced_one(v):
    """Paper: 'new read-only 1-bit fields for VS and guest external
    interrupts' — writes can never clear them."""
    new, ok, _ = _rw(_csrs(), 0x303, v)
    got = int(new[C.R_MIDELEG])
    assert got & C.HS_INTERRUPTS == C.HS_INTERRUPTS
    # and only S-interrupt bits are writable
    assert got & ~(C.HS_INTERRUPTS | C.S_INTERRUPTS) == 0


@pytest.mark.parametrize("v", _vals("hvip"))
def test_hvip_writes_only_vs_bits_and_alias_mip(v):
    new, ok, _ = _rw(_csrs(), 0x645, v)
    mip = int(new[C.R_MIP])
    # only the VS bits can have changed, and hvip reads back == those bits
    assert mip & ~C.VS_INTERRUPTS == 0
    rd, _, _ = _rd(new, 0x645)
    assert rd == mip & C.VS_INTERRUPTS


@pytest.mark.parametrize("v", _vals("hedeleg"))
def test_hedeleg_cannot_delegate_guest_faults(v):
    """hedeleg must never delegate guest-page-faults / ecall-VS to VS."""
    new, _, _ = _rw(_csrs(), 0x602, v)
    got = int(new[C.R_HEDELEG])
    for bit in (C.EXC_IGUEST_PAGE_FAULT, C.EXC_LGUEST_PAGE_FAULT,
                C.EXC_SGUEST_PAGE_FAULT, C.EXC_VIRTUAL_INSTRUCTION,
                C.EXC_ECALL_VS, C.EXC_ECALL_M, C.EXC_ECALL_S):
        assert not (got >> bit) & 1


@pytest.mark.parametrize("v", _vals("vs_swap", 12))
def test_vs_swap_sstatus_redirects(v):
    """With V=1, sstatus writes hit vsstatus; mstatus untouched."""
    base = _csrs()
    m_before = int(base[C.R_MSTATUS])
    new, ok, vinst = _rw(base, 0x100, v, priv=1, virt=True)
    assert not vinst and ok
    assert int(new[C.R_MSTATUS]) == m_before
    assert int(new[C.R_VSSTATUS]) & ~C.SSTATUS_MASK == 0


@pytest.mark.parametrize("v", _vals("vsip", 12))
def test_vsip_shifted_alias_roundtrip(v):
    """vsip.SSIP ↔ mip.VSSIP (shifted-by-1 alias), gated by hideleg."""
    base, _, _ = _rw(_csrs(), 0x603, C.VS_INTERRUPTS)   # hideleg all VS
    new, ok, _ = _rw(base, 0x244, v, priv=1, virt=False)
    mip = int(new[C.R_MIP])
    want_vssip = bool(v & C.IP_SSIP)
    assert bool(mip & C.IP_VSSIP) == want_vssip
    rd, _, _ = _rd(new, 0x244)
    assert bool(rd & C.IP_SSIP) == want_vssip


def test_h_csrs_fault_virtual_from_vs():
    for addr in (0x600, 0x602, 0x603, 0x645, 0x680, 0xE12, 0x200, 0x280):
        _, ok, vinst = _rd(_csrs(), addr, priv=1, virt=True)
        assert vinst, hex(addr)
    # and are fine from HS
    for addr in (0x600, 0x602, 0x603, 0x645, 0x680):
        _, ok, vinst = _rd(_csrs(), addr, priv=1, virt=False)
        assert ok and not vinst, hex(addr)


def test_mepc_low_bit_warl():
    new, _, _ = _rw(_csrs(), 0x341, 0x1003)
    assert int(new[C.R_MEPC]) == 0x1002       # bit 0 forced clear


@pytest.mark.parametrize("v", _vals("plain_rw", 8))
def test_plain_csr_write_read_roundtrip(v):
    for addr, idx in ((0x305, C.R_MTVEC), (0x340, C.R_MSCRATCH),
                      (0x643, C.R_HTVAL), (0x680, C.R_HGATP)):
        new, ok, _ = _rw(_csrs(), addr, v)
        assert ok
        rd, ok2, _ = _rd(new, addr)
        assert ok2 and rd == int(new[idx])


@pytest.mark.parametrize("v", _vals("oracle_csr", 12))
def test_csr_file_matches_oracle(v):
    """Differential micro-check: the pure-Python oracle CSR file (DESIGN.md
    §5) agrees with the JAX one on random writes + reads across modes."""
    from repro.core.hext import oracle
    for addr in (0x300, 0x100, 0x104, 0x144, 0x303, 0x602, 0x645, 0x14D,
                 0x605, 0x680):
        for priv, virt in ((3, False), (1, False), (1, True), (0, False)):
            jnew, jok, jvi = _rw(_csrs(), addr, v, priv, virt)
            onew, ook, ovi = oracle.csr_write(
                oracle.init_csrs(), addr, v, priv, virt)
            assert (jok, jvi) == (ook, ovi), (hex(addr), priv, virt)
            with jax.experimental.enable_x64():   # u64 host reads need x64
                jlist = [int(x) for x in np.asarray(jnew)]
            assert jlist == onew, (hex(addr), priv, virt)
            jv, jok, jvi = _rd(jnew, addr, priv, virt)
            ov, ook, ovi = oracle.csr_read(onew, addr, priv, virt)
            assert (jv, jok, jvi) == (ov, ook, ovi), (hex(addr), priv, virt)
