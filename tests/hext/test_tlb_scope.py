"""Scoped fence semantics (DESIGN.md §5): `sfence.vma` / `hfence.vvma`
with rs1 ≠ x0 must drop only the entries covering that VA page, in both
the machine's software TLB and the oracle's mirror of it.  rs1 = x0
stays the conservative full-class flush; superpage entries match (and
are dropped) by their level mask.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hext import oracle
from repro.core.hext import tlb as TLB


def _count_valid(t):
    return int(np.sum(np.asarray(t["valid"])))


def _mk_machine_tlb():
    t = TLB.init_tlb()
    # two native 4K pages, one guest 4K page, one native 2M superpage
    t = TLB.insert(t, 0x3000, 0x3000, 0, 7, False, 1, False, False)
    t = TLB.insert(t, 0x4000, 0x4000, 0, 7, False, 1, False, False)
    t = TLB.insert(t, 0x3000, 0x8000, 0, 7, True, 1, False, False)
    t = TLB.insert(t, 0x200000, 0x400000, 1, 7, False, 1, False, False)
    return t


def test_machine_flush_va_scoped_native():
    with jax.experimental.enable_x64():
        t = _mk_machine_tlb()
        out = TLB.flush(t, native_only=True, va=0x3000)
        # only the native 0x3000 entry drops: guest 0x3000 and native
        # 0x4000 and the superpage all survive
        assert _count_valid(out) == 3
        v = np.asarray(out["valid"])[:4]
        assert list(v) == [False, True, True, True]


def test_machine_flush_va_matches_superpage_by_level():
    with jax.experimental.enable_x64():
        t = _mk_machine_tlb()
        # any VA inside the 2M superpage selects it via the level mask
        out = TLB.flush(t, native_only=True, va=0x200000 + 0x5A000)
        v = np.asarray(out["valid"])[:4]
        assert list(v) == [True, True, True, False]


def test_machine_flush_full_class_without_va():
    with jax.experimental.enable_x64():
        t = _mk_machine_tlb()
        out = TLB.flush(t, native_only=True)
        v = np.asarray(out["valid"])[:4]
        assert list(v) == [False, False, True, False]
        out = TLB.flush(t, guest_only=True)
        v = np.asarray(out["valid"])[:4]
        assert list(v) == [True, True, False, True]


def test_machine_flush_where_addr_conditions():
    with jax.experimental.enable_x64():
        t = _mk_machine_tlb()
        zb = jnp.asarray(False)
        tb = jnp.asarray(True)
        # scoped guest-class flush of VA 0x3000: only the guest entry
        out = TLB.flush_where(t, zb, zb, cond_guest_addr=tb,
                              cond_native_addr=zb, va=jnp.asarray(0x3000))
        v = np.asarray(out["valid"])[:4]
        assert list(v) == [True, True, False, True]
        # scoped native-class flush of the same VA: only the native one
        out = TLB.flush_where(t, zb, zb, cond_guest_addr=zb,
                              cond_native_addr=tb, va=jnp.asarray(0x3000))
        v = np.asarray(out["valid"])[:4]
        assert list(v) == [False, True, True, True]
        # full-class conditions ignore the VA
        out = TLB.flush_where(t, tb, tb)
        assert _count_valid(out) == 0


def _mk_oracle_tlb():
    t = oracle.init_tlb()
    for i, (vpn, guest, level) in enumerate(
            ((0x3, False, 0), (0x4, False, 0), (0x3, True, 0),
             (0x200, False, 1))):
        t["vpn"][i] = vpn
        t["ppn"][i] = vpn + 0x10
        t["level"][i] = level
        t["perm"][i] = 7
        t["guest"][i] = guest
        t["priv"][i] = 1
        t["valid"][i] = True
    t["ptr"] = 4
    return t


def test_oracle_flush_mirrors_machine_scoping():
    t = _mk_oracle_tlb()
    oracle.tlb_flush(t, native=True, va=0x3000)
    assert t["valid"][:4] == [False, True, True, True]
    t = _mk_oracle_tlb()
    oracle.tlb_flush(t, guest=True, va=0x3000)
    assert t["valid"][:4] == [True, True, False, True]
    t = _mk_oracle_tlb()
    # superpage match by level mask (VA inside the 2M region)
    oracle.tlb_flush(t, native=True, va=0x200000 + 0x1F000)
    assert t["valid"][:4] == [True, True, True, False]
    t = _mk_oracle_tlb()
    oracle.tlb_flush(t, guest=True, native=True)
    assert t["valid"][:4] == [False, False, False, False]


def test_oracle_lookup_respects_context_tags():
    t = _mk_oracle_tlb()
    hit, pa, ok = oracle.tlb_lookup(t, 0x3008, False, oracle.ACC_R, 1,
                                    False, False)
    assert hit and ok and pa == 0x13008
    # virt mismatch → miss the native entry, hit the guest one
    hit, pa, ok = oracle.tlb_lookup(t, 0x3008, True, oracle.ACC_R, 1,
                                    False, False)
    assert hit and pa == 0x13008
    # priv mismatch → miss entirely
    hit, _, _ = oracle.tlb_lookup(t, 0x3008, False, oracle.ACC_R, 0,
                                  False, False)
    assert not hit


def _run_pte_swap(fence_va, engine="oracle"):
    """S-mode Sv39 program: warm VA 0x3000 (reads 0xBBBB), rewrite its
    live L0 PTE to alias PA 0x2000 (holds 0xAAAA), sfence.vma scoped to
    `fence_va`, reload, ecall to M which exits with the loaded value.

    The exit code is the architectural observable: a fence that covers
    0x3000 forces a fresh walk (0xAAAA); a fence scoped to a different
    page must leave the warm entry alone (stale 0xBBBB)."""
    from repro.core.hext.programs import (Asm, Image, MEM_WORDS, P_KERN,
                                          S_L0, S_L1, S_L2, SATP_SV39)
    from repro.core.hext.sim import Fleet

    a = Asm(0)
    a.li("t0", 0x100)
    a.csrw(0x305, "t0")                      # mtvec → exit handler
    a.li("t0", SATP_SV39 | (S_L2 >> 12))
    a.csrw(0x180, "t0")                      # satp (inert in M)
    a.li("t0", 1 << 11)                      # MPP = S
    a.csrrs(0, 0x300, "t0")
    a.li("t0", 0x200)
    a.csrw(0x341, "t0")
    a.mret()
    a.pad_to(0x100)
    a.li("t6", 0x10000008)                   # M handler: exit with t3
    a.sd("t3", 0, "t6")
    a.label("spin")
    a.j("spin")
    a.pad_to(0x200)
    a.li("t2", 0x3000)
    a.ld("t3", 0, "t2")                      # warm walk: t3 = 0xBBBB
    a.li("t0", S_L0 + 3 * 8)                 # live L0 PTE for VA 0x3000
    a.li("t1", ((0x2000 >> 12) << 10) | P_KERN)
    a.sd("t1", 0, "t0")                      # now maps to PA 0x2000
    a.li("t5", fence_va)
    a.sfence_vma(rs1="t5")
    a.ld("t3", 0, "t2")                      # stale hit or fresh walk
    a.ecall()

    img = Image(MEM_WORDS)
    img.place_code(0, a.assemble())
    img.link(S_L2, 0, S_L1)
    img.link(S_L1, 0, S_L0)
    for page in range(0, 0xB000, 0x1000):    # code+data+table pages
        img.map_page(S_L0, page, page, P_KERN)
    img.store64(0x2000, 0xAAAA)
    img.store64(0x3000, 0xBBBB)

    if engine == "oracle":
        st = oracle.run(img.mem, 512)
        assert st["done"]
        return int(st["exit_code"])
    fleet = Fleet.from_images([img.mem], mem_words=MEM_WORDS)
    fleet.run(512, chunk=512)
    st = fleet[0]
    assert bool(st.counters.done)
    return int(st.counters.exit_code)


@pytest.mark.parametrize("engine", ["oracle", "machine"])
def test_scoped_fence_preserves_sibling_entries_end_to_end(engine):
    # fence scoped to a *different* page: warm entry survives → stale pa
    assert _run_pte_swap(0x2000, engine) == 0xBBBB
    # fence scoped to the rewritten page: fresh walk sees the new PTE
    assert _run_pte_swap(0x3000, engine) == 0xAAAA


@pytest.mark.parametrize("case", [5, 23])
def test_scoped_fence_machine_matches_oracle(case):
    """The corpus path exercises scoped fences randomly; this pins one
    fuzz case and one sched case through both models as a cheap
    deterministic anchor."""
    from repro.core.hext import torture
    s = torture.gen_scenario(torture.DEFAULT_SEED, case)
    mw = torture._fleet_words(s.image)
    mach = torture._run_corpus_fleet([s], s.max_ticks, torture.CHUNK,
                                     mem_words=mw)
    ost = oracle.run(torture._pad_image(s.image, mw), s.max_ticks)
    assert torture.diff_case(mach, 0, ost) == []
