"""Property tests: RV64 arithmetic helper semantics vs Python golden models
(division/remainder/mulh corner cases are classic simulator bugs)."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.hext import isa

I64_MIN = -(1 << 63)
u64s = st.integers(0, (1 << 64) - 1)
i64s = st.integers(I64_MIN, (1 << 63) - 1)


def _u(x):
    with jax.experimental.enable_x64():
        return jnp.asarray(x % (1 << 64), jnp.uint64)


def _as_i64(u):
    u = int(u) & ((1 << 64) - 1)
    return u - (1 << 64) if u >= (1 << 63) else u


def _as_u64(i):
    return i & ((1 << 64) - 1)


@settings(max_examples=40, deadline=None)
@given(a=i64s, b=i64s)
def test_divs_matches_riscv_semantics(a, b):
    with jax.experimental.enable_x64():
        got = _as_i64(isa.divs(_u(a), _u(b)))
    if b == 0:
        want = -1
    elif a == I64_MIN and b == -1:
        want = I64_MIN
    else:
        want = int(abs(a) // abs(b))
        if (a < 0) != (b < 0):
            want = -want
    assert got == want, (a, b)


@settings(max_examples=40, deadline=None)
@given(a=i64s, b=i64s)
def test_rems_matches_riscv_semantics(a, b):
    with jax.experimental.enable_x64():
        got = _as_i64(isa.rems(_u(a), _u(b)))
    if b == 0:
        want = a
    elif a == I64_MIN and b == -1:
        want = 0
    else:
        want = int(abs(a) % abs(b))
        if a < 0:
            want = -want
    assert got == want, (a, b)


@settings(max_examples=40, deadline=None)
@given(a=u64s, b=u64s)
def test_mulhu_matches_python(a, b):
    with jax.experimental.enable_x64():
        got = int(isa.mulhu(_u(a), _u(b)))
    assert got == (a * b) >> 64


@settings(max_examples=40, deadline=None)
@given(a=i64s, b=i64s)
def test_mulh_matches_python(a, b):
    with jax.experimental.enable_x64():
        got = _as_i64(isa.mulh(_u(_as_u64(a)), _u(_as_u64(b))))
    assert got == (a * b) >> 64


@settings(max_examples=40, deadline=None)
@given(a=i64s, b=u64s)
def test_mulhsu_matches_python(a, b):
    with jax.experimental.enable_x64():
        got = _as_i64(isa.mulhsu(_u(_as_u64(a)), _u(b)))
    assert got == (a * b) >> 64


@settings(max_examples=30, deadline=None)
@given(v=u64s, bits=st.sampled_from([8, 12, 16, 32]))
def test_sext_matches_python(v, bits):
    with jax.experimental.enable_x64():
        got = _as_i64(isa.sext(_u(v), bits))
    low = v & ((1 << bits) - 1)
    want = low - (1 << bits) if low >= (1 << (bits - 1)) else low
    assert got == want


@settings(max_examples=20, deadline=None)
@given(val=u64s, off=st.integers(0, 7).map(lambda x: x & ~0),
       size=st.sampled_from([0, 1, 2, 3]))
def test_mem_write_read_roundtrip(val, off, size):
    nbytes = 1 << size
    off = (off // nbytes) * nbytes          # naturally aligned
    with jax.experimental.enable_x64():
        mem = jnp.zeros((4,), jnp.uint64)
        mem = isa.mem_write(mem, _u(8 + off), _u(val), size)
        rd = int(isa.mem_read(mem, _u(8 + off), size,
                              jnp.asarray(True)))  # unsigned read
    assert rd == val & ((1 << (8 * nbytes)) - 1)


def test_assembler_encodings_golden():
    """Spot-check assembler encodings against known-good golden words."""
    from repro.core.hext.programs import Asm
    a = Asm(0)
    a.addi("a0", "zero", 5)       # 00500513
    a.add("a1", "a0", "a0")       # 00a505b3
    a.ld("t0", 8, "sp")           # 00813283
    a.sd("t0", 16, "sp")          # 00513823
    a.ecall()                     # 00000073
    a.sret()                      # 10200073
    a.mret()                      # 30200073
    a.wfi()                       # 10500073
    a.hfence_gvma()               # 62000073
    words = [hex(w) for w in a.assemble()]
    assert words == ['0x500513', '0xa505b3', '0x813283', '0x513823',
                     '0x73', '0x10200073', '0x30200073', '0x10500073',
                     '0x62000073']
