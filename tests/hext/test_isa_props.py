"""Randomized property tests: RV64 arithmetic helper semantics vs Python
golden models (division/remainder/mulh corner cases are classic simulator
bugs).

Seeded ``numpy.random.Generator`` + ``pytest.mark.parametrize`` instead of
hypothesis (absent from the CI container, which used to skip this file
silently).  Every parametrized stream always includes the architectural
corner values (0, ±1, INT_MIN, all-ones) alongside the random draws.
"""
import jax
import jax.numpy as jnp
import pytest

import numpy as np

from repro.core.hext import isa

I64_MIN = -(1 << 63)
U64_MAX = (1 << 64) - 1
N_CASES = 24


def _pairs(tag: str, signed: bool, n: int = N_CASES):
    """Deterministic (a, b) operand pairs, corner cases first."""
    rng = np.random.Generator(np.random.PCG64(
        np.random.SeedSequence([0x15A] + list(tag.encode()))))
    if signed:
        corners = [(0, 0), (I64_MIN, -1), (I64_MIN, 1), (-1, -1),
                   ((1 << 63) - 1, -1), (7, 0), (-7, 0), (I64_MIN, 0)]
        rand = rng.integers(I64_MIN, 1 << 63, size=(n, 2), dtype=np.int64)
    else:
        corners = [(0, 0), (U64_MAX, U64_MAX), (U64_MAX, 1), (1, U64_MAX),
                   (0, U64_MAX), (1 << 63, 2), (U64_MAX, 0)]
        rand = rng.integers(0, 1 << 64, size=(n, 2), dtype=np.uint64)
    return corners + [(int(a), int(b)) for a, b in rand]


def _u(x):
    with jax.experimental.enable_x64():
        return jnp.asarray(x % (1 << 64), jnp.uint64)


def _as_i64(u):
    u = int(u) & U64_MAX
    return u - (1 << 64) if u >= (1 << 63) else u


def _as_u64(i):
    return i & U64_MAX


@pytest.mark.parametrize("a,b", _pairs("divs", signed=True))
def test_divs_matches_riscv_semantics(a, b):
    with jax.experimental.enable_x64():
        got = _as_i64(isa.divs(_u(a), _u(b)))
    if b == 0:
        want = -1
    elif a == I64_MIN and b == -1:
        want = I64_MIN
    else:
        want = int(abs(a) // abs(b))
        if (a < 0) != (b < 0):
            want = -want
    assert got == want, (a, b)


@pytest.mark.parametrize("a,b", _pairs("rems", signed=True))
def test_rems_matches_riscv_semantics(a, b):
    with jax.experimental.enable_x64():
        got = _as_i64(isa.rems(_u(a), _u(b)))
    if b == 0:
        want = a
    elif a == I64_MIN and b == -1:
        want = 0
    else:
        want = int(abs(a) % abs(b))
        if a < 0:
            want = -want
    assert got == want, (a, b)


@pytest.mark.parametrize("a,b", _pairs("mulhu", signed=False))
def test_mulhu_matches_python(a, b):
    with jax.experimental.enable_x64():
        got = int(isa.mulhu(_u(a), _u(b)))
    assert got == (a * b) >> 64


@pytest.mark.parametrize("a,b", _pairs("mulh", signed=True))
def test_mulh_matches_python(a, b):
    with jax.experimental.enable_x64():
        got = _as_i64(isa.mulh(_u(_as_u64(a)), _u(_as_u64(b))))
    assert got == (a * b) >> 64


@pytest.mark.parametrize("a,b", _pairs("mulhsu", signed=True))
def test_mulhsu_matches_python(a, b):
    b = _as_u64(b)                       # rs2 is unsigned for mulhsu
    with jax.experimental.enable_x64():
        got = _as_i64(isa.mulhsu(_u(_as_u64(a)), _u(b)))
    assert got == (a * b) >> 64


@pytest.mark.parametrize("bits", [8, 12, 16, 32])
def test_sext_matches_python(bits):
    for v, _ in _pairs(f"sext{bits}", signed=False, n=8):
        with jax.experimental.enable_x64():
            got = _as_i64(isa.sext(_u(v), bits))
        low = v & ((1 << bits) - 1)
        want = low - (1 << bits) if low >= (1 << (bits - 1)) else low
        assert got == want, v


@pytest.mark.parametrize("size", [0, 1, 2, 3])
def test_mem_write_read_roundtrip(size):
    nbytes = 1 << size
    for val, off in _pairs(f"mem{size}", signed=False, n=6):
        off = (off % 8 // nbytes) * nbytes        # naturally aligned
        with jax.experimental.enable_x64():
            mem = jnp.zeros((4,), jnp.uint64)
            mem = isa.mem_write(mem, _u(8 + off), _u(val), size)
            rd = int(isa.mem_read(mem, _u(8 + off), size,
                                  jnp.asarray(True)))  # unsigned read
        assert rd == val & ((1 << (8 * nbytes)) - 1)


@pytest.mark.parametrize("a,b", _pairs("oracle_alu", signed=True, n=12))
def test_alu_helpers_match_oracle(a, b):
    """Differential micro-check vs the pure-Python oracle (DESIGN.md §5):
    the two independent div/rem/mulh implementations must agree."""
    from repro.core.hext import oracle
    au, bu = _as_u64(a), _as_u64(b)
    with jax.experimental.enable_x64():
        assert int(isa.divs(_u(au), _u(bu))) == oracle._divs(au, bu)
        assert int(isa.rems(_u(au), _u(bu))) == oracle._rems(au, bu)
        assert int(isa.mulhu(_u(au), _u(bu))) == oracle._mulhu(au, bu)
        assert int(isa.sext(_u(au), 32)) == oracle.sext(au, 32)


def test_assembler_encodings_golden():
    """Spot-check assembler encodings against known-good golden words."""
    from repro.core.hext.programs import Asm
    a = Asm(0)
    a.addi("a0", "zero", 5)       # 00500513
    a.add("a1", "a0", "a0")       # 00a505b3
    a.ld("t0", 8, "sp")           # 00813283
    a.sd("t0", 16, "sp")          # 00513823
    a.ecall()                     # 00000073
    a.sret()                      # 10200073
    a.mret()                      # 30200073
    a.wfi()                       # 10500073
    a.hfence_gvma()               # 62000073
    words = [hex(w) for w in a.assemble()]
    assert words == ['0x500513', '0xa505b3', '0x813283', '0x513823',
                     '0x73', '0x10200073', '0x30200073', '0x10500073',
                     '0x62000073']


# ---------------------------------------------------------------------------
# decode-table sweep: table-driven decode vs the oracle's independent
# bit-slicing decoder (no shared tables), plus traced-vs-host identity
# ---------------------------------------------------------------------------

_KNOWN_OPS = (0x33, 0x13, 0x3B, 0x1B, 0x37, 0x17, 0x6F, 0x67, 0x63,
              0x03, 0x23, 0x73, 0x0F)


def _decode_words(n: int = 256):
    """Deterministic instruction-word sweep: fixed architectural
    encodings, then random words biased onto the known major opcodes (so
    every opclass and immediate format is exercised), then fully random
    words (mostly illegal — the table's default row)."""
    rng = np.random.Generator(np.random.PCG64(
        np.random.SeedSequence([0x15A] + list(b"decode"))))
    fixed = [0x00000000, 0xFFFFFFFF,
             0x02A00093,              # addi x1, x0, 42
             0x40C5D533,              # sra a0, a1, a2
             0x02C5C533,              # div a0, a1, a2
             0x0015051B,              # addiw a0, a0, 1
             0x12345037, 0x12345017,  # lui / auipc
             0x0040006F, 0x00008067,  # jal / jalr
             0xFE550AE3,              # branch (negative B-imm)
             0x00853083, 0x00853023,  # ld / sd
             0x00000073, 0x10200073,  # ecall / sret
             0x30200073, 0x10500073,  # mret / wfi
             0x62000073,              # hfence.gvma
             0x0000000F, 0x0000100F]  # fence / fence.i
    rand = rng.integers(0, 1 << 32, size=n, dtype=np.uint32)
    ops = rng.choice(np.asarray(_KNOWN_OPS, np.uint32), size=n // 2)
    biased = (rand[: n // 2] & ~np.uint32(0x7F)) | ops
    return fixed + [int(w) for w in biased] + \
        [int(w) for w in rand[n // 2:]]


def test_decode_word_matches_independent_decoder():
    """Host table decode vs the oracle's if/elif decoder, field by field
    (a mis-built table row or a wrong immediate mux fails by name)."""
    from repro.core.hext import decode as D
    from repro.core.hext import oracle
    for w in _decode_words():
        got = D.decode_word(w)
        ref = oracle.decode_fields(w)
        assert D.CLS_NAMES[got["cls"]] == ref["cls"], hex(w)
        for k in ("rd", "rs1", "rs2", "f3", "f7", "imm", "alu_imm",
                  "instr"):
            assert got[k] == ref[k], (hex(w), k)


def test_traced_decode_matches_decode_word():
    """The jnp.take-gather decode must agree with the host-side decoder
    over the same tables for every sweep word (one vmapped trace)."""
    from repro.core.hext import decode as D
    words = _decode_words()
    with jax.experimental.enable_x64():
        uops = jax.jit(jax.vmap(D.decode))(jnp.asarray(words, jnp.uint64))
        uops = jax.tree.map(np.asarray, uops)
    for i, w in enumerate(words):
        ref = D.decode_word(w)
        got = {
            "cls": int(uops.cls[i]), "rd": int(uops.rd[i]),
            "rs1": int(uops.rs1[i]), "rs2": int(uops.rs2[i]),
            "f3": int(uops.f3[i]), "f7": int(uops.f7[i]),
            "imm": int(uops.imm[i]), "alu_imm": bool(uops.alu_imm[i]),
            "instr": int(uops.instr[i]),
        }
        assert got == ref, hex(w)
