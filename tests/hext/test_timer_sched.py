"""End-to-end tests for the virtual timer & interrupt-injection subsystem
(ISSUE 2 tentpole) plus the interrupt/TLB conformance regressions:

* WFI wake-on-pending regression (deadlocked before the fix),
* CLINT-style mtime/mtimecmp MMIO driving MTI at M,
* a guest arming its own timer via the stimecmp→vstimecmp swap, with the
  resulting VSTI delegated to VS,
* stale-TLB cross-privilege regression (U reusing an S entry),
* HLVX through an X-only G-stage page (asm-level counterpart of the unit
  test),
* the preemptive N-guest scheduler: golden checks, timer_irqs,
  ctx_switches, disarmed-timer counter parity, the 2-guest column's
  bit-parity with the committed benchmark JSON, an N=4 heterogeneous
  golden run, and htimedelta-virtualized guest time across preemption.
"""
import pytest

from repro.core.hext import csr as C
from repro.core.hext import isa
from repro.core.hext import programs
from repro.core.hext.programs import (G_L0, P_GUEST, S_L0, S_L2)
from repro.core.hext.sim import Fleet
from tests.hext.conftest import (build_gstage_identity, build_vs_identity,
                                 csr_of, enter_vs, exit_with,
                                 m_handler_capture, prologue, result, run_asm)

SV39 = 8 << 60

pytestmark = pytest.mark.slow


# ---------------------------------------------------------------------------
# WFI wakeup regression — deadlocked the fleet before the fix
# ---------------------------------------------------------------------------

def test_wfi_wakes_on_pending_but_globally_masked_interrupt():
    """wfi must resume on (mip & mie) != 0 even with mstatus.MIE clear.
    The interrupt becomes pending only *after* the hart halts (armed CLINT
    comparator), so before the fix this hart slept until max_ticks."""
    def build(a, img):
        prologue(a)
        a.li("t0", C.IP_MTIP)
        a.csrw(0x304, "t0")                  # mie.MTIE (locally enabled)
        a.li("t0", 60)
        a.li("t1", isa.MMIO_MTIMECMP)
        a.sd("t0", 0, "t1")                  # arm CLINT comparator
        a.wfi()                              # halt; MTIP pends at tick 60
        a.li("a0", 77)
        exit_with(a, "a0")
        m_handler_capture(a)

    st = run_asm(build, ticks=600)
    assert result(st) == 77                  # woke and continued past wfi
    # the interrupt was never *taken* (mstatus.MIE=0) — wake only
    assert st.counters.int_by_level.tolist() == [0, 0, 0]
    assert int(st.counters.timer_irqs) == 0


def test_mti_taken_at_m_via_clint():
    def build(a, img):
        prologue(a)
        a.li("t0", C.IP_MTIP)
        a.csrw(0x304, "t0")                  # mie.MTIE
        a.li("t0", C.MSTATUS_MIE)
        a.csrrs(0, 0x300, "t0")              # global enable
        a.li("t0", 40)
        a.li("t1", isa.MMIO_MTIMECMP)
        a.sd("t0", 0, "t1")                  # arm: fires at tick 40
        a.label("idle")
        a.j("idle")
        m_handler_capture(a)

    st = run_asm(build, ticks=600)
    assert result(st) == (1 << 63) | 7       # MTI cause
    assert int(st.counters.int_by_level[0]) == 1
    assert int(st.counters.timer_irqs) == 1


def test_clint_split_32bit_mtimecmp_write():
    """The classic RV32-style CLINT sequence (two sw's) must arm the
    comparator correctly, and the upper-half store must hit the MMIO
    register — not wrap through the modulo word index into RAM."""
    CANARY = 0xFEEDF00D0000DEAD

    def build(a, img):
        img.store64(0x4000, CANARY)          # where a wrapped store lands
        prologue(a)
        a.li("t0", C.IP_MTIP)
        a.csrw(0x304, "t0")
        a.li("t0", C.MSTATUS_MIE)
        a.csrrs(0, 0x300, "t0")
        a.li("t1", isa.MMIO_MTIMECMP)
        a.li("t0", 40)
        a.sw("t0", 0, "t1")                  # low word
        a.sw("zero", 4, "t1")                # high word → cmp = 40, armed
        a.label("idle2")
        a.j("idle2")
        m_handler_capture(a)

    st = run_asm(build, ticks=600)
    assert result(st) == (1 << 63) | 7       # MTI fired
    assert int(st.counters.timer_irqs) == 1
    assert int(st.mem[0x4000 // 8]) == CANARY   # RAM untouched


def test_time_csr_and_clint_mtime_agree():
    def build(a, img):
        prologue(a)
        a.csrr("t0", 0xC01)                  # time CSR
        a.li("t1", isa.MMIO_MTIME)
        a.ld("t1", 0, "t1")                  # CLINT mtime load
        a.sub("a0", "t1", "t0")              # load is 2 instrs later
        exit_with(a, "a0")
        m_handler_capture(a)

    st = run_asm(build, ticks=300)
    # both views advance once per tick; the ld retires 3 ticks after the
    # csrr (li expands to lui+addiw, then the load)
    assert result(st) == 3


# ---------------------------------------------------------------------------
# guest-owned timer: stimecmp→vstimecmp swap, VSTI delegated to VS
# ---------------------------------------------------------------------------

def test_guest_arms_vstimecmp_and_takes_vsti_at_vs():
    def build(a, img):
        prologue(a)
        build_gstage_identity(img)
        enter_vs(a, 0x400, vsatp=0, hideleg=0x444)
        while a.pc < 0x400:
            a.nop()
        # VS guest: handler at 0x500 (vstvec), enable STI, arm its timer
        a.li("t0", 0x500)
        a.csrw(0x105, "t0")                  # stvec → vstvec (swap)
        a.li("t0", C.IP_STIP)
        a.csrw(0x104, "t0")                  # sie → vsie (VSTIE via shift)
        a.li("t0", C.MSTATUS_SIE)
        a.csrrs(0, 0x100, "t0")              # sstatus.SIE → vsstatus.SIE
        a.csrr("t0", 0xC01)                  # guest reads time
        a.addi("t0", "t0", 50)
        a.csrw(0x14D, "t0")                  # stimecmp → vstimecmp (swap)
        a.label("g_idle")
        a.j("g_idle")
        while a.pc < 0x500:
            a.nop()
        # VS trap handler: capture vscause then ecall → M
        a.csrr("a0", 0x142)
        a.ecall()
        m_handler_capture(a)

    st = run_asm(build, ticks=600)
    # vscause = interrupt | STI (VS-level causes presented at S encodings)
    assert int(st.regs[10]) == (1 << 63) | 5
    assert int(st.counters.int_by_level[2]) == 1     # handled at VS
    assert int(st.counters.timer_irqs) == 1


# ---------------------------------------------------------------------------
# stale-TLB regression: U-mode must not reuse an S-mode entry's verdict
# ---------------------------------------------------------------------------

def test_umode_load_cannot_reuse_smode_tlb_entry():
    """S loads a kernel (U=0) page — TLB caches the S-mode verdict.  The
    subsequent U-mode load of the same VA must page-fault; before the fix
    it hit the S entry and passed its permission check."""
    U_CODE = 0x1000

    def build(a, img):
        prologue(a)
        build_vs_identity(img)               # identity P_KERN (U=0) tables
        img.map_page(S_L0, U_CODE, U_CODE, P_GUEST)   # U-executable page
        # M → S
        a.li("t0", 1 << 11)
        a.csrrs(0, 0x300, "t0")
        a.li("t0", 0x400)
        a.csrw(0x341, "t0")
        a.mret()
        while a.pc < 0x400:
            a.nop()
        # S: enable paging, warm the TLB with the kernel data page
        a.li("t0", SV39 | (S_L2 >> 12))
        a.csrw(0x180, "t0")
        a.sfence_vma()
        a.li("t1", 0x5000)
        a.ld("s0", 0, "t1")                  # inserts 0x5000 entry (priv=S)
        # drop to U at the U-executable page
        a.li("t0", 1 << 8)
        a.csrrc(0, 0x100, "t0")              # sstatus.SPP = 0 → U
        a.li("t0", U_CODE)
        a.csrw(0x141, "t0")                  # sepc
        a.sret()
        m_handler_capture(a)                 # M handler sits below U_CODE
        while a.pc < U_CODE:
            a.nop()
        # U: same VA, same access — must fault (page has U=0)
        a.ld("a0", 0, "t1")
        a.nop()

    st = run_asm(build)
    assert result(st) == C.EXC_LPAGE_FAULT
    assert csr_of(st, C.R_MTVAL) == 0x5000


# ---------------------------------------------------------------------------
# HLVX through an X-only G-stage page (asm-level)
# ---------------------------------------------------------------------------

def test_hlvx_reads_xonly_gstage_page():
    MAGIC = 0x1BADB002

    def build(a, img):
        prologue(a)
        img.store64(0x5000, MAGIC)
        build_vs_identity(img)
        build_gstage_identity(img)
        # remap GPA 0x5000 execute-only at the G-stage
        XONLY = (programs.PTE_V | programs.PTE_X | programs.PTE_U |
                 programs.PTE_A | programs.PTE_D)
        img.map_page(G_L0, 0x5000, 0x5000, XONLY)
        a.li("t0", SV39 | (programs.G_L2 >> 12))
        a.csrw(0x680, "t0")
        a.li("t0", SV39 | (S_L2 >> 12))
        a.csrw(0x280, "t0")
        a.li("t0", C.HSTATUS_SPVP)
        a.csrw(0x600, "t0")
        a.li("t1", 0x5000)
        a.hlvx_wu("a0", "t1")                # X perms at BOTH stages
        exit_with(a, "a0")
        m_handler_capture(a)

    st = run_asm(build)
    assert result(st) == MAGIC


# ---------------------------------------------------------------------------
# the preemptive 2-guest scheduler
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def preempt_fleet():
    fleet = Fleet.boot([(programs.SHA(), programs.FFT()),
                        programs.CRC32()],
                       guests_per_hart=2, timeslice=200)
    fleet.run(30000, chunk=1024)
    return fleet


def test_two_guest_preemption_golden_checks(preempt_fleet):
    rep = preempt_fleet.report()
    mixed = rep["sha+fft/2guest-preempt"]
    pair = rep["crc32+crc32/2guest-preempt"]
    for entry in (mixed, pair):
        assert entry["done"]
        assert entry["ok_a"] and entry["ok_b"] and entry["ok"]
        assert entry["ctx_switches"] > 0
        assert entry["timer_irqs"] > 0
        # scheduler STIs are all handled at HS; guests also ran in VS
        assert entry["int_by_level"][1] == entry["timer_irqs"]
        assert entry["instret_virt"] > 0


def test_two_guest_runs_are_time_sliced_not_serial(preempt_fleet):
    """Preemption must interleave the guests: more context switches than
    the single exit handoff a serial run would produce."""
    rep = preempt_fleet.report()["sha+fft/2guest-preempt"]
    assert rep["ctx_switches"] >= 3
    # every preemption costs HS instructions: the hart retires more than
    # the two guests alone would
    assert rep["instret"] > rep["instret_virt"]


class _OutOfWindowWorkload(programs.Workload):
    """Malicious guest: touches GPA 0x10000, outside its 64 KiB window."""
    name = "oob"

    def asm(self, a):
        a.label("workload_entry")
        a.li("t0", 0x10000)
        a.ld("a0", 0, "t0")
        a.ret()

    def golden(self):
        return 0


def test_scheduler_rejects_out_of_window_gpa():
    """Isolation: the scheduler must never G-map a GPA beyond the guest's
    window (it would alias the other guest's memory) — it kills the
    machine with the offending GPA instead."""
    fleet = Fleet.boot([(_OutOfWindowWorkload(), programs.SHA())],
                       guests_per_hart=2, timeslice=200)
    fleet.run(20000, chunk=1024)
    c = fleet[0].counters
    assert bool(c.done)
    assert int(c.exit_code) == 0x10000


def _committed_benchmark():
    import json
    import pathlib
    ref_path = pathlib.Path(__file__).resolve().parents[2] / \
        "benchmarks" / "results" / "hext_runs.json"
    return json.loads(ref_path.read_text())["workloads"]


def test_disarmed_timer_counter_parity():
    """With no comparator armed, single-guest counters are bit-identical to
    the pre-timer implementation (golden values recorded pre-PR)."""
    ref = _committed_benchmark()["crc32"]
    wl = programs.CRC32()
    fleet = Fleet.boot([wl, wl], guest=[False, True])
    fleet.run(30000, chunk=1024)
    rep = fleet.report()
    for mode in ("native", "guest"):
        got = rep[f"crc32/{mode}"]
        for key in ("instret", "instret_virt", "ticks", "exc_by_level",
                    "int_by_level", "pagefaults", "walks"):
            assert got[key] == ref[mode][key], (mode, key)
        assert got["timer_irqs"] == 0
        assert got["ctx_switches"] == 0


_PARITY_KEYS = ("instret", "instret_virt", "ticks", "exc_by_level",
                "int_by_level", "pagefaults", "walks", "timer_irqs",
                "ctx_switches", "checksum_a", "checksum_b", "golden")


def test_two_guest_counters_match_committed_benchmark():
    """The N-generalized scheduler at guests_per_hart=2 must stay
    bit-identical to the committed benchmark JSON — the 2-guest column is
    the regression oracle for the N-guest rewrite (counters are per-hart
    and independent of fleet batching, so a single-slot fleet suffices)."""
    ref = _committed_benchmark()["crc32"]["2guest-preempt"]
    wl = programs.CRC32()
    fleet = Fleet.boot([wl], guests_per_hart=2)   # DEFAULT_TIMESLICE
    fleet.run(120000, chunk=1024)
    got = fleet.report()["crc32+crc32/2guest-preempt"]
    for key in _PARITY_KEYS:
        assert got[key] == ref[key], key
    assert got["ok"] and got["ok_a"] and got["ok_b"]


def test_four_guest_e2e_golden():
    """N=4 heterogeneous slot: all four tenants hit their goldens, HS takes
    every scheduler tick, and preemption actually interleaves them."""
    quad = (programs.SHA(), programs.FFT(), programs.CRC32(),
            programs.BitCount())
    fleet = Fleet.boot([quad], guests_per_hart=4, timeslice=300)
    fleet.run(120000, chunk=2048)
    rep = fleet.report()["sha+fft+crc32+bitcount/4guest-preempt"]
    assert rep["done"] and rep["ok"]
    assert rep["guests"] == 4 and all(rep["ok_guests"])
    assert rep["ctx_switches"] >= 4               # every tenant got CPU time
    assert rep["timer_irqs"] >= rep["ctx_switches"] - 3   # + exit handoffs
    assert rep["int_by_level"][1] == rep["timer_irqs"]    # all STIs at HS
    assert rep["instret"] > rep["instret_virt"] > 0


class _TimerGuest(programs.Workload):
    """Guest that sleeps WAIT ticks of its OWN clock on vstimecmp and
    returns the virtually-elapsed time.  Under a correct htimedelta the
    returned value is ≈ WAIT even though the guest was descheduled for
    whole timeslices while waiting."""
    name = "timerguest"
    WAIT = 400
    HANDLER = programs.WORKLOAD + 0x100

    def asm(self, a):
        a.label("workload_entry")
        a.li("t0", self.HANDLER)
        a.csrw(0x105, "t0")                  # stvec → vstvec (V=1 swap)
        a.li("t0", C.IP_STIP)
        a.csrrs(0, 0x104, "t0")              # sie → vsie (VSTIE via shift)
        a.li("t0", C.MSTATUS_SIE)
        a.csrrs(0, 0x100, "t0")              # sstatus.SIE → vsstatus.SIE
        a.csrr("s0", 0xC01)                  # t_start (guest virtual time)
        a.addi("t0", "s0", self.WAIT)
        a.csrw(0x14D, "t0")                  # stimecmp → vstimecmp (swap)
        a.li("s1", 0)
        a.label("tg_wait")
        a.beqz("s1", "tg_wait")              # handler sets s1
        a.csrr("t0", 0xC01)                  # t_end (guest virtual time)
        a.sub("a0", "t0", "s0")              # elapsed in guest time
        a.ret()
        while a.pc < self.HANDLER:
            a.nop()
        # VSTI handler: flag completion, disarm, mask VSTIE, resume
        a.li("s1", 1)
        a.li("t0", -1)
        a.csrw(0x14D, "t0")                  # vstimecmp ← disarmed
        a.li("t0", C.IP_STIP)
        a.csrrc(0, 0x104, "t0")              # vsie.STIE off
        a.sret()

    def golden(self):
        return 0                             # checked by range, not golden


def test_htimedelta_virtualizes_guest_time_across_preemption():
    """The timer guest waits 400 ticks of its own clock while a busy
    sibling steals whole 150-tick slices.  With htimedelta maintained by
    the scheduler the guest-observed elapsed time stays ≈ WAIT; without it
    the guest would observe every descheduled tick as well (≥ WAIT + a
    timeslice per preemption)."""
    tg = _TimerGuest()
    fleet = Fleet.boot([(tg, programs.SHA())], guests_per_hart=2,
                       timeslice=150)
    fleet.run(60000, chunk=1024)
    rep = fleet.report()["timerguest+sha/2guest-preempt"]
    assert rep["done"]
    assert rep["ok_guests"][1]                   # sha still hits its golden
    elapsed = rep["checksums"][0]
    assert rep["ctx_switches"] >= 3              # the wait spanned slices
    assert _TimerGuest.WAIT <= elapsed < _TimerGuest.WAIT + 80, elapsed
