"""Shared harness for the paper's §3.4 test families.

Each test assembles a small RV64 program with the hext assembler, boots it
in the simulator (M mode, pc=0) through the typed `Fleet` facade, runs a
bounded number of ticks, and checks architectural state. `run_asm` builds:
M-mode prologue (caller-provided), and returns the final `HartState`.
"""
import pytest

from repro.core.hext import csr as C
from repro.core.hext.sim import Fleet
from repro.core.hext.programs import (Asm, Image, MEM_WORDS, P_GUEST, P_KERN,
                                      G_L0, G_L1, G_L2, S_L0, S_L1, S_L2)

MAX_TICKS = 3000


def run_asm(build_fn, ticks=MAX_TICKS, mem_words=MEM_WORDS):
    """build_fn(asm, img) → assembles at 0x0; returns final HartState."""
    a = Asm(0)
    img = Image(mem_words)
    build_fn(a, img)
    img.place_code(0, a.assemble())
    fleet = Fleet.from_images([img.mem], mem_words=mem_words)
    fleet.run(ticks, chunk=min(ticks, 1024))
    return fleet[0]


def result(st):
    return int(st.counters.exit_code)


def csr_of(st, idx):
    return int(st.csrs[idx])


@pytest.fixture
def mk():
    return run_asm


# -- common asm fragments ------------------------------------------------------

MTVEC = 0x800            # shared M handler location in these tests


def prologue(a):
    a.li("t0", MTVEC)
    a.csrw(0x305, "t0")


def m_handler_capture(a):
    """M handler at MTVEC: exits with mcause (tests read other CSRs from
    final state)."""
    assert a.pc <= MTVEC, hex(a.pc)
    while a.pc < MTVEC:
        a.nop()
    a.label("mh")
    a.csrr("t0", 0x342)
    exit_with(a, "t0")


def exit_with(a, reg="a0"):
    """Store reg to the DONE MMIO (bare M-mode)."""
    a.li("t6", 0x10000008)
    a.sd(reg, 0, "t6")
    lab = f"_spin{a.pc}"
    a.label(lab)
    a.j(lab)


def build_gstage_identity(img, pages=range(0, 0x20000, 0x1000)):
    img.link(G_L2, 0, G_L1)
    img.link(G_L1, 0, G_L0)
    for p in pages:
        img.map_page(G_L0, p, p, P_GUEST)


def build_vs_identity(img, pages=range(0, 0x20000, 0x1000)):
    img.link(S_L2, 0, S_L1)
    img.link(S_L1, 0, S_L0)
    for p in pages:
        img.map_page(S_L0, p, p, P_KERN)


S_L0B = 0xB000   # second VS L0 table: VA 0x200000+x → GPA x (2MB region 1)


def build_vs_split_data(img, va_page=0x205000, gpa_page=0x5000):
    """Map VA 0x205000 → GPA 0x5000 through a *separate* L0 table so a test
    can G-unmap just that table page and provoke an implicit (PTE-fetch)
    guest fault for data accesses while code fetches keep working."""
    img.link(S_L1, 1, S_L0B)
    img.map_page(S_L0B, va_page, gpa_page, P_KERN)


def enter_vs(a, entry, hedeleg=0, hideleg=0, vsatp=0, medeleg=0):
    """M-mode fragment: set up H regs and drop to VS at `entry`.

    medeleg defaults to 0 so every exception from the guest lands at the
    M handler (where the tests capture mcause/mtval/mtval2/mtinst).
    Counter enables (mcounteren/hcounteren TM et al.) are opened so guest
    `time` reads do not trap — tests for the counteren gating itself drive
    `csr_read` directly."""
    a.li("t0", 7)
    a.csrw(0x306, "t0")                   # mcounteren: CY|TM|IR
    a.csrw(0x606, "t0")                   # hcounteren
    if medeleg:
        a.li("t0", medeleg)
        a.csrw(0x302, "t0")               # medeleg
    a.li("t0", 8 << 60 | (G_L2 >> 12))
    a.csrw(0x680, "t0")                   # hgatp
    if hedeleg:
        a.li("t0", hedeleg)
        a.csrw(0x602, "t0")
    if hideleg:
        a.li("t0", hideleg)
        a.csrw(0x603, "t0")
    if vsatp:
        a.li("t0", vsatp)
        a.csrw(0x280, "t0")               # vsatp directly from M
    # mstatus: MPV=1, MPP=S
    a.li("t0", 1 << 39)
    a.csrrs(0, 0x300, "t0")
    a.li("t0", 1 << 11)
    a.csrrs(0, 0x300, "t0")
    a.li("t0", entry)
    a.csrw(0x341, "t0")                   # mepc
    a.mret()
