"""Differential conformance: batched torture scenarios vs the pure-Python
oracle (DESIGN.md §5).

The quick (push-gate) smoke runs a fixed-seed 64-scenario corpus as ONE
batched Fleet and requires zero machine-vs-oracle mismatches; the slow
(nightly) test runs the full 256-scenario acceptance corpus.  Mutation
tests verify that an injected fault is actually *reported*, with a
working one-command repro line — a diff harness that can't fail is
worthless.
"""
import numpy as np
import pytest

from repro.core.hext import oracle, torture
from repro.core.hext import csr as C

SEED = torture.DEFAULT_SEED


# ---------------------------------------------------------------------------
# generator determinism + scenario well-formedness (no Fleet run)
# ---------------------------------------------------------------------------

def test_generator_is_deterministic():
    a = torture.gen_scenario(SEED, 7)
    b = torture.gen_scenario(SEED, 7)
    assert np.array_equal(a.image, b.image)
    assert a.cfg == b.cfg
    c = torture.gen_scenario(SEED, 8)
    assert not np.array_equal(a.image, c.image)


def test_corpus_covers_all_modes_and_shapes():
    """One 96-scenario draw must exercise every entry mode, both paging
    states per stage, and at least one broken-PTE shape."""
    cfgs = [torture.gen_scenario(SEED, k).cfg for k in range(96)]
    assert {c["mode"] for c in cfgs} == set(torture.MODES)
    assert any(c["satp"]["on"] for c in cfgs)
    assert any(not c["satp"]["on"] for c in cfgs)
    assert any(c["hgatp"]["on"] for c in cfgs)
    assert any(c["satp"].get("superpage") for c in cfgs)
    assert any(c["satp"].get("root_oob") or c["vsatp"].get("root_oob")
               for c in cfgs)
    assert any(c["stimecmp_delta"] is not None for c in cfgs)
    assert any(c["use_wfi"] for c in cfgs)


def test_every_scenario_terminates_under_oracle():
    """Termination-by-construction check on a cheap oracle-only sweep:
    the overwhelming majority of scenarios must finish well inside the
    budget (a budget-burner is legal but must stay rare)."""
    done = 0
    for k in range(64):
        s = torture.gen_scenario(SEED, k)
        st = oracle.run(s.image, torture.MAX_TICKS)
        done += bool(st["done"])
    assert done >= 60, f"only {done}/64 scenarios terminated"


# ---------------------------------------------------------------------------
# the quick differential smoke: one batched Fleet, fixed seed, zero diffs
# ---------------------------------------------------------------------------

@pytest.mark.fuzz
def test_quick_fuzz_smoke_zero_mismatches():
    rep = torture.run_corpus(SEED, 64)
    assert rep["failures"] == [], \
        "\n".join(f["repro"] for f in rep["failures"])
    # one batched run: throughput is per-Fleet wall time, must be sane
    assert rep["scenarios_per_sec_batched"] > 0


@pytest.mark.fuzz
@pytest.mark.slow
def test_full_fuzz_corpus_zero_mismatches():
    """The 256-scenario acceptance corpus (nightly)."""
    rep = torture.run_corpus(SEED, 256)
    assert rep["failures"] == [], \
        "\n".join(f["repro"] for f in rep["failures"])


# ---------------------------------------------------------------------------
# mutation tests: an injected fault must be caught AND carry a repro line
# ---------------------------------------------------------------------------

def _oracle_final(case: int):
    s = torture.gen_scenario(SEED, case)
    return s, oracle.run(s.image, torture.MAX_TICKS)


def _as_machine_arrays(ost):
    """Shape an oracle final state like `_final_arrays`' batch-of-1 —
    the production conversion itself, so the mutation tests validate the
    exact shape the diff path consumes."""
    return torture._oracle_arrays(ost)


def test_identical_states_diff_clean():
    _, ost = _oracle_final(3)
    assert torture.diff_case(_as_machine_arrays(ost), 0, ost) == []


def test_mutated_state_is_caught_per_field():
    _, ost = _oracle_final(3)
    for field, mutate in (
            ("x7", lambda m: m["regs"].__setitem__((0, 7), 0xDEAD)),
            ("csr", lambda m: m["csrs"].__setitem__((0, C.R_MCAUSE), 99)),
            ("instret", lambda m: m.__setitem__(
                "instret", m["instret"] + 1)),
            ("mem", lambda m: m["mem"].__setitem__((0, 0x3000 // 8), 1)),
            ("exit_code", lambda m: m.__setitem__(
                "exit_code", m["exit_code"] ^ 1))):
        mach = _as_machine_arrays(ost)
        mutate(mach)
        d = torture.diff_case(mach, 0, ost)
        assert d, f"mutation of {field} not caught"


def test_failure_report_carries_working_repro_line():
    line = torture.repro_line(SEED, 42)
    assert "--seed" in line and "--case 42" in line \
        and "repro.core.hext.torture" in line
    # the repro entry point regenerates the exact same scenario
    s = torture.gen_scenario(SEED, 42)
    s2 = torture.gen_scenario(SEED, 42)
    assert np.array_equal(s.image, s2.image)


# ---------------------------------------------------------------------------
# oracle unit checks against hand-computed architecture facts
# ---------------------------------------------------------------------------

def test_oracle_reset_and_counters_shape():
    st = oracle.reset_state(np.zeros(64, dtype=np.uint64))
    assert st["priv"] == 3 and not st["virt"] and st["pc"] == 0
    assert st["csrs"][C.R_MTIMECMP] == C.TIMER_DISARMED
    assert st["csrs"][C.R_MIDELEG] == C.MIDELEG_FORCED


def test_oracle_timer_advance_and_fire():
    st = oracle.reset_state(np.zeros(64, dtype=np.uint64))
    st["csrs"][C.R_STIMECMP] = 3
    for _ in range(2):
        oracle._advance_timers(st["csrs"])
    assert st["csrs"][C.R_MTIME] == 2
    assert not st["csrs"][C.R_MIP] & C.IP_STIP
    oracle._advance_timers(st["csrs"])
    assert st["csrs"][C.R_MIP] & C.IP_STIP


def test_oracle_two_stage_walk_faults_reserved_pte():
    """A W=1/R=0 leaf must page-fault in the oracle too."""
    st = oracle.reset_state(np.zeros(1 << 12, dtype=np.uint64))
    st["csrs"][C.R_SATP] = (8 << 60) | (0x0000 >> 12)
    st["priv"] = 1
    # L2[0] → table @0x1000; L1[0] → table @0x2000; L0[3] = reserved leaf
    st["mem"][0] = (0x1000 >> 12) << 10 | 0x1
    st["mem"][0x1000 // 8] = (0x2000 >> 12) << 10 | 0x1
    st["mem"][0x2000 // 8 + 3] = (0x3000 >> 12) << 10 | 0x5  # V|W, no R
    xr = oracle.translate(st, 0x3008, oracle.ACC_R)
    assert xr["fault"] and xr["cause"] == C.EXC_LPAGE_FAULT
