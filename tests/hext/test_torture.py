"""Differential conformance: batched torture scenarios vs the pure-Python
oracle (DESIGN.md §5).

The quick (push-gate) smoke runs a fixed-seed 64-scenario corpus as ONE
batched Fleet and requires zero machine-vs-oracle mismatches; the slow
(nightly) test runs the full 256-scenario acceptance corpus.  Mutation
tests verify that an injected fault is actually *reported*, with a
working one-command repro line — a diff harness that can't fail is
worthless.
"""
import numpy as np
import pytest

from repro.core.hext import oracle, programs, torture
from repro.core.hext import csr as C

SEED = torture.DEFAULT_SEED


# ---------------------------------------------------------------------------
# generator determinism + scenario well-formedness (no Fleet run)
# ---------------------------------------------------------------------------

def test_generator_is_deterministic():
    a = torture.gen_scenario(SEED, 7)
    b = torture.gen_scenario(SEED, 7)
    assert np.array_equal(a.image, b.image)
    assert a.cfg == b.cfg
    c = torture.gen_scenario(SEED, 8)
    assert not np.array_equal(a.image, c.image)


def test_corpus_covers_all_modes_and_shapes():
    """One 96-scenario draw must exercise every entry mode, both paging
    states per stage, at least one broken-PTE shape, every action-block
    kind, and the sched family."""
    scens = torture.generate(SEED, 96)
    cfgs = [s.cfg for s in scens if s.family == "fuzz"]
    assert {c["mode"] for c in cfgs} == set(torture.MODES)
    assert any(c["satp"]["on"] for c in cfgs)
    assert any(not c["satp"]["on"] for c in cfgs)
    assert any(c["hgatp"]["on"] for c in cfgs)
    assert any(c["satp"].get("superpage") for c in cfgs)
    assert any(c["satp"].get("root_oob") or c["vsatp"].get("root_oob")
               for c in cfgs)
    assert any(c["stimecmp_delta"] is not None for c in cfgs)
    assert any(c["use_wfi"] for c in cfgs)
    # v2: every action-block kind appears, and tables get mapped for the
    # PTE-rewrite blocks at least once
    kinds = {k for c in cfgs for k in c["blocks"]}
    assert kinds == {"straight", "fuel", "pte", "tramp"}
    assert any(c["map_tables"] for c in cfgs)
    # sched family: every 8th case composes fuzz bodies with the
    # preemptive N-guest scheduler
    sched = [s.cfg for s in scens if s.family == "sched"]
    assert len(sched) == 96 // torture.SCHED_EVERY
    assert all(c["n_guests"] >= 2 for c in sched)


def test_coverage_bias_and_buckets():
    """Candidate selection is deterministic, and the static bucket map
    of a 64-draw corpus covers modes × blocks broadly."""
    scens = torture.generate(SEED, 64)
    buckets = set()
    for s in scens:
        buckets |= set(torture._static_buckets(s.cfg))
    assert len(buckets) > 40
    hist = torture.coverage_map(scens, {})
    assert len(hist) == len(buckets)
    assert sum(hist.values()) >= len(scens)


def test_every_scenario_terminates_under_oracle():
    """Termination-by-construction check on a cheap oracle-only sweep:
    the overwhelming majority of scenarios must finish well inside the
    budget (a budget-burner is legal but must stay rare)."""
    done = 0
    for s in torture.generate(SEED, 64):
        st = oracle.run(s.image, s.max_ticks)
        done += bool(st["done"])
    assert done >= 60, f"only {done}/64 scenarios terminated"


# ---------------------------------------------------------------------------
# the quick differential smoke: one batched Fleet, fixed seed, zero diffs
# ---------------------------------------------------------------------------

@pytest.mark.fuzz
def test_quick_fuzz_smoke_zero_mismatches():
    rep = torture.run_corpus(SEED, 64)
    assert rep["failures"] == [], \
        "\n".join(f["repro"] for f in rep["failures"])
    # one batched run: throughput is per-Fleet wall time, must be sane
    assert rep["scenarios_per_sec_batched"] > 0


@pytest.mark.fuzz
@pytest.mark.slow
def test_full_fuzz_corpus_zero_mismatches():
    """The 256-scenario acceptance corpus (nightly)."""
    rep = torture.run_corpus(SEED, 256)
    assert rep["failures"] == [], \
        "\n".join(f["repro"] for f in rep["failures"])


# ---------------------------------------------------------------------------
# mutation tests: an injected fault must be caught AND carry a repro line
# ---------------------------------------------------------------------------

def _oracle_final(case: int):
    s = torture.gen_scenario(SEED, case)
    return s, oracle.run(s.image, torture.MAX_TICKS)


def _as_machine_arrays(ost):
    """Shape an oracle final state like `_final_arrays`' batch-of-1 —
    the production conversion itself, so the mutation tests validate the
    exact shape the diff path consumes."""
    return torture._oracle_arrays(ost)


def test_identical_states_diff_clean():
    _, ost = _oracle_final(3)
    assert torture.diff_case(_as_machine_arrays(ost), 0, ost) == []


def test_mutated_state_is_caught_per_field():
    _, ost = _oracle_final(3)
    for field, mutate in (
            ("x7", lambda m: m["regs"].__setitem__((0, 7), 0xDEAD)),
            ("csr", lambda m: m["csrs"].__setitem__((0, C.R_MCAUSE), 99)),
            ("instret", lambda m: m.__setitem__(
                "instret", m["instret"] + 1)),
            ("walks", lambda m: m.__setitem__("walks", m["walks"] + 1)),
            ("mem", lambda m: m["mem"].__setitem__((0, 0x3000 // 8), 1)),
            ("exit_code", lambda m: m.__setitem__(
                "exit_code", m["exit_code"] ^ 1))):
        mach = _as_machine_arrays(ost)
        mutate(mach)
        d = torture.diff_case(mach, 0, ost)
        assert d, f"mutation of {field} not caught"


def test_failure_report_carries_working_repro_line():
    line = torture.repro_line(SEED, 42)
    assert "--seed" in line and "--case 42" in line \
        and "repro.core.hext.torture" in line
    # the repro entry point regenerates the exact same scenario
    s = torture.gen_scenario(SEED, 42)
    s2 = torture.gen_scenario(SEED, 42)
    assert np.array_equal(s.image, s2.image)


# ---------------------------------------------------------------------------
# repro CLI conformance: exit status + both-model dump (bugfix satellite)
# ---------------------------------------------------------------------------

def test_repro_cli_clean_case_exits_zero(capsys):
    assert torture.main(["--seed", str(SEED), "--case", "3"]) == 0
    out = capsys.readouterr().out
    assert "machine == oracle" in out
    # the dump prints both-model values for every diffable scalar field
    for field in torture._CASE_FIELDS:
        assert field in out


@pytest.mark.parametrize("field", ["x7", "walks", "exit_code"])
def test_repro_cli_injected_fault_exits_nonzero(field, capsys):
    rc = torture.main(["--seed", str(SEED), "--case", "3",
                       "--inject-fault", field])
    assert rc == 1
    out = capsys.readouterr().out
    assert "MISMATCH" in out
    assert f"--case 3" in out          # repro line present on failure


def test_repro_cli_handles_sched_family_case(capsys):
    """Sched-family images are larger than the fuzz mem budget; the
    single-case path must pad the raw-oracle leg to the Fleet's
    power-of-two memory instead of crashing on a shape mismatch."""
    case = torture.SCHED_EVERY - 1       # first sched case
    assert torture.main(["--seed", str(SEED), "--case", str(case)]) == 0
    assert "family=sched" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# scheduler × fuzz composition (quick tier)
# ---------------------------------------------------------------------------

def _sched_smoke_scenarios(n_cases=32):
    """32 fixed-seed v2 sched scenarios forced to N=2 guests/hart and a
    short timeslice — the quick-tier composition smoke."""
    scens = []
    for k in range(n_cases):
        rng = torture._case_rng(SEED + 1000, k)
        cfg = torture._sample_sched_cfg(rng)
        cfg["n_guests"], cfg["mode"] = 2, "SCHED2"
        cfg["guests"] = cfg["guests"][:2]
        cfg["timeslice"] = min(cfg["timeslice"], 150)
        scens.append(torture.Scenario(
            seed=SEED + 1000, case=k,
            image=torture._build_sched_image(cfg), cfg=cfg))
    return scens


def test_sched_fuzz_smoke_one_fleet_zero_mismatches():
    from repro.core.hext.engine import OracleEngine
    scens = _sched_smoke_scenarios()
    budget = 3072                        # whole chunk-scans; most finish
    mach = torture._run_corpus_fleet(scens, budget, torture.CHUNK)
    orac = torture._run_corpus_fleet(scens, budget, torture.CHUNK,
                                     engine=OracleEngine())
    fails = [k for k in range(len(scens))
             if torture.diff_pair(mach, k, orac, k)]
    assert fails == [], f"sched smoke mismatches in cases {fails}"
    # the composition must actually run guest code, not just boot
    assert all(int(mach["ctx_switches"][k]) >= 2 for k in range(len(scens)))


# ---------------------------------------------------------------------------
# WFI starvation guard (bugfix satellite): a guest whose only pending
# wake source is the *scheduler's* slice timer must not deadlock
# ---------------------------------------------------------------------------

class _WfiHog(programs.Workload):
    """Immediately parks in WFI, repeatedly, with nothing of its own
    armed — only the HS slice timer (always re-armed by the scheduler)
    can wake it."""
    name = "wfihog"

    def asm(self, a):
        a.label("workload_entry")
        for _ in range(6):
            a.wfi()
        a.li("a0", 0)
        a.ret()

    def golden(self):
        return 0


def test_wfi_with_only_sibling_timer_cannot_starve():
    from repro.core.hext.engine import OracleEngine
    compute = torture.FuzzGuest(
        {"seed": 7, "n_items": 8, "wfi": False, "loops": True})
    img = programs.build_image_nguest([_WfiHog(), compute], timeslice=120)
    s = torture.Scenario(seed=0, case=0, image=img,
                         cfg={"family": "sched", "mode": "SCHED2",
                              "n_guests": 2})
    budget = torture.SCHED_MAX_TICKS
    ost = oracle.run(torture._pad_image(img, torture._fleet_words(img)),
                     budget)
    assert ost["done"], "WFI hog starved: scenario never terminated"
    mach = torture._run_corpus_fleet([s], budget, torture.CHUNK)
    assert torture.diff_case(mach, 0, ost) == []


# ---------------------------------------------------------------------------
# oracle unit checks against hand-computed architecture facts
# ---------------------------------------------------------------------------

def test_oracle_reset_and_counters_shape():
    st = oracle.reset_state(np.zeros(64, dtype=np.uint64))
    assert st["priv"] == 3 and not st["virt"] and st["pc"] == 0
    assert st["csrs"][C.R_MTIMECMP] == C.TIMER_DISARMED
    assert st["csrs"][C.R_MIDELEG] == C.MIDELEG_FORCED


def test_oracle_timer_advance_and_fire():
    st = oracle.reset_state(np.zeros(64, dtype=np.uint64))
    st["csrs"][C.R_STIMECMP] = 3
    for _ in range(2):
        oracle._advance_timers(st["csrs"])
    assert st["csrs"][C.R_MTIME] == 2
    assert not st["csrs"][C.R_MIP] & C.IP_STIP
    oracle._advance_timers(st["csrs"])
    assert st["csrs"][C.R_MIP] & C.IP_STIP


def test_oracle_two_stage_walk_faults_reserved_pte():
    """A W=1/R=0 leaf must page-fault in the oracle too."""
    st = oracle.reset_state(np.zeros(1 << 12, dtype=np.uint64))
    st["csrs"][C.R_SATP] = (8 << 60) | (0x0000 >> 12)
    st["priv"] = 1
    # L2[0] → table @0x1000; L1[0] → table @0x2000; L0[3] = reserved leaf
    st["mem"][0] = (0x1000 >> 12) << 10 | 0x1
    st["mem"][0x1000 // 8] = (0x2000 >> 12) << 10 | 0x1
    st["mem"][0x2000 // 8 + 3] = (0x3000 >> 12) << 10 | 0x5  # V|W, no R
    xr = oracle.translate(st, 0x3008, oracle.ACC_R)
    assert xr["fault"] and xr["cause"] == C.EXC_LPAGE_FAULT
