"""The paper's §3.4 validation families, one test (or more) each:

tinst_tests, wfi_exception_tests, hfence_tests, virtual_instruction,
interrupt_tests, check_xip_regs, m_and_hs_using_vs_access,
second_stage_only_translation, two_stage_translation.
"""
import jax
import pytest

from repro.core.hext import csr as C
from repro.core.hext.programs import (G_L0, G_L1, G_L2, P_GUEST, P_KERN,
                                      S_L0, S_L1, S_L2)
from tests.hext.conftest import (S_L0B, build_gstage_identity,
                                 build_vs_identity, build_vs_split_data,
                                 csr_of, enter_vs, exit_with,
                                 m_handler_capture, prologue, result,
                                 run_asm)

SV39 = 8 << 60

# the long §3.4 validation suite — excluded from quick CI via -m "not slow"
pytestmark = pytest.mark.slow


# ---------------------------------------------------------------------------
# two_stage_translation — full VS+G walk, checks final value and fault info
# ---------------------------------------------------------------------------

def test_two_stage_translation_loads_value():
    MAGIC = 0xABCD1234

    def build(a, img):
        prologue(a)
        img.store64(0x5000, MAGIC)
        build_vs_identity(img)
        build_gstage_identity(img)
        enter_vs(a, 0x400, vsatp=SV39 | (S_L2 >> 12))
        while a.pc < 0x400:
            a.nop()
        # VS mode, two-stage on: load through VA 0x5000
        a.li("t1", 0x5000)
        a.ld("a0", 0, "t1")
        a.ecall()                      # cause 10 → HS (stvec=0 → spins @0)
        m_handler_capture(a)

    st = run_asm(build, ticks=600)
    assert int(st.regs[10]) == MAGIC


def test_two_stage_translation_guest_fault_reports_gpa():
    def build(a, img):
        prologue(a)
        build_vs_identity(img)          # VS maps VA→GPA fine
        build_gstage_identity(
            img, pages=list(range(0, 0x6000, 0x1000)) +
            [S_L2, S_L1, S_L0])         # PT pages G-mapped; 0x7000 NOT
        # → load guest-page fault (cause 21) at M (medeleg cleared)
        a.li("t0", SV39 | (G_L2 >> 12))
        a.csrw(0x680, "t0")
        a.li("t0", SV39 | (S_L2 >> 12))
        a.csrw(0x280, "t0")
        a.li("t0", (1 << 39) | (1 << 11))
        a.csrrs(0, 0x300, "t0")
        a.li("t0", 0x400)
        a.csrw(0x341, "t0")
        a.mret()
        while a.pc < 0x400:
            a.nop()
        a.li("t1", 0x7008)
        a.ld("a0", 0, "t1")
        a.ecall()
        m_handler_capture(a)

    st = run_asm(build)
    assert result(st) == C.EXC_LGUEST_PAGE_FAULT
    # mtval = faulting guest VA; mtval2 = GPA >> 2; GVA bit set
    assert csr_of(st, C.R_MTVAL) == 0x7008
    assert csr_of(st, C.R_MTVAL2) == 0x7008 >> 2
    assert csr_of(st, C.R_MSTATUS) & C.MSTATUS_GVA


# ---------------------------------------------------------------------------
# second_stage_only_translation — vsatp BARE, hgatp active
# ---------------------------------------------------------------------------

def test_second_stage_only_translation():
    MAGIC = 0x5151

    def build(a, img):
        prologue(a)
        img.store64(0x5000, MAGIC)
        build_gstage_identity(img)
        enter_vs(a, 0x400, vsatp=0)     # vsatp.mode = BARE
        while a.pc < 0x400:
            a.nop()
        a.li("t1", 0x5000)
        a.ld("a0", 0, "t1")             # VA == GPA → G-stage only
        a.ecall()
        m_handler_capture(a)

    st = run_asm(build, ticks=600)
    assert int(st.regs[10]) == MAGIC


def test_second_stage_only_gstage_fault():
    def build(a, img):
        prologue(a)
        build_gstage_identity(img, pages=range(0, 0x6000, 0x1000))
        enter_vs(a, 0x400, vsatp=0)
        while a.pc < 0x400:
            a.nop()
        a.li("t1", 0x9010)              # GPA unmapped
        a.ld("a0", 0, "t1")
        a.ecall()
        m_handler_capture(a)

    st = run_asm(build)
    assert result(st) == C.EXC_LGUEST_PAGE_FAULT
    assert csr_of(st, C.R_MTVAL2) == 0x9010 >> 2


# ---------------------------------------------------------------------------
# tinst_tests — pseudoinstruction vs transformed instruction vs zero
# ---------------------------------------------------------------------------

def test_tinst_explicit_load_transformed():
    def build(a, img):
        prologue(a)
        build_vs_identity(img)
        build_gstage_identity(
            img, pages=list(range(0, 0x6000, 0x1000)) +
            [S_L2, S_L1, S_L0])
        enter_vs(a, 0x400, vsatp=SV39 | (S_L2 >> 12))
        while a.pc < 0x400:
            a.nop()
        a.li("t1", 0x7008)
        a.ld("a0", 0, "t1")             # explicit load → guest PF
        m_handler_capture(a)

    st = run_asm(build)
    tinst = csr_of(st, C.R_MTINST)
    # transformed: original ld encoding with rs1 cleared
    assert tinst != 0
    assert (tinst & 0x7F) == 0x03       # LOAD opcode preserved
    assert ((tinst >> 15) & 0x1F) == 0  # rs1 zeroed
    assert ((tinst >> 12) & 7) == 3     # funct3 = ld


def test_tinst_implicit_walk_pseudoinstruction():
    def build(a, img):
        prologue(a)
        build_vs_identity(img)
        build_vs_split_data(img)        # VA 0x205000 → GPA 0x5000 via L0B
        # G-stage maps code + main PT pages but NOT the data L0B table →
        # the load's VS-stage PTE fetch guest-faults → pseudoinstr 0x2000
        build_gstage_identity(
            img, pages=list(range(0, 0x6000, 0x1000)) +
            [S_L2, S_L1, S_L0])
        enter_vs(a, 0x400, vsatp=SV39 | (S_L2 >> 12))
        while a.pc < 0x400:
            a.nop()
        a.li("t1", 0x205000)
        a.ld("a0", 0, "t1")
        m_handler_capture(a)

    st = run_asm(build)
    assert csr_of(st, C.R_MTINST) == 0x2000   # load pseudoinstruction
    # cause is still a LOAD guest-page fault (original access type)
    assert result(st) == C.EXC_LGUEST_PAGE_FAULT


# ---------------------------------------------------------------------------
# wfi_exception_tests
# ---------------------------------------------------------------------------

def test_wfi_executes_in_m():
    def build(a, img):
        prologue(a)
        # locally-enabled pending interrupt (mie set, mstatus.MIE clear):
        # wfi completes without trapping (spec WFI semantics)
        a.li("t0", C.IP_MSIP)
        a.csrw(0x344, "t0")             # mip.MSIP pending
        a.li("t0", C.IP_MSIP)
        a.csrw(0x304, "t0")             # mie.MSIE (locally enabled)
        a.wfi()
        a.li("a0", 77)
        exit_with(a, "a0")
        m_handler_capture(a)

    st = run_asm(build)
    assert result(st) == 77


def test_wfi_vtw_virtual_instruction():
    def build(a, img):
        prologue(a)
        build_vs_identity(img)
        build_gstage_identity(img)
        # hstatus.VTW=1 then enter VS; wfi in VS → virtual instruction
        a.li("t0", C.HSTATUS_VTW)
        a.csrw(0x600, "t0")
        enter_vs(a, 0x400, vsatp=0)
        while a.pc < 0x400:
            a.nop()
        a.wfi()
        a.li("a0", 1)
        m_handler_capture(a)

    st = run_asm(build)
    assert result(st) == C.EXC_VIRTUAL_INSTRUCTION


def test_wfi_tw_illegal_from_s():
    def build(a, img):
        prologue(a)
        a.li("t0", C.MSTATUS_TW)
        a.csrrs(0, 0x300, "t0")
        # drop to native S
        a.li("t0", 1 << 11)
        a.csrrs(0, 0x300, "t0")
        a.li("t0", 0x400)
        a.csrw(0x341, "t0")
        a.mret()
        while a.pc < 0x400:
            a.nop()
        a.wfi()
        m_handler_capture(a)

    st = run_asm(build)
    assert result(st) == C.EXC_ILLEGAL


# ---------------------------------------------------------------------------
# virtual_instruction — hfence/sret/CSR access from VS
# ---------------------------------------------------------------------------

def test_hfence_from_vs_is_virtual_instruction():
    def build(a, img):
        prologue(a)
        build_gstage_identity(img)
        enter_vs(a, 0x400, vsatp=0)
        while a.pc < 0x400:
            a.nop()
        a.hfence_gvma()
        m_handler_capture(a)

    st = run_asm(build)
    assert result(st) == C.EXC_VIRTUAL_INSTRUCTION


def test_h_csr_from_vs_is_virtual_instruction():
    def build(a, img):
        prologue(a)
        build_gstage_identity(img)
        enter_vs(a, 0x400, vsatp=0)
        while a.pc < 0x400:
            a.nop()
        a.csrr("t0", 0x680)             # hgatp from VS
        m_handler_capture(a)

    st = run_asm(build)
    assert result(st) == C.EXC_VIRTUAL_INSTRUCTION


def test_vtsr_sret_virtual_instruction():
    def build(a, img):
        prologue(a)
        build_gstage_identity(img)
        a.li("t0", C.HSTATUS_VTSR)
        a.csrw(0x600, "t0")
        enter_vs(a, 0x400, vsatp=0)
        while a.pc < 0x400:
            a.nop()
        a.sret()
        m_handler_capture(a)

    st = run_asm(build)
    assert result(st) == C.EXC_VIRTUAL_INSTRUCTION


# ---------------------------------------------------------------------------
# check_xip_regs — aliasing of interrupt-pending registers
# ---------------------------------------------------------------------------

def test_hvip_aliases_mip_and_vsip_shift():
    def build(a, img):
        prologue(a)
        # write hvip.VSSIP (bit 2); read mip and vsip
        a.li("t0", C.IP_VSSIP)
        a.csrw(0x645, "t0")             # hvip
        a.csrr("t1", 0x344)             # mip — expect bit 2
        a.csrr("t2", 0x244)             # vsip — expect bit 1 (shifted)…
        # …but vsip gating needs hideleg.VSSIP
        a.li("t0", 0x444)
        a.csrw(0x603, "t0")             # hideleg
        a.csrr("t3", 0x244)             # vsip now shows SSIP
        a.slli("t1", "t1", 8)
        a.slli("t3", "t3", 16)
        a.or_("a0", "t1", "t3")
        exit_with(a, "a0")
        m_handler_capture(a)

    st = run_asm(build)
    r = result(st)
    assert (r >> 8) & 0xFF == C.IP_VSSIP      # mip.VSSIP set via hvip alias
    assert (r >> 16) & 0xFF == C.IP_SSIP      # vsip shows it at SSIP position


def test_mideleg_vs_bits_read_only_one():
    def build(a, img):
        prologue(a)
        a.csrw(0x303, "zero")           # try to clear mideleg
        a.csrr("a0", 0x303)
        exit_with(a, "a0")
        m_handler_capture(a)

    st = run_asm(build)
    # VS interrupt bits are forced-one (paper: "read-only 1-bit fields")
    assert result(st) & C.HS_INTERRUPTS == C.HS_INTERRUPTS


# ---------------------------------------------------------------------------
# m_and_hs_using_vs_access — hlv/hsv
# ---------------------------------------------------------------------------

def test_hlv_reads_through_guest_translation():
    MAGIC = 0xBEEF

    def build(a, img):
        prologue(a)
        img.store64(0x5000, MAGIC)
        build_vs_identity(img)
        build_gstage_identity(img)
        # from M: set vsatp+hgatp, hstatus.SPVP=1, then hlv.d VA 0x5000
        a.li("t0", SV39 | (G_L2 >> 12))
        a.csrw(0x680, "t0")
        a.li("t0", SV39 | (S_L2 >> 12))
        a.csrw(0x280, "t0")
        a.li("t0", C.HSTATUS_SPVP)
        a.csrw(0x600, "t0")
        a.li("t1", 0x5000)
        a.hlv_d("a0", "t1")
        exit_with(a, "a0")
        m_handler_capture(a)

    st = run_asm(build)
    assert result(st) == MAGIC


def test_hsv_writes_through_guest_translation():
    def build(a, img):
        prologue(a)
        build_vs_identity(img)
        build_gstage_identity(img)
        a.li("t0", SV39 | (G_L2 >> 12))
        a.csrw(0x680, "t0")
        a.li("t0", SV39 | (S_L2 >> 12))
        a.csrw(0x280, "t0")
        a.li("t0", C.HSTATUS_SPVP)
        a.csrw(0x600, "t0")
        a.li("t1", 0x5100)
        a.li("t2", 4242)
        a.hsv_d("t2", "t1")
        a.ld("a0", 0x100, "zero")       # hmm — read back via M bare: 0x5100
        a.li("t3", 0x5100)
        a.ld("a0", 0, "t3")
        exit_with(a, "a0")
        m_handler_capture(a)

    st = run_asm(build)
    assert result(st) == 4242


def test_hlv_guest_page_fault_on_unmapped():
    def build(a, img):
        prologue(a)
        build_vs_identity(img)
        build_gstage_identity(img, pages=range(0, 0x6000, 0x1000))
        a.li("t0", SV39 | (G_L2 >> 12))
        a.csrw(0x680, "t0")
        a.li("t0", SV39 | (S_L2 >> 12))
        a.csrw(0x280, "t0")
        a.li("t0", C.HSTATUS_SPVP)
        a.csrw(0x600, "t0")
        a.li("t1", 0x9000)
        a.hlv_d("a0", "t1")
        m_handler_capture(a)

    st = run_asm(build)
    assert result(st) == C.EXC_LGUEST_PAGE_FAULT
    assert csr_of(st, C.R_MSTATUS) & C.MSTATUS_GVA


# ---------------------------------------------------------------------------
# hfence_tests — TLB invalidation semantics
# ---------------------------------------------------------------------------

def test_hfence_flushes_stale_guest_translation():
    def build(a, img):
        prologue(a)
        img.store64(0x5000, 111)
        img.store64(0x6000, 222)
        build_vs_identity(img)
        build_gstage_identity(img)
        a.li("t0", SV39 | (G_L2 >> 12))
        a.csrw(0x680, "t0")
        a.li("t0", SV39 | (S_L2 >> 12))
        a.csrw(0x280, "t0")
        a.li("t0", C.HSTATUS_SPVP)
        a.csrw(0x600, "t0")
        a.li("t1", 0x5000)
        a.hlv_d("s0", "t1")             # caches VA 0x5000 → PA 0x5000
        # hypervisor remaps GPA 0x5000 → HPA 0x6000 in the G-stage
        a.li("t2", G_L0 + (0x5 * 8))
        a.li("t3", ((0x6000 >> 12) << 10) | P_GUEST)
        a.sd("t3", 0, "t2")
        a.hlv_d("s1", "t1")             # STALE TLB → still 111
        a.hfence_gvma()
        a.hlv_d("s2", "t1")             # fresh walk → 222
        a.slli("s1", "s1", 16)
        a.slli("s2", "s2", 32)
        a.or_("a0", "s0", "s1")
        a.or_("a0", "a0", "s2")
        exit_with(a, "a0")
        m_handler_capture(a)

    st = run_asm(build)
    r = result(st)
    assert r & 0xFFFF == 111
    assert (r >> 16) & 0xFFFF == 111    # stale entry used before hfence
    assert (r >> 32) & 0xFFFF == 222    # hfence → new mapping visible


def test_sfence_does_not_flush_guest_entries():
    """sfence.vma (native) must leave guest-tagged TLB entries intact —
    the paper's 'hfence affects only guest entries', inverted."""
    def build(a, img):
        prologue(a)
        img.store64(0x5000, 111)
        img.store64(0x6000, 222)
        build_vs_identity(img)
        build_gstage_identity(img)
        a.li("t0", SV39 | (G_L2 >> 12))
        a.csrw(0x680, "t0")
        a.li("t0", SV39 | (S_L2 >> 12))
        a.csrw(0x280, "t0")
        a.li("t0", C.HSTATUS_SPVP)
        a.csrw(0x600, "t0")
        a.li("t1", 0x5000)
        a.hlv_d("s0", "t1")             # guest entry cached
        a.li("t2", G_L0 + (0x5 * 8))
        a.li("t3", ((0x6000 >> 12) << 10) | P_GUEST)
        a.sd("t3", 0, "t2")
        a.sfence_vma()                  # flushes NATIVE entries only
        a.hlv_d("a0", "t1")             # guest entry survives → stale 111
        exit_with(a, "a0")
        m_handler_capture(a)

    st = run_asm(build)
    assert result(st) == 111


# ---------------------------------------------------------------------------
# interrupt_tests — priority & delegation level
# ---------------------------------------------------------------------------

def test_interrupt_msi_taken_in_m():
    def build(a, img):
        prologue(a)
        a.li("t0", C.IP_MSIP)
        a.csrw(0x344, "t0")             # mip.MSIP pending
        a.li("t0", C.IP_MSIP)
        a.csrw(0x304, "t0")             # mie.MSIE
        a.li("t0", C.MSTATUS_MIE)
        a.csrrs(0, 0x300, "t0")         # global enable → take MSI
        a.nop()
        a.nop()
        m_handler_capture(a)

    st = run_asm(build, ticks=600)
    assert result(st) == (1 << 63) | 3  # MSI cause, interrupt bit set
    assert int(st.counters.int_by_level[0]) == 1


def test_vssi_injected_and_handled_at_vs():
    """Hypervisor injects hvip.VSSIP; guest with vsie.SSIE+vsstatus.SIE takes
    it at VS with vscause = SSI (shifted encoding)."""
    def build(a, img):
        prologue(a)
        build_vs_identity(img)
        build_gstage_identity(img)
        a.li("t0", 0x444)
        a.csrw(0x603, "t0")             # hideleg: VS interrupts → VS
        a.li("t0", C.IP_VSSIP)
        a.csrw(0x645, "t0")             # hvip.VSSIP injected
        enter_vs(a, 0x400, vsatp=0)
        while a.pc < 0x400:
            a.nop()
        # VS: set vstvec, enable SSI, wait
        a.li("t0", 0x500)
        a.csrw(0x105, "t0")             # stvec → vstvec (swap)
        a.li("t0", C.IP_SSIP)
        a.csrw(0x104, "t0")             # sie → vsie (shifted alias)
        a.li("t0", C.MSTATUS_SIE)
        a.csrrs(0, 0x100, "t0")         # sstatus.SIE → vsstatus.SIE
        a.nop()
        a.nop()
        a.nop()
        a.li("a0", 999)                 # should NOT reach before interrupt
        while a.pc < 0x500:
            a.nop()
        # VS trap handler: capture vscause (via scause swap) then ecall → M…
        a.csrr("a0", 0x142)             # scause (vscause)
        a.ecall()
        m_handler_capture(a)

    st = run_asm(build)
    # vscause = interrupt | 1 (SSI at supervisor encoding)
    assert int(st.regs[10]) == (1 << 63) | 1
    assert int(st.counters.int_by_level[2]) == 1    # handled at VS


def test_interrupt_to_hs_when_not_hideleg():
    """VSSIP pending but hideleg=0 → handled at HS level, not VS."""
    def build(a, img):
        prologue(a)
        build_gstage_identity(img)
        a.csrw(0x603, "zero")           # hideleg = 0
        a.li("t0", C.IP_VSSIP)
        a.csrw(0x645, "t0")
        # HS: stvec handler, enable VSSIE at mie… (hie alias)
        a.li("t0", 0x500)
        a.csrw(0x105, "t0")             # stvec (HS)
        a.li("t0", C.IP_VSSIP)
        a.csrw(0x604, "t0")             # hie
        a.li("t0", 1 << 11)
        a.csrrs(0, 0x300, "t0")         # MPP=S
        a.li("t0", 0x400)
        a.csrw(0x341, "t0")
        a.mret()                        # → HS with SIE=0: still takes VSSI?
        while a.pc < 0x400:
            a.nop()
        a.li("t0", C.MSTATUS_SIE)
        a.csrrs(0, 0x100, "t0")         # sstatus.SIE=1 at HS
        a.nop()
        a.nop()
        a.li("a0", 999)
        while a.pc < 0x500:
            a.nop()
        a.csrr("a0", 0x142)             # scause at HS
        a.ecall()
        m_handler_capture(a)

    st = run_asm(build)
    assert int(st.regs[10]) == (1 << 63) | 2   # VSSI cause (2) at HS
    assert int(st.counters.int_by_level[1]) == 1
