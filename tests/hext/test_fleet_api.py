"""Smoke test for the typed simulation API (DESIGN.md §3).

`Fleet.boot` + `fleet.run` (on-device `lax.while_loop` early exit) must
reproduce, counter-for-counter, what the legacy host-sync chunk loop
computed over hand-stacked raw dicts — same `instret`, same
`exc_by_level`, same exit codes — on ≥2 workloads, native and guest.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.hext import machine, programs
from repro.core.hext.sim import Counters, Fleet, HartState, checksum_ok

MAX_TICKS = 30000
CHUNK = 2048


def _legacy_host_loop(raw_batch, max_ticks, chunk):
    """The pre-Fleet algorithm: jitted vmapped chunk scan with a per-chunk
    `bool(jnp.all(...))` host sync — the reference for counter parity."""
    with jax.experimental.enable_x64():
        def body(s, _):
            return machine.step(s), None
        one = lambda s: jax.lax.scan(body, s, None, length=chunk)[0]
        chunk_fn = jax.jit(jax.vmap(one))
        t = 0
        while t < max_ticks:
            raw_batch = chunk_fn(raw_batch)
            t += chunk
            if bool(jnp.all(raw_batch["done"])):
                break
        return raw_batch


@pytest.fixture(scope="module")
def fleet_and_legacy():
    wls = [programs.BitCount(), programs.SHA()]
    guests = [False, False, True, True]
    pairs = list(zip(wls + wls, guests))

    fleet = Fleet.boot([w for w, _ in pairs], guest=guests)
    fleet.run(MAX_TICKS, chunk=CHUNK)

    with jax.experimental.enable_x64():
        states = [HartState.boot(w, guest=g).to_raw() for w, g in pairs]
        raw = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    raw = _legacy_host_loop(raw, MAX_TICKS, CHUNK)
    return pairs, fleet, raw


def test_fleet_matches_legacy_counters(fleet_and_legacy):
    pairs, fleet, raw = fleet_and_legacy
    for i, c in enumerate(fleet.counters()):
        assert bool(c.done), pairs[i]
        assert int(c.instret) == int(raw["instret"][i]), pairs[i]
        assert int(c.instret_virt) == int(raw["instret_virt"][i]), pairs[i]
        assert int(c.ticks) == int(raw["ticks"][i]), pairs[i]
        assert c.exc_by_level.tolist() == raw["exc_by_level"][i].tolist()
        assert c.int_by_level.tolist() == raw["int_by_level"][i].tolist()
        assert int(c.pagefaults) == int(raw["pagefaults"][i]), pairs[i]
        assert int(c.walks) == int(raw["walks"][i]), pairs[i]
        assert int(c.exit_code) == int(raw["exit_code"][i]), pairs[i]


def test_fleet_golden_checks(fleet_and_legacy):
    pairs, fleet, _ = fleet_and_legacy
    for (w, _), c in zip(pairs, fleet.counters()):
        assert c.ok(w.golden()), w.name
    report = fleet.report()
    assert set(report) == {"bitcount/native", "sha/native",
                           "bitcount/guest", "sha/guest"}
    for entry in report.values():
        assert entry["ok"] and entry["done"]


def test_to_dict_exit_code_reproduces_checksum(fleet_and_legacy):
    """A report entry must carry the exact uint64 checksum its `ok` was
    computed from (`exit_code`), so the committed benchmark records are
    self-verifying: checksum_ok(entry['exit_code'], entry['golden'])."""
    pairs, fleet, _ = fleet_and_legacy
    for (w, _), c in zip(pairs, fleet.counters()):
        d = c.to_dict(w.golden())
        assert d["exit_code"] == int(c.exit_code) & ((1 << 64) - 1)
        assert checksum_ok(d["exit_code"], w.golden()) == d["ok"]
    for entry in fleet.report().values():
        assert "exit_code" in entry
        assert checksum_ok(entry["exit_code"], entry["golden"])


def test_counters_ok_is_mod_2_64():
    # one canonical uint64 comparison: both sides reduced mod 2**64
    assert checksum_ok(0, 1 << 64)
    assert not checksum_ok(1, 1 + (1 << 63))
    # top-bit-set goldens must not be truncated by a signed/63-bit mask
    top = (1 << 63) | 5
    assert checksum_ok(top, top)
    assert not checksum_ok(top & ((1 << 63) - 1), top)
    with jax.experimental.enable_x64():
        z = Counters.zero()
        assert z.ok(0) and not z.ok(top)


def test_hartstate_raw_round_trip():
    st = HartState.fresh(1 << 10)
    st2 = HartState.from_raw(st.to_raw())
    leaves1 = jax.tree_util.tree_leaves(st)
    leaves2 = jax.tree_util.tree_leaves(st2)
    assert len(leaves1) == len(leaves2)
    for a, b in zip(leaves1, leaves2):
        assert a.shape == b.shape and a.dtype == b.dtype


# ---------------------------------------------------------------------------
# N-guest VMM smoke (quick CI): three tiny tenants under the scheduler
# ---------------------------------------------------------------------------

class _Const(programs.Workload):
    """Trivial tenant returning a constant — boots the full VS kernel
    (paging + demand faults) but finishes within a couple of timeslices,
    keeping this in the quick (not slow) suite."""

    def __init__(self, name, val):
        self.name, self.val = name, val

    def asm(self, a):
        a.label("workload_entry")
        a.li("a0", self.val)
        a.ret()

    def golden(self):
        return self.val


def test_three_guest_smoke():
    trio = tuple(_Const(f"c{i}", 100 + i) for i in range(3))
    fleet = Fleet.boot([trio], guests_per_hart=3, timeslice=100)
    fleet.run(20000, chunk=512)
    rep = fleet.report()["c0+c1+c2/3guest-preempt"]
    assert rep["done"] and rep["ok"]
    assert rep["guests"] == 3 and all(rep["ok_guests"])
    assert rep["checksums"] == [100, 101, 102]   # per-guest mailboxes
    assert rep["ctx_switches"] >= 2              # every tenant got the CPU
    assert rep["int_by_level"][1] == rep["timer_irqs"]


def test_preemptive_boot_rejects_mismatched_tuple_and_guest_flag():
    trio = tuple(_Const(f"c{i}", i) for i in range(3))
    with pytest.raises(ValueError):
        Fleet.boot([trio], guests_per_hart=2)    # length-3 tuple for N=2
    with pytest.raises(ValueError):
        Fleet.boot([trio[0]], guests_per_hart=3, guest=True)
    with pytest.raises(ValueError):
        Fleet.boot([trio[0]], guests_per_hart=0)
