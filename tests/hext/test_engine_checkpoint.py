"""Pluggable Engine backends + gem5-style checkpoint/restore (ISSUE 5).

Quick tests (CI push gate):
* engine registry resolution / rejection,
* the OracleEngine differential smoke (jit vs oracle on a native+guest
  pair, field-by-field diff empty),
* a checkpoint round-trip smoke (snapshot mid-run → restore → resume ==
  uninterrupted, bit for bit),
* corrupted / schema-mismatched snapshots rejected,
* the `fleet.harts` stale-donated-buffer guard.

Slow tests (nightly / full suite):
* all three engines run the 9-workload native/guest matrix with counters
  bit-identical to the committed `hext_runs.json` goldens,
* snapshot-resume bit-identity for native, guest, and an N=4 preemptive
  slot,
* a true multi-device ShardedEngine run (subprocess with forced host
  devices) matching JitEngine per hart,
* the live-migration demo: a mid-flight guest moves harts and still hits
  its golden checksum on the destination.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.hext import checkpoint, engine, programs
from repro.core.hext.sim import (Fleet, MigrationError, StaleHartsError,
                                 MASK64, checksum_ok)

REPO = pathlib.Path(__file__).resolve().parents[2]
CHUNK = 1024


def _boot_sha_pair(engine_name=None):
    wl = programs.SHA()
    return Fleet.boot([wl, wl], guest=[False, True], engine=engine_name)


def _assert_states_identical(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    with jax.experimental.enable_x64():
        for x, y in zip(la, lb):
            assert np.array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_engine_registry_resolution():
    assert engine.resolve(None).name == "jit"
    assert engine.resolve("jit").name == "jit"
    assert engine.resolve("sharded").name == "sharded"
    assert engine.resolve("oracle").name == "oracle"
    inst = engine.JitEngine(donate=False)
    assert engine.resolve(inst) is inst           # instances pass through
    with pytest.raises(ValueError, match="unknown engine"):
        engine.resolve("warp-drive")
    with pytest.raises(TypeError):
        engine.resolve(42)
    # Fleet plumbs the selection through
    assert _boot_sha_pair("oracle").engine.name == "oracle"
    assert _boot_sha_pair().engine.name == "jit"


# ---------------------------------------------------------------------------
# OracleEngine differential smoke (the CI push gate)
# ---------------------------------------------------------------------------

def test_oracle_engine_differential_smoke():
    """The same native+guest pair through the jit and oracle backends must
    agree on every architectural field — the oracle models the software
    TLB too, so `walks` is in scope — and both hit the workload golden."""
    golden = programs.SHA().golden()
    fj = _boot_sha_pair().run(30000, chunk=CHUNK)
    fo = _boot_sha_pair("oracle").run(30000, chunk=CHUNK)
    for i in range(2):
        assert engine.diff_states(fj[i], fo[i]) == [], f"hart {i}"
        assert fj[i].counters.ok(golden) and fo[i].counters.ok(golden)
    # the oracle independently reproduced the machine's TLB-miss count
    assert int(fj[0].counters.walks) > 0
    assert int(fo[0].counters.walks) == int(fj[0].counters.walks)


# ---------------------------------------------------------------------------
# checkpoint round-trip (the CI push gate)
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_smoke(tmp_path):
    """snapshot mid-run → restore → resume must be bit-identical to an
    uninterrupted run (every leaf: counters, memory, TLB, CSRs)."""
    ref = _boot_sha_pair().run(30000, chunk=CHUNK)
    part = _boot_sha_pair().run(600, chunk=CHUNK)     # mid-run (not done)
    assert not part.all_done
    path = tmp_path / "fleet.npz"
    part.snapshot(path)
    resumed = Fleet.restore(path)
    resumed.run(30000, chunk=CHUNK)
    _assert_states_identical(ref.harts.unwrap(), resumed.harts.unwrap())
    # specs survived by name: the report still carries golden checks
    rep = resumed.report()
    assert rep["sha/native"]["ok"] and rep["sha/guest"]["ok"]
    assert rep["sha/guest"]["exit_code"] == \
        int(programs.SHA().golden()) & MASK64


def test_checkpoint_rejects_corruption_and_schema_mismatch(tmp_path):
    fleet = _boot_sha_pair()                      # boot only — no run
    path = tmp_path / "ok.npz"
    fleet.snapshot(path)
    Fleet.restore(path)                           # sanity: loads clean

    # truncated file
    blob = path.read_bytes()
    trunc = tmp_path / "trunc.npz"
    trunc.write_bytes(blob[: len(blob) // 2])
    with pytest.raises(checkpoint.CheckpointError):
        Fleet.restore(trunc)

    # not a checkpoint at all
    junk = tmp_path / "junk.npz"
    junk.write_bytes(b"\x00" * 512)
    with pytest.raises(checkpoint.CheckpointError):
        Fleet.restore(junk)

    def rewrite(dst, mutate_meta=None, drop=None):
        with np.load(path, allow_pickle=False) as z:
            arrays = {k: z[k] for k in z.files if k != "__meta__"}
            meta = json.loads(str(z["__meta__"][()]))
        if mutate_meta:
            mutate_meta(meta)
        if drop:
            arrays.pop(drop)
        np.savez_compressed(dst, __meta__=np.array(json.dumps(meta)),
                            **arrays)

    # wrong version
    vbad = tmp_path / "vbad.npz"
    rewrite(vbad, mutate_meta=lambda m: m.update(version=999))
    with pytest.raises(checkpoint.CheckpointError, match="version"):
        Fleet.restore(vbad)

    # missing field → schema hash no longer matches the arrays
    fbad = tmp_path / "fbad.npz"
    rewrite(fbad, drop="csrs")
    with pytest.raises(checkpoint.CheckpointError):
        Fleet.restore(fbad)

    # tampered schema hash
    hbad = tmp_path / "hbad.npz"
    rewrite(hbad, mutate_meta=lambda m: m.update(
        schema_sha256="0" * 64))
    with pytest.raises(checkpoint.CheckpointError, match="schema"):
        Fleet.restore(hbad)

    # spec count mismatch on explicit override
    with pytest.raises(ValueError):
        Fleet.restore(path, specs=fleet.specs[:1])


class _CustomWl(programs.Workload):
    name = "notinregistry"

    def asm(self, a):
        a.label("workload_entry")
        a.li("a0", 1234)
        a.ret()

    def golden(self):
        return 1234


def test_restore_unknown_workload_needs_explicit_specs(tmp_path):
    """Custom workloads can't travel by name: the restored spec carries
    workload=None (no golden check) unless the caller passes specs."""
    wl = _CustomWl()
    fleet = Fleet.boot([wl, wl], guest=[False, True])
    path = tmp_path / "custom.npz"
    fleet.snapshot(path)
    restored = Fleet.restore(path)
    assert all(s.workload is None for s in restored.specs)
    assert "ok" not in restored.report()["notinregistry/native"]
    explicit = Fleet.restore(path, specs=fleet.specs)
    assert explicit.specs[0].workload is wl


def test_restore_preemptive_unknown_guest_rejected(tmp_path):
    """A preemptive spec with an unresolvable guest name must NOT decode
    to None (the report layer reads None as 'migrated away' and would
    mis-total the expected checksum) — restore demands explicit specs."""
    wl = _CustomWl()
    fleet = Fleet.boot([(wl, programs.SHA())], guests_per_hart=2,
                       timeslice=300)
    path = tmp_path / "pcustom.npz"
    fleet.snapshot(path)
    with pytest.raises(checkpoint.CheckpointError, match="registry"):
        Fleet.restore(path)
    explicit = Fleet.restore(path, specs=fleet.specs)
    assert explicit.specs[0].guests[0] is wl


# ---------------------------------------------------------------------------
# stale-donated-buffer guard
# ---------------------------------------------------------------------------

def test_stale_harts_reference_raises():
    fleet = _boot_sha_pair()
    view = fleet.harts
    _ = view.pc                                   # live before the run
    fleet.run(2000, chunk=CHUNK)
    with pytest.raises(StaleHartsError, match="generation"):
        _ = view.pc
    with pytest.raises(StaleHartsError):
        view.unwrap()
    fresh = fleet.harts                           # re-read after the run
    assert np.asarray(fresh.pc).shape == (2,)
    assert fresh.unwrap() is fleet.harts.unwrap()
    # a rejected migration does NOT bump the generation
    with pytest.raises(MigrationError):
        fleet.migrate_guest(0, 1)                 # not preemptive slots
    _ = fresh.pc                                  # still live


# ---------------------------------------------------------------------------
# ShardedEngine
# ---------------------------------------------------------------------------

def test_sharded_engine_fallback_matches_jit():
    """On a single device ShardedEngine must fall back to the jit path and
    produce identical results (on a forced multi-device host this instead
    exercises the pmap path — equally required to match)."""
    fj = _boot_sha_pair().run(30000, chunk=CHUNK)
    fs = _boot_sha_pair("sharded").run(30000, chunk=CHUNK)
    for i in range(2):
        assert engine.diff_states(fs[i], fj[i]) == []
        assert int(fs[i].counters.walks) == int(fj[i].counters.walks)


def test_instrs_per_step_bit_identical():
    """The multi-instruction dispatch knob (DESIGN.md §7d) unrolls N
    architectural ticks per scan element — every counter and every
    architectural field must be bit-identical to the N=1 engine."""
    fj = _boot_sha_pair().run(30000, chunk=CHUNK)
    for ips in (2, 8):
        eng = engine.JitEngine(instrs_per_step=ips)
        fu = Fleet.boot([programs.SHA()] * 2, guest=[False, True],
                        engine=eng).run(30000, chunk=CHUNK)
        for i in range(2):
            assert engine.diff_states(fu[i], fj[i]) == [], f"ips={ips}"
            _assert_states_identical(fu[i], fj[i])
    with pytest.raises(ValueError, match="instrs_per_step"):
        engine._check_ips(CHUNK, 3)       # 1024 % 3 != 0


@pytest.mark.slow
def test_sharded_engine_multi_device_matches_jit():
    """The real pmap path: 4 forced host devices, 6 harts (padding 6→8).
    Per-hart results must be bit-identical to the jit engine."""
    script = textwrap.dedent("""
        import numpy as np, jax
        assert len(jax.devices()) == 4, jax.devices()
        from repro.core.hext.sim import Fleet
        from repro.core.hext import engine, programs

        def img(val):
            a = programs.Asm(0)
            a.li('a0', val)
            a.li('t6', 0x10000008)
            a.sd('a0', 0, 't6')
            a.label('sp'); a.j('sp')
            im = programs.Image(256)
            im.place_code(0, a.assemble())
            return im.mem

        imgs = [img(100 + i) for i in range(6)]
        fj = Fleet.from_images(imgs, mem_words=256).run(512, chunk=128)
        fs = Fleet.from_images(imgs, mem_words=256,
                               engine='sharded').run(512, chunk=128)
        for i in range(6):
            assert engine.diff_states(fs[i], fj[i]) == [], i
            assert int(fs[i].counters.walks) == int(fj[i].counters.walks)
            assert int(fs[i].counters.exit_code) == 100 + i
        print('SHARDED-MULTI-OK')
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(REPO / "src")
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         cwd=str(REPO), capture_output=True, text=True,
                         timeout=600)
    assert res.returncode == 0, res.stderr
    assert "SHARDED-MULTI-OK" in res.stdout


# ---------------------------------------------------------------------------
# acceptance: all three engines × the 9-workload native/guest matrix
# ---------------------------------------------------------------------------

def _committed_workloads():
    path = REPO / "benchmarks" / "results" / "hext_runs.json"
    return json.loads(path.read_text())["workloads"]


_GOLDEN_KEYS = ("instret", "instret_virt", "ticks", "exc_by_level",
                "int_by_level", "pagefaults", "timer_irqs", "ctx_switches",
                "exit_code")


@pytest.mark.slow
def test_all_engines_match_committed_goldens():
    """jit, sharded, and oracle all run the full native/guest matrix with
    counters bit-identical to the committed hext_runs.json (the oracle
    skips only the microarchitectural `walks`)."""
    ref = _committed_workloads()
    wls = programs.WORKLOADS
    flags = [False] * len(wls) + [True] * len(wls)

    def matrix(engine_name):
        return Fleet.boot(wls + wls, guest=flags,
                          engine=engine_name).run(120000, chunk=8192)

    fleets = {name: matrix(name) for name in ("jit", "sharded", "oracle")}
    for name, fleet in fleets.items():
        rep = fleet.report()
        for i, w in enumerate(wls):
            for mode in ("native", "guest"):
                got = rep[f"{w.name}/{mode}"]
                assert got["ok"], (name, w.name, mode)
                for key in _GOLDEN_KEYS:
                    assert got[key] == ref[w.name][mode][key], \
                        (name, w.name, mode, key)
                if name != "oracle":              # walks: device-only
                    assert got["walks"] == ref[w.name][mode]["walks"], \
                        (name, w.name, mode)


# ---------------------------------------------------------------------------
# snapshot-resume bit-identity per workload class
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_snapshot_resume_bit_identical_native_and_guest(tmp_path):
    wl = programs.CRC32()

    def boot():
        return Fleet.boot([wl, wl], guest=[False, True])

    ref = boot().run(30000, chunk=CHUNK)
    part = boot().run(1200, chunk=CHUNK)
    assert not part.all_done                      # genuinely mid-run
    path = tmp_path / "crc.npz"
    part.snapshot(path)
    resumed = Fleet.restore(path).run(30000, chunk=CHUNK)
    _assert_states_identical(ref.harts.unwrap(), resumed.harts.unwrap())
    rep = resumed.report()
    assert rep["crc32/native"]["ok"] and rep["crc32/guest"]["ok"]


@pytest.mark.slow
def test_snapshot_resume_bit_identical_n4_preemptive(tmp_path):
    quad = (programs.SHA(), programs.FFT(), programs.CRC32(),
            programs.BitCount())

    def boot():
        return Fleet.boot([quad], guests_per_hart=4, timeslice=300)

    ref = boot().run(120000, chunk=2048)
    part = boot().run(6000, chunk=2048)
    assert not part.all_done
    path = tmp_path / "quad.npz"
    part.snapshot(path)
    resumed = Fleet.restore(path).run(120000, chunk=2048)
    _assert_states_identical(ref.harts.unwrap(), resumed.harts.unwrap())
    rep = resumed.report()["sha+fft+crc32+bitcount/4guest-preempt"]
    assert rep["ok"] and all(rep["ok_guests"])
    assert rep["guests"] == 4


# ---------------------------------------------------------------------------
# live migration demo
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_migrate_guest_mid_run_hits_golden_on_destination():
    """crc32 starts on hart 0, is migrated mid-flight into hart 1's slot 1
    (vaporizing the fft tenant there), and must still hit its golden on
    the destination — proof the copied window/context/tables carried the
    running VM.  The source hart finishes with only sha checked."""
    sha, crc, bits, fft = (programs.SHA(), programs.CRC32(),
                           programs.BitCount(), programs.FFT())
    fleet = Fleet.boot([(sha, crc), (bits, fft)], guests_per_hart=2,
                       timeslice=300)
    fleet.run(1000, chunk=CHUNK)
    assert not fleet.all_done

    # retry until guest 1 is descheduled on both harts (deterministic but
    # phase-dependent; a few extra slices always suffice)
    for _ in range(12):
        try:
            fleet.migrate_guest(0, 1, guest=1)
            break
        except MigrationError:
            fleet.run(300, chunk=CHUNK)
    else:
        pytest.fail("guest 1 never became migratable")

    assert fleet.specs[0].guests[1] is None
    assert fleet.specs[1].guests[1] is crc
    fleet.run(120000, chunk=CHUNK)
    rep = fleet.report()

    src = rep["sha+moved/2guest-preempt"]
    assert src["done"] and src["ok"]
    assert src["ok_guests"] == [True, None]
    assert src["checksums"][1] == 0               # mailbox zeroed on exit
    assert src["golden"] == int(sha.golden()) & MASK64
    assert checksum_ok(src["exit_code"], sha.golden())

    dst = rep["bitcount+crc32/2guest-preempt"]
    assert dst["done"] and dst["ok"]
    assert dst["ok_guests"] == [True, True]
    assert dst["checksums"][1] == int(crc.golden()) & MASK64
    total = (int(bits.golden()) + int(crc.golden())) & MASK64
    assert checksum_ok(dst["exit_code"], total)


def test_migrate_guest_precondition_errors():
    sha = programs.SHA()
    fleet = Fleet.boot([(sha, sha), (sha, sha)], guests_per_hart=2,
                       timeslice=300)
    with pytest.raises(MigrationError, match="different"):
        fleet.migrate_guest(0, 0, guest=0)
    with pytest.raises(MigrationError, match="out of range"):
        fleet.migrate_guest(0, 1, guest=5)
    # at boot the hart is still in M firmware (V=0): refuse — whenever
    # the scheduler (or firmware) owns the hart a context switch may be
    # in flight, so SCHED_CUR / context slots are not authoritative
    with pytest.raises(MigrationError, match="V=0"):
        fleet.migrate_guest(0, 1, guest=0)
    plain = _boot_sha_pair()
    with pytest.raises(MigrationError, match="preemptive"):
        plain.migrate_guest(0, 1)
