"""Checkpoint/restore, preemption, elasticity, and supervisor retry tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.manager import CheckpointManager
from repro.runtime.fault_tolerance import (ElasticMeshManager,
                                           HeartbeatMonitor, TrainSupervisor)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "opt": {"m": jnp.zeros((8, 8)), "step": jnp.asarray(3)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = _tree()
    ck.save(7, t)
    assert ck.latest_step() == 7
    r = ck.restore(7, jax.tree.map(np.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_no_tmp_visible(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, _tree())
    ck.save(2, _tree(1))
    names = os.listdir(tmp_path)
    assert not any(n.endswith(".tmp") for n in names)
    assert ck.latest_step() == 2


def test_manager_keep_n_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=2,
                            async_save=False)
    t = _tree()
    for step in range(1, 6):
        mgr.maybe_save(step, jax.tree.map(lambda x: x + step, t))
    mgr.finalize()
    state, start = mgr.restore_or_init(lambda: jax.tree.map(np.zeros_like,
                                                            t))
    assert start == 5
    # keep=2 garbage collection
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) <= 2


def test_supervisor_retries_through_injected_failures(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=3,
                            async_save=False)
    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        if calls["n"] in (3, 7):          # inject two transient faults
            raise RuntimeError("injected chip failure")
        state = {"x": state["x"] + 1}
        mgr.maybe_save(step, state)
        return state

    def restore_fn():
        st, sp = mgr.restore_or_init(lambda: {"x": jnp.zeros(())})
        return st, sp

    sup = TrainSupervisor(step_fn, lambda s, st: mgr.maybe_save(s, st,
                                                                force=True),
                          restore_fn, max_retries=3)
    state, step = sup.run({"x": jnp.zeros(())}, 0, 10)
    assert step == 10
    assert len(sup.failures) == 2
    assert float(state["x"]) > 0


def test_elastic_mesh_plan():
    em = ElasticMeshManager(model_axis=16)
    plan = em.plan(512, dead_chips=[17, 300])   # two dead chips, 2 groups
    assert plan["mesh_shape"][1] == 16
    assert plan["mesh_shape"][0] == 30          # 32 groups - 2
    assert abs(plan["microbatch_scale"] - 32 / 30) < 1e-9


def test_heartbeat_straggler_detection():
    hm = HeartbeatMonitor(4, straggler_factor=2.0)
    import time
    for w in range(4):
        for _ in range(5):
            hm.heartbeat(w, step_time=1.0)
    hm.heartbeat(2, step_time=5.0)              # straggler
    assert hm.stragglers() == [2]


def test_restore_with_resharding_specs(tmp_path):
    """Checkpoints store logical specs → restoring onto a different device
    layout is a device_put, not a rewrite."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, t)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    r = ck.restore(1, t, shardings={"w": sh})
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
