"""Checkpoint/restore tests (train-side Checkpointer / CheckpointManager).

The old fault-tolerance scaffolding tests (TrainSupervisor /
ElasticMeshManager / HeartbeatMonitor) left with
``repro.runtime.fault_tolerance``; its straggler accounting and
retry-with-restore loop live on in the hypervisor control plane and are
covered by ``tests/hext/test_service.py``."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "opt": {"m": jnp.zeros((8, 8)), "step": jnp.asarray(3)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = _tree()
    ck.save(7, t)
    assert ck.latest_step() == 7
    r = ck.restore(7, jax.tree.map(np.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_no_tmp_visible(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, _tree())
    ck.save(2, _tree(1))
    names = os.listdir(tmp_path)
    assert not any(n.endswith(".tmp") for n in names)
    assert ck.latest_step() == 2


def test_manager_keep_n_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=2,
                            async_save=False)
    t = _tree()
    for step in range(1, 6):
        mgr.maybe_save(step, jax.tree.map(lambda x: x + step, t))
    mgr.finalize()
    state, start = mgr.restore_or_init(lambda: jax.tree.map(np.zeros_like,
                                                            t))
    assert start == 5
    # keep=2 garbage collection
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) <= 2


def test_restore_with_resharding_specs(tmp_path):
    """Checkpoints store logical specs → restoring onto a different device
    layout is a device_put, not a rewrite."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, t)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    r = ck.restore(1, t, shardings={"w": sh})
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
