"""Per-kernel validation: shape/dtype sweeps, interpret-mode vs jnp oracle,
plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

try:
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.pagewalk.ops import two_stage_translate
except (ImportError, NotImplementedError, RuntimeError) as e:
    # pallas backend unavailable on this host (real bugs still propagate)
    pytest.skip(f"pallas kernel backend unavailable: {e}",
                allow_module_level=True)


# ---------------------------------------------------------------------------
# pagewalk
# ---------------------------------------------------------------------------

def _random_tables(rng, T=3, R=4, P=16, G=32, slots=40):
    vs = rng.randint(-1, G, size=(T, R, P)).astype(np.int32)
    perm = rng.randint(0, 4, size=(T, R, P)).astype(np.int32)
    g = rng.randint(-1, slots, size=(T, G)).astype(np.int32)
    return vs, perm, g


@pytest.mark.parametrize("B", [1, 7, 512, 513])
def test_pagewalk_kernel_matches_ref_shapes(B):
    rng = np.random.RandomState(B)
    vs, perm, g = _random_tables(rng)
    t = rng.randint(0, 3, B).astype(np.int32)
    r = rng.randint(0, 4, B).astype(np.int32)
    p = rng.randint(0, 16, B).astype(np.int32)
    w = rng.randint(0, 2, B).astype(bool)
    a = two_stage_translate(vs, perm, g, t, r, p, w, force="ref")
    b = two_stage_translate(vs, perm, g, t, r, p, w, force="interpret")
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_pagewalk_property_fault_iff_any_stage_invalid(seed):
    rng = np.random.RandomState(seed)
    vs, perm, g = _random_tables(rng)
    B = 64
    t = rng.randint(0, 3, B).astype(np.int32)
    r = rng.randint(0, 4, B).astype(np.int32)
    p = rng.randint(0, 16, B).astype(np.int32)
    w = np.zeros(B, bool)
    slot, fault, stage = two_stage_translate(vs, perm, g, t, r, p, w,
                                             force="ref")
    slot, fault = np.asarray(slot), np.asarray(fault)
    for i in range(B):
        tp = vs[t[i], r[i], p[i]]
        s1_bad = tp < 0 or (perm[t[i], r[i], p[i]] & 1) == 0
        s2_bad = (not s1_bad) and g[t[i], tp] < 0
        assert bool(fault[i]) == (s1_bad or s2_bad)
        if not fault[i]:
            assert slot[i] == g[t[i], tp]


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,hd,page,n_pages", [
    (2, 4, 1, 16, 8, 4),
    (3, 8, 2, 32, 16, 6),
    (1, 16, 8, 64, 8, 3),
])
def test_paged_attention_matches_ref(B, H, KV, hd, page, n_pages):
    rng = np.random.RandomState(0)
    slots = n_pages * B + 2
    q = jnp.asarray(rng.randn(B, H, hd), jnp.float32)
    kp = jnp.asarray(rng.randn(slots, page, KV, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(slots, page, KV, hd), jnp.float32)
    pm = rng.randint(0, slots, size=(B, n_pages)).astype(np.int32)
    lengths = rng.randint(1, n_pages * page, size=B).astype(np.int32)
    a = paged_attention(q, kp, vp, jnp.asarray(pm), jnp.asarray(lengths),
                        hd ** -0.5, force="ref")
    b = paged_attention(q, kp, vp, jnp.asarray(pm), jnp.asarray(lengths),
                        hd ** -0.5, force="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=3e-5, rtol=3e-5)


def test_paged_attention_ignores_unmapped_pages():
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(1, 4, 16), jnp.float32)
    kp = jnp.asarray(rng.randn(8, 8, 2, 16), jnp.float32)
    vp = jnp.asarray(rng.randn(8, 8, 2, 16), jnp.float32)
    pm_full = np.array([[0, 1, 2, 3]], np.int32)
    pm_holes = np.array([[0, 1, -1, -1]], np.int32)
    out_full_16 = paged_attention(q, kp, vp, jnp.asarray(pm_full),
                                  jnp.asarray(np.array([16], np.int32)),
                                  0.25, force="ref")
    out_holes_16 = paged_attention(q, kp, vp, jnp.asarray(pm_holes),
                                   jnp.asarray(np.array([16], np.int32)),
                                   0.25, force="ref")
    # tokens 0..15 live in pages 0,1 → identical with/without tail pages
    np.testing.assert_allclose(np.asarray(out_full_16),
                               np.asarray(out_holes_16), rtol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd,window,dtype", [
    (1, 64, 2, 1, 16, 0, jnp.float32),
    (2, 128, 4, 2, 32, 0, jnp.float32),
    (1, 128, 4, 4, 32, 32, jnp.float32),
    (2, 256, 8, 2, 64, 0, jnp.bfloat16),
])
def test_flash_attention_matches_ref(B, S, H, KV, hd, window, dtype):
    rng = np.random.RandomState(42)
    q = jnp.asarray(rng.randn(B, S, H, hd), dtype) * 0.5
    k = jnp.asarray(rng.randn(B, S, KV, hd), dtype) * 0.5
    v = jnp.asarray(rng.randn(B, S, KV, hd), dtype)
    a = flash_attention(q, k, v, hd ** -0.5, window, force="ref")
    b = flash_attention(q, k, v, hd ** -0.5, window, force="interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=tol, rtol=tol)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100), bq=st.sampled_from([32, 64]),
       bk=st.sampled_from([32, 128]))
def test_flash_attention_block_size_invariance(seed, bq, bk):
    """Property: output independent of BlockSpec tiling."""
    from repro.kernels.flash_attention.kernel import flash_attention_kernel
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(1, 128, 2, 16), jnp.float32)
    k = jnp.asarray(rng.randn(1, 128, 2, 16), jnp.float32)
    v = jnp.asarray(rng.randn(1, 128, 2, 16), jnp.float32)
    a = flash_attention_kernel(q, k, v, 0.25, 0, bq=bq, bk=bk,
                               interpret=True)
    b = flash_attention_kernel(q, k, v, 0.25, 0, bq=128, bk=128,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)
