"""The TPU-native 'gem5 pod': simulate a fleet of VMs in lockstep with one
vmapped step function — the DESIGN.md §2a adaptation, demonstrated through
the typed `Fleet` facade (DESIGN.md §3).

All nine MiBench-like workloads run natively AND as guests (18 machines)
inside a single jitted run: a `lax.while_loop` over chunked vmapped scans
that exits on-device as soon as every machine is done.  Per-machine
architectural counters come back as typed `Counters` records.

Run with the package on the path (see DESIGN.md §6):

    PYTHONPATH=src python examples/batched_fleet_sim.py
"""
import tempfile
import time

from repro.core.hext import programs
from repro.core.hext.sim import Fleet, MigrationError


def main():
    wls = programs.WORKLOADS
    fleet = Fleet.boot(wls + wls, guest=[False] * len(wls) + [True] * len(wls))
    print(f"fleet: {len(fleet)} machines, lockstep vmapped simulation")
    t0 = time.time()
    fleet.run(120000, chunk=8192)
    wall = time.time() - t0
    counters = fleet.counters()
    total = sum(int(c.instret) for c in counters)
    print(f"all done: {fleet.all_done}   total instructions: {total:,}   "
          f"wall: {wall:.1f}s   ({total/wall:,.0f} instr/s aggregate)")
    n = len(wls)
    for i, w in enumerate(wls):
        nat, gst = counters[i], counters[i + n]
        print(f"  {w.name:14s} native_ok={nat.ok(w.golden())} "
              f"guest_ok={gst.ok(w.golden())} "
              f"overhead={int(gst.instret)/max(int(nat.instret), 1):.2f}x")

    # the multi-tenant column (DESIGN.md §2c): two guests per hart, the HS
    # scheduler round-robins them on timer interrupts every `timeslice`
    print("\npreemptive multi-guest fleet (2 VMs per hart, timer-sliced):")
    pfleet = Fleet.boot(wls, guests_per_hart=2, timeslice=1000)
    t0 = time.time()
    pfleet.run(120000, chunk=8192)
    wall = time.time() - t0
    for label, e in pfleet.report().items():
        print(f"  {label:28s} ok={e['ok']} timer_irqs={e['timer_irqs']} "
              f"ctx_switches={e['ctx_switches']}")
    print(f"preempt fleet wall: {wall:.1f}s")

    # consolidation density (the paper's cloud story): a heterogeneous
    # 4-tenant VM per hart — every slot packs four *different* workloads,
    # each with its own G-stage table set, 64 KiB window, and htimedelta
    # virtual time base.  Reported per-guest via the mailbox checksums.
    print("\nheterogeneous 4-guest fleet (4 mixed tenants per hart):")
    quads = [tuple(wls[(i + k) % len(wls)] for k in range(4))
             for i in range(0, len(wls), 4)]
    hfleet = Fleet.boot(quads, guests_per_hart=4, timeslice=500)
    t0 = time.time()
    hfleet.run(480000, chunk=8192)
    wall = time.time() - t0
    for label, e in hfleet.report().items():
        print(f"  {label:44s} ok={e['ok']} guests_ok={e['ok_guests']} "
              f"irq={e['timer_irqs']} ctxsw={e['ctx_switches']}")
    print(f"4-guest fleet wall: {wall:.1f}s")

    # gem5-style checkpointing + live migration (DESIGN.md §3): run two
    # 2-tenant harts partway, snapshot the whole pod to a versioned .npz,
    # restore it, then evacuate one mid-flight VM from hart 0 to hart 1 —
    # its saved context / G-stage tables / 64 KiB window move wholesale,
    # and the guest still hits its golden checksum on the new hart.
    print("\ncheckpoint/restore + live migration (crc32 evacuates "
          "hart 0 → hart 1):")
    sha, crc, bits, fft = (programs.SHA(), programs.CRC32(),
                           programs.BitCount(), programs.FFT())
    mfleet = Fleet.boot([(sha, crc), (bits, fft)], guests_per_hart=2,
                        timeslice=300)
    mfleet.run(1000, chunk=1024)
    with tempfile.TemporaryDirectory() as td:
        path = f"{td}/pod.npz"
        mfleet.snapshot(path)
        print(f"  snapshot taken mid-run → {path}")
        mfleet = Fleet.restore(path)              # resumes bit-identically
    for _ in range(12):                           # wait until descheduled
        try:
            mfleet.migrate_guest(0, 1, guest=1)
            print("  migrated: hart 0 guest 1 (crc32) → hart 1 slot 1")
            break
        except MigrationError:
            mfleet.run(300, chunk=1024)
    else:
        print("  WARNING: guest never became migratable — demo skipped "
              "the move; reports below are for the unmigrated fleet")
    mfleet.run(120000, chunk=1024)
    for label, e in mfleet.report().items():
        print(f"  {label:32s} ok={e['ok']} guests_ok={e['ok_guests']} "
              f"checksums={[hex(c) for c in e['checksums']]}")


if __name__ == "__main__":
    main()
