"""The TPU-native 'gem5 pod': simulate a fleet of VMs in lockstep with one
vmapped step function — the DESIGN.md §2a adaptation, demonstrated.

All nine MiBench-like workloads run natively AND as guests (18 machines)
inside a single jitted/vmapped scan; per-machine architectural counters come
back as batched tensors.

    PYTHONPATH=src python examples/batched_fleet_sim.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.hext import machine, programs  # noqa: E402


def main():
    wls = programs.WORKLOADS
    with jax.experimental.enable_x64():
        states = [programs.boot_state(w, guest=False) for w in wls] + \
                 [programs.boot_state(w, guest=True) for w in wls]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    print(f"fleet: {len(states)} machines, lockstep vmapped simulation")
    t0 = time.time()
    batch = machine.batched_run_until_done(batch, 120000, chunk=8192)
    wall = time.time() - t0
    done = batch["done"].tolist()
    instret = batch["instret"].tolist()
    total = sum(instret)
    print(f"all done: {all(done)}   total instructions: {total:,}   "
          f"wall: {wall:.1f}s   ({total/wall:,.0f} instr/s aggregate)")
    for i, w in enumerate(wls):
        ok_n = int(batch["exit_code"][i]) == w.golden()
        ok_g = int(batch["exit_code"][i + len(wls)]) == w.golden()
        print(f"  {w.name:14s} native_ok={ok_n} guest_ok={ok_g} "
              f"overhead={instret[i+len(wls)]/max(instret[i],1):.2f}x")


if __name__ == "__main__":
    main()
