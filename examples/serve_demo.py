"""Fleet-as-a-service walkthrough: submit -> evict -> resume -> drain.

Boots a two-hart pod (two scheduler guests per hart) plus one solo lane,
fills it with four long-running tenants, then submits a fifth while
every slot is busy — the control plane parks the youngest guest as a
per-guest checkpoint (eviction), serves the newcomer, resumes the parked
guest into a reserved slot, and drains everything to its registry
golden.  Prints the control-plane event log and a per-tenant
time-to-result table.

Run:
    PYTHONPATH=src python examples/serve_demo.py
"""
from repro.core.hext import programs
from repro.core.hext.policies import BinPackPolicy
from repro.core.hext.service import FleetService

BY_NAME = {w.name: w for w in programs.WORKLOADS}


def main():
    svc = FleetService(n_harts=2, guests_per_hart=2, n_solo=1,
                       timeslice=300, slice_ticks=2048, chunk=512,
                       policy=BinPackPolicy(partial_after=1))

    print("== submit: four long tenants fill both harts ==")
    for tenant, name in enumerate(["qsort", "bitcount", "dijkstra",
                                   "susan"]):
        jid = svc.submit(BY_NAME[name], tenant=tenant)
        print(f"  tenant {tenant}: {name} -> job {jid}")
    svc.step()                       # placement happens on the next round

    print("== submit under pressure: tenant 4 arrives, no free slot ==")
    late = svc.submit(BY_NAME["sha"], tenant=4)
    solo = svc.submit(BY_NAME["crc32"], tenant=5, mode="native")
    print(f"  tenant 4: sha -> job {late} (queued; eviction incoming)")
    print(f"  tenant 5: crc32 -> job {solo} (native solo lane)")

    ok = svc.drain(max_slices=500)
    print(f"\n== drained in {svc.slices} control rounds "
          f"({svc.ticks} simulated ticks), all goldens ok: {ok} ==")
    print("stats:", svc.stats)

    print("\n== per-tenant time-to-result ==")
    print(f"  {'job':>3} {'tenant':>6} {'workload':>12} {'mode':>7} "
          f"{'slices':>6}  ok")
    for j in svc.jobs():
        print(f"  {j.job_id:>3} {j.tenant:>6} {j.name:>12} {j.mode:>7} "
              f"{j.time_to_result():>6}  ok={j.ok}")

    evicted = [j for j in svc.jobs()
               if any("parked" in e for e in j.events)]
    print("\n== control-plane log of the evicted tenant ==")
    for j in evicted:
        for e in j.events:
            print(f"  job {j.job_id}: {e}")

    m = svc.metrics()
    print(f"\np50 time-to-result: {m['p50_ttr_slices']} slices, "
          f"p99: {m['p99_ttr_slices']} slices")
    assert ok and evicted, "demo should evict at least one tenant"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
