"""Quickstart: boot a guest VM under the xvisor-lite hypervisor and compare
it against native execution — the paper's experiment in 30 lines.

    PYTHONPATH=src python examples/quickstart.py [workload]
"""
import sys
import time

sys.path.insert(0, "src")

from repro.core.hext import machine, programs  # noqa: E402


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "crc32"
    wl = next(w for w in programs.WORKLOADS if w.name == name)
    print(f"workload: {wl.name}   golden checksum: {wl.golden()}")
    for guest in (False, True):
        label = "guest (two-stage, xvisor-lite)" if guest else "native"
        st = programs.boot_state(wl, guest=guest)
        t0 = time.time()
        st = machine.run_until_done(st, max_ticks=120000, chunk=8192)
        ok = int(st["exit_code"]) == wl.golden()
        exc = st["exc_by_level"].tolist()
        print(f"{label:34s} checksum_ok={ok}  instret={int(st['instret'])}  "
              f"exceptions M/HS/VS={exc}  pagefaults={int(st['pagefaults'])}"
              f"  wall={time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
