"""Quickstart: boot a guest VM under the xvisor-lite hypervisor and compare
it against native execution — the paper's experiment in 30 lines.

The optional second argument picks the execution backend (DESIGN.md §3):
``jit`` (default), ``sharded`` (pmap over jax.devices()), or ``oracle``
(the pure-Python reference model — slow, but great for differential
debugging: every counter, `walks` included, matches the device engines
bit-for-bit).

Run with the package on the path (see DESIGN.md §6):

    PYTHONPATH=src python examples/quickstart.py [workload] [engine]
"""
import sys
import time

from repro.core.hext import programs
from repro.core.hext.engine import ENGINES
from repro.core.hext.sim import Fleet


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "crc32"
    engine = sys.argv[2] if len(sys.argv) > 2 else "jit"
    by_name = {w.name: w for w in programs.WORKLOADS}
    if name not in by_name:
        sys.exit(f"unknown workload {name!r}; "
                 f"choose from: {', '.join(sorted(by_name))}")
    if engine not in ENGINES:
        sys.exit(f"unknown engine {engine!r}; "
                 f"choose from: {', '.join(sorted(ENGINES))}")
    wl = by_name[name]
    print(f"workload: {wl.name}   golden checksum: {wl.golden()}   "
          f"engine: {engine}")
    fleet = Fleet.boot([wl, wl], guest=[False, True], engine=engine)
    t0 = time.time()
    fleet.run(max_ticks=120000, chunk=8192)
    wall = time.time() - t0
    for spec, c in zip(fleet.specs, fleet.counters()):
        label = ("guest (two-stage, xvisor-lite)" if spec.guest else "native")
        print(f"{label:34s} checksum_ok={c.ok(wl.golden())}  "
              f"instret={int(c.instret)}  "
              f"exceptions M/HS/VS={c.exc_by_level.tolist()}  "
              f"pagefaults={int(c.pagefaults)}")
    print(f"fleet wall={wall:.1f}s (both machines in one lockstep run)")


if __name__ == "__main__":
    main()
