"""Multi-tenant serving with the two-stage paged KV cache.

Three tenants with different page quotas submit batched requests; the
scheduler handles translation faults exactly like the H extension handles
guest page faults (stage-1 edit by the tenant, stage-2 allocation by the
"hypervisor" + hfence), and tears a tenant down with one stage-2 sweep.

    PYTHONPATH=src python examples/serve_paged.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tf
from repro.runtime.serve_loop import PagedServer, Request


def main():
    cfg = get_config("qwen3_moe_30b_a3b", reduced=True)
    params, _ = tf.init_lm(cfg, jax.random.PRNGKey(0))
    server = PagedServer(cfg, params, page_size=8, n_slots=96, n_tenants=3,
                         quotas=[24, 12, 4], max_batch=6)
    rng = np.random.default_rng(0)
    for i in range(9):
        server.submit(Request(
            req_id=i, tenant=i % 3,
            prompt=rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
            max_new=6))
    stats = server.run_until_drained()
    print("stats:", stats)
    print("pool used per tenant:", np.asarray(server.kv.pool.used))
    print("evicting tenant 0 (one stage-2 sweep)…")
    server.evict_tenant(0)
    print("pool used per tenant:", np.asarray(server.kv.pool.used))
    assert int(server.kv.pool.used[0]) == 0


if __name__ == "__main__":
    main()
