"""End-to-end training driver example: train a ~small LM for a few hundred
steps on CPU with checkpointing + auto-resume, and show the loss falling.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    args = ["--arch", "minicpm_2b", "--reduced", "--steps", "200",
            "--batch", "8", "--seq", "64", "--schedule", "wsd",
            "--ckpt-dir", "/tmp/repro_train_small",
            "--ckpt-every", "100"]
    if "--steps" in sys.argv:
        i = sys.argv.index("--steps")
        args[args.index("--steps") + 1] = sys.argv[i + 1]
    losses = train_main(args)
    assert losses[-1] < losses[0], "loss should fall"
    print("OK: loss fell from %.3f to %.3f" % (losses[0], losses[-1]))
